//! Fleet policies: cross-session arbitration of the *host-level* knobs.
//!
//! On a multi-tenant host (see [`crate::sim::Simulation`] and the fleet
//! driver in [`crate::sim::fleet`]), individual sessions keep tuning their
//! own channel counts, but the shared knobs — active cores, CPU frequency
//! and the per-session channel budget — belong to one [`FleetPolicy`]
//! arbitrating on aggregate telemetry. Per-session governors are disabled
//! in fleet mode so tenants cannot fight over the package
//! ([`crate::config::experiment::GovernorKind::None`]).
//!
//! Two policies ship:
//!
//! * [`FairShare`] — the static reference: performance governor, equal
//!   channel budget per active session;
//! * [`MinEnergyFleet`] — Algorithm 3 generalized from one session's load
//!   to the host's *aggregate* load, so capacity follows the sum of all
//!   tenants' demand instead of any single transfer.

use super::load_control::LoadThresholds;
use crate::config::experiment::TunerParams;
use crate::cpusim::{CpuSpec, CpuState};
use crate::sim::FleetView;

/// Host-level actuation a policy hands back to the fleet driver.
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetDirective {
    /// Cap each active session's channel count (None = leave tenants
    /// alone). Enforced after every tenant tuning step, and applied to
    /// sessions admitted between arbitrations.
    pub per_session_channel_cap: Option<u32>,
    /// Total channel budget to split across active sessions in
    /// proportion to their *remaining bytes* (None = no weighted split).
    /// When set, the driver derives per-tenant caps via
    /// [`weighted_caps`] at each arbitration instead of the uniform
    /// `per_session_channel_cap`, which then only covers sessions
    /// admitted before the next arbitration.
    pub weighted_channel_budget: Option<u32>,
}

/// A cross-session arbitration policy, invoked once per fleet interval.
///
/// `Send` is a supertrait: each host's policy travels with its
/// crate-internal `HostWorld` (`crate::sim::fleet`) when the sharded
/// dispatcher fans hosts out across worker threads (arbitration itself
/// still runs at segment boundaries, inside the shard that owns the
/// host).
pub trait FleetPolicy: std::fmt::Debug + Send {
    /// Policy name for outcomes and telemetry.
    fn name(&self) -> &'static str;

    /// The host CPU setting the fleet starts at.
    fn initial_cpu(&self, spec: &CpuSpec) -> CpuState;

    /// Inspect aggregate host telemetry, actuate the shared client CPU
    /// setting, and return per-session constraints.
    fn arbitrate(&mut self, view: &FleetView, client: &mut CpuState) -> FleetDirective;
}

/// Equal split of a total channel budget over the active sessions.
fn fair_cap(max_total_channels: u32, active_sessions: u32) -> u32 {
    (max_total_channels / active_sessions.max(1)).max(1)
}

/// Split a total channel budget over sessions in proportion to their
/// remaining bytes: largest-remainder rounding of `weight_i × total`,
/// floored at one channel per session (matching [`fair_cap`]'s floor —
/// with a budget below one-per-session the sum exceeds the budget rather
/// than starving anyone). All-zero remainders fall back to the equal
/// split. Deterministic: remainder ties break to the lower index.
pub fn weighted_caps(total: u32, remaining_bytes: &[f64]) -> Vec<u32> {
    let n = remaining_bytes.len();
    if n == 0 {
        return Vec::new();
    }
    let sum: f64 = remaining_bytes.iter().map(|r| r.max(0.0)).sum();
    if sum <= 0.0 {
        return vec![fair_cap(total, n as u32); n];
    }
    let total = total.max(1);
    let mut caps: Vec<u32> = Vec::with_capacity(n);
    let mut fracs: Vec<(usize, f64)> = Vec::with_capacity(n);
    let mut assigned = 0u32;
    for (i, r) in remaining_bytes.iter().enumerate() {
        let share = r.max(0.0) / sum * total as f64;
        let floor = (share.floor() as u32).max(1);
        fracs.push((i, share - share.floor()));
        caps.push(floor);
        assigned += floor;
    }
    // Hand out what largest-remainder rounding still owes; never claw
    // back below the one-channel floor.
    fracs.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    let mut fi = 0;
    while assigned < total {
        caps[fracs[fi % n].0] += 1;
        assigned += 1;
        fi += 1;
    }
    while assigned > total {
        // Trim the largest cap above the floor (ties to the lower index).
        match (0..n)
            .filter(|&i| caps[i] > 1)
            .max_by(|&a, &b| caps[a].cmp(&caps[b]).then_with(|| b.cmp(&a)))
        {
            Some(k) => {
                caps[k] -= 1;
                assigned -= 1;
            }
            None => break, // everyone at the floor: accept the overshoot
        }
    }
    caps
}

/// Static reference policy: the host runs the performance governor and
/// every tenant gets an equal slice of the channel budget.
#[derive(Debug, Clone)]
pub struct FairShare {
    /// Total channel budget split across active sessions.
    pub max_total_channels: u32,
}

impl FleetPolicy for FairShare {
    fn name(&self) -> &'static str {
        "fair-share"
    }

    fn initial_cpu(&self, spec: &CpuSpec) -> CpuState {
        CpuState::performance(spec.clone())
    }

    fn arbitrate(&mut self, view: &FleetView, _client: &mut CpuState) -> FleetDirective {
        FleetDirective {
            per_session_channel_cap: Some(fair_cap(
                self.max_total_channels,
                view.active_sessions,
            )),
            weighted_channel_budget: None,
        }
    }
}

/// [`FairShare`] with remaining-bytes-weighted channel budgets instead of
/// the equal split: the host still runs the performance governor, but the
/// arbitration hands each session a slice of the total channel budget
/// proportional to its remaining bytes (see [`weighted_caps`]) — heavy
/// tenants hold the concurrency, nearly-done tenants release it early.
#[derive(Debug, Clone)]
pub struct WeightedShare {
    /// Total channel budget split across active sessions by remaining
    /// bytes.
    pub max_total_channels: u32,
}

impl FleetPolicy for WeightedShare {
    fn name(&self) -> &'static str {
        "weighted-share"
    }

    fn initial_cpu(&self, spec: &CpuSpec) -> CpuState {
        CpuState::performance(spec.clone())
    }

    fn arbitrate(&mut self, view: &FleetView, _client: &mut CpuState) -> FleetDirective {
        FleetDirective {
            // Equal-split fallback for sessions admitted before the next
            // arbitration recomputes the weighted slices.
            per_session_channel_cap: Some(fair_cap(
                self.max_total_channels,
                view.active_sessions,
            )),
            weighted_channel_budget: Some(self.max_total_channels),
        }
    }
}

/// Algorithm 3 lifted to the host: threshold-based core/frequency scaling
/// driven by the *aggregate* CPU load of all tenants, plus the same fair
/// channel split. Starts from the minimum-energy operating point and lets
/// demand pull capacity up.
#[derive(Debug, Clone)]
pub struct MinEnergyFleet {
    /// Algorithm 3 load thresholds.
    pub thresholds: LoadThresholds,
    /// Total channel budget split across active sessions.
    pub max_total_channels: u32,
}

impl FleetPolicy for MinEnergyFleet {
    fn name(&self) -> &'static str {
        "min-energy-fleet"
    }

    fn initial_cpu(&self, spec: &CpuSpec) -> CpuState {
        CpuState::min_energy_start(spec.clone())
    }

    fn arbitrate(&mut self, view: &FleetView, client: &mut CpuState) -> FleetDirective {
        // Lines 2–13 of Algorithm 3, with `cpuLoad` replaced by the mean
        // host load over the interval: cores first on the way up (an extra
        // core at low frequency is cheaper than a voltage bump on all
        // active cores), frequency first on the way down.
        if view.avg_load > self.thresholds.max_load {
            if !client.increase_cores() {
                client.increase_freq();
            }
        } else if view.avg_load < self.thresholds.min_load {
            if !client.decrease_freq() {
                client.decrease_cores();
            }
        }
        FleetDirective {
            per_session_channel_cap: Some(fair_cap(
                self.max_total_channels,
                view.active_sessions,
            )),
            weighted_channel_budget: None,
        }
    }
}

/// Every fleet policy the driver and the CLI can construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetPolicyKind {
    /// Static performance governor + equal channel split.
    FairShare,
    /// Static performance governor + remaining-bytes-weighted channel
    /// split ([`WeightedShare`]).
    WeightedShare,
    /// Aggregate-load Algorithm 3 + equal channel split.
    MinEnergyFleet,
}

impl FleetPolicyKind {
    /// Stable identifier used by the CLI.
    pub fn id(&self) -> &'static str {
        match self {
            FleetPolicyKind::FairShare => "fairshare",
            FleetPolicyKind::WeightedShare => "weightedshare",
            FleetPolicyKind::MinEnergyFleet => "minenergy",
        }
    }

    /// Parse a CLI identifier (accepts common spellings).
    pub fn parse(id: &str) -> Option<FleetPolicyKind> {
        Some(match id {
            "fairshare" | "fair-share" => FleetPolicyKind::FairShare,
            "weightedshare" | "weighted-share" | "weighted" => {
                FleetPolicyKind::WeightedShare
            }
            "minenergy" | "min-energy" | "min-energy-fleet" => {
                FleetPolicyKind::MinEnergyFleet
            }
            _ => return None,
        })
    }

    /// Instantiate the policy; the tenant tuner params supply the shared
    /// channel budget and thresholds.
    pub fn build(&self, params: &TunerParams) -> Box<dyn FleetPolicy> {
        match self {
            FleetPolicyKind::FairShare => {
                Box::new(FairShare { max_total_channels: params.max_ch })
            }
            FleetPolicyKind::WeightedShare => {
                Box::new(WeightedShare { max_total_channels: params.max_ch })
            }
            FleetPolicyKind::MinEnergyFleet => Box::new(MinEnergyFleet {
                thresholds: params.thresholds,
                max_total_channels: params.max_ch,
            }),
        }
    }
}

/// Session-placement policies for the multi-host dispatcher
/// ([`crate::sim::dispatcher`]): given the per-host candidate snapshots
/// the dispatcher builds, decide which host an arriving session lands on.
/// The selection itself lives in
/// [`Dispatcher::place`](crate::sim::dispatcher::Dispatcher::place); this
/// enum is the policy identity shared by the CLI, configs and telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    /// Cycle through hosts in order, skipping full ones — the classic
    /// load-oblivious baseline.
    RoundRobin,
    /// The host with the fewest active sessions wins (ties go to the
    /// lowest host index).
    LeastLoaded,
    /// GreenDataFlow-style scoring (arXiv:1810.05892): the host with the
    /// lowest predicted *marginal energy per byte* wins — the delta in
    /// whole-host power between its post-placement and current operating
    /// points (both priced by [`crate::power::PowerModel::at`]), divided
    /// by the new session's expected goodput there.
    MarginalEnergy,
    /// `MarginalEnergy` corrected by experience (historical-log learning,
    /// arXiv:2104.01192): the model score is blended with the
    /// history-observed J/B of similar workloads on each host, weighted
    /// by the observation's k-NN confidence (see
    /// [`crate::history::KnnIndex::observed_j_per_byte`]). Identical to
    /// `MarginalEnergy` when the run has no history attached or the
    /// store knows nothing relevant.
    Learned,
}

impl PlacementKind {
    /// Stable identifier used by the CLI and in telemetry.
    pub fn id(&self) -> &'static str {
        match self {
            PlacementKind::RoundRobin => "roundrobin",
            PlacementKind::LeastLoaded => "leastloaded",
            PlacementKind::MarginalEnergy => "marginalenergy",
            PlacementKind::Learned => "learned",
        }
    }

    /// Parse a CLI identifier (accepts common spellings).
    pub fn parse(id: &str) -> Option<PlacementKind> {
        Some(match id {
            "roundrobin" | "round-robin" | "rr" => PlacementKind::RoundRobin,
            "leastloaded" | "least-loaded" | "least" => PlacementKind::LeastLoaded,
            "marginalenergy" | "marginal-energy" | "marginal" | "me" => {
                PlacementKind::MarginalEnergy
            }
            "learned" | "history" => PlacementKind::Learned,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpusim::standard::broadwell_client;
    use crate::units::{Power, Rate, SimTime};

    fn view(load: f64, sessions: u32) -> FleetView {
        FleetView {
            now: SimTime::from_secs(10.0),
            active_sessions: sessions,
            avg_load: load,
            avg_server_load: 0.3,
            avg_throughput: Rate::from_mbps(800.0),
            avg_power: Power::from_watts(40.0),
        }
    }

    #[test]
    fn ids_round_trip() {
        for kind in [
            FleetPolicyKind::FairShare,
            FleetPolicyKind::WeightedShare,
            FleetPolicyKind::MinEnergyFleet,
        ] {
            assert_eq!(FleetPolicyKind::parse(kind.id()), Some(kind));
        }
        assert_eq!(
            FleetPolicyKind::parse("weighted"),
            Some(FleetPolicyKind::WeightedShare)
        );
        assert!(FleetPolicyKind::parse("bogus").is_none());
    }

    #[test]
    fn weighted_caps_follow_remaining_bytes() {
        // 3:1 remaining split of a 48-channel budget → 36/12.
        let caps = weighted_caps(48, &[30e9, 10e9]);
        assert_eq!(caps, vec![36, 12]);
        assert_eq!(caps.iter().sum::<u32>(), 48, "budget conserved");
        // A nearly-done tenant keeps the one-channel floor.
        let caps = weighted_caps(48, &[47.9e9, 0.1e9]);
        assert_eq!(caps.iter().sum::<u32>(), 48);
        assert!(caps[1] >= 1 && caps[0] > 40, "floor holds, heavy tenant dominates");
        // All-zero remainders fall back to the equal split.
        assert_eq!(weighted_caps(48, &[0.0, 0.0, 0.0]), vec![16, 16, 16]);
        // Budget below one-per-session floors at 1 each (like fair_cap).
        assert_eq!(weighted_caps(2, &[1e9, 1e9, 1e9]), vec![1, 1, 1]);
        assert_eq!(weighted_caps(5, &[]), Vec::<u32>::new());
        // Deterministic under exact ties.
        assert_eq!(weighted_caps(7, &[1e9, 1e9]), weighted_caps(7, &[1e9, 1e9]));
        assert_eq!(weighted_caps(7, &[1e9, 1e9]).iter().sum::<u32>(), 7);
    }

    #[test]
    fn weighted_share_hands_out_the_budget_and_the_fallback_cap() {
        let mut p = WeightedShare { max_total_channels: 48 };
        let cpu0 = p.initial_cpu(&broadwell_client());
        assert!(cpu0.at_max_cores() && cpu0.at_max_freq());
        let mut cpu = cpu0.clone();
        let d = p.arbitrate(&view(0.9, 4), &mut cpu);
        assert_eq!(d.weighted_channel_budget, Some(48));
        assert_eq!(d.per_session_channel_cap, Some(12), "equal-split fallback");
        assert!(cpu.at_max_cores() && cpu.at_max_freq(), "never touches the CPU");
        // The equal-split policies never request a weighted split.
        let mut fair = FairShare { max_total_channels: 48 };
        assert_eq!(
            fair.arbitrate(&view(0.9, 4), &mut cpu).weighted_channel_budget,
            None
        );
    }

    #[test]
    fn placement_ids_round_trip() {
        for kind in [
            PlacementKind::RoundRobin,
            PlacementKind::LeastLoaded,
            PlacementKind::MarginalEnergy,
            PlacementKind::Learned,
        ] {
            assert_eq!(PlacementKind::parse(kind.id()), Some(kind));
        }
        assert_eq!(PlacementKind::parse("rr"), Some(PlacementKind::RoundRobin));
        assert_eq!(PlacementKind::parse("marginal"), Some(PlacementKind::MarginalEnergy));
        assert_eq!(PlacementKind::parse("history"), Some(PlacementKind::Learned));
        assert!(PlacementKind::parse("bogus").is_none());
    }

    #[test]
    fn fair_share_pins_performance_and_splits_evenly() {
        let mut p = FairShare { max_total_channels: 48 };
        let cpu0 = p.initial_cpu(&broadwell_client());
        assert!(cpu0.at_max_cores() && cpu0.at_max_freq());
        let mut cpu = cpu0.clone();
        let d = p.arbitrate(&view(0.9, 4), &mut cpu);
        assert_eq!(d.per_session_channel_cap, Some(12));
        assert!(cpu.at_max_cores() && cpu.at_max_freq(), "never touches the CPU");
    }

    #[test]
    fn min_energy_fleet_tracks_aggregate_load() {
        let params = TunerParams::default();
        let mut p = MinEnergyFleet {
            thresholds: params.thresholds,
            max_total_channels: params.max_ch,
        };
        let mut cpu = p.initial_cpu(&broadwell_client());
        assert_eq!(cpu.active_cores(), 1);
        assert!(cpu.at_min_freq());
        // High aggregate load grows cores first.
        p.arbitrate(&view(0.95, 4), &mut cpu);
        assert_eq!(cpu.active_cores(), 2);
        assert!(cpu.at_min_freq());
        // Sustained pressure walks all the way up.
        for _ in 0..40 {
            p.arbitrate(&view(0.95, 4), &mut cpu);
        }
        assert!(cpu.at_max_cores() && cpu.at_max_freq());
        // Low aggregate load sheds frequency first.
        p.arbitrate(&view(0.1, 4), &mut cpu);
        assert!(cpu.at_max_cores() && !cpu.at_max_freq());
    }

    #[test]
    fn cap_floors_at_one_channel_per_session() {
        let mut p = FairShare { max_total_channels: 4 };
        let mut cpu = p.initial_cpu(&broadwell_client());
        let d = p.arbitrate(&view(0.5, 9), &mut cpu);
        assert_eq!(d.per_session_channel_cap, Some(1));
    }
}
