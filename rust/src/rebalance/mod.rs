//! Fleet rebalancer: cap-aware preemption and live session migration.
//!
//! The paper's algorithms tune a transfer *in place*; the dispatcher
//! ([`crate::sim::dispatcher`]) decides *where* a session runs — but only
//! once, at admission. At fleet scale the biggest remaining energy lever
//! is moving work *between* hosts after admission: a host that saturates,
//! or a power cap that tightens mid-run, strands sessions on an operating
//! point the dispatcher would never choose today. This subsystem is that
//! missing decision layer, one level above placement:
//!
//! * **policy** ([`policy`]) — [`RebalancePolicyKind`]: `Off` (the
//!   bit-for-bit status quo), `CapPressure` (move sessions only while the
//!   projected aggregate fleet power exceeds the admission cap) and
//!   `MarginalEnergyDelta` (move whenever another host would serve a
//!   session's *remaining* bytes at a sufficiently lower marginal J/B,
//!   GreenDataFlow-style — arXiv:1810.05892 — but applied to running
//!   sessions);
//! * **cost** ([`cost`]) — an explicit [`MigrationCost`] model: a move is
//!   never free. The session drains its streams, waits a configurable
//!   handoff delay, and re-enters TCP slow start plus the coordinator's
//!   slow-start FSM on the target, so the estimated joules of the move
//!   must be beaten by the estimated joules saved before a move is
//!   proposed;
//! * **executor** ([`executor`]) — the [`Rebalancer`]: scans
//!   [`HostView`] snapshots at dispatcher segment boundaries and proposes
//!   at most one [`MoveProposal`] per boundary (the driver executes it:
//!   preempt, emit partial-run accounting and a
//!   [`MigrationRecord`](crate::sim::MigrationRecord), re-admit the
//!   remaining bytes after the drain). Per-session move budgets stop
//!   ping-pong.
//!
//! Invariants (pinned by `rust/tests/rebalance_migration.rs`):
//! **byte conservation** — a migrated session delivers exactly its
//! dataset's bytes, split across its partial and resumed runs; **no
//! migration during drain** — a session in handoff is resident nowhere
//! and cannot be proposed again until it is running again; **`Off` is
//! inert** — with the policy off the dispatcher is bit-for-bit today's.

pub mod cost;
pub mod executor;
pub mod policy;

pub use cost::{contention_price_j_per_byte, MigrationCost};
pub use executor::{HostView, MoveProposal, MoveVerdict, Rebalancer, SessionView};
pub use policy::{RebalanceConfig, RebalancePolicyKind};
