//! End-system CPU substrate: cores, DVFS P-states, utilization.
//!
//! The paper's load-control module (Algorithm 3) observes `cpuLoad` and
//! actuates two knobs: the number of *active cores* (offlining via CPU
//! hotplug / cpusets) and the *core frequency* (a P-state ladder shared by
//! all active cores, as on the paper's Haswell/Broadwell testbeds).
//!
//! This module models the mechanics the algorithm interacts with:
//!
//! * [`CpuSpec`] — a CPU model: core count, P-state ladder, and the cycle
//!   costs of transfer work (cycles/byte for the network stack + memcpy,
//!   cycles/request for protocol processing, polling overhead per stream);
//! * [`CpuState`] — current (active cores, frequency) setting;
//! * [`CpuDemand`] / [`CpuSpec::load`] — translate transfer activity into
//!   CPU utilization, and — when the CPU saturates — back-pressure the
//!   achievable throughput ([`CpuSpec::achievable_bytes_per_sec`]), which
//!   is exactly why running at minimum frequency can slow a 10 Gbps
//!   transfer and why Algorithm 3 exists.

mod spec;
mod state;

pub use spec::{standard, CpuDemand, CpuSpec};
pub use state::CpuState;
