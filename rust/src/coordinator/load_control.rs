//! Algorithm 3 — threshold-based dynamic frequency and core scaling.
//!
//! Called by every tuning algorithm at each timeout. When CPU load is
//! above `max_load`, it first brings more cores online, then raises the
//! frequency; when load is below `min_load`, it first lowers the
//! frequency, then takes cores offline. (Cores-before-frequency on the way
//! up is the energy-aware ordering: an extra core at low frequency is
//! cheaper than a voltage bump on all active cores.)
//!
//! The [`Governor`] trait abstracts the policy so the predictive governor
//! (PJRT-compiled energy model, see [`crate::predictor`]) can be swapped
//! in for the paper's threshold policy; `NullGovernor` disables scaling
//! entirely (Figure 4's "w/o scaling" ablation and all baselines).

use crate::cpusim::CpuState;
use crate::sim::Telemetry;

/// Decision thresholds of Algorithm 3.
#[derive(Debug, Clone, Copy)]
pub struct LoadThresholds {
    /// `maxLoad`: above this, add capacity.
    pub max_load: f64,
    /// `minLoad`: below this, remove capacity.
    pub min_load: f64,
}

impl Default for LoadThresholds {
    fn default() -> Self {
        // The paper does not publish its thresholds; 0.85/0.40 keeps a
        // safety margin above and avoids oscillation between the bands.
        LoadThresholds { max_load: 0.85, min_load: 0.40 }
    }
}

/// A CPU-scaling policy invoked once per tuning timeout. `Send` is a
/// supertrait so a session carrying one can cross the sharded
/// dispatcher's worker threads with its host (the predictive governor's
/// compiled PJRT artifact is a per-thread cache for exactly this
/// reason — see [`crate::runtime::Executable`]).
pub trait Governor: std::fmt::Debug + Send {
    /// Inspect the interval telemetry and adjust the client CPU setting.
    fn control(&mut self, telemetry: &Telemetry, cpu: &mut CpuState);
    /// Governor name for traces.
    fn name(&self) -> &'static str;
}

/// Algorithm 3 verbatim.
#[derive(Debug, Clone, Default)]
pub struct ThresholdGovernor {
    /// Algorithm 3 load thresholds.
    pub thresholds: LoadThresholds,
}

impl ThresholdGovernor {
    /// A threshold governor with the given thresholds.
    pub fn new(thresholds: LoadThresholds) -> Self {
        ThresholdGovernor { thresholds }
    }
}

impl Governor for ThresholdGovernor {
    fn control(&mut self, telemetry: &Telemetry, cpu: &mut CpuState) {
        let load = telemetry.cpu_load;
        if load > self.thresholds.max_load {
            // Lines 2–7: grow capacity — cores first, then frequency.
            if !cpu.increase_cores() {
                cpu.increase_freq();
            }
        } else if load < self.thresholds.min_load {
            // Lines 8–13: shrink capacity — frequency first, then cores.
            if !cpu.decrease_freq() {
                cpu.decrease_cores();
            }
        }
    }

    fn name(&self) -> &'static str {
        "threshold"
    }
}

/// No scaling at all (a pinned `performance` governor). Kept for tests and
/// as an explicit configuration.
#[derive(Debug, Clone, Default)]
pub struct NullGovernor;

impl Governor for NullGovernor {
    fn control(&mut self, _telemetry: &Telemetry, _cpu: &mut CpuState) {}

    fn name(&self) -> &'static str {
        "null"
    }
}

/// The OS default on the paper's testbeds: Linux `ondemand`. Tracks load
/// by moving the shared frequency so utilization sits near `target_util`;
/// never offlines cores (only the paper's load-control module does that).
///
/// Real ondemand reacts at millisecond scale; we apply the equivalent
/// steady-state frequency at each tuning timeout, which is equivalent at
/// the tick resolution of the simulator. All baselines and the Figure 4
/// "w/o scaling" ablation run under this governor.
#[derive(Debug, Clone)]
pub struct OndemandGovernor {
    /// Utilization level ondemand steers the CPU toward.
    pub target_util: f64,
}

impl Default for OndemandGovernor {
    fn default() -> Self {
        OndemandGovernor { target_util: 0.7 }
    }
}

impl Governor for OndemandGovernor {
    fn control(&mut self, telemetry: &Telemetry, cpu: &mut CpuState) {
        // demand (cycles/s) = load * cores * f_current; pick the lowest
        // ladder frequency that keeps utilization at or below the target.
        let demand = telemetry.cpu_load * cpu.active_cores() as f64 * cpu.freq().as_hz();
        let wanted_hz = demand / (cpu.active_cores() as f64 * self.target_util);
        cpu.apply(cpu.active_cores(), crate::units::Freq::from_hz(wanted_hz));
    }

    fn name(&self) -> &'static str {
        "ondemand"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpusim::standard::haswell_server;
    use crate::units::{Bytes, Energy, Power, Rate, SimDuration, SimTime};

    fn tel(load: f64) -> Telemetry {
        Telemetry {
            now: SimTime::ZERO,
            avg_throughput: Rate::from_mbps(500.0),
            interval_energy: Energy::from_joules(10.0),
            avg_power: Power::from_watts(30.0),
            cpu_load: load,
            remaining: Bytes::from_gb(1.0),
            total: Bytes::from_gb(2.0),
            elapsed: SimDuration::from_secs(3.0),
            num_channels: 4,
            open_streams: 8,
            net: Default::default(),
        }
    }

    #[test]
    fn high_load_adds_cores_before_frequency() {
        let mut g = ThresholdGovernor::default();
        let mut cpu = CpuState::min_energy_start(haswell_server());
        g.control(&tel(0.95), &mut cpu);
        assert_eq!(cpu.active_cores(), 2, "core first");
        assert!(cpu.at_min_freq(), "freq untouched while cores remain");
    }

    #[test]
    fn high_load_raises_freq_when_cores_maxed() {
        let mut g = ThresholdGovernor::default();
        let mut cpu = CpuState::max_throughput_start(haswell_server());
        assert!(cpu.at_max_cores());
        let f0 = cpu.freq();
        g.control(&tel(0.95), &mut cpu);
        assert!(cpu.freq() > f0);
    }

    #[test]
    fn low_load_lowers_freq_before_cores() {
        let mut g = ThresholdGovernor::default();
        let mut cpu = CpuState::performance(haswell_server());
        let cores0 = cpu.active_cores();
        g.control(&tel(0.1), &mut cpu);
        assert_eq!(cpu.active_cores(), cores0, "cores untouched while freq can drop");
        assert!(!cpu.at_max_freq());
    }

    #[test]
    fn low_load_drops_cores_at_min_freq() {
        let mut g = ThresholdGovernor::default();
        let mut cpu = CpuState::max_throughput_start(haswell_server()); // min freq
        let cores0 = cpu.active_cores();
        g.control(&tel(0.1), &mut cpu);
        assert_eq!(cpu.active_cores(), cores0 - 1);
    }

    #[test]
    fn mid_band_load_is_stable() {
        let mut g = ThresholdGovernor::default();
        let mut cpu = CpuState::new(haswell_server(), 4, crate::units::Freq::from_ghz(2.0));
        let (c0, f0) = (cpu.active_cores(), cpu.freq());
        for _ in 0..10 {
            g.control(&tel(0.6), &mut cpu);
        }
        assert_eq!((cpu.active_cores(), cpu.freq()), (c0, f0));
    }

    #[test]
    fn repeated_pressure_walks_to_max() {
        let mut g = ThresholdGovernor::default();
        let mut cpu = CpuState::min_energy_start(haswell_server());
        for _ in 0..40 {
            g.control(&tel(0.95), &mut cpu);
        }
        assert!(cpu.at_max_cores() && cpu.at_max_freq());
    }

    #[test]
    fn null_governor_never_moves() {
        let mut g = NullGovernor;
        let mut cpu = CpuState::performance(haswell_server());
        g.control(&tel(0.99), &mut cpu);
        g.control(&tel(0.01), &mut cpu);
        assert!(cpu.at_max_cores() && cpu.at_max_freq());
    }
}
