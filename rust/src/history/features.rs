//! Feature extraction: turn a workload + its context into the normalized,
//! discretized vector the k-NN index measures distances in.
//!
//! Following the decision-tree line of work on historical transfer logs
//! (arXiv:2204.07601), every numeric feature is log- or range-scaled into
//! roughly `[0, 1]` and then *discretized* onto a fixed grid
//! ([`QUANT_BINS`] levels) before any distance is computed. Discretization
//! does two jobs: it makes near-identical workloads (same dataset family,
//! different generator seed) land on exactly the same grid point, and it
//! keeps the index deterministic — distances are sums of exact multiples
//! of `1/QUANT_BINS`, so ordering never depends on float noise.

use crate::dataset::Dataset;

/// Files strictly smaller than this many bytes are "small" (the Table II
/// small family averages ~100 KB).
pub const SMALL_FILE_MAX_BYTES: f64 = 1e6;
/// Files up to this many bytes are "medium"; larger ones are "large"
/// (the Table II large family averages ~223 MB).
pub const MEDIUM_FILE_MAX_BYTES: f64 = 64e6;

/// Number of discretization levels per feature dimension.
pub const QUANT_BINS: f64 = 32.0;

/// Dimensionality of the numeric feature vector.
pub const FEATURE_DIMS: usize = 9;

/// The shape of a workload, as the history subsystem fingerprints it at
/// admission time: total volume, file-count, and the byte-weighted
/// small/medium/large class mix. Derivable from any [`Dataset`] without
/// keeping the file list alive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadFingerprint {
    /// Total bytes to move.
    pub total_bytes: f64,
    /// Number of files.
    pub num_files: u64,
    /// Mean file size, bytes.
    pub avg_file_bytes: f64,
    /// Fraction of bytes in files smaller than [`SMALL_FILE_MAX_BYTES`].
    pub frac_small: f64,
    /// Fraction of bytes in files between the small and medium bounds.
    pub frac_medium: f64,
    /// Fraction of bytes in files larger than [`MEDIUM_FILE_MAX_BYTES`].
    pub frac_large: f64,
}

impl WorkloadFingerprint {
    /// Fingerprint a dataset (one pass over the file list).
    pub fn of(dataset: &Dataset) -> WorkloadFingerprint {
        let mut total = 0.0f64;
        let mut small = 0.0f64;
        let mut medium = 0.0f64;
        let mut large = 0.0f64;
        for f in &dataset.files {
            let sz = f.size.as_f64();
            total += sz;
            if sz < SMALL_FILE_MAX_BYTES {
                small += sz;
            } else if sz <= MEDIUM_FILE_MAX_BYTES {
                medium += sz;
            } else {
                large += sz;
            }
        }
        let n = dataset.files.len();
        let denom = if total > 0.0 { total } else { 1.0 };
        WorkloadFingerprint {
            total_bytes: total,
            num_files: n as u64,
            avg_file_bytes: if n == 0 { 0.0 } else { total / n as f64 },
            frac_small: small / denom,
            frac_medium: medium / denom,
            frac_large: large / denom,
        }
    }
}

/// A "workload like this" question put to the k-NN index: the fingerprint
/// plus the context the answer must transfer to. `testbed` and
/// `algorithm` are categorical — a mismatch adds a fixed distance penalty
/// instead of filtering, so sparse stores still answer (with lower
/// confidence); `None` matches everything penalty-free.
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    /// Workload shape.
    pub workload: WorkloadFingerprint,
    /// Testbed name to prefer records from (`None` = indifferent).
    pub testbed: Option<String>,
    /// Path round-trip time, seconds.
    pub rtt_s: f64,
    /// Path bandwidth, bits/s.
    pub bandwidth_bps: f64,
    /// Sessions already active on the host at admission time.
    pub contention: u32,
    /// Algorithm id to prefer records from (`None` = indifferent).
    pub algorithm: Option<String>,
}

impl Query {
    /// A query for `workload` on `testbed` with `contention` concurrent
    /// sessions already running.
    pub fn on_testbed(
        testbed: &crate::config::Testbed,
        workload: WorkloadFingerprint,
        contention: u32,
    ) -> Query {
        Query {
            workload,
            testbed: Some(testbed.name.to_string()),
            rtt_s: testbed.link.rtt.as_secs(),
            bandwidth_bps: testbed.link.capacity.as_bits_per_sec(),
            contention,
            algorithm: None,
        }
    }

    /// Restrict the query to records from one algorithm id.
    pub fn with_algorithm(mut self, id: impl Into<String>) -> Query {
        self.algorithm = Some(id.into());
        self
    }
}

/// A normalized, discretized feature vector (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FeatureVec(pub [f64; FEATURE_DIMS]);

/// Snap a scaled feature onto the [`QUANT_BINS`] grid (clamped to
/// `[0, 2]` so outliers cannot dominate the distance).
fn quantize(x: f64) -> f64 {
    (x.clamp(0.0, 2.0) * QUANT_BINS).round() / QUANT_BINS
}

/// Build the feature vector for a workload in its context.
pub fn features(
    w: &WorkloadFingerprint,
    rtt_s: f64,
    bandwidth_bps: f64,
    contention: u32,
) -> FeatureVec {
    FeatureVec([
        quantize(w.total_bytes.max(1.0).log10() / 12.0),
        quantize((w.num_files.max(1) as f64).log10() / 6.0),
        quantize(w.avg_file_bytes.max(1.0).log10() / 10.0),
        quantize(w.frac_small),
        quantize(w.frac_medium),
        quantize(w.frac_large),
        quantize(rtt_s.max(0.0) * 10.0),
        quantize(bandwidth_bps.max(1.0).log10() / 11.0),
        quantize(contention as f64 / 8.0),
    ])
}

/// Euclidean distance between two feature vectors.
pub fn distance(a: &FeatureVec, b: &FeatureVec) -> f64 {
    a.0.iter()
        .zip(b.0.iter())
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::standard;

    #[test]
    fn fingerprint_classes_partition_the_bytes() {
        let fp = WorkloadFingerprint::of(&standard::mixed_dataset(3));
        assert!((fp.frac_small + fp.frac_medium + fp.frac_large - 1.0).abs() < 1e-12);
        // Mixed = small (~1.9 GB) + medium (~11.7 GB) + large (~27.9 GB):
        // the large files dominate the byte mix.
        assert!(fp.frac_large > 0.5, "large fraction {}", fp.frac_large);
        assert!(fp.frac_small < 0.1);
        assert_eq!(fp.num_files, 25_128);
    }

    #[test]
    fn fingerprint_of_empty_dataset_is_safe() {
        let fp = WorkloadFingerprint::of(&Dataset::new("empty", vec![]));
        assert_eq!(fp.total_bytes, 0.0);
        assert_eq!(fp.avg_file_bytes, 0.0);
        assert_eq!(fp.frac_small + fp.frac_medium + fp.frac_large, 0.0);
    }

    #[test]
    fn same_family_different_seed_lands_on_the_same_grid_point() {
        // The whole point of discretization: generator noise between seeds
        // must not perturb the feature vector.
        let a = WorkloadFingerprint::of(&standard::medium_dataset(1));
        let b = WorkloadFingerprint::of(&standard::medium_dataset(2));
        let fa = features(&a, 0.044, 1e9, 0);
        let fb = features(&b, 0.044, 1e9, 0);
        assert_eq!(fa, fb, "seed noise must quantize away");
        assert_eq!(distance(&fa, &fb), 0.0);
    }

    #[test]
    fn different_families_are_far_apart() {
        let small = WorkloadFingerprint::of(&standard::small_dataset(1));
        let large = WorkloadFingerprint::of(&standard::large_dataset(1));
        let fs = features(&small, 0.044, 1e9, 0);
        let fl = features(&large, 0.044, 1e9, 0);
        assert!(distance(&fs, &fl) > 0.5, "distance {}", distance(&fs, &fl));
    }

    #[test]
    fn query_on_testbed_captures_the_path() {
        let tb = testbeds::didclab();
        let q = Query::on_testbed(&tb, WorkloadFingerprint::of(&standard::small_dataset(1)), 2);
        assert_eq!(q.testbed.as_deref(), Some("DIDCLab"));
        assert!((q.rtt_s - 0.044).abs() < 1e-9);
        assert!((q.bandwidth_bps - 1e9).abs() < 1.0);
        assert_eq!(q.contention, 2);
        assert_eq!(q.with_algorithm("me").algorithm.as_deref(), Some("me"));
    }
}
