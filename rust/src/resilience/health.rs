//! The health monitor: notice a host degrading *before* it dies.
//!
//! The retry pipeline reacts to failures after the fact; the monitor
//! is the proactive half. The dispatcher feeds it one observation per
//! host per segment — delivered goodput versus the host's own
//! projection — and when a host underdelivers past
//! [`HealthConfig::degrade_ratio`] for a full
//! [`HealthConfig::dwell_s`] dwell, the monitor emits one
//! [`Advisory`]. Advisories feed the rebalancer's evacuation path:
//! sessions leave a degrading host on the ordinary migration machinery
//! (drain, re-ramp, byte conservation) instead of waiting to be lost.
//!
//! One advisory per degradation episode: the monitor stays latched
//! until the host recovers (ratio back above the threshold, or no
//! meaningful demand left to judge), then re-arms. Pure logic — the
//! monitor never touches the simulation, it only compares the two
//! numbers it is handed.

/// Knobs of the [`HealthMonitor`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthConfig {
    /// A host is degrading while `observed / expected` sits below this.
    pub degrade_ratio: f64,
    /// How long the ratio must stay below before an advisory fires,
    /// seconds — one slow segment is noise, a dwell is a signal.
    pub dwell_s: f64,
    /// Expected-goodput floor, bytes/s: below it the host has no
    /// meaningful demand (idle, or everything already evacuated) and
    /// the monitor treats it as signal-free rather than stalled.
    pub min_expected_bps: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig { degrade_ratio: 0.5, dwell_s: 30.0, min_expected_bps: 1e6 }
    }
}

/// One emitted degradation advisory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Advisory {
    /// The degrading host.
    pub host: usize,
    /// When the advisory fired, simulated seconds.
    pub at_secs: f64,
    /// Delivered goodput at that instant, bytes/s.
    pub observed_bps: f64,
    /// What the host's projection said it should deliver, bytes/s.
    pub expected_bps: f64,
    /// When the host first dipped below the ratio (the dwell start).
    pub below_since_secs: f64,
}

/// Per-host dwell state.
#[derive(Debug, Clone, Copy, Default)]
struct HostHealth {
    /// When the current below-ratio stretch began (`None` = healthy).
    below_since: Option<f64>,
    /// True once this episode's advisory has fired.
    advised: bool,
}

/// Tracks per-host stall/degradation episodes (see the module docs).
#[derive(Debug, Clone)]
pub struct HealthMonitor {
    cfg: HealthConfig,
    states: Vec<HostHealth>,
}

impl HealthMonitor {
    /// A monitor for `hosts` hosts, all healthy.
    pub fn new(cfg: HealthConfig, hosts: usize) -> Self {
        HealthMonitor { cfg, states: vec![HostHealth::default(); hosts] }
    }

    /// Feed one observation for `host`. Returns the episode's advisory
    /// when the dwell just elapsed; `None` otherwise (healthy, still
    /// dwelling, or already advised this episode).
    pub fn observe(
        &mut self,
        host: usize,
        now_secs: f64,
        observed_bps: f64,
        expected_bps: f64,
    ) -> Option<Advisory> {
        let st = &mut self.states[host];
        if expected_bps < self.cfg.min_expected_bps
            || observed_bps >= self.cfg.degrade_ratio * expected_bps
        {
            // Healthy (or signal-free): end the episode and re-arm.
            st.below_since = None;
            st.advised = false;
            return None;
        }
        let since = *st.below_since.get_or_insert(now_secs);
        if !st.advised && now_secs - since + 1e-9 >= self.cfg.dwell_s {
            st.advised = true;
            return Some(Advisory {
                host,
                at_secs: now_secs,
                observed_bps,
                expected_bps,
                below_since_secs: since,
            });
        }
        None
    }

    /// True while `host` is in an advised (latched) degradation
    /// episode — the evacuation trigger.
    pub fn is_degraded(&self, host: usize) -> bool {
        self.states[host].advised
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor() -> HealthMonitor {
        HealthMonitor::new(HealthConfig::default(), 2)
    }

    #[test]
    fn advisory_fires_only_after_the_dwell() {
        let mut m = monitor();
        // 10% of expectation: clearly degraded, but the dwell gates it.
        assert!(m.observe(0, 0.0, 1e7, 1e8).is_none());
        assert!(m.observe(0, 15.0, 1e7, 1e8).is_none(), "still dwelling");
        let a = m.observe(0, 30.0, 1e7, 1e8).expect("dwell elapsed");
        assert_eq!(a.host, 0);
        assert_eq!(a.below_since_secs, 0.0);
        assert!(m.is_degraded(0));
        // Latched: the episode advises once.
        assert!(m.observe(0, 45.0, 1e7, 1e8).is_none());
        assert!(!m.is_degraded(1), "other hosts independent");
    }

    #[test]
    fn recovery_ends_the_episode_and_rearms() {
        let mut m = monitor();
        assert!(m.observe(0, 0.0, 1e7, 1e8).is_none());
        let _ = m.observe(0, 30.0, 1e7, 1e8).expect("advised");
        // Back above the ratio: episode over.
        assert!(m.observe(0, 40.0, 9e7, 1e8).is_none());
        assert!(!m.is_degraded(0));
        // A fresh dip starts a fresh dwell — and advises again.
        assert!(m.observe(0, 50.0, 1e7, 1e8).is_none());
        assert!(m.observe(0, 80.0, 1e7, 1e8).is_some(), "re-armed episode advises");
    }

    #[test]
    fn tiny_expectations_are_signal_free() {
        let mut m = monitor();
        // Below the demand floor nothing is judged — an idle host never
        // reads as stalled, whatever its observed goodput.
        for t in 0..100 {
            assert!(m.observe(0, t as f64, 0.0, 1e3).is_none());
        }
        assert!(!m.is_degraded(0));
    }

    #[test]
    fn healthy_hosts_never_advise() {
        let mut m = monitor();
        for t in 0..100 {
            assert!(m.observe(0, t as f64, 8e7, 1e8).is_none());
        }
        assert!(!m.is_degraded(0));
    }
}
