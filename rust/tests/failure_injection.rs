//! Failure injection: scripted bandwidth collapses mid-transfer.
//!
//! The FSM (Figure 1) exists precisely for these events: Warning/Recovery
//! must distinguish "too many channels" from "the path lost capacity",
//! and the algorithms must neither stall nor spiral.

use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::dataset::standard;
use greendt::netsim::BandwidthEvent;
use greendt::sim::session::{run_session, SessionConfig};
use greendt::units::{Rate, SimTime};

fn drop_events(at: f64, until: f64, severity: f64) -> Vec<BandwidthEvent> {
    vec![
        BandwidthEvent { at: SimTime::from_secs(at), mean_fraction: severity },
        BandwidthEvent { at: SimTime::from_secs(until), mean_fraction: 0.08 },
    ]
}

#[test]
fn eemt_survives_a_half_capacity_dip() {
    let cfg = SessionConfig::new(
        testbeds::cloudlab(),
        standard::large_dataset(42),
        AlgorithmKind::MaxThroughput,
    )
    .with_bandwidth_events(drop_events(30.0, 90.0, 0.55))
    .recording();
    let out = run_session(&cfg);
    assert!(out.completed, "must finish despite the dip");

    // Throughput must visibly fall inside the window and recover after.
    let during: Vec<f64> = out
        .timeline
        .iter()
        .filter(|p| p.t_secs > 35.0 && p.t_secs < 85.0)
        .map(|p| p.throughput.as_mbps())
        .collect();
    let after: Vec<f64> = out
        .timeline
        .iter()
        .filter(|p| p.t_secs > 100.0)
        .map(|p| p.throughput.as_mbps())
        .collect();
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    assert!(mean(&during) < 550.0, "congested mean {}", mean(&during));
    if !after.is_empty() {
        assert!(mean(&after) > 750.0, "recovered mean {}", mean(&after));
    }
}

#[test]
fn eett_reacquires_target_after_event_clears() {
    let target = Rate::from_mbps(400.0);
    let cfg = SessionConfig::new(
        testbeds::cloudlab(),
        standard::mixed_dataset(42),
        AlgorithmKind::TargetThroughput(target),
    )
    .with_bandwidth_events(drop_events(40.0, 80.0, 0.7))
    .recording();
    let out = run_session(&cfg);
    assert!(out.completed);
    // After the event clears, tracking must return to the band.
    let tail: Vec<f64> = out
        .timeline
        .iter()
        .filter(|p| p.t_secs > 110.0)
        .map(|p| p.throughput.as_mbps())
        .collect();
    if tail.len() >= 5 {
        let mean = tail.iter().sum::<f64>() / tail.len() as f64;
        assert!(
            (mean - 400.0).abs() / 400.0 < 0.3,
            "post-event tracking mean {mean} vs target 400"
        );
    }
}

#[test]
fn me_does_not_stall_under_repeated_dips() {
    let events: Vec<BandwidthEvent> = (0..5)
        .flat_map(|k| {
            let base = 20.0 + 40.0 * k as f64;
            drop_events(base, base + 20.0, 0.6)
        })
        .collect();
    let cfg = SessionConfig::new(
        testbeds::cloudlab(),
        standard::large_dataset(42),
        AlgorithmKind::MinEnergy,
    )
    .with_bandwidth_events(events);
    let out = run_session(&cfg);
    assert!(out.completed, "repeated dips must not stall ME");
    assert!(out.avg_throughput.as_mbps() > 300.0, "tput {}", out.avg_throughput);
}

#[test]
fn total_blackoutish_event_only_delays_completion() {
    // 95% of capacity vanishes for a minute; the floor keeps a trickle.
    let cfg = SessionConfig::new(
        testbeds::cloudlab(),
        standard::medium_dataset(42),
        AlgorithmKind::MaxThroughput,
    )
    .with_bandwidth_events(drop_events(20.0, 80.0, 0.85));
    let out = run_session(&cfg);
    assert!(out.completed);
    // Clean run takes ~105 s; with the event it must take noticeably more.
    assert!(out.duration.as_secs() > 130.0, "duration {}", out.duration);
}

#[test]
fn fsm_visits_warning_or_recovery_during_the_dip() {
    // The FSM trace must show the algorithm actually *reacting*: at least
    // one Warning or Recovery occupancy while the path is congested.
    let cfg = SessionConfig::new(
        testbeds::cloudlab(),
        standard::large_dataset(42),
        AlgorithmKind::MaxThroughput,
    )
    .with_bandwidth_events(drop_events(30.0, 120.0, 0.6))
    .recording();
    let out = run_session(&cfg);
    assert!(out.completed);
    let reacted = out
        .timeline
        .iter()
        .any(|p| p.fsm == "warning" || p.fsm == "recovery");
    assert!(reacted, "FSM never left increase: {:?}",
        out.timeline.iter().map(|p| p.fsm).collect::<Vec<_>>());
}
