//! The resilience benchmark scenario: one fault script, recovery on
//! vs off — shared by `cargo bench --bench bench_resilience`, the
//! `fleet_faults` example and the integration tests, so every consumer
//! measures the same story.
//!
//! The script: two single-slot hosts, `steady` (CloudLab, efficient)
//! and `flaky` (DIDCLAB, legacy, wall-metered). Two sessions arrive
//! together; the dispatcher puts `anchor` on the efficient host, which
//! forces `victim` onto the legacy one. At [`DEGRADE_AT_S`] the flaky
//! host's link collapses to a [`DEGRADED_FRACTION`] background
//! fraction, and at [`DEATH_AT_S`] its transfer service dies for good.
//!
//! With recovery off the victim crawls on the degraded link until the
//! crash dead-letters it: bytes are lost, and the fleet pays the
//! legacy host's wall draw for the whole stretch. With recovery on the
//! health monitor notices the goodput crater, latches an advisory, and
//! the rebalancer evacuates the victim to the efficient host as soon
//! as the anchor's slot frees — the run finishes earlier, delivers
//! every byte, and never meters the long crawl. That is the acceptance
//! claim in one scenario: recovery wins goodput *and* joules.

use crate::config::testbeds;
use crate::coordinator::{AlgorithmKind, PlacementKind};
use crate::dataset::standard;
use crate::resilience::{FaultSchedule, ResilienceConfig};
use crate::sim::dispatcher::{DispatchOutcome, DispatcherConfig, HostSpec, SessionSpec};
use crate::units::SimTime;

/// When the flaky host's link collapses, simulated seconds.
pub const DEGRADE_AT_S: f64 = 40.0;

/// When the flaky host's transfer service dies, simulated seconds.
/// Late enough that the degraded victim cannot finish first (a
/// `large` dataset needs far longer than the crawl window allows), so
/// the recovery-off run always loses bytes.
pub const DEATH_AT_S: f64 = 800.0;

/// Background fraction in force while degraded: sessions keep ~15% of
/// the bottleneck (the `quiet` process ceiling — higher requests
/// clamp there anyway).
pub const DEGRADED_FRACTION: f64 = 0.85;

/// The scripted fault sequence on the flaky host (index 1): link
/// collapse at [`DEGRADE_AT_S`], death at [`DEATH_AT_S`].
pub fn fault_schedule() -> FaultSchedule {
    FaultSchedule::default()
        .with_link_degrade(
            1,
            SimTime::from_secs(DEGRADE_AT_S),
            SimTime::from_secs(DEATH_AT_S),
            DEGRADED_FRACTION,
        )
        .with_host_failure(1, SimTime::from_secs(DEATH_AT_S), None)
}

/// The benchmark dispatcher config, identical apart from the recovery
/// switch: same hosts, sessions, seed and fault script.
pub fn scenario(recovery: bool) -> DispatcherConfig {
    let hosts = vec![
        HostSpec::new("steady", testbeds::cloudlab()).with_max_sessions(1),
        HostSpec::new("flaky", testbeds::didclab()).with_max_sessions(1),
    ];
    let sessions = vec![
        SessionSpec::new("anchor", standard::medium_dataset(21), AlgorithmKind::MaxThroughput),
        SessionSpec::new("victim", standard::large_dataset(22), AlgorithmKind::MaxThroughput),
    ];
    let mut resilience = ResilienceConfig::new().with_faults(fault_schedule());
    if recovery {
        resilience = resilience.with_recovery();
    }
    DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(42)
        .with_resilience(resilience)
}

/// The figures the acceptance criteria compare, reduced from one run.
#[derive(Debug, Clone, Copy)]
pub struct FaultRunSummary {
    /// Bytes delivered across the fleet (partial residencies included).
    pub delivered_bytes: f64,
    /// Run makespan, seconds.
    pub duration_s: f64,
    /// Fleet goodput: delivered bytes over the makespan.
    pub goodput_bps: f64,
    /// Total client instrument energy, joules.
    pub joules: f64,
    /// Sessions quarantined (dead letters plus overflow).
    pub dead_lettered: u64,
    /// True when every session finished.
    pub completed: bool,
}

/// Reduce a dispatcher outcome to the figures the bench compares.
pub fn summarize(out: &DispatchOutcome) -> FaultRunSummary {
    let fleet = &out.fleet;
    let delivered = fleet.moved.as_f64();
    let duration = fleet.duration.as_secs();
    FaultRunSummary {
        delivered_bytes: delivered,
        duration_s: duration,
        goodput_bps: if duration > 0.0 { delivered / duration } else { 0.0 },
        joules: fleet.client_energy.as_joules(),
        dead_lettered: fleet.dead_letters.len() as u64 + fleet.dead_letter_overflow,
        completed: fleet.completed,
    }
}

/// Assert the acceptance invariant on an (off, on) outcome pair:
/// recovery-on completes, delivers strictly more goodput, and spends
/// no more energy than recovery-off; recovery-off quarantines the
/// victim. Panics with the offending figures otherwise.
pub fn assert_recovery_wins(off: &FaultRunSummary, on: &FaultRunSummary) {
    assert!(!off.completed, "recovery-off must lose the victim to the crash");
    assert!(off.dead_lettered > 0, "recovery-off must quarantine the victim");
    assert!(on.completed, "recovery-on must deliver every session");
    assert_eq!(on.dead_lettered, 0, "recovery-on must quarantine nothing");
    assert!(
        on.goodput_bps > off.goodput_bps,
        "recovery-on goodput {:.3e} B/s must beat recovery-off {:.3e} B/s",
        on.goodput_bps,
        off.goodput_bps
    );
    assert!(
        on.joules <= off.joules,
        "recovery-on spent {:.1} J, more than recovery-off {:.1} J",
        on.joules,
        off.joules
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_configs_differ_only_in_recovery() {
        let off = scenario(false);
        let on = scenario(true);
        assert!(!off.resilience.enabled);
        assert!(on.resilience.enabled);
        assert!(off.resilience.active(), "faults alone keep the pipeline active");
        assert_eq!(off.resilience.faults, on.resilience.faults);
        assert_eq!(off.hosts.len(), 2);
        assert_eq!(off.sessions.len(), 2);
    }

    #[test]
    fn fault_script_is_valid_for_the_two_host_fleet() {
        assert!(fault_schedule().validate(2).is_ok());
        assert!(fault_schedule().validate(1).is_err(), "targets host 1");
    }

    #[test]
    fn script_orders_degrade_before_death() {
        assert!(DEGRADE_AT_S < DEATH_AT_S);
        let mut t = fault_schedule().timeline();
        let first = t.pop_due(DEATH_AT_S).expect("degrade first");
        assert_eq!(first.at, SimTime::from_secs(DEGRADE_AT_S));
    }
}
