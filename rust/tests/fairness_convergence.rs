//! Fairness of competing flows on one shared bottleneck.
//!
//! The paper's testbeds are shared WAN paths: whenever two transfer
//! sessions overlap, the per-channel FSM plus the host's fair-share
//! allocation decide who gets the pipe. These tests pin the convergence
//! contract for both channel FSMs — the legacy slow-start-then-hold
//! model and the AIMD competing-flow dynamics — on quiet and contended
//! links: a flow joining an occupied bottleneck must converge to its
//! fair share (Jain index >= 0.95 over residency-normalized goodput),
//! and the incumbent must actually give that share up.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, FleetPolicyKind};
use greendt::dataset::standard;
use greendt::netsim::CrossTrafficConfig;
use greendt::sim::fleet::{run_fleet, FleetConfig, FleetOutcome, TenantSpec};
use greendt::units::SimTime;

/// Two identical large transfers on one CloudLab host; the second joins
/// the occupied link 5 s in. Static 8-channel sessions (no tuner) keep
/// both flows demanding well above the fair share for the whole run, so
/// the outcome isolates the channel FSM + allocator.
fn staggered_cfg(aimd: bool, cross: Option<CrossTrafficConfig>, seed: u64) -> FleetConfig {
    let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
        .with_seed(seed)
        .with_aimd(aimd);
    if let Some(cross) = cross {
        cfg = cfg.with_cross_traffic(cross);
    }
    for (name, at) in [("incumbent", 0.0), ("joiner", 5.0)] {
        cfg.tenants.push(
            TenantSpec::new(name, standard::large_dataset(seed), AlgorithmKind::NoTune(8))
                .arriving_at(SimTime::from_secs(at)),
        );
    }
    cfg
}

fn assert_fair(out: &FleetOutcome, label: &str) {
    assert!(out.completed, "{label}: both flows must finish");
    let j = out.jain_fairness();
    assert!(
        j >= 0.95,
        "{label}: staggered flows must converge to fair shares, Jain {j:.4}"
    );
    // Fairness must come from actual sharing, not from the flows taking
    // turns: the runs overlap for almost their whole lifetime.
    let first_out = out
        .tenants
        .iter()
        .map(|t| t.finished_at.unwrap().as_secs())
        .fold(f64::INFINITY, f64::min);
    assert!(
        first_out > 0.5 * out.duration.as_secs(),
        "{label}: flows must overlap, first finished at {first_out:.0} s \
         of a {} run",
        out.duration
    );
}

#[test]
fn staggered_flows_converge_on_a_quiet_link() {
    for aimd in [false, true] {
        let out = run_fleet(&staggered_cfg(aimd, None, 5));
        assert_fair(&out, &format!("quiet/aimd={aimd}"));
    }
}

#[test]
fn staggered_flows_converge_under_cross_traffic() {
    let cross = CrossTrafficConfig {
        udp_fraction: 0.1,
        tcp_rate_per_sec: 0.3,
        tcp_burst_bytes: 20e6,
        tcp_burst_secs: 1.0,
    };
    for aimd in [false, true] {
        let out = run_fleet(&staggered_cfg(aimd, Some(cross), 5));
        assert_fair(&out, &format!("contended/aimd={aimd}"));
    }
}

#[test]
fn the_joiner_costs_the_incumbent_real_bandwidth() {
    // Convergence to a fair share has to mean the incumbent slowed
    // down: against a solo run of the same transfer, sharing the
    // bottleneck must push its finish time out substantially.
    let solo = {
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
            .with_seed(5);
        cfg.tenants.push(TenantSpec::new(
            "incumbent",
            standard::large_dataset(5),
            AlgorithmKind::NoTune(8),
        ));
        run_fleet(&cfg)
    };
    let shared = run_fleet(&staggered_cfg(false, None, 5));
    let solo_finish = solo.tenants[0].finished_at.unwrap().as_secs();
    let shared_finish = shared
        .tenants
        .iter()
        .find(|t| t.name == "incumbent")
        .unwrap()
        .finished_at
        .unwrap()
        .as_secs();
    assert!(
        shared_finish > 1.5 * solo_finish,
        "the incumbent must cede bandwidth: solo {solo_finish:.0} s vs \
         shared {shared_finish:.0} s"
    );
}

#[test]
fn aimd_changes_the_trajectory_but_not_the_fairness() {
    // The two FSMs are genuinely different dynamics — same workload,
    // different bits — yet both land at the fair split. (The AIMD-off
    // path being bit-identical to the pre-AIMD engine is pinned in the
    // stepper_equivalence suite.)
    let hold = run_fleet(&staggered_cfg(false, None, 7));
    let aimd = run_fleet(&staggered_cfg(true, None, 7));
    assert_fair(&hold, "trajectory/hold");
    assert_fair(&aimd, "trajectory/aimd");
    assert_ne!(
        hold.duration.as_secs().to_bits(),
        aimd.duration.as_secs().to_bits(),
        "AIMD must actually change the window dynamics"
    );
}

#[test]
fn contended_fairness_is_seed_reproducible() {
    // The generators are the only stochastic input; the whole fairness
    // figure must be a pure function of the seed.
    let cross = CrossTrafficConfig {
        udp_fraction: 0.1,
        tcp_rate_per_sec: 0.3,
        tcp_burst_bytes: 20e6,
        tcp_burst_secs: 1.0,
    };
    let a = run_fleet(&staggered_cfg(true, Some(cross), 11));
    let b = run_fleet(&staggered_cfg(true, Some(cross), 11));
    assert_eq!(a.jain_fairness().to_bits(), b.jain_fairness().to_bits());
    assert_eq!(a.duration.as_secs().to_bits(), b.duration.as_secs().to_bits());
}
