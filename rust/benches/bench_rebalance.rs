//! Rebalancer bench: decision cost at fleet scale plus the end-to-end
//! price of running a rebalancing dispatcher, written to
//! `BENCH_rebalance.json` (the committed seed carries the schema; CI
//! regenerates and uploads the file next to `BENCH_hotpath.json`).
//!
//!     cargo bench --bench bench_rebalance
//!
//! Micro: `Rebalancer::propose` over a synthetic 16-host / 128-session
//! snapshot — what every segment boundary pays while a policy is active —
//! and the `weighted_caps` split. Macro: the hot-spot migration scenario
//! end-to-end with the rebalancer off vs on (`marginal-delta`), so the
//! decision layer's wall-clock overhead and the migration machinery are
//! both on the record.

use greendt::benchkit::{bench, time_once, BenchReport};
use greendt::config::testbeds;
use greendt::coordinator::fleet::weighted_caps;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::dataset::standard;
use greendt::rebalance::{
    HostView, RebalanceConfig, RebalancePolicyKind, Rebalancer, SessionView,
};
use greendt::sim::dispatcher::{run_dispatcher, DispatcherConfig, HostSpec, SessionSpec};
use greendt::units::SimTime;

/// A 16-host fleet snapshot with 8 sessions per host and mild
/// heterogeneity, so proposals must actually compare candidates.
fn synthetic_views() -> Vec<HostView> {
    (0..16usize)
        .map(|i| {
            let active = 8u32;
            let idle = 15.0 + i as f64;
            let per_session = 4.0 + ((i * 5) % 11) as f64;
            HostView {
                host: i,
                active,
                free_slots: if i % 4 == 0 { 0 } else { 4 },
                idle_power_w: idle,
                power_now_w: idle + per_session * active as f64,
                power_minus_one_w: idle + per_session * (active - 1) as f64,
                power_plus_one_w: idle + per_session * (active + 1) as f64,
                session_bps_now: 40e6 + (i as f64) * 2e6,
                session_bps_plus_one: 36e6 + (i as f64) * 2e6,
                session_bps_alone: 110e6,
                rtt_s: 0.036,
                sessions: (0..active)
                    .map(|s| SessionView {
                        tenant: s as usize,
                        name: format!("h{i}-s{s}"),
                        remaining_bytes: 1e9 + (s as f64) * 3e9,
                    })
                    .collect(),
            }
        })
        .collect()
}

/// The `fleet_rebalance` example's hot-spot scenario (a stranded long
/// session the rebalancer rescues), as the macro workload.
fn hotspot(policy: RebalancePolicyKind) -> DispatcherConfig {
    let hosts = vec![
        HostSpec::new("efficient", testbeds::cloudlab()).with_max_sessions(1),
        HostSpec::new("legacy", testbeds::didclab()).with_max_sessions(1),
    ];
    let sessions = vec![
        SessionSpec::new("short", standard::medium_dataset(11), AlgorithmKind::MaxThroughput),
        SessionSpec::new("long", standard::medium_dataset(12), AlgorithmKind::MaxThroughput)
            .arriving_at(SimTime::from_secs(5.0)),
    ];
    let mut cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(42);
    cfg.rebalance = RebalanceConfig::new(policy);
    cfg
}

fn main() {
    println!("== bench_rebalance: fleet rebalancer decision + migration cost ==\n");
    let mut reports: Vec<BenchReport> = Vec::new();

    // Micro: one proposal scan per policy over the 16-host snapshot.
    let views = synthetic_views();
    for policy in [RebalancePolicyKind::CapPressure, RebalancePolicyKind::MarginalEnergyDelta] {
        let r = Rebalancer::new(RebalanceConfig::new(policy));
        let cap = Some(500.0);
        reports.push(bench(
            &format!("rebalance propose/{}/16 hosts x 8", policy.id()),
            200,
            20_000,
            || r.propose(&views, cap),
        ));
    }

    // Micro: the weighted channel split at a plausible tenant count.
    let remaining: Vec<f64> = (0..64).map(|i| 1e9 + (i as f64) * 7e8).collect();
    let caps_bench = bench("weighted_caps/64 tenants", 200, 50_000, || {
        weighted_caps(48, &remaining)
    });
    reports.push(caps_bench);

    // Macro: the hot-spot scenario end-to-end, rebalancer off vs on.
    let (off, off_s) = time_once("run_dispatcher/hotspot/rebalance off", || {
        run_dispatcher(&hotspot(RebalancePolicyKind::Off))
    });
    assert!(off.fleet.completed && off.migrations.is_empty());
    let (on, on_s) = time_once("run_dispatcher/hotspot/marginal-delta", || {
        run_dispatcher(&hotspot(RebalancePolicyKind::MarginalEnergyDelta))
    });
    assert!(on.fleet.completed, "rebalancing run must finish");

    // Machine-readable record, next to BENCH_hotpath.json.
    let micro: Vec<String> = reports.iter().map(|r| r.to_json()).collect();
    let json = format!(
        "{{\n  \"bench\": \"rebalance\",\n  \"measured\": true,\n  \
         \"macro\": {{\n    \"off_wall_seconds\": {},\n    \"on_wall_seconds\": {},\n    \
         \"migrations\": {}\n  }},\n  \"micro\": [{}]\n}}\n",
        off_s,
        on_s,
        on.migrations.len(),
        micro.join(",")
    );
    std::fs::write("BENCH_rebalance.json", json).expect("writing BENCH_rebalance.json");
    println!("\nbench report written to BENCH_rebalance.json");
}
