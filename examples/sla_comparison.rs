//! SLA comparison: the same dataset under the three SLA policies.
//!
//!     cargo run --release --example sla_comparison
//!
//! Minimum Energy (Alg. 4), Energy-Efficient Maximum Throughput (Alg. 5)
//! and Energy-Efficient Target Throughput (Alg. 6, target = 40% of the
//! pipe) move the mixed dataset over Chameleon; the table shows the
//! throughput ↔ energy trade each SLA buys.

use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::dataset::standard;
use greendt::metrics::Table;
use greendt::sim::session::{run_session, SessionConfig};
use greendt::units::Rate;

fn main() {
    let cases = [
        ("ME (min energy)", AlgorithmKind::MinEnergy),
        ("EEMT (max throughput)", AlgorithmKind::MaxThroughput),
        ("EETT (target 4 Gbps)", AlgorithmKind::TargetThroughput(Rate::from_gbps(4.0))),
    ];

    let mut table = Table::new(
        "SLA comparison — Chameleon, mixed dataset",
        &["SLA", "throughput", "duration", "client energy", "final CPU"],
    );

    for (label, kind) in cases {
        let cfg =
            SessionConfig::new(testbeds::chameleon(), standard::mixed_dataset(42), kind);
        let out = run_session(&cfg);
        assert!(out.completed, "{label} must complete");
        table.push_row(vec![
            label.to_string(),
            format!("{}", out.avg_throughput),
            format!("{}", out.duration),
            format!("{}", out.client_energy),
            format!("{} cores @ {}", out.final_active_cores, out.final_freq),
        ]);
    }

    println!("{}", table.to_markdown());
    println!("Reading the table: EEMT buys speed with a few extra joules; ME gives some");
    println!("throughput back for the lowest energy; EETT holds the pipe at the SLA rate.");
}
