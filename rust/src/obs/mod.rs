//! Deterministic, zero-dependency observability: lifecycle spans,
//! decision events, counters, percentile histograms (ISSUE 9) and the
//! analysis layer on top of them — decision calibration and structural
//! trace diffing (ISSUE 10).
//!
//! The paper's algorithms live on runtime measurements — throughput
//! deltas, power draw, tuning reactions per monitoring interval — yet
//! until this subsystem the reproduction only reported end-of-run
//! aggregates. `obs` adds the missing substrate in five pieces:
//!
//! * **[`trace`]** — sim-clock spans (`session` → `admit` residencies,
//!   `slow_start`, `migrate`, `penalty_box`) and instant decision events
//!   (`tune`, `placement`/`placement_score`, `rebalance_proposal`
//!   including rejected candidates, `cap_event`, `fault`, `retry`,
//!   `complete`/`dead_letter`) with parent links, versioned JSONL
//!   serialization and a Chrome `trace_event` export for Perfetto;
//! * **[`metrics`]** — counters, gauges and exact-percentile log2-bucket
//!   histograms, snapshotted per dispatcher segment into a
//!   [`MetricsTimeline`];
//! * **[`summarize`]** — the read side: parse a trace back, rebuild
//!   per-session span trees, check connectivity, render waterfalls and
//!   histogram tables (the `greendt trace` CLI);
//! * **[`calibrate`]** — the decision calibration ledger: join each
//!   placement's and migration's *predicted* joules-per-byte against
//!   the realized bytes/joules at residency close (bit-reconciled with
//!   [`crate::sim::FleetOutcome`]), flag anomalies, and run the
//!   starved-queue / fairness-drop watchdogs;
//! * **[`diff`]** — `greendt trace diff A B`: structural, seed-matched
//!   diffing of two trace logs or metrics documents, turning the
//!   determinism contract into an A/B debugging tool.
//!
//! The governing constraint is *determinism preservation*: tracing off
//! is bit-identical to an untraced run (every hook is a pure read behind
//! an `Option`), and trace bytes are bit-identical across `--shards`
//! 1/2/8 (emission only at segment boundaries, per-host buffers merged
//! in host-index order — the PR-6 lockstep discipline). The one
//! deliberately shard-*sensitive* series, warm/slow stepper occupancy,
//! lives in metrics only — see [`metrics`]'s module docs, and note that
//! [`diff`] excludes exactly that carve-out. Pinned by
//! `rust/tests/trace_determinism.rs` and
//! `rust/tests/calibration_diff.rs`.

pub mod calibrate;
pub mod diff;
pub mod metrics;
pub mod summarize;
pub mod trace;

pub use calibrate::{
    jain_index, CalibrationAnomaly, CalibrationConfig, CalibrationLedger,
    CalibrationRecord, MigrationCalibration,
};
pub use diff::{MetricsDelta, MetricsDiff, RecordDelta, SessionDelta, TraceDiff};
pub use metrics::{
    FleetMetrics, Histogram, MetricsRegistry, MetricsTimeline, SegmentSnapshot,
    METRICS_FORMAT_VERSION,
};
pub use summarize::{SessionTree, TraceLog};
pub use trace::{
    chrome_trace_json, trace_jsonl, AttrValue, TraceBuf, TraceRecord, TraceSink,
    TRACE_FORMAT_VERSION,
};
