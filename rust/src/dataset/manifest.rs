//! Dataset manifests: load and save real file listings.
//!
//! Besides the synthetic Table II generators, GreenDT can transfer a
//! *real* dataset described by a manifest — a CSV of `name,size_bytes`
//! rows (what `find -printf '%p,%s\n'` produces). This is how a
//! downstream user points the tuner at their actual corpus.

use super::{Dataset, FileSpec};
use crate::units::Bytes;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Parse manifest text (`name,size_bytes` per line; `#` comments and a
/// `name,size` header row are tolerated).
pub fn parse_manifest(name: &str, text: &str) -> Result<Dataset> {
    let mut files = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (_, size_str) = line
            .rsplit_once(',')
            .with_context(|| format!("manifest line {}: expected 'name,size'", idx + 1))?;
        let size_str = size_str.trim();
        // Header detection is explicit: the first row may be `…,size`.
        if idx == 0 && size_str.eq_ignore_ascii_case("size") {
            continue;
        }
        let size: f64 = size_str
            .parse()
            .with_context(|| format!("manifest line {}: bad size '{size_str}'", idx + 1))?;
        if size < 0.0 {
            bail!("manifest line {}: negative size", idx + 1);
        }
        files.push(FileSpec::new(files.len() as u32, Bytes::new(size)));
    }
    if files.is_empty() {
        bail!("manifest contains no files");
    }
    Ok(Dataset::new(name, files))
}

/// Load a manifest file.
pub fn load_manifest(path: impl AsRef<Path>) -> Result<Dataset> {
    let path = path.as_ref();
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("manifest");
    parse_manifest(name, &text)
}

/// Serialize a dataset back to manifest form (round-trip and tooling).
pub fn to_manifest(dataset: &Dataset) -> String {
    let mut out = String::from("name,size\n");
    for f in &dataset.files {
        out.push_str(&format!("file{:06},{:.0}\n", f.id.0, f.size.as_f64()));
    }
    out
}

/// Save a dataset as a manifest file.
pub fn save_manifest(dataset: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, to_manifest(dataset))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_rows() {
        let d = parse_manifest("t", "a.bin,1000\nb.bin,2500\n").unwrap();
        assert_eq!(d.num_files(), 2);
        assert_eq!(d.total_size(), Bytes::new(3500.0));
    }

    #[test]
    fn tolerates_header_and_comments() {
        let d = parse_manifest("t", "name,size\n# comment\nx,10\n\ny,20\n").unwrap();
        assert_eq!(d.num_files(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_manifest("t", "").is_err());
        assert!(parse_manifest("t", "no-comma-here\n").is_err());
        assert!(parse_manifest("t", "x,abc\ny,5\n").is_err());
        assert!(parse_manifest("t", "x,-5\n").is_err());
    }

    #[test]
    fn names_with_commas_use_last_field() {
        let d = parse_manifest("t", "weird,name,123\n").unwrap();
        assert_eq!(d.files[0].size, Bytes::new(123.0));
    }

    #[test]
    fn round_trip() {
        let d = crate::dataset::standard::large_dataset(3);
        let text = to_manifest(&d);
        let back = parse_manifest("large", &text).unwrap();
        assert_eq!(back.num_files(), d.num_files());
        assert!((back.total_size().as_f64() - d.total_size().as_f64()).abs() < d.num_files() as f64);
    }

    #[test]
    fn file_round_trip() {
        let d = crate::dataset::standard::medium_dataset(1);
        let path = std::env::temp_dir().join("greendt_manifest_test/m.csv");
        save_manifest(&d, &path).unwrap();
        let back = load_manifest(&path).unwrap();
        assert_eq!(back.num_files(), d.num_files());
        assert_eq!(back.name, "m");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}
