//! Trace reading: parse a JSONL trace back, rebuild per-session span
//! trees, and render waterfalls and histogram tables.
//!
//! This is the read side of the `greendt trace` CLI (`summarize` /
//! `sessions` / `spans`) and of `examples/fleet_trace.rs`. Loading is
//! forgiving in the history-store tradition: unparseable lines are
//! counted in [`TraceLog::skipped`], never fatal.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::metrics::Histogram;
use super::trace::TraceRecord;
use crate::history::json;
use crate::metrics::Table;

/// A parsed trace file.
#[derive(Debug, Clone, Default)]
pub struct TraceLog {
    /// Every parsed record, in file order.
    pub records: Vec<TraceRecord>,
    /// Lines that failed to parse (unknown version/kind, syntax).
    pub skipped: usize,
}

impl TraceLog {
    /// Parse trace JSONL text (blank lines ignored, bad lines counted).
    pub fn parse(text: &str) -> TraceLog {
        let mut log = TraceLog::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match json::parse(line).as_ref().and_then(TraceRecord::from_json) {
                Some(r) => log.records.push(r),
                None => log.skipped += 1,
            }
        }
        log
    }

    /// Load and parse the trace file at `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<TraceLog> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Ok(TraceLog::parse(&text))
    }

    /// Session names present in the log, sorted and deduplicated.
    pub fn sessions(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.records.iter().filter_map(|r| r.session.clone()).collect();
        names.sort();
        names.dedup();
        names
    }

    /// Every record attributed to `session`, in file order.
    pub fn session_records(&self, session: &str) -> Vec<&TraceRecord> {
        self.records
            .iter()
            .filter(|r| r.session.as_deref() == Some(session))
            .collect()
    }

    /// Rebuild the span tree for one session.
    pub fn tree(&self, session: &str) -> SessionTree {
        let records: Vec<TraceRecord> =
            self.session_records(session).into_iter().cloned().collect();
        let root = records.iter().find(|r| r.name == "session").cloned();
        SessionTree { session: session.to_string(), root, records }
    }

    /// Roll up one session: record counts, bytes/joules summed over
    /// ended residencies, and how the session ended. This is the one
    /// tally every consumer shares — the markdown table, the `--json`
    /// output and [`super::diff::TraceDiff`] all read it.
    pub fn session_summary(&self, session: &str) -> SessionSummary {
        let recs = self.session_records(session);
        let residencies: Vec<&&TraceRecord> =
            recs.iter().filter(|r| r.name == "admit").collect();
        SessionSummary {
            session: session.to_string(),
            spans: recs.iter().filter(|r| r.is_span()).count(),
            events: recs.iter().filter(|r| !r.is_span()).count(),
            residencies: residencies.len(),
            moved_bytes: residencies.iter().filter_map(|r| r.attr_f64("moved_bytes")).sum(),
            joules: residencies.iter().filter_map(|r| r.attr_f64("attributed_j")).sum(),
            end: if recs.iter().any(|r| r.name == "dead_letter") {
                "dead_letter"
            } else if recs.iter().any(|r| r.name == "complete") {
                "complete"
            } else {
                "open"
            },
        }
    }

    /// Every session's roll-up, in session-name order.
    pub fn summaries(&self) -> Vec<SessionSummary> {
        self.sessions().iter().map(|s| self.session_summary(s)).collect()
    }

    /// Per-session roll-up table: residencies, lifecycle events, bytes
    /// and joules summed over ended residencies, and how the session
    /// ended.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(
            "sessions",
            &["session", "spans", "events", "residencies", "moved", "joules", "end"],
        );
        for s in self.summaries() {
            t.push_row(vec![
                s.session,
                s.spans.to_string(),
                s.events.to_string(),
                s.residencies.to_string(),
                format!("{:.2e} B", s.moved_bytes),
                format!("{:.1} J", s.joules),
                s.end.to_string(),
            ]);
        }
        t
    }

    /// The `summarize` roll-up as one JSON document
    /// (`kind: "greendt-trace-summary"`), the machine-readable sibling
    /// of [`TraceLog::summary_table`].
    pub fn summary_json(&self) -> String {
        let rows: Vec<String> = self.summaries().iter().map(SessionSummary::to_json).collect();
        format!(
            "{{\"kind\":\"greendt-trace-summary\",\"records\":{},\"skipped\":{},\
             \"sessions\":[{}]}}",
            self.records.len(),
            self.skipped,
            rows.join(",")
        )
    }

    /// The session-name list as one JSON document
    /// (`kind: "greendt-trace-sessions"`).
    pub fn sessions_json(&self) -> String {
        let names: Vec<String> =
            self.sessions().iter().map(|s| format!("\"{}\"", json::escape(s))).collect();
        format!(
            "{{\"kind\":\"greendt-trace-sessions\",\"sessions\":[{}]}}",
            names.join(",")
        )
    }

    /// Span-duration histogram table: one row per span name with exact
    /// p50/p95/p99 over the recorded durations.
    pub fn histogram_table(&self) -> Table {
        let mut by_name: BTreeMap<String, Histogram> = BTreeMap::new();
        for r in &self.records {
            if let Some(d) = r.duration_secs() {
                by_name.entry(r.name.clone()).or_default().record(d);
            }
        }
        let mut t = Table::new(
            "span durations (seconds)",
            &["span", "count", "min", "p50", "p95", "p99", "max"],
        );
        let cell = |v: Option<f64>| match v {
            Some(x) => format!("{x:.2}"),
            None => "-".to_string(),
        };
        for (name, h) in &by_name {
            t.push_row(vec![
                name.clone(),
                h.count().to_string(),
                cell(h.min()),
                cell(h.percentile(0.50)),
                cell(h.percentile(0.95)),
                cell(h.percentile(0.99)),
                cell(h.max()),
            ]);
        }
        t
    }
}

/// One session's `summarize` roll-up row.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSummary {
    /// The session name.
    pub session: String,
    /// Span records attributed to the session.
    pub spans: usize,
    /// Instant events attributed to the session.
    pub events: usize,
    /// `admit` residencies (closed host stays).
    pub residencies: usize,
    /// Bytes summed over the residencies' `moved_bytes` attrs.
    pub moved_bytes: f64,
    /// Joules summed over the residencies' `attributed_j` attrs.
    pub joules: f64,
    /// `complete`, `dead_letter` or `open`.
    pub end: &'static str,
}

impl SessionSummary {
    /// One JSON object (embedded by [`TraceLog::summary_json`]).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"session\":\"{}\",\"spans\":{},\"events\":{},\"residencies\":{},\
             \"moved_bytes\":{},\"joules\":{},\"end\":\"{}\"}}",
            json::escape(&self.session),
            self.spans,
            self.events,
            self.residencies,
            json::num(self.moved_bytes),
            json::num(self.joules),
            self.end
        )
    }
}

/// One session's records, rooted at its `session` span.
#[derive(Debug, Clone)]
pub struct SessionTree {
    /// The session name.
    pub session: String,
    /// The root `session` span, when the log carries one.
    pub root: Option<TraceRecord>,
    /// Every record of the session, in file order (root included).
    pub records: Vec<TraceRecord>,
}

impl SessionTree {
    /// True when every record is reachable from the root via parent
    /// links — the "single connected span tree" acceptance property.
    pub fn connected(&self) -> bool {
        let Some(root) = &self.root else {
            return false;
        };
        let ids: BTreeMap<u64, Option<u64>> =
            self.records.iter().map(|r| (r.id, r.parent)).collect();
        self.records.iter().all(|r| {
            let mut cur = r.id;
            // Walk up; bounded by the record count to survive cycles.
            for _ in 0..=self.records.len() {
                if cur == root.id {
                    return true;
                }
                match ids.get(&cur).copied().flatten() {
                    Some(p) => cur = p,
                    None => return false,
                }
            }
            false
        })
    }

    /// Direct children of record `id`, sorted by `(t0, id)`.
    pub fn children(&self, id: u64) -> Vec<&TraceRecord> {
        let mut out: Vec<&TraceRecord> =
            self.records.iter().filter(|r| r.parent == Some(id)).collect();
        out.sort_by(|a, b| a.t0_secs.total_cmp(&b.t0_secs).then(a.id.cmp(&b.id)));
        out
    }

    /// The tree as one JSON document (`kind: "greendt-trace-spans"`):
    /// connectivity plus every record in file order, each serialized
    /// with the trace-line codec (the machine-readable sibling of
    /// [`SessionTree::waterfall`]).
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self.records.iter().map(|r| r.to_json_line()).collect();
        format!(
            "{{\"kind\":\"greendt-trace-spans\",\"session\":\"{}\",\"connected\":{},\
             \"records\":[{}]}}",
            json::escape(&self.session),
            self.connected(),
            rows.join(",")
        )
    }

    /// Render the tree as an indented text waterfall: spans as
    /// `[t0 .. t1]` intervals, events as `@t` instants, with hosts and
    /// key attributes inline.
    pub fn waterfall(&self) -> String {
        let mut out = String::new();
        match &self.root {
            Some(root) => {
                let root = root.clone();
                self.render(&root, 0, &mut out);
            }
            None => out.push_str(&format!("(no session root span for '{}')\n", self.session)),
        }
        out
    }

    fn render(&self, r: &TraceRecord, depth: usize, out: &mut String) {
        let indent = "  ".repeat(depth);
        let host = r.host.as_deref().map(|h| format!(" on {h}")).unwrap_or_default();
        let attrs: Vec<String> = r
            .attrs
            .iter()
            .map(|(k, v)| match v.as_f64() {
                Some(x) => format!("{k}={x:.4}"),
                None => format!("{k}={}", v.as_str().unwrap_or("?")),
            })
            .collect();
        let attrs =
            if attrs.is_empty() { String::new() } else { format!("  ({})", attrs.join(", ")) };
        match r.t1_secs {
            Some(t1) => out.push_str(&format!(
                "{indent}[{:>8.1}s .. {:>8.1}s] {}{host}{attrs}\n",
                r.t0_secs, t1, r.name
            )),
            None => out.push_str(&format!(
                "{indent}@{:>8.1}s           {}{host}{attrs}\n",
                r.t0_secs, r.name
            )),
        }
        for c in self.children(r.id) {
            self.render(c, depth + 1, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{trace_jsonl, AttrValue, TraceSink};

    fn sample_log() -> TraceLog {
        let mut sink = TraceSink::new();
        let root = sink.root("s1", 0.0);
        let other = sink.root("s2", 1.0);
        sink.event("admit_event", 0.0, Some("s1"), Some("h0"), Some(root), vec![]);
        sink.span(
            "admit",
            0.0,
            20.0,
            Some("s1"),
            Some("h0"),
            Some(root),
            vec![
                ("moved_bytes", AttrValue::F64(5e8)),
                ("attributed_j", AttrValue::F64(120.0)),
                ("end", "complete".into()),
            ],
        );
        sink.event("complete", 20.0, Some("s1"), Some("h0"), Some(root), vec![]);
        sink.span("admit", 1.0, 9.0, Some("s2"), Some("h1"), Some(other), vec![
            ("moved_bytes", AttrValue::F64(1e8)),
            ("attributed_j", AttrValue::F64(30.0)),
        ]);
        let recs = sink.finalize(20.0);
        TraceLog::parse(&trace_jsonl(&recs))
    }

    #[test]
    fn parse_round_trips_and_counts_bad_lines() {
        let log = sample_log();
        assert_eq!(log.skipped, 0);
        assert_eq!(log.sessions(), vec!["s1".to_string(), "s2".to_string()]);
        let bad = TraceLog::parse("not json\n{\"v\":99,\"kind\":\"span\"}\n");
        assert_eq!(bad.records.len(), 0);
        assert_eq!(bad.skipped, 2);
    }

    #[test]
    fn trees_are_connected_and_render() {
        let log = sample_log();
        let tree = log.tree("s1");
        assert!(tree.root.is_some());
        assert!(tree.connected(), "all s1 records hang off the root");
        let wf = tree.waterfall();
        assert!(wf.contains("session"), "waterfall starts at the root: {wf}");
        assert!(wf.contains("admit on h0"));
        assert!(wf.contains("complete"));
    }

    #[test]
    fn orphan_records_break_connectivity() {
        let mut log = sample_log();
        // Detach the residency span from its parent.
        for r in &mut log.records {
            if r.name == "admit" && r.session.as_deref() == Some("s1") {
                r.parent = None;
            }
        }
        assert!(!log.tree("s1").connected());
    }

    #[test]
    fn summary_table_reconciles_attrs() {
        let log = sample_log();
        let md = log.summary_table().to_markdown();
        assert!(md.contains("s1"));
        assert!(md.contains("complete"));
        assert!(md.contains("120.0 J"), "joules summed from residency attrs: {md}");
    }

    #[test]
    fn json_siblings_parse_and_reconcile() {
        let log = sample_log();
        let summary = json::parse(&log.summary_json()).expect("summary JSON parses");
        assert_eq!(
            summary.get("kind").and_then(|k| k.as_str()),
            Some("greendt-trace-summary")
        );
        let sessions = summary.get("sessions").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(sessions.len(), 2);
        let s1 = &sessions[0];
        assert_eq!(s1.get("session").and_then(|v| v.as_str()), Some("s1"));
        assert_eq!(s1.get("joules").and_then(|v| v.as_f64()), Some(120.0));
        assert_eq!(s1.get("end").and_then(|v| v.as_str()), Some("complete"));

        let names = json::parse(&log.sessions_json()).expect("sessions JSON parses");
        let arr = names.get("sessions").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].as_str(), Some("s2"));

        let tree = json::parse(&log.tree("s1").to_json()).expect("spans JSON parses");
        assert_eq!(tree.get("connected").and_then(|v| v.as_bool()), Some(true));
        let recs = tree.get("records").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(recs.len(), log.session_records("s1").len());
        assert!(recs.iter().all(|r| r.get("v").is_some()), "records use the line codec");
    }

    #[test]
    fn histogram_table_covers_span_names() {
        let log = sample_log();
        let md = log.histogram_table().to_markdown();
        assert!(md.contains("admit"));
        assert!(md.contains("session"));
    }
}
