//! The epoch-cached stepper must be indistinguishable — bit-for-bit —
//! from the naive per-tick reference stepper it replaced.
//!
//! Every figure, sweep and fleet number flows through `Simulation::step`,
//! so the fast path is only admissible if duration, moved bytes and the
//! client/server energy books come out with identical bits across
//! testbeds, algorithms, seeds, fleet arrivals/departures and scripted
//! bandwidth events. These tests drive whole sessions through both
//! steppers (`reference_stepper` flag) and compare outcomes exactly.
//!
//! The same contract extends to the two scale mechanisms layered on
//! top: warm-epoch tick batching (`constant_bg`) and the sharded
//! lockstep dispatcher (`shards`). Both are pinned here as bit-for-bit
//! invariant — outcomes, dispatch records and migration records — for
//! every shard count, including across mid-run power-cap squeezes that
//! land inside warm epochs.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, FleetPolicyKind, PlacementKind};
use greendt::dataset::standard;
use greendt::netsim::{BandwidthEvent, CrossTrafficConfig};
use greendt::rebalance::{RebalanceConfig, RebalancePolicyKind};
use greendt::sim::dispatcher::{
    run_dispatcher, DispatchOutcome, DispatcherConfig, HostSpec, SessionSpec,
};
use greendt::sim::fleet::{run_fleet, FleetConfig, FleetOutcome, TenantSpec};
use greendt::sim::session::{run_session, SessionConfig};
use greendt::units::{Power, Rate, SimTime};

fn assert_f64_bits(a: f64, b: f64, what: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: epoch {a} vs reference {b}");
}

fn assert_fleet_outcomes_identical(fast: &FleetOutcome, naive: &FleetOutcome, label: &str) {
    assert_eq!(fast.completed, naive.completed, "{label}: completed");
    assert_f64_bits(
        fast.duration.as_secs(),
        naive.duration.as_secs(),
        &format!("{label}: duration"),
    );
    assert_f64_bits(fast.moved.as_f64(), naive.moved.as_f64(), &format!("{label}: moved"));
    assert_f64_bits(
        fast.client_energy.as_joules(),
        naive.client_energy.as_joules(),
        &format!("{label}: client energy"),
    );
    assert_f64_bits(
        fast.client_package_energy.as_joules(),
        naive.client_package_energy.as_joules(),
        &format!("{label}: client package energy"),
    );
    assert_f64_bits(
        fast.server_energy.as_joules(),
        naive.server_energy.as_joules(),
        &format!("{label}: server energy"),
    );
    assert_eq!(fast.final_active_cores, naive.final_active_cores, "{label}: cores");
    assert_eq!(fast.tenants.len(), naive.tenants.len());
    for (f, n) in fast.tenants.iter().zip(&naive.tenants) {
        let t = format!("{label}/{}", f.name);
        assert_f64_bits(f.moved.as_f64(), n.moved.as_f64(), &format!("{t}: moved"));
        assert_f64_bits(
            f.attributed_energy.as_joules(),
            n.attributed_energy.as_joules(),
            &format!("{t}: attributed energy"),
        );
        assert_f64_bits(
            f.attributed_package_energy.as_joules(),
            n.attributed_package_energy.as_joules(),
            &format!("{t}: attributed package energy"),
        );
        assert_eq!(
            f.finished_at.map(|x| x.as_secs().to_bits()),
            n.finished_at.map(|x| x.as_secs().to_bits()),
            "{t}: finish time"
        );
        assert_eq!(f.peak_channels, n.peak_channels, "{t}: peak channels");
    }
}

#[test]
fn single_sessions_bit_identical_across_grid() {
    // Testbeds × algorithms × seeds: the threshold-FSM tuners (whose
    // timeouts bound epochs), a static baseline (whose epochs span nearly
    // the whole run) and different path/CPU models.
    let algos = [
        AlgorithmKind::MaxThroughput,
        AlgorithmKind::MinEnergy,
        AlgorithmKind::NoTune(8),
        AlgorithmKind::TargetThroughput(Rate::from_mbps(300.0)),
    ];
    for testbed in ["chameleon", "cloudlab", "didclab"] {
        for algo in algos {
            for seed in [3u64, 11] {
                let mk = |reference: bool| {
                    let mut cfg = SessionConfig::new(
                        testbeds::by_name(testbed).unwrap(),
                        standard::medium_dataset(seed),
                        algo,
                    )
                    .with_seed(seed);
                    cfg.reference_stepper = reference;
                    cfg
                };
                let fast = run_session(&mk(false));
                let naive = run_session(&mk(true));
                let label = format!("{testbed}/{}/seed{seed}", algo.id());
                assert!(naive.completed, "{label}: reference run must finish");
                assert_f64_bits(
                    fast.duration.as_secs(),
                    naive.duration.as_secs(),
                    &format!("{label}: duration"),
                );
                assert_f64_bits(
                    fast.moved.as_f64(),
                    naive.moved.as_f64(),
                    &format!("{label}: moved"),
                );
                assert_f64_bits(
                    fast.client_energy.as_joules(),
                    naive.client_energy.as_joules(),
                    &format!("{label}: client energy"),
                );
                assert_f64_bits(
                    fast.server_energy.as_joules(),
                    naive.server_energy.as_joules(),
                    &format!("{label}: server energy"),
                );
                assert_eq!(fast.peak_channels, naive.peak_channels, "{label}: peak ch");
            }
        }
    }
}

fn fleet_cfg(
    policy: FleetPolicyKind,
    seed: u64,
    server_scaling: bool,
    reference: bool,
) -> FleetConfig {
    let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(policy)).with_seed(seed);
    for i in 0..4u64 {
        cfg.tenants.push(
            TenantSpec::new(
                format!("tenant-{i}"),
                standard::medium_dataset(seed + i),
                if i % 2 == 0 { AlgorithmKind::MaxThroughput } else { AlgorithmKind::MinEnergy },
            )
            .arriving_at(SimTime::from_secs(25.0 * i as f64)),
        );
    }
    // A mid-run bandwidth drop (and later recovery) lands inside warm
    // epochs: the budget moves every tick while the stream caches hold.
    cfg.bandwidth_events = vec![
        BandwidthEvent { at: SimTime::from_secs(40.0), mean_fraction: 0.5 },
        BandwidthEvent { at: SimTime::from_secs(120.0), mean_fraction: 0.1 },
    ];
    cfg.server_scaling = server_scaling;
    cfg.reference_stepper = reference;
    cfg
}

#[test]
fn fleet_with_arrivals_and_bandwidth_events_bit_identical() {
    for (policy, server_scaling, seed) in [
        (FleetPolicyKind::MinEnergyFleet, false, 5u64),
        (FleetPolicyKind::FairShare, true, 9),
    ] {
        let fast = run_fleet(&fleet_cfg(policy, seed, server_scaling, false));
        let naive = run_fleet(&fleet_cfg(policy, seed, server_scaling, true));
        assert!(naive.completed, "reference fleet must finish");
        assert_fleet_outcomes_identical(
            &fast,
            &naive,
            &format!("{}/seed{seed}", naive.policy),
        );
    }
}

#[test]
fn empty_dataset_tenant_departs_identically() {
    // A zero-byte tenant is done on arrival: the event-horizon driver
    // must retire it on the same tick the per-tick reference does.
    let mk = |reference: bool| {
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
            .with_seed(2);
        cfg.tenants.push(TenantSpec::new(
            "real",
            standard::medium_dataset(2),
            AlgorithmKind::MaxThroughput,
        ));
        cfg.tenants.push(
            TenantSpec::new(
                "empty",
                greendt::dataset::Dataset::new("empty", Vec::new()),
                AlgorithmKind::NoTune(2),
            )
            .arriving_at(SimTime::from_secs(10.0)),
        );
        cfg.reference_stepper = reference;
        cfg
    };
    let fast = run_fleet(&mk(false));
    let naive = run_fleet(&mk(true));
    assert_fleet_outcomes_identical(&fast, &naive, "empty-tenant");
}

#[test]
fn constant_bg_fleet_warm_batching_bit_identical() {
    // The warm-epoch fast path (constant background freezes the link
    // between events, so whole epochs batch into one jump) must replay
    // the naive per-tick stepper's accumulation exactly — including
    // across scripted bandwidth events, which land mid-epoch and must
    // break the batch on the same tick the reference reacts on.
    for seed in [5u64, 9] {
        let mk = |reference: bool| {
            let mut cfg = fleet_cfg(FleetPolicyKind::MinEnergyFleet, seed, false, reference);
            cfg.constant_bg = true;
            cfg
        };
        let fast = run_fleet(&mk(false));
        let naive = run_fleet(&mk(true));
        assert!(naive.completed, "reference fleet must finish");
        assert_fleet_outcomes_identical(&fast, &naive, &format!("constant-bg/seed{seed}"));
    }
}

/// The contended-path scenarios share one generator shape: a 10% UDP
/// floor plus ~0.3 bursts/s of 20 MB TCP flows.
fn cross() -> CrossTrafficConfig {
    CrossTrafficConfig {
        udp_fraction: 0.1,
        tcp_rate_per_sec: 0.3,
        tcp_burst_bytes: 20e6,
        tcp_burst_secs: 1.0,
    }
}

#[test]
fn contended_fleet_bit_identical_to_reference() {
    // Cross-traffic keeps the link un-frozen, so warm batching never
    // engages — but the epoch *cache* still does (the allocator re-reads
    // the link budget every tick), and under AIMD even that is held off
    // because every stream stays "unstable". Both modes must replay the
    // naive per-tick reference exactly.
    for aimd in [false, true] {
        let mk = |reference: bool| {
            fleet_cfg(FleetPolicyKind::MinEnergyFleet, 5, false, reference)
                .with_cross_traffic(cross())
                .with_aimd(aimd)
        };
        let fast = run_fleet(&mk(false));
        let naive = run_fleet(&mk(true));
        assert!(naive.completed, "contended reference fleet must finish");
        assert_fleet_outcomes_identical(&fast, &naive, &format!("contended/aimd={aimd}"));
    }
}

#[test]
fn cross_traffic_off_is_the_quiet_path_bit_for_bit() {
    // `--cross-traffic off` parses to `None`; a config routed through
    // that spelling must be indistinguishable from one that never
    // mentioned the flag — the quiet engine's bits are the contract.
    assert_eq!(CrossTrafficConfig::parse("off").unwrap(), None);
    let mk = |spell_it_out: bool| {
        let mut cfg = fleet_cfg(FleetPolicyKind::FairShare, 9, false, false);
        if spell_it_out {
            cfg.cross_traffic = CrossTrafficConfig::parse("off").unwrap();
            cfg.aimd = false;
        }
        cfg
    };
    let spelled = run_fleet(&mk(true));
    let default = run_fleet(&mk(false));
    assert_fleet_outcomes_identical(&spelled, &default, "cross-traffic-off");
}

#[test]
fn contended_dispatcher_invariant_to_shard_count() {
    // Shard-count invariance must survive the contended path: each
    // host's generators are seeded from its own host_seed, so the
    // partition of hosts onto worker threads may not leak into any
    // outcome or record.
    let mk = |shards: usize| {
        sharded_cfg(shards, false).with_cross_traffic(cross()).with_aimd(true)
    };
    let reference = run_dispatcher(&mk(1));
    assert!(reference.fleet.completed, "contended serial run must finish");
    for shards in [2usize, 8] {
        let sharded = run_dispatcher(&mk(shards));
        assert_dispatch_outcomes_identical(
            &reference,
            &sharded,
            &format!("contended/{shards}-shard"),
        );
    }
}

/// Shard-count invariance is the dispatcher's whole determinism
/// contract: every piece of telemetry — not just the aggregate books —
/// must come out identical whatever the worker-thread count.
fn assert_dispatch_outcomes_identical(a: &DispatchOutcome, b: &DispatchOutcome, label: &str) {
    assert_fleet_outcomes_identical(&a.fleet, &b.fleet, label);
    assert_eq!(a.decisions.len(), b.decisions.len(), "{label}: decision count");
    for (x, y) in a.decisions.iter().zip(&b.decisions) {
        let t = format!("{label}/decision {}", x.session);
        assert_eq!(x.session, y.session, "{t}: session order");
        assert_f64_bits(x.t_secs, y.t_secs, &format!("{t}: decision time"));
        assert_f64_bits(x.requested_at_secs, y.requested_at_secs, &format!("{t}: requested"));
        assert_eq!(x.admitted_host, y.admitted_host, "{t}: admitted host");
        assert_eq!(x.host, y.host, "{t}: host name");
        assert_f64_bits(
            x.projected_fleet_power_w,
            y.projected_fleet_power_w,
            &format!("{t}: projected power"),
        );
    }
    assert_eq!(a.migrations.len(), b.migrations.len(), "{label}: migration count");
    for (x, y) in a.migrations.iter().zip(&b.migrations) {
        let t = format!("{label}/migration {}", x.session);
        assert_eq!(x.session, y.session, "{t}: session order");
        assert_f64_bits(x.t_secs, y.t_secs, &format!("{t}: preemption time"));
        assert_eq!((x.from_host, x.to_host), (y.from_host, y.to_host), "{t}: hosts");
        assert_f64_bits(x.moved_bytes, y.moved_bytes, &format!("{t}: moved"));
        assert_f64_bits(x.remaining_bytes, y.remaining_bytes, &format!("{t}: remaining"));
        assert_f64_bits(x.drain_secs, y.drain_secs, &format!("{t}: drain"));
    }
    assert_eq!(a.unplaced, b.unplaced, "{label}: unplaced");
}

/// A five-host heterogeneous fleet with staggered arrivals: enough
/// hosts that 2- and 8-shard partitions differ, enough sessions that
/// admissions land across segment boundaries.
fn sharded_cfg(shards: usize, constant_bg: bool) -> DispatcherConfig {
    let testbeds = testbeds::all();
    let hosts: Vec<HostSpec> = (0..5)
        .map(|i| {
            let tb = testbeds[i % testbeds.len()].clone();
            HostSpec::new(format!("host{i}-{}", tb.name), tb).with_max_sessions(2)
        })
        .collect();
    let sessions: Vec<SessionSpec> = (0..10u64)
        .map(|i| {
            SessionSpec::new(
                format!("session-{i}"),
                standard::medium_dataset(100 + i),
                if i % 2 == 0 { AlgorithmKind::MaxThroughput } else { AlgorithmKind::MinEnergy },
            )
            .arriving_at(SimTime::from_secs(10.0 * i as f64))
        })
        .collect();
    let mut cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(7)
        .with_shards(shards);
    if constant_bg {
        cfg = cfg.with_constant_bg();
    }
    cfg
}

#[test]
fn dispatcher_outcomes_invariant_to_shard_count() {
    // The same fleet at 1 (serial reference loop), 2 and 8 worker
    // threads, with and without warm-epoch batching: identical
    // outcomes, identical dispatch records. The 1-shard run is the
    // loop earlier releases shipped, so this also pins "sharding off
    // by default changes nothing".
    for constant_bg in [false, true] {
        let reference = run_dispatcher(&sharded_cfg(1, constant_bg));
        assert!(reference.fleet.completed, "serial run must finish");
        for shards in [2usize, 8] {
            let sharded = run_dispatcher(&sharded_cfg(shards, constant_bg));
            assert_dispatch_outcomes_identical(
                &reference,
                &sharded,
                &format!("{shards}-shard/constant_bg={constant_bg}"),
            );
        }
    }
}

#[test]
fn migrations_are_invariant_to_shard_count() {
    // The rebalancer's hot-spot scenario (a stranded session that must
    // move from the legacy host to the efficient one): the preemption,
    // the drain window and the re-admission all cross segment
    // boundaries, and every record must be bit-identical however the
    // inner loop is sharded.
    let mk = |shards: usize| {
        let hosts = vec![
            HostSpec::new("efficient", testbeds::cloudlab()).with_max_sessions(1),
            HostSpec::new("legacy", testbeds::didclab()).with_max_sessions(4),
        ];
        let sessions = vec![
            SessionSpec::new("s0", standard::medium_dataset(301), AlgorithmKind::MaxThroughput),
            SessionSpec::new("s1", standard::large_dataset(302), AlgorithmKind::MaxThroughput)
                .arriving_at(SimTime::from_secs(5.0)),
        ];
        let mut cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
            .with_sessions(sessions)
            .with_seed(61)
            .with_shards(shards);
        cfg.rebalance = RebalanceConfig::new(RebalancePolicyKind::MarginalEnergyDelta);
        cfg
    };
    let reference = run_dispatcher(&mk(1));
    assert!(!reference.migrations.is_empty(), "scenario must actually migrate");
    for shards in [2usize, 8] {
        let sharded = run_dispatcher(&mk(shards));
        assert_dispatch_outcomes_identical(&reference, &sharded, &format!("{shards}-shard"));
    }
}

#[test]
fn cap_squeeze_mid_epoch_breaks_the_horizon() {
    // Regression for the event-horizon contract: a scripted power-cap
    // squeeze landing inside an otherwise-quiet stretch (every link
    // frozen, warm epochs batching thousands of ticks) must still fire
    // on its exact tick, and — the bug this test caught — a cap *lift*
    // still ahead must keep a fully-drained fleet alive: the queued
    // sessions wait out the squeeze on idle hosts and re-admit at the
    // lift, instead of the run ending early and reporting them
    // unplaced. Warm batching and sharding may not leap over either
    // event.
    let mk = |shards: usize, reference: bool| {
        let testbeds = testbeds::all();
        let hosts: Vec<HostSpec> = (0..3)
            .map(|i| {
                let tb = testbeds[i % testbeds.len()].clone();
                HostSpec::new(format!("host{i}-{}", tb.name), tb).with_max_sessions(1)
            })
            .collect();
        let sessions: Vec<SessionSpec> = (0..6u64)
            .map(|i| {
                SessionSpec::new(
                    format!("session-{i}"),
                    standard::medium_dataset(200 + i),
                    AlgorithmKind::MaxThroughput,
                )
            })
            .collect();
        // The squeeze lands at t = 5 s — before the fastest possible
        // session can finish (11.7 GB needs > 9 s even at 10 Gbps line
        // rate) — so every slot a departure frees stays cap-blocked
        // until the lift at t = 400 s.
        let mut cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
            .with_sessions(sessions)
            .with_seed(13)
            .with_shards(shards)
            .with_constant_bg()
            .with_cap_event(SimTime::from_secs(5.0), Some(Power::from_watts(1.0)))
            .with_cap_event(SimTime::from_secs(400.0), None);
        cfg.reference_stepper = reference;
        cfg
    };
    let naive = run_dispatcher(&mk(1, true));
    assert!(naive.fleet.completed, "reference run must finish");
    assert!(naive.unplaced.is_empty(), "the queue must survive the squeeze");
    // The squeeze must actually bite: no admission between the cap
    // events, and the queued half of the workload re-admitted only
    // once the cap lifted.
    assert!(
        !naive
            .decisions
            .iter()
            .any(|d| d.t_secs > 5.0 && d.t_secs < 400.0 - 1e-9 && !d.queued()),
        "no admission may slip through the 1 W squeeze"
    );
    assert!(
        naive.decisions.iter().any(|d| d.t_secs >= 400.0 - 1e-9 && !d.queued()),
        "queued sessions must re-admit at the cap lift"
    );
    let serial_fast = run_dispatcher(&mk(1, false));
    assert_dispatch_outcomes_identical(&naive, &serial_fast, "warm vs naive");
    for shards in [2usize, 8] {
        let sharded = run_dispatcher(&mk(shards, false));
        assert_dispatch_outcomes_identical(&naive, &sharded, &format!("{shards}-shard warm"));
    }
}
