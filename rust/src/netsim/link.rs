//! Bottleneck link and fair-share goodput allocation.

use super::crosstraffic::{CrossTraffic, MAX_CROSS_FRACTION};
use super::{BackgroundTraffic, StreamState};
use crate::rng::Xoshiro256;
use crate::units::{Bytes, Rate, Rtt, SimDuration, SimTime};

/// Static parameters of a WAN path (one row of Table I).
#[derive(Debug, Clone)]
pub struct LinkParams {
    /// Nominal bottleneck capacity.
    pub capacity: Rate,
    /// Round-trip time.
    pub rtt: Rtt,
    /// Average TCP window a single stream reaches (what iperf reports —
    /// Alg. 1 uses `avgWinSize / RTT` as the per-channel throughput).
    pub avg_win: Bytes,
    /// Overload penalty strength: how sharply aggregate goodput degrades
    /// once the open-stream count exceeds the knee (retransmission +
    /// contention losses).
    pub overload_gamma: f64,
    /// Goodput floor under extreme overload, as a fraction of available
    /// capacity (TCP keeps moving data even when over-subscribed).
    pub overload_floor: f64,
}

impl LinkParams {
    /// Number of steady-state streams needed to fill the pipe — the "knee"
    /// of the throughput-vs-streams curve.
    pub fn knee_streams(&self) -> f64 {
        let per_stream = self.avg_win.as_f64() / self.rtt.as_secs().max(1e-9); // bytes/s
        (self.capacity.as_bytes_per_sec() / per_stream.max(1.0)).max(1.0)
    }

    /// Throughput of one steady-state stream (Alg. 1 line 8).
    pub fn channel_throughput(&self) -> Rate {
        Rate::from_bytes_per_sec(self.avg_win.as_f64() / self.rtt.as_secs().max(1e-9))
    }

    /// Bandwidth-delay product of the path.
    pub fn bdp(&self) -> Bytes {
        crate::units::bdp(self.capacity, self.rtt)
    }

    /// Aggregate overload penalty for `n` open streams (step 3 of
    /// [`share_goodput`]'s model): past the knee, every extra stream adds
    /// retransmission + contention losses, linear in the over-subscription
    /// ratio and floored. Constant while the stream count is constant, so
    /// epoch caches compute it once.
    pub fn overload_penalty(&self, n: usize) -> f64 {
        let knee = self.knee_streams();
        let over = (n as f64 - knee).max(0.0) / knee;
        (1.0 / (1.0 + self.overload_gamma * over)).max(self.overload_floor)
    }
}

/// A bottleneck link with time-varying residual capacity.
#[derive(Debug, Clone)]
pub struct Link {
    /// Static path parameters (capacity, RTT, window/knee model).
    pub params: LinkParams,
    bg: BackgroundTraffic,
    /// Optional seeded cross-traffic generators (UDP floor + TCP bursts)
    /// stacked on top of the OU background. `None` keeps every code path
    /// bit-identical to a link built before this layer existed.
    cross: Option<CrossTraffic>,
}

impl Link {
    /// A link with the given parameters and background process.
    pub fn new(params: LinkParams, bg: BackgroundTraffic) -> Self {
        Link { params, bg, cross: None }
    }

    /// Stack seeded cross-traffic generators on the link (see
    /// [`CrossTraffic`]). A link carrying a generator is never frozen —
    /// [`Self::bg_frozen`] returns `false` — so warm-epoch batching
    /// always defers to the per-tick path.
    pub fn with_cross_traffic(mut self, cross: CrossTraffic) -> Self {
        self.cross = Some(cross);
        self
    }

    /// Capacity left for the transfer after background cross traffic.
    /// Without generators this is exactly the pre-cross-traffic
    /// expression (bit-for-bit); with them, the OU fraction and the
    /// generator fraction add, capped so the transfer is never fully
    /// starved.
    pub fn available(&self) -> Rate {
        match &self.cross {
            None => self.params.capacity * (1.0 - self.bg.fraction()),
            Some(ct) => {
                let f = (self.bg.fraction() + ct.fraction(self.params.capacity))
                    .min(MAX_CROSS_FRACTION);
                self.params.capacity * (1.0 - f)
            }
        }
    }

    /// Current background fraction (observability for tests/metrics).
    pub fn background_fraction(&self) -> f64 {
        self.bg.fraction()
    }

    /// Current cross-traffic generator fraction of capacity (`None`
    /// when no generator is attached).
    pub fn cross_traffic_fraction(&self) -> Option<f64> {
        self.cross.as_ref().map(|ct| ct.fraction(self.params.capacity))
    }

    /// Advance the background process and any cross-traffic generators.
    pub fn tick(&mut self, now: SimTime, dt: SimDuration, rng: &mut Xoshiro256) {
        self.bg.tick(now, dt, rng);
        if let Some(ct) = &mut self.cross {
            ct.tick(now);
        }
    }

    /// True when [`Self::tick`] with no scripted event due is a state
    /// no-op (constant background, no RNG draws) — the link-side
    /// precondition for warm-epoch tick batching. See
    /// [`BackgroundTraffic::is_frozen`]. A link with cross-traffic
    /// generators attached is *never* frozen: burst arrivals move the
    /// budget on any tick, so a batched warm epoch would silently replay
    /// stale rates across a burst boundary.
    pub fn bg_frozen(&self) -> bool {
        self.cross.is_none() && self.bg.is_frozen()
    }

    /// When the next scripted background event fires, if any — a batched
    /// stepper must take the real tick path for any tick this instant
    /// has reached.
    pub fn next_bg_event_at(&self) -> Option<SimTime> {
        self.bg.next_event_at()
    }
}

/// Allocate goodput to `streams` over `link` for one tick.
///
/// Model (see DESIGN.md §5):
/// 1. each stream is bounded by its window rate `win/RTT`;
/// 2. the aggregate is bounded by the available capacity, shared
///    max-min-fairly (equal split, window-limited streams donate surplus);
/// 3. past the knee, over-subscription causes losses: the aggregate is
///    scaled by `1 / (1 + gamma * (n - knee)/knee)`, floored at
///    `overload_floor` — TCP degrades gracefully, but "more channels"
///    eventually *hurts*, the concavity Algorithms 4–6 search.
///
/// Returns per-stream rates (same order as `streams`).
pub fn share_goodput(link: &Link, streams: &[StreamState]) -> Vec<Rate> {
    let mut out = Vec::new();
    share_goodput_into(link, streams, &mut out);
    out.into_iter().map(Rate::from_bytes_per_sec).collect()
}

/// Allocation-free variant for the per-tick hot path: writes per-stream
/// rates in **bytes/s** into `out` (cleared and refilled; scratch space is
/// reused by the caller across ticks).
pub fn share_goodput_into(link: &Link, streams: &[StreamState], out: &mut Vec<f64>) {
    out.clear();
    let n = streams.len();
    if n == 0 {
        return;
    }
    let rtt = link.params.rtt;
    let avail = link.available().as_bytes_per_sec();

    // Overload penalty on the aggregate (TCP degrades gracefully past the
    // knee; see `LinkParams::overload_penalty`).
    let penalty = link.params.overload_penalty(n);
    let budget = avail * penalty;

    // Max-min fair allocation among window-capped streams:
    // iterate: give every unfrozen stream an equal share; freeze streams
    // whose window cap is below their share; redistribute the surplus.
    // `out` doubles as the allocation buffer; a negative entry marks a
    // still-unfrozen stream, so no side vectors are needed and the hot
    // path stays allocation-free (caps are recomputed in the freeze scan —
    // window_rate is two flops, and rounds are typically 1-2).
    out.resize(n, -1.0);
    let alloc = out;
    let mut remaining = budget;
    let mut active = n;
    // At most n rounds. `remaining`/`active` are maintained incrementally
    // so each round is a single O(n) scan (the naive re-summation made the
    // allocator O(n²) at high stream counts).
    for _ in 0..n {
        if active == 0 || remaining <= 1e-9 {
            break;
        }
        let share = remaining / active as f64;
        let mut newly_frozen = 0;
        for (s, a) in streams.iter().zip(alloc.iter_mut()) {
            if *a >= 0.0 {
                continue; // frozen
            }
            let cap = s.window_rate(rtt).as_bytes_per_sec();
            if cap <= share {
                *a = cap;
                newly_frozen += 1;
                remaining -= cap;
                active -= 1;
            }
        }
        if newly_frozen == 0 {
            // Everyone can absorb the equal share.
            for a in alloc.iter_mut() {
                if *a < 0.0 {
                    *a = share;
                }
            }
            break;
        }
        if remaining < 0.0 {
            remaining = 0.0;
        }
    }
    // Streams never reached (budget exhausted) get nothing.
    for a in alloc.iter_mut() {
        if *a < 0.0 {
            *a = 0.0;
        }
    }
}

/// Epoch cache for [`share_goodput_into`].
///
/// Within an epoch — no channel churn and every window warm — the stream
/// set is frozen, so the per-stream window caps and the overload penalty
/// are constants; the only per-tick input is the scalar link budget
/// (available capacity × penalty) that moves with background traffic.
/// [`Self::alloc_into`] reproduces the reference allocation **bit-for-bit**:
/// cached values carry the same bits the reference recomputes (window
/// caps and penalty are pure functions of frozen inputs), and the
/// uniform-cap fast path takes exactly the single round the reference
/// freeze loop executes when every cap is equal. The property tests in
/// `rust/tests/stepper_equivalence.rs` pin this.
#[derive(Debug, Clone, Default)]
pub struct AllocCache {
    /// Per-stream window cap (`win / RTT`), bytes/s, in staged order.
    caps: Vec<f64>,
    /// `Some(cap)` when every cap carries the same bits — the warm-epoch
    /// common case (all streams at `avg_win`).
    uniform_cap: Option<f64>,
    /// `LinkParams::overload_penalty` at the cached stream count.
    penalty: f64,
}

impl AllocCache {
    /// Re-derive the cache from a freshly staged stream snapshot.
    pub fn rebuild(&mut self, link: &Link, streams: &[StreamState]) {
        let rtt = link.params.rtt;
        self.caps.clear();
        self.caps
            .extend(streams.iter().map(|s| s.window_rate(rtt).as_bytes_per_sec()));
        self.uniform_cap = match self.caps.split_first() {
            Some((&first, rest)) if rest.iter().all(|&c| c == first) => Some(first),
            _ => None,
        };
        self.penalty = link.params.overload_penalty(streams.len());
    }

    /// Allocate one tick's goodput at the current link budget — the cached
    /// equivalent of [`share_goodput_into`] over the streams this cache was
    /// rebuilt from.
    pub fn alloc_into(&self, link: &Link, out: &mut Vec<f64>) {
        out.clear();
        let n = self.caps.len();
        if n == 0 {
            return;
        }
        let avail = link.available().as_bytes_per_sec();
        let budget = avail * self.penalty;

        if let Some(cap) = self.uniform_cap {
            // Reference loop, round 1: share = budget / n. With equal caps
            // either every stream freezes at its cap (`cap <= share`) or
            // nobody freezes and everyone absorbs the equal share; a
            // sub-epsilon budget zero-fills before the first round.
            if budget <= 1e-9 {
                out.resize(n, 0.0);
            } else {
                let share = budget / n as f64;
                out.resize(n, if cap <= share { cap } else { share });
            }
            return;
        }

        // Mixed caps (slow-start transients): the reference freeze loop,
        // verbatim, reading cached caps instead of recomputing them.
        out.resize(n, -1.0);
        let alloc = out;
        let mut remaining = budget;
        let mut active = n;
        for _ in 0..n {
            if active == 0 || remaining <= 1e-9 {
                break;
            }
            let share = remaining / active as f64;
            let mut newly_frozen = 0;
            for (&cap, a) in self.caps.iter().zip(alloc.iter_mut()) {
                if *a >= 0.0 {
                    continue; // frozen
                }
                if cap <= share {
                    *a = cap;
                    newly_frozen += 1;
                    remaining -= cap;
                    active -= 1;
                }
            }
            if newly_frozen == 0 {
                for a in alloc.iter_mut() {
                    if *a < 0.0 {
                        *a = share;
                    }
                }
                break;
            }
            if remaining < 0.0 {
                remaining = 0.0;
            }
        }
        for a in alloc.iter_mut() {
            if *a < 0.0 {
                *a = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::BackgroundTraffic;

    /// CloudLab-like link: 1 Gbps, 36 ms, 4.5 MB BDP, ~1 MB avg window.
    fn link() -> Link {
        Link::new(
            LinkParams {
                capacity: Rate::from_gbps(1.0),
                rtt: SimDuration::from_millis(36.0),
                avg_win: Bytes::from_mb(1.0),
                overload_gamma: 0.02,
                overload_floor: 0.55,
            },
            BackgroundTraffic::constant(0.0),
        )
    }

    fn warm_streams(link: &Link, n: usize) -> Vec<StreamState> {
        (0..n).map(|_| StreamState::warm(link.params.avg_win)).collect()
    }

    #[test]
    fn knee_matches_alg1_channel_estimate() {
        let l = link();
        // one stream: 1 MB / 36 ms = 27.8 MB/s = 222 Mbps; knee = 1 Gbps / 222 Mbps ≈ 4.5
        let knee = l.params.knee_streams();
        assert!((knee - 4.5).abs() < 0.1, "knee {knee}");
        assert!((l.params.channel_throughput().as_mbps() - 222.2).abs() < 1.0);
    }

    #[test]
    fn single_stream_is_window_limited() {
        let l = link();
        let rates = share_goodput(&l, &warm_streams(&l, 1));
        assert!((rates[0].as_mbps() - 222.2).abs() < 1.0, "{}", rates[0]);
    }

    #[test]
    fn aggregate_grows_then_saturates() {
        let l = link();
        let t1: f64 = share_goodput(&l, &warm_streams(&l, 1)).iter().map(|r| r.as_mbps()).sum();
        let t4: f64 = share_goodput(&l, &warm_streams(&l, 4)).iter().map(|r| r.as_mbps()).sum();
        let t5: f64 = share_goodput(&l, &warm_streams(&l, 5)).iter().map(|r| r.as_mbps()).sum();
        assert!(t4 > 3.9 * t1 * 0.99, "linear regime: {t4} vs {t1}");
        assert!(t5 <= 1000.0 + 1.0, "cannot exceed capacity: {t5}");
        assert!(t5 > 950.0, "near-saturation at the knee: {t5}");
    }

    #[test]
    fn overload_degrades_aggregate() {
        let l = link();
        let t5: f64 = share_goodput(&l, &warm_streams(&l, 5)).iter().map(|r| r.as_mbps()).sum();
        let t80: f64 = share_goodput(&l, &warm_streams(&l, 80)).iter().map(|r| r.as_mbps()).sum();
        assert!(t80 < t5 * 0.9, "overload must hurt: {t80} vs {t5}");
        let floor = 1000.0 * l.params.overload_floor;
        assert!(t80 >= floor * 0.99, "floor holds: {t80} >= {floor}");
        // Degradation is graceful: 2x the knee costs only a few percent.
        let t9: f64 = share_goodput(&l, &warm_streams(&l, 9)).iter().map(|r| r.as_mbps()).sum();
        assert!(t9 > t5 * 0.95, "mild oversubscription is cheap: {t9} vs {t5}");
    }

    #[test]
    fn slow_start_stream_gets_less() {
        let l = link();
        let mut streams = warm_streams(&l, 3);
        streams.push(StreamState::new(l.params.avg_win)); // cold
        let rates = share_goodput(&l, &streams);
        assert!(rates[3] < rates[0], "cold stream {} vs warm {}", rates[3], rates[0]);
    }

    #[test]
    fn background_traffic_reduces_budget() {
        let mut l = link();
        l.bg = BackgroundTraffic::constant(0.5);
        let total: f64 = share_goodput(&l, &warm_streams(&l, 10)).iter().map(|r| r.as_mbps()).sum();
        assert!(total < 510.0, "half capacity available: {total}");
    }

    #[test]
    fn empty_streams_ok() {
        assert!(share_goodput(&link(), &[]).is_empty());
    }

    #[test]
    fn allocation_never_exceeds_window_cap() {
        let l = link();
        let mut streams = warm_streams(&l, 2);
        streams.push(StreamState::new(l.params.avg_win));
        let rates = share_goodput(&l, &streams);
        for (s, r) in streams.iter().zip(&rates) {
            let cap = s.window_rate(l.params.rtt);
            assert!(r.as_bits_per_sec() <= cap.as_bits_per_sec() * (1.0 + 1e-9));
        }
    }

    fn assert_alloc_cache_matches(link: &Link, streams: &[StreamState]) {
        let mut reference = Vec::new();
        share_goodput_into(link, streams, &mut reference);
        let mut cache = AllocCache::default();
        cache.rebuild(link, streams);
        let mut cached = Vec::new();
        cache.alloc_into(link, &mut cached);
        assert_eq!(reference.len(), cached.len());
        for (i, (r, c)) in reference.iter().zip(&cached).enumerate() {
            assert_eq!(
                r.to_bits(),
                c.to_bits(),
                "stream {i}: reference {r} vs cached {c} ({} streams)",
                streams.len()
            );
        }
    }

    #[test]
    fn alloc_cache_matches_reference_on_uniform_caps() {
        let base = link();
        for n in [1usize, 2, 4, 5, 9, 64, 200] {
            for bg in [0.0, 0.08, 0.5, 0.95, 1.0] {
                let mut l = base.clone();
                l.bg = BackgroundTraffic::constant(bg.min(0.95));
                assert_alloc_cache_matches(&l, &warm_streams(&l, n));
            }
        }
        assert_alloc_cache_matches(&base, &[]);
    }

    #[test]
    fn alloc_cache_matches_reference_on_mixed_caps() {
        // Slow-start transients: a pseudo-random mix of cold, part-ramped
        // and warm windows across budgets, including budget-exhausted and
        // multi-round freeze cases.
        let base = link();
        let mut rng = crate::rng::Xoshiro256::seeded(0x5eed);
        for trial in 0..200 {
            let n = 1 + (rng.next_u64() % 40) as usize;
            let mut streams = Vec::with_capacity(n);
            for _ in 0..n {
                let mut s = StreamState::new(base.params.avg_win);
                // Ramp a pseudo-random number of RTTs (0 → cold, many → warm).
                for _ in 0..(rng.next_u64() % 12) {
                    s.tick(base.params.rtt, base.params.rtt);
                }
                streams.push(s);
            }
            let mut l = base.clone();
            l.bg = BackgroundTraffic::constant(0.95 * rng.next_f64());
            assert_alloc_cache_matches(&l, &streams);
            if trial == 0 {
                assert!(streams.iter().any(|s| s.in_slow_start()));
            }
        }
    }

    #[test]
    fn cross_traffic_unfreezes_and_reduces_budget() {
        use crate::netsim::{CrossTraffic, CrossTrafficConfig};

        let quiet = Link::new(link().params.clone(), BackgroundTraffic::constant(0.1));
        assert!(quiet.bg_frozen(), "constant background is frozen");
        let avail_quiet = quiet.available().as_bytes_per_sec();

        let contended = Link::new(link().params.clone(), BackgroundTraffic::constant(0.1))
            .with_cross_traffic(CrossTraffic::new(CrossTrafficConfig::udp_floor(0.2), 7));
        // The warm-batch gate must refuse a link with generators attached.
        assert!(!contended.bg_frozen(), "cross traffic must unfreeze the link");
        // Fractions stack: 0.1 OU + 0.2 UDP floor = 0.3 consumed.
        let avail = contended.available().as_bytes_per_sec();
        assert!(avail < avail_quiet);
        let expected = contended.params.capacity.as_bytes_per_sec() * 0.7;
        assert!((avail - expected).abs() < 1.0, "available {avail} vs {expected}");
        assert_eq!(contended.cross_traffic_fraction(), Some(0.2));
        assert_eq!(quiet.cross_traffic_fraction(), None);
    }

    #[test]
    fn combined_fraction_is_capped() {
        use crate::netsim::{CrossTraffic, CrossTrafficConfig, MAX_CROSS_FRACTION};

        let l = Link::new(link().params.clone(), BackgroundTraffic::constant(0.9))
            .with_cross_traffic(CrossTraffic::new(CrossTrafficConfig::udp_floor(0.9), 7));
        let min_avail = l.params.capacity.as_bytes_per_sec() * (1.0 - MAX_CROSS_FRACTION);
        assert!((l.available().as_bytes_per_sec() - min_avail).abs() < 1.0);
    }

    #[test]
    fn max_min_fairness_redistributes_surplus() {
        let l = link();
        // One tiny-window stream + two warm: tiny's surplus goes to the warm.
        let mut streams = vec![StreamState::new(Bytes::new(14600.0))];
        streams.extend(warm_streams(&l, 2));
        let rates = share_goodput(&l, &streams);
        let total: f64 = rates.iter().map(|r| r.as_mbps()).sum();
        // 2 warm streams can take 444 Mbps; tiny adds its cap.
        assert!(total > 440.0, "total {total}");
    }
}
