//! Typed physical units used throughout the simulator and coordinator.
//!
//! Every quantity the paper's algorithms reason about — data sizes, rates,
//! power, energy, CPU frequency, time — gets a newtype here so that unit
//! mistakes (bits vs bytes, MHz vs GHz, J vs Wh) are compile errors instead
//! of silent mis-tunings.
//!
//! Conventions:
//! * [`Bytes`] — data volume in bytes (f64; datasets reach tens of GB).
//! * [`Rate`] — network/application throughput in **bits per second**.
//! * [`Freq`] — CPU core frequency in Hz.
//! * [`Power`] — watts; [`Energy`] — joules.
//! * [`SimTime`] / [`SimDuration`] — simulation clock, seconds.

mod bytes;
mod rate;
mod freq;
mod power;
mod time;

pub use bytes::Bytes;
pub use rate::Rate;
pub use freq::Freq;
pub use power::{Energy, Power};
pub use time::{SimDuration, SimTime};

/// Round-trip time, stored as a [`SimDuration`].
pub type Rtt = SimDuration;

/// Bandwidth-delay product helper: `bandwidth * rtt`, in bytes.
///
/// This is the quantity Algorithm 1 uses both as the chunk size for large
/// files and to decide whether a file needs splitting.
pub fn bdp(bandwidth: Rate, rtt: Rtt) -> Bytes {
    Bytes::new(bandwidth.as_bits_per_sec() / 8.0 * rtt.as_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bdp_matches_table1_chameleon() {
        // Table I: 10 Gbps, 32 ms -> 40 MB.
        let b = bdp(Rate::from_gbps(10.0), SimDuration::from_millis(32.0));
        assert!((b.as_mb() - 40.0).abs() < 0.1, "got {} MB", b.as_mb());
    }

    #[test]
    fn bdp_matches_table1_cloudlab() {
        // Table I: 1 Gbps, 36 ms -> 4.5 MB.
        let b = bdp(Rate::from_gbps(1.0), SimDuration::from_millis(36.0));
        assert!((b.as_mb() - 4.5).abs() < 0.05, "got {} MB", b.as_mb());
    }

    #[test]
    fn bdp_matches_table1_didclab() {
        // Table I: 1 Gbps, 44 ms -> 5.5 MB.
        let b = bdp(Rate::from_gbps(1.0), SimDuration::from_millis(44.0));
        assert!((b.as_mb() - 5.5).abs() < 0.05, "got {} MB", b.as_mb());
    }
}
