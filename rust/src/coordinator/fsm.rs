//! Figure 1 — the shared tuning finite state machine.
//!
//! All three algorithms move through the same four states. What differs is
//! (a) how *feedback* is computed (energy estimate for ME, measured
//! throughput vs reference for EEMT, distance to target for EETT) and
//! (b) the action taken on each transition. This module encodes the state
//! graph itself so its totality/legality is testable in isolation
//! (`cargo test fsm`), plus the transition function shared by ME/EEMT.

/// Tuning states (Figure 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FsmState {
    /// Initial correction phase right after Algorithm 1.
    SlowStart,
    /// Normal operation: grow parameters on positive feedback.
    Increase,
    /// One negative feedback seen; watching whether it persists.
    Warning,
    /// Parameters were reduced; deciding whether that helped.
    Recovery,
}

impl FsmState {
    /// Short state label for traces and telemetry.
    pub fn label(self) -> &'static str {
        match self {
            FsmState::SlowStart => "slow-start",
            FsmState::Increase => "increase",
            FsmState::Warning => "warning",
            FsmState::Recovery => "recovery",
        }
    }
}

/// Channel feedback classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Feedback {
    /// Feedback improved beyond the tolerance band.
    Positive,
    /// Feedback within the tolerance band.
    Neutral,
    /// Feedback regressed beyond the tolerance band.
    Negative,
}

/// Action the algorithm should take alongside a transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Increase the channel count (`numCh += ΔCh`).
    Grow,
    /// Decrease the channel count (`numCh -= ΔCh`).
    Shrink,
    /// Restore the channel count reduced on entry to Recovery.
    Restore,
    /// Leave parameters unchanged.
    Hold,
}

/// The transition function shared by ME (Alg. 4) and EEMT (Alg. 5):
/// returns the next state and the action to apply.
///
/// * Increase: positive → stay, Grow; neutral → stay, Hold;
///   negative → Warning, Hold.
/// * Warning: positive/neutral → Increase, Hold (drop was temporary);
///   negative → Recovery, Shrink.
/// * Recovery: positive/neutral → Increase, Hold (reduction helped);
///   negative → Increase, Restore (bandwidth changed; put channels back).
/// * SlowStart is handled by [`super::slow_start`] and exits to Increase.
pub fn step(state: FsmState, feedback: Feedback) -> (FsmState, Action) {
    use Action::*;
    use Feedback::*;
    use FsmState::*;
    match (state, feedback) {
        (SlowStart, _) => (Increase, Hold),
        (Increase, Positive) => (Increase, Grow),
        (Increase, Neutral) => (Increase, Hold),
        (Increase, Negative) => (Warning, Hold),
        (Warning, Positive) | (Warning, Neutral) => (Increase, Hold),
        (Warning, Negative) => (Recovery, Shrink),
        (Recovery, Positive) | (Recovery, Neutral) => (Increase, Hold),
        (Recovery, Negative) => (Increase, Restore),
    }
}

/// Classify a measurement against a reference with the paper's (α, β)
/// bands: `> (1+β)·ref` is positive, `< (1−α)·ref` is negative, otherwise
/// neutral. Used with throughput (EEMT, EETT); ME inverts the comparison
/// because *lower* energy is good.
pub fn classify(value: f64, reference: f64, alpha: f64, beta: f64) -> Feedback {
    if value > (1.0 + beta) * reference {
        Feedback::Positive
    } else if value < (1.0 - alpha) * reference {
        Feedback::Negative
    } else {
        Feedback::Neutral
    }
}

/// Inverted classification for energy-valued feedback (lower is better).
pub fn classify_energy(value: f64, reference: f64, alpha: f64, beta: f64) -> Feedback {
    if value < (1.0 - alpha) * reference {
        Feedback::Positive
    } else if value > (1.0 + beta) * reference {
        Feedback::Negative
    } else {
        Feedback::Neutral
    }
}

#[cfg(test)]
mod tests {
    use super::Action::*;
    use super::Feedback::*;
    use super::FsmState::*;
    use super::*;

    const STATES: [FsmState; 4] = [SlowStart, Increase, Warning, Recovery];
    const FEEDBACK: [Feedback; 3] = [Positive, Neutral, Negative];

    #[test]
    fn transition_function_is_total() {
        for s in STATES {
            for f in FEEDBACK {
                let (next, _) = step(s, f);
                // SlowStart is never re-entered (Figure 1 has no edge back).
                assert_ne!(next, SlowStart, "{s:?} + {f:?} must not re-enter SlowStart");
            }
        }
    }

    #[test]
    fn increase_grows_only_on_positive() {
        assert_eq!(step(Increase, Positive), (Increase, Grow));
        assert_eq!(step(Increase, Neutral), (Increase, Hold));
        assert_eq!(step(Increase, Negative), (Warning, Hold));
    }

    #[test]
    fn warning_forgives_temporary_drops() {
        assert_eq!(step(Warning, Positive), (Increase, Hold));
        assert_eq!(step(Warning, Neutral), (Increase, Hold));
        assert_eq!(step(Warning, Negative), (Recovery, Shrink));
    }

    #[test]
    fn recovery_restores_on_persistent_drop() {
        assert_eq!(step(Recovery, Positive), (Increase, Hold));
        assert_eq!(step(Recovery, Negative), (Increase, Restore));
    }

    #[test]
    fn warning_needs_two_negatives_to_shrink() {
        // One negative: Increase -> Warning (no shrink). Second: shrink.
        let (s1, a1) = step(Increase, Negative);
        assert_eq!((s1, a1), (Warning, Hold));
        let (s2, a2) = step(s1, Negative);
        assert_eq!((s2, a2), (Recovery, Shrink));
    }

    #[test]
    fn classify_bands() {
        assert_eq!(classify(1.2, 1.0, 0.1, 0.1), Positive);
        assert_eq!(classify(1.05, 1.0, 0.1, 0.1), Neutral);
        assert_eq!(classify(0.95, 1.0, 0.1, 0.1), Neutral);
        assert_eq!(classify(0.8, 1.0, 0.1, 0.1), Negative);
    }

    #[test]
    fn classify_energy_inverts() {
        assert_eq!(classify_energy(0.8, 1.0, 0.1, 0.1), Positive);
        assert_eq!(classify_energy(1.2, 1.0, 0.1, 0.1), Negative);
        assert_eq!(classify_energy(1.0, 1.0, 0.1, 0.1), Neutral);
    }
}
