//! The migration cost model: bytes never teleport, and neither do joules.
//!
//! A live migration is simulated faithfully by the dispatcher — the
//! session's streams drain, a handoff delay passes with the session
//! resident nowhere, and the remaining bytes re-enter both TCP slow start
//! and the coordinator's slow-start FSM on the target host. This module
//! is the *predictive* side of that same price: the estimate a
//! [`Rebalancer`](super::Rebalancer) charges against a move's estimated
//! saving before proposing it, so marginal-looking moves are suppressed
//! instead of thrashing.

use crate::units::SimDuration;

/// The contention price, J/B: the extra seconds-per-byte a session
/// suffers at `bps_shared` relative to running alone at `bps_alone`,
/// charged at the host's idle draw. The one formula shared by admission
/// scoring (`HostCandidate::queue_delay_j_per_byte` in
/// [`crate::sim::dispatcher`]) and the rebalancer's move comparison
/// ([`HostView`](super::HostView)), so the two layers can never price
/// the same contention differently. Zero for degenerate inputs and when
/// sharing does not slow the session.
pub fn contention_price_j_per_byte(idle_w: f64, bps_shared: f64, bps_alone: f64) -> f64 {
    if bps_shared <= 0.0 || bps_alone <= 0.0 {
        return 0.0;
    }
    (idle_w * (1.0 / bps_shared - 1.0 / bps_alone)).max(0.0)
}

/// Round-trips the re-admitted transfer is charged for ramping back to
/// steady state: TCP window doublings from a cold congestion window plus
/// the coordinator's slow-start FSM rounds. A deliberate over-estimate —
/// hysteresis belongs on the cost side.
const RAMP_RTTS: f64 = 16.0;

/// What one migration is estimated to cost, and the knobs of that
/// estimate. The same `drain` value parameterizes the *simulated* handoff
/// (the dispatcher holds the session out of every host for exactly this
/// long), so the model and the simulation cannot drift apart on the
/// dominant term.
#[derive(Debug, Clone, Copy)]
pub struct MigrationCost {
    /// Drain/handoff delay: simulated time between preemption on the
    /// source and re-admission on the target (stream teardown, control
    /// plane, connection re-establishment).
    pub drain: SimDuration,
    /// Hysteresis: a move needs `benefit > cost × (1 + min_gain)` before
    /// the marginal-delta policy (see
    /// [`RebalancePolicyKind`](super::RebalancePolicyKind)) proposes it.
    pub min_gain: f64,
}

impl Default for MigrationCost {
    fn default() -> Self {
        MigrationCost { drain: SimDuration::from_secs(5.0), min_gain: 0.25 }
    }
}

impl MigrationCost {
    /// A cost model with an explicit drain delay (the CLI's
    /// `--migration-cost <secs>`).
    pub fn with_drain_secs(secs: f64) -> Self {
        MigrationCost {
            drain: SimDuration::from_secs(secs.max(0.0)),
            ..MigrationCost::default()
        }
    }

    /// Estimated joules one move burns, given the *target* host's idle
    /// draw, the extra watts it will draw while serving the session, and
    /// its path RTT:
    ///
    /// * the drain delay pushes the whole remaining transfer `drain`
    ///   seconds later, so the serving host stays powered that much
    ///   longer — priced at the target's idle draw;
    /// * the slow-start re-ramp wastes roughly [`RAMP_RTTS`] round-trips
    ///   of the target's *marginal* (serving-minus-idle) draw.
    pub fn estimate_joules(
        &self,
        target_idle_w: f64,
        target_marginal_w: f64,
        target_rtt_s: f64,
    ) -> f64 {
        let drain_j = self.drain.as_secs() * target_idle_w.max(0.0);
        let ramp_j = RAMP_RTTS * target_rtt_s.max(0.0) * target_marginal_w.max(0.0);
        drain_j + ramp_j
    }

    /// The gate the marginal-delta policy applies: does `benefit_j`
    /// clear the estimated cost plus the hysteresis margin? Infinite
    /// benefits (a stalled source host) always pass; NaNs never do.
    pub fn worth_it(&self, benefit_j: f64, cost_j: f64) -> bool {
        benefit_j > cost_j * (1.0 + self.min_gain.max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_scales_with_drain_and_rtt() {
        let cheap = MigrationCost::with_drain_secs(1.0);
        let slow = MigrationCost::with_drain_secs(30.0);
        let a = cheap.estimate_joules(20.0, 15.0, 0.036);
        let b = slow.estimate_joules(20.0, 15.0, 0.036);
        assert!(b > a + 500.0, "29 extra idle-seconds at 20 W: {a} vs {b}");
        // A longer path pays a bigger re-ramp.
        let lan = cheap.estimate_joules(20.0, 15.0, 0.001);
        let wan = cheap.estimate_joules(20.0, 15.0, 0.1);
        assert!(wan > lan);
        // Degenerate inputs clamp instead of going negative.
        assert_eq!(MigrationCost::with_drain_secs(-3.0).drain, SimDuration::ZERO);
        assert!(cheap.estimate_joules(-5.0, -5.0, 0.04) == 0.0);
    }

    #[test]
    fn worth_it_applies_hysteresis() {
        let m = MigrationCost { drain: SimDuration::from_secs(5.0), min_gain: 0.25 };
        assert!(!m.worth_it(100.0, 100.0), "break-even is not worth a move");
        assert!(!m.worth_it(120.0, 100.0), "inside the hysteresis band");
        assert!(m.worth_it(130.0, 100.0));
        assert!(m.worth_it(f64::INFINITY, 100.0), "stalled source always moves");
        assert!(!m.worth_it(f64::NAN, 100.0), "NaN never passes the gate");
    }
}
