"""Layer-2 JAX model: the predictor the Rust coordinator executes via PJRT.

The model wraps the Layer-1 Pallas kernel (`kernels.energy_model`) into the
jitted function that `aot.py` lowers to HLO text. Shapes are fixed at AOT
time (`layout.NUM_CANDIDATES` candidate rows); the Rust side pads its grid
to that size.

Python only ever runs at build time: the compiled artifact is executed by
`rust/src/runtime` on the coordinator's decision path.
"""

import jax
import jax.numpy as jnp

from .kernels import layout as L
from .kernels.energy_model import predict_pallas
from .kernels.ref import predict_ref


def predict(cand, state):
    """The exported entry point: (cand[N,3], state[24]) -> out[N,3]."""
    return predict_pallas(cand, state, interpret=True)


def predict_reference(cand, state):
    """Pure-jnp oracle (identical math, no Pallas) for tests."""
    return predict_ref(cand, state)


def example_args():
    """ShapeDtypeStructs the AOT pipeline lowers against."""
    return (
        jax.ShapeDtypeStruct((L.NUM_CANDIDATES, L.CAND_WIDTH), jnp.float32),
        jax.ShapeDtypeStruct((L.STATE_WIDTH,), jnp.float32),
    )


def demo_state():
    """A CloudLab-flavoured state vector (used by tests and smoke checks)."""
    s = [0.0] * L.STATE_WIDTH
    s[L.S_CAPACITY_BPS] = 115e6  # 1 Gbps * (1 - 8% bg) in bytes/s
    s[L.S_RTT_S] = 0.036
    s[L.S_AVG_WIN_BYTES] = 1e6
    s[L.S_KNEE_STREAMS] = 4.5
    s[L.S_OVERLOAD_GAMMA] = 0.02
    s[L.S_OVERLOAD_FLOOR] = 0.55
    s[L.S_PARALLELISM] = 1.0
    s[L.S_REMAINING_BYTES] = 10e9
    s[L.S_AVG_FILE_BYTES] = 2.4e6
    s[L.S_PP_LEVEL] = 2.0
    s[L.S_CYCLES_PER_BYTE] = 2.2
    s[L.S_CYCLES_PER_REQ] = 11_000.0
    s[L.S_CYCLES_PER_STREAM] = 1.4e6
    s[L.S_MAX_APP_UTIL] = 0.92
    s[L.S_PKG_STATIC_W] = 10.0
    s[L.S_CORE_IDLE_BASE_W] = 0.5
    s[L.S_CORE_IDLE_PER_GHZ_W] = 0.28
    s[L.S_DYN_KAPPA] = 1.7
    s[L.S_V_MIN] = 0.65
    s[L.S_V_MAX] = 1.05
    s[L.S_F_MIN_GHZ] = 1.2
    s[L.S_F_MAX_GHZ] = 3.4
    s[L.S_DRAM_W_PER_GBS] = 2.0
    return jnp.asarray(s, jnp.float32)


def demo_grid():
    """A (cores x freq) grid at fixed channel count, padded to NUM_CANDIDATES."""
    rows = []
    for cores in range(1, 11):
        f = 1.2
        while f <= 3.4 + 1e-9:
            rows.append((6.0, float(cores), round(f, 1)))
            f += 0.2
    rows = rows[: L.NUM_CANDIDATES]
    while len(rows) < L.NUM_CANDIDATES:
        rows.append((0.0, 0.0, 0.0))
    return jnp.asarray(rows, jnp.float32)
