//! The versioned on-disk record schema (JSONL, one record per line).
//!
//! Three record kinds share the stream, discriminated by `"kind"`:
//!
//! * `"run"` — one [`RunRecord`] per *ended residency* that moved bytes:
//!   the workload fingerprint, the path, the operating point the run
//!   settled at, what it cost, and how it ended ([`RunOutcome`]). These
//!   are what the k-NN index learns from (non-completed outcomes
//!   down-weighted, never censored — see the v3 note below).
//! * `"dispatch"` — one line per dispatcher placement decision
//!   ([`DispatchRecord`]), written for offline mining; the store counts
//!   and preserves them but does not parse them back into structs.
//! * `"migration"` — one line per rebalancer move
//!   ([`MigrationRecord`](crate::sim::MigrationRecord)), write-mostly
//!   like dispatch lines.
//!
//! Every line carries `"v"` ([`FORMAT_VERSION`]). Loaders accept every
//! version from [`MIN_SUPPORTED_VERSION`] up (missing newer optional
//! fields default) and skip lines with an *unknown* version or kind
//! (counting them), so an old binary reading a newer store degrades
//! gracefully instead of failing — the forward-compatibility contract
//! pinned by `rust/tests/history_learning.rs`.
//!
//! **v1 → v2**: run records gained `"adm_jpb"` — the dispatcher's
//! *marginal* J/B estimate for the admitting host at admission time
//! (`null`/absent on single-host runs). It gives learned placement a
//! scale-consistent observation to blend with the marginal model score,
//! instead of the full-cost attributed bill v1 could only offer.
//!
//! **v2 → v3**: run records gained `"outcome"` ([`RunOutcome`]) and the
//! fleet drivers started emitting records for runs that *ended without
//! completing* — preempted, failed under a fault, dead-lettered. Before
//! v3 the log only ever saw survivors, so the k-NN index learned a
//! biased picture of flaky hosts (their disasters were censored, their
//! lucky runs recorded). Loaders derive the outcome from the old
//! boolean `"completed"` when the key is absent (v1/v2 lines), so old
//! stores keep loading; old binaries reading v3 lines skip them by the
//! unknown-version rule, which only costs them the new samples.

use super::features::WorkloadFingerprint;
use super::json::{self, Json};
use crate::sim::{DispatchRecord, MigrationRecord};

/// Version written into every line this build produces.
pub const FORMAT_VERSION: u32 = 3;

/// Oldest line version this build still parses (older *known* versions
/// simply leave their missing optional fields unset).
pub const MIN_SUPPORTED_VERSION: u32 = 1;

/// How a recorded residency ended — the v3 field that lets the learner
/// see failures instead of only survivors (survivorship bias: a host
/// that kills half its sessions used to look *better* in the log,
/// because only its lucky half got recorded).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunOutcome {
    /// The transfer finished all its bytes on this host.
    Completed,
    /// A rebalancer/evacuation move ended the residency early; the
    /// remaining bytes continued elsewhere.
    Preempted,
    /// The residency was cut short by a fault (or ran out of simulated
    /// time) without finishing.
    Failed,
    /// The session exhausted its retry budget and was quarantined.
    DeadLettered,
}

impl RunOutcome {
    /// Stable string written into the `"outcome"` key.
    pub fn id(self) -> &'static str {
        match self {
            RunOutcome::Completed => "completed",
            RunOutcome::Preempted => "preempted",
            RunOutcome::Failed => "failed",
            RunOutcome::DeadLettered => "dead_lettered",
        }
    }

    /// Parse the stable string back; `None` for unknown values (the
    /// loader then falls back to the `"completed"` boolean).
    pub fn parse(s: &str) -> Option<RunOutcome> {
        match s {
            "completed" => Some(RunOutcome::Completed),
            "preempted" => Some(RunOutcome::Preempted),
            "failed" => Some(RunOutcome::Failed),
            "dead_lettered" => Some(RunOutcome::DeadLettered),
            _ => None,
        }
    }

    /// The pre-v3 boolean this outcome collapses to.
    pub fn is_completed(self) -> bool {
        self == RunOutcome::Completed
    }
}

/// One sample of a session's `(cores, P-state, channels)` trajectory
/// (recorded at tuning timeouts when the driver keeps timelines).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrajPoint {
    /// Simulated time of the sample, seconds.
    pub t_secs: f64,
    /// Client cores online.
    pub cores: u32,
    /// Client P-state index.
    pub pstate: u32,
    /// Channels open.
    pub channels: u32,
}

/// Everything the history subsystem remembers about one completed
/// session — see the module docs for the schema contract.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    /// Session/tenant name.
    pub session: String,
    /// Algorithm id (see [`crate::coordinator::AlgorithmKind::id`]).
    pub algorithm: String,
    /// Name of the host that served the session.
    pub host: String,
    /// Name of the testbed that host models.
    pub testbed: String,
    /// Path round-trip time, seconds.
    pub rtt_s: f64,
    /// Path bandwidth, bits/s.
    pub bandwidth_bps: f64,
    /// Workload shape at admission.
    pub workload: WorkloadFingerprint,
    /// Sessions already active on the host when this one was admitted.
    pub contention: u32,
    /// Client cores at departure (the settled operating point).
    pub cores: u32,
    /// Client P-state index at departure.
    pub pstate: u32,
    /// Channels in effect at departure (the converged concurrency).
    pub channels: u32,
    /// Most channels the session ever had open.
    pub peak_channels: u32,
    /// Whole-residency average goodput, bytes/s.
    pub goodput_bps: f64,
    /// Host instrument energy attributed to the session, joules.
    pub joules: f64,
    /// `joules / moved_bytes` — the figure the learned placement blends.
    pub j_per_byte: f64,
    /// Bytes the session moved.
    pub moved_bytes: f64,
    /// Residency on the host, seconds.
    pub duration_s: f64,
    /// Whether the transfer finished before the run's time cap. Kept
    /// alongside [`Self::outcome`] for pre-v3 readers; writers keep the
    /// two consistent (`completed == outcome.is_completed()`).
    pub completed: bool,
    /// How the residency ended (v3; derived from `completed` on older
    /// lines, so v1/v2 stores load as all-completed/all-failed).
    pub outcome: RunOutcome,
    /// The dispatcher's *marginal* J/B estimate for the admitting host
    /// at admission time (the `MarginalEnergy` model score) — `None` on
    /// single-host fleets and on v1 records. Scale-consistent with the
    /// placement model, unlike [`Self::j_per_byte`], which is the
    /// session's full attributed bill.
    pub admission_marginal_jpb: Option<f64>,
    /// Tuning-timeout trajectory (empty unless the driver recorded
    /// timelines).
    pub traj: Vec<TrajPoint>,
}

impl RunRecord {
    /// Relative calibration error of the admission-time J/B prediction
    /// against the realized bill: `realized / predicted − 1` (0 = the
    /// model was exact, +1 = the session cost twice the estimate).
    /// `None` when the record carries no prediction (single-host fleet,
    /// v1 line) or either side is non-positive.
    pub fn jpb_calibration_error(&self) -> Option<f64> {
        let predicted = self.admission_marginal_jpb?;
        if predicted <= 0.0 || self.j_per_byte <= 0.0 {
            return None;
        }
        Some(self.j_per_byte / predicted - 1.0)
    }

    /// Serialize to one JSONL line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        let traj: Vec<String> = self
            .traj
            .iter()
            .map(|p| {
                format!(
                    "{{\"t\":{},\"cores\":{},\"pstate\":{},\"ch\":{}}}",
                    json::num(p.t_secs),
                    p.cores,
                    p.pstate,
                    p.channels
                )
            })
            .collect();
        format!(
            concat!(
                "{{\"v\":{},\"kind\":\"run\",\"session\":\"{}\",\"algo\":\"{}\",",
                "\"host\":\"{}\",\"testbed\":\"{}\",\"rtt_s\":{},\"bw_bps\":{},",
                "\"total_bytes\":{},\"num_files\":{},\"avg_file_bytes\":{},",
                "\"frac_small\":{},\"frac_medium\":{},\"frac_large\":{},",
                "\"contention\":{},\"cores\":{},\"pstate\":{},\"channels\":{},",
                "\"peak_channels\":{},\"goodput_bps\":{},\"joules\":{},",
                "\"j_per_byte\":{},\"moved_bytes\":{},\"duration_s\":{},",
                "\"completed\":{},\"outcome\":\"{}\",\"adm_jpb\":{},\"traj\":[{}]}}"
            ),
            FORMAT_VERSION,
            json::escape(&self.session),
            json::escape(&self.algorithm),
            json::escape(&self.host),
            json::escape(&self.testbed),
            json::num(self.rtt_s),
            json::num(self.bandwidth_bps),
            json::num(self.workload.total_bytes),
            self.workload.num_files,
            json::num(self.workload.avg_file_bytes),
            json::num(self.workload.frac_small),
            json::num(self.workload.frac_medium),
            json::num(self.workload.frac_large),
            self.contention,
            self.cores,
            self.pstate,
            self.channels,
            self.peak_channels,
            json::num(self.goodput_bps),
            json::num(self.joules),
            json::num(self.j_per_byte),
            json::num(self.moved_bytes),
            json::num(self.duration_s),
            self.completed,
            self.outcome.id(),
            match self.admission_marginal_jpb {
                Some(m) => json::num(m),
                None => "null".to_string(),
            },
            traj.join(",")
        )
    }

    /// Rebuild a record from a parsed `"kind":"run"` object. `None` when
    /// any required field is missing or mistyped (the store counts such
    /// lines as skipped).
    pub fn from_json(v: &Json) -> Option<RunRecord> {
        let f = |key: &str| v.get(key).and_then(Json::as_f64);
        let u = |key: &str| v.get(key).and_then(Json::as_u32);
        let s = |key: &str| v.get(key).and_then(Json::as_str).map(str::to_string);
        let mut traj = Vec::new();
        for p in v.get("traj").and_then(Json::as_arr).unwrap_or(&[]) {
            traj.push(TrajPoint {
                t_secs: p.get("t").and_then(Json::as_f64)?,
                cores: p.get("cores").and_then(Json::as_u32)?,
                pstate: p.get("pstate").and_then(Json::as_u32)?,
                channels: p.get("ch").and_then(Json::as_u32)?,
            });
        }
        let completed = v.get("completed").and_then(Json::as_bool)?;
        // v3 optional: older lines only have the boolean, which maps
        // completed→Completed and not-completed→Failed (the only two
        // fates a pre-v3 writer could record).
        let outcome = v
            .get("outcome")
            .and_then(Json::as_str)
            .and_then(RunOutcome::parse)
            .unwrap_or(if completed { RunOutcome::Completed } else { RunOutcome::Failed });
        Some(RunRecord {
            session: s("session")?,
            algorithm: s("algo")?,
            host: s("host")?,
            testbed: s("testbed")?,
            rtt_s: f("rtt_s")?,
            bandwidth_bps: f("bw_bps")?,
            workload: WorkloadFingerprint {
                total_bytes: f("total_bytes")?,
                num_files: v.get("num_files").and_then(Json::as_u64)?,
                avg_file_bytes: f("avg_file_bytes")?,
                frac_small: f("frac_small")?,
                frac_medium: f("frac_medium")?,
                frac_large: f("frac_large")?,
            },
            contention: u("contention")?,
            cores: u("cores")?,
            pstate: u("pstate")?,
            channels: u("channels")?,
            peak_channels: u("peak_channels")?,
            goodput_bps: f("goodput_bps")?,
            joules: f("joules")?,
            j_per_byte: f("j_per_byte")?,
            moved_bytes: f("moved_bytes")?,
            duration_s: f("duration_s")?,
            completed,
            outcome,
            // v2 optional: absent (v1) and null both mean "not recorded".
            admission_marginal_jpb: f("adm_jpb"),
            traj,
        })
    }
}

/// Serialize one dispatcher decision to its JSONL line (no trailing
/// newline). Scores keep the host order of the decision.
pub fn dispatch_to_json_line(d: &DispatchRecord) -> String {
    let scores: Vec<String> = d
        .scores
        .iter()
        .map(|s| {
            let learned = match s.learned_j_per_byte {
                Some(x) => json::num(x),
                None => "null".to_string(),
            };
            format!(
                concat!(
                    "{{\"host\":\"{}\",\"active\":{},\"cur_w\":{},\"proj_w\":{},",
                    "\"bps\":{},\"jpb\":{},\"queue_jpb\":{},\"learned_jpb\":{}}}"
                ),
                json::escape(&s.host),
                s.active_sessions,
                json::num(s.current_power_w),
                json::num(s.projected_power_w),
                json::num(s.projected_session_bps),
                json::num(s.marginal_j_per_byte),
                json::num(s.queue_delay_j_per_byte),
                learned
            )
        })
        .collect();
    format!(
        concat!(
            "{{\"v\":{},\"kind\":\"dispatch\",\"t\":{},\"session\":\"{}\",",
            "\"requested_at\":{},\"admitted_host\":{},\"host\":{},",
            "\"fleet_w\":{},\"scores\":[{}]}}"
        ),
        FORMAT_VERSION,
        json::num(d.t_secs),
        json::escape(&d.session),
        json::num(d.requested_at_secs),
        match d.admitted_host {
            Some(h) => h.to_string(),
            None => "null".to_string(),
        },
        match &d.host {
            Some(h) => format!("\"{}\"", json::escape(h)),
            None => "null".to_string(),
        },
        json::num(d.projected_fleet_power_w),
        scores.join(",")
    )
}

/// Serialize one rebalancer migration to its JSONL line (no trailing
/// newline). Write-mostly like dispatch lines: the store preserves and
/// counts them for offline mining, nothing parses them back in-process.
pub fn migration_to_json_line(m: &MigrationRecord) -> String {
    format!(
        concat!(
            "{{\"v\":{},\"kind\":\"migration\",\"t\":{},\"session\":\"{}\",",
            "\"from_host\":{},\"from\":\"{}\",\"to_host\":{},\"to\":\"{}\",",
            "\"moved_bytes\":{},\"remaining_bytes\":{},\"drain_s\":{},",
            "\"resume_at\":{},\"est_benefit_j\":{},\"est_cost_j\":{},",
            "\"policy\":\"{}\"}}"
        ),
        FORMAT_VERSION,
        json::num(m.t_secs),
        json::escape(&m.session),
        m.from_host,
        json::escape(&m.from),
        m.to_host,
        json::escape(&m.to),
        json::num(m.moved_bytes),
        json::num(m.remaining_bytes),
        json::num(m.drain_secs),
        json::num(m.resume_at_secs),
        json::num(m.est_benefit_j),
        json::num(m.est_cost_j),
        json::escape(m.policy),
    )
}

/// A fully populated sample record shared by the history unit tests.
#[cfg(test)]
pub(crate) fn sample_record() -> RunRecord {
    RunRecord {
        session: "tenant-0".to_string(),
        algorithm: "history".to_string(),
        host: "host0-DIDCLab".to_string(),
        testbed: "DIDCLab".to_string(),
        rtt_s: 0.044,
        bandwidth_bps: 1e9,
        workload: WorkloadFingerprint {
            total_bytes: 11.7e9,
            num_files: 5000,
            avg_file_bytes: 2.34e6,
            frac_small: 0.0,
            frac_medium: 1.0,
            frac_large: 0.0,
        },
        contention: 1,
        cores: 2,
        pstate: 1,
        channels: 9,
        peak_channels: 14,
        goodput_bps: 1.0817e8,
        joules: 8123.25,
        j_per_byte: 8123.25 / 11.7e9,
        moved_bytes: 11.7e9,
        duration_s: 108.2,
        completed: true,
        outcome: RunOutcome::Completed,
        admission_marginal_jpb: Some(3.2e-7),
        traj: vec![
            TrajPoint { t_secs: 3.0, cores: 1, pstate: 0, channels: 6 },
            TrajPoint { t_secs: 6.0, cores: 2, pstate: 0, channels: 12 },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::PlacementScore;

    fn sample() -> RunRecord {
        sample_record()
    }

    #[test]
    fn run_record_round_trips_bit_for_bit() {
        let r = sample();
        let line = r.to_json_line();
        let v = crate::history::json::parse(&line).expect("line must be valid JSON");
        assert_eq!(v.get("v").and_then(Json::as_u32), Some(FORMAT_VERSION));
        assert_eq!(v.get("kind").and_then(Json::as_str), Some("run"));
        let back = RunRecord::from_json(&v).expect("round trip");
        assert_eq!(back, r);
        // f64 equality above is bitwise in practice (shortest round-trip
        // rendering); pin the sharpest field explicitly.
        assert_eq!(back.j_per_byte.to_bits(), r.j_per_byte.to_bits());
    }

    #[test]
    fn jpb_calibration_error_joins_prediction_and_bill() {
        let mut r = sample();
        r.j_per_byte = 3.0e-7;
        r.admission_marginal_jpb = Some(2.0e-7);
        assert!((r.jpb_calibration_error().unwrap() - 0.5).abs() < 1e-12);
        // Exact prediction → zero error.
        r.admission_marginal_jpb = Some(3.0e-7);
        assert_eq!(r.jpb_calibration_error(), Some(0.0));
        // No prediction (single-host fleet) or degenerate sides → None.
        r.admission_marginal_jpb = None;
        assert_eq!(r.jpb_calibration_error(), None);
        r.admission_marginal_jpb = Some(0.0);
        assert_eq!(r.jpb_calibration_error(), None);
        r.admission_marginal_jpb = Some(2.0e-7);
        r.j_per_byte = 0.0;
        assert_eq!(r.jpb_calibration_error(), None);
    }

    #[test]
    fn missing_fields_reject_the_record() {
        let r = sample();
        let line = r.to_json_line().replace("\"cores\":2,", "");
        let v = crate::history::json::parse(&line).unwrap();
        assert!(RunRecord::from_json(&v).is_none());
    }

    #[test]
    fn v1_lines_without_the_marginal_field_still_parse() {
        // A v1 writer never emitted "adm_jpb" or "outcome": stripping
        // both (and carrying the old version stamp) must load with the
        // fields defaulted — the forgiving-loader side of the bumps.
        let mut r = sample();
        r.admission_marginal_jpb = Some(1.5e-7);
        let rendered = format!("\"adm_jpb\":{},", crate::history::json::num(1.5e-7));
        let line = r
            .to_json_line()
            .replace(&rendered, "")
            .replace("\"outcome\":\"completed\",", "")
            .replace("\"v\":3,", "\"v\":1,");
        let v = crate::history::json::parse(&line).expect("stripped line stays valid JSON");
        let back = RunRecord::from_json(&v).expect("v1 shape must parse");
        assert_eq!(back.admission_marginal_jpb, None);
        assert_eq!(back.outcome, RunOutcome::Completed);
        assert_eq!(back.cores, r.cores);
        // And an explicit null means the same thing.
        let nulled = r.to_json_line().replace(&rendered, "\"adm_jpb\":null,");
        let v = crate::history::json::parse(&nulled).unwrap();
        assert_eq!(RunRecord::from_json(&v).unwrap().admission_marginal_jpb, None);
    }

    #[test]
    fn v2_lines_derive_the_outcome_from_the_completed_boolean() {
        // A v2 writer emitted "completed" but not "outcome": the loader
        // must map true→Completed and false→Failed.
        let r = sample();
        let line = r
            .to_json_line()
            .replace("\"outcome\":\"completed\",", "")
            .replace("\"v\":3,", "\"v\":2,");
        let v = crate::history::json::parse(&line).unwrap();
        assert_eq!(RunRecord::from_json(&v).unwrap().outcome, RunOutcome::Completed);
        let line = line.replace("\"completed\":true,", "\"completed\":false,");
        let v = crate::history::json::parse(&line).unwrap();
        let back = RunRecord::from_json(&v).unwrap();
        assert_eq!(back.outcome, RunOutcome::Failed);
        assert!(!back.completed);
    }

    #[test]
    fn every_outcome_round_trips() {
        for (oc, done) in [
            (RunOutcome::Completed, true),
            (RunOutcome::Preempted, false),
            (RunOutcome::Failed, false),
            (RunOutcome::DeadLettered, false),
        ] {
            let mut r = sample();
            r.outcome = oc;
            r.completed = done;
            assert_eq!(oc.is_completed(), done);
            assert_eq!(RunOutcome::parse(oc.id()), Some(oc));
            let v = crate::history::json::parse(&r.to_json_line()).unwrap();
            assert_eq!(RunRecord::from_json(&v).unwrap(), r);
        }
        assert_eq!(RunOutcome::parse("exploded"), None);
    }

    #[test]
    fn migration_line_is_valid_json() {
        let m = MigrationRecord {
            t_secs: 120.5,
            session: "session-1".to_string(),
            from_host: 1,
            from: "legacy".to_string(),
            to_host: 0,
            to: "efficient".to_string(),
            moved_bytes: 9.5e9,
            remaining_bytes: 18.3e9,
            drain_secs: 5.0,
            resume_at_secs: 125.5,
            est_benefit_j: 4100.0,
            est_cost_j: 160.0,
            policy: "cap-pressure",
        };
        let v = crate::history::json::parse(&migration_to_json_line(&m)).unwrap();
        assert_eq!(v.get("v").and_then(Json::as_u32), Some(FORMAT_VERSION));
        assert_eq!(v.get("kind").and_then(Json::as_str), Some("migration"));
        assert_eq!(v.get("session").and_then(Json::as_str), Some("session-1"));
        assert_eq!(v.get("from").and_then(Json::as_str), Some("legacy"));
        assert_eq!(v.get("to").and_then(Json::as_str), Some("efficient"));
        assert_eq!(v.get("policy").and_then(Json::as_str), Some("cap-pressure"));
        let moved = v.get("moved_bytes").and_then(Json::as_f64).unwrap();
        let rem = v.get("remaining_bytes").and_then(Json::as_f64).unwrap();
        assert_eq!(moved.to_bits(), 9.5e9f64.to_bits());
        assert_eq!(rem.to_bits(), 18.3e9f64.to_bits());
    }

    #[test]
    fn dispatch_line_is_valid_json_with_scores() {
        let d = DispatchRecord {
            t_secs: 12.5,
            session: "session-3".to_string(),
            requested_at_secs: 10.0,
            admitted_host: Some(1),
            host: Some("legacy".to_string()),
            projected_fleet_power_w: 95.5,
            scores: vec![PlacementScore {
                host: "legacy".to_string(),
                active_sessions: 2,
                current_power_w: 40.0,
                projected_power_w: 55.0,
                projected_session_bps: 5e7,
                marginal_j_per_byte: 3e-7,
                queue_delay_j_per_byte: 0.0,
                learned_j_per_byte: None,
            }],
        };
        let v = crate::history::json::parse(&dispatch_to_json_line(&d)).unwrap();
        assert_eq!(v.get("kind").and_then(Json::as_str), Some("dispatch"));
        assert_eq!(v.get("session").and_then(Json::as_str), Some("session-3"));
        let scores = v.get("scores").and_then(Json::as_arr).unwrap();
        assert_eq!(scores.len(), 1);
        assert_eq!(scores[0].get("learned_jpb"), Some(&Json::Null));
        // A queued decision renders nulls.
        let mut q = d.clone();
        q.admitted_host = None;
        q.host = None;
        let v = crate::history::json::parse(&dispatch_to_json_line(&q)).unwrap();
        assert_eq!(v.get("admitted_host"), Some(&Json::Null));
        assert_eq!(v.get("host"), Some(&Json::Null));
    }
}
