//! Figure-level shape assertions: the paper's qualitative claims must
//! hold on every regeneration (who wins, and by roughly what factor).

use greendt::experiments::{fig2, fig3, fig4, validate};
use greendt::units::Rate;

#[test]
fn tables_match_paper() {
    assert!(validate::check(42).is_empty());
}

#[test]
fn fig2_shapes_hold() {
    let r = fig2::run(42);

    for tb in fig2::TESTBEDS {
        for ds in fig2::DATASETS {
            // wget is always the slowest tool; our EEMT is never beaten.
            let wget = r.outcome(tb, ds, "wget").avg_throughput.as_bits_per_sec();
            let eemt = r.outcome(tb, ds, "EEMT").avg_throughput.as_bits_per_sec();
            for tool in ["curl", "http2", "Ismail-ME", "Ismail-MT", "ME"] {
                let t = r.outcome(tb, ds, tool).avg_throughput.as_bits_per_sec();
                assert!(t >= wget * 0.99, "{tool} slower than wget on {tb}/{ds}");
                assert!(eemt >= t * 0.93, "EEMT beaten by {tool} on {tb}/{ds}");
            }
            // ME never uses more energy than the simple tools.
            let me = r.outcome(tb, ds, "ME").client_energy.as_joules();
            for tool in ["wget", "curl", "http2"] {
                let e = r.outcome(tb, ds, tool).client_energy.as_joules();
                assert!(me < e, "ME not cheaper than {tool} on {tb}/{ds}");
            }
        }
    }

    // §V-A headline factors on Chameleon/mixed (direction + rough size).
    let h = r.headlines();
    assert!(h.me_energy_reduction > 0.35, "ME saving {:.2} (paper 0.48)", h.me_energy_reduction);
    assert!(h.eemt_tput_gain > 0.50, "EEMT gain {:.2} (paper 0.80)", h.eemt_tput_gain);
    assert!(
        h.eemt_energy_reduction > 0.25,
        "EEMT saving {:.2} (paper 0.43)",
        h.eemt_energy_reduction
    );

    // http2 beats curl on small files; on the WAN it is window-limited.
    let h2 = r.outcome("chameleon", "small", "http2").avg_throughput;
    let curl = r.outcome("chameleon", "small", "curl").avg_throughput;
    assert!(h2.as_bits_per_sec() > 5.0 * curl.as_bits_per_sec());
    let h2_large = r.outcome("chameleon", "large", "http2").avg_throughput;
    assert!(h2_large.as_gbps() < 1.5, "http2 must stay window-limited");
}

#[test]
fn fig3_shapes_hold() {
    let r = fig3::run(42);
    for (tb, bw) in fig3::PANELS {
        for frac in fig3::FRACTIONS {
            let target = Rate::from_mbps(bw * frac);
            let eett = r.outcome(tb, target, "EETT");
            let ismail = r.outcome(tb, target, "Ismail-TT");
            let err = (eett.avg_throughput.as_mbps() - target.as_mbps()).abs()
                / target.as_mbps();
            assert!(err < 0.15, "EETT err {:.2} on {tb} @ {target}", err);
            // EETT never uses more energy when achieving a comparable rate.
            if (ismail.avg_throughput.as_mbps() - target.as_mbps()).abs() / target.as_mbps()
                < 0.25
            {
                assert!(
                    eett.client_energy.as_joules() < ismail.client_energy.as_joules() * 1.05,
                    "EETT energy {} vs Ismail {} on {tb} @ {target}",
                    eett.client_energy,
                    ismail.client_energy
                );
            }
        }
    }
    // The slow-ramp complaint: Ismail-TT misses high targets badly.
    let high = Rate::from_mbps(10_000.0 * 0.8);
    let ismail_high = r.outcome("chameleon", high, "Ismail-TT");
    assert!(ismail_high.avg_throughput.as_gbps() < 0.8 * 8.0);
}

#[test]
fn fig4_shapes_hold() {
    let r = fig4::run(42);
    for tb in fig4::TESTBEDS {
        // Scaling always helps, on every testbed.
        let me_gain = r.reduction(tb, "ME", "ME w/o scaling");
        let eemt_gain = r.reduction(tb, "EEMT", "EEMT w/o scaling");
        assert!(me_gain > 0.05, "ME scaling gain {me_gain:.2} on {tb}");
        assert!(eemt_gain > 0.05, "EEMT scaling gain {eemt_gain:.2} on {tb}");
        // And the full systems beat Alan et al.
        assert!(r.reduction(tb, "ME", "Alan-ME") > 0.10, "{tb}");
        assert!(r.reduction(tb, "EEMT", "Alan-MT") > 0.10, "{tb}");
    }
    // On the big-BDP testbed, tuning alone (w/o scaling) already wins
    // substantially (paper: −42 % / −30 %).
    assert!(r.reduction("chameleon", "ME w/o scaling", "Alan-ME") > 0.15);
    assert!(r.reduction("chameleon", "EEMT w/o scaling", "Alan-MT") > 0.15);
}
