//! Transfer a *real* corpus described by a manifest file.
//!
//!     cargo run --release --example manifest_transfer [manifest.csv]
//!
//! A manifest is a `name,size_bytes` CSV (what
//! `find DIR -type f -printf '%p,%s\n'` emits). Without an argument this
//! example writes a demo manifest (a Linux-kernel-tree-like mix of many
//! small sources and a few large objects), loads it back, and moves it
//! over DIDCLab under the Minimum Energy SLA.

use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::dataset::{load_manifest, save_manifest, Dataset, FileSpec};
use greendt::rng::{self, Distribution, LogNormal};
use greendt::sim::session::{run_session, SessionConfig};
use greendt::units::Bytes;

fn demo_manifest(path: &std::path::Path) -> anyhow::Result<()> {
    // ~3k small sources (mean 14 KB), 40 build artifacts (mean 60 MB).
    let mut rng = rng::stream(7, "manifest-demo");
    let small = LogNormal::from_mean_std(14e3, 22e3);
    let big = LogNormal::from_mean_std(60e6, 25e6);
    let mut files = Vec::new();
    for i in 0..3000u32 {
        files.push(FileSpec::new(i, Bytes::new(small.sample(&mut rng).max(128.0))));
    }
    for i in 0..40u32 {
        files.push(FileSpec::new(3000 + i, Bytes::new(big.sample(&mut rng).max(1e6))));
    }
    save_manifest(&Dataset::new("kernel-tree", files), path)?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let arg = std::env::args().nth(1);
    let path = match &arg {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            let p = std::env::temp_dir().join("greendt_demo_manifest.csv");
            demo_manifest(&p)?;
            println!("(no manifest given — wrote a demo corpus to {})\n", p.display());
            p
        }
    };

    let dataset = load_manifest(&path)?;
    println!(
        "manifest '{}': {} files, {} total (avg {}, std {})",
        dataset.name,
        dataset.num_files(),
        dataset.total_size(),
        dataset.avg_file_size(),
        dataset.std_file_size()
    );

    let cfg = SessionConfig::new(testbeds::didclab(), dataset, AlgorithmKind::MinEnergy);
    let out = run_session(&cfg);
    assert!(out.completed);
    println!("\nME over DIDCLab:");
    println!("  duration       : {}", out.duration);
    println!("  avg throughput : {}", out.avg_throughput);
    println!("  client energy  : {} (wall meter)", out.client_energy);
    println!("  final CPU      : {} cores @ {}", out.final_active_cores, out.final_freq);
    Ok(())
}
