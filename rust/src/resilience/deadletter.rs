//! The dead-letter queue: bounded quarantine for sessions that cannot
//! be served.
//!
//! A session that exhausts its retry budget (or is lost to a failure
//! in a run with recovery disabled) must not vanish silently: its
//! bytes are part of the byte-conservation ledger, and the fleet
//! report must account for every admitted byte as delivered,
//! retried-and-delivered, or dead-lettered. The queue is bounded —
//! quarantine is evidence, not a landfill — and overflow is *counted*,
//! never hidden.

/// Why a session was dead-lettered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureReason {
    /// The serving host died and the run's recovery machinery is off —
    /// the loss is terminal by configuration.
    HostFailure,
    /// The session was retried up to its budget and lost its host
    /// every time.
    RetryBudgetExhausted,
}

impl FailureReason {
    /// Stable identifier (telemetry tables and JSON lines).
    pub fn id(&self) -> &'static str {
        match self {
            FailureReason::HostFailure => "host-failure",
            FailureReason::RetryBudgetExhausted => "retry-budget-exhausted",
        }
    }
}

/// One quarantined session: what it was, where it died, and how many
/// bytes it still owed.
#[derive(Debug, Clone, PartialEq)]
pub struct DeadLetter {
    /// Session name.
    pub session: String,
    /// Host index the final failure happened on.
    pub host: usize,
    /// Why the session ended here.
    pub reason: FailureReason,
    /// Placement attempts the session consumed (1 = never retried).
    pub attempts: u32,
    /// Bytes the session delivered across all its residencies.
    pub moved_bytes: f64,
    /// Bytes it still owed when quarantined.
    pub remaining_bytes: f64,
    /// Simulated time of quarantine, seconds.
    pub at_secs: f64,
}

/// Bounded FIFO of [`DeadLetter`]s. Entries past the capacity are
/// dropped *and counted* — the report can always say how many losses
/// it could not itemize.
#[derive(Debug, Clone)]
pub struct DeadLetterQueue {
    capacity: usize,
    entries: Vec<DeadLetter>,
    dropped: u64,
}

impl DeadLetterQueue {
    /// An empty queue holding at most `capacity` entries.
    pub fn new(capacity: usize) -> Self {
        DeadLetterQueue { capacity: capacity.max(1), entries: Vec::new(), dropped: 0 }
    }

    /// Quarantine one session. Returns `false` when the queue was full
    /// and the entry was counted instead of stored.
    pub fn push(&mut self, letter: DeadLetter) -> bool {
        if self.entries.len() < self.capacity {
            self.entries.push(letter);
            true
        } else {
            self.dropped += 1;
            false
        }
    }

    /// The quarantined sessions, oldest first.
    pub fn entries(&self) -> &[DeadLetter] {
        &self.entries
    }

    /// Quarantined session count (stored entries only).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing was quarantined (and nothing overflowed).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty() && self.dropped == 0
    }

    /// Entries the bound forced out (0 unless the run lost more
    /// sessions than the queue holds).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Tear down into the stored entries and the overflow count.
    pub fn into_parts(self) -> (Vec<DeadLetter>, u64) {
        (self.entries, self.dropped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn letter(name: &str) -> DeadLetter {
        DeadLetter {
            session: name.to_string(),
            host: 0,
            reason: FailureReason::RetryBudgetExhausted,
            attempts: 4,
            moved_bytes: 1e9,
            remaining_bytes: 2e9,
            at_secs: 300.0,
        }
    }

    #[test]
    fn bounded_queue_counts_overflow_instead_of_hiding_it() {
        let mut q = DeadLetterQueue::new(2);
        assert!(q.is_empty());
        assert!(q.push(letter("a")));
        assert!(q.push(letter("b")));
        assert!(!q.push(letter("c")), "third entry overflows");
        assert_eq!(q.len(), 2);
        assert_eq!(q.dropped(), 1);
        assert!(!q.is_empty());
        assert_eq!(q.entries()[0].session, "a");
        let (entries, dropped) = q.into_parts();
        assert_eq!(entries.len(), 2);
        assert_eq!(dropped, 1);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut q = DeadLetterQueue::new(0);
        assert!(q.push(letter("a")), "a degenerate bound still quarantines one entry");
        assert_eq!(q.len(), 1);
    }
}
