//! CPU frequency newtype.

use std::fmt;
use std::ops::{Add, Div, Mul, Sub};

/// A CPU core frequency in Hz.
///
/// The DVFS ladders in [`crate::cpusim`] are expressed as lists of `Freq`
/// P-states; Algorithm 3 walks them one step at a time.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Freq(f64);

impl Freq {
    /// Zero hertz.
    pub const ZERO: Freq = Freq(0.0);

    /// Construct from hertz.
    pub fn from_hz(hz: f64) -> Self {
        Freq(if hz > 0.0 { hz } else { 0.0 })
    }

    /// Construct from megahertz.
    pub fn from_mhz(mhz: f64) -> Self {
        Freq::from_hz(mhz * 1e6)
    }

    /// Construct from gigahertz.
    pub fn from_ghz(ghz: f64) -> Self {
        Freq::from_hz(ghz * 1e9)
    }

    /// Value in hertz.
    pub fn as_hz(self) -> f64 {
        self.0
    }

    /// Value in megahertz.
    pub fn as_mhz(self) -> f64 {
        self.0 / 1e6
    }

    /// Value in gigahertz.
    pub fn as_ghz(self) -> f64 {
        self.0 / 1e9
    }

    /// The lower of two frequencies.
    pub fn min(self, other: Freq) -> Freq {
        Freq(self.0.min(other.0))
    }

    /// The higher of two frequencies.
    pub fn max(self, other: Freq) -> Freq {
        Freq(self.0.max(other.0))
    }

    /// Cycles executed over `secs` seconds at this frequency.
    pub fn cycles_over(self, secs: f64) -> f64 {
        self.0 * secs
    }
}

impl Add for Freq {
    type Output = Freq;
    fn add(self, rhs: Freq) -> Freq {
        Freq(self.0 + rhs.0)
    }
}

impl Sub for Freq {
    type Output = Freq;
    fn sub(self, rhs: Freq) -> Freq {
        Freq::from_hz(self.0 - rhs.0)
    }
}

impl Mul<f64> for Freq {
    type Output = Freq;
    fn mul(self, rhs: f64) -> Freq {
        Freq::from_hz(self.0 * rhs)
    }
}

impl Div for Freq {
    type Output = f64;
    fn div(self, rhs: Freq) -> f64 {
        if rhs.0 == 0.0 {
            0.0
        } else {
            self.0 / rhs.0
        }
    }
}

impl fmt::Display for Freq {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.2} GHz", self.as_ghz())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(Freq::from_ghz(2.5).as_mhz(), 2500.0);
        assert_eq!(Freq::from_mhz(1200.0).as_ghz(), 1.2);
    }

    #[test]
    fn cycles_over_seconds() {
        assert_eq!(Freq::from_ghz(2.0).cycles_over(0.5), 1e9);
    }

    #[test]
    fn ordering_works() {
        assert!(Freq::from_ghz(1.2) < Freq::from_ghz(3.5));
    }
}
