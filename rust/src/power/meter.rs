//! Energy meters: RAPL-style package meter and wall-socket node meter.

use crate::units::{Energy, Power, SimDuration, SimTime};

/// One power sample (kept for time-series plots and debugging).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergySample {
    /// When the sample was taken.
    pub at: SimTime,
    /// Instantaneous power at that instant.
    pub power: Power,
}

/// Intel-RAPL-equivalent: integrates package (+DRAM) power over time and
/// exposes cumulative energy counters, like reading
/// `/sys/class/powercap/intel-rapl/.../energy_uj` at two instants.
#[derive(Debug, Clone, Default)]
pub struct RaplMeter {
    total: Energy,
    samples: Vec<EnergySample>,
    keep_samples: bool,
}

impl RaplMeter {
    /// A meter with cumulative counters only (no sample series).
    pub fn new() -> Self {
        RaplMeter { total: Energy::ZERO, samples: Vec::new(), keep_samples: false }
    }

    /// Also retain the full sample series (costs memory; used by reports).
    pub fn recording() -> Self {
        RaplMeter { total: Energy::ZERO, samples: Vec::new(), keep_samples: true }
    }

    /// Integrate one tick at constant `power`.
    pub fn record(&mut self, at: SimTime, power: Power, dt: SimDuration) {
        self.total += power.over(dt);
        if self.keep_samples {
            self.samples.push(EnergySample { at, power });
        }
    }

    /// Cumulative energy counter (the "RAPL reading").
    pub fn total(&self) -> Energy {
        self.total
    }

    /// Energy consumed since a previous reading.
    pub fn since(&self, earlier: Energy) -> Energy {
        self.total.saturating_sub(earlier)
    }

    /// The retained sample series (empty unless recording).
    pub fn samples(&self) -> &[EnergySample] {
        &self.samples
    }
}

/// Wall-socket meter (the Yokogawa WT210 on the DIDCLab client): package
/// power plus a constant platform base — NIC, fans, VRM losses, idle disks.
#[derive(Debug, Clone)]
pub struct NodeMeter {
    rapl: RaplMeter,
    base: Power,
}

impl NodeMeter {
    /// A wall meter with the given always-on platform base.
    pub fn new(base: Power) -> Self {
        NodeMeter { rapl: RaplMeter::new(), base }
    }

    /// Default platform base for the paper's server-class nodes.
    pub fn standard() -> Self {
        NodeMeter::new(Power::from_watts(45.0))
    }

    /// Integrate one tick: package power plus the platform base.
    pub fn record(&mut self, at: SimTime, package: Power, dt: SimDuration) {
        self.rapl.record(at, package + self.base, dt);
    }

    /// Cumulative wall energy.
    pub fn total(&self) -> Energy {
        self.rapl.total()
    }

    /// The always-on platform base power.
    pub fn base(&self) -> Power {
        self.base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_power_over_time() {
        let mut m = RaplMeter::new();
        let dt = SimDuration::from_millis(100.0);
        let mut t = SimTime::ZERO;
        for _ in 0..100 {
            m.record(t, Power::from_watts(50.0), dt);
            t += dt;
        }
        // 50 W * 10 s = 500 J
        assert!((m.total().as_joules() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn since_gives_interval_energy() {
        let mut m = RaplMeter::new();
        m.record(SimTime::ZERO, Power::from_watts(10.0), SimDuration::from_secs(1.0));
        let checkpoint = m.total();
        m.record(SimTime::from_secs(1.0), Power::from_watts(20.0), SimDuration::from_secs(2.0));
        assert!((m.since(checkpoint).as_joules() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn recording_keeps_samples() {
        let mut m = RaplMeter::recording();
        m.record(SimTime::ZERO, Power::from_watts(5.0), SimDuration::from_secs(1.0));
        m.record(SimTime::from_secs(1.0), Power::from_watts(6.0), SimDuration::from_secs(1.0));
        assert_eq!(m.samples().len(), 2);
        assert_eq!(m.samples()[1].power, Power::from_watts(6.0));
        let quiet = RaplMeter::new();
        assert!(quiet.samples().is_empty());
    }

    #[test]
    fn node_meter_adds_base() {
        let mut m = NodeMeter::new(Power::from_watts(40.0));
        m.record(SimTime::ZERO, Power::from_watts(60.0), SimDuration::from_secs(10.0));
        assert!((m.total().as_joules() - 1000.0).abs() < 1e-9);
    }
}
