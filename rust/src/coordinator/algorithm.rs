//! The common tuning-algorithm interface and factory.

use super::load_control::{Governor, NullGovernor, OndemandGovernor, ThresholdGovernor};
use super::sla::SlaPolicy;
use crate::config::experiment::{GovernorKind, TunerParams};
use crate::config::Testbed;
use crate::cpusim::CpuState;
use crate::dataset::{Dataset, Partition};
use crate::sim::{Telemetry, TuneCtx};
use crate::units::{Rate, SimDuration};

/// Everything a session needs to start: Algorithm 1's output (or a
/// baseline's static choice).
#[derive(Debug, Clone)]
pub struct InitPlan {
    /// Partitioned dataset (Algorithm 1 lines 1–8).
    pub partitions: Vec<Partition>,
    /// Initial channel count.
    pub num_channels: u32,
    /// Initial client CPU setting.
    pub client_cpu: CpuState,
    /// Extra per-file round-trips applied to every partition (0 for
    /// persistent-connection tools; wget pays handshakes per file).
    pub handshake_rtts: f64,
}

impl InitPlan {
    /// Bundle an init plan.
    pub fn new(partitions: Vec<Partition>, num_channels: u32, client_cpu: CpuState) -> Self {
        InitPlan { partitions, num_channels, client_cpu, handshake_rtts: 0.0 }
    }
}

/// A runtime tuning algorithm driving one transfer session.
///
/// `Send` is a supertrait: sessions live inside the crate-internal
/// `HostWorld`s (`crate::sim::fleet`), which the sharded dispatcher moves across
/// worker threads between driver events. An algorithm must not hold
/// thread-pinned state (`Rc`, raw thread-local handles) — keep such
/// caches keyed per thread instead, as the PJRT runtime does
/// (`crate::runtime::Executable`).
pub trait Algorithm: std::fmt::Debug + Send {
    /// Algorithm name as the paper's figures label it.
    fn name(&self) -> &'static str;

    /// Tuning interval: the session driver calls [`Self::on_timeout`]
    /// every `timeout()` of simulated time.
    fn timeout(&self) -> SimDuration;

    /// Choose initial parameters (Algorithm 1 for the paper's algorithms;
    /// static heuristics for baselines).
    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan;

    /// One tuning step: read telemetry, adjust this session's channels
    /// and (when the session owns the host knobs) the client CPU setting.
    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx);

    /// Current FSM state label (observability: traces, the `--trace` CLI
    /// output, failure-injection assertions). Baselines have no FSM.
    fn fsm_label(&self) -> &'static str {
        "-"
    }
}

/// Construct the configured governor. `mode` tells the predictive backend
/// what the SLA optimizes for.
pub fn make_governor(
    kind: GovernorKind,
    params: &TunerParams,
    mode: crate::predictor::PredictMode,
) -> Box<dyn Governor> {
    match kind {
        GovernorKind::Os => Box::new(OndemandGovernor::default()),
        GovernorKind::Threshold => Box::new(ThresholdGovernor::new(params.thresholds)),
        GovernorKind::Predictive => {
            Box::new(crate::predictor::PredictiveGovernor::from_env(mode))
        }
        GovernorKind::None => Box::new(NullGovernor),
    }
}

/// Every algorithm the experiment harness can run — the paper's three plus
/// all comparison tools of §V.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AlgorithmKind {
    /// Alg. 4 — Minimum Energy (ours).
    MinEnergy,
    /// Alg. 5 — Energy-Efficient Maximum Throughput (ours).
    MaxThroughput,
    /// Alg. 6 — Energy-Efficient Target Throughput (ours).
    TargetThroughput(Rate),
    /// wget: sequential, one connection, no pipelining.
    Wget,
    /// curl: sequential, one keep-alive connection.
    Curl,
    /// HTTP/2: one connection, full multiplexing.
    Http2,
    /// Ismail et al. Minimum Energy (static tuning).
    IsmailMinEnergy,
    /// Ismail et al. Maximum Throughput (static tuning).
    IsmailMaxThroughput,
    /// Ismail et al. Target Throughput (slow additive ramp from 1 channel).
    IsmailTarget(Rate),
    /// Alan et al. Minimum Energy (Figure 4 comparison).
    AlanMinEnergy,
    /// Alan et al. Maximum Throughput (Figure 4 comparison).
    AlanMaxThroughput,
    /// No tuning at all: a fixed channel count under the performance
    /// governor (the static baseline the sweep harness measures, and a
    /// simple tenant workload for fleet scenarios).
    NoTune(u32),
    /// ME warm-started from the historical-log subsystem: starts at the
    /// carried [`WarmStart`](crate::history::WarmStart) (the k-NN answer
    /// for this workload) and keeps the paper's runtime adaptation;
    /// `None` — an empty store, or confidence below the floor — is
    /// bit-for-bit the cold [`Self::MinEnergy`] slow-start path.
    HistoryTuned(Option<crate::history::WarmStart>),
}

impl AlgorithmKind {
    /// Stable identifier used in CSV output and the CLI.
    pub fn id(&self) -> &'static str {
        match self {
            AlgorithmKind::MinEnergy => "me",
            AlgorithmKind::MaxThroughput => "eemt",
            AlgorithmKind::TargetThroughput(_) => "eett",
            AlgorithmKind::Wget => "wget",
            AlgorithmKind::Curl => "curl",
            AlgorithmKind::Http2 => "http2",
            AlgorithmKind::IsmailMinEnergy => "ismail-me",
            AlgorithmKind::IsmailMaxThroughput => "ismail-mt",
            AlgorithmKind::IsmailTarget(_) => "ismail-tt",
            AlgorithmKind::AlanMinEnergy => "alan-me",
            AlgorithmKind::AlanMaxThroughput => "alan-mt",
            AlgorithmKind::NoTune(_) => "notune",
            AlgorithmKind::HistoryTuned(_) => "history",
        }
    }

    /// Parse a CLI identifier (target rates are provided separately).
    /// `history` parses cold ([`Self::HistoryTuned`] with no warm start);
    /// the CLI swaps in the k-NN answer when `--history` names a store.
    pub fn parse(id: &str, target: Option<Rate>) -> Option<AlgorithmKind> {
        Some(match id {
            "me" => AlgorithmKind::MinEnergy,
            "eemt" => AlgorithmKind::MaxThroughput,
            "eett" => AlgorithmKind::TargetThroughput(target?),
            "wget" => AlgorithmKind::Wget,
            "curl" => AlgorithmKind::Curl,
            "http2" => AlgorithmKind::Http2,
            "ismail-me" => AlgorithmKind::IsmailMinEnergy,
            "ismail-mt" => AlgorithmKind::IsmailMaxThroughput,
            "ismail-tt" => AlgorithmKind::IsmailTarget(target?),
            "alan-me" => AlgorithmKind::AlanMinEnergy,
            "alan-mt" => AlgorithmKind::AlanMaxThroughput,
            "history" => AlgorithmKind::HistoryTuned(None),
            _ => return None,
        })
    }

    /// Instantiate the algorithm.
    pub fn build(&self, params: TunerParams) -> Box<dyn Algorithm> {
        match *self {
            AlgorithmKind::MinEnergy => {
                Box::new(super::min_energy::MinEnergy::new(params))
            }
            AlgorithmKind::MaxThroughput => {
                Box::new(super::max_throughput::MaxThroughput::new(params))
            }
            AlgorithmKind::TargetThroughput(rate) => {
                Box::new(super::target_throughput::TargetThroughput::new(params, rate))
            }
            AlgorithmKind::Wget => Box::new(crate::baselines::simple::SimpleTool::wget()),
            AlgorithmKind::Curl => Box::new(crate::baselines::simple::SimpleTool::curl()),
            AlgorithmKind::Http2 => Box::new(crate::baselines::simple::SimpleTool::http2()),
            AlgorithmKind::IsmailMinEnergy => {
                Box::new(crate::baselines::ismail::Ismail::min_energy())
            }
            AlgorithmKind::IsmailMaxThroughput => {
                Box::new(crate::baselines::ismail::Ismail::max_throughput())
            }
            AlgorithmKind::IsmailTarget(rate) => {
                Box::new(crate::baselines::ismail::IsmailTarget::new(rate))
            }
            AlgorithmKind::AlanMinEnergy => {
                Box::new(crate::baselines::alan::Alan::min_energy())
            }
            AlgorithmKind::AlanMaxThroughput => {
                Box::new(crate::baselines::alan::Alan::max_throughput())
            }
            AlgorithmKind::NoTune(channels) => {
                Box::new(super::no_tune::NoTune::new(channels))
            }
            AlgorithmKind::HistoryTuned(warm) => {
                Box::new(super::history_tuned::HistoryTuned::new(params, warm))
            }
        }
    }

    /// The SLA the algorithm serves (drives Alg. 1's CPU init).
    pub fn sla(&self) -> SlaPolicy {
        match *self {
            AlgorithmKind::MinEnergy
            | AlgorithmKind::IsmailMinEnergy
            | AlgorithmKind::AlanMinEnergy
            | AlgorithmKind::HistoryTuned(_) => SlaPolicy::Energy,
            AlgorithmKind::TargetThroughput(r) | AlgorithmKind::IsmailTarget(r) => {
                SlaPolicy::TargetThroughput(r)
            }
            _ => SlaPolicy::Throughput,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_round_trip() {
        let target = Some(Rate::from_gbps(2.0));
        for kind in [
            AlgorithmKind::MinEnergy,
            AlgorithmKind::MaxThroughput,
            AlgorithmKind::TargetThroughput(Rate::from_gbps(2.0)),
            AlgorithmKind::Wget,
            AlgorithmKind::Curl,
            AlgorithmKind::Http2,
            AlgorithmKind::IsmailMinEnergy,
            AlgorithmKind::IsmailMaxThroughput,
            AlgorithmKind::IsmailTarget(Rate::from_gbps(2.0)),
            AlgorithmKind::AlanMinEnergy,
            AlgorithmKind::AlanMaxThroughput,
            AlgorithmKind::HistoryTuned(None),
        ] {
            let parsed = AlgorithmKind::parse(kind.id(), target).unwrap();
            assert_eq!(parsed.id(), kind.id());
        }
        assert!(AlgorithmKind::parse("bogus", None).is_none());
        assert!(AlgorithmKind::parse("eett", None).is_none(), "target required");
        // `history` always parses cold; warm starts come from the store.
        assert_eq!(
            AlgorithmKind::parse("history", None),
            Some(AlgorithmKind::HistoryTuned(None))
        );
    }

    #[test]
    fn sla_mapping() {
        assert!(AlgorithmKind::MinEnergy.sla().is_energy());
        assert!(AlgorithmKind::HistoryTuned(None).sla().is_energy());
        assert!(!AlgorithmKind::MaxThroughput.sla().is_energy());
        assert!(AlgorithmKind::TargetThroughput(Rate::from_mbps(400.0)).sla().target().is_some());
    }

    #[test]
    fn build_constructs_every_kind() {
        let p = TunerParams::default();
        for kind in [
            AlgorithmKind::MinEnergy,
            AlgorithmKind::MaxThroughput,
            AlgorithmKind::TargetThroughput(Rate::from_gbps(1.0)),
            AlgorithmKind::Wget,
            AlgorithmKind::Curl,
            AlgorithmKind::Http2,
            AlgorithmKind::IsmailMinEnergy,
            AlgorithmKind::IsmailMaxThroughput,
            AlgorithmKind::IsmailTarget(Rate::from_gbps(1.0)),
            AlgorithmKind::AlanMinEnergy,
            AlgorithmKind::AlanMaxThroughput,
            AlgorithmKind::NoTune(4),
            AlgorithmKind::HistoryTuned(None),
            AlgorithmKind::HistoryTuned(Some(crate::history::WarmStart {
                cores: 2,
                pstate: 1,
                channels: 8,
            })),
        ] {
            let a = kind.build(p);
            assert!(!a.name().is_empty());
            assert!(a.timeout().as_secs() > 0.0);
        }
    }

    #[test]
    fn notune_is_not_cli_parseable() {
        // Deliberate: the channel count cannot be carried through the
        // id/parse round trip, so `notune` stays a programmatic kind.
        assert_eq!(AlgorithmKind::NoTune(8).id(), "notune");
        assert!(AlgorithmKind::parse("notune", None).is_none());
    }
}
