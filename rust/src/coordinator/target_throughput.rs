//! Algorithm 6 — the Energy-Efficient Target Throughput (EETT) algorithm.
//!
//! Holds the measured throughput inside `[(1−α)·target, (1+β)·target]`
//! using as few channels as possible. A simplified 3-state FSM (Slow
//! Start → Increase ⇄ Recovery) gives it a faster reaction time than the
//! 4-state machine (§IV-C): one out-of-band observation arms Recovery, a
//! second one actuates the channel step.

use super::algorithm::{make_governor, Algorithm, InitPlan};
use super::heuristic;
use super::load_control::Governor;
use super::sla::SlaPolicy;
use super::slow_start::SlowStart;
use crate::config::experiment::TunerParams;
use crate::config::Testbed;
use crate::dataset::Dataset;
use crate::sim::{Telemetry, TuneCtx};
use crate::transfer::TransferEngine;
use crate::units::{Rate, SimDuration};

/// EETT's reduced state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TargetState {
    /// Initial correction phase.
    SlowStart,
    /// Below target: adding channels.
    Increase,
    /// Above target: shedding channels.
    Recovery,
}

#[derive(Debug)]
/// Algorithm 6 — Energy-Efficient Target Throughput (EETT).
pub struct TargetThroughput {
    params: TunerParams,
    governor: Box<dyn Governor>,
    target: Rate,
    state: TargetState,
    slow_start: Option<SlowStart>,
    num_ch: u32,
}

impl TargetThroughput {
    /// Fresh EETT instance for `target`.
    pub fn new(params: TunerParams, target: Rate) -> Self {
        TargetThroughput {
            governor: make_governor(
                params.governor,
                &params,
                crate::predictor::PredictMode::Target(target.as_bytes_per_sec()),
            ),
            params,
            target,
            state: TargetState::SlowStart,
            slow_start: None,
            num_ch: 1,
        }
    }

    /// Current reduced-FSM state.
    pub fn state(&self) -> TargetState {
        self.state
    }

    /// Channel count the algorithm currently wants.
    pub fn num_channels(&self) -> u32 {
        self.num_ch
    }

    /// The SLA target rate.
    pub fn target(&self) -> Rate {
        self.target
    }

    fn above(&self, avg_bps: f64) -> bool {
        avg_bps > (1.0 + self.params.beta) * self.target.as_bits_per_sec()
    }

    fn below(&self, avg_bps: f64) -> bool {
        avg_bps < (1.0 - self.params.alpha) * self.target.as_bits_per_sec()
    }

    fn apply_channels(&mut self, engine: &mut TransferEngine) {
        engine.update_weights();
        engine.set_num_channels(self.num_ch);
    }
}

impl Algorithm for TargetThroughput {
    fn name(&self) -> &'static str {
        "EETT"
    }

    fn timeout(&self) -> SimDuration {
        // §IV-C: simplified FSM for faster reaction → shorter timeout.
        self.params.target_timeout
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        let init =
            heuristic::initialize(testbed, dataset, SlaPolicy::TargetThroughput(self.target));
        self.num_ch = init.num_channels;
        self.slow_start = Some(SlowStart::new(
            // EETT ramps toward the *target*, not the full bandwidth: the
            // whole point is not to overshoot the SLA.
            self.target,
            self.params.max_ch,
            self.params.slow_start_rounds,
        ));
        self.state = TargetState::SlowStart;
        // Without the load-control module the OS owns the CPU: all cores
        // online, ondemand frequency (Figure 4's "w/o scaling" ablation).
        let client_cpu = if self.params.governor == crate::config::experiment::GovernorKind::Os {
            crate::cpusim::CpuState::performance(testbed.client_cpu.clone())
        } else {
            init.client_cpu
        };
        InitPlan::new(init.partitions, init.num_channels, client_cpu)
    }

    fn fsm_label(&self) -> &'static str {
        match self.state {
            TargetState::SlowStart => "slow-start",
            TargetState::Increase => "increase",
            TargetState::Recovery => "recovery",
        }
    }

    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx) {
        self.governor.control(telemetry, ctx.client);

        if let Some(ss) = &mut self.slow_start {
            let done = ss.on_timeout(telemetry, ctx.engine);
            self.num_ch = ctx.engine.num_channels().max(1);
            if done {
                self.slow_start = None;
                self.state = TargetState::Increase;
            }
            return;
        }

        let avg = telemetry.avg_throughput.as_bits_per_sec();
        match self.state {
            TargetState::SlowStart => unreachable!("handled above"),
            TargetState::Increase => {
                // Lines 4–7: out-of-band → arm Recovery.
                if self.above(avg) || self.below(avg) {
                    self.state = TargetState::Recovery;
                }
            }
            TargetState::Recovery => {
                // Lines 8–15: actuate on the second consecutive deviation.
                if self.above(avg) {
                    self.num_ch =
                        self.num_ch.saturating_sub(self.params.target_delta_ch).max(1);
                } else if self.below(avg) {
                    self.num_ch =
                        (self.num_ch + self.params.target_delta_ch).min(self.params.max_ch);
                }
                self.state = TargetState::Increase;
            }
        }
        self.apply_channels(ctx.engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::coordinator::AlgorithmKind;
    use crate::dataset::standard;
    use crate::sim::session::{run_session, SessionConfig};

    #[test]
    fn tracks_a_feasible_target_on_cloudlab() {
        let target = Rate::from_mbps(400.0);
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::mixed_dataset(2),
            AlgorithmKind::TargetThroughput(target),
        );
        let out = run_session(&cfg);
        assert!(out.completed);
        let err = (out.avg_throughput.as_mbps() - 400.0).abs() / 400.0;
        assert!(err < 0.25, "avg {} vs target 400 Mbps", out.avg_throughput);
    }

    #[test]
    fn infeasible_target_is_bandwidth_limited() {
        // 8 Gbps target on Chameleon: the paper observes no algorithm
        // exceeds ~7 Gbps; EETT must deliver close to the available
        // bandwidth, not crash or oscillate wildly.
        let target = Rate::from_gbps(8.0);
        let cfg = SessionConfig::new(
            testbeds::chameleon(),
            standard::mixed_dataset(2),
            AlgorithmKind::TargetThroughput(target),
        );
        let out = run_session(&cfg);
        assert!(out.completed);
        assert!(out.avg_throughput.as_gbps() > 5.0, "got {}", out.avg_throughput);
    }

    #[test]
    fn band_checks() {
        let t = TargetThroughput::new(TunerParams::default(), Rate::from_mbps(1000.0));
        assert!(t.above(1.2e9));
        assert!(!t.above(1.02e9));
        assert!(t.below(0.85e9));
        assert!(!t.below(0.95e9));
    }

    #[test]
    fn two_step_actuation() {
        let mut t = TargetThroughput::new(
            TunerParams {
                slow_start_rounds: 1,
                governor: crate::config::experiment::GovernorKind::Os,
                ..Default::default()
            },
            Rate::from_mbps(500.0),
        );
        t.state = TargetState::Increase;
        t.num_ch = 8;
        // First high observation arms Recovery but does not actuate.
        assert!(t.above(0.9e9));
        t.state = TargetState::Recovery; // (what on_timeout would do)
        assert_eq!(t.num_ch, 8);
        // Second high observation shrinks.
        let before = t.num_ch;
        if t.above(0.9e9) {
            t.num_ch = t.num_ch.saturating_sub(t.params.target_delta_ch).max(1);
        }
        assert!(t.num_ch < before);
    }

    #[test]
    fn uses_faster_timeout() {
        let p = TunerParams::default();
        let t = TargetThroughput::new(p, Rate::from_mbps(100.0));
        assert!(t.timeout() < p.timeout);
    }
}
