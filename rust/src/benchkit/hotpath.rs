//! The hot-path benchmark suite, shared by `cargo bench --bench
//! bench_hotpath` and `greendt bench`.
//!
//! The headline number is end-to-end simulated-time throughput —
//! sim-seconds per wall-second of the "EEMT session chameleon/mixed"
//! case — measured for **both** steppers in one run: the naive per-tick
//! reference (`Simulation::step_reference`, the pre-epoch semantics) and
//! the epoch-cached fast path. Recording both in `BENCH_hotpath.json`
//! keeps the speedup claim reproducible on any machine, independent of
//! the hardware the baseline was first taken on.
//!
//! Note the reference run still goes through the event-horizon driver
//! (only the *stepper* is naive), so it is a touch faster than the true
//! pre-PR per-tick-scanning driver — the recorded speedup is therefore a
//! conservative lower bound on the improvement over the pre-PR code.

use super::{bench, json_escape, json_f64, time_once, BenchReport};
use crate::config::testbeds;
use crate::coordinator::AlgorithmKind;
use crate::cpusim::CpuState;
use crate::dataset::{partition_files_capped, standard};
use crate::netsim::{share_goodput, StreamState};
use crate::sim::session::{run_session, SessionConfig};
use crate::sim::Simulation;
use crate::transfer::TransferEngine;
use crate::units::SimDuration;

/// The end-to-end case the acceptance criteria track.
pub const HEADLINE_CASE: &str = "EEMT session chameleon/mixed";

/// One stepper's end-to-end measurement.
#[derive(Debug, Clone, Copy)]
pub struct SessionRate {
    /// Simulated seconds covered by the run.
    pub sim_seconds: f64,
    /// Wall-clock seconds the run took.
    pub wall_seconds: f64,
}

impl SessionRate {
    /// Simulated-time throughput: how many simulated seconds one wall
    /// second buys.
    pub fn sim_seconds_per_wall_second(&self) -> f64 {
        self.sim_seconds / self.wall_seconds.max(1e-12)
    }

    fn to_json(self) -> String {
        format!(
            "{{\"sim_seconds\":{},\"wall_seconds\":{},\"sim_seconds_per_wall_second\":{}}}",
            json_f64(self.sim_seconds),
            json_f64(self.wall_seconds),
            json_f64(self.sim_seconds_per_wall_second())
        )
    }
}

/// Everything one hotpath run produced.
#[derive(Debug, Clone)]
pub struct HotpathReport {
    /// Micro benches of the per-tick pipeline.
    pub micro: Vec<BenchReport>,
    /// Naive per-tick stepper (pre-epoch semantics baseline).
    pub reference: SessionRate,
    /// Epoch-cached stepper.
    pub epoch: SessionRate,
}

impl HotpathReport {
    /// End-to-end speedup of the epoch-cached stepper over the reference.
    pub fn speedup(&self) -> f64 {
        self.epoch.sim_seconds_per_wall_second()
            / self.reference.sim_seconds_per_wall_second().max(1e-12)
    }

    /// The machine-readable report (the `BENCH_hotpath.json` schema).
    /// `histograms` carries the full per-iteration cost distribution of
    /// every micro bench (log2 buckets, exact p50/p99) so a perf
    /// trajectory can distinguish a shifted median from a fat tail.
    pub fn to_json(&self) -> String {
        let micro: Vec<String> = self.micro.iter().map(|r| r.to_json()).collect();
        let hists: Vec<String> = self
            .micro
            .iter()
            .map(|r| format!("\"{}\": {}", json_escape(&r.name), r.hist.to_json()))
            .collect();
        format!(
            "{{\n  \"bench\": \"hotpath\",\n  \"case\": \"{}\",\n  \"measured\": true,\n  \
             \"reference\": {},\n  \"epoch\": {},\n  \"speedup\": {},\n  \"micro\": [{}],\n  \
             \"histograms\": {{{}}}\n}}\n",
            json_escape(HEADLINE_CASE),
            self.reference.to_json(),
            self.epoch.to_json(),
            json_f64(self.speedup()),
            micro.join(", "),
            hists.join(", ")
        )
    }

    /// Write [`Self::to_json`] to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

fn headline_config(reference: bool) -> SessionConfig {
    let mut cfg = SessionConfig::new(
        testbeds::chameleon(),
        standard::mixed_dataset(42),
        AlgorithmKind::MaxThroughput,
    );
    cfg.reference_stepper = reference;
    cfg
}

/// Run the suite. `smoke` trims micro-benchmark iteration counts for CI;
/// the end-to-end case always runs in full (it is a single session and
/// the number the acceptance criteria track).
pub fn run(smoke: bool) -> HotpathReport {
    let (warmup, iters) = if smoke { (5u32, 50u32) } else { (100, 2000) };
    let (step_warmup, step_iters) = if smoke { (10u32, 100u32) } else { (200, 5000) };
    let mut micro = Vec::new();

    // share_goodput at various stream counts.
    let tb = testbeds::cloudlab();
    for n in [4usize, 16, 64, 256] {
        let link = tb.make_link_constant_bg();
        let streams: Vec<StreamState> =
            (0..n).map(|_| StreamState::warm(tb.link.avg_win)).collect();
        micro.push(bench(&format!("share_goodput/{n} streams"), warmup, iters, || {
            share_goodput(&link, &streams)
        }));
    }
    println!();

    // Whole-world step at mixed-dataset scale, both steppers, so the
    // per-tick win is visible next to the end-to-end one.
    for channels in [4u32, 16, 48] {
        for reference in [true, false] {
            let ds = standard::mixed_dataset(7);
            let parts = partition_files_capped(&ds, tb.bdp(), 5);
            let mut engine =
                TransferEngine::with_knee(&parts, tb.link.avg_win, tb.link.knee_streams());
            engine.set_num_channels(channels);
            let mut sim = Simulation::new(
                &tb,
                engine,
                CpuState::performance(tb.client_cpu.clone()),
                SimDuration::from_millis(100.0),
                9,
            );
            let label = if reference { "reference" } else { "epoch" };
            micro.push(bench(
                &format!("simulation step/{channels} channels/{label}"),
                step_warmup,
                step_iters,
                || if reference { sim.step_reference() } else { sim.step() },
            ));
        }
    }
    println!();

    // Channel redistribution.
    let ds = standard::mixed_dataset(7);
    let parts = partition_files_capped(&ds, tb.bdp(), 5);
    let mut engine = TransferEngine::with_knee(&parts, tb.link.avg_win, tb.link.knee_streams());
    let mut n = 4u32;
    micro.push(bench("set_num_channels (4<->24)", warmup, iters, || {
        n = if n == 4 { 24 } else { 4 };
        engine.update_weights();
        engine.set_num_channels(n);
    }));
    println!();

    // End-to-end session rate: reference first (the pre-epoch baseline),
    // then the epoch-cached path, on the identical workload.
    let (ref_out, ref_secs) =
        time_once(&format!("{HEADLINE_CASE} [reference]"), || {
            run_session(&headline_config(true))
        });
    let (fast_out, fast_secs) =
        time_once(&format!("{HEADLINE_CASE} [epoch]"), || {
            run_session(&headline_config(false))
        });
    assert_eq!(
        ref_out.duration.as_secs().to_bits(),
        fast_out.duration.as_secs().to_bits(),
        "steppers must agree on the simulated outcome"
    );
    assert_eq!(
        ref_out.client_energy.as_joules().to_bits(),
        fast_out.client_energy.as_joules().to_bits(),
        "steppers must agree on the energy bill"
    );

    let report = HotpathReport {
        micro,
        reference: SessionRate {
            sim_seconds: ref_out.duration.as_secs(),
            wall_seconds: ref_secs,
        },
        epoch: SessionRate {
            sim_seconds: fast_out.duration.as_secs(),
            wall_seconds: fast_secs,
        },
    };
    println!(
        "  reference: {:.0} sim-s in {:.3} s wall => {:.0}x real time",
        report.reference.sim_seconds,
        report.reference.wall_seconds,
        report.reference.sim_seconds_per_wall_second()
    );
    println!(
        "  epoch    : {:.0} sim-s in {:.3} s wall => {:.0}x real time",
        report.epoch.sim_seconds,
        report.epoch.wall_seconds,
        report.epoch.sim_seconds_per_wall_second()
    );
    println!("  speedup  : {:.2}x", report.speedup());
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_rate_math() {
        let r = SessionRate { sim_seconds: 100.0, wall_seconds: 0.5 };
        assert!((r.sim_seconds_per_wall_second() - 200.0).abs() < 1e-9);
        let j = r.to_json();
        assert!(j.contains("\"sim_seconds\":100"));
    }

    #[test]
    fn report_json_shape() {
        let rate = SessionRate { sim_seconds: 10.0, wall_seconds: 1.0 };
        let report = HotpathReport {
            micro: Vec::new(),
            reference: rate,
            epoch: SessionRate { sim_seconds: 10.0, wall_seconds: 0.25 },
        };
        assert!((report.speedup() - 4.0).abs() < 1e-9);
        let j = report.to_json();
        assert!(j.contains("\"bench\": \"hotpath\""));
        assert!(j.contains("\"speedup\": 4"));
        assert!(j.contains("\"micro\": []"));
        assert!(j.contains("\"histograms\": {}"));
    }

    #[test]
    fn histograms_section_carries_percentiles() {
        let rate = SessionRate { sim_seconds: 10.0, wall_seconds: 1.0 };
        let report = HotpathReport {
            micro: vec![super::super::bench("step/quick", 0, 8, || 1 + 1)],
            reference: rate,
            epoch: rate,
        };
        let j = report.to_json();
        assert!(j.contains("\"step/quick\": {\"count\":8"), "{j}");
        assert!(j.contains("\"p99\":"), "{j}");
        assert!(j.contains("\"buckets\":[["), "{j}");
    }
}
