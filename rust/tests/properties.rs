//! Property tests (testutil::property) on coordinator + substrate
//! invariants: channel routing conservation, weight normalization, FSM
//! legality, model monotonicity, byte conservation under random traffic.

use greendt::config::testbeds;
use greendt::coordinator::fsm::{self, Feedback, FsmState};
use greendt::dataset::{partition_files_capped, standard, Dataset, FileSpec};
use greendt::netsim::{share_goodput, StreamState};
use greendt::power::standard_power;
use greendt::predictor::{reference, Candidate};
use greendt::testutil::property;
use greendt::transfer::TransferEngine;
use greendt::units::{Bytes, Freq, SimDuration};

fn random_dataset(g: &mut greendt::testutil::Gen) -> Dataset {
    let n = g.usize_in(1, 200);
    let files = (0..n)
        .map(|i| FileSpec::new(i as u32, Bytes::new(g.f64_in(1e3, 5e8))))
        .collect();
    Dataset::new("prop", files)
}

#[test]
fn partitions_always_cover_the_dataset() {
    property("partition coverage", 200, |g| {
        let ds = random_dataset(g);
        let bdp = Bytes::new(g.f64_in(1e5, 1e8));
        let cap = g.u32_in(1, 16);
        let parts = partition_files_capped(&ds, bdp, cap);
        let covered: usize = parts.iter().map(|p| p.files.len()).sum();
        assert_eq!(covered, ds.num_files());
        let total: f64 = parts.iter().map(|p| p.total_size().as_f64()).sum();
        assert!((total - ds.total_size().as_f64()).abs() < 1.0);
        for p in &parts {
            assert!(p.pp_level >= 1 && p.parallelism >= 1 && p.parallelism <= 16);
        }
    });
}

#[test]
fn channel_allocation_conserves_and_respects_weights() {
    property("channel conservation", 150, |g| {
        let ds = random_dataset(g);
        let tb = testbeds::cloudlab();
        let parts = partition_files_capped(&ds, tb.bdp(), 5);
        let mut engine = TransferEngine::with_knee(&parts, tb.link.avg_win, tb.link.knee_streams());
        let n = g.u32_in(1, 64);
        engine.update_weights();
        engine.set_num_channels(n);
        // Conservation: exactly n channels; cc_levels sum to n.
        assert_eq!(engine.num_channels(), n);
        let cc: u32 = engine.partitions().iter().map(|p| p.cc_level).sum();
        assert_eq!(cc, n);
        // Weights are a probability vector.
        let w: f64 = engine.partitions().iter().map(|p| p.weight).sum();
        assert!((w - 1.0).abs() < 1e-9);
        // With budget >= #partitions, no unfinished partition starves.
        if n as usize >= engine.partitions().len() {
            for p in engine.partitions() {
                assert!(p.done() || p.cc_level >= 1);
            }
        }
    });
}

#[test]
fn bytes_are_conserved_under_random_traffic() {
    property("byte conservation", 60, |g| {
        let ds = random_dataset(g);
        let tb = testbeds::cloudlab();
        let link = tb.make_link_constant_bg();
        let parts = partition_files_capped(&ds, tb.bdp(), 5);
        let mut engine = TransferEngine::with_knee(&parts, tb.link.avg_win, tb.link.knee_streams());
        engine.set_num_channels(g.u32_in(1, 12));
        let total = engine.total();
        let mut moved = Bytes::ZERO;
        for _ in 0..g.usize_in(1, 400) {
            let cap = g.f64_in(1e5, 1e10);
            moved += engine.tick(&link, SimDuration::from_millis(100.0), cap).moved;
            if engine.is_done() {
                break;
            }
        }
        let accounted = moved + engine.remaining();
        assert!(
            (accounted.as_f64() - total.as_f64()).abs() < total.as_f64() * 1e-9 + 16.0,
            "moved {} + remaining {} vs total {}",
            moved,
            engine.remaining(),
            total
        );
    });
}

#[test]
fn goodput_allocation_is_bounded_and_fair() {
    property("goodput bounds", 200, |g| {
        let tb = testbeds::by_name(*g.choose(&["chameleon", "cloudlab", "didclab"])).unwrap();
        let link = tb.make_link_constant_bg();
        let n = g.usize_in(1, 128);
        let streams: Vec<StreamState> = (0..n)
            .map(|_| {
                if g.bool() {
                    StreamState::warm(tb.link.avg_win)
                } else {
                    StreamState::new(tb.link.avg_win)
                }
            })
            .collect();
        let rates = share_goodput(&link, &streams);
        let total: f64 = rates.iter().map(|r| r.as_bytes_per_sec()).sum();
        assert!(total <= link.available().as_bytes_per_sec() * (1.0 + 1e-9));
        for (s, r) in streams.iter().zip(&rates) {
            let cap = s.window_rate(tb.link.rtt).as_bytes_per_sec();
            assert!(r.as_bytes_per_sec() <= cap * (1.0 + 1e-9), "window cap violated");
            assert!(r.as_bytes_per_sec() >= 0.0);
        }
    });
}

#[test]
fn fsm_never_reenters_slow_start_and_only_shrinks_from_warning() {
    property("fsm legality", 300, |g| {
        let mut state = FsmState::Increase;
        for _ in 0..g.usize_in(1, 64) {
            let fb = *g.choose(&[Feedback::Positive, Feedback::Neutral, Feedback::Negative]);
            let (next, action) = fsm::step(state, fb);
            assert_ne!(next, FsmState::SlowStart);
            if action == fsm::Action::Shrink {
                assert_eq!(state, FsmState::Warning, "shrink only out of Warning");
                assert_eq!(fb, Feedback::Negative);
            }
            if action == fsm::Action::Restore {
                assert_eq!(state, FsmState::Recovery);
            }
            state = next;
        }
    });
}

#[test]
fn power_model_is_monotone_everywhere() {
    property("power monotonicity", 200, |g| {
        let spec = greendt::cpusim::standard::haswell_server();
        let pm = standard_power(&spec);
        let cores = g.u32_in(1, 7);
        let f = Freq::from_ghz(g.f64_in(1.2, 3.2));
        let util = g.f64_in(0.0, 0.9);
        let bytes = g.f64_in(0.0, 1e9);
        let base = pm.package_power(cores, f, util, bytes).as_watts();
        assert!(pm.package_power(cores + 1, f, util, bytes).as_watts() > base);
        assert!(pm.package_power(cores, Freq::from_ghz(f.as_ghz() + 0.2), util, bytes).as_watts() > base);
        assert!(pm.package_power(cores, f, util + 0.1, bytes).as_watts() > base);
        assert!(pm.package_power(cores, f, util, bytes + 1e9).as_watts() > base);
    });
}

#[test]
fn predictor_oracle_is_sane_across_state_space() {
    property("predictor sanity", 200, |g| {
        let mut state = greendt::predictor::demo_state_for_tests();
        use greendt::predictor::layout as l;
        state[l::S_CAPACITY_BPS] = g.f64_in(1e6, 2e9) as f32;
        state[l::S_RTT_S] = g.f64_in(0.001, 0.2) as f32;
        state[l::S_AVG_FILE_BYTES] = g.f64_in(1e4, 3e8) as f32;
        state[l::S_PP_LEVEL] = g.f64_in(1.0, 32.0) as f32;
        state[l::S_REMAINING_BYTES] = g.f64_in(1e6, 1e11) as f32;
        let cand = Candidate {
            channels: g.f64_in(1.0, 48.0) as f32,
            cores: g.f64_in(1.0, 16.0).floor() as f32,
            freq_ghz: g.f64_in(1.0, 4.0) as f32,
        };
        let p = reference::predict_one(&cand, &state);
        assert!(p.tput_bps >= 0.0 && p.tput_bps.is_finite());
        assert!(p.power_w > 0.0 && p.power_w < 1000.0, "power {}", p.power_w);
        assert!(p.energy_j > 0.0);
        // Throughput cannot exceed the offered capacity.
        assert!(p.tput_bps <= state[l::S_CAPACITY_BPS] as f64 * (1.0 + 1e-6));
    });
}

#[test]
fn session_outcomes_are_physical() {
    property("session physicality", 12, |g| {
        use greendt::coordinator::AlgorithmKind;
        use greendt::sim::session::{run_session, SessionConfig};
        let tb = testbeds::by_name(*g.choose(&["cloudlab", "didclab"])).unwrap();
        let kind = *g.choose(&[AlgorithmKind::MinEnergy, AlgorithmKind::MaxThroughput]);
        let cap_bps = tb.link.capacity.as_bits_per_sec();
        let cfg = SessionConfig::new(tb, standard::large_dataset(g.usize_in(0, 1000) as u64), kind);
        let out = run_session(&cfg);
        assert!(out.completed);
        assert!(out.avg_throughput.as_bits_per_sec() <= cap_bps);
        assert!(out.client_energy.as_joules() > 0.0);
        assert!(out.duration.as_secs() >= out.moved.as_f64() * 8.0 / cap_bps);
    });
}
