//! Acceptance tests for the multi-host dispatcher (ISSUE 3).
//!
//! Pins the two headline properties:
//!
//! * on a heterogeneous two-host fleet, `MarginalEnergy` placement
//!   consumes strictly less total energy than `RoundRobin` at equal or
//!   better aggregate goodput;
//! * admission control never admits a session whose projected fleet
//!   power exceeds the configured cap, and queued sessions drain FIFO as
//!   capacity frees up.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::sim::dispatcher::{
    run_dispatcher, DispatcherConfig, HostSpec, PoissonArrivals, SessionSpec,
};
use greendt::units::{Power, SimTime};

/// A heterogeneous fleet: an efficient Broadwell client (CloudLab) next
/// to a legacy Bloomfield one (DIDCLab), both behind 1 Gbps paths.
fn hetero_hosts() -> Vec<HostSpec> {
    vec![
        HostSpec::new("efficient", testbeds::cloudlab()),
        HostSpec::new("legacy", testbeds::didclab()),
    ]
}

/// Four medium sessions spaced far enough apart that each completes
/// before the next arrives: placement then changes *where* work runs,
/// never how much of it overlaps — the clean energy comparison.
fn spaced_sessions(n: u64, spacing: f64) -> Vec<SessionSpec> {
    (0..n)
        .map(|i| {
            SessionSpec::new(
                format!("session-{i}"),
                greendt::dataset::standard::medium_dataset(100 + i),
                AlgorithmKind::MaxThroughput,
            )
            .arriving_at(SimTime::from_secs(spacing * i as f64))
        })
        .collect()
}

fn hetero_cfg(placement: PlacementKind) -> DispatcherConfig {
    DispatcherConfig::new(hetero_hosts(), placement)
        .with_sessions(spaced_sessions(4, 180.0))
        .with_seed(17)
}

#[test]
fn marginal_energy_beats_round_robin_on_heterogeneous_fleet() {
    let me = run_dispatcher(&hetero_cfg(PlacementKind::MarginalEnergy));
    let rr = run_dispatcher(&hetero_cfg(PlacementKind::RoundRobin));
    assert!(me.fleet.completed, "marginal-energy run must finish");
    assert!(rr.fleet.completed, "round-robin run must finish");
    assert!(me.unplaced.is_empty() && rr.unplaced.is_empty());

    // Marginal-energy placement routes every session to the efficient
    // host — its predicted joules-per-byte delta is lower at every
    // arrival instant.
    for t in &me.fleet.tenants {
        assert_eq!(t.host, "efficient", "{} placed on {}", t.name, t.host);
    }
    // Round-robin alternates, so the legacy host serves half the work.
    assert!(
        rr.fleet.tenants.iter().any(|t| t.host == "legacy"),
        "round-robin must exercise the legacy host"
    );

    // Headline: strictly less total energy …
    let me_j = me.fleet.client_energy.as_joules();
    let rr_j = rr.fleet.client_energy.as_joules();
    assert!(
        me_j < rr_j,
        "marginal energy must beat round-robin on joules: {me_j:.0} vs {rr_j:.0}"
    );

    // … at equal or better aggregate goodput (same bytes moved; the
    // makespan must not be worse, because the legacy host is also the
    // slower one).
    assert!(
        (me.fleet.moved.as_f64() - rr.fleet.moved.as_f64()).abs() < 1.0,
        "both placements move the same workload: {} vs {}",
        me.fleet.moved,
        rr.fleet.moved
    );
    assert!(
        me.fleet.duration.as_secs() <= rr.fleet.duration.as_secs() + 1e-9,
        "marginal energy may not sacrifice makespan: {} vs {}",
        me.fleet.duration,
        rr.fleet.duration
    );

    // The decision telemetry carries the scores that justify the choice.
    assert_eq!(me.decisions.len(), 4);
    for d in &me.decisions {
        assert!(!d.queued());
        assert_eq!(d.scores.len(), 2);
        let eff = d.scores.iter().find(|s| s.host == "efficient").unwrap();
        let old = d.scores.iter().find(|s| s.host == "legacy").unwrap();
        assert!(
            eff.marginal_j_per_byte < old.marginal_j_per_byte,
            "at t={} the efficient host must score better ({:.3e} vs {:.3e})",
            d.t_secs,
            eff.marginal_j_per_byte,
            old.marginal_j_per_byte
        );
    }
}

#[test]
fn admission_control_respects_the_power_cap() {
    // Two single-slot CloudLab hosts, three simultaneous arrivals. The
    // cap is calibrated from an uncapped probe: room for one serving
    // host (idle fleet + 1.5 × one session's power delta) but not two.
    let mk_hosts = || {
        vec![
            HostSpec::new("a", testbeds::cloudlab()).with_max_sessions(1),
            HostSpec::new("b", testbeds::cloudlab()).with_max_sessions(1),
        ]
    };
    let mk_sessions = || spaced_sessions(3, 0.0);

    let probe_cfg = DispatcherConfig::new(mk_hosts(), PlacementKind::MarginalEnergy)
        .with_sessions(mk_sessions())
        .with_seed(29);
    let probe = run_dispatcher(&probe_cfg);
    assert!(probe.fleet.completed);
    let first = &probe.decisions[0];
    let idle_fleet: f64 = first.scores.iter().map(|s| s.current_power_w).sum();
    let chosen = first.admitted_host.expect("uncapped first arrival admits");
    let delta =
        first.scores[chosen].projected_power_w - first.scores[chosen].current_power_w;
    assert!(delta > 0.0, "serving a session must project extra power");
    let cap = idle_fleet + 1.5 * delta;

    let capped_cfg = DispatcherConfig::new(mk_hosts(), PlacementKind::MarginalEnergy)
        .with_sessions(mk_sessions())
        .with_seed(29)
        .with_power_cap(Power::from_watts(cap));
    let out = run_dispatcher(&capped_cfg);

    // Everyone is eventually served — admission control delays, it does
    // not starve.
    assert!(out.fleet.completed, "capped run must still finish");
    assert!(out.unplaced.is_empty());
    for t in &out.fleet.tenants {
        assert!(t.completed, "{} never finished", t.name);
    }

    // The invariant under test: no admitted decision ever projected the
    // fleet past the cap.
    let mut admitted = 0;
    let mut queued = 0;
    for d in &out.decisions {
        if d.queued() {
            queued += 1;
        } else {
            admitted += 1;
            assert!(
                d.projected_fleet_power_w <= cap + 1e-6,
                "session {} admitted at {:.2} W over the {:.2} W cap",
                d.session,
                d.projected_fleet_power_w,
                cap
            );
        }
    }
    assert_eq!(admitted, 3, "every session is admitted exactly once");
    assert!(queued >= 2, "the cap must actually queue the burst, got {queued}");

    // FIFO: sessions are admitted in request order, and the queued ones
    // waited for a departure.
    let admit_order: Vec<&str> = out
        .decisions
        .iter()
        .filter(|d| !d.queued())
        .map(|d| d.session.as_str())
        .collect();
    assert_eq!(admit_order, ["session-0", "session-1", "session-2"]);
    let waited: Vec<f64> = out
        .decisions
        .iter()
        .filter(|d| !d.queued())
        .map(|d| d.waited_secs())
        .collect();
    assert_eq!(waited[0], 0.0);
    assert!(waited[1] > 0.0 && waited[2] > waited[1] - 1e-9);

    // Serialization is the price: the capped run takes longer than the
    // uncapped probe that ran two hosts in parallel.
    assert!(
        out.fleet.duration.as_secs() > probe.fleet.duration.as_secs(),
        "cap must serialize the burst: {} vs {}",
        out.fleet.duration,
        probe.fleet.duration
    );
}

#[test]
fn fifo_queue_blocks_head_of_line_and_retries_on_the_departure_tick() {
    // Two single-slot hosts under a cap sized for one serving host: s0
    // is admitted; s1 (t=1) queues on the cap even though a slot is
    // free; s2 (t=2) must wait *behind* s1 — head-of-line blocking, not
    // shortest-job-first.
    let mk_hosts = || {
        vec![
            HostSpec::new("a", testbeds::cloudlab()).with_max_sessions(1),
            HostSpec::new("b", testbeds::cloudlab()).with_max_sessions(1),
        ]
    };
    let mk_sessions = || -> Vec<SessionSpec> {
        (0..3u64)
            .map(|i| {
                SessionSpec::new(
                    format!("session-{i}"),
                    greendt::dataset::standard::medium_dataset(500 + i),
                    AlgorithmKind::MaxThroughput,
                )
                .arriving_at(SimTime::from_secs(i as f64))
            })
            .collect()
    };
    // Calibrate the cap from an uncapped probe, exactly like the
    // admission-control test: one serving host fits, two do not.
    let probe = run_dispatcher(
        &DispatcherConfig::new(mk_hosts(), PlacementKind::MarginalEnergy)
            .with_sessions(mk_sessions())
            .with_seed(37),
    );
    let first = &probe.decisions[0];
    let idle_fleet: f64 = first.scores.iter().map(|s| s.current_power_w).sum();
    let chosen = first.admitted_host.expect("uncapped first arrival admits");
    let delta =
        first.scores[chosen].projected_power_w - first.scores[chosen].current_power_w;
    let cap = idle_fleet + 1.5 * delta;

    let run = || {
        run_dispatcher(
            &DispatcherConfig::new(mk_hosts(), PlacementKind::MarginalEnergy)
                .with_sessions(mk_sessions())
                .with_seed(37)
                .with_power_cap(Power::from_watts(cap)),
        )
    };
    let out = run();
    assert!(out.fleet.completed);

    // Admissions happen in strict request order.
    let admits: Vec<&greendt::sim::DispatchRecord> =
        out.decisions.iter().filter(|d| !d.queued()).collect();
    assert_eq!(
        admits.iter().map(|d| d.session.as_str()).collect::<Vec<_>>(),
        ["session-0", "session-1", "session-2"]
    );
    // s2's arrival-time decision is a queue record made while s1 held
    // the head: the FIFO blocked it without even trying placement.
    let s2_queued = out
        .decisions
        .iter()
        .find(|d| d.session == "session-2" && d.queued())
        .expect("s2 must be queued at arrival");
    assert!((s2_queued.t_secs - 2.0).abs() < 1e-9);

    // Retry-on-departure-tick: each queued session is admitted on
    // exactly the simulated instant its predecessor finished — not a
    // tick later (the event-horizon loop must break segments on the
    // departure tick).
    let finished: Vec<f64> = out
        .fleet
        .tenants
        .iter()
        .map(|t| t.finished_at.expect("all complete").as_secs())
        .collect();
    let t_admit_1 = admits[1].t_secs;
    let t_admit_2 = admits[2].t_secs;
    assert_eq!(
        t_admit_1.to_bits(),
        finished[0].to_bits(),
        "s1 admitted on s0's departure tick: {t_admit_1} vs {}",
        finished[0]
    );
    assert_eq!(
        t_admit_2.to_bits(),
        finished[1].to_bits(),
        "s2 admitted on s1's departure tick: {t_admit_2} vs {}",
        finished[1]
    );

    // Queue-wait accounting is pinned and deterministic: waited ==
    // admit − request for every decision, bit-identical across reruns.
    assert_eq!(admits[0].waited_secs(), 0.0);
    assert_eq!(
        admits[1].waited_secs().to_bits(),
        (t_admit_1 - 1.0).to_bits(),
        "s1 requested at t=1"
    );
    assert_eq!(
        admits[2].waited_secs().to_bits(),
        (t_admit_2 - 2.0).to_bits(),
        "s2 requested at t=2"
    );
    assert!(admits[2].waited_secs() > admits[1].waited_secs());
    let again = run();
    for (x, y) in out.decisions.iter().zip(&again.decisions) {
        assert_eq!(x.session, y.session);
        assert_eq!(x.queued(), y.queued());
        assert_eq!(x.waited_secs().to_bits(), y.waited_secs().to_bits());
    }
}

#[test]
fn dispatcher_runs_are_deterministic_under_a_seed() {
    let mk = |seed: u64| {
        let sessions = PoissonArrivals::new(1.0 / 90.0, 3, seed)
            .sessions("medium", AlgorithmKind::MaxThroughput)
            .expect("known family");
        DispatcherConfig::new(hetero_hosts(), PlacementKind::MarginalEnergy)
            .with_sessions(sessions)
            .with_seed(seed)
    };
    let a = run_dispatcher(&mk(11));
    let b = run_dispatcher(&mk(11));
    assert_eq!(a.fleet.duration.as_secs(), b.fleet.duration.as_secs());
    assert_eq!(
        a.fleet.client_energy.as_joules(),
        b.fleet.client_energy.as_joules()
    );
    for (x, y) in a.fleet.tenants.iter().zip(&b.fleet.tenants) {
        assert_eq!(x.host, y.host);
        assert_eq!(
            x.finished_at.unwrap().as_secs(),
            y.finished_at.unwrap().as_secs()
        );
    }
    for (x, y) in a.decisions.iter().zip(&b.decisions) {
        assert_eq!(x.session, y.session);
        assert_eq!(x.admitted_host, y.admitted_host);
        assert_eq!(x.projected_fleet_power_w, y.projected_fleet_power_w);
    }
    // A different seed perturbs arrivals and background noise.
    let c = run_dispatcher(&mk(12));
    assert_ne!(
        a.fleet.client_energy.as_joules(),
        c.fleet.client_energy.as_joules()
    );
}

#[test]
fn fairness_improves_when_placement_spreads_load() {
    // Two identical hosts, four simultaneous sessions. Least-loaded
    // spreads them two per host; every session then sees the same world,
    // so per-tenant goodput is near-identical and the Jain index is
    // close to 1.
    let hosts = vec![
        HostSpec::new("a", testbeds::cloudlab()),
        HostSpec::new("b", testbeds::cloudlab()),
    ];
    let cfg = DispatcherConfig::new(hosts, PlacementKind::LeastLoaded)
        .with_sessions(spaced_sessions(4, 0.0))
        .with_seed(23);
    let out = run_dispatcher(&cfg);
    assert!(out.fleet.completed);
    let on_a = out.fleet.tenants.iter().filter(|t| t.host == "a").count();
    assert_eq!(on_a, 2, "least-loaded must split 4 sessions 2/2");
    let j = out.fleet.jain_fairness();
    assert!(j > 0.95, "near-symmetric fleet must be near-fair, Jain {j}");
}
