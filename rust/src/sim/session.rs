//! Session driver: one complete transfer under one tuning algorithm.
//!
//! Since the multi-tenant refactor this is the N=1 special case of the
//! fleet driver ([`super::fleet::run_fleet`]): one tenant, no fleet
//! policy (so the session's own governor keeps the host CPU knobs), and
//! the outcome read from the host meters exactly as before.

use crate::config::experiment::TunerParams;
use crate::config::Testbed;
use crate::coordinator::AlgorithmKind;
use crate::dataset::Dataset;
use crate::sim::fleet::{run_fleet, FleetConfig, TenantSpec};
use crate::units::{Bytes, Energy, Freq, Rate, SimDuration};

/// Everything needed to run one session.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// The end systems + path to run on.
    pub testbed: Testbed,
    /// The files to move.
    pub dataset: Dataset,
    /// The tuning algorithm.
    pub algorithm: AlgorithmKind,
    /// Tuner knobs.
    pub params: TunerParams,
    /// RNG seed (background noise).
    pub seed: u64,
    /// Simulation tick length.
    pub tick: SimDuration,
    /// Abort the session after this much simulated time.
    pub max_sim_time: SimDuration,
    /// Record a per-timeout timeline (costs memory; reports/examples).
    pub record_timeline: bool,
    /// Scripted background-traffic events (failure injection / the
    /// `adaptive_bandwidth` example).
    pub bandwidth_events: Vec<crate::netsim::BandwidthEvent>,
    /// GreenDT extension: Algorithm-3 scaling on the *server* too (the
    /// paper's testbeds scale only the client).
    pub server_scaling: bool,
    /// Drive the session with the naive per-tick reference stepper
    /// instead of the epoch-cached fast path (tests and benchmarks; see
    /// [`crate::sim::fleet::FleetConfig::reference_stepper`]).
    pub reference_stepper: bool,
}

impl SessionConfig {
    /// A session with default knobs.
    pub fn new(testbed: Testbed, dataset: Dataset, algorithm: AlgorithmKind) -> Self {
        SessionConfig {
            testbed,
            dataset,
            algorithm,
            params: TunerParams::default(),
            seed: 42,
            tick: SimDuration::from_millis(100.0),
            max_sim_time: SimDuration::from_secs(14_400.0),
            record_timeline: false,
            bandwidth_events: Vec::new(),
            server_scaling: false,
            reference_stepper: false,
        }
    }

    /// Enable the server-side scaling extension.
    pub fn with_server_scaling(mut self) -> Self {
        self.server_scaling = true;
        self
    }

    /// Inject scripted bandwidth events into the session's path.
    pub fn with_bandwidth_events(mut self, events: Vec<crate::netsim::BandwidthEvent>) -> Self {
        self.bandwidth_events = events;
        self
    }

    /// Replace the tuner parameters.
    pub fn with_params(mut self, params: TunerParams) -> Self {
        self.params = params;
        self
    }

    /// Set the RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Record the per-timeout timeline.
    pub fn recording(mut self) -> Self {
        self.record_timeline = true;
        self
    }
}

/// One point of the per-timeout timeline.
#[derive(Debug, Clone, Copy)]
pub struct TimelinePoint {
    /// Time of the timeout, seconds.
    pub t_secs: f64,
    /// FSM state the algorithm was in when this interval was observed.
    pub fsm: &'static str,
    /// Interval-average throughput.
    pub throughput: Rate,
    /// Channels open at the timeout.
    pub channels: u32,
    /// Client cores online.
    pub active_cores: u32,
    /// Client frequency.
    pub freq: Freq,
    /// Interval-average client CPU load.
    pub cpu_load: f64,
    /// Interval-average client power, W.
    pub power_w: f64,
}

/// What one session produced — the quantities the paper's figures plot.
#[derive(Debug, Clone)]
pub struct SessionOutcome {
    /// Algorithm that drove the transfer.
    pub algorithm: String,
    /// Testbed name.
    pub testbed: String,
    /// Dataset name.
    pub dataset: String,
    /// Whether the transfer finished before the cap.
    pub completed: bool,
    /// Session wall time (simulated).
    pub duration: SimDuration,
    /// Bytes moved.
    pub moved: Bytes,
    /// Whole-session average application throughput.
    pub avg_throughput: Rate,
    /// Client energy per the testbed's instrument (RAPL or wall meter).
    pub client_energy: Energy,
    /// Client package (RAPL) energy, regardless of instrument.
    pub client_package_energy: Energy,
    /// Server package energy.
    pub server_energy: Energy,
    /// Client cores online at the end.
    pub final_active_cores: u32,
    /// Client frequency at the end.
    pub final_freq: Freq,
    /// Most channels ever open.
    pub peak_channels: u32,
    /// Per-timeout timeline (empty unless recorded).
    pub timeline: Vec<TimelinePoint>,
    /// History record of the session if it completed (see
    /// [`crate::history::RunRecord`]) — what `--record-history` appends.
    pub run_records: Vec<crate::history::RunRecord>,
}

impl SessionOutcome {
    /// Client + server package energy: the "end systems" total.
    pub fn total_energy(&self) -> Energy {
        self.client_package_energy + self.server_energy
    }
}

/// Run a session to completion (or the time cap) — the N=1 fleet.
pub fn run_session(cfg: &SessionConfig) -> SessionOutcome {
    let fleet = FleetConfig {
        testbed: cfg.testbed.clone(),
        tenants: vec![TenantSpec::new("session", cfg.dataset.clone(), cfg.algorithm)],
        policy: None,
        params: cfg.params,
        fleet_interval: cfg.params.timeout,
        seed: cfg.seed,
        tick: cfg.tick,
        max_sim_time: cfg.max_sim_time,
        record_timeline: cfg.record_timeline,
        bandwidth_events: cfg.bandwidth_events.clone(),
        server_scaling: cfg.server_scaling,
        reference_stepper: cfg.reference_stepper,
    };
    let mut out = run_fleet(&fleet);
    let tenant = out.tenants.remove(0);

    SessionOutcome {
        algorithm: tenant.algorithm,
        testbed: cfg.testbed.name.to_string(),
        dataset: cfg.dataset.name.clone(),
        completed: out.completed,
        duration: out.duration,
        moved: tenant.moved,
        avg_throughput: Rate::average(tenant.moved, out.duration),
        client_energy: out.client_energy,
        client_package_energy: out.client_package_energy,
        server_energy: out.server_energy,
        final_active_cores: out.final_active_cores,
        final_freq: out.final_freq,
        peak_channels: tenant.peak_channels,
        timeline: tenant.timeline,
        run_records: out.run_records,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::standard;

    #[test]
    fn eemt_session_on_cloudlab_medium() {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::medium_dataset(1),
            AlgorithmKind::MaxThroughput,
        );
        let out = run_session(&cfg);
        assert!(out.completed, "must finish within the cap");
        // 11.7 GB over 1 Gbps is at least ~94 s.
        assert!(out.duration.as_secs() > 90.0);
        assert!(out.avg_throughput.as_mbps() > 500.0, "tput {}", out.avg_throughput);
        assert!(out.client_energy.as_joules() > 0.0);
        assert!((out.moved.as_gb() - 11.7).abs() < 0.5);
    }

    #[test]
    fn timeline_recorded_when_asked() {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::large_dataset(1),
            AlgorithmKind::MaxThroughput,
        )
        .recording();
        let out = run_session(&cfg);
        assert!(!out.timeline.is_empty());
        // Time increases monotonically.
        for w in out.timeline.windows(2) {
            assert!(w[1].t_secs > w[0].t_secs);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            SessionConfig::new(
                testbeds::didclab(),
                standard::large_dataset(9),
                AlgorithmKind::MinEnergy,
            )
            .with_seed(123)
        };
        let a = run_session(&mk());
        let b = run_session(&mk());
        assert_eq!(a.duration.as_secs(), b.duration.as_secs());
        assert_eq!(a.client_energy.as_joules(), b.client_energy.as_joules());
    }

    #[test]
    fn seed_changes_outcome_slightly() {
        let base = SessionConfig::new(
            testbeds::didclab(),
            standard::large_dataset(9),
            AlgorithmKind::MinEnergy,
        );
        let a = run_session(&base.clone().with_seed(1));
        let b = run_session(&base.with_seed(2));
        assert_ne!(
            a.client_energy.as_joules(),
            b.client_energy.as_joules(),
            "background noise must differ across seeds"
        );
    }

    #[test]
    fn long_ticks_do_not_skew_tuning_cadence() {
        // A tick that spans several tuning timeouts (10 s tick, 3 s
        // timeout) must drain telemetry once per tick and advance the
        // deadline past the clock — not slide it one timeout at a time.
        let mut cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::large_dataset(4),
            AlgorithmKind::MaxThroughput,
        )
        .recording();
        cfg.tick = SimDuration::from_secs(10.0);
        let out = run_session(&cfg);
        assert!(out.completed);
        assert!(out.timeline.len() >= 2);
        for w in out.timeline.windows(2) {
            let dt = w[1].t_secs - w[0].t_secs;
            assert!(
                (dt - 10.0).abs() < 1e-6,
                "tuning cadence must follow the long tick, got {dt}"
            );
        }
    }

    #[test]
    fn n1_fleet_reproduces_run_session() {
        // The acceptance check for the refactor: driving the same single
        // session through the fleet API yields the same energy/duration.
        use crate::sim::fleet::{run_fleet, FleetConfig, TenantSpec};
        let cfg = SessionConfig::new(
            testbeds::didclab(),
            standard::medium_dataset(6),
            AlgorithmKind::MinEnergy,
        )
        .with_seed(77);
        let session = run_session(&cfg);
        let fleet = run_fleet(&FleetConfig {
            testbed: testbeds::didclab(),
            tenants: vec![TenantSpec::new(
                "only",
                standard::medium_dataset(6),
                AlgorithmKind::MinEnergy,
            )],
            policy: None,
            params: cfg.params,
            fleet_interval: cfg.params.timeout,
            seed: 77,
            tick: cfg.tick,
            max_sim_time: cfg.max_sim_time,
            record_timeline: false,
            bandwidth_events: Vec::new(),
            server_scaling: false,
            reference_stepper: false,
        });
        assert_eq!(session.duration.as_secs(), fleet.duration.as_secs());
        assert_eq!(
            session.client_energy.as_joules(),
            fleet.client_energy.as_joules()
        );
        assert_eq!(
            session.client_energy.as_joules(),
            fleet.tenants[0].attributed_energy.as_joules(),
            "a lone tenant is attributed the whole host bill"
        );
    }

    #[test]
    fn total_energy_combines_nodes() {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::large_dataset(1),
            AlgorithmKind::MaxThroughput,
        );
        let out = run_session(&cfg);
        assert!(out.total_energy() > out.client_package_energy);
        assert!(out.total_energy() > out.server_energy);
    }
}
