//! Figure 3 macro-benchmark: the target-throughput comparison
//! (2 testbeds × 4 targets × 2 algorithms).
//!
//!     cargo bench --bench bench_fig3

use greendt::benchkit::time_once;
use greendt::experiments::fig3;

fn main() {
    println!("== bench_fig3: target-throughput comparison ==");
    let (results, secs) = time_once("fig3 grid (16 sessions)", || fig3::run(42));
    for t in &results.tables {
        println!("{}", t.to_markdown());
    }
    // Paper claim: EETT within 5–10% of target in nearly all scenarios.
    let mut worst: f64 = 0.0;
    for (tb, target, tool, out) in &results.outcomes {
        if tool == "EETT" {
            let err =
                (out.avg_throughput.as_mbps() - target.as_mbps()).abs() / target.as_mbps();
            println!("  EETT on {tb} @ {target}: err {:.1}%", err * 100.0);
            worst = worst.max(err);
        }
    }
    println!("worst EETT tracking error: {:.1}% (paper: 5-10%)", worst * 100.0);
    println!("wall time: {secs:.2}s");
}
