//! Historical-log learning: record every run, learn from what the fleet
//! has already seen.
//!
//! The paper's algorithms start every transfer from a cold slow-start
//! probe, and the dispatcher scores hosts from instantaneous projections
//! only. Kosar et al.'s follow-on work shows the probing energy can be
//! reused away: cross-layer tuning from historical log analysis
//! (arXiv:2104.01192) and decision-tree uncertainty reduction over past
//! transfers (arXiv:2204.07601). This subsystem is that loop, in three
//! layers:
//!
//! * **store** ([`store`], [`record`], [`json`]) — a versioned JSONL
//!   [`HistoryStore`]: one [`RunRecord`] per completed session (workload
//!   fingerprint, path, settled `(cores, P-state, channels)` point, cost)
//!   plus one line per dispatcher decision, written by
//!   `--record-history <path>` and loadable across runs;
//! * **learn** ([`features`], [`knn`]) — normalized, discretized feature
//!   vectors and a deterministic distance-weighted k-NN index answering
//!   "best known operating point for a workload like this"
//!   ([`KnnIndex::warm_start`]) and "observed J/B on host *h*"
//!   ([`KnnIndex::observed_j_per_byte`]);
//! * **apply** — the
//!   [`HistoryTuned`](crate::coordinator::history_tuned::HistoryTuned)
//!   algorithm (warm-starts cores/P-state/concurrency, falls back to the
//!   paper's slow start below [`CONFIDENCE_FLOOR`]) and
//!   [`PlacementKind::Learned`](crate::coordinator::fleet::PlacementKind)
//!   (blends the model-based marginal-energy score with history-observed
//!   ΔJ/byte per host).
//!
//! `examples/learned_fleet.rs` is the end-to-end demo: the same arrival
//! script run cold and then warm, with the joules/goodput delta printed.

pub mod features;
pub mod json;
pub mod knn;
pub mod record;
pub mod store;

pub use features::{Query, WorkloadFingerprint};
pub use knn::{KnnIndex, WarmStart, CONFIDENCE_FLOOR};
pub use record::{RunOutcome, RunRecord, TrajPoint, FORMAT_VERSION, MIN_SUPPORTED_VERSION};
pub use store::{HistoryStore, StoreStats};
