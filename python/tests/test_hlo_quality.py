"""L2 performance checks on the lowered HLO (DESIGN.md §7).

`interpret=True` means wallclock is meaningless here; what we *can* verify
at build time is the structure of the compiled module: shapes, absence of
TPU-only custom calls, no superfluous recomputation (the module stays a
compact elementwise pipeline), and that the artifact on disk matches what
the current sources lower to.
"""

import os
import re

import pytest

from compile import aot
from compile.kernels import layout as L


@pytest.fixture(scope="module")
def hlo_text():
    return aot.lower_predictor()


def test_entry_signature_matches_layout(hlo_text):
    assert (
        f"f32[{L.NUM_CANDIDATES},{L.CAND_WIDTH}]" in hlo_text
    ), "candidate operand shape"
    assert f"f32[{L.STATE_WIDTH}]" in hlo_text, "state operand shape"
    assert f"f32[{L.NUM_CANDIDATES},{L.OUT_WIDTH}]" in hlo_text, "output shape"


def test_no_device_custom_calls(hlo_text):
    # interpret=True must flatten the Pallas kernel to plain HLO: a Mosaic
    # custom-call would make the artifact unloadable on the CPU PJRT client.
    assert "mosaic" not in hlo_text.lower()
    assert "tpu_custom_call" not in hlo_text.lower()


def test_module_is_compact(hlo_text):
    # The whole model is ~40 scalar formulas over a (128, 3) grid. If the
    # instruction count explodes, something is being re-computed per tile
    # or the grid got unrolled into per-row ops.
    n_instructions = len(re.findall(r"^\s+\S+ = ", hlo_text, flags=re.M))
    assert n_instructions < 400, f"{n_instructions} instructions — lowering regressed"
    # The candidate-axis loop must stay a loop (XLA while), not unroll 4x.
    assert hlo_text.count("while") >= 1 or n_instructions < 200


def test_no_float64_leaks(hlo_text):
    # f64 ops on the decision path would double memory traffic; everything
    # is declared f32.
    assert "f64[" not in hlo_text


def test_artifact_on_disk_is_current():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "predictor.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        on_disk = f.read()
    fresh = aot.lower_predictor()
    assert on_disk == fresh, (
        "artifacts/predictor.hlo.txt is stale — re-run `make artifacts`"
    )
