//! The PenaltyBox: exponential-backoff deprioritization of flaky hosts.
//!
//! Two different consequences flow from one host failure, and the box
//! owns both clocks:
//!
//! * **session backoff** — a session lost to a failure waits an
//!   exponentially growing delay before its retry re-enters placement
//!   (attempt 1 waits [`PenaltyConfig::base_backoff_s`], each further
//!   attempt multiplies by [`PenaltyConfig::backoff_factor`], capped at
//!   [`PenaltyConfig::max_backoff_s`]), so a crash-looping host cannot
//!   thrash the queue;
//! * **host deprioritization** — every failure strikes the host, and
//!   placement scoring pays a J/B surcharge per live strike
//!   ([`PenaltyBox::surcharge_j_per_byte`]). Strikes expire after
//!   [`PenaltyConfig::strike_decay_s`], so a host that stays healthy
//!   earns its way back to neutral scoring instead of being
//!   blacklisted forever — the decay contract ARCHITECTURE.md
//!   §Resilience documents.
//!
//! Pure logic: seconds in, scores out; no clock, no RNG, no knowledge
//! of what a host or session actually is.

use std::collections::BTreeMap;

/// Knobs of the [`PenaltyBox`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PenaltyConfig {
    /// Backoff of a session's first retry, seconds.
    pub base_backoff_s: f64,
    /// Multiplier applied per further attempt.
    pub backoff_factor: f64,
    /// Ceiling on any single backoff, seconds.
    pub max_backoff_s: f64,
    /// How long one strike keeps penalizing its host, seconds.
    pub strike_decay_s: f64,
    /// Placement-score surcharge per live strike, J/B — the same unit
    /// as the marginal-energy score, so a struck host is outbid rather
    /// than masked (it still wins when every alternative is worse).
    pub strike_surcharge_j_per_byte: f64,
}

impl Default for PenaltyConfig {
    fn default() -> Self {
        PenaltyConfig {
            base_backoff_s: 10.0,
            backoff_factor: 2.0,
            max_backoff_s: 300.0,
            strike_decay_s: 600.0,
            strike_surcharge_j_per_byte: 1e-7,
        }
    }
}

/// Per-host failure memory (see the module docs).
#[derive(Debug, Clone)]
pub struct PenaltyBox {
    cfg: PenaltyConfig,
    /// Strike timestamps per host, oldest first.
    strikes: BTreeMap<usize, Vec<f64>>,
}

impl PenaltyBox {
    /// An empty box with the given knobs.
    pub fn new(cfg: PenaltyConfig) -> Self {
        PenaltyBox { cfg, strikes: BTreeMap::new() }
    }

    /// The configured knobs.
    pub fn config(&self) -> &PenaltyConfig {
        &self.cfg
    }

    /// Record one failure on `host` at `now_secs`.
    pub fn note_failure(&mut self, host: usize, now_secs: f64) {
        self.strikes.entry(host).or_default().push(now_secs);
    }

    /// Strikes still live on `host` at `now_secs` (failures younger
    /// than the decay window).
    pub fn strikes(&self, host: usize, now_secs: f64) -> u32 {
        self.strikes
            .get(&host)
            .map(|s| {
                s.iter()
                    .filter(|&&at| now_secs - at < self.cfg.strike_decay_s)
                    .count() as u32
            })
            .unwrap_or(0)
    }

    /// Placement-score surcharge for `host` at `now_secs`, J/B: the
    /// per-strike surcharge times the live strike count (zero for a
    /// clean host, so unstruck fleets score exactly as without a box).
    pub fn surcharge_j_per_byte(&self, host: usize, now_secs: f64) -> f64 {
        self.strikes(host, now_secs) as f64 * self.cfg.strike_surcharge_j_per_byte
    }

    /// Backoff before retry `attempt` (1-based) re-enters placement,
    /// seconds: `base * factor^(attempt-1)`, capped at the maximum.
    pub fn backoff_secs(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(63);
        (self.cfg.base_backoff_s * self.cfg.backoff_factor.powi(exp as i32))
            .min(self.cfg.max_backoff_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially_to_the_cap() {
        let b = PenaltyBox::new(PenaltyConfig::default());
        assert_eq!(b.backoff_secs(1), 10.0);
        assert_eq!(b.backoff_secs(2), 20.0);
        assert_eq!(b.backoff_secs(3), 40.0);
        assert_eq!(b.backoff_secs(10), 300.0, "capped");
        assert_eq!(b.backoff_secs(200), 300.0, "huge attempts stay capped, no overflow");
    }

    #[test]
    fn strikes_accumulate_and_decay() {
        let mut b = PenaltyBox::new(PenaltyConfig::default());
        assert_eq!(b.strikes(0, 0.0), 0);
        assert_eq!(b.surcharge_j_per_byte(0, 0.0), 0.0, "clean host pays nothing");
        b.note_failure(0, 100.0);
        b.note_failure(0, 200.0);
        assert_eq!(b.strikes(0, 250.0), 2);
        assert_eq!(
            b.surcharge_j_per_byte(0, 250.0),
            2.0 * PenaltyConfig::default().strike_surcharge_j_per_byte
        );
        // The first strike expires at 100 + 600.
        assert_eq!(b.strikes(0, 750.0), 1);
        assert_eq!(b.strikes(0, 850.0), 0, "fully decayed");
        assert_eq!(b.strikes(1, 250.0), 0, "other hosts untouched");
    }
}
