//! Measurement harness for `cargo bench` targets.
//!
//! The offline crate set has no criterion, so GreenDT ships a small
//! warmup-then-measure harness with criterion-like reporting (mean ± std,
//! p50/p99) plus a stopwatch for macro benchmarks that run whole simulated
//! sessions.

pub mod hotpath;
pub mod resilience;
pub mod scale;
pub mod sentinel;

use crate::metrics::Summary;
use crate::obs::Histogram;
use std::time::Instant;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// Benchmark name.
    pub name: String,
    /// Timing summary over the samples.
    pub summary: Summary,
    /// Exact-percentile histogram over the same samples — feeds the
    /// `histograms` section of `BENCH_*.json` (log2 buckets + p50/p99).
    pub hist: Histogram,
}

impl BenchReport {
    /// Print the criterion-style one-line report.
    pub fn print(&self) {
        let s = &self.summary;
        println!(
            "{:<44} {:>12} ± {:>10}   p50 {:>12}  p99 {:>12}  (n={})",
            self.name,
            fmt_duration(s.mean),
            fmt_duration(s.std),
            fmt_duration(s.p50),
            fmt_duration(s.p99),
            s.n
        );
    }

    /// Machine-readable form (seconds), one JSON object per report — the
    /// perf trajectory in `BENCH_hotpath.json` is built from these.
    pub fn to_json(&self) -> String {
        let s = &self.summary;
        format!(
            "{{\"name\":\"{}\",\"n\":{},\"mean_s\":{},\"std_s\":{},\"p50_s\":{},\"p99_s\":{}}}",
            json_escape(&self.name),
            s.n,
            json_f64(s.mean),
            json_f64(s.std),
            json_f64(s.p50),
            json_f64(s.p99)
        )
    }
}

// The write-side JSON helpers now live with the history store's codec
// (`crate::history::json`) — one escaping/number implementation for every
// JSON line the crate emits.
pub(crate) use crate::history::json::{escape as json_escape, num as json_f64};

fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Time `iters` runs of `f` after `warmup` unmeasured runs; prints and
/// returns the report. The closure's return value is black-boxed so the
/// optimizer cannot elide the work.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> BenchReport {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    let mut hist = Histogram::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        samples.push(dt);
        hist.record(dt);
    }
    let report = BenchReport { name: name.to_string(), summary: Summary::of(&samples), hist };
    report.print();
    report
}

/// Wall-clock a single long-running closure (macro benchmarks).
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("{name:<44} {:>12}", fmt_duration(dt));
    (out, dt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 2, 16, || 1 + 1);
        assert_eq!(r.summary.n, 16);
        assert!(r.summary.mean >= 0.0);
        assert_eq!(r.hist.count(), 16);
        assert!(r.hist.percentile(0.99).is_some());
    }

    #[test]
    fn time_once_returns_value() {
        let (v, dt) = time_once("quick", || 7);
        assert_eq!(v, 7);
        assert!(dt >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(2e-3).ends_with(" ms"));
        assert!(fmt_duration(2e-6).contains("µs"));
        assert!(fmt_duration(2e-9).ends_with(" ns"));
    }

    #[test]
    fn report_json_is_wellformed() {
        let r = bench("quo\"ted", 0, 4, || 1 + 1);
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"name\":\"quo\\\"ted\""));
        assert!(j.contains("\"n\":4"));
        assert!(j.contains("\"mean_s\":"));
    }

    #[test]
    fn json_f64_handles_non_finite() {
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
        assert_eq!(json_f64(1.5), "1.5");
    }
}
