//! Throughput / bandwidth newtype.

use super::{Bytes, SimDuration};
use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub};

/// A data rate in **bits per second** (the unit the paper reports:
/// Gbps testbed bandwidths, Mbps targets).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Rate(f64);

impl Rate {
    /// Zero bits per second.
    pub const ZERO: Rate = Rate(0.0);

    /// From raw bits per second; negative clamps to zero.
    pub fn from_bits_per_sec(bps: f64) -> Self {
        Rate(if bps > 0.0 { bps } else { 0.0 })
    }

    /// Construct from megabits per second.
    pub fn from_mbps(mbps: f64) -> Self {
        Rate::from_bits_per_sec(mbps * 1e6)
    }

    /// Construct from gigabits per second.
    pub fn from_gbps(gbps: f64) -> Self {
        Rate::from_bits_per_sec(gbps * 1e9)
    }

    /// From bytes per second.
    pub fn from_bytes_per_sec(bytes: f64) -> Self {
        Rate::from_bits_per_sec(bytes * 8.0)
    }

    /// Value in bits per second.
    pub fn as_bits_per_sec(self) -> f64 {
        self.0
    }

    /// Value in megabits per second.
    pub fn as_mbps(self) -> f64 {
        self.0 / 1e6
    }

    /// Value in gigabits per second.
    pub fn as_gbps(self) -> f64 {
        self.0 / 1e9
    }

    /// Value in bytes per second.
    pub fn as_bytes_per_sec(self) -> f64 {
        self.0 / 8.0
    }

    /// True when nothing is flowing.
    pub fn is_zero(self) -> bool {
        self.0 <= 0.0
    }

    /// The slower of two rates.
    pub fn min(self, other: Rate) -> Rate {
        Rate(self.0.min(other.0))
    }

    /// The faster of two rates.
    pub fn max(self, other: Rate) -> Rate {
        Rate(self.0.max(other.0))
    }

    /// Volume moved over a duration at this rate.
    pub fn volume_over(self, dt: SimDuration) -> Bytes {
        Bytes::new(self.as_bytes_per_sec() * dt.as_secs())
    }

    /// Average rate that moves `volume` in `dt`.
    pub fn average(volume: Bytes, dt: SimDuration) -> Rate {
        if dt.as_secs() <= 0.0 {
            Rate::ZERO
        } else {
            Rate::from_bytes_per_sec(volume.as_f64() / dt.as_secs())
        }
    }
}

impl Add for Rate {
    type Output = Rate;
    fn add(self, rhs: Rate) -> Rate {
        Rate(self.0 + rhs.0)
    }
}

impl AddAssign for Rate {
    fn add_assign(&mut self, rhs: Rate) {
        self.0 += rhs.0;
    }
}

impl Sub for Rate {
    type Output = Rate;
    fn sub(self, rhs: Rate) -> Rate {
        Rate::from_bits_per_sec(self.0 - rhs.0)
    }
}

impl Mul<f64> for Rate {
    type Output = Rate;
    fn mul(self, rhs: f64) -> Rate {
        Rate::from_bits_per_sec(self.0 * rhs)
    }
}

impl Div<f64> for Rate {
    type Output = Rate;
    fn div(self, rhs: f64) -> Rate {
        Rate::from_bits_per_sec(self.0 / rhs)
    }
}

impl Div for Rate {
    /// Ratio of two rates (dimensionless); 0 when the divisor is 0.
    type Output = f64;
    fn div(self, rhs: Rate) -> f64 {
        if rhs.0 == 0.0 {
            0.0
        } else {
            self.0 / rhs.0
        }
    }
}

impl Sum for Rate {
    fn sum<I: Iterator<Item = Rate>>(iter: I) -> Rate {
        iter.fold(Rate::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for Rate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1e9 {
            write!(f, "{:.2} Gbps", self.as_gbps())
        } else if self.0 >= 1e6 {
            write!(f, "{:.1} Mbps", self.as_mbps())
        } else {
            write!(f, "{:.0} bps", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_bytes_conversion() {
        assert_eq!(Rate::from_bytes_per_sec(125e6).as_gbps(), 1.0);
        assert_eq!(Rate::from_gbps(1.0).as_bytes_per_sec(), 125e6);
    }

    #[test]
    fn volume_over_duration() {
        let v = Rate::from_gbps(1.0).volume_over(SimDuration::from_secs(8.0));
        assert_eq!(v.as_gb(), 1.0);
    }

    #[test]
    fn average_rate() {
        let r = Rate::average(Bytes::from_gb(1.0), SimDuration::from_secs(8.0));
        assert!((r.as_gbps() - 1.0).abs() < 1e-12);
        assert_eq!(Rate::average(Bytes::from_gb(1.0), SimDuration::ZERO), Rate::ZERO);
    }

    #[test]
    fn ratio_handles_zero() {
        assert_eq!(Rate::from_mbps(5.0) / Rate::ZERO, 0.0);
        assert_eq!(Rate::from_mbps(5.0) / Rate::from_mbps(10.0), 0.5);
    }

    #[test]
    fn display_formats() {
        assert_eq!(format!("{}", Rate::from_gbps(10.0)), "10.00 Gbps");
        assert_eq!(format!("{}", Rate::from_mbps(400.0)), "400.0 Mbps");
    }
}
