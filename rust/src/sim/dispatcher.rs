//! Multi-host fleet dispatcher: place each arriving session on the host
//! that serves it cheapest.
//!
//! The paper tunes *how* a transfer runs on one end system; GreenDataFlow
//! (arXiv:1810.05892) shows the larger fleet-level win comes from *where*
//! it runs: on a heterogeneous fleet, the host whose operating point
//! yields the lowest marginal energy should take the next session. This
//! module owns that layer:
//!
//! * [`HostSpec`] / [`run_dispatcher`] — several independent hosts (each
//!   with its own link, power model and session-slot pool) driven in
//!   lockstep behind one [`Dispatcher`];
//! * [`PlacementKind`] policies — `RoundRobin`, `LeastLoaded` and
//!   `MarginalEnergy`, the last scoring candidates by predicted
//!   joules-per-byte deltas priced through the same
//!   [`PowerModel::at`](crate::power::PowerModel::at) /
//!   [`OpPointPower`](crate::power::OpPointPower) coefficients the
//!   epoch-cached stepper runs on;
//! * open workloads — a seeded [`PoissonArrivals`] process generating
//!   [`SessionSpec`]s, instead of PR 1's scripted schedules;
//! * admission control — a fleet-wide cap on *projected* aggregate host
//!   power: arrivals that would push the projection past the cap wait in
//!   a FIFO queue and retry as sessions depart;
//! * decision telemetry — every placement emits a
//!   [`DispatchRecord`](crate::sim::DispatchRecord) with the per-host
//!   scores, so the dispatcher's behavior can be mined offline
//!   (historical-log-driven tuning, arXiv:2104.01192).
//!
//! The driver extends the PR 2 event-horizon loop across hosts: each
//! segment computes the earliest driver-level event over *all* hosts
//! (arrivals, tuning timeouts, arbitrations, the time cap) and then runs
//! a tight lockstep inner loop of bare `step()` calls, so ticks between
//! cross-host deadlines stay as cheap as in the single-host fleet.

use std::collections::VecDeque;

use super::fleet::{FleetOutcome, HostWorld, TenantSpec};
use super::telemetry::{DispatchRecord, PlacementScore};
use crate::config::experiment::TunerParams;
use crate::config::Testbed;
use crate::coordinator::fleet::{FleetPolicyKind, PlacementKind};
use crate::coordinator::AlgorithmKind;
use crate::rng::{self, Distribution, Exponential};
use crate::units::{Bytes, Energy, Power, SimDuration, SimTime};

/// An open-workload session request. Exactly a [`TenantSpec`] — the
/// dispatcher decides *which host* becomes the session's tenant world,
/// then hands the spec to that host's fleet driver unchanged.
pub type SessionSpec = TenantSpec;

/// One host in the dispatcher's fleet: a named testbed (its own WAN
/// path, CPUs, power models and meters) plus a bound on how many
/// concurrent sessions its slot pool accepts.
#[derive(Debug, Clone)]
pub struct HostSpec {
    /// Display name, unique within the fleet (used in telemetry and
    /// outcomes).
    pub name: String,
    /// The end system + path this host models.
    pub testbed: Testbed,
    /// Hard cap on concurrently admitted sessions (the slot pool size).
    pub max_sessions: u32,
}

impl HostSpec {
    /// A host with the default 8-session slot pool.
    pub fn new(name: impl Into<String>, testbed: Testbed) -> Self {
        HostSpec { name: name.into(), testbed, max_sessions: 8 }
    }

    /// Override the slot-pool size.
    pub fn with_max_sessions(mut self, max_sessions: u32) -> Self {
        self.max_sessions = max_sessions.max(1);
        self
    }
}

/// A seeded Poisson arrival process: `count` sessions whose inter-arrival
/// times are exponential with rate `rate_per_sec`. Fully deterministic
/// under a fixed seed (the generator draws from its own
/// [`rng::stream`]), so open-workload experiments are reproducible.
#[derive(Debug, Clone, Copy)]
pub struct PoissonArrivals {
    /// Mean arrival rate, sessions per simulated second.
    pub rate_per_sec: f64,
    /// How many sessions to generate.
    pub count: u32,
    /// RNG seed for the inter-arrival draws (and derived dataset seeds).
    pub seed: u64,
}

impl PoissonArrivals {
    /// A process with `rate_per_sec` mean arrivals per second.
    pub fn new(rate_per_sec: f64, count: u32, seed: u64) -> Self {
        assert!(rate_per_sec > 0.0, "Poisson arrivals need a positive rate");
        PoissonArrivals { rate_per_sec, count, seed }
    }

    /// The arrival instants: a strictly increasing sequence of `count`
    /// times starting after t = 0.
    pub fn times(&self) -> Vec<SimTime> {
        let mut rng = rng::stream(self.seed, "poisson-arrivals");
        let exp = Exponential::new(self.rate_per_sec);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(self.count as usize);
        for _ in 0..self.count {
            t += exp.sample(&mut rng);
            out.push(SimTime::from_secs(t));
        }
        out
    }

    /// Generate the session specs: one dataset per session drawn from the
    /// standard family `dataset_family` (`"small"`, `"medium"`, `"large"`,
    /// `"mixed"`) with per-session derived seeds, all tuned by
    /// `algorithm`. Returns `None` for an unknown family name.
    pub fn sessions(
        &self,
        dataset_family: &str,
        algorithm: AlgorithmKind,
    ) -> Option<Vec<SessionSpec>> {
        self.times()
            .into_iter()
            .enumerate()
            .map(|(i, at)| {
                let ds = crate::dataset::standard::by_name(
                    dataset_family,
                    self.seed.wrapping_add(1 + i as u64),
                )?;
                Some(TenantSpec::new(format!("session-{i}"), ds, algorithm).arriving_at(at))
            })
            .collect()
    }
}

/// A candidate host as [`Dispatcher::place`] sees it: a snapshot of the
/// host's occupancy plus the power projections the dispatcher computed
/// for it. `projected_*` quantities assume the new session is placed on
/// this host; `current_power_w` assumes it is not.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostCandidate {
    /// Index of the host in the dispatcher's host list.
    pub host: usize,
    /// Sessions currently admitted and unfinished on this host.
    pub active_sessions: u32,
    /// Session slots still free (0 = the host cannot take the session).
    pub free_slots: u32,
    /// Predicted whole-host instrument power at the current session
    /// count, W.
    pub current_power_w: f64,
    /// Predicted whole-host instrument power with the new session
    /// placed here, W.
    pub projected_power_w: f64,
    /// Expected goodput of the new session if placed here, bytes/s.
    pub projected_session_bps: f64,
    /// Projected aggregate fleet power if placed here (every other host
    /// at its current projection), W — what admission control compares
    /// against the power cap.
    pub projected_fleet_power_w: f64,
}

impl HostCandidate {
    /// The `MarginalEnergy` score: predicted extra watts divided by the
    /// new session's expected goodput — joules per byte moved. Infinite
    /// when the host could not move any bytes for the session.
    pub fn marginal_j_per_byte(&self) -> f64 {
        if self.projected_session_bps <= 0.0 {
            f64::INFINITY
        } else {
            (self.projected_power_w - self.current_power_w).max(0.0)
                / self.projected_session_bps
        }
    }
}

/// What [`Dispatcher::place`] decided for one arriving session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlaceDecision {
    /// Admit on this host (a [`HostCandidate::host`] index).
    Admit(usize),
    /// Some host has a free slot, but every placement would push the
    /// projected fleet power past the cap — the session must wait.
    QueuePowerCap,
    /// No host has a free session slot.
    QueueNoSlot,
}

/// The placement + admission state machine: ranks candidate hosts by the
/// configured [`PlacementKind`] and enforces the fleet power cap. Pure
/// over the candidate snapshots (no simulation access), so decisions are
/// easy to test, replay and mine offline.
#[derive(Debug, Clone)]
pub struct Dispatcher {
    placement: PlacementKind,
    power_cap: Option<Power>,
    /// Round-robin cursor (next host index to try first).
    rr_cursor: usize,
}

impl Dispatcher {
    /// A dispatcher using `placement`, admitting only while the projected
    /// aggregate fleet power stays within `power_cap` (if set).
    pub fn new(placement: PlacementKind, power_cap: Option<Power>) -> Self {
        Dispatcher { placement, power_cap, rr_cursor: 0 }
    }

    /// Which placement policy this dispatcher ranks hosts by.
    pub fn placement(&self) -> PlacementKind {
        self.placement
    }

    /// Choose a host for one arriving session.
    ///
    /// Candidates are ranked by the placement policy; the best-ranked
    /// host with a free slot whose projected fleet power fits the cap
    /// wins. With a cap set, a worse-ranked host that fits is preferred
    /// over queueing behind a better-ranked host that does not.
    ///
    /// # Examples
    ///
    /// ```
    /// use greendt::coordinator::fleet::PlacementKind;
    /// use greendt::sim::dispatcher::{Dispatcher, HostCandidate, PlaceDecision};
    ///
    /// let mut d = Dispatcher::new(PlacementKind::MarginalEnergy, None);
    /// let candidates = [
    ///     HostCandidate {
    ///         host: 0,
    ///         active_sessions: 1,
    ///         free_slots: 3,
    ///         current_power_w: 30.0,
    ///         projected_power_w: 55.0,   // +25 W …
    ///         projected_session_bps: 50e6, // … for 50 MB/s → 0.5 µJ/B
    ///         projected_fleet_power_w: 75.0,
    ///     },
    ///     HostCandidate {
    ///         host: 1,
    ///         active_sessions: 0,
    ///         free_slots: 4,
    ///         current_power_w: 20.0,
    ///         projected_power_w: 35.0,   // +15 W …
    ///         projected_session_bps: 100e6, // … for 100 MB/s → 0.15 µJ/B
    ///         projected_fleet_power_w: 65.0,
    ///     },
    /// ];
    /// // Host 1 moves the session's bytes for fewer joules each: admit it.
    /// assert_eq!(d.place(&candidates), PlaceDecision::Admit(1));
    /// ```
    pub fn place(&mut self, candidates: &[HostCandidate]) -> PlaceDecision {
        if candidates.is_empty() {
            return PlaceDecision::QueueNoSlot;
        }
        // Preference order over candidate positions.
        let mut order: Vec<usize> = (0..candidates.len()).collect();
        match self.placement {
            PlacementKind::RoundRobin => {
                order = (0..candidates.len())
                    .map(|k| (self.rr_cursor + k) % candidates.len())
                    .collect();
            }
            PlacementKind::LeastLoaded => {
                order.sort_by_key(|&i| (candidates[i].active_sessions, candidates[i].host));
            }
            PlacementKind::MarginalEnergy => {
                order.sort_by(|&a, &b| {
                    candidates[a]
                        .marginal_j_per_byte()
                        .total_cmp(&candidates[b].marginal_j_per_byte())
                        .then_with(|| candidates[a].host.cmp(&candidates[b].host))
                });
            }
        }
        let mut any_free = false;
        for idx in order {
            let c = &candidates[idx];
            if c.free_slots == 0 {
                continue;
            }
            any_free = true;
            if let Some(cap) = self.power_cap {
                if c.projected_fleet_power_w > cap.as_watts() + 1e-9 {
                    continue;
                }
            }
            if self.placement == PlacementKind::RoundRobin {
                self.rr_cursor = (idx + 1) % candidates.len();
            }
            return PlaceDecision::Admit(c.host);
        }
        if any_free {
            PlaceDecision::QueuePowerCap
        } else {
            PlaceDecision::QueueNoSlot
        }
    }
}

/// Everything needed to run a multi-host world.
#[derive(Debug, Clone)]
pub struct DispatcherConfig {
    /// The fleet's hosts, in placement-index order.
    pub hosts: Vec<HostSpec>,
    /// The workload: scripted [`SessionSpec`]s or a generated
    /// [`PoissonArrivals`] batch (see [`PoissonArrivals::sessions`]).
    pub sessions: Vec<SessionSpec>,
    /// How arriving sessions are placed on hosts.
    pub placement: PlacementKind,
    /// Per-host arbitration policy (always active in dispatcher mode —
    /// each host needs an owner for its CPU knobs).
    pub policy: FleetPolicyKind,
    /// Fleet-wide admission cap on *projected* aggregate host power.
    /// Admission control never admits a session whose projection exceeds
    /// it; `None` admits freely. This bounds the steady-state projection,
    /// not the instantaneous meters.
    pub power_cap: Option<Power>,
    /// Tuner knobs shared by every session's algorithm.
    pub params: TunerParams,
    /// Arbitration cadence of each host's fleet policy.
    pub fleet_interval: SimDuration,
    /// Base RNG seed; each host derives its own background-traffic seed.
    pub seed: u64,
    /// Simulation tick length (shared by every host).
    pub tick: SimDuration,
    /// Abort the run after this much simulated time.
    pub max_sim_time: SimDuration,
    /// Record per-timeout timelines for every session (costs memory).
    pub record_timeline: bool,
    /// Drive every host with the naive reference stepper instead of the
    /// epoch-cached fast path (tests and benchmarks).
    pub reference_stepper: bool,
}

impl DispatcherConfig {
    /// A dispatcher fleet with default knobs (min-energy host policy, no
    /// power cap) and no sessions yet.
    pub fn new(hosts: Vec<HostSpec>, placement: PlacementKind) -> Self {
        DispatcherConfig {
            hosts,
            sessions: Vec::new(),
            placement,
            policy: FleetPolicyKind::MinEnergyFleet,
            power_cap: None,
            params: TunerParams::default(),
            fleet_interval: SimDuration::from_secs(3.0),
            seed: 42,
            tick: SimDuration::from_millis(100.0),
            max_sim_time: SimDuration::from_secs(14_400.0),
            record_timeline: false,
            reference_stepper: false,
        }
    }

    /// Replace the workload.
    pub fn with_sessions(mut self, sessions: Vec<SessionSpec>) -> Self {
        self.sessions = sessions;
        self
    }

    /// Set the fleet-wide power cap.
    pub fn with_power_cap(mut self, cap: Power) -> Self {
        self.power_cap = Some(cap);
        self
    }

    /// Set the RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// What a dispatcher run produced: the fleet outcome (tenants flattened
/// across hosts, per-host breakdowns in [`FleetOutcome::hosts`]) plus the
/// dispatcher's own telemetry.
#[derive(Debug, Clone)]
pub struct DispatchOutcome {
    /// Aggregate + per-tenant + per-host results.
    pub fleet: FleetOutcome,
    /// One record per placement decision, in decision order.
    pub decisions: Vec<DispatchRecord>,
    /// Sessions never admitted before the run ended (still queued or
    /// still pending arrival at the time cap).
    pub unplaced: Vec<String>,
}

/// Derive one host's RNG seed from the fleet seed (distinct background
/// noise per host, reproducible from the pair).
fn host_seed(seed: u64, host: usize) -> u64 {
    seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(host as u64 + 1))
}

/// Snapshot every host into placement candidates (see [`HostCandidate`]).
fn build_candidates(worlds: &[HostWorld], hosts: &[HostSpec]) -> Vec<HostCandidate> {
    let current: Vec<(u32, f64)> = worlds
        .iter()
        .map(|w| {
            // Occupancy, not activation: sessions registered this segment
            // activate on the next tick but already claim their slot and
            // their share of the projection, otherwise two simultaneous
            // arrivals would both see an empty host.
            let active = w.occupancy();
            (active, w.projected_power_w(active))
        })
        .collect();
    let fleet_base: f64 = current.iter().map(|(_, w)| w).sum();
    worlds
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let (active, cur_w) = current[i];
            let proj_w = w.projected_power_w(active + 1);
            HostCandidate {
                host: i,
                active_sessions: active,
                free_slots: hosts[i].max_sessions.saturating_sub(active),
                current_power_w: cur_w,
                projected_power_w: proj_w,
                projected_session_bps: w.projected_session_bps(active + 1),
                projected_fleet_power_w: fleet_base - cur_w + proj_w,
            }
        })
        .collect()
}

/// Turn one decision into its telemetry record.
fn make_record(
    now: f64,
    session: &str,
    requested_at: f64,
    admitted: Option<usize>,
    candidates: &[HostCandidate],
    hosts: &[HostSpec],
) -> DispatchRecord {
    let scores = candidates
        .iter()
        .map(|c| PlacementScore {
            host: hosts[c.host].name.clone(),
            active_sessions: c.active_sessions,
            current_power_w: c.current_power_w,
            projected_power_w: c.projected_power_w,
            projected_session_bps: c.projected_session_bps,
            marginal_j_per_byte: c.marginal_j_per_byte(),
        })
        .collect();
    let projected_fleet_power_w = match admitted {
        Some(h) => candidates
            .iter()
            .find(|c| c.host == h)
            .map(|c| c.projected_fleet_power_w)
            .unwrap_or(0.0),
        // Queued: report the best projection among hosts that had a free
        // slot — the one that still broke the cap (or the fleet's current
        // draw when no slot was free at all).
        None => {
            let best = candidates
                .iter()
                .filter(|c| c.free_slots > 0)
                .map(|c| c.projected_fleet_power_w)
                .fold(f64::INFINITY, f64::min);
            if best.is_finite() {
                best
            } else {
                candidates.iter().map(|c| c.current_power_w).sum()
            }
        }
    };
    DispatchRecord {
        t_secs: now,
        session: session.to_string(),
        requested_at_secs: requested_at,
        admitted_host: admitted,
        host: admitted.map(|h| hosts[h].name.clone()),
        projected_fleet_power_w,
        scores,
    }
}

/// Run a multi-host fleet to completion (or the time cap): sessions
/// arrive on their [`TenantSpec::arrive_at`] schedule, the
/// [`Dispatcher`] places each one, and every host runs the shared
/// [`super::fleet`] driver. See the module docs for the semantics of
/// placement, admission control and the cross-host event horizon.
pub fn run_dispatcher(cfg: &DispatcherConfig) -> DispatchOutcome {
    assert!(!cfg.hosts.is_empty(), "a dispatcher needs at least one host");

    let mut worlds: Vec<HostWorld> = cfg
        .hosts
        .iter()
        .enumerate()
        .map(|(i, h)| {
            HostWorld::build(
                h.name.clone(),
                &h.testbed,
                &[],
                Some(cfg.policy),
                cfg.params,
                cfg.fleet_interval,
                cfg.tick,
                host_seed(cfg.seed, i),
                Vec::new(),
                false,
                cfg.record_timeline,
                cfg.reference_stepper,
            )
        })
        .collect();

    // Arrivals ordered by request time (stable for equal instants, so
    // spec order breaks ties deterministically).
    let mut pending: Vec<SessionSpec> = cfg.sessions.clone();
    pending.sort_by(|a, b| a.arrive_at.as_secs().total_cmp(&b.arrive_at.as_secs()));
    let mut pending: VecDeque<SessionSpec> = pending.into();
    // Sessions admission control is holding back, FIFO: the head blocks
    // the rest so a power-hungry host cannot starve early requesters.
    let mut queue: VecDeque<(SessionSpec, f64)> = VecDeque::new();
    let mut dispatcher = Dispatcher::new(cfg.placement, cfg.power_cap);
    let mut decisions: Vec<DispatchRecord> = Vec::new();

    let max = cfg.max_sim_time.as_secs();
    loop {
        let now = worlds[0].now_secs();

        // Queued sessions retry first (FIFO: stop at the first that still
        // does not fit), then arrivals due now. A newcomer never jumps an
        // occupied queue.
        while !queue.is_empty() {
            let candidates = build_candidates(&worlds, &cfg.hosts);
            match dispatcher.place(&candidates) {
                PlaceDecision::Admit(h) => {
                    let (spec, requested) = queue.pop_front().expect("non-empty");
                    decisions.push(make_record(
                        now,
                        &spec.name,
                        requested,
                        Some(h),
                        &candidates,
                        &cfg.hosts,
                    ));
                    worlds[h].register_arrival(spec);
                }
                _ => break,
            }
        }
        while pending
            .front()
            .is_some_and(|s| s.arrive_at.as_secs() <= now + 1e-9)
        {
            let spec = pending.pop_front().expect("non-empty");
            let requested = spec.arrive_at.as_secs();
            let candidates = build_candidates(&worlds, &cfg.hosts);
            let decision = if queue.is_empty() {
                dispatcher.place(&candidates)
            } else {
                PlaceDecision::QueuePowerCap // FIFO: wait behind the queue head
            };
            match decision {
                PlaceDecision::Admit(h) => {
                    decisions.push(make_record(
                        now,
                        &spec.name,
                        requested,
                        Some(h),
                        &candidates,
                        &cfg.hosts,
                    ));
                    worlds[h].register_arrival(spec);
                }
                _ => {
                    decisions.push(make_record(
                        now,
                        &spec.name,
                        requested,
                        None,
                        &candidates,
                        &cfg.hosts,
                    ));
                    queue.push_back((spec, requested));
                }
            }
        }

        let all_done = worlds.iter().all(|w| w.all_done());
        if (pending.is_empty() && queue.is_empty() && all_done) || now >= max {
            break;
        }
        // Stuck queue: nothing is running or pending, yet the head still
        // does not fit. Occupancy — and therefore every projection the
        // cap is checked against — can never change again, so simulating
        // idle hosts until the time cap would be pure waste: end the run
        // now and report the queue as unplaced.
        if pending.is_empty() && all_done && !queue.is_empty() {
            break;
        }

        for w in worlds.iter_mut() {
            w.admissions_due();
            w.sample_peaks();
        }

        // Cross-host event horizon: the earliest driver-level event on
        // any host, or the next arrival, or the time cap. Between now and
        // then every tick on every host is pure stepping.
        let mut horizon = max;
        if let Some(s) = pending.front() {
            horizon = horizon.min(s.arrive_at.as_secs());
        }
        for w in worlds.iter() {
            horizon = horizon.min(w.internal_horizon(max));
        }

        // Lockstep inner loop: one tick on every host per iteration. A
        // completion on any host ends the segment (its departure — and
        // any queued admission it unblocks — must be handled on exactly
        // that tick).
        loop {
            let mut completed = false;
            for w in worlds.iter_mut() {
                completed |= w.step_once().session_completed;
            }
            let t = worlds[0].now_secs();
            if completed || t + 1e-9 >= horizon || t >= max {
                break;
            }
        }

        for w in worlds.iter_mut() {
            w.post_segment();
        }
    }

    let completed =
        pending.is_empty() && queue.is_empty() && worlds.iter().all(|w| w.all_done());
    let duration = worlds[0].sim.now.since(SimTime::ZERO);
    let unplaced: Vec<String> = queue
        .iter()
        .map(|(s, _)| s.name.clone())
        .chain(pending.iter().map(|s| s.name.clone()))
        .collect();
    let policy = format!("{}+{}", cfg.placement.id(), worlds[0].policy_name());

    let mut tenants = Vec::new();
    let mut hosts = Vec::new();
    let mut moved = Bytes::ZERO;
    let mut client_energy = Energy::ZERO;
    let mut client_package_energy = Energy::ZERO;
    let mut server_energy = Energy::ZERO;
    for w in worlds {
        let (t, b) = w.finish();
        tenants.extend(t);
        moved += b.moved;
        client_energy = client_energy + b.client_energy;
        client_package_energy = client_package_energy + b.client_package_energy;
        server_energy = server_energy + b.server_energy;
        hosts.push(b);
    }
    tenants.sort_by(|a, b| {
        a.arrived_at
            .as_secs()
            .total_cmp(&b.arrived_at.as_secs())
            .then_with(|| a.name.cmp(&b.name))
    });

    DispatchOutcome {
        fleet: FleetOutcome {
            policy,
            tenants,
            completed,
            duration,
            moved,
            client_energy,
            client_package_energy,
            server_energy,
            final_active_cores: hosts[0].final_active_cores,
            final_freq: hosts[0].final_freq,
            hosts,
        },
        decisions,
        unplaced,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;

    fn cand(
        host: usize,
        active: u32,
        free: u32,
        cur_w: f64,
        proj_w: f64,
        bps: f64,
        fleet_w: f64,
    ) -> HostCandidate {
        HostCandidate {
            host,
            active_sessions: active,
            free_slots: free,
            current_power_w: cur_w,
            projected_power_w: proj_w,
            projected_session_bps: bps,
            projected_fleet_power_w: fleet_w,
        }
    }

    #[test]
    fn poisson_times_are_deterministic_and_hit_the_rate() {
        let a = PoissonArrivals::new(0.5, 4000, 7).times();
        let b = PoissonArrivals::new(0.5, 4000, 7).times();
        assert_eq!(a.len(), 4000);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.as_secs().to_bits(), y.as_secs().to_bits());
        }
        for w in a.windows(2) {
            assert!(w[1] > w[0], "arrival times must strictly increase");
        }
        // Empirical rate: mean inter-arrival ≈ 1/λ = 2 s within 5%.
        let mean = a.last().unwrap().as_secs() / 4000.0;
        assert!((mean - 2.0).abs() < 0.1, "mean inter-arrival {mean}");
        // A different seed perturbs the process.
        let c = PoissonArrivals::new(0.5, 4000, 8).times();
        assert_ne!(a[0].as_secs(), c[0].as_secs());
    }

    #[test]
    fn poisson_sessions_carry_arrival_times_and_distinct_datasets() {
        let specs = PoissonArrivals::new(0.1, 5, 3)
            .sessions("medium", AlgorithmKind::MaxThroughput)
            .expect("known family");
        assert_eq!(specs.len(), 5);
        for w in specs.windows(2) {
            assert!(w[1].arrive_at > w[0].arrive_at);
        }
        // Per-session seeds differ, so file layouts differ.
        assert_ne!(
            specs[0].dataset.files[0].size.as_f64(),
            specs[1].dataset.files[0].size.as_f64()
        );
        assert!(PoissonArrivals::new(0.1, 5, 3)
            .sessions("no-such-family", AlgorithmKind::MaxThroughput)
            .is_none());
    }

    #[test]
    fn round_robin_cycles_and_skips_full_hosts() {
        let mut d = Dispatcher::new(PlacementKind::RoundRobin, None);
        let free = |h| cand(h, 0, 2, 10.0, 12.0, 1e8, 40.0);
        let cands = vec![free(0), free(1), free(2)];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(0));
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
        assert_eq!(d.place(&cands), PlaceDecision::Admit(2));
        assert_eq!(d.place(&cands), PlaceDecision::Admit(0));
        // A full host is skipped without disturbing the rotation.
        let cands = vec![free(0), cand(1, 2, 0, 10.0, 12.0, 1e8, 40.0), free(2)];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(2));
    }

    #[test]
    fn least_loaded_prefers_the_emptier_host() {
        let mut d = Dispatcher::new(PlacementKind::LeastLoaded, None);
        let cands = vec![
            cand(0, 3, 1, 30.0, 32.0, 1e8, 60.0),
            cand(1, 1, 3, 30.0, 32.0, 1e8, 60.0),
            cand(2, 2, 2, 30.0, 32.0, 1e8, 60.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
    }

    #[test]
    fn marginal_energy_prefers_fewer_joules_per_byte() {
        let mut d = Dispatcher::new(PlacementKind::MarginalEnergy, None);
        // Host 0: +25 W for 50 MB/s = 0.5 µJ/B; host 1: +15 W for
        // 100 MB/s = 0.15 µJ/B.
        let cands = vec![
            cand(0, 1, 3, 30.0, 55.0, 50e6, 75.0),
            cand(1, 0, 4, 20.0, 35.0, 100e6, 65.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
        // A host that cannot move bytes scores infinitely bad.
        let cands = vec![
            cand(0, 1, 3, 30.0, 31.0, 0.0, 61.0),
            cand(1, 0, 4, 20.0, 50.0, 100e6, 80.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
    }

    #[test]
    fn power_cap_queues_or_reroutes() {
        let mut d =
            Dispatcher::new(PlacementKind::MarginalEnergy, Some(Power::from_watts(70.0)));
        // Best-scored host breaks the cap; the other fits → reroute.
        let cands = vec![
            cand(0, 0, 4, 20.0, 35.0, 100e6, 75.0), // 0.15 µJ/B but 75 W > cap
            cand(1, 0, 4, 30.0, 55.0, 50e6, 65.0),  // 0.5 µJ/B, fits
        ];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
        // Nobody fits → queue on the power cap.
        let cands = vec![
            cand(0, 0, 4, 20.0, 35.0, 100e6, 75.0),
            cand(1, 0, 4, 30.0, 55.0, 50e6, 72.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::QueuePowerCap);
        // No free slots anywhere → queue on capacity instead.
        let cands = vec![
            cand(0, 4, 0, 20.0, 35.0, 100e6, 60.0),
            cand(1, 4, 0, 30.0, 55.0, 50e6, 60.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::QueueNoSlot);
        assert_eq!(d.place(&[]), PlaceDecision::QueueNoSlot);
    }

    #[test]
    fn two_hosts_two_sessions_least_loaded_spreads() {
        let hosts = vec![
            HostSpec::new("a", testbeds::cloudlab()),
            HostSpec::new("b", testbeds::cloudlab()),
        ];
        let sessions = vec![
            TenantSpec::new(
                "s0",
                crate::dataset::standard::medium_dataset(1),
                AlgorithmKind::MaxThroughput,
            ),
            TenantSpec::new(
                "s1",
                crate::dataset::standard::medium_dataset(2),
                AlgorithmKind::MaxThroughput,
            ),
        ];
        let cfg = DispatcherConfig::new(hosts, PlacementKind::LeastLoaded)
            .with_sessions(sessions)
            .with_seed(5);
        let out = run_dispatcher(&cfg);
        assert!(out.fleet.completed, "both sessions must finish");
        assert!(out.unplaced.is_empty());
        assert_eq!(out.fleet.tenants.len(), 2);
        assert_eq!(out.fleet.hosts.len(), 2);
        // Least-loaded spreads simultaneous arrivals across hosts.
        assert_ne!(out.fleet.tenants[0].host, out.fleet.tenants[1].host);
        assert_eq!(out.decisions.len(), 2);
        assert!(out.decisions.iter().all(|d| !d.queued()));
        // Both hosts billed some energy (idle or serving).
        for h in &out.fleet.hosts {
            assert!(h.client_energy.as_joules() > 0.0, "{} unbilled", h.host);
        }
    }
}
