//! Figure 2 — throughput and energy consumption of all transfer tools
//! across the three testbeds and four datasets.
//!
//! Paper shapes this harness must reproduce (§V-A):
//! * wget/curl far behind everything; http/2.0 better on small files but
//!   window-limited on the WAN;
//! * Ismail et al. competitive on the 1 Gbps testbeds but weak on the
//!   large-BDP testbed (static tuning + parallelism=1), especially on the
//!   large and mixed datasets;
//! * ME cuts energy up to ~48 % vs Ismail-ME (mixed), EEMT gains up to
//!   ~80 % throughput vs Ismail-MT (mixed) at up to ~43 % less energy.

use super::common::{fmt_energy_kj, fmt_tput, run_cells, Cell};
use crate::coordinator::AlgorithmKind;
use crate::metrics::Table;
use crate::sim::session::SessionOutcome;
use std::path::Path;

/// Testbeds of the Figure 2 grid, paper order.
pub const TESTBEDS: [&str; 3] = ["chameleon", "cloudlab", "didclab"];
/// Datasets of the Figure 2 grid, paper order.
pub const DATASETS: [&str; 4] = ["small", "medium", "large", "mixed"];

/// The tools compared in Figure 2 (label, algorithm).
pub fn tools() -> Vec<(&'static str, AlgorithmKind)> {
    vec![
        ("wget", AlgorithmKind::Wget),
        ("curl", AlgorithmKind::Curl),
        ("http2", AlgorithmKind::Http2),
        ("Ismail-ME", AlgorithmKind::IsmailMinEnergy),
        ("Ismail-MT", AlgorithmKind::IsmailMaxThroughput),
        ("ME", AlgorithmKind::MinEnergy),
        ("EEMT", AlgorithmKind::MaxThroughput),
    ]
}

/// All outcomes of the Figure 2 grid, in (testbed, dataset, tool) order.
pub struct Fig2Results {
    /// (testbed, dataset, tool, outcome) in grid order.
    pub outcomes: Vec<(String, String, String, SessionOutcome)>,
    /// Rendered throughput / energy tables.
    pub tables: Vec<Table>,
}

/// Run the whole grid and build one throughput + one energy table per
/// testbed (the six panels of Figure 2).
pub fn run(seed: u64) -> Fig2Results {
    let tool_list = tools();
    let mut cells = Vec::new();
    for tb in TESTBEDS {
        for ds in DATASETS {
            for (_, kind) in &tool_list {
                cells.push(Cell::new(tb, ds, *kind).with_seed(seed));
            }
        }
    }
    let outs = run_cells(&cells);

    let mut outcomes = Vec::new();
    let mut tables = Vec::new();
    let mut idx = 0;
    for tb in TESTBEDS {
        let mut t_tput = Table::new(
            format!("Figure 2 — average throughput on {tb}"),
            &[&["dataset"], &tool_list.iter().map(|(n, _)| *n).collect::<Vec<_>>()[..]]
                .concat(),
        );
        let mut t_energy = Table::new(
            format!("Figure 2 — client energy on {tb}"),
            &[&["dataset"], &tool_list.iter().map(|(n, _)| *n).collect::<Vec<_>>()[..]]
                .concat(),
        );
        for ds in DATASETS {
            let mut row_t = vec![ds.to_string()];
            let mut row_e = vec![ds.to_string()];
            for (name, _) in &tool_list {
                let out = &outs[idx];
                idx += 1;
                row_t.push(fmt_tput(out));
                row_e.push(fmt_energy_kj(out.client_energy.as_joules()));
                outcomes.push((tb.to_string(), ds.to_string(), name.to_string(), out.clone()));
            }
            t_tput.push_row(row_t);
            t_energy.push_row(row_e);
        }
        tables.push(t_tput);
        tables.push(t_energy);
    }
    Fig2Results { outcomes, tables }
}

impl Fig2Results {
    /// Look one grid cell up by its labels.
    pub fn outcome(&self, testbed: &str, dataset: &str, tool: &str) -> &SessionOutcome {
        &self
            .outcomes
            .iter()
            .find(|(tb, ds, t, _)| tb == testbed && ds == dataset && t == tool)
            .expect("cell present")
            .3
    }

    /// The paper's two headline comparisons (§V-A), as ratios.
    pub fn headlines(&self) -> Fig2Headlines {
        let me = self.outcome("chameleon", "mixed", "ME");
        let ismail_me = self.outcome("chameleon", "mixed", "Ismail-ME");
        let eemt = self.outcome("chameleon", "mixed", "EEMT");
        let ismail_mt = self.outcome("chameleon", "mixed", "Ismail-MT");
        Fig2Headlines {
            me_energy_reduction: 1.0
                - me.client_energy.as_joules() / ismail_me.client_energy.as_joules(),
            eemt_tput_gain: eemt.avg_throughput.as_bits_per_sec()
                / ismail_mt.avg_throughput.as_bits_per_sec()
                - 1.0,
            eemt_energy_reduction: 1.0
                - eemt.client_energy.as_joules() / ismail_mt.client_energy.as_joules(),
        }
    }

    /// Write the per-panel CSV files into `dir`.
    pub fn save_csvs(&self, dir: impl AsRef<Path>) -> anyhow::Result<()> {
        let dir = dir.as_ref();
        for (i, t) in self.tables.iter().enumerate() {
            let kind = if i % 2 == 0 { "throughput" } else { "energy" };
            let tb = TESTBEDS[i / 2];
            t.save_csv(dir.join(format!("fig2_{tb}_{kind}.csv")))?;
        }
        Ok(())
    }
}

/// §V-A headline ratios.
#[derive(Debug, Clone, Copy)]
pub struct Fig2Headlines {
    /// ME energy reduction vs Ismail-ME on Chameleon/mixed (paper: up to 0.48).
    pub me_energy_reduction: f64,
    /// EEMT throughput gain vs Ismail-MT on Chameleon/mixed (paper: up to 0.80).
    pub eemt_tput_gain: f64,
    /// EEMT energy reduction vs Ismail-MT (paper: up to 0.43).
    pub eemt_energy_reduction: f64,
}

impl Fig2Headlines {
    /// Print the headline comparisons.
    pub fn print(&self) {
        println!("Fig2 headlines (Chameleon, mixed dataset):");
        println!(
            "  ME   vs Ismail-ME : {:+.0}% energy (paper: -48%)",
            -self.me_energy_reduction * 100.0
        );
        println!(
            "  EEMT vs Ismail-MT : {:+.0}% throughput (paper: +80%), {:+.0}% energy (paper: -43%)",
            self.eemt_tput_gain * 100.0,
            -self.eemt_energy_reduction * 100.0
        );
    }
}
