//! Interchange layout with the AOT predictor artifact.
//!
//! **Mirror of `python/compile/kernels/layout.py`** — keep in sync. The
//! `predictor_parity` integration test executes the compiled artifact
//! against [`super::reference`] and fails on drift.

/// Rows in the candidate matrix.
pub const NUM_CANDIDATES: usize = 128;
/// Pallas tile size along the candidate axis.
pub const TILE: usize = 32;

/// Columns per candidate row.
pub const CAND_WIDTH: usize = 3;
/// Candidate column: channel count.
pub const CAND_CHANNELS: usize = 0;
/// Candidate column: active cores.
pub const CAND_CORES: usize = 1;
/// Candidate column: frequency, GHz.
pub const CAND_FREQ_GHZ: usize = 2;

/// Length of the state vector.
pub const STATE_WIDTH: usize = 24;
/// State slot: available path capacity, bytes/s.
pub const S_CAPACITY_BPS: usize = 0;
/// State slot: round-trip time, s.
pub const S_RTT_S: usize = 1;
/// State slot: mean TCP window, bytes.
pub const S_AVG_WIN_BYTES: usize = 2;
/// State slot: overload-knee stream count.
pub const S_KNEE_STREAMS: usize = 3;
/// State slot: overload penalty slope.
pub const S_OVERLOAD_GAMMA: usize = 4;
/// State slot: overload penalty floor.
pub const S_OVERLOAD_FLOOR: usize = 5;
/// State slot: streams per channel.
pub const S_PARALLELISM: usize = 6;
/// State slot: bytes still to move.
pub const S_REMAINING_BYTES: usize = 7;
/// State slot: mean file size, bytes.
pub const S_AVG_FILE_BYTES: usize = 8;
/// State slot: pipelining level.
pub const S_PP_LEVEL: usize = 9;
/// State slot: CPU cycles per byte moved.
pub const S_CYCLES_PER_BYTE: usize = 10;
/// State slot: CPU cycles per request.
pub const S_CYCLES_PER_REQ: usize = 11;
/// State slot: CPU cycles per stream-second.
pub const S_CYCLES_PER_STREAM: usize = 12;
/// State slot: usable CPU fraction.
pub const S_MAX_APP_UTIL: usize = 13;
/// State slot: package static power, W.
pub const S_PKG_STATIC_W: usize = 14;
/// State slot: per-core idle power, W.
pub const S_CORE_IDLE_BASE_W: usize = 15;
/// State slot: per-core idle power per GHz, W.
pub const S_CORE_IDLE_PER_GHZ_W: usize = 16;
/// State slot: dynamic power coefficient κ.
pub const S_DYN_KAPPA: usize = 17;
/// State slot: voltage at the bottom P-state, V.
pub const S_V_MIN: usize = 18;
/// State slot: voltage at the top P-state, V.
pub const S_V_MAX: usize = 19;
/// State slot: bottom of the P-state ladder, GHz.
pub const S_F_MIN_GHZ: usize = 20;
/// State slot: top of the P-state ladder, GHz.
pub const S_F_MAX_GHZ: usize = 21;
/// State slot: DRAM power per GB/s, W.
pub const S_DRAM_W_PER_GBS: usize = 22;
/// State slot: reserved / padding.
pub const S_RESERVED: usize = 23;

/// Columns per output row.
pub const OUT_WIDTH: usize = 3;
/// Output column: predicted throughput, bytes/s.
pub const OUT_TPUT_BPS: usize = 0;
/// Output column: predicted package power, W.
pub const OUT_POWER_W: usize = 1;
/// Output column: predicted energy to completion, J.
pub const OUT_ENERGY_J: usize = 2;

/// Energy assigned to infeasible candidates (mirrors the Python constant).
pub const INFEASIBLE_ENERGY: f32 = 1e30;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_tiles_evenly() {
        assert_eq!(NUM_CANDIDATES % TILE, 0);
    }

    #[test]
    fn state_indices_dense() {
        // The last index must be the final slot.
        assert_eq!(S_RESERVED, STATE_WIDTH - 1);
    }
}
