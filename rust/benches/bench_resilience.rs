//! Resilience bench: the per-boundary cost of the fault machinery plus
//! the end-to-end joules/goodput comparison under the shared fault
//! script, written to `BENCH_resilience.json` (the committed seed
//! carries the schema; CI regenerates and uploads the file next to the
//! other bench artifacts).
//!
//!     cargo bench --bench bench_resilience
//!
//! Micro: what a segment boundary pays while the pipeline is armed —
//! fault-spec parsing + timeline expansion, a PenaltyBox surcharge
//! lookup sweep, and a HealthMonitor observation sweep over a 64-host
//! fleet. Macro: the `benchkit::resilience` scenario end-to-end with
//! recovery off vs on, asserting the acceptance invariant (recovery
//! wins goodput at no extra joules) before the figures are published.

use greendt::benchkit::resilience::{assert_recovery_wins, scenario, summarize, FaultRunSummary};
use greendt::benchkit::{bench, time_once, BenchReport};
use greendt::resilience::{FaultSchedule, HealthConfig, HealthMonitor, PenaltyBox, PenaltyConfig};
use greendt::sim::dispatcher::run_dispatcher;

fn main() {
    println!("== bench_resilience: fault pipeline cost + recovery payoff ==\n");
    let mut reports: Vec<BenchReport> = Vec::new();

    // Micro: parse + expand a multi-clause fault spec (the CLI path).
    let spec = "down:host=1,at=300,revive=900; degrade:host=0,at=60,until=240,frac=0.9; \
                down:host=3,at=500";
    reports.push(bench("faults parse+timeline/3 clauses", 200, 20_000, || {
        let s = FaultSchedule::parse(spec).expect("valid spec");
        s.timeline()
    }));

    // Micro: the placement-scoring surcharge lookup, per boundary, for a
    // 64-host fleet with a handful of struck hosts.
    let mut penalty = PenaltyBox::new(PenaltyConfig::default());
    for h in [3usize, 17, 41] {
        penalty.note_failure(h, 100.0);
        penalty.note_failure(h, 180.0);
    }
    reports.push(bench("penalty surcharge/64 hosts", 200, 20_000, || {
        (0..64usize).map(|h| penalty.surcharge_j_per_byte(h, 400.0)).sum::<f64>()
    }));

    // Micro: one health observation round over the same fleet.
    let mut health = HealthMonitor::new(HealthConfig::default(), 64);
    let mut t = 0.0f64;
    reports.push(bench("health observe/64 hosts", 200, 20_000, || {
        t += 5.0;
        let mut advisories = 0u32;
        for h in 0..64usize {
            let observed = if h % 7 == 0 { 1e7 } else { 9e7 };
            if health.observe(h, t, observed, 1e8).is_some() {
                advisories += 1;
            }
        }
        advisories
    }));

    // Macro: the shared scenario end-to-end, recovery off vs on.
    let (off_out, off_s) = time_once("run_dispatcher/faults/recovery off", || {
        run_dispatcher(&scenario(false))
    });
    let (on_out, on_s) = time_once("run_dispatcher/faults/recovery on", || {
        run_dispatcher(&scenario(true))
    });
    let off = summarize(&off_out);
    let on = summarize(&on_out);
    assert_recovery_wins(&off, &on);
    println!(
        "\nrecovery off: {:.2} GB in {:.0} s ({:.1} MB/s) for {:.0} J, {} dead-lettered",
        off.delivered_bytes / 1e9,
        off.duration_s,
        off.goodput_bps / 1e6,
        off.joules,
        off.dead_lettered
    );
    println!(
        "recovery on : {:.2} GB in {:.0} s ({:.1} MB/s) for {:.0} J, {} advisories, {} moves",
        on.delivered_bytes / 1e9,
        on.duration_s,
        on.goodput_bps / 1e6,
        on.joules,
        on_out.advisories.len(),
        on_out.migrations.len()
    );

    // Machine-readable record, next to the other bench artifacts.
    fn leg(s: &FaultRunSummary, wall: f64) -> String {
        format!(
            "{{\"goodput_bps\":{:.1},\"joules\":{:.1},\"delivered_bytes\":{:.0},\
             \"duration_s\":{:.3},\"dead_lettered\":{},\"completed\":{},\
             \"wall_seconds\":{}}}",
            s.goodput_bps, s.joules, s.delivered_bytes, s.duration_s, s.dead_lettered,
            s.completed, wall
        )
    }
    let micro: Vec<String> = reports.iter().map(|r| r.to_json()).collect();
    let json = format!(
        "{{\n  \"bench\": \"resilience\",\n  \"measured\": true,\n  \
         \"macro\": {{\n    \"off\": {},\n    \"on\": {},\n    \
         \"advisories\": {},\n    \"evacuations\": {}\n  }},\n  \"micro\": [{}]\n}}\n",
        leg(&off, off_s),
        leg(&on, on_s),
        on_out.advisories.len(),
        on_out.migrations.len(),
        micro.join(",")
    );
    std::fs::write("BENCH_resilience.json", json).expect("writing BENCH_resilience.json");
    println!("\nbench report written to BENCH_resilience.json");
}
