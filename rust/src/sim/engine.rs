//! The whole-world simulation stepper.
//!
//! A [`Simulation`] is one shared client [`Host`] plus N [`SessionSlot`]s
//! (one per concurrent transfer session) contending for the same CPU
//! package and bottleneck [`Link`]. Each tick, every active slot's streams
//! are pooled into a single global bottleneck allocation (so the link's
//! overload knee sees the *total* stream count), and the host's CPU
//! capacity is split across slots in proportion to their open streams.
//! With one slot this reduces exactly to the original single-session
//! world.

use super::host::{Host, HostTick};
use super::{Telemetry, TickStats};
use crate::config::Testbed;
use crate::cpusim::{CpuDemand, CpuState};
use crate::netsim::{AllocCache, Link, StreamState};
use crate::rng::{self, Xoshiro256};
use crate::transfer::{TickOutput, TransferEngine};
use crate::units::{Bytes, Energy, Rate, SimDuration, SimTime};

/// The stepper's epoch state: between structural events (channel churn,
/// tuning-knob changes, admissions/departures, slot completions,
/// slow-start transitions) the staged stream snapshot in the simulation's
/// scratch buffer — and the allocation cache derived from it — are
/// constant, so ticks can skip restaging and re-deriving them entirely.
/// See ARCHITECTURE.md §Perf for the invalidation rules.
#[derive(Debug, Clone, Default)]
struct EpochCache {
    /// The staged snapshot (slot spans + [`AllocCache`]) is current and
    /// every staged window is warm.
    valid: bool,
    /// Per-slot (active, engine generation) at the last staging; any
    /// mismatch — a knob change, channel churn, admission or departure —
    /// ends the epoch.
    stamps: Vec<(bool, u64)>,
    alloc: AllocCache,
}

/// Aggregates of one tick's per-slot pass, handed to the shared tick
/// tail ([`Simulation::settle_tick`]) by both the slow path and the
/// warm-batch path.
struct SlotPass {
    moved_total: Bytes,
    goodput_bps: f64,
    requests_out: f64,
    open_streams: usize,
    active_count: u32,
    session_completed: bool,
}

/// One tenant session on the host: its transfer engine plus per-session
/// telemetry accumulators and the energy attributed to it.
#[derive(Debug, Clone)]
pub struct SessionSlot {
    /// This session's transfer engine.
    pub engine: TransferEngine,
    active: bool,
    arrived_at: SimTime,
    // Interval accumulators (reset by `Simulation::drain_telemetry_for`).
    acc_moved: Bytes,
    acc_time: SimDuration,
    acc_load: f64,
    acc_server_load: f64,
    acc_load_ticks: u32,
    /// Instrument energy attributed to this session since it started (J).
    energy_j: f64,
    /// Package energy attributed to this session since it started (J).
    package_energy_j: f64,
    /// Snapshot of `energy_j` at the last telemetry drain.
    interval_energy_start_j: f64,
    /// Last-tick request rate, used for CPU overhead estimation.
    last_requests_per_sec: f64,
    // Per-tick scratch: this slot's span in the pooled stream buffer and
    // its last tick output (no allocation on the step path).
    stream_start: usize,
    stream_end: usize,
    tick_out: TickOutput,
}

impl SessionSlot {
    fn new(engine: TransferEngine) -> Self {
        SessionSlot {
            engine,
            active: false,
            arrived_at: SimTime::ZERO,
            acc_moved: Bytes::ZERO,
            acc_time: SimDuration::ZERO,
            acc_load: 0.0,
            acc_server_load: 0.0,
            acc_load_ticks: 0,
            energy_j: 0.0,
            package_energy_j: 0.0,
            interval_energy_start_j: 0.0,
            last_requests_per_sec: 0.0,
            stream_start: 0,
            stream_end: 0,
            tick_out: TickOutput::default(),
        }
    }

    /// True while the session is admitted.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// When the session was admitted.
    pub fn arrived_at(&self) -> SimTime {
        self.arrived_at
    }

    /// Instrument energy attributed to this session (its share of the
    /// host's draw, weighted by bytes moved each tick).
    pub fn attributed_energy(&self) -> Energy {
        Energy::from_joules(self.energy_j)
    }

    /// Package (RAPL) energy attributed to this session.
    pub fn attributed_package_energy(&self) -> Energy {
        Energy::from_joules(self.package_energy_j)
    }
}

/// The per-session mutable view handed to a tuning algorithm at each
/// timeout: its own transfer engine plus the (possibly shared) client CPU
/// setting it may actuate. In fleet mode the session-level governor is
/// disabled and the [`crate::coordinator::fleet::FleetPolicy`] owns the
/// CPU knobs instead.
#[derive(Debug)]
pub struct TuneCtx<'a> {
    /// The session's own transfer engine.
    pub engine: &'a mut TransferEngine,
    /// The client CPU setting the algorithm may actuate.
    pub client: &'a mut CpuState,
}

/// The complete simulated world: one shared host, N tenant sessions.
#[derive(Debug, Clone)]
pub struct Simulation {
    /// The shared bottleneck path.
    pub link: Link,
    /// The shared client end system (CPU settings, power models, meters).
    pub host: Host,
    slots: Vec<SessionSlot>,
    /// Current simulated time.
    pub now: SimTime,
    tick: SimDuration,
    rng: Xoshiro256,
    // Pooled per-tick scratch (streams of every tenant + their rates),
    // reused across ticks to keep the hot path allocation-free.
    scratch_streams: Vec<StreamState>,
    scratch_rates: Vec<f64>,
    last_world_stats: TickStats,
    epoch: EpochCache,
    /// Ticks run on the per-tick (slow) path vs. inside a warm batch.
    /// Observability only ([`Self::tick_counts`]) — the split is
    /// shard-count-sensitive by design (the serial driver never warm
    /// batches), so it feeds metrics, never the trace.
    ticks_slow: u64,
    ticks_warm: u64,
}

impl Simulation {
    /// Assemble a single-session world. `client` is the initial CPU
    /// setting chosen by the algorithm (Alg. 1 lines 14–20).
    pub fn new(
        testbed: &Testbed,
        engine: TransferEngine,
        client: CpuState,
        tick: SimDuration,
        seed: u64,
    ) -> Self {
        Self::with_bandwidth_events(testbed, engine, client, tick, seed, Vec::new())
    }

    /// Like [`Self::new`] with scripted background-traffic events
    /// (failure injection).
    pub fn with_bandwidth_events(
        testbed: &Testbed,
        engine: TransferEngine,
        client: CpuState,
        tick: SimDuration,
        seed: u64,
        events: Vec<crate::netsim::BandwidthEvent>,
    ) -> Self {
        let mut sim = Simulation::empty(testbed, client, tick, seed, events);
        let slot = sim.add_slot(engine);
        sim.activate_slot(slot);
        sim
    }

    /// A world with no sessions yet — the fleet driver adds slots and
    /// activates them as tenants arrive.
    pub fn empty(
        testbed: &Testbed,
        client: CpuState,
        tick: SimDuration,
        seed: u64,
        events: Vec<crate::netsim::BandwidthEvent>,
    ) -> Self {
        Self::empty_with_link(testbed, client, tick, seed, testbed.make_link_with_events(events))
    }

    /// Like [`Self::empty`] but with a *deterministic constant*
    /// background (plus the scripted events) instead of the noisy quiet
    /// one. Between events such a background is frozen, so warm epochs
    /// batch (`warm_batch_until`) — this is what the large-scale
    /// fleet paths and `bench_scale` use. Results stay bit-identical
    /// across steppers and shard counts with either link; only the
    /// modeled cross-traffic differs.
    pub fn empty_constant_bg(
        testbed: &Testbed,
        client: CpuState,
        tick: SimDuration,
        seed: u64,
        events: Vec<crate::netsim::BandwidthEvent>,
    ) -> Self {
        Self::empty_with_link(
            testbed,
            client,
            tick,
            seed,
            testbed.make_link_constant_bg_with_events(events),
        )
    }

    /// Like [`Self::empty`] on a *contended* path: seeded cross-traffic
    /// generators (a steady UDP floor plus bursty TCP flows) composed on
    /// top of the quiet OU background, plus the scripted events. The
    /// generator RNG derives from `seed`, so runs are reproducible; the
    /// link is never frozen, so every tick takes the slow path (warm
    /// epochs cannot batch over stochastic cross-traffic).
    pub fn empty_with_cross_traffic(
        testbed: &Testbed,
        client: CpuState,
        tick: SimDuration,
        seed: u64,
        events: Vec<crate::netsim::BandwidthEvent>,
        cross: crate::netsim::CrossTrafficConfig,
    ) -> Self {
        Self::empty_with_link(
            testbed,
            client,
            tick,
            seed,
            testbed.make_link_with_cross_traffic(events, cross, seed),
        )
    }

    fn empty_with_link(
        testbed: &Testbed,
        client: CpuState,
        tick: SimDuration,
        seed: u64,
        link: Link,
    ) -> Self {
        Simulation {
            link,
            host: Host::new(testbed, client),
            slots: Vec::new(),
            now: SimTime::ZERO,
            tick,
            rng: rng::stream(seed, "sim"),
            scratch_streams: Vec::new(),
            scratch_rates: Vec::new(),
            last_world_stats: TickStats::default(),
            epoch: EpochCache::default(),
            ticks_slow: 0,
            ticks_warm: 0,
        }
    }

    /// Register a session slot (inactive until [`Self::activate_slot`]).
    pub fn add_slot(&mut self, engine: TransferEngine) -> usize {
        self.slots.push(SessionSlot::new(engine));
        self.slots.len() - 1
    }

    /// Admit a session: it starts consuming host capacity on the next tick.
    pub fn activate_slot(&mut self, slot: usize) {
        let s = &mut self.slots[slot];
        s.active = true;
        s.arrived_at = self.now;
    }

    /// Retire a session (departed or finished).
    pub fn deactivate_slot(&mut self, slot: usize) {
        self.slots[slot].active = false;
    }

    /// Registered session slots (active or not).
    pub fn num_slots(&self) -> usize {
        self.slots.len()
    }

    /// Sessions currently admitted and consuming capacity.
    pub fn active_sessions(&self) -> u32 {
        self.slots.iter().filter(|s| s.active).count() as u32
    }

    /// Borrow one session slot.
    pub fn slot(&self, slot: usize) -> &SessionSlot {
        &self.slots[slot]
    }

    /// Mutably borrow one session slot.
    pub fn slot_mut(&mut self, slot: usize) -> &mut SessionSlot {
        &mut self.slots[slot]
    }

    /// All session slots.
    pub fn slots(&self) -> &[SessionSlot] {
        &self.slots
    }

    /// The first session's engine — the N=1 convenience used by the
    /// single-session driver, tests and benches.
    pub fn engine(&self) -> &TransferEngine {
        &self.slots[0].engine
    }

    /// Mutable access to the first session's engine.
    pub fn engine_mut(&mut self) -> &mut TransferEngine {
        &mut self.slots[0].engine
    }

    /// Disjoint borrow of one session's engine plus the shared client CPU
    /// setting — what a tuning algorithm actuates at its timeout.
    pub fn tune_ctx(&mut self, slot: usize) -> TuneCtx<'_> {
        TuneCtx { engine: &mut self.slots[slot].engine, client: &mut self.host.client }
    }

    /// The simulation tick length.
    pub fn tick_len(&self) -> SimDuration {
        self.tick
    }

    /// True once every session (including not-yet-admitted ones) has moved
    /// all of its data.
    pub fn is_done(&self) -> bool {
        self.slots.iter().all(|s| s.engine.is_done())
    }

    /// Client energy according to the testbed's instrument (RAPL package
    /// or wall meter).
    pub fn client_energy(&self) -> Energy {
        self.host.client_energy()
    }

    /// Server package energy so far.
    pub fn server_energy(&self) -> Energy {
        self.host.server_energy()
    }

    /// Aggregate stats of the most recent tick.
    pub fn last_stats(&self) -> TickStats {
        self.last_world_stats
    }

    /// True when the staged snapshot from the last tick is still exactly
    /// what restaging would produce: the epoch is warm (no slow-start
    /// windows) and no slot changed activity or structure since.
    fn epoch_stamps_match(&self) -> bool {
        self.epoch.stamps.len() == self.slots.len()
            && self
                .slots
                .iter()
                .zip(&self.epoch.stamps)
                .all(|(s, st)| s.active == st.0 && s.engine.generation() == st.1)
    }

    fn record_epoch_stamps(&mut self) {
        self.epoch.stamps.clear();
        self.epoch
            .stamps
            .extend(self.slots.iter().map(|s| (s.active, s.engine.generation())));
    }

    /// Advance the world by one tick. Returns aggregate (host-level)
    /// statistics; per-session stats are on each [`SessionSlot`].
    ///
    /// This is the epoch-cached fast path: within an epoch (all windows
    /// warm, no structural change) it reuses the staged stream snapshot
    /// and the cached allocation instead of re-deriving them. Outcomes
    /// are bit-identical to [`Self::step_reference`] — pinned by the
    /// stepper-equivalence property tests.
    pub fn step(&mut self) -> TickStats {
        self.step_inner(false)
    }

    /// The pre-epoch naive stepper: restages every tenant's streams and
    /// re-runs the full max-min allocation every tick. Kept as the oracle
    /// the epoch-cached fast path is validated (and benchmarked) against.
    pub fn step_reference(&mut self) -> TickStats {
        self.step_inner(true)
    }

    fn step_inner(&mut self, force_naive: bool) -> TickStats {
        let dt = self.tick;
        self.ticks_slow += 1;
        self.link.tick(self.now, dt, &mut self.rng);

        let reuse = !force_naive && self.epoch.valid && self.epoch_stamps_match();

        // End-system achievable throughput at current settings, using the
        // previous tick's aggregate request rate and the current total
        // stream count as the overhead estimate (one-step fixed point;
        // error is O(tick)). Within an epoch the staged spans carry the
        // same stream counts the engines would report.
        let mut requests = 0.0;
        let mut total_streams = 0usize;
        for s in &self.slots {
            if s.active {
                requests += s.last_requests_per_sec;
                total_streams += if reuse {
                    s.stream_end - s.stream_start
                } else {
                    s.engine.open_streams()
                };
            }
        }
        let cap = self.host.capacity_bytes_per_sec(requests, total_streams as f64);

        // Pool every active tenant's streams and run one global bottleneck
        // allocation, so cross-session contention and the overload knee
        // act on the true total (scratch reused; no allocation here). An
        // unbroken epoch skips the restage: the snapshot is unchanged.
        let rtt = self.link.params.rtt;
        let mut flat = std::mem::take(&mut self.scratch_streams);
        if !reuse {
            flat.clear();
            let mut slow_start_streams = 0usize;
            for s in &mut self.slots {
                if s.active {
                    s.stream_start = flat.len();
                    slow_start_streams += s.engine.stage_streams(dt, rtt, &mut flat);
                    s.stream_end = flat.len();
                }
            }
            if force_naive {
                self.epoch.valid = false;
            } else {
                self.epoch.alloc.rebuild(&self.link, &flat);
                // A warm epoch begins once every staged window sits at
                // steady state; it survives until a structural change.
                self.epoch.valid = slow_start_streams == 0;
                self.record_epoch_stamps();
            }
        }
        let mut rates = std::mem::take(&mut self.scratch_rates);
        if force_naive {
            crate::netsim::share_goodput_into(&self.link, &flat, &mut rates);
        } else {
            self.epoch.alloc.alloc_into(&self.link, &mut rates);
        }
        let staged = flat.len();

        // Hand each tenant its rate slice and its stream-proportional
        // share of the host CPU budget.
        let mut moved_total = Bytes::ZERO;
        let mut goodput_bps = 0.0;
        let mut requests_out = 0.0;
        let mut open_streams = 0usize;
        let mut active_count = 0u32;
        let mut session_completed = false;
        for s in &mut self.slots {
            if !s.active {
                continue;
            }
            active_count += 1;
            let share = if staged == 0 {
                1.0
            } else {
                (s.stream_end - s.stream_start) as f64 / staged as f64
            };
            let out = s.engine.apply_shared_rates(
                &rates[s.stream_start..s.stream_end],
                &self.link,
                dt,
                cap * share,
            );
            s.last_requests_per_sec = out.requests_per_sec;
            s.tick_out = out;
            moved_total += out.moved;
            goodput_bps += out.goodput.as_bytes_per_sec();
            requests_out += out.requests_per_sec;
            open_streams += out.open_streams;
            if s.engine.is_done() {
                session_completed = true;
            }
        }
        self.scratch_streams = flat;
        self.scratch_rates = rates;

        self.settle_tick(
            dt,
            SlotPass {
                moved_total,
                goodput_bps,
                requests_out,
                open_streams,
                active_count,
                session_completed,
            },
        )
    }

    /// The tick tail shared by the slow path and the warm-batch path:
    /// epoch revalidation, host accounting, the clock, per-tenant energy
    /// attribution and the aggregate stats. Keeping this in one place is
    /// what makes warm ticks bit-identical to slow ticks by construction.
    fn settle_tick(&mut self, dt: SimDuration, pass: SlotPass) -> TickStats {
        // Moving bytes can retire partitions, which reassigns or clears
        // channels (a generation bump) — that ends the epoch.
        if self.epoch.valid && !self.epoch_stamps_match() {
            self.epoch.valid = false;
        }

        // CPU loads and power implied by the aggregate goodput.
        let demand = CpuDemand {
            bytes_per_sec: pass.goodput_bps,
            requests_per_sec: pass.requests_out,
            open_streams: pass.open_streams as f64,
        };
        let ht: HostTick = self.host.record_tick(self.now, &demand, pass.moved_total, dt);

        self.now += dt;

        // Attribute host energy to tenants by bytes moved this tick (even
        // split of idle ticks), and roll the per-session accumulators.
        let moved_f = pass.moved_total.as_f64();
        for s in &mut self.slots {
            if !s.active {
                continue;
            }
            let share = if moved_f > 0.0 {
                s.tick_out.moved.as_f64() / moved_f
            } else {
                1.0 / pass.active_count as f64
            };
            s.energy_j += ht.instrument_energy_j * share;
            s.package_energy_j += ht.package_energy_j * share;
            s.acc_moved += s.tick_out.moved;
            s.acc_time += dt;
            s.acc_load += ht.client_load.min(4.0);
            s.acc_server_load += ht.server_load.min(4.0);
            s.acc_load_ticks += 1;
        }

        let stats = TickStats {
            goodput: Rate::from_bytes_per_sec(pass.goodput_bps),
            moved: pass.moved_total,
            client_load: ht.client_load,
            server_load: ht.server_load,
            client_power: ht.client_power,
            server_power: ht.server_power,
            open_streams: pass.open_streams,
            session_completed: pass.session_completed,
        };
        self.last_world_stats = stats;
        stats
    }

    /// One warm-epoch tick, skipping the per-tick heavy work the slow
    /// path would redo with identical results: the (frozen) link tick,
    /// the max-min allocation fill and per-channel efficiency recompute.
    /// Returns `None` — having changed nothing — when the warm-tick
    /// preconditions do not hold; the caller then takes [`Self::step`].
    ///
    /// Bit-exactness argument (see ARCHITECTURE.md §Scale): each gate
    /// conjunct certifies that one skipped piece of the slow path is a
    /// state no-op or value-identical from cache —
    /// * `epoch.valid && epoch_stamps_match()`: the slow path would take
    ///   its reuse branch (no restage), and since no structural change
    ///   happened the cached per-stream rates and per-channel stage-two
    ///   rates still carry exactly the bits it would recompute (the
    ///   allocation depends only on the frozen link and the unchanged
    ///   snapshot; channel efficiency never reads remaining bytes).
    /// * `link.bg_frozen()`: `link.tick` draws no randomness and cannot
    ///   change link state, so skipping it preserves the RNG and the
    ///   available bandwidth bit-for-bit.
    /// * no background event due: the only other way `link.tick` mutates
    ///   state. Events fire on the first tick whose start time reaches
    ///   them, so `at > now` defers exactly like the slow path would.
    ///
    /// Everything still executed — capacity lookup, byte movement
    /// ([`TransferEngine::apply_warm_rates`]), host accounting, energy
    /// attribution — is the identical expression sequence on identical
    /// bits. Depletion self-detects: the clamp to remaining bytes and
    /// the stage-five generation bump happen exactly as on the slow
    /// path, ending the epoch through the usual stamp mismatch.
    fn try_warm_step(&mut self) -> Option<TickStats> {
        if !(self.epoch.valid && self.epoch_stamps_match() && self.link.bg_frozen()) {
            return None;
        }
        if self.link.next_bg_event_at().is_some_and(|at| at <= self.now) {
            return None;
        }
        let dt = self.tick;

        // Identical to the slow path's reuse branch: spans carry the
        // stream counts the engines would report.
        let mut requests = 0.0;
        let mut total_streams = 0usize;
        for s in &self.slots {
            if s.active {
                requests += s.last_requests_per_sec;
                total_streams += s.stream_end - s.stream_start;
            }
        }
        let cap = self.host.capacity_bytes_per_sec(requests, total_streams as f64);
        let staged = self.scratch_streams.len();

        let mut moved_total = Bytes::ZERO;
        let mut goodput_bps = 0.0;
        let mut requests_out = 0.0;
        let mut open_streams = 0usize;
        let mut active_count = 0u32;
        let mut session_completed = false;
        for s in &mut self.slots {
            if !s.active {
                continue;
            }
            active_count += 1;
            let span = s.stream_end - s.stream_start;
            let share = if staged == 0 { 1.0 } else { span as f64 / staged as f64 };
            let out = s.engine.apply_warm_rates(dt, cap * share, span);
            s.last_requests_per_sec = out.requests_per_sec;
            s.tick_out = out;
            moved_total += out.moved;
            goodput_bps += out.goodput.as_bytes_per_sec();
            requests_out += out.requests_per_sec;
            open_streams += out.open_streams;
            if s.engine.is_done() {
                session_completed = true;
            }
        }

        Some(self.settle_tick(
            dt,
            SlotPass {
                moved_total,
                goodput_bps,
                requests_out,
                open_streams,
                active_count,
                session_completed,
            },
        ))
    }

    /// Run warm ticks until the clock would reach `stop_before` (minus
    /// the driver's `1e-9` horizon slack), the warm gate fails, or a
    /// session completes. Returns how many ticks ran and the last tick's
    /// stats (the previous tick's stats when none ran).
    ///
    /// The stopping test computes the candidate clock with the *same*
    /// floating-point operation the tick itself uses, so a batch can
    /// never carry the clock onto or past a deadline the event-horizon
    /// driver's post-tick break checks compare against — the final ticks
    /// of every segment always run in the driver's slow loop.
    pub(crate) fn warm_batch_until(&mut self, stop_before: f64) -> (u64, TickStats) {
        let dt = self.tick.as_secs();
        let mut done = 0u64;
        let mut last = self.last_world_stats;
        loop {
            if self.now.as_secs() + dt + 1e-9 >= stop_before {
                break;
            }
            match self.try_warm_step() {
                Some(stats) => {
                    done += 1;
                    last = stats;
                    if stats.session_completed {
                        break;
                    }
                }
                None => break,
            }
        }
        self.ticks_warm += done;
        (done, last)
    }

    /// Run up to `max_ticks` warm ticks (no clock bound — the sharded
    /// dispatcher precomputes safe tick counts instead). Stops early when
    /// the warm gate fails or a session completes.
    pub(crate) fn warm_batch_ticks(&mut self, max_ticks: u64) -> (u64, TickStats) {
        let mut done = 0u64;
        let mut last = self.last_world_stats;
        while done < max_ticks {
            match self.try_warm_step() {
                Some(stats) => {
                    done += 1;
                    last = stats;
                    if stats.session_completed {
                        break;
                    }
                }
                None => break,
            }
        }
        self.ticks_warm += done;
        (done, last)
    }

    /// Cumulative `(warm, slow)` tick counts for this world: ticks run
    /// inside a warm batch vs. on the per-tick path. The split depends
    /// on the driver (the serial dispatcher loop never warm-batches),
    /// so it is exported through the metrics registry only — never the
    /// trace, which must stay bit-identical across shard counts.
    pub fn tick_counts(&self) -> (u64, u64) {
        (self.ticks_warm, self.ticks_slow)
    }

    /// Path + transfer model view for the predictive governor.
    fn net_view(&self, slot: usize) -> crate::sim::telemetry::NetView {
        let p = &self.link.params;
        let engine = &self.slots[slot].engine;
        let parts = engine.partitions();
        let remaining: f64 = parts.iter().map(|x| x.remaining.as_f64()).sum();
        let (mut avg_file, mut pp) = (0.0, 0.0);
        if remaining > 0.0 {
            for x in parts {
                let w = x.remaining.as_f64() / remaining;
                avg_file += w * x.avg_file_size.as_f64();
                pp += w * x.pp_level as f64;
            }
        }
        let channels = engine.num_channels().max(1) as f64;
        crate::sim::telemetry::NetView {
            available_bps: self.link.available().as_bytes_per_sec(),
            rtt_s: p.rtt.as_secs(),
            avg_win_bytes: p.avg_win.as_f64(),
            knee_streams: p.knee_streams(),
            overload_gamma: p.overload_gamma,
            overload_floor: p.overload_floor,
            parallelism: (engine.open_streams() as f64 / channels).max(1.0),
            avg_file_bytes: avg_file.max(1.0),
            pp_level: pp.max(1.0),
        }
    }

    /// Read and reset one session's interval accumulators — called by the
    /// session/fleet driver at each tuning timeout to build the
    /// algorithm's view.
    pub fn drain_telemetry_for(&mut self, slot: usize) -> Telemetry {
        let net = self.net_view(slot);
        let now = self.now;
        let s = &mut self.slots[slot];
        let interval_energy =
            Energy::from_joules(s.energy_j - s.interval_energy_start_j);
        let tel = Telemetry {
            now,
            avg_throughput: Rate::average(s.acc_moved, s.acc_time),
            interval_energy,
            avg_power: interval_energy.average_power(s.acc_time),
            cpu_load: if s.acc_load_ticks == 0 {
                0.0
            } else {
                s.acc_load / s.acc_load_ticks as f64
            },
            remaining: s.engine.remaining(),
            total: s.engine.total(),
            elapsed: now.since(s.arrived_at),
            num_channels: s.engine.num_channels(),
            open_streams: s.engine.open_streams(),
            net,
        };
        // Server-side scaling extension: Algorithm 3 on the server,
        // driven by the same interval cadence. Rate-limited inside the
        // host so N tenants draining independently do not multiply the
        // server's step rate.
        if self.host.server_autoscale && s.acc_load_ticks > 0 {
            let load = s.acc_server_load / s.acc_load_ticks as f64;
            self.host.maybe_autoscale_server(now, s.acc_time, load);
        }
        let s = &mut self.slots[slot];
        s.acc_moved = Bytes::ZERO;
        s.acc_time = SimDuration::ZERO;
        s.acc_load = 0.0;
        s.acc_server_load = 0.0;
        s.acc_load_ticks = 0;
        s.interval_energy_start_j = s.energy_j;
        tel
    }

    /// [`Self::drain_telemetry_for`] on the first session (N=1 worlds).
    pub fn drain_telemetry(&mut self) -> Telemetry {
        self.drain_telemetry_for(0)
    }

    /// Average power of the client at an arbitrary hypothetical setting —
    /// exposed for the predictive governor's candidate evaluation.
    pub fn client_power_model(&self) -> &crate::power::PowerModel {
        self.host.client_power_model()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::{partition_files, standard};

    fn make_sim(testbed: &str, dataset: &str, channels: u32) -> Simulation {
        let tb = testbeds::by_name(testbed).unwrap();
        let ds = standard::by_name(dataset, 5).unwrap();
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(channels);
        let client = CpuState::performance(tb.client_cpu.clone());
        Simulation::new(&tb, engine, client, SimDuration::from_millis(100.0), 11)
    }

    #[test]
    fn stepping_moves_data_and_burns_energy() {
        let mut sim = make_sim("cloudlab", "medium", 6);
        for _ in 0..100 {
            sim.step();
        }
        assert!(sim.engine().remaining() < sim.engine().total());
        assert!(sim.client_energy().as_joules() > 0.0);
        assert!(sim.server_energy().as_joules() > 0.0);
        assert!((sim.now.as_secs() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn telemetry_reflects_interval() {
        let mut sim = make_sim("cloudlab", "medium", 6);
        for _ in 0..50 {
            sim.step();
        }
        let tel = sim.drain_telemetry();
        assert!(tel.avg_throughput.as_mbps() > 50.0, "tput {}", tel.avg_throughput);
        assert!(tel.interval_energy.as_joules() > 0.0);
        assert!(tel.cpu_load > 0.0);
        assert!((tel.elapsed.as_secs() - 5.0).abs() < 1e-9);
        // Drained: second read covers an empty interval.
        let tel2 = sim.drain_telemetry();
        assert_eq!(tel2.avg_throughput, Rate::ZERO);
    }

    #[test]
    fn min_freq_single_core_caps_10gbps() {
        let tb = testbeds::chameleon();
        let ds = standard::large_dataset(5);
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(8);
        let client = CpuState::min_energy_start(tb.client_cpu.clone());
        let mut sim = Simulation::new(&tb, engine, client, SimDuration::from_millis(100.0), 3);
        for _ in 0..100 {
            sim.step();
        }
        let tel = sim.drain_telemetry();
        // 1 core @ 1.2 GHz can push at most ~0.46 GB/s ≈ 3.7 Gbps.
        assert!(
            tel.avg_throughput.as_gbps() < 4.5,
            "CPU should bottleneck: {}",
            tel.avg_throughput
        );
        assert!(tel.cpu_load > 0.85, "load {}", tel.cpu_load);
    }

    #[test]
    fn performance_governor_uses_more_power_when_idle_ish() {
        let mut perf = make_sim("cloudlab", "large", 4);
        let tb = testbeds::cloudlab();
        let ds = standard::large_dataset(5);
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(4);
        let low = CpuState::min_energy_start(tb.client_cpu.clone());
        let mut eco = Simulation::new(&tb, engine, low, SimDuration::from_millis(100.0), 11);
        for _ in 0..100 {
            perf.step();
            eco.step();
        }
        let e_perf = perf.host.client_rapl.total();
        let e_eco = eco.host.client_rapl.total();
        assert!(
            e_perf.as_joules() > 1.5 * e_eco.as_joules(),
            "perf {} vs eco {}",
            e_perf,
            e_eco
        );
    }

    #[test]
    fn wall_meter_selected_on_didclab() {
        let mut sim = make_sim("didclab", "medium", 4);
        for _ in 0..10 {
            sim.step();
        }
        // Wall energy includes the platform base, so it must exceed RAPL.
        assert!(sim.client_energy() > sim.host.client_rapl.total());
    }

    fn make_fleet_sim(tenants: usize, channels_each: u32) -> Simulation {
        let tb = testbeds::cloudlab();
        let client = CpuState::performance(tb.client_cpu.clone());
        let mut sim =
            Simulation::empty(&tb, client, SimDuration::from_millis(100.0), 7, Vec::new());
        for i in 0..tenants {
            let ds = standard::large_dataset(10 + i as u64);
            let parts = partition_files(&ds, tb.bdp());
            let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
            engine.set_num_channels(channels_each);
            let slot = sim.add_slot(engine);
            sim.activate_slot(slot);
        }
        sim
    }

    #[test]
    fn tenants_split_the_bottleneck() {
        // One tenant alone vs four tenants sharing: the aggregate cannot
        // exceed the link, so each tenant gets roughly a quarter.
        let mut solo = make_fleet_sim(1, 4);
        let mut fleet = make_fleet_sim(4, 4);
        for _ in 0..200 {
            solo.step();
            fleet.step();
        }
        let solo_moved = solo.slot(0).engine.total() - solo.slot(0).engine.remaining();
        let t0 = fleet.slot(0).engine.total() - fleet.slot(0).engine.remaining();
        assert!(
            t0.as_f64() < 0.6 * solo_moved.as_f64(),
            "sharing must slow a tenant: {} vs solo {}",
            t0,
            solo_moved
        );
        // Aggregate stays within the pipe.
        let total: f64 = (0..4)
            .map(|i| {
                (fleet.slot(i).engine.total() - fleet.slot(i).engine.remaining()).as_f64()
            })
            .sum();
        let cap_bytes = 1e9 / 8.0 * 20.0; // 1 Gbps for 20 s
        assert!(total <= cap_bytes * 1.05, "aggregate {total} over link capacity");
    }

    #[test]
    fn attributed_energy_sums_to_host_energy() {
        let mut sim = make_fleet_sim(3, 4);
        for _ in 0..200 {
            sim.step();
        }
        let attributed: f64 =
            (0..3).map(|i| sim.slot(i).attributed_energy().as_joules()).sum();
        let host = sim.client_energy().as_joules();
        assert!(
            (attributed - host).abs() < 1e-6 * host.max(1.0),
            "attributed {attributed} vs host {host}"
        );
    }

    #[test]
    fn inactive_slot_consumes_nothing() {
        let tb = testbeds::cloudlab();
        let client = CpuState::performance(tb.client_cpu.clone());
        let mut sim =
            Simulation::empty(&tb, client, SimDuration::from_millis(100.0), 9, Vec::new());
        let ds = standard::medium_dataset(1);
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(4);
        let slot = sim.add_slot(engine); // never activated
        for _ in 0..50 {
            sim.step();
        }
        assert_eq!(sim.slot(slot).engine.remaining(), sim.slot(slot).engine.total());
        assert_eq!(sim.slot(slot).attributed_energy(), Energy::ZERO);
        assert!(!sim.is_done(), "a pending session keeps the world unfinished");
    }

    fn assert_stats_bits_eq(a: &TickStats, b: &TickStats, tick: usize) {
        assert_eq!(a.moved.as_f64().to_bits(), b.moved.as_f64().to_bits(), "moved @ {tick}");
        assert_eq!(
            a.goodput.as_bytes_per_sec().to_bits(),
            b.goodput.as_bytes_per_sec().to_bits(),
            "goodput @ {tick}"
        );
        assert_eq!(a.client_load.to_bits(), b.client_load.to_bits(), "load @ {tick}");
        assert_eq!(
            a.client_power.as_watts().to_bits(),
            b.client_power.as_watts().to_bits(),
            "power @ {tick}"
        );
        assert_eq!(a.open_streams, b.open_streams, "streams @ {tick}");
        assert_eq!(a.session_completed, b.session_completed, "completed @ {tick}");
    }

    #[test]
    fn epoch_stepper_matches_reference_bit_for_bit() {
        // Same world, one copy driven by the epoch-cached stepper and one
        // by the naive reference; every tick's stats and the final energy
        // books must carry identical bits, across slow-start ramps and a
        // mid-run redistribution that breaks the epoch.
        let mut fast = make_sim("chameleon", "mixed", 8);
        let mut naive = fast.clone();
        for tick in 0..400 {
            if tick == 150 {
                for sim in [&mut fast, &mut naive] {
                    sim.engine_mut().update_weights();
                    sim.engine_mut().set_num_channels(12);
                }
            }
            let a = fast.step();
            let b = naive.step_reference();
            assert_stats_bits_eq(&a, &b, tick);
        }
        assert_eq!(
            fast.client_energy().as_joules().to_bits(),
            naive.client_energy().as_joules().to_bits()
        );
        assert_eq!(
            fast.server_energy().as_joules().to_bits(),
            naive.server_energy().as_joules().to_bits()
        );
        assert_eq!(fast.engine().remaining(), naive.engine().remaining());
    }

    #[test]
    fn epoch_stepper_matches_reference_across_admissions() {
        // Fleet worlds: staggered admissions and a mid-run departure are
        // epoch boundaries; outcomes must stay bit-identical through them.
        let mut fast = make_fleet_sim(3, 4);
        let mut naive = fast.clone();
        // Park tenant 2 and re-admit it later to exercise (de)activation.
        fast.deactivate_slot(2);
        naive.deactivate_slot(2);
        for tick in 0..300 {
            if tick == 120 {
                fast.activate_slot(2);
                naive.activate_slot(2);
            }
            if tick == 220 {
                fast.deactivate_slot(1);
                naive.deactivate_slot(1);
            }
            let a = fast.step();
            let b = naive.step_reference();
            assert_stats_bits_eq(&a, &b, tick);
        }
        for i in 0..3 {
            assert_eq!(
                fast.slot(i).attributed_energy().as_joules().to_bits(),
                naive.slot(i).attributed_energy().as_joules().to_bits(),
                "tenant {i} energy attribution"
            );
        }
    }

    fn make_constant_bg_sim(channels: u32) -> Simulation {
        let tb = testbeds::cloudlab();
        let ds = standard::large_dataset(5);
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(channels);
        let client = CpuState::performance(tb.client_cpu.clone());
        let mut sim = Simulation::empty_constant_bg(
            &tb,
            client,
            SimDuration::from_millis(100.0),
            13,
            vec![crate::netsim::BandwidthEvent {
                at: SimTime::from_secs(30.0),
                mean_fraction: 0.4,
            }],
        );
        let slot = sim.add_slot(engine);
        sim.activate_slot(slot);
        sim
    }

    #[test]
    fn warm_ticks_match_reference_bit_for_bit() {
        // Constant-background world: once slow start ends the epoch warms
        // and the warm tick path engages. Every warm tick must carry the
        // same bits as the naive reference tick, and the scripted
        // bandwidth event at 30 s must force the slow path on its tick
        // (the gate defers to `link.tick` whenever an event is due).
        let mut fast = make_constant_bg_sim(6);
        let mut naive = fast.clone();
        let mut warm = 0u64;
        for tick in 0..600 {
            let (n, batched) = fast.warm_batch_ticks(1);
            let a = if n == 1 {
                warm += 1;
                batched
            } else {
                fast.step()
            };
            let b = naive.step_reference();
            assert_stats_bits_eq(&a, &b, tick);
        }
        assert!(warm > 300, "warm path engaged on only {warm}/600 ticks");
        assert_eq!(
            fast.client_energy().as_joules().to_bits(),
            naive.client_energy().as_joules().to_bits()
        );
        assert_eq!(
            fast.server_energy().as_joules().to_bits(),
            naive.server_energy().as_joules().to_bits()
        );
        assert_eq!(fast.engine().remaining(), naive.engine().remaining());
    }

    #[test]
    fn warm_batch_until_respects_the_stop_line() {
        // The batch must leave the clock strictly below the stop time
        // minus the driver's slack, so segment-ending ticks always run in
        // the driver's slow loop where the break checks live.
        let mut sim = make_constant_bg_sim(6);
        for _ in 0..50 {
            sim.step();
        }
        let (n, _) = sim.warm_batch_until(20.0);
        assert!(n > 0, "expected a warm epoch by 5 s");
        let now = sim.now.as_secs();
        assert!(now + sim.tick_len().as_secs() + 1e-9 >= 20.0, "stopped early: {now}");
        assert!(now + 1e-9 < 20.0, "overshot the stop line: {now}");
    }

    fn make_cross_traffic_sim(aimd: bool) -> Simulation {
        let tb = testbeds::cloudlab();
        let client = CpuState::performance(tb.client_cpu.clone());
        let cross = crate::netsim::CrossTrafficConfig {
            udp_fraction: 0.1,
            tcp_rate_per_sec: 0.5,
            tcp_burst_bytes: 20e6,
            tcp_burst_secs: 1.0,
        };
        let mut sim = Simulation::empty_with_cross_traffic(
            &tb,
            client,
            SimDuration::from_millis(100.0),
            21,
            Vec::new(),
            cross,
        );
        for i in 0..2 {
            let ds = standard::large_dataset(30 + i);
            let parts = partition_files(&ds, tb.bdp());
            let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
            engine.set_aimd(aimd);
            engine.set_num_channels(4);
            let slot = sim.add_slot(engine);
            sim.activate_slot(slot);
        }
        sim
    }

    #[test]
    fn cross_traffic_keeps_warm_batching_off_but_matches_reference() {
        // A contended link is never frozen, so the warm-batch path must
        // refuse every tick — while the epoch-cached slow path (which
        // re-reads the moving budget each tick) stays bit-identical to
        // the naive reference.
        let mut fast = make_cross_traffic_sim(false);
        let mut naive = fast.clone();
        assert!(!fast.link.bg_frozen());
        for tick in 0..300 {
            let (n, _) = fast.warm_batch_ticks(1);
            assert_eq!(n, 0, "warm tick engaged on a contended link at {tick}");
            let a = fast.step();
            let b = naive.step_reference();
            assert_stats_bits_eq(&a, &b, tick);
        }
        assert_eq!(
            fast.client_energy().as_joules().to_bits(),
            naive.client_energy().as_joules().to_bits()
        );
    }

    #[test]
    fn aimd_world_matches_reference_bit_for_bit() {
        // AIMD streams are permanently unstable (the epoch never warms),
        // so the fast stepper restages every tick; its outcomes must
        // still carry the reference's exact bits.
        let mut fast = make_cross_traffic_sim(true);
        let mut naive = fast.clone();
        for tick in 0..300 {
            let a = fast.step();
            let b = naive.step_reference();
            assert_stats_bits_eq(&a, &b, tick);
        }
        for i in 0..2 {
            assert_eq!(
                fast.slot(i).engine.remaining(),
                naive.slot(i).engine.remaining(),
                "tenant {i} remaining"
            );
        }
    }

    #[test]
    fn server_autoscale_branch_drains_in_host_layout() {
        // Direct test of the `server_autoscale` branch in
        // `drain_telemetry`: a network-bound session leaves the server
        // nearly idle, so the drain must shed server frequency.
        let mut sim = make_sim("cloudlab", "large", 4);
        sim.host.server_autoscale = true;
        assert!(sim.host.server.at_max_freq());
        for _ in 0..50 {
            sim.step();
        }
        let f0 = sim.host.server.freq();
        sim.drain_telemetry();
        assert!(sim.host.server.freq() < f0, "idle server must downscale");
        // With the extension off, the server stays pinned.
        let mut pinned = make_sim("cloudlab", "large", 4);
        for _ in 0..50 {
            pinned.step();
        }
        pinned.drain_telemetry();
        assert!(pinned.host.server.at_max_freq());
    }
}
