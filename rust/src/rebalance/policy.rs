//! Rebalance policies: when the fleet is allowed to move running work.

use super::cost::MigrationCost;

/// Which trigger the rebalancer acts on. The decision *how* a move is
/// scored lives in [`super::executor::Rebalancer`]; this enum is the
/// policy identity shared by the CLI, configs and telemetry (mirroring
/// [`PlacementKind`](crate::coordinator::fleet::PlacementKind) one layer
/// down).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RebalancePolicyKind {
    /// Never move running sessions — the dispatcher behaves bit-for-bit
    /// as it does without a rebalancer at all.
    #[default]
    Off,
    /// Move sessions only while the projected aggregate fleet power
    /// exceeds the admission power cap (a cap that tightened mid-run, or
    /// a projection that grew past it): pick the move that sheds the most
    /// projected watts. Inert without a cap.
    CapPressure,
    /// Move a session whenever another host would serve its *remaining*
    /// bytes at a lower marginal J/B by more than the estimated migration
    /// cost (plus a hysteresis margin) — the GreenDataFlow placement
    /// score (arXiv:1810.05892) applied continuously instead of only at
    /// admission.
    MarginalEnergyDelta,
}

impl RebalancePolicyKind {
    /// Stable identifier used by the CLI and in telemetry.
    pub fn id(&self) -> &'static str {
        match self {
            RebalancePolicyKind::Off => "off",
            RebalancePolicyKind::CapPressure => "cap-pressure",
            RebalancePolicyKind::MarginalEnergyDelta => "marginal-delta",
        }
    }

    /// Parse a CLI identifier (accepts common spellings).
    pub fn parse(id: &str) -> Option<RebalancePolicyKind> {
        Some(match id {
            "off" | "none" => RebalancePolicyKind::Off,
            "cap-pressure" | "cappressure" | "cap" => RebalancePolicyKind::CapPressure,
            "marginal-delta" | "marginaldelta" | "me-delta" | "medelta"
            | "marginal-energy-delta" => RebalancePolicyKind::MarginalEnergyDelta,
            _ => return None,
        })
    }
}

/// Everything the dispatcher needs to run a rebalancer: the trigger
/// policy, the migration cost model, and the per-session move budget.
#[derive(Debug, Clone)]
pub struct RebalanceConfig {
    /// When moves are considered at all.
    pub policy: RebalancePolicyKind,
    /// What a move is estimated (and simulated) to cost.
    pub migration_cost: MigrationCost,
    /// Hard ceiling on how many times one session may be migrated over a
    /// run — the anti-ping-pong budget. A session at its budget is
    /// pinned to wherever it currently runs.
    pub max_moves_per_session: u32,
    /// Evacuate sessions off hosts the resilience
    /// [`HealthMonitor`](crate::resilience::HealthMonitor) flags as
    /// degraded, even when the trigger policy is `Off` — advisory moves
    /// are damage control, not an optimization, so they bypass the
    /// benefit gate (but still respect the move budget). Only consulted
    /// while the dispatcher's recovery machinery is on; on by default
    /// because advisories cannot exist without it.
    pub evacuate_on_advisory: bool,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig {
            policy: RebalancePolicyKind::Off,
            migration_cost: MigrationCost::default(),
            max_moves_per_session: 2,
            evacuate_on_advisory: true,
        }
    }
}

impl RebalanceConfig {
    /// A config for `policy` with default cost model and move budget.
    pub fn new(policy: RebalancePolicyKind) -> Self {
        RebalanceConfig { policy, ..RebalanceConfig::default() }
    }

    /// Replace the migration cost model.
    pub fn with_cost(mut self, cost: MigrationCost) -> Self {
        self.migration_cost = cost;
        self
    }

    /// Turn advisory-driven evacuation on or off.
    pub fn with_evacuation(mut self, on: bool) -> Self {
        self.evacuate_on_advisory = on;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_round_trip() {
        for kind in [
            RebalancePolicyKind::Off,
            RebalancePolicyKind::CapPressure,
            RebalancePolicyKind::MarginalEnergyDelta,
        ] {
            assert_eq!(RebalancePolicyKind::parse(kind.id()), Some(kind));
        }
        assert_eq!(
            RebalancePolicyKind::parse("cap"),
            Some(RebalancePolicyKind::CapPressure)
        );
        assert_eq!(
            RebalancePolicyKind::parse("medelta"),
            Some(RebalancePolicyKind::MarginalEnergyDelta)
        );
        assert!(RebalancePolicyKind::parse("bogus").is_none());
    }

    #[test]
    fn default_config_is_off() {
        let cfg = RebalanceConfig::default();
        assert_eq!(cfg.policy, RebalancePolicyKind::Off);
        assert!(cfg.max_moves_per_session >= 1);
    }
}
