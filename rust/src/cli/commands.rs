//! CLI subcommand implementations.

use super::args::{ArgError, ParsedArgs};
use crate::config::experiment::{GovernorKind, TunerParams};
use crate::config::testbeds;
use crate::coordinator::AlgorithmKind;
use crate::dataset::standard;
use crate::experiments::{fig2, fig3, fig4, validate};
use crate::sim::session::{run_session, SessionConfig};
use crate::units::Rate;
use anyhow::{bail, Context, Result};

/// The `greendt help` text.
pub const USAGE: &str = "\
GreenDT — energy-efficient high-throughput data transfers
(reproduction of Di Tacchio et al., CS.DC 2019)

USAGE:
  greendt <COMMAND> [OPTIONS]

COMMANDS:
  session    Run one transfer session (alias: run)
             --config <FILE>       load session/tuner/testbed from TOML
             --csv <FILE>          write the per-timeout timeline as CSV
             --testbed chameleon|cloudlab|didclab   (default cloudlab)
             --dataset small|medium|large|mixed     (default mixed)
             --algo me|eemt|eett|wget|curl|http2|ismail-me|ismail-mt|
                    ismail-tt|alan-me|alan-mt       (default eemt)
             --target-mbps <N>     target for eett / ismail-tt
             --governor threshold|predictive|os|none  (default threshold;
                    'none' pins the CPU at the algorithm's initial setting)
             --seed <N>            RNG seed (default 42)
             --trace               print the per-timeout timeline
             --server-scaling      extension: Algorithm 3 on the server too
             --record-history <F>  append this run to a JSONL history store
             --history <F>         warm-start `--algo history` from a store
  sweep      Ablations: static-concurrency sweep + tuner sensitivity
             --testbed <T> --dataset <D>  (sweep panel; default cloudlab/large)
  fleet      Multi-tenant fleet: N sessions under one arbitration policy,
             on one shared host or on several hosts behind a dispatcher
             --testbed <T[,T2,..]> testbed per host, cycled (default cloudlab)
             --dataset <D>         per-tenant dataset family (default medium)
             --tenants <N>         number of sessions (default 4)
             --algo <A>            per-tenant algorithm (default eemt;
                                   `history` = warm-started ME)
             --policy fairshare|weightedshare|minenergy   host arbitration
                                   (default minenergy; weightedshare splits the
                                   channel budget by remaining bytes)
             --spacing <SECS>      arrival spacing between tenants (default 30)
             --seed <N>            RNG seed (default 42)
             --cross-traffic <SPEC>  seeded contending load on each link:
                                   udp:FRAC;tcp:RATE:SIZE:DUR adds a steady
                                   UDP floor (fraction of capacity) plus
                                   bursty TCP flows (RATE bursts/s of mean
                                   SIZE bytes over DUR s); 'off' (default)
                                   keeps the quiet path bit-identical
             --aimd                AIMD competing-flow channel dynamics:
                                   additive increase per RTT, multiplicative
                                   decrease on overload (default: slow-start
                                   then hold)
             --record-history <F>  append completed sessions (and, multi-host,
                                   placement decisions) to a JSONL store
             --history <F>         learn from a store: warm-starts
                                   `--algo history`, feeds `--placement learned`
             multi-host dispatcher (any of these flags selects it):
             --hosts <N>           number of hosts (default 2)
             --placement rr|leastloaded|marginal|learned   session placement
                                   (default marginal = marginal energy)
             --arrivals poisson:<per-min>:<count>   open workload: Poisson
                                   arrivals instead of --tenants/--spacing
             --power-cap <WATTS>   fleet admission cap on projected power
             --max-sessions <N>    per-host session-slot pool (default 8)
             --rebalance off|cap-pressure|marginal-delta   live migration of
                                   running sessions between hosts (default off)
             --migration-cost <S>  drain/handoff delay per migration, seconds
                                   (default 5)
             --price-queue-delay   price expected contention delay into
                                   marginal/learned placement scores
             --shards <N>          worker threads the lockstep stepper
                                   shards hosts across (default: one per
                                   available core; 1 = the serial reference
                                   loop; outcomes are bit-identical for
                                   every value)
             --constant-bg         freeze each host's background traffic at
                                   the testbed mean (fully deterministic,
                                   lets warm epochs batch ticks)
             --faults <SPEC>       scripted faults, semicolon-separated:
                                   down:host=H,at=T[,revive=T2] kills host H
                                   at T seconds; degrade:host=H,at=T,until=T2,
                                   frac=F collapses its link to background
                                   fraction F for the window
             --resilience on|off   recovery machinery: PenaltyBox retries +
                                   health-driven evacuation (default off —
                                   with --faults, losses are then terminal
                                   and dead-lettered immediately)
             --retry-budget <N>    host failures one session may survive
                                   before dead-letter quarantine (default 3;
                                   only meaningful with --resilience on)
             --trace <FILE>        write lifecycle spans + decision events
                                   (admission, placement scores, migrations,
                                   retries, faults) to FILE; off-path runs
                                   are bit-identical to runs without it
             --trace-format jsonl|chrome   trace encoding (default jsonl;
                                   chrome = trace_event JSON, loadable in
                                   Perfetto / chrome://tracing)
             --metrics <FILE>      write the fleet metrics registry
                                   (counters, gauges, percentile histograms,
                                   per-segment snapshots) as JSON to FILE
             --metrics-csv <FILE>  write the per-segment metrics timeline
                                   as CSV (enables metrics collection on
                                   its own, like --metrics)
  trace      Inspect a JSONL trace written by `fleet --trace`
             summarize <FILE>      per-session rollup + span-duration
                                   percentile table (default action)
             sessions <FILE>       list session names in the trace
             spans <FILE> --session <NAME>   span-tree waterfall for one
                                   session (omit --session for all)
             diff <A> <B>          structural diff of two trace logs (or
                                   two --metrics JSON files): records only
                                   in one side, per-session tally drift;
                                   exit 0 when identical, 1 when not
             --json                machine-readable output (all actions)
  history    Inspect or maintain a JSONL history store
             stats --history <F>   record counts + per-host/testbed costs
             query --history <F>   k-NN answer for a workload:
                   --testbed <T> --dataset <D> [--contention <N>] [--algo <A>]
             prune --history <F> --keep <N>   keep the newest N records
  bench      Hot-path benchmark: sim-seconds/wall-second of the naive
             reference stepper vs the epoch-cached stepper (plus micro
             benches of the per-tick pipeline)
             --json <FILE>         write the machine-readable report
                                   (e.g. BENCH_hotpath.json)
             --smoke               trimmed iteration counts (CI)
  sentinel   Perf/energy regression gate: compare a freshly regenerated
             BENCH_*.json against the committed baseline
             <BASELINE> <FRESH>    the two reports to compare
             --tolerance <F>       relative tolerance (default 0.25;
                                   micro paths get at least 0.5)
             --json                machine-readable report
             exit 0 = pass/warn, 1 = a measured metric regressed
                                   (warn-only while the baseline says
                                   \"measured\": false)
  fig2       Reproduce Figure 2 (all tools × datasets × testbeds)
  fig3       Reproduce Figure 3 (target-throughput comparison)
  fig4       Reproduce Figure 4 (frequency/core-scaling ablation)
             --seed <N>   --out <DIR>   (CSV output dir, default results/)
  validate   Regenerate Tables I & II and check them against the paper
  help       Show this message

ENVIRONMENT:
  GREENDT_PREDICTOR   path to predictor.hlo.txt (default artifacts/…)
  GREENDT_LOG         error|warn|info|debug|trace (default warn)
";

/// Entry point used by `main` (and by CLI tests). Returns the exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    // `--trace` means two different things: for `run`/`session` it is a
    // bare switch (print the per-timeout timeline); for `fleet` and the
    // `trace` subcommand it takes a file path. The switch list is
    // therefore command-dependent, keyed on the first positional.
    let cmd0 = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let value_trace = matches!(cmd0, "fleet" | "trace");
    let mut switches: Vec<&str> = vec![
        "no-csv",
        "server-scaling",
        "smoke",
        "price-queue-delay",
        "constant-bg",
        "aimd",
    ];
    if !value_trace {
        switches.push("trace");
    }
    // `--json` is a value flag for `bench` (the output file) but a bare
    // switch for the inspection commands, which print to stdout.
    if matches!(cmd0, "trace" | "sentinel") {
        switches.push("json");
    }
    let args = ParsedArgs::parse(argv, &switches).map_err(|e| anyhow::anyhow!(e))?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" | "session" => cmd_run(&args),
        "fleet" => cmd_fleet(&args),
        "trace" => cmd_trace(&args),
        "history" => cmd_history(&args),
        "sweep" => cmd_sweep(&args),
        "bench" => cmd_bench(&args),
        "sentinel" => cmd_sentinel(&args),
        "fig2" => cmd_fig2(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig4(&args),
        "validate" => cmd_validate(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            Ok(2)
        }
    }
}

fn parse_algo(args: &ParsedArgs) -> Result<AlgorithmKind> {
    let id = args.get_or("algo", "eemt");
    let target = args
        .get_f64("target-mbps")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
        .map(Rate::from_mbps);
    AlgorithmKind::parse(id, target).with_context(|| {
        format!("unknown algorithm '{id}' (or missing --target-mbps for target algorithms)")
    })
}

/// Load the `--history` store's k-NN index, if the flag was given.
fn load_history_index(args: &ParsedArgs) -> Result<Option<crate::history::KnnIndex>> {
    match args.get("history") {
        Some(path) => {
            let store = crate::history::HistoryStore::open(path)?;
            let index = store.index();
            println!(
                "history: loaded {} run records from {path} ({} indexed, {} lines skipped)",
                store.runs().len(),
                index.len(),
                store.skipped()
            );
            Ok(Some(index))
        }
        None => Ok(None),
    }
}

/// Swap a cold `--algo history` kind for the k-NN warm start answered by
/// the index (when one is loaded and confident); every other kind passes
/// through unchanged.
fn warm_kind(
    kind: AlgorithmKind,
    index: Option<&crate::history::KnnIndex>,
    dataset: &crate::dataset::Dataset,
    testbed: &crate::config::Testbed,
    contention: u32,
) -> AlgorithmKind {
    use crate::history::{Query, WorkloadFingerprint};
    if kind != AlgorithmKind::HistoryTuned(None) {
        return kind;
    }
    let Some(index) = index else { return kind };
    let q = Query::on_testbed(testbed, WorkloadFingerprint::of(dataset), contention)
        .with_algorithm(kind.id());
    match index.confident_warm_start(&q) {
        Some(warm) => AlgorithmKind::HistoryTuned(Some(warm)),
        None => kind,
    }
}

/// Append a run's records to the `--record-history` store, if requested.
/// The recording path never queries past records, so the store is opened
/// append-only (no load/parse of the accumulated log).
fn record_history(
    args: &ParsedArgs,
    runs: &[crate::history::RunRecord],
    decisions: &[crate::sim::DispatchRecord],
    migrations: &[crate::sim::MigrationRecord],
) -> Result<()> {
    let Some(path) = args.get("record-history") else { return Ok(()) };
    let mut store = crate::history::HistoryStore::append_only(path);
    let n = store.append_runs(runs)?;
    let d = store.append_dispatches(decisions)?;
    let m = store.append_migrations(migrations)?;
    match (d, m) {
        (0, 0) => println!("history: {n} run records appended to {path}"),
        (_, 0) => println!("history: {n} run records + {d} decisions appended to {path}"),
        _ => println!(
            "history: {n} run records + {d} decisions + {m} migrations appended to {path}"
        ),
    }
    Ok(())
}

/// Parse `--cross-traffic` (absent and `off` both mean a quiet link).
fn parse_cross_traffic(args: &ParsedArgs) -> Result<Option<crate::netsim::CrossTrafficConfig>> {
    match args.get("cross-traffic") {
        Some(spec) => crate::netsim::CrossTrafficConfig::parse(spec)
            .map_err(|e| anyhow::anyhow!("--cross-traffic: {e}")),
        None => Ok(None),
    }
}

fn parse_params(args: &ParsedArgs) -> Result<TunerParams> {
    let mut p = TunerParams::default();
    p.governor = match args.get_or("governor", "threshold") {
        "threshold" => GovernorKind::Threshold,
        "predictive" => GovernorKind::Predictive,
        "os" => GovernorKind::Os,
        // `none` means no governor at all — not even the OS default —
        // now that the fleet refactor gave that a first-class variant.
        "none" => GovernorKind::None,
        other => bail!("unknown governor '{other}'"),
    };
    Ok(p)
}

fn cmd_run(args: &ParsedArgs) -> Result<i32> {
    // Either a TOML config file or individual flags (flags win over file
    // values only for --seed; a config file fully specifies the session).
    let (testbed, dataset, kind, params, seed) = if let Some(path) = args.get("config") {
        let c = crate::config::load_file(path)?;
        let seed = args.get_u64("seed").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(c.seed);
        (c.testbed, c.dataset, c.algorithm, c.tuner, seed)
    } else {
        let tb_name = args.get_or("testbed", "cloudlab");
        let ds_name = args.get_or("dataset", "mixed");
        let seed = args.get_u64("seed").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(42);
        let testbed = testbeds::by_name(tb_name)
            .with_context(|| format!("unknown testbed '{tb_name}'"))?;
        let dataset = standard::by_name(ds_name, seed)
            .with_context(|| format!("unknown dataset '{ds_name}'"))?;
        (testbed, dataset, parse_algo(args)?, parse_params(args)?, seed)
    };

    // `--algo history` + `--history <store>`: replace the cold kind with
    // the k-NN warm start for this workload (a lone session queries at
    // contention 0).
    let index = load_history_index(args)?;
    let kind = warm_kind(kind, index.as_ref(), &dataset, &testbed, 0);
    if let AlgorithmKind::HistoryTuned(Some(w)) = kind {
        println!(
            "history: warm start at {} cores / P-state {} / {} channels",
            w.cores, w.pstate, w.channels
        );
    }

    let mut cfg =
        SessionConfig::new(testbed, dataset, kind).with_params(params).with_seed(seed);
    if args.has("trace") || args.get("csv").is_some() {
        cfg = cfg.recording();
    }
    if args.has("server-scaling") {
        cfg = cfg.with_server_scaling();
    }
    let out = run_session(&cfg);

    println!("session: {} on {} / {}", out.algorithm, out.testbed, out.dataset);
    println!("  completed        : {}", out.completed);
    println!("  moved            : {}", out.moved);
    println!("  duration         : {}", out.duration);
    println!("  avg throughput   : {}", out.avg_throughput);
    println!("  client energy    : {}", out.client_energy);
    println!("  client pkg energy: {}", out.client_package_energy);
    println!("  server energy    : {}", out.server_energy);
    println!("  peak channels    : {}", out.peak_channels);
    println!("  final CPU        : {} cores @ {}", out.final_active_cores, out.final_freq);
    if args.has("trace") {
        println!("\n  t(s)    state       tput        ch  cores  freq     load   power");
        for p in &out.timeline {
            println!(
                "  {:>6.1}  {:<10}  {:>10}  {:>2}  {:>5}  {:>7}  {:>5.2}  {:>6.1} W",
                p.t_secs,
                p.fsm,
                format!("{}", p.throughput),
                p.channels,
                p.active_cores,
                format!("{}", p.freq),
                p.cpu_load,
                p.power_w
            );
        }
    }
    if let Some(path) = args.get("csv") {
        crate::metrics::timeseries::save_timeline(&out, path)?;
        println!("\ntimeline written to {path}");
    }
    record_history(args, &out.run_records, &[], &[])?;
    Ok(if out.completed { 0 } else { 1 })
}

fn cmd_fleet(args: &ParsedArgs) -> Result<i32> {
    use crate::coordinator::FleetPolicyKind;
    use crate::sim::fleet::{run_fleet, FleetConfig, TenantSpec};
    use crate::units::SimTime;

    // An *active* cross-traffic spec and a frozen constant background
    // contradict each other (the generators unfreeze the link); reject
    // the pair before either path builds a world. `--cross-traffic off`
    // stays compatible with everything.
    if parse_cross_traffic(args)?.is_some() && args.has("constant-bg") {
        bail!(
            "--constant-bg and --cross-traffic are mutually exclusive: stochastic \
             cross-traffic unfreezes the link, so the constant (batchable) background \
             cannot hold; drop one of the flags"
        );
    }

    // Any dispatcher-only flag selects the multi-host path.
    if args.get("hosts").is_some()
        || args.get("placement").is_some()
        || args.get("arrivals").is_some()
        || args.get("power-cap").is_some()
        || args.get("max-sessions").is_some()
        || args.get("rebalance").is_some()
        || args.get("migration-cost").is_some()
        || args.get("shards").is_some()
        || args.get("faults").is_some()
        || args.get("resilience").is_some()
        || args.get("retry-budget").is_some()
        || args.has("price-queue-delay")
        || args.has("constant-bg")
        || args.get("trace").is_some()
        || args.get("trace-format").is_some()
        || args.get("metrics").is_some()
        || args.get("metrics-csv").is_some()
    {
        return cmd_fleet_dispatch(args);
    }

    let tb_name = args.get_or("testbed", "cloudlab");
    let ds_name = args.get_or("dataset", "medium");
    let seed = seed_of(args)?;
    let tenants = args
        .get_u32("tenants")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
        .unwrap_or(4)
        .max(1);
    let spacing = args
        .get_f64("spacing")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
        .unwrap_or(30.0)
        .max(0.0);
    let policy_id = args.get_or("policy", "minenergy");
    let policy = FleetPolicyKind::parse(policy_id)
        .with_context(|| format!("unknown fleet policy '{policy_id}'"))?;
    let kind = parse_algo(args)?;
    let testbed =
        testbeds::by_name(tb_name).with_context(|| format!("unknown testbed '{tb_name}'"))?;
    let index = load_history_index(args)?;

    let mut cfg = FleetConfig::new(testbed, Some(policy))
        .with_seed(seed)
        .with_aimd(args.has("aimd"));
    if let Some(cross) = parse_cross_traffic(args)? {
        cfg = cfg.with_cross_traffic(cross);
    }
    for i in 0..tenants {
        let ds = standard::by_name(ds_name, seed.wrapping_add(i as u64))
            .with_context(|| format!("unknown dataset '{ds_name}'"))?;
        // Warm-start `history` tenants: tenant i expects roughly i earlier
        // sessions still resident (the scripted arrivals overlap).
        let kind = warm_kind(kind, index.as_ref(), &ds, &cfg.testbed, i.min(8));
        cfg.tenants.push(
            TenantSpec::new(format!("tenant-{i}"), ds, kind)
                .arriving_at(SimTime::from_secs(spacing * i as f64)),
        );
    }
    let out = run_fleet(&cfg);
    record_history(args, &out.run_records, &[], &[])?;

    println!(
        "fleet: {} tenants ({}) on {} under {}",
        tenants,
        kind.id(),
        tb_name,
        out.policy
    );
    let mut t = crate::metrics::Table::new(
        "per-tenant outcomes",
        &["tenant", "arrive", "finish", "moved", "throughput", "energy share", "peak ch"],
    );
    for tn in &out.tenants {
        t.push_row(vec![
            tn.name.clone(),
            format!("{:.0} s", tn.arrived_at.as_secs()),
            match tn.finished_at {
                Some(at) => format!("{:.0} s", at.as_secs()),
                None => "-".to_string(),
            },
            format!("{}", tn.moved),
            format!("{}", tn.avg_throughput),
            format!("{}", tn.attributed_energy),
            tn.peak_channels.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    println!("  completed        : {}", out.completed);
    println!("  makespan         : {}", out.duration);
    println!("  host energy      : {}", out.client_energy);
    println!("  energy / tenant  : {}", out.energy_per_tenant());
    println!("  jain fairness    : {:.3}", out.jain_fairness());
    println!("  server energy    : {}", out.server_energy);
    println!("  final host CPU   : {} cores @ {}", out.final_active_cores, out.final_freq);
    Ok(if out.completed { 0 } else { 1 })
}

/// The multi-host dispatcher path of `greendt fleet`: several hosts
/// behind a placement policy, optionally with Poisson arrivals and a
/// fleet power cap.
fn cmd_fleet_dispatch(args: &ParsedArgs) -> Result<i32> {
    use crate::coordinator::{FleetPolicyKind, PlacementKind};
    use crate::sim::dispatcher::{
        run_dispatcher, DispatcherConfig, HostSpec, PoissonArrivals, SessionSpec,
    };
    use crate::units::{Power, SimTime};

    let seed = seed_of(args)?;
    let ds_name = args.get_or("dataset", "medium");
    let kind = parse_algo(args)?;

    // Observability flags are validated before the run so a typo'd
    // format fails fast instead of after minutes of simulation.
    let trace_path = args.get("trace");
    let trace_format = args.get_or("trace-format", "jsonl");
    if !matches!(trace_format, "jsonl" | "chrome") {
        bail!("--trace-format expects jsonl|chrome, got '{trace_format}'");
    }
    if args.get("trace-format").is_some() && trace_path.is_none() {
        bail!("--trace-format needs --trace <FILE>");
    }
    let metrics_path = args.get("metrics");
    let metrics_csv_path = args.get("metrics-csv");

    // Hosts: `--hosts N` machines, testbeds cycled from the (comma-
    // separated) `--testbed` list — `--testbed cloudlab,didclab` builds a
    // heterogeneous fleet.
    let hosts_n = args
        .get_u32("hosts")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
        .unwrap_or(2)
        .max(1);
    let max_sessions = args
        .get_u32("max-sessions")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
        .unwrap_or(8)
        .max(1);
    let tb_names: Vec<&str> = args.get_or("testbed", "cloudlab").split(',').collect();
    let mut hosts = Vec::with_capacity(hosts_n as usize);
    for i in 0..hosts_n {
        let tb_name = tb_names[i as usize % tb_names.len()].trim();
        let testbed = testbeds::by_name(tb_name)
            .with_context(|| format!("unknown testbed '{tb_name}'"))?;
        hosts.push(
            HostSpec::new(format!("host{i}-{}", testbed.name), testbed)
                .with_max_sessions(max_sessions),
        );
    }

    let placement_id = args.get_or("placement", "marginal");
    let placement = PlacementKind::parse(placement_id)
        .with_context(|| format!("unknown placement policy '{placement_id}'"))?;
    let policy_id = args.get_or("policy", "minenergy");
    let policy = FleetPolicyKind::parse(policy_id)
        .with_context(|| format!("unknown fleet policy '{policy_id}'"))?;
    let power_cap = args
        .get_f64("power-cap")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
        .map(Power::from_watts);

    // The rebalancer: policy + drain delay (`--migration-cost`).
    let rebalance_id = args.get_or("rebalance", "off");
    let rebalance_policy = crate::rebalance::RebalancePolicyKind::parse(rebalance_id)
        .with_context(|| format!("unknown rebalance policy '{rebalance_id}'"))?;
    let mut rebalance = crate::rebalance::RebalanceConfig::new(rebalance_policy);
    if let Some(drain) = args
        .get_f64("migration-cost")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
    {
        rebalance = rebalance.with_cost(crate::rebalance::MigrationCost::with_drain_secs(drain));
    }

    // The resilience pipeline: scripted faults (`--faults`), the
    // recovery switch (`--resilience on|off`) and the retry budget.
    let mut resilience = crate::resilience::ResilienceConfig::new();
    if let Some(spec) = args.get("faults") {
        let faults = crate::resilience::FaultSchedule::parse(spec)
            .map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
        faults.validate(hosts.len()).map_err(|e| anyhow::anyhow!("--faults: {e}"))?;
        resilience = resilience.with_faults(faults);
    }
    match args.get_or("resilience", "off") {
        "on" => resilience = resilience.with_recovery(),
        "off" => {}
        other => bail!("--resilience expects on|off, got '{other}'"),
    }
    if let Some(budget) =
        args.get_u32("retry-budget").map_err(|e: ArgError| anyhow::anyhow!(e))?
    {
        resilience = resilience.with_retry_budget(budget);
    }

    // Workload: an open Poisson process, or the scripted
    // --tenants/--spacing schedule the single-host mode uses.
    let sessions: Vec<SessionSpec> = if let Some(spec) = args.get("arrivals") {
        let parts: Vec<&str> = spec.split(':').collect();
        let (per_min, count) = match parts.as_slice() {
            ["poisson", rate, count] => (
                rate.parse::<f64>().ok().filter(|r| *r > 0.0),
                count.parse::<u32>().ok().filter(|c| *c > 0),
            ),
            _ => (None, None),
        };
        let (Some(per_min), Some(count)) = (per_min, count) else {
            bail!("--arrivals expects poisson:<per-min>:<count>, got '{spec}'");
        };
        PoissonArrivals::new(per_min / 60.0, count, seed)
            .sessions(ds_name, kind)
            .with_context(|| format!("unknown dataset '{ds_name}'"))?
    } else {
        let tenants = args
            .get_u32("tenants")
            .map_err(|e: ArgError| anyhow::anyhow!(e))?
            .unwrap_or(4)
            .max(1);
        let spacing = args
            .get_f64("spacing")
            .map_err(|e: ArgError| anyhow::anyhow!(e))?
            .unwrap_or(30.0)
            .max(0.0);
        let mut sessions = Vec::with_capacity(tenants as usize);
        for i in 0..tenants {
            let ds = standard::by_name(ds_name, seed.wrapping_add(i as u64))
                .with_context(|| format!("unknown dataset '{ds_name}'"))?;
            sessions.push(
                SessionSpec::new(format!("session-{i}"), ds, kind)
                    .arriving_at(SimTime::from_secs(spacing * i as f64)),
            );
        }
        sessions
    };
    let n_sessions = sessions.len();

    // Historical-log learning: the dispatcher itself warm-starts
    // `history` sessions at admission time (against the host that
    // actually admits them) and blends observed costs into `learned`
    // placement — the CLI only loads the index.
    let index = load_history_index(args)?;
    if placement == PlacementKind::Learned && index.is_none() {
        println!("note: --placement learned without --history scores like marginal energy");
    }

    let mut cfg = DispatcherConfig::new(hosts, placement).with_seed(seed);
    cfg.sessions = sessions;
    cfg.policy = policy;
    cfg.power_cap = power_cap;
    cfg.rebalance = rebalance;
    cfg.price_queue_delay = args.has("price-queue-delay");
    cfg.history = index;
    cfg.resilience = resilience;
    // `--shards N` (0 / absent = one per available core); outcomes are
    // shard-count invariant, so the CLI defaults to full parallelism.
    cfg.shards = args
        .get_u32("shards")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
        .unwrap_or(0) as usize;
    cfg.constant_bg = args.has("constant-bg");
    cfg.cross_traffic = parse_cross_traffic(args)?;
    cfg.aimd = args.has("aimd");
    cfg.trace = trace_path.is_some();
    cfg.metrics = metrics_path.is_some() || metrics_csv_path.is_some();
    let out = run_dispatcher(&cfg);
    record_history(args, &out.fleet.run_records, &out.decisions, &out.migrations)?;

    if let (Some(path), Some(records)) = (trace_path, &out.trace) {
        let text = match trace_format {
            "chrome" => crate::obs::chrome_trace_json(records),
            _ => crate::obs::trace_jsonl(records),
        };
        std::fs::write(path, text).with_context(|| format!("writing trace to {path}"))?;
        println!("trace: {} records ({trace_format}) -> {path}", records.len());
    }
    if let (Some(path), Some(m)) = (metrics_path, &out.metrics) {
        std::fs::write(path, m.to_json())
            .with_context(|| format!("writing metrics to {path}"))?;
        println!("metrics: {} segment snapshots -> {path}", m.timeline.snapshots.len());
    }
    if let (Some(path), Some(m)) = (metrics_csv_path, &out.metrics) {
        std::fs::write(path, m.timeline.to_csv())
            .with_context(|| format!("writing metrics CSV to {path}"))?;
        println!("metrics: {} timeline rows (csv) -> {path}", m.timeline.snapshots.len());
    }
    if let Some(cal) = &out.calibration {
        println!(
            "calibration: {} residencies, {} migrations joined, {} anomalies",
            cal.placements.len(),
            cal.migrations.iter().filter(|m| m.realized_benefit_j.is_some()).count(),
            cal.anomalies.len()
        );
    }
    let fleet = &out.fleet;

    println!(
        "dispatcher: {} sessions ({}) on {} hosts under {}",
        n_sessions,
        kind.id(),
        fleet.hosts.len(),
        fleet.policy
    );
    let mut ht = crate::metrics::Table::new(
        "per-host breakdown",
        &["host", "testbed", "served", "moved", "energy", "final CPU"],
    );
    for h in &fleet.hosts {
        ht.push_row(vec![
            h.host.clone(),
            h.testbed.clone(),
            h.tenants_served.to_string(),
            format!("{}", h.moved),
            format!("{}", h.client_energy),
            format!("{} cores @ {}", h.final_active_cores, h.final_freq),
        ]);
    }
    println!("{}", ht.to_markdown());
    let mut tt = crate::metrics::Table::new(
        "per-session outcomes",
        &["session", "host", "admit", "finish", "moved", "throughput", "energy share"],
    );
    for tn in &fleet.tenants {
        tt.push_row(vec![
            tn.name.clone(),
            tn.host.clone(),
            format!("{:.0} s", tn.arrived_at.as_secs()),
            match tn.finished_at {
                Some(at) => format!("{:.0} s", at.as_secs()),
                None => "-".to_string(),
            },
            format!("{}", tn.moved),
            format!("{}", tn.avg_throughput),
            format!("{}", tn.attributed_energy),
        ]);
    }
    println!("{}", tt.to_markdown());
    if !out.migrations.is_empty() {
        let mut mt = crate::metrics::Table::new(
            "rebalancer migrations",
            &["t (s)", "session", "from", "to", "moved", "re-admitted", "policy"],
        );
        for m in &out.migrations {
            mt.push_row(vec![
                format!("{:.1}", m.t_secs),
                m.session.clone(),
                m.from.clone(),
                m.to.clone(),
                format!("{}", crate::units::Bytes::new(m.moved_bytes)),
                format!("{}", crate::units::Bytes::new(m.remaining_bytes)),
                m.policy.to_string(),
            ]);
        }
        println!("{}", mt.to_markdown());
    }
    if !out.faults.is_empty() {
        let mut ft = crate::metrics::Table::new(
            "fault timeline",
            &["t (s)", "host", "event", "sessions hit"],
        );
        for f in &out.faults {
            ft.push_row(vec![
                format!("{:.1}", f.t_secs),
                f.host_name.clone(),
                f.kind.id().to_string(),
                f.sessions_hit.to_string(),
            ]);
        }
        println!("{}", ft.to_markdown());
    }
    if !fleet.dead_letters.is_empty() || fleet.dead_letter_overflow > 0 {
        let mut dt = crate::metrics::Table::new(
            "dead letters",
            &["session", "host", "reason", "attempts", "moved", "owed"],
        );
        for d in &fleet.dead_letters {
            dt.push_row(vec![
                d.session.clone(),
                fleet.hosts[d.host].host.clone(),
                d.reason.id().to_string(),
                d.attempts.to_string(),
                format!("{}", crate::units::Bytes::new(d.moved_bytes)),
                format!("{}", crate::units::Bytes::new(d.remaining_bytes)),
            ]);
        }
        println!("{}", dt.to_markdown());
        if fleet.dead_letter_overflow > 0 {
            println!(
                "  ({} more dead letters past the queue bound)",
                fleet.dead_letter_overflow
            );
        }
    }
    let queued = out.decisions.iter().filter(|d| d.queued()).count();
    println!("  completed        : {}", fleet.completed);
    println!("  makespan         : {}", fleet.duration);
    println!("  fleet energy     : {}", fleet.client_energy);
    println!("  energy / session : {}", fleet.energy_per_tenant());
    println!("  jain fairness    : {:.3}", fleet.jain_fairness());
    println!(
        "  admissions       : {} decisions, {} queued by admission control",
        out.decisions.len(),
        queued
    );
    if cfg.rebalance.policy != crate::rebalance::RebalancePolicyKind::Off {
        println!(
            "  rebalancer       : {} ({} migrations executed)",
            cfg.rebalance.policy.id(),
            out.migrations.len()
        );
    }
    if cfg.resilience.active() {
        println!(
            "  resilience       : recovery {} | {} faults fired, {} retries, {} advisories, \
             {} dead-lettered",
            if cfg.resilience.enabled { "on" } else { "off" },
            out.faults.len(),
            out.retries.len(),
            out.advisories.len(),
            fleet.dead_letters.len() as u64 + fleet.dead_letter_overflow,
        );
    }
    if let Some(cap) = cfg.power_cap {
        let peak = out
            .decisions
            .iter()
            .filter(|d| !d.queued())
            .map(|d| d.projected_fleet_power_w)
            .fold(0.0, f64::max);
        println!(
            "  power cap        : {} (peak admitted projection {:.1} W)",
            cap, peak
        );
    }
    if !out.unplaced.is_empty() {
        println!("  never admitted   : {}", out.unplaced.join(", "));
    }
    Ok(if fleet.completed { 0 } else { 1 })
}

/// The `greendt trace` subcommand: offline inspection of a JSONL trace
/// written by `fleet --trace` (`summarize` / `sessions` / `spans` /
/// `diff`), each with a `--json` sibling for machine consumers.
fn cmd_trace(args: &ParsedArgs) -> Result<i32> {
    use crate::obs::TraceLog;

    // `greendt trace <FILE>` reads as `summarize <FILE>`: a bare path in
    // the action slot is treated as the file.
    let mut action = args.positional.get(1).map(|s| s.as_str()).unwrap_or("summarize");
    let mut path = args.positional.get(2).map(|s| s.as_str());
    if action == "diff" {
        return cmd_trace_diff(args);
    }
    if !matches!(action, "summarize" | "sessions" | "spans") {
        if path.is_none() && args.positional.len() == 2 {
            path = Some(action);
            action = "summarize";
        } else {
            bail!("trace expects summarize|sessions|spans|diff <FILE..>, got '{action}'");
        }
    }
    let json = args.has("json");
    let path = path.context("trace commands need a trace file: greendt trace <ACTION> <FILE>")?;
    let log = TraceLog::load(path)?;
    if log.skipped > 0 {
        eprintln!("note: {} unparseable line(s) skipped in {path}", log.skipped);
    }
    match action {
        "sessions" => {
            if json {
                println!("{}", log.sessions_json());
            } else {
                for s in log.sessions() {
                    println!("{s}");
                }
            }
        }
        "spans" => {
            let names = match args.get("session") {
                Some(one) => vec![one.to_string()],
                None => log.sessions(),
            };
            if names.is_empty() && !json {
                println!("(no sessions in trace)");
            }
            for name in names {
                let tree = log.tree(&name);
                if tree.records.is_empty() {
                    bail!("no records for session '{name}' in {path}");
                }
                if json {
                    println!("{}", tree.to_json());
                    continue;
                }
                let status = if tree.connected() { "connected" } else { "DISCONNECTED" };
                println!("session {name} ({} records, {status})", tree.records.len());
                print!("{}", tree.waterfall());
                println!();
            }
        }
        _ => {
            if json {
                println!("{}", log.summary_json());
            } else {
                println!("trace: {path} ({} records)", log.records.len());
                println!("{}", log.summary_table().to_markdown());
                println!("{}", log.histogram_table().to_markdown());
            }
        }
    }
    Ok(0)
}

/// `greendt trace diff A B`: structural, id-insensitive diff of two
/// trace logs — or of two `--metrics` JSON documents, routed by their
/// `kind` stamp. Exit 0 when the sides are identical, 1 when they
/// differ (the CI smoke gates on that).
fn cmd_trace_diff(args: &ParsedArgs) -> Result<i32> {
    use crate::history::json;
    use crate::obs::{MetricsDiff, TraceDiff, TraceLog};

    let path_a = args
        .positional
        .get(2)
        .context("trace diff needs two files: greendt trace diff <A> <B>")?;
    let path_b = args
        .positional
        .get(3)
        .context("trace diff needs two files: greendt trace diff <A> <B>")?;
    let json_out = args.has("json");

    // A `--metrics` export is one JSON document stamped
    // `"kind":"greendt-metrics"`; anything else is treated as a JSONL
    // trace log.
    let text_a =
        std::fs::read_to_string(path_a).with_context(|| format!("reading {path_a}"))?;
    let text_b =
        std::fs::read_to_string(path_b).with_context(|| format!("reading {path_b}"))?;
    let is_metrics = |text: &str| {
        json::parse(text)
            .and_then(|d| d.get("kind").and_then(json::Json::as_str).map(String::from))
            .is_some_and(|k| k == "greendt-metrics")
    };
    if is_metrics(&text_a) || is_metrics(&text_b) {
        let (Some(a), Some(b)) = (json::parse(&text_a), json::parse(&text_b)) else {
            bail!("metrics diff needs two parseable JSON documents");
        };
        if !(is_metrics(&text_a) && is_metrics(&text_b)) {
            bail!("cannot diff a metrics document against a trace log");
        }
        let diff = MetricsDiff::compute(&a, &b);
        if json_out {
            println!("{}", diff.to_json(path_a, path_b));
        } else {
            print!("{}", diff.to_markdown(path_a, path_b));
        }
        return Ok(if diff.is_empty() { 0 } else { 1 });
    }

    let a = TraceLog::parse(&text_a);
    let b = TraceLog::parse(&text_b);
    for (path, log) in [(path_a, &a), (path_b, &b)] {
        if log.skipped > 0 {
            eprintln!("note: {} unparseable line(s) skipped in {path}", log.skipped);
        }
    }
    let diff = TraceDiff::compute(&a, &b);
    if json_out {
        println!("{}", diff.to_json(path_a, path_b));
    } else {
        print!("{}", diff.to_markdown(path_a, path_b));
    }
    Ok(if diff.is_empty() { 0 } else { 1 })
}

/// The `greendt sentinel` subcommand: compare a regenerated bench
/// report against the committed baseline and gate on regressions.
fn cmd_sentinel(args: &ParsedArgs) -> Result<i32> {
    use crate::benchkit::sentinel::SentinelReport;
    use crate::history::json;

    let path_a = args
        .positional
        .get(1)
        .context("sentinel needs two files: greendt sentinel <BASELINE> <FRESH>")?;
    let path_b = args
        .positional
        .get(2)
        .context("sentinel needs two files: greendt sentinel <BASELINE> <FRESH>")?;
    let tol = args
        .get_f64("tolerance")
        .map_err(|e: ArgError| anyhow::anyhow!(e))?
        .unwrap_or(0.25);
    if !(tol > 0.0) {
        bail!("--tolerance must be positive, got {tol}");
    }
    let text_a =
        std::fs::read_to_string(path_a).with_context(|| format!("reading {path_a}"))?;
    let text_b =
        std::fs::read_to_string(path_b).with_context(|| format!("reading {path_b}"))?;
    let baseline =
        json::parse(&text_a).with_context(|| format!("{path_a} is not valid JSON"))?;
    let fresh = json::parse(&text_b).with_context(|| format!("{path_b} is not valid JSON"))?;
    let report = SentinelReport::compare(&baseline, &fresh, tol);
    if args.has("json") {
        println!("{}", report.to_json(path_a, path_b));
    } else {
        print!("{}", report.to_markdown(path_a, path_b));
    }
    Ok(if report.failed() { 1 } else { 0 })
}

/// The `greendt history` subcommand: inspect or maintain a JSONL store
/// (`stats` / `query` / `prune`).
fn cmd_history(args: &ParsedArgs) -> Result<i32> {
    use crate::history::{HistoryStore, Query, WorkloadFingerprint, CONFIDENCE_FLOOR};
    use crate::units::Bytes;

    let action = args.positional.get(1).map(|s| s.as_str()).unwrap_or("stats");
    let path = args.get("history").context("history commands need --history <file>")?;
    let mut store = HistoryStore::open(path)?;
    match action {
        "stats" => {
            let s = store.stats();
            println!("history store: {path}");
            println!("  run records      : {}", s.runs);
            println!("  dispatch records : {}", s.dispatches);
            println!("  migration records: {}", s.migrations);
            println!("  skipped lines    : {}", s.skipped);
            if s.runs == 0 {
                return Ok(0);
            }
            let mut hosts: Vec<String> =
                store.runs().iter().map(|r| r.host.clone()).collect();
            hosts.sort();
            hosts.dedup();
            let mut t = crate::metrics::Table::new(
                "per-host history",
                &["host", "testbed", "runs", "moved", "mean J/B", "mean goodput"],
            );
            for h in hosts {
                let rs: Vec<_> = store.runs().iter().filter(|r| r.host == h).collect();
                let moved: f64 = rs.iter().map(|r| r.moved_bytes).sum();
                let joules: f64 = rs.iter().map(|r| r.joules).sum();
                let goodput =
                    rs.iter().map(|r| r.goodput_bps).sum::<f64>() / rs.len() as f64;
                t.push_row(vec![
                    h,
                    rs[0].testbed.clone(),
                    rs.len().to_string(),
                    format!("{}", Bytes::new(moved)),
                    format!("{:.3e}", if moved > 0.0 { joules / moved } else { 0.0 }),
                    format!("{}", Rate::from_bytes_per_sec(goodput)),
                ]);
            }
            println!("{}", t.to_markdown());
            Ok(0)
        }
        "query" => {
            let tb_name = args.get_or("testbed", "cloudlab");
            let ds_name = args.get_or("dataset", "medium");
            let contention = args
                .get_u32("contention")
                .map_err(|e: ArgError| anyhow::anyhow!(e))?
                .unwrap_or(0);
            let testbed = testbeds::by_name(tb_name)
                .with_context(|| format!("unknown testbed '{tb_name}'"))?;
            let dataset = standard::by_name(ds_name, seed_of(args)?)
                .with_context(|| format!("unknown dataset '{ds_name}'"))?;
            let index = store.index();
            let mut q =
                Query::on_testbed(&testbed, WorkloadFingerprint::of(&dataset), contention);
            if let Some(algo) = args.get("algo") {
                q = q.with_algorithm(algo);
            }
            println!(
                "query: {ds_name} on {tb_name} at contention {contention} \
                 ({} records indexed)",
                index.len()
            );
            match index.warm_start(&q) {
                Some((w, conf)) => {
                    println!(
                        "  warm start : {} cores / P-state {} / {} channels",
                        w.cores, w.pstate, w.channels
                    );
                    let verdict = if conf >= CONFIDENCE_FLOOR {
                        "above the floor — would be applied"
                    } else {
                        "below the floor — sessions would slow-start"
                    };
                    println!("  confidence : {conf:.2} ({verdict})");
                }
                None => println!("  warm start : none (empty store)"),
            }
            for host in index.hosts() {
                if let Some((jpb, conf)) = index.observed_j_per_byte(&host, &q) {
                    println!(
                        "  {host:<18}: {jpb:.3e} J/B observed (confidence {conf:.2})"
                    );
                }
            }
            Ok(0)
        }
        "prune" => {
            // Destructive maintenance never guesses a default budget.
            let keep = args
                .get_u32("keep")
                .map_err(|e: ArgError| anyhow::anyhow!(e))?
                .context("history prune needs an explicit --keep <N>")?
                as usize;
            let before = store.stats();
            let dropped = store.prune(keep)?;
            let after = store.stats();
            println!(
                "pruned {dropped} of {} lines; kept {} runs + {} decisions",
                before.runs + before.dispatches,
                after.runs,
                after.dispatches
            );
            Ok(0)
        }
        other => {
            eprintln!("unknown history action '{other}' (expected stats|query|prune)");
            Ok(2)
        }
    }
}

fn cmd_sweep(args: &ParsedArgs) -> Result<i32> {
    use crate::experiments::sweep;
    let tb = args.get_or("testbed", "cloudlab").to_string();
    let ds = args.get_or("dataset", "large").to_string();
    let seed = seed_of(args)?;
    let points = sweep::concurrency_sweep(&tb, &ds, seed);
    println!("{}", sweep::sweep_table(&tb, &ds, &points).to_markdown());
    println!("{}", sweep::band_sensitivity(seed).to_markdown());
    println!("{}", sweep::timeout_sensitivity(seed).to_markdown());
    println!("{}", sweep::slow_start_ablation(seed).to_markdown());
    Ok(0)
}

fn cmd_bench(args: &ParsedArgs) -> Result<i32> {
    let smoke = args.has("smoke");
    println!(
        "== greendt bench: simulation hot loop{} ==\n",
        if smoke { " (smoke)" } else { "" }
    );
    let report = crate::benchkit::hotpath::run(smoke);
    if let Some(path) = args.get("json") {
        report
            .write_json(path)
            .with_context(|| format!("writing bench report to {path}"))?;
        println!("\nbench report written to {path}");
    }
    Ok(0)
}

fn out_dir(args: &ParsedArgs) -> String {
    args.get_or("out", "results").to_string()
}

fn seed_of(args: &ParsedArgs) -> Result<u64> {
    Ok(args.get_u64("seed").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(42))
}

fn cmd_fig2(args: &ParsedArgs) -> Result<i32> {
    let results = fig2::run(seed_of(args)?);
    for t in &results.tables {
        println!("{}", t.to_markdown());
    }
    results.headlines().print();
    if !args.has("no-csv") {
        results.save_csvs(out_dir(args))?;
        println!("\nCSV written to {}/fig2_*.csv", out_dir(args));
    }
    Ok(0)
}

fn cmd_fig3(args: &ParsedArgs) -> Result<i32> {
    let results = fig3::run(seed_of(args)?);
    for t in &results.tables {
        println!("{}", t.to_markdown());
    }
    if !args.has("no-csv") {
        results.save_csvs(out_dir(args))?;
        println!("\nCSV written to {}/fig3_*.csv", out_dir(args));
    }
    Ok(0)
}

fn cmd_fig4(args: &ParsedArgs) -> Result<i32> {
    let results = fig4::run(seed_of(args)?);
    for t in &results.tables {
        println!("{}", t.to_markdown());
    }
    results.print_headlines();
    if !args.has("no-csv") {
        results.save_csvs(out_dir(args))?;
        println!("\nCSV written to {}/fig4_*.csv", out_dir(args));
    }
    Ok(0)
}

fn cmd_validate() -> Result<i32> {
    println!("{}", validate::table1().to_markdown());
    println!("{}", validate::table2(42).to_markdown());
    let problems = validate::check(42);
    if problems.is_empty() {
        println!("all Table I / Table II values match the paper ✓");
        Ok(0)
    } else {
        for p in &problems {
            println!("MISMATCH: {p}");
        }
        Ok(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn help_exits_zero() {
        assert_eq!(run(&argv("help")).unwrap(), 0);
    }

    #[test]
    fn unknown_command_exits_two() {
        assert_eq!(run(&argv("frobnicate")).unwrap(), 2);
    }

    #[test]
    fn validate_passes() {
        assert_eq!(run(&argv("validate")).unwrap(), 0);
    }

    #[test]
    fn run_quick_session() {
        let code =
            run(&argv("run --testbed cloudlab --dataset large --algo eemt --seed 3")).unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn eett_requires_target() {
        assert!(run(&argv("run --algo eett")).is_err());
        assert_eq!(run(&argv("run --algo eett --target-mbps 400 --dataset large")).unwrap(), 0);
    }

    #[test]
    fn bad_governor_rejected() {
        assert!(run(&argv("run --governor warp")).is_err());
    }

    #[test]
    fn fleet_quick_run() {
        let code =
            run(&argv("fleet --tenants 2 --dataset small --spacing 5 --seed 3")).unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_bad_policy_rejected() {
        assert!(run(&argv("fleet --policy warp")).is_err());
    }

    #[test]
    fn session_alias_runs_a_session() {
        let code = run(&argv(
            "session --testbed cloudlab --dataset large --algo eemt --seed 3",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_dispatcher_quick_run() {
        let code = run(&argv(
            "fleet --hosts 2 --placement leastloaded --tenants 2 --dataset small \
             --spacing 5 --seed 3",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_dispatcher_bad_flags_rejected() {
        assert!(run(&argv("fleet --placement warp")).is_err());
        assert!(run(&argv("fleet --arrivals uniform:1:3")).is_err());
        assert!(run(&argv("fleet --hosts 2 --testbed cloudlab,atlantis")).is_err());
    }

    #[test]
    fn fleet_weighted_share_policy_runs() {
        let code = run(&argv(
            "fleet --tenants 2 --dataset small --spacing 5 --policy weightedshare --seed 3",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_rebalance_flags_select_the_dispatcher_and_validate() {
        // `--rebalance off` alone selects the multi-host path and runs.
        let code = run(&argv(
            "fleet --rebalance off --tenants 2 --dataset small --spacing 5 --seed 3",
        ))
        .unwrap();
        assert_eq!(code, 0);
        // Unknown policies are rejected up front.
        assert!(run(&argv("fleet --rebalance sideways")).is_err());
        // An active policy with an explicit migration cost parses and runs
        // (two spaced small sessions: no move will pay, which is fine —
        // the path under test is flag plumbing, not the move itself).
        let code = run(&argv(
            "fleet --rebalance marginal-delta --migration-cost 2 --price-queue-delay \
             --hosts 2 --tenants 2 --dataset small --spacing 5 --seed 3",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_shards_flag_selects_the_dispatcher_and_runs() {
        // `--shards` alone routes to the multi-host path; sharded and
        // serial runs of the same workload both complete.
        let base = "fleet --hosts 2 --tenants 2 --dataset small --spacing 5 --seed 3";
        assert_eq!(run(&argv(&format!("{base} --shards 2 --constant-bg"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("{base} --shards 1"))).unwrap(), 0);
        assert_eq!(run(&argv("fleet --shards 0 --tenants 2 --dataset small --seed 3")).unwrap(), 0);
    }

    #[test]
    fn fleet_resilience_flags_select_the_dispatcher_and_validate() {
        // `--resilience on` alone selects the multi-host path; without a
        // fault schedule nothing fails and the run completes clean.
        let code = run(&argv(
            "fleet --resilience on --tenants 2 --dataset small --spacing 5 --seed 3",
        ))
        .unwrap();
        assert_eq!(code, 0);
        // A fault scheduled long after the workload drains never fires —
        // the flags plumb through and the run still exits 0.
        let code = run(&argv(
            "fleet --hosts 2 --tenants 2 --dataset small --spacing 5 --seed 3 \
             --resilience on --retry-budget 2 --faults down:host=1,at=14000",
        ))
        .unwrap();
        assert_eq!(code, 0);
        // Recovery off + a death under a running session is a terminal
        // loss: the session is dead-lettered and the run reports
        // incomplete (exit 1).
        let code = run(&argv(
            "fleet --hosts 1 --tenants 1 --dataset small --seed 3 \
             --faults down:host=0,at=1",
        ))
        .unwrap();
        assert_eq!(code, 1);
        // Malformed schedules, out-of-range hosts and bad switch values
        // are rejected up front.
        assert!(run(&argv("fleet --faults boom:host=0,at=1 --tenants 2")).is_err());
        assert!(run(&argv("fleet --hosts 2 --faults down:host=7,at=10 --tenants 2")).is_err());
        assert!(run(&argv("fleet --resilience maybe --tenants 2")).is_err());
    }

    #[test]
    fn fleet_cross_traffic_and_aimd_run_on_both_paths() {
        // Single-host fleet under contention with AIMD channels.
        let code = run(&argv(
            "fleet --tenants 2 --dataset small --spacing 5 --seed 3 \
             --cross-traffic udp:0.1;tcp:0.5:20e6:1 --aimd",
        ))
        .unwrap();
        assert_eq!(code, 0);
        // The dispatcher path takes the same flags.
        let code = run(&argv(
            "fleet --hosts 2 --tenants 2 --dataset small --spacing 5 --seed 3 \
             --cross-traffic udp:0.1;tcp:0.5:20e6:1 --aimd",
        ))
        .unwrap();
        assert_eq!(code, 0);
        // 'off' is the quiet path and composes with anything.
        let code = run(&argv(
            "fleet --hosts 2 --tenants 2 --dataset small --spacing 5 --seed 3 \
             --cross-traffic off --constant-bg",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_cross_traffic_conflicts_and_garbage_are_rejected() {
        // An active generator cannot ride a frozen constant background.
        let err = run(&argv(
            "fleet --tenants 2 --dataset small --seed 3 \
             --constant-bg --cross-traffic udp:0.1",
        ))
        .unwrap_err();
        assert!(
            err.to_string().contains("mutually exclusive"),
            "unhelpful conflict error: {err}"
        );
        // Malformed specs are rejected up front with the flag named.
        let err = run(&argv("fleet --tenants 2 --cross-traffic frob:1")).unwrap_err();
        assert!(err.to_string().contains("--cross-traffic"), "{err}");
    }

    #[test]
    fn history_algo_runs_cold_without_a_store() {
        let code = run(&argv(
            "run --testbed cloudlab --dataset large --algo history --seed 3",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn learned_placement_without_history_degrades_gracefully() {
        let code = run(&argv(
            "fleet --hosts 2 --placement learned --tenants 2 --dataset small \
             --spacing 5 --seed 3",
        ))
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn history_subcommand_needs_a_store_flag() {
        assert!(run(&argv("history stats")).is_err());
        assert_eq!(run(&argv("history frobnicate --history /tmp/x.jsonl")).unwrap(), 2);
    }

    #[test]
    fn record_then_warm_then_inspect_cycle() {
        let path = std::env::temp_dir()
            .join(format!("greendt_cli_history_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let p = path.to_str().unwrap();
        let base = "fleet --tenants 2 --dataset small --spacing 5 --algo history --seed 3";
        assert_eq!(run(&argv(&format!("{base} --record-history {p}"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("{base} --history {p}"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("history stats --history {p}"))).unwrap(), 0);
        assert_eq!(
            run(&argv(&format!(
                "history query --history {p} --testbed cloudlab --dataset small"
            )))
            .unwrap(),
            0
        );
        assert_eq!(
            run(&argv(&format!("history prune --history {p} --keep 1"))).unwrap(),
            0
        );
        // Destructive prune refuses to guess a budget.
        assert!(run(&argv(&format!("history prune --history {p}"))).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fleet_trace_and_metrics_write_then_trace_inspects() {
        let pid = std::process::id();
        let dir = std::env::temp_dir();
        let trace = dir.join(format!("greendt_cli_trace_{pid}.jsonl"));
        let chrome = dir.join(format!("greendt_cli_trace_{pid}.chrome.json"));
        let metrics = dir.join(format!("greendt_cli_metrics_{pid}.json"));
        let (tp, cp, mp) =
            (trace.to_str().unwrap(), chrome.to_str().unwrap(), metrics.to_str().unwrap());
        let base = "fleet --hosts 2 --tenants 2 --dataset small --spacing 5 --seed 3";
        assert_eq!(run(&argv(&format!("{base} --trace {tp} --metrics {mp}"))).unwrap(), 0);
        let text = std::fs::read_to_string(&trace).unwrap();
        assert!(text.lines().count() > 4, "trace too sparse:\n{text}");
        assert!(text.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
        let mtext = std::fs::read_to_string(&metrics).unwrap();
        assert!(mtext.contains("greendt-metrics"), "{mtext}");
        // The Chrome export is a top-level JSON array of trace events.
        assert_eq!(
            run(&argv(&format!("{base} --trace {cp} --trace-format chrome"))).unwrap(),
            0
        );
        let ctext = std::fs::read_to_string(&chrome).unwrap();
        assert!(ctext.trim_start().starts_with('['), "{ctext}");
        assert!(ctext.contains("\"ph\":\"X\""), "no complete events: {ctext}");
        // All three inspection actions run against the JSONL file, and a
        // bare path defaults to `summarize`.
        assert_eq!(run(&argv(&format!("trace summarize {tp}"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("trace {tp}"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("trace sessions {tp}"))).unwrap(), 0);
        assert_eq!(
            run(&argv(&format!("trace spans {tp} --session session-0"))).unwrap(),
            0
        );
        let _ = std::fs::remove_file(&trace);
        let _ = std::fs::remove_file(&chrome);
        let _ = std::fs::remove_file(&metrics);
    }

    #[test]
    fn trace_flag_misuse_is_rejected_up_front() {
        // Bad formats and a dangling --trace-format fail before any run.
        assert!(run(&argv("fleet --tenants 2 --trace /tmp/x.jsonl --trace-format svg"))
            .is_err());
        assert!(run(&argv("fleet --tenants 2 --trace-format chrome")).is_err());
        // Unknown trace actions and missing files are errors too.
        assert!(run(&argv("trace frobnicate /tmp/x.jsonl")).is_err());
        assert!(run(&argv("trace summarize /nonexistent/greendt.jsonl")).is_err());
        assert!(run(&argv("trace summarize")).is_err());
        // `diff` demands both files; `sentinel` demands both + JSON.
        assert!(run(&argv("trace diff /tmp/only_one.jsonl")).is_err());
        assert!(run(&argv("sentinel /tmp/only_one.json")).is_err());
    }

    #[test]
    fn trace_json_siblings_and_metrics_csv_write() {
        let pid = std::process::id();
        let dir = std::env::temp_dir();
        let trace = dir.join(format!("greendt_cli_tracejson_{pid}.jsonl"));
        let csv = dir.join(format!("greendt_cli_metrics_{pid}.csv"));
        let (tp, cp) = (trace.to_str().unwrap(), csv.to_str().unwrap());
        let base = "fleet --hosts 2 --tenants 2 --dataset small --spacing 5 --seed 3";
        assert_eq!(
            run(&argv(&format!("{base} --trace {tp} --metrics-csv {cp}"))).unwrap(),
            0
        );
        // --metrics-csv alone turned metrics collection on and wrote the
        // timeline with its fixed header.
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(
            csv_text.starts_with("t_secs,active_sessions,queued,goodput_bps,watts"),
            "{csv_text}"
        );
        assert!(csv_text.lines().count() > 1, "timeline rows missing:\n{csv_text}");
        // The --json siblings exit 0 on every action (stdout content is
        // pinned by the obs unit tests).
        assert_eq!(run(&argv(&format!("trace summarize {tp} --json"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("trace sessions {tp} --json"))).unwrap(), 0);
        assert_eq!(
            run(&argv(&format!("trace spans {tp} --session session-0 --json"))).unwrap(),
            0
        );
        let _ = std::fs::remove_file(&trace);
        let _ = std::fs::remove_file(&csv);
    }

    #[test]
    fn trace_diff_cli_discriminates_identical_from_drifted() {
        let pid = std::process::id();
        let dir = std::env::temp_dir();
        let a = dir.join(format!("greendt_cli_diff_a_{pid}.jsonl"));
        let b = dir.join(format!("greendt_cli_diff_b_{pid}.jsonl"));
        let (ap, bp) = (a.to_str().unwrap(), b.to_str().unwrap());
        let base = "fleet --hosts 2 --tenants 2 --dataset small --spacing 5 --seed 3";
        assert_eq!(run(&argv(&format!("{base} --trace {ap}"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("{base} --trace {bp}"))).unwrap(), 0);
        // Seed-matched runs: empty diff, exit 0 (markdown and JSON).
        assert_eq!(run(&argv(&format!("trace diff {ap} {bp}"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("trace diff {ap} {bp} --json"))).unwrap(), 0);
        // A different seed drifts: exit 1.
        let base7 = base.replace("--seed 3", "--seed 7");
        assert_eq!(run(&argv(&format!("{base7} --trace {bp}"))).unwrap(), 0);
        assert_eq!(run(&argv(&format!("trace diff {ap} {bp}"))).unwrap(), 1);
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }

    #[test]
    fn sentinel_cli_gates_on_measured_regressions() {
        let pid = std::process::id();
        let dir = std::env::temp_dir();
        let base = dir.join(format!("greendt_cli_sent_base_{pid}.json"));
        let fresh = dir.join(format!("greendt_cli_sent_fresh_{pid}.json"));
        let (bp, fp) = (base.to_str().unwrap(), fresh.to_str().unwrap());
        std::fs::write(&base, r#"{"measured":true,"speedup":4.0}"#).unwrap();
        std::fs::write(&fresh, r#"{"measured":true,"speedup":3.5}"#).unwrap();
        // Within the default ±25%: pass.
        assert_eq!(run(&argv(&format!("sentinel {bp} {fp}"))).unwrap(), 0);
        // A halved speedup fails — unless the baseline is unmeasured.
        std::fs::write(&fresh, r#"{"measured":true,"speedup":2.0}"#).unwrap();
        assert_eq!(run(&argv(&format!("sentinel {bp} {fp} --json"))).unwrap(), 1);
        std::fs::write(&base, r#"{"measured":false,"speedup":4.0}"#).unwrap();
        assert_eq!(run(&argv(&format!("sentinel {bp} {fp}"))).unwrap(), 0);
        // A loose explicit tolerance also passes the measured pair.
        std::fs::write(&base, r#"{"measured":true,"speedup":4.0}"#).unwrap();
        assert_eq!(run(&argv(&format!("sentinel {bp} {fp} --tolerance 0.6"))).unwrap(), 0);
        assert!(run(&argv(&format!("sentinel {bp} {fp} --tolerance -1"))).is_err());
        let _ = std::fs::remove_file(&base);
        let _ = std::fs::remove_file(&fresh);
    }
}
