//! Synthetic dataset generation.
//!
//! The paper's datasets (Table II) are characterized only by file count,
//! average size and standard deviation; we regenerate them with lognormal
//! sizes (the canonical heavy-tail-ish shape of real file-size
//! distributions) from a deterministic seed.

use super::{Dataset, FileSpec};
use crate::rng::{self, Distribution, LogNormal};
use crate::units::Bytes;

/// Recipe for a synthetic dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Name of the generated dataset.
    pub name: String,
    /// How many files to draw.
    pub num_files: usize,
    /// Target mean file size.
    pub avg_size: Bytes,
    /// Target standard deviation of file sizes.
    pub std_size: Bytes,
}

impl DatasetSpec {
    /// A spec with the given shape parameters.
    pub fn new(name: impl Into<String>, num_files: usize, avg_size: Bytes, std_size: Bytes) -> Self {
        DatasetSpec { name: name.into(), num_files, avg_size, std_size }
    }
}

/// Generate a dataset from a spec and seed. Sizes are lognormal with the
/// spec's mean/std; a final affine correction pins the *sample* mean to the
/// spec mean so Table II totals reproduce closely even for small counts
/// (the large dataset has only 128 files).
pub fn generate(spec: &DatasetSpec, seed: u64) -> Dataset {
    let mut rng = rng::stream(seed, &format!("dataset:{}", spec.name));
    let dist = LogNormal::from_mean_std(spec.avg_size.as_f64(), spec.std_size.as_f64());
    let mut sizes: Vec<f64> = (0..spec.num_files).map(|_| dist.sample(&mut rng).max(1.0)).collect();

    // Affine correction: scale so the sample mean equals the target mean.
    let sample_mean = sizes.iter().sum::<f64>() / sizes.len().max(1) as f64;
    if sample_mean > 0.0 {
        let k = spec.avg_size.as_f64() / sample_mean;
        for s in &mut sizes {
            *s *= k;
        }
    }

    let files = sizes
        .into_iter()
        .enumerate()
        .map(|(i, s)| FileSpec::new(i as u32, Bytes::new(s)))
        .collect();
    Dataset::new(spec.name.clone(), files)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count() {
        let spec = DatasetSpec::new("x", 100, Bytes::from_mb(1.0), Bytes::from_kb(100.0));
        let d = generate(&spec, 1);
        assert_eq!(d.num_files(), 100);
    }

    #[test]
    fn mean_is_pinned() {
        let spec = DatasetSpec::new("x", 128, Bytes::from_mb(222.78), Bytes::from_mb(15.19));
        let d = generate(&spec, 2);
        assert!((d.avg_file_size().as_mb() - 222.78).abs() < 1e-6);
    }

    #[test]
    fn std_is_approximate() {
        let spec = DatasetSpec::new("x", 20_000, Bytes::from_kb(101.92), Bytes::from_kb(29.06));
        let d = generate(&spec, 3);
        let std = d.std_file_size().as_kb();
        assert!((std / 29.06 - 1.0).abs() < 0.1, "std {std} KB");
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = DatasetSpec::new("x", 50, Bytes::from_mb(2.4), Bytes::from_mb(0.27));
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        assert_eq!(a.files, b.files);
        let c = generate(&spec, 8);
        assert_ne!(a.files, c.files);
    }

    #[test]
    fn sizes_are_positive() {
        let spec = DatasetSpec::new("x", 1000, Bytes::from_kb(10.0), Bytes::from_kb(30.0));
        let d = generate(&spec, 4);
        assert!(d.files.iter().all(|f| f.size.as_f64() >= 1.0));
    }
}
