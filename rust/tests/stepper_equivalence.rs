//! The epoch-cached stepper must be indistinguishable — bit-for-bit —
//! from the naive per-tick reference stepper it replaced.
//!
//! Every figure, sweep and fleet number flows through `Simulation::step`,
//! so the fast path is only admissible if duration, moved bytes and the
//! client/server energy books come out with identical bits across
//! testbeds, algorithms, seeds, fleet arrivals/departures and scripted
//! bandwidth events. These tests drive whole sessions through both
//! steppers (`reference_stepper` flag) and compare outcomes exactly.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, FleetPolicyKind};
use greendt::dataset::standard;
use greendt::netsim::BandwidthEvent;
use greendt::sim::fleet::{run_fleet, FleetConfig, FleetOutcome, TenantSpec};
use greendt::sim::session::{run_session, SessionConfig};
use greendt::units::{Rate, SimTime};

fn assert_f64_bits(a: f64, b: f64, what: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: epoch {a} vs reference {b}");
}

fn assert_fleet_outcomes_identical(fast: &FleetOutcome, naive: &FleetOutcome, label: &str) {
    assert_eq!(fast.completed, naive.completed, "{label}: completed");
    assert_f64_bits(
        fast.duration.as_secs(),
        naive.duration.as_secs(),
        &format!("{label}: duration"),
    );
    assert_f64_bits(fast.moved.as_f64(), naive.moved.as_f64(), &format!("{label}: moved"));
    assert_f64_bits(
        fast.client_energy.as_joules(),
        naive.client_energy.as_joules(),
        &format!("{label}: client energy"),
    );
    assert_f64_bits(
        fast.client_package_energy.as_joules(),
        naive.client_package_energy.as_joules(),
        &format!("{label}: client package energy"),
    );
    assert_f64_bits(
        fast.server_energy.as_joules(),
        naive.server_energy.as_joules(),
        &format!("{label}: server energy"),
    );
    assert_eq!(fast.final_active_cores, naive.final_active_cores, "{label}: cores");
    assert_eq!(fast.tenants.len(), naive.tenants.len());
    for (f, n) in fast.tenants.iter().zip(&naive.tenants) {
        let t = format!("{label}/{}", f.name);
        assert_f64_bits(f.moved.as_f64(), n.moved.as_f64(), &format!("{t}: moved"));
        assert_f64_bits(
            f.attributed_energy.as_joules(),
            n.attributed_energy.as_joules(),
            &format!("{t}: attributed energy"),
        );
        assert_f64_bits(
            f.attributed_package_energy.as_joules(),
            n.attributed_package_energy.as_joules(),
            &format!("{t}: attributed package energy"),
        );
        assert_eq!(
            f.finished_at.map(|x| x.as_secs().to_bits()),
            n.finished_at.map(|x| x.as_secs().to_bits()),
            "{t}: finish time"
        );
        assert_eq!(f.peak_channels, n.peak_channels, "{t}: peak channels");
    }
}

#[test]
fn single_sessions_bit_identical_across_grid() {
    // Testbeds × algorithms × seeds: the threshold-FSM tuners (whose
    // timeouts bound epochs), a static baseline (whose epochs span nearly
    // the whole run) and different path/CPU models.
    let algos = [
        AlgorithmKind::MaxThroughput,
        AlgorithmKind::MinEnergy,
        AlgorithmKind::NoTune(8),
        AlgorithmKind::TargetThroughput(Rate::from_mbps(300.0)),
    ];
    for testbed in ["chameleon", "cloudlab", "didclab"] {
        for algo in algos {
            for seed in [3u64, 11] {
                let mk = |reference: bool| {
                    let mut cfg = SessionConfig::new(
                        testbeds::by_name(testbed).unwrap(),
                        standard::medium_dataset(seed),
                        algo,
                    )
                    .with_seed(seed);
                    cfg.reference_stepper = reference;
                    cfg
                };
                let fast = run_session(&mk(false));
                let naive = run_session(&mk(true));
                let label = format!("{testbed}/{}/seed{seed}", algo.id());
                assert!(naive.completed, "{label}: reference run must finish");
                assert_f64_bits(
                    fast.duration.as_secs(),
                    naive.duration.as_secs(),
                    &format!("{label}: duration"),
                );
                assert_f64_bits(
                    fast.moved.as_f64(),
                    naive.moved.as_f64(),
                    &format!("{label}: moved"),
                );
                assert_f64_bits(
                    fast.client_energy.as_joules(),
                    naive.client_energy.as_joules(),
                    &format!("{label}: client energy"),
                );
                assert_f64_bits(
                    fast.server_energy.as_joules(),
                    naive.server_energy.as_joules(),
                    &format!("{label}: server energy"),
                );
                assert_eq!(fast.peak_channels, naive.peak_channels, "{label}: peak ch");
            }
        }
    }
}

fn fleet_cfg(
    policy: FleetPolicyKind,
    seed: u64,
    server_scaling: bool,
    reference: bool,
) -> FleetConfig {
    let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(policy)).with_seed(seed);
    for i in 0..4u64 {
        cfg.tenants.push(
            TenantSpec::new(
                format!("tenant-{i}"),
                standard::medium_dataset(seed + i),
                if i % 2 == 0 { AlgorithmKind::MaxThroughput } else { AlgorithmKind::MinEnergy },
            )
            .arriving_at(SimTime::from_secs(25.0 * i as f64)),
        );
    }
    // A mid-run bandwidth drop (and later recovery) lands inside warm
    // epochs: the budget moves every tick while the stream caches hold.
    cfg.bandwidth_events = vec![
        BandwidthEvent { at: SimTime::from_secs(40.0), mean_fraction: 0.5 },
        BandwidthEvent { at: SimTime::from_secs(120.0), mean_fraction: 0.1 },
    ];
    cfg.server_scaling = server_scaling;
    cfg.reference_stepper = reference;
    cfg
}

#[test]
fn fleet_with_arrivals_and_bandwidth_events_bit_identical() {
    for (policy, server_scaling, seed) in [
        (FleetPolicyKind::MinEnergyFleet, false, 5u64),
        (FleetPolicyKind::FairShare, true, 9),
    ] {
        let fast = run_fleet(&fleet_cfg(policy, seed, server_scaling, false));
        let naive = run_fleet(&fleet_cfg(policy, seed, server_scaling, true));
        assert!(naive.completed, "reference fleet must finish");
        assert_fleet_outcomes_identical(
            &fast,
            &naive,
            &format!("{}/seed{seed}", naive.policy),
        );
    }
}

#[test]
fn empty_dataset_tenant_departs_identically() {
    // A zero-byte tenant is done on arrival: the event-horizon driver
    // must retire it on the same tick the per-tick reference does.
    let mk = |reference: bool| {
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
            .with_seed(2);
        cfg.tenants.push(TenantSpec::new(
            "real",
            standard::medium_dataset(2),
            AlgorithmKind::MaxThroughput,
        ));
        cfg.tenants.push(
            TenantSpec::new(
                "empty",
                greendt::dataset::Dataset::new("empty", Vec::new()),
                AlgorithmKind::NoTune(2),
            )
            .arriving_at(SimTime::from_secs(10.0)),
        );
        cfg.reference_stepper = reference;
        cfg
    };
    let fast = run_fleet(&mk(false));
    let naive = run_fleet(&mk(true));
    assert_fleet_outcomes_identical(&fast, &naive, "empty-tenant");
}
