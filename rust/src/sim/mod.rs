//! The end-to-end simulation: WAN + two end systems + transfer engine.
//!
//! [`Simulation`] advances the whole world one tick at a time;
//! [`session`] runs a complete transfer under a tuning algorithm and
//! produces a [`session::SessionOutcome`] (the numbers the paper's figures
//! plot).

mod engine;
mod telemetry;
pub mod session;

pub use engine::{Simulation, MAX_APP_UTILIZATION};
pub use telemetry::{NetView, Telemetry, TickStats};
