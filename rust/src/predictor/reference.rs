//! Pure-Rust predictor oracle — formula-for-formula mirror of
//! `python/compile/kernels/ref.py` (all arithmetic in f32 so PJRT and
//! oracle agree to float tolerance).

use super::grid::{Candidate, Prediction};
use super::layout as L;

const EPS: f32 = 1e-9;

/// Evaluate one candidate against a state vector.
pub fn predict_one(cand: &Candidate, state: &[f32]) -> Prediction {
    debug_assert_eq!(state.len(), L::STATE_WIDTH);
    let channels = cand.channels;
    let cores = cand.cores;
    let freq = cand.freq_ghz;

    let capacity = state[L::S_CAPACITY_BPS];
    let rtt = state[L::S_RTT_S];
    let avg_win = state[L::S_AVG_WIN_BYTES];
    let knee = state[L::S_KNEE_STREAMS];
    let gamma = state[L::S_OVERLOAD_GAMMA];
    let floor = state[L::S_OVERLOAD_FLOOR];
    let par = state[L::S_PARALLELISM];
    let remaining = state[L::S_REMAINING_BYTES];
    let avg_file = state[L::S_AVG_FILE_BYTES];
    let pp = state[L::S_PP_LEVEL];
    let cpb = state[L::S_CYCLES_PER_BYTE];
    let cpr = state[L::S_CYCLES_PER_REQ];
    let cps = state[L::S_CYCLES_PER_STREAM];
    let max_util = state[L::S_MAX_APP_UTIL];

    // Network: window-limited aggregate with overload penalty.
    let streams = channels * par;
    let win_rate = avg_win / rtt.max(EPS);
    let over = (streams - knee).max(0.0) / knee.max(EPS);
    let penalty = (1.0 / (1.0 + gamma * over)).max(floor);
    let net = (streams * win_rate).min(capacity * penalty);

    // Pipelining pacing.
    let r_chan = net / channels.max(EPS);
    let xfer = avg_file / r_chan.max(EPS);
    let paced = xfer.max(rtt / pp.max(1.0));
    let eff = xfer / paced.max(EPS);
    let net_eff = net * eff;

    // CPU ceiling.
    let cap_cycles = cores * freq * 1e9 * max_util;
    let req_rate_net = net_eff / avg_file.max(EPS);
    let overhead = req_rate_net * cpr + streams * cps;
    let cpu_bytes = (cap_cycles - overhead).max(0.0) / cpb.max(EPS);
    let tput = net_eff.min(cpu_bytes);

    // Utilization at the achieved rate.
    let req_rate = tput / avg_file.max(EPS);
    let demand = tput * cpb + req_rate * cpr + streams * cps;
    let cap_full = cores * freq * 1e9;
    let load = demand / cap_full.max(EPS);
    let util = load.clamp(0.0, 1.0);

    // Package power.
    let v_min = state[L::S_V_MIN];
    let v_max = state[L::S_V_MAX];
    let f_min = state[L::S_F_MIN_GHZ];
    let f_max = state[L::S_F_MAX_GHZ];
    let t = ((freq - f_min) / (f_max - f_min).max(EPS)).clamp(0.0, 1.0);
    let v = v_min + (v_max - v_min) * t;
    let per_core_idle = state[L::S_CORE_IDLE_BASE_W] + state[L::S_CORE_IDLE_PER_GHZ_W] * freq;
    let per_core_dyn = util * state[L::S_DYN_KAPPA] * v * v * freq;
    let dram = state[L::S_DRAM_W_PER_GBS] * tput / 1e9;
    let power = state[L::S_PKG_STATIC_W] + cores * (per_core_idle + per_core_dyn) + dram;

    let feasible = tput > EPS;
    let energy = if feasible {
        power * remaining / tput.max(EPS)
    } else {
        L::INFEASIBLE_ENERGY
    };

    Prediction {
        tput_bps: if feasible { tput as f64 } else { 0.0 },
        power_w: power as f64,
        energy_j: energy as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::grid::demo_state;

    fn cand(ch: f32, cores: f32, f: f32) -> Candidate {
        Candidate { channels: ch, cores, freq_ghz: f }
    }

    #[test]
    fn zero_cores_is_infeasible() {
        let p = predict_one(&cand(4.0, 0.0, 0.0), &demo_state());
        assert_eq!(p.tput_bps, 0.0);
        assert!(p.energy_j >= 1e29);
    }

    #[test]
    fn throughput_monotone_in_cores_until_network_bound() {
        let s = demo_state();
        let mut prev = 0.0;
        for cores in 1..=10 {
            let p = predict_one(&cand(6.0, cores as f32, 2.0), &s);
            assert!(p.tput_bps >= prev - 1e-3, "cores {cores}");
            prev = p.tput_bps;
        }
    }

    #[test]
    fn power_monotone_in_frequency() {
        let s = demo_state();
        let mut prev = 0.0;
        for i in 0..12 {
            let f = 1.2 + 0.2 * i as f32;
            let p = predict_one(&cand(6.0, 4.0, f), &s);
            assert!(p.power_w > prev, "f {f}");
            prev = p.power_w;
        }
    }

    #[test]
    fn network_bound_energy_favors_low_frequency() {
        // On the CloudLab-like demo state, 2 cores cover 1 Gbps easily:
        // the energy-optimal frequency is at/near the bottom of the ladder.
        let s = demo_state();
        let mut best = (f64::MAX, 0.0f32);
        for i in 0..12 {
            let f = 1.2 + 0.2 * i as f32;
            let p = predict_one(&cand(6.0, 2.0, f), &s);
            if p.energy_j < best.0 {
                best = (p.energy_j, f);
            }
        }
        assert!(best.1 <= 1.6, "best frequency {} GHz", best.1);
    }

    #[test]
    fn more_channels_saturate_then_cost_power() {
        let s = demo_state();
        let p4 = predict_one(&cand(4.0, 4.0, 2.0), &s);
        let p12 = predict_one(&cand(12.0, 4.0, 2.0), &s);
        assert!(p12.tput_bps <= p4.tput_bps * 1.25, "saturation");
        assert!(p12.power_w > p4.power_w, "streams cost cycles -> power");
    }
}
