//! The end-to-end simulation: WAN + two end systems + transfer engines.
//!
//! [`Simulation`] advances the whole world — one shared client [`Host`]
//! running N tenant [`SessionSlot`]s — one tick at a time; [`session`]
//! runs a single complete transfer under a tuning algorithm and produces
//! a [`session::SessionOutcome`] (the numbers the paper's figures plot);
//! [`fleet`] drives N concurrent sessions with cross-session arbitration
//! and per-tenant accounting. The session driver is the N=1 special case
//! of the fleet driver.

mod engine;
mod host;
mod telemetry;
pub mod fleet;
pub mod session;

pub use engine::{SessionSlot, Simulation, TuneCtx};
pub use host::{FleetView, Host, HostTick, MAX_APP_UTILIZATION};
pub use telemetry::{NetView, Telemetry, TickStats};
