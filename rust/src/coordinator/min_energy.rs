//! Algorithm 4 — the Minimum Energy (ME) tuning algorithm.
//!
//! Feedback signal: the estimated total transfer energy
//! `E_last + E_future`, where `E_last` is the energy measured over the
//! last interval and `E_future = avgPower × remainTime` is the projection
//! to completion. Channels are added only when that estimate *drops*
//! (i.e. the added concurrency pays for its own power), and the
//! Warning/Recovery states distinguish "too many channels" from "the
//! network itself got slower" exactly as Figure 1 prescribes.

use super::algorithm::{make_governor, Algorithm, InitPlan};
use super::fsm::{self, Action, Feedback, FsmState};
use super::heuristic;
use super::load_control::Governor;
use super::sla::SlaPolicy;
use super::slow_start::SlowStart;
use crate::config::experiment::TunerParams;
use crate::config::Testbed;
use crate::dataset::Dataset;
use crate::sim::{Telemetry, TuneCtx};
use crate::transfer::TransferEngine;
use crate::units::SimDuration;

#[derive(Debug)]
/// Algorithm 4 — Minimum Energy (ME).
pub struct MinEnergy {
    params: TunerParams,
    governor: Box<dyn Governor>,
    state: FsmState,
    slow_start: Option<SlowStart>,
    /// Previous total-energy estimate (`E_past`).
    e_past: Option<f64>,
    /// The algorithm's intended channel count (`numCh`).
    num_ch: u32,
}

impl MinEnergy {
    /// Fresh ME instance with the given tuner knobs.
    pub fn new(params: TunerParams) -> Self {
        MinEnergy {
            governor: make_governor(
                params.governor,
                &params,
                crate::predictor::PredictMode::MinEnergy,
            ),
            params,
            state: FsmState::SlowStart,
            slow_start: None,
            e_past: None,
            num_ch: 1,
        }
    }

    fn apply_channels(&mut self, engine: &mut TransferEngine) {
        // Lines 28–32: updateWeights; ccLevel_i = weight_i * numCh;
        // updateChannels — every timeout, so finishing partitions donate
        // their channels to slower ones.
        engine.update_weights();
        engine.set_num_channels(self.num_ch);
    }
}

impl Algorithm for MinEnergy {
    fn name(&self) -> &'static str {
        "ME"
    }

    fn timeout(&self) -> SimDuration {
        self.params.timeout
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        let init = heuristic::initialize(testbed, dataset, SlaPolicy::Energy);
        self.num_ch = init.num_channels;
        self.slow_start = Some(SlowStart::new(
            testbed.link.capacity,
            self.params.max_ch,
            self.params.slow_start_rounds,
        ));
        self.state = FsmState::SlowStart;
        // Without the load-control module the OS owns the CPU: all cores
        // online, ondemand frequency (Figure 4's "w/o scaling" ablation).
        let client_cpu = if self.params.governor == crate::config::experiment::GovernorKind::Os {
            crate::cpusim::CpuState::performance(testbed.client_cpu.clone())
        } else {
            init.client_cpu
        };
        InitPlan::new(init.partitions, init.num_channels, client_cpu)
    }

    fn fsm_label(&self) -> &'static str {
        self.state.label()
    }

    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx) {
        // Algorithm 3 runs at every timeout regardless of FSM state.
        self.governor.control(telemetry, ctx.client);

        // Slow Start phase (line 1).
        if let Some(ss) = &mut self.slow_start {
            let done = ss.on_timeout(telemetry, ctx.engine);
            self.num_ch = ctx.engine.num_channels().max(1);
            if done {
                self.slow_start = None;
                self.state = FsmState::Increase;
                // Seed E_past from the first measurement.
                let e_total = telemetry.interval_energy.as_joules()
                    + telemetry.predicted_future_energy().as_joules();
                self.e_past = Some(e_total);
            }
            return;
        }

        // Lines 3–6: energy measurement + projection.
        let e_total = telemetry.interval_energy.as_joules()
            + telemetry.predicted_future_energy().as_joules();
        let e_past = self.e_past.unwrap_or(e_total);

        let feedback = fsm::classify_energy(e_total, e_past, self.params.alpha, self.params.beta);
        let (next, action) = fsm::step(self.state, feedback);

        match action {
            Action::Grow | Action::Restore => {
                self.num_ch = (self.num_ch + self.params.delta_ch).min(self.params.max_ch);
            }
            Action::Shrink => {
                self.num_ch = self.num_ch.saturating_sub(self.params.delta_ch).max(1);
            }
            Action::Hold => {}
        }
        self.state = next;
        // Track the declining remaining-energy trend: E_past follows the
        // latest estimate so the comparison stays local in time.
        self.e_past = Some(e_total);

        self.apply_channels(ctx.engine);
    }
}

impl MinEnergy {
    /// Warm start (historical-log learning): drop the pending Slow Start
    /// phase and enter the steady-state FSM directly at `num_ch`
    /// channels, as if the probe had already converged there. Call after
    /// [`Algorithm::init`]; every later timeout runs the unchanged
    /// Algorithm 4 loop, so a stale warm point is corrected at runtime.
    pub fn skip_slow_start(&mut self, num_ch: u32) {
        self.slow_start = None;
        self.state = FsmState::Increase;
        self.e_past = None;
        self.num_ch = num_ch.max(1);
    }

    /// Observable state for tests and the CLI's `--trace` output.
    pub fn fsm_state(&self) -> FsmState {
        self.state
    }

    /// Channel count the algorithm currently wants.
    pub fn num_channels(&self) -> u32 {
        self.num_ch
    }

    /// Expose the raw feedback classification (test hook).
    pub fn classify(&self, e_total: f64, e_past: f64) -> Feedback {
        fsm::classify_energy(e_total, e_past, self.params.alpha, self.params.beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::standard;
    use crate::sim::session::{run_session, SessionConfig};
    use crate::units::{Bytes, Energy, Power, Rate, SimTime};

    fn tel(energy_j: f64, power_w: f64, tput_mbps: f64, load: f64) -> Telemetry {
        Telemetry {
            now: SimTime::from_secs(10.0),
            avg_throughput: Rate::from_mbps(tput_mbps),
            interval_energy: Energy::from_joules(energy_j),
            avg_power: Power::from_watts(power_w),
            cpu_load: load,
            remaining: Bytes::from_gb(1.0),
            total: Bytes::from_gb(2.0),
            elapsed: SimDuration::from_secs(10.0),
            num_channels: 4,
            open_streams: 8,
            net: Default::default(),
        }
    }

    #[test]
    fn init_uses_energy_sla() {
        let mut me = MinEnergy::new(TunerParams::default());
        let plan = me.init(&testbeds::didclab(), &standard::medium_dataset(1));
        assert_eq!(plan.client_cpu.active_cores(), 1);
        assert!(plan.client_cpu.at_min_freq());
        assert!(plan.num_channels >= 1);
        assert_eq!(me.fsm_state(), FsmState::SlowStart);
    }

    #[test]
    fn energy_drop_grows_channels() {
        let params = TunerParams { slow_start_rounds: 1, ..TunerParams::default() };
        let mut me = MinEnergy::new(params);
        assert_eq!(me.classify(800.0, 1000.0), Feedback::Positive);
        assert_eq!(me.classify(1100.0, 1000.0), Feedback::Negative);
        assert_eq!(me.classify(1000.0, 1000.0), Feedback::Neutral);
    }

    #[test]
    fn full_session_completes_on_didclab_medium() {
        let cfg = SessionConfig::new(
            testbeds::didclab(),
            standard::medium_dataset(2),
            crate::coordinator::AlgorithmKind::MinEnergy,
        );
        let out = run_session(&cfg);
        assert!(out.completed, "ME session must finish");
        assert!(out.avg_throughput.as_mbps() > 100.0, "tput {}", out.avg_throughput);
        assert!(out.client_energy.as_joules() > 0.0);
    }

    #[test]
    fn me_scales_down_cpu_when_network_bound() {
        // On a 1 Gbps link the client CPU is mostly idle: after a few
        // timeouts ME must be at (or near) the minimum setting.
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::large_dataset(3),
            crate::coordinator::AlgorithmKind::MinEnergy,
        );
        let out = run_session(&cfg);
        assert!(out.completed);
        assert!(
            out.final_active_cores <= 2,
            "network-bound ME should shed cores, got {}",
            out.final_active_cores
        );
    }

    #[test]
    fn warning_recovery_sequence_shrinks_then_restores() {
        let params =
            TunerParams { slow_start_rounds: 1, governor: crate::config::experiment::GovernorKind::Os, ..TunerParams::default() };
        let mut me = MinEnergy::new(params);
        me.state = FsmState::Increase;
        me.e_past = Some(1000.0);
        me.num_ch = 10;
        // Simulate the pure FSM by feeding classifications directly.
        let f1 = me.classify(1200.0, 1000.0);
        let (s1, a1) = fsm::step(me.state, f1);
        assert_eq!((s1, a1), (FsmState::Warning, Action::Hold));
        let f2 = me.classify(1400.0, 1200.0);
        let (s2, a2) = fsm::step(s1, f2);
        assert_eq!((s2, a2), (FsmState::Recovery, Action::Shrink));
        let f3 = me.classify(1100.0, 1400.0);
        let (s3, a3) = fsm::step(s2, f3);
        assert_eq!((s3, a3), (FsmState::Increase, Action::Hold));
    }

    #[test]
    fn governor_reacts_to_synthetic_load() {
        let mut me = MinEnergy::new(TunerParams { slow_start_rounds: 1, ..Default::default() });
        let tb = testbeds::chameleon();
        let plan = me.init(&tb, &standard::medium_dataset(1));
        let parts = plan.partitions.clone();
        let mut engine = crate::transfer::TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(plan.num_channels);
        let mut sim = crate::sim::Simulation::new(
            &tb,
            engine,
            plan.client_cpu,
            SimDuration::from_millis(100.0),
            1,
        );
        let cores0 = sim.host.client.active_cores();
        me.slow_start = None; // jump straight to Increase for this test
        me.state = FsmState::Increase;
        me.on_timeout(&tel(100.0, 30.0, 900.0, 0.97), &mut sim.tune_ctx(0));
        assert!(sim.host.client.active_cores() > cores0, "high load must add capacity");
    }
}
