//! Fleet rebalancer: rescue a session stranded on an expensive host.
//!
//!     cargo run --release --example fleet_rebalance
//!
//! A hot-spot script: a short session takes the efficient host's only
//! slot, so the long session arriving moments later is admitted on the
//! legacy (Bloomfield, wall-metered) host — the placement the dispatcher
//! would never pick on an empty fleet. Mid-run the fleet power cap
//! tightens. Three rebalance policies are compared:
//!
//! * `off`           — the stranded session serves out on the legacy host;
//! * `cap-pressure`  — the squeeze forces a move as soon as the efficient
//!                     slot frees (sheds projected watts to satisfy the cap);
//! * `marginal-delta`— the move fires on energy grounds alone, cap or not.
//!
//! Every move pays a real price: streams drain, a handoff delay passes,
//! and the remaining bytes re-enter slow start on the target.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::dataset::standard;
use greendt::metrics::Table;
use greendt::rebalance::{RebalanceConfig, RebalancePolicyKind};
use greendt::sim::dispatcher::{
    run_dispatcher, DispatchOutcome, DispatcherConfig, HostSpec, SessionSpec,
};
use greendt::units::{Power, Rate, SimTime};

fn base_cfg() -> DispatcherConfig {
    let hosts = vec![
        HostSpec::new("efficient", testbeds::cloudlab()).with_max_sessions(1),
        HostSpec::new("legacy", testbeds::didclab()).with_max_sessions(1),
    ];
    let sessions = vec![
        SessionSpec::new("short", standard::medium_dataset(11), AlgorithmKind::MaxThroughput),
        SessionSpec::new("long", standard::large_dataset(12), AlgorithmKind::MaxThroughput)
            .arriving_at(SimTime::from_secs(5.0)),
    ];
    DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(42)
}

fn run_policy(policy: RebalancePolicyKind, cap: Power) -> DispatchOutcome {
    let mut cfg = base_cfg().with_cap_event(SimTime::from_secs(50.0), Some(cap));
    cfg.rebalance = RebalanceConfig::new(policy);
    run_dispatcher(&cfg)
}

fn main() {
    println!("== fleet_rebalance: a stranded session, three rebalance policies ==\n");

    // Size the squeeze from the fleet's own projections: between the
    // "long stays on legacy" and "long moved to efficient" steady states.
    let probe = run_dispatcher(&base_cfg());
    assert!(probe.fleet.completed, "probe run must finish");
    let first = &probe.decisions[0];
    let eff = first.scores.iter().find(|s| s.host == "efficient").unwrap();
    let leg = first.scores.iter().find(|s| s.host == "legacy").unwrap();
    let cap = Power::from_watts(
        0.5 * (eff.current_power_w + leg.projected_power_w)
            + 0.5 * (eff.projected_power_w + leg.current_power_w),
    );
    println!(
        "power cap tightens to {cap} at t=50 s (stranded projection {:.1} W, \
         post-move projection {:.1} W)\n",
        eff.current_power_w + leg.projected_power_w,
        eff.projected_power_w + leg.current_power_w,
    );

    let mut table = Table::new(
        "rebalance policies compared",
        &["rebalance", "fleet energy", "makespan", "agg goodput", "moves", "on legacy"],
    );
    for policy in [
        RebalancePolicyKind::Off,
        RebalancePolicyKind::CapPressure,
        RebalancePolicyKind::MarginalEnergyDelta,
    ] {
        let out = run_policy(policy, cap);
        let fleet = &out.fleet;
        assert!(fleet.completed, "{} run did not finish", policy.id());
        let legacy_bytes: f64 = fleet
            .tenants
            .iter()
            .filter(|t| t.host == "legacy")
            .map(|t| t.moved.as_f64())
            .sum();
        table.push_row(vec![
            policy.id().to_string(),
            format!("{}", fleet.client_energy),
            format!("{}", fleet.duration),
            format!("{}", Rate::average(fleet.moved, fleet.duration)),
            out.migrations.len().to_string(),
            format!("{:.1} GB", legacy_bytes / 1e9),
        ]);
        for m in &out.migrations {
            println!(
                "{}: t={:.1}s  {} {} -> {} ({:.1} GB done, {:.1} GB re-admitted, \
                 drain {:.0} s, est. saving {:.0} J vs cost {:.0} J)",
                policy.id(),
                m.t_secs,
                m.session,
                m.from,
                m.to,
                m.moved_bytes / 1e9,
                m.remaining_bytes / 1e9,
                m.drain_secs,
                m.est_benefit_j,
                m.est_cost_j,
            );
        }
    }
    println!("\n{}", table.to_markdown());
    println!(
        "a migration is never free — the drain delay and slow-start re-ramp are\n\
         simulated — but serving the remaining bytes on the efficient host repays\n\
         the move many times over, and the cap squeeze is satisfied by shedding\n\
         the legacy host's marginal draw instead of queueing future work."
    );
}
