//! Core transfer bookkeeping and per-tick data movement.

use super::Channel;
use crate::dataset::Partition;
use crate::netsim::{Link, StreamState};
use crate::units::{Bytes, Rate, Rtt, SimDuration};

/// Per-partition progress the tuning algorithms observe.
#[derive(Debug, Clone)]
pub struct PartitionProgress {
    /// Partition band name (`"small"` / `"medium"` / `"large"`).
    pub name: &'static str,
    /// Per-partition pipelining level (requests in flight back-to-back).
    pub pp_level: u32,
    /// Streams per channel for this partition.
    pub parallelism: u32,
    /// Average file size (drives request-rate and pipelining overhead).
    pub avg_file_size: Bytes,
    /// Bytes the partition started with.
    pub total: Bytes,
    /// Bytes still to move.
    pub remaining: Bytes,
    /// Channel-distribution weight (recomputed by `update_weights`).
    pub weight: f64,
    /// Channels currently assigned.
    pub cc_level: u32,
    /// Extra round-trips charged per file *before* the pipelined request
    /// (0 for persistent connections; 2 for tools like wget that do a TCP
    /// handshake + sequential HTTP request per file).
    pub handshake_rtts: f64,
}

impl PartitionProgress {
    /// True once the partition has no bytes left.
    pub fn done(&self) -> bool {
        self.remaining.is_zero()
    }
}

/// What moved during one tick (feeds CPU/power models and metrics).
#[derive(Debug, Clone, Copy, Default)]
pub struct TickOutput {
    /// Application goodput achieved this tick.
    pub goodput: Rate,
    /// Bytes moved this tick.
    pub moved: Bytes,
    /// File/chunk requests issued per second (CPU protocol work).
    pub requests_per_sec: f64,
    /// TCP streams currently open.
    pub open_streams: usize,
}

/// The transfer engine: owns partitions + channels and implements
/// channel (re)distribution and per-tick byte movement.
#[derive(Debug, Clone)]
pub struct TransferEngine {
    partitions: Vec<PartitionProgress>,
    channels: Vec<Channel>,
    avg_win: Bytes,
    /// Streams that fill the pipe (`LinkParams::knee_streams`); used to
    /// derate per-channel parallelism as the channel count grows.
    knee_streams: f64,
    /// Hard ceiling on the channel count (a fleet policy's per-session
    /// budget). `None` in single-session worlds.
    channel_cap: Option<u32>,
    /// Tick-loop scratch (stream snapshot + per-stream and per-channel
    /// rates), reused across ticks to keep the hot path allocation-free.
    scratch_streams: Vec<StreamState>,
    scratch_rates: Vec<f64>,
    scratch_channel_rates: Vec<f64>,
    /// Monotone counter bumped on every structural mutation — channel
    /// open/close/reassignment and per-partition knob changes (pp,
    /// parallelism, handshake RTTs). The epoch cache in [`crate::sim`]
    /// watches it to learn when a staged stream snapshot goes stale.
    generation: u64,
    /// Competing-flow mode: streams run AIMD (additive increase per RTT,
    /// multiplicative decrease on the allocation clip) instead of holding
    /// still after slow start. See [`Self::set_aimd`].
    aimd: bool,
    /// Seconds until the next multiplicative decrease is allowed —
    /// classic TCP halves at most once per RTT, not once per ACK (tick).
    aimd_cooldown_s: f64,
    /// Total multiplicative decreases taken (per stream, across the
    /// engine's lifetime). Observability only — the fleet metrics
    /// registry reads it; nothing on the decision path does.
    aimd_backoffs: u64,
    /// BBR-like variant (feature `bbr`): drain-to-delivered-BDP instead
    /// of halving, 25%-per-RTT probing instead of one MSS per RTT.
    #[cfg(feature = "bbr")]
    bbr: bool,
}

impl TransferEngine {
    /// Build from Algorithm 1's partitions with no parallelism derating
    /// (tests, baselines).
    pub fn new(partitions: &[Partition], avg_win: Bytes) -> Self {
        Self::with_knee(partitions, avg_win, f64::INFINITY)
    }

    /// Build with pipe-aware parallelism: a channel opens
    /// `min(partition.parallelism, ceil(knee / total_channels))` streams —
    /// parallel streams help exactly while the pipe is not already filled
    /// by concurrency (§II: parallelism vs concurrency trade).
    pub fn with_knee(partitions: &[Partition], avg_win: Bytes, knee_streams: f64) -> Self {
        let progress = partitions
            .iter()
            .map(|p| {
                let st = p.stats();
                PartitionProgress {
                    name: p.name,
                    pp_level: p.pp_level,
                    parallelism: p.parallelism,
                    avg_file_size: st.avg_file_size,
                    total: st.total_size,
                    remaining: st.total_size,
                    weight: 0.0,
                    cc_level: 0,
                    handshake_rtts: 0.0,
                }
            })
            .collect::<Vec<_>>();
        let mut engine = TransferEngine {
            partitions: progress,
            channels: Vec::new(),
            avg_win,
            knee_streams,
            channel_cap: None,
            scratch_streams: Vec::new(),
            scratch_rates: Vec::new(),
            scratch_channel_rates: Vec::new(),
            generation: 0,
            aimd: false,
            aimd_cooldown_s: 0.0,
            aimd_backoffs: 0,
            #[cfg(feature = "bbr")]
            bbr: false,
        };
        engine.update_weights();
        engine
    }

    /// Switch the per-stream congestion model between the default
    /// slow-start-then-hold FSM (the paper's loss-managed testbeds, where
    /// the overload penalty at the link absorbs contention) and AIMD
    /// competing-flow dynamics: additive increase of one MSS per RTT
    /// while the allocation grants the full window demand, multiplicative
    /// decrease (at most once per RTT) when the grant falls short. The
    /// grant is the stream's fair share of the *penalty-scaled* budget
    /// ([`crate::netsim::AllocCache`]), so past the stream-count knee the
    /// overload penalty is exactly what drives the backoff.
    ///
    /// Structural (bumps the generation): AIMD windows move on every
    /// tick, so the epoch cache must never treat the snapshot as warm —
    /// [`Self::stage_streams`] reports every AIMD stream as unstable.
    pub fn set_aimd(&mut self, on: bool) {
        if self.aimd != on {
            self.aimd = on;
            self.aimd_cooldown_s = 0.0;
            self.generation += 1;
        }
    }

    /// True when AIMD competing-flow dynamics are active.
    pub fn aimd_enabled(&self) -> bool {
        self.aimd
    }

    /// Total multiplicative decreases this engine's streams have taken
    /// (0 unless AIMD/BBR is on). Pure read; feeds the `aimd.backoffs`
    /// fleet counter.
    pub fn aimd_backoffs(&self) -> u64 {
        self.aimd_backoffs
    }

    /// Use the BBR-like congestion response instead of AIMD halving
    /// (requires [`Self::set_aimd`] to be on for any effect).
    #[cfg(feature = "bbr")]
    pub fn set_bbr(&mut self, on: bool) {
        if self.bbr != on {
            self.bbr = on;
            self.generation += 1;
        }
    }

    /// Structural-mutation counter (see the field doc). Equal generations
    /// guarantee the channel/stream structure and per-partition transfer
    /// knobs are unchanged; window state is tracked separately by the
    /// stager because slow-start growth mutates windows without touching
    /// structure.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Streams a freshly opened channel for partition `i` should carry,
    /// given the current total channel budget.
    fn effective_parallelism(&self, partition: usize, total_channels: u32) -> u32 {
        let p = self.partitions[partition].parallelism;
        if !self.knee_streams.is_finite() {
            return p;
        }
        let room = (self.knee_streams / total_channels.max(1) as f64).ceil() as u32;
        p.min(room.max(1))
    }

    /// Per-partition progress view.
    pub fn partitions(&self) -> &[PartitionProgress] {
        &self.partitions
    }

    /// The open channels.
    pub fn channels(&self) -> &[Channel] {
        &self.channels
    }

    /// Open channel count.
    pub fn num_channels(&self) -> u32 {
        self.channels.len() as u32
    }

    /// Total TCP streams across open channels.
    pub fn open_streams(&self) -> usize {
        self.channels.iter().map(|c| c.num_streams()).sum()
    }

    /// Bytes still to move across all partitions.
    pub fn remaining(&self) -> Bytes {
        self.partitions.iter().map(|p| p.remaining).sum()
    }

    /// Total session size.
    pub fn total(&self) -> Bytes {
        self.partitions.iter().map(|p| p.total).sum()
    }

    /// True once every partition is finished.
    pub fn is_done(&self) -> bool {
        self.partitions.iter().all(|p| p.done())
    }

    /// Override a partition's pipelining level (exposed for baselines that
    /// tune statically).
    pub fn set_pp_level(&mut self, partition: usize, pp: u32) {
        self.partitions[partition].pp_level = pp.max(1);
        self.generation += 1;
    }

    /// Override a partition's parallelism (affects newly opened channels).
    pub fn set_parallelism(&mut self, partition: usize, p: u32) {
        self.partitions[partition].parallelism = p.max(1);
        self.generation += 1;
    }

    /// Charge `rtts` extra round-trips per file (non-persistent tools).
    pub fn set_handshake_rtts(&mut self, partition: usize, rtts: f64) {
        self.partitions[partition].handshake_rtts = rtts.max(0.0);
        self.generation += 1;
    }

    /// Close every channel without touching partition progress — the
    /// preemption/migration path: the remaining bytes stay exactly where
    /// they are (the engine is *not* done), but no stream remains open,
    /// so the session stops consuming link and CPU capacity immediately.
    /// Structural (bumps the generation), so the epoch cache restages.
    pub fn drain_channels(&mut self) {
        if self.channels.is_empty() {
            return;
        }
        self.channels.clear();
        for p in &mut self.partitions {
            p.cc_level = 0;
        }
        self.generation += 1;
    }

    /// Cap the total channel count (a fleet policy's per-session budget).
    /// Every later [`Self::set_num_channels`] clamps to this ceiling, so a
    /// tuning algorithm asking for more does not churn channels open and
    /// closed. Does not shrink already-open channels by itself.
    pub fn set_channel_cap(&mut self, cap: Option<u32>) {
        self.channel_cap = cap.map(|c| c.max(1));
    }

    /// The active per-session channel budget, if any.
    pub fn channel_cap(&self) -> Option<u32> {
        self.channel_cap
    }

    /// `updateWeights()` (Algs. 2/4/5/6): weight_i = remaining_i / Σ remaining.
    ///
    /// Slower (larger-remainder) partitions get more channels so all
    /// partitions finish at about the same time (§IV-A last paragraph).
    pub fn update_weights(&mut self) {
        let total_remaining: f64 = self.partitions.iter().map(|p| p.remaining.as_f64()).sum();
        for p in &mut self.partitions {
            p.weight = if total_remaining <= 0.0 {
                0.0
            } else {
                p.remaining.as_f64() / total_remaining
            };
        }
    }

    /// `updateChannels()`: redistribute `num_channels` total channels over
    /// partitions proportionally to weight (ccLevel_i = weight_i × numCh).
    ///
    /// When the budget covers every unfinished partition, each gets at
    /// least one channel; when it does not (low-target SLAs run with very
    /// few channels), the highest-weight partitions get the channels and
    /// the rest wait — they pick channels up at later redistributions as
    /// weights shift. Channels are reused where possible: surplus channels
    /// close newest-first (preserving warm streams), deficits open cold
    /// channels (slow start — this is why over-eager growth costs).
    pub fn set_num_channels(&mut self, num_channels: u32) {
        // A redistribution may open, close or retarget channels; treat
        // every call as structural (a spurious bump only costs one
        // restage, and calls happen at tuning timeouts, not per tick).
        self.generation += 1;
        let unfinished: Vec<usize> =
            (0..self.partitions.len()).filter(|&i| !self.partitions[i].done()).collect();
        if unfinished.is_empty() {
            self.channels.clear();
            for p in &mut self.partitions {
                p.cc_level = 0;
            }
            return;
        }
        let n = match self.channel_cap {
            Some(cap) => num_channels.max(1).min(cap),
            None => num_channels.max(1),
        };

        let weights: Vec<f64> = unfinished.iter().map(|&i| self.partitions[i].weight).collect();
        let wsum: f64 = weights.iter().sum();
        let norm: Vec<f64> = if wsum <= 0.0 {
            vec![1.0 / unfinished.len() as f64; unfinished.len()]
        } else {
            weights.iter().map(|w| w / wsum).collect()
        };

        let mut alloc: Vec<u32>;
        if n < unfinished.len() as u32 {
            // Budget below one-per-partition: give the n highest-weight
            // partitions one channel each.
            let mut order: Vec<usize> = (0..unfinished.len()).collect();
            order.sort_by(|&a, &b| norm[b].partial_cmp(&norm[a]).unwrap());
            alloc = vec![0; unfinished.len()];
            for &k in order.iter().take(n as usize) {
                alloc[k] = 1;
            }
        } else {
            // Largest-remainder rounding of weight_i * n, floored at 1.
            alloc = norm.iter().map(|w| (w * n as f64).floor() as u32).collect();
            for a in &mut alloc {
                if *a == 0 {
                    *a = 1;
                }
            }
            let mut assigned: u32 = alloc.iter().sum();
            while assigned > n {
                // Remove from the partition with the most channels (> 1).
                if let Some(k) =
                    (0..alloc.len()).filter(|&k| alloc[k] > 1).max_by_key(|&k| alloc[k])
                {
                    alloc[k] -= 1;
                    assigned -= 1;
                } else {
                    break; // all at the floor; accept the overshoot
                }
            }
            let mut frac: Vec<(usize, f64)> = norm
                .iter()
                .enumerate()
                .map(|(k, w)| (k, w * n as f64 - (w * n as f64).floor()))
                .collect();
            frac.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let mut fi = 0;
            while assigned < n {
                let k = frac[fi % frac.len()].0;
                alloc[k] += 1;
                assigned += 1;
                fi += 1;
            }
        }

        // Reconcile the live channel list with the new allocation.
        for (k, &i) in unfinished.iter().enumerate() {
            self.partitions[i].cc_level = alloc[k];
            let current =
                self.channels.iter().filter(|c| c.partition == i).count() as u32;
            if current > alloc[k] {
                // Close surplus channels, newest first.
                let mut to_close = (current - alloc[k]) as usize;
                let mut j = self.channels.len();
                while to_close > 0 && j > 0 {
                    j -= 1;
                    if self.channels[j].partition == i {
                        self.channels.remove(j);
                        to_close -= 1;
                    }
                }
            } else {
                let p = self.effective_parallelism(i, n);
                for _ in current..alloc[k] {
                    self.channels.push(Channel::open(i, p, self.avg_win));
                }
            }
        }
        // Drop channels pointing at finished partitions.
        let parts = &self.partitions;
        self.channels.retain(|c| !parts[c.partition].done());
    }

    /// Advance one tick: allocate network goodput to streams, charge
    /// pipelining overhead, optionally cap by CPU capacity, move bytes.
    ///
    /// `cpu_cap_bytes_per_sec` is the end-system ceiling (min of client and
    /// server achievable throughput); pass `f64::INFINITY` to disable.
    ///
    /// This is the single-engine path; a multi-tenant world instead calls
    /// [`Self::stage_streams`] on every engine, allocates the bottleneck
    /// over the pooled streams once, and hands each engine its slice via
    /// [`Self::apply_shared_rates`].
    pub fn tick(
        &mut self,
        link: &Link,
        dt: SimDuration,
        cpu_cap_bytes_per_sec: f64,
    ) -> TickOutput {
        if self.channels.is_empty() || dt.is_zero() {
            return TickOutput::default();
        }

        // 1. Advance stream windows, then allocate the bottleneck
        //    (scratch buffers reused across ticks; no allocation here).
        let mut flat = std::mem::take(&mut self.scratch_streams);
        flat.clear();
        self.stage_streams(dt, link.params.rtt, &mut flat);
        let mut rates = std::mem::take(&mut self.scratch_rates);
        crate::netsim::share_goodput_into(link, &flat, &mut rates);

        let out = self.apply_shared_rates(&rates, link, dt, cpu_cap_bytes_per_sec);
        self.scratch_streams = flat;
        self.scratch_rates = rates;
        out
    }

    /// Stage one of a tick: advance every stream's congestion window by
    /// `dt` and append snapshots to `flat` (a buffer that may already hold
    /// other tenants' streams). Returns how many staged streams are still
    /// *unstable* — zero means the snapshot stays valid until the next
    /// structural mutation (see [`Self::generation`]), which is what lets
    /// the epoch-cached stepper skip restaging entirely. With the default
    /// FSM only slow-start streams are unstable; under AIMD
    /// ([`Self::set_aimd`]) every stream is, because additive increase
    /// and backoff move windows on arbitrary later ticks, so a warm epoch
    /// would replay stale rates.
    ///
    /// The slow-start growth factor is computed once per call
    /// ([`StreamState::growth_factor`]) instead of one `powf` per stream;
    /// `StreamState::tick_cached` is bit-identical to `StreamState::tick`.
    pub fn stage_streams(
        &mut self,
        dt: SimDuration,
        rtt: Rtt,
        flat: &mut Vec<StreamState>,
    ) -> usize {
        let growth = StreamState::growth_factor(dt, rtt);
        let start = flat.len();
        let mut in_slow_start = 0;
        for c in &mut self.channels {
            for s in &mut c.streams {
                if let Some(g) = growth {
                    s.tick_cached(g);
                }
                if s.in_slow_start() {
                    in_slow_start += 1;
                }
                flat.push(*s);
            }
        }
        if self.aimd {
            flat.len() - start
        } else {
            in_slow_start
        }
    }

    /// Stage two of a tick: consume this engine's per-stream goodput rates
    /// (bytes/s, in [`Self::stage_streams`] order), charge pipelining
    /// overhead, cap by the CPU budget, and move bytes.
    pub fn apply_shared_rates(
        &mut self,
        rates: &[f64],
        link: &Link,
        dt: SimDuration,
        cpu_cap_bytes_per_sec: f64,
    ) -> TickOutput {
        if self.channels.is_empty() || dt.is_zero() {
            return TickOutput::default();
        }
        let rtt = link.params.rtt;

        // AIMD reaction to this tick's grants (windows move for the *next*
        // tick; this tick's rates are already fixed by the allocation).
        if self.aimd {
            self.aimd_update(rates, rtt, dt);
        }

        // 2. Per-channel raw rate, then pipelining efficiency:
        //    long-run goodput of a channel moving files of size S at raw
        //    rate r with pipelining pp is r * S / (S + r*RTT/pp).
        let mut idx = 0;
        let mut channel_rates = std::mem::take(&mut self.scratch_channel_rates);
        channel_rates.clear();
        let mut total_raw = 0.0;
        for c in &self.channels {
            let mut r = 0.0;
            for _ in 0..c.num_streams() {
                r += rates[idx];
                idx += 1;
            }
            let p = &self.partitions[c.partition];
            let s = p.avg_file_size.as_f64().max(1.0);
            // Pipelining model: with pp requests in flight the server can
            // stream files back-to-back as long as pp transmissions cover
            // one RTT; otherwise the channel idles RTT/pp per file. Non-
            // persistent tools additionally pay handshake RTTs per file.
            //   time_per_file = max(S/r, RTT/pp) + handshakes*RTT
            let eff = if r > 0.0 {
                let xfer = s / r;
                let paced = xfer.max(rtt.as_secs() / p.pp_level.max(1) as f64)
                    + p.handshake_rtts * rtt.as_secs();
                xfer / paced
            } else {
                0.0
            };
            let g = r * eff;
            channel_rates.push(g);
            total_raw += g;
        }

        // 3. End-system cap: scale all channels uniformly if the CPUs
        //    cannot keep up with the network allocation.
        let scale = if total_raw > cpu_cap_bytes_per_sec && total_raw > 0.0 {
            cpu_cap_bytes_per_sec / total_raw
        } else {
            1.0
        };

        // 4. Move bytes and account requests.
        let mut moved_total = 0.0;
        let mut requests_per_sec = 0.0;
        for (c, &g) in self.channels.iter().zip(&channel_rates) {
            let p = &mut self.partitions[c.partition];
            let rate = g * scale;
            let moved = (rate * dt.as_secs()).min(p.remaining.as_f64());
            p.remaining = p.remaining.saturating_sub(Bytes::new(moved));
            moved_total += moved;
            // Each avg-file worth of bytes is one request (chunked large
            // files issue one request per chunk ≈ per avg_file/parallelism).
            requests_per_sec += rate / p.avg_file_size.as_f64().max(1.0);
        }

        let open_streams = rates.len();
        self.scratch_channel_rates = channel_rates;
        self.retire_finished_partitions();

        TickOutput {
            goodput: Rate::from_bytes_per_sec(moved_total / dt.as_secs()),
            moved: Bytes::new(moved_total),
            requests_per_sec,
            open_streams,
        }
    }

    /// The AIMD competing-flow step, run inside
    /// [`Self::apply_shared_rates`] against this tick's per-stream grants
    /// (staged order):
    ///
    /// * a stream whose grant covers its window demand grows additively
    ///   (one MSS per RTT, [`StreamState::additive_increase`]);
    /// * a *clipped* stream — grant short of `window / RTT`, i.e. its
    ///   penalty-scaled fair share ran out — backs off multiplicatively,
    ///   at most once per RTT across the engine (the cooldown), which is
    ///   the loss-event granularity of real TCP rather than per-ACK.
    ///   A clipped slow-start stream exits slow start through the same
    ///   backoff, like classic TCP on its first loss.
    ///
    /// With the `bbr` feature and [`Self::set_bbr`] on, the responses are
    /// the BBR-like drain/probe pair instead.
    fn aimd_update(&mut self, rates: &[f64], rtt: Rtt, dt: SimDuration) {
        if rtt.is_zero() {
            return;
        }
        self.aimd_cooldown_s = (self.aimd_cooldown_s - dt.as_secs()).max(0.0);
        let md_armed = self.aimd_cooldown_s == 0.0;
        let mut backed_off = false;
        let mut idx = 0;
        for c in &mut self.channels {
            for s in &mut c.streams {
                let rate = rates[idx];
                idx += 1;
                let demand = s.window_rate(rtt).as_bytes_per_sec();
                let clipped = rate < demand * (1.0 - 1e-9);
                if clipped && md_armed {
                    backed_off = true;
                    self.aimd_backoffs += 1;
                    #[cfg(feature = "bbr")]
                    if self.bbr {
                        s.drain_to_delivered(rate, rtt);
                        continue;
                    }
                    s.backoff();
                } else if !clipped {
                    #[cfg(feature = "bbr")]
                    if self.bbr {
                        s.probe_gain(dt, rtt);
                        continue;
                    }
                    s.additive_increase(dt, rtt);
                }
            }
        }
        if backed_off {
            self.aimd_cooldown_s = rtt.as_secs();
        }
    }

    /// Warm-epoch variant of [`Self::apply_shared_rates`]: move one
    /// tick's bytes using the per-channel goodput rates cached by the
    /// previous tick's stage two instead of recomputing them.
    ///
    /// # Contract
    ///
    /// The caller must guarantee that since the last
    /// [`Self::apply_shared_rates`] call (a) no structural mutation
    /// happened ([`Self::generation`] unchanged, no knob changes) and
    /// (b) the per-stream rate slice this engine would receive is
    /// bit-identical. Channel efficiency depends only on the raw rates
    /// and per-partition knobs (average file size, pipelining level,
    /// handshake RTTs) — never on remaining bytes — so under (a)+(b)
    /// `scratch_channel_rates` carries exactly the bits stage two would
    /// recompute, and the stages below are the reference code verbatim.
    /// The epoch-cached stepper ([`crate::sim::Simulation`]) is the only
    /// caller and enforces the contract through its epoch stamps.
    ///
    /// `open_streams` is this engine's staged stream count — the value
    /// `rates.len()` carries on the slow path.
    ///
    /// Depletion stays self-detecting: the `.min(remaining)` clamp in
    /// stage four and the generation bump in stage five happen here
    /// exactly as on the slow path, so a partition finishing mid-batch
    /// ends the epoch through the usual stamp mismatch.
    pub fn apply_warm_rates(
        &mut self,
        dt: SimDuration,
        cpu_cap_bytes_per_sec: f64,
        open_streams: usize,
    ) -> TickOutput {
        if self.channels.is_empty() || dt.is_zero() {
            return TickOutput::default();
        }
        let channel_rates = std::mem::take(&mut self.scratch_channel_rates);
        debug_assert_eq!(
            channel_rates.len(),
            self.channels.len(),
            "warm tick without one cached stage-two rate per channel"
        );
        // Same accumulation order as stage two's running `total_raw += g`.
        let total_raw: f64 = channel_rates.iter().sum();

        // 3. End-system cap: scale all channels uniformly if the CPUs
        //    cannot keep up with the network allocation.
        let scale = if total_raw > cpu_cap_bytes_per_sec && total_raw > 0.0 {
            cpu_cap_bytes_per_sec / total_raw
        } else {
            1.0
        };

        // 4. Move bytes and account requests.
        let mut moved_total = 0.0;
        let mut requests_per_sec = 0.0;
        for (c, &g) in self.channels.iter().zip(&channel_rates) {
            let p = &mut self.partitions[c.partition];
            let rate = g * scale;
            let moved = (rate * dt.as_secs()).min(p.remaining.as_f64());
            p.remaining = p.remaining.saturating_sub(Bytes::new(moved));
            moved_total += moved;
            requests_per_sec += rate / p.avg_file_size.as_f64().max(1.0);
        }

        self.scratch_channel_rates = channel_rates;
        self.retire_finished_partitions();

        TickOutput {
            goodput: Rate::from_bytes_per_sec(moved_total / dt.as_secs()),
            moved: Bytes::new(moved_total),
            requests_per_sec,
            open_streams,
        }
    }

    /// Stage five of a tick, shared by [`Self::apply_shared_rates`] and
    /// [`Self::apply_warm_rates`]: reassign channels of partitions that
    /// just finished to the unfinished partition with the most remaining
    /// data (a real tool's worker simply dequeues the next file).
    /// Streams stay warm: the TCP connections are reused.
    fn retire_finished_partitions(&mut self) {
        if self.partitions.iter().any(|p| p.done()) {
            let target = (0..self.partitions.len())
                .filter(|&i| !self.partitions[i].done())
                .max_by(|&a, &b| {
                    self.partitions[a]
                        .remaining
                        .partial_cmp(&self.partitions[b].remaining)
                        .unwrap()
                });
            let mut restructured = false;
            match target {
                Some(t) => {
                    let parallelism =
                        self.effective_parallelism(t, self.channels.len().max(1) as u32);
                    let avg_win = self.avg_win;
                    for c in &mut self.channels {
                        if self.partitions[c.partition].done() {
                            *c = Channel::open_warm(t, parallelism, avg_win);
                            restructured = true;
                        }
                    }
                }
                None => {
                    restructured = !self.channels.is_empty();
                    self.channels.clear();
                }
            }
            if restructured {
                self.generation += 1;
            }
            // Refresh cc_level bookkeeping.
            for i in 0..self.partitions.len() {
                let count = self.channels.iter().filter(|c| c.partition == i).count() as u32;
                self.partitions[i].cc_level = count;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{partition_files, standard};
    use crate::netsim::{BackgroundTraffic, LinkParams};
    use crate::units::Rate;

    fn cloudlab_link() -> Link {
        Link::new(
            LinkParams {
                capacity: Rate::from_gbps(1.0),
                rtt: SimDuration::from_millis(36.0),
                avg_win: Bytes::from_mb(1.0),
                overload_gamma: 0.02,
                overload_floor: 0.55,
            },
            BackgroundTraffic::constant(0.0),
        )
    }

    fn engine_for(dataset_name: &str, link: &Link) -> TransferEngine {
        let ds = standard::by_name(dataset_name, 7).unwrap();
        // Mirror the heuristic initializer: parallelism capped at the
        // per-channel stream count that fills the pipe.
        let p_cap = link.params.knee_streams().ceil() as u32;
        let parts =
            crate::dataset::partition_files_capped(&ds, link.params.bdp(), p_cap.max(1));
        TransferEngine::new(&parts, link.params.avg_win)
    }

    #[test]
    fn weights_sum_to_one() {
        let link = cloudlab_link();
        let e = engine_for("mixed", &link);
        let sum: f64 = e.partitions().iter().map(|p| p.weight).sum();
        assert!((sum - 1.0).abs() < 1e-9, "weights sum {sum}");
    }

    #[test]
    fn channel_distribution_conserves_total() {
        let link = cloudlab_link();
        let mut e = engine_for("mixed", &link);
        for n in [3u32, 8, 17, 2, 1, 30] {
            e.set_num_channels(n);
            assert_eq!(e.num_channels(), n, "requested {n}");
            let cc_sum: u32 = e.partitions().iter().map(|p| p.cc_level).sum();
            assert_eq!(cc_sum, n);
        }
    }

    #[test]
    fn channel_cap_clamps_requests() {
        let link = cloudlab_link();
        let mut e = engine_for("mixed", &link);
        e.set_channel_cap(Some(6));
        e.set_num_channels(20);
        assert_eq!(e.num_channels(), 6, "cap must bound the request");
        e.set_channel_cap(None);
        e.set_num_channels(20);
        assert_eq!(e.num_channels(), 20, "uncapped again");
    }

    #[test]
    fn low_budget_goes_to_heaviest_partitions() {
        let link = cloudlab_link();
        let mut e = engine_for("mixed", &link);
        e.set_num_channels(1);
        assert_eq!(e.num_channels(), 1);
        // The single channel must serve the partition with the most data.
        let heaviest = e
            .partitions()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.remaining.partial_cmp(&b.1.remaining).unwrap())
            .unwrap()
            .0;
        assert_eq!(e.channels()[0].partition, heaviest);
    }

    #[test]
    fn every_unfinished_partition_gets_a_channel() {
        let link = cloudlab_link();
        let mut e = engine_for("mixed", &link);
        e.set_num_channels(3);
        for p in e.partitions() {
            if !p.done() {
                assert!(p.cc_level >= 1, "partition {} starved", p.name);
            }
        }
    }

    #[test]
    fn moving_bytes_decreases_remaining() {
        let link = cloudlab_link();
        let mut e = engine_for("medium", &link);
        e.set_num_channels(4);
        let before = e.remaining();
        let out = e.tick(&link, SimDuration::from_millis(100.0), f64::INFINITY);
        assert!(out.moved.as_f64() > 0.0);
        let after = e.remaining() + out.moved;
        assert!(
            (after.as_f64() - before.as_f64()).abs() < 1.0,
            "conservation: {} vs {}",
            after,
            before
        );
    }

    #[test]
    fn transfer_completes() {
        let link = cloudlab_link();
        let ds = standard::large_dataset(3);
        // Shrink for test speed: keep 4 files.
        let small = crate::dataset::Dataset::new("t", ds.files[..4].to_vec());
        let parts = partition_files(&small, link.params.bdp());
        let mut e = TransferEngine::new(&parts, link.params.avg_win);
        e.set_num_channels(4);
        let dt = SimDuration::from_millis(100.0);
        let mut ticks = 0;
        while !e.is_done() && ticks < 200_000 {
            e.tick(&link, dt, f64::INFINITY);
            ticks += 1;
        }
        assert!(e.is_done(), "transfer should finish, remaining {}", e.remaining());
        assert_eq!(e.num_channels(), 0, "channels released on completion");
    }

    #[test]
    fn goodput_bounded_by_capacity() {
        let link = cloudlab_link();
        let mut e = engine_for("large", &link);
        e.set_num_channels(8);
        // Warm up.
        let dt = SimDuration::from_millis(100.0);
        let mut peak: f64 = 0.0;
        for _ in 0..100 {
            let out = e.tick(&link, dt, f64::INFINITY);
            peak = peak.max(out.goodput.as_gbps());
        }
        assert!(peak <= 1.0 + 1e-6, "goodput {peak} Gbps over 1 Gbps link");
        assert!(peak > 0.8, "large files should nearly saturate, got {peak}");
    }

    #[test]
    fn cpu_cap_limits_goodput() {
        let link = cloudlab_link();
        let mut e = engine_for("large", &link);
        e.set_num_channels(8);
        let dt = SimDuration::from_millis(100.0);
        for _ in 0..50 {
            e.tick(&link, dt, f64::INFINITY);
        }
        let capped = e.tick(&link, dt, 10e6); // 10 MB/s cap
        assert!(capped.goodput.as_bytes_per_sec() <= 10e6 * 1.001);
    }

    #[test]
    fn pipelining_hurts_small_files_when_disabled() {
        let link = cloudlab_link();
        let mut e1 = engine_for("small", &link);
        let mut e2 = engine_for("small", &link);
        // Force pp=1 on e2.
        for i in 0..e2.partitions().len() {
            e2.set_pp_level(i, 1);
        }
        e1.set_num_channels(4);
        e2.set_num_channels(4);
        let dt = SimDuration::from_millis(100.0);
        let (mut g1, mut g2) = (0.0, 0.0);
        for _ in 0..100 {
            g1 += e1.tick(&link, dt, f64::INFINITY).moved.as_f64();
            g2 += e2.tick(&link, dt, f64::INFINITY).moved.as_f64();
        }
        assert!(g1 > 2.0 * g2, "pipelining should speed small files: {g1} vs {g2}");
    }

    #[test]
    fn shrinking_channels_closes_streams() {
        let link = cloudlab_link();
        let mut e = engine_for("medium", &link);
        e.set_num_channels(10);
        let s10 = e.open_streams();
        e.set_num_channels(2);
        let s2 = e.open_streams();
        assert!(s2 < s10);
        assert_eq!(e.num_channels(), 2);
    }

    #[test]
    fn empty_engine_is_done() {
        let e = TransferEngine::new(&[], Bytes::from_mb(1.0));
        assert!(e.is_done());
        assert_eq!(e.remaining(), Bytes::ZERO);
    }

    #[test]
    fn generation_tracks_structure_not_plain_ticks() {
        let link = cloudlab_link();
        let mut e = engine_for("large", &link);
        let g0 = e.generation();
        e.set_num_channels(4);
        assert!(e.generation() > g0, "redistribution is structural");
        let g1 = e.generation();
        e.set_pp_level(0, 8);
        e.set_parallelism(0, 2);
        e.set_handshake_rtts(0, 1.0);
        assert!(e.generation() > g1, "knob changes are structural");

        // Mid-transfer ticks (slow-start growth, byte movement) must NOT
        // bump the generation — that is what lets warm epochs persist.
        let g2 = e.generation();
        let dt = SimDuration::from_millis(100.0);
        for _ in 0..20 {
            e.tick(&link, dt, f64::INFINITY);
        }
        assert!(!e.is_done(), "large dataset cannot finish in 2 s");
        assert_eq!(e.generation(), g2, "plain ticks are not structural");
    }

    #[test]
    fn drain_channels_stops_work_but_keeps_remaining_bytes() {
        let link = cloudlab_link();
        let mut e = engine_for("medium", &link);
        e.set_num_channels(6);
        let dt = SimDuration::from_millis(100.0);
        for _ in 0..20 {
            e.tick(&link, dt, f64::INFINITY);
        }
        let remaining = e.remaining();
        assert!(!e.is_done() && remaining > Bytes::ZERO);
        let g0 = e.generation();
        e.drain_channels();
        assert!(e.generation() > g0, "draining is structural");
        assert_eq!(e.num_channels(), 0);
        assert_eq!(e.open_streams(), 0);
        assert!(e.partitions().iter().all(|p| p.cc_level == 0));
        // The bytes stay put: a drained engine is parked, not finished.
        assert_eq!(e.remaining(), remaining);
        assert!(!e.is_done());
        let out = e.tick(&link, dt, f64::INFINITY);
        assert_eq!(out.moved, Bytes::ZERO, "no channels, no movement");
        // Draining an already-drained engine is a no-op.
        let g1 = e.generation();
        e.drain_channels();
        assert_eq!(e.generation(), g1);
    }

    #[test]
    fn aimd_streams_stay_unstable_for_the_epoch_cache() {
        let link = cloudlab_link();
        let mut e = engine_for("medium", &link);
        e.set_num_channels(4);
        let g0 = e.generation();
        e.set_aimd(true);
        assert!(e.aimd_enabled());
        assert!(e.generation() > g0, "switching the congestion model is structural");
        let dt = SimDuration::from_millis(100.0);
        for _ in 0..200 {
            e.tick(&link, dt, f64::INFINITY);
        }
        // Long past the slow-start ramp, every stream must still report
        // unstable: AIMD windows move on arbitrary later ticks, so a warm
        // epoch would replay stale rates.
        let mut flat = Vec::new();
        let unstable = e.stage_streams(dt, link.params.rtt, &mut flat);
        assert_eq!(unstable, e.open_streams(), "all AIMD streams are unstable");
        // Toggling back to the default FSM is also structural.
        let g1 = e.generation();
        e.set_aimd(false);
        assert!(e.generation() > g1);
        e.set_aimd(false); // no-op: same mode
        assert_eq!(e.generation(), g1 + 1);
    }

    #[test]
    fn aimd_halves_at_most_once_per_rtt() {
        let link = cloudlab_link(); // rtt 36 ms
        let mut e = engine_for("large", &link);
        e.set_num_channels(1);
        // Warm the streams to avg_win under the default FSM first.
        let dt = SimDuration::from_millis(100.0);
        for _ in 0..100 {
            e.tick(&link, dt, f64::INFINITY);
        }
        e.set_aimd(true);
        let avg_win = link.params.avg_win.as_f64();
        assert_eq!(e.channels()[0].streams[0].window().as_f64(), avg_win);
        // Starve the engine (zero grants) with a 10 ms tick: every stream
        // is clipped every tick, but the per-RTT cooldown arms the
        // multiplicative decrease only on ticks 0, 4 and 8 — exactly
        // three halvings over 100 ms, not ten.
        let zero = vec![0.0; e.open_streams()];
        let small = SimDuration::from_millis(10.0);
        let mut flat = Vec::new();
        for _ in 0..10 {
            flat.clear();
            e.stage_streams(small, link.params.rtt, &mut flat);
            e.apply_shared_rates(&zero, &link, small, f64::INFINITY);
        }
        let w = e.channels()[0].streams[0].window().as_f64();
        assert_eq!(w, avg_win * 0.125, "expected exactly three backoffs, window {w}");
        assert!(!e.channels()[0].streams[0].in_slow_start());
    }

    #[test]
    fn aimd_adapts_windows_below_the_path_ceiling() {
        // On a link whose capacity cannot cover every window at avg_win,
        // AIMD streams sawtooth below the ceiling while the default FSM
        // pins every warm window at avg_win regardless of contention.
        let link = cloudlab_link();
        let dt = SimDuration::from_millis(100.0);
        let mut hold = engine_for("large", &link);
        hold.set_num_channels(8);
        let mut aimd = engine_for("large", &link);
        aimd.set_aimd(true);
        aimd.set_num_channels(8);
        let (mut moved_hold, mut moved_aimd) = (0.0, 0.0);
        for _ in 0..300 {
            moved_hold += hold.tick(&link, dt, f64::INFINITY).moved.as_f64();
            moved_aimd += aimd.tick(&link, dt, f64::INFINITY).moved.as_f64();
        }
        let max_win = |e: &TransferEngine| {
            e.channels()
                .iter()
                .flat_map(|c| c.streams.iter())
                .map(|s| s.window().as_f64())
                .fold(0.0, f64::max)
        };
        let ceiling = link.params.avg_win.as_f64();
        assert_eq!(max_win(&hold), ceiling, "default FSM pins warm windows");
        assert!(
            max_win(&aimd) < ceiling,
            "AIMD must back off under contention: {} vs {ceiling}",
            max_win(&aimd)
        );
        // Backing off costs some utilization but not collapse.
        assert!(
            moved_aimd > 0.25 * moved_hold,
            "AIMD moved {moved_aimd} vs hold {moved_hold}"
        );
    }

    #[cfg(feature = "bbr")]
    #[test]
    fn bbr_mode_drains_instead_of_halving() {
        let link = cloudlab_link();
        let mut e = engine_for("large", &link);
        e.set_num_channels(1);
        let dt = SimDuration::from_millis(100.0);
        for _ in 0..100 {
            e.tick(&link, dt, f64::INFINITY);
        }
        e.set_aimd(true);
        e.set_bbr(true);
        // A grant of 10 MB/s against a 1 MB window (27.8 MB/s demand at
        // 36 ms) is a clip: BBR drains to delivered BDP = 360 KB rather
        // than halving to 500 KB.
        let grants = vec![10e6; e.open_streams()];
        let mut flat = Vec::new();
        flat.clear();
        e.stage_streams(dt, link.params.rtt, &mut flat);
        e.apply_shared_rates(&grants, &link, dt, f64::INFINITY);
        let w = e.channels()[0].streams[0].window().as_f64();
        assert!((w - 10e6 * 0.036).abs() < 1.0, "drained window {w}");
    }

    #[test]
    fn stage_streams_counts_slow_start() {
        let link = cloudlab_link();
        let mut e = engine_for("medium", &link);
        e.set_num_channels(4);
        let dt = SimDuration::from_millis(100.0);
        let mut flat = Vec::new();
        let cold = e.stage_streams(dt, link.params.rtt, &mut flat);
        assert!(cold > 0, "fresh channels start cold");
        assert_eq!(flat.len(), e.open_streams());
        // Ramp to steady state: the count must hit zero and stay there.
        for _ in 0..100 {
            flat.clear();
            e.stage_streams(dt, link.params.rtt, &mut flat);
        }
        flat.clear();
        assert_eq!(e.stage_streams(dt, link.params.rtt, &mut flat), 0);
    }
}
