//! # GreenDT
//!
//! Full-system reproduction of *"Energy-Efficient High-Throughput Data
//! Transfers via Dynamic CPU Frequency and Core Scaling"* (Di Tacchio,
//! Nine, Kosar, Bulut, Hwang — CS.DC 2019).
//!
//! GreenDT is a three-layer system:
//!
//! * **Layer 3 (this crate)** — the coordinator: the paper's three
//!   SLA-driven parameter-tuning algorithms (Minimum Energy, Energy-Efficient
//!   Maximum Throughput, Energy-Efficient Target Throughput) jointly tuning
//!   pipelining, parallelism, concurrency, active CPU cores and CPU
//!   frequency over a simulated WAN + end-system substrate.
//! * **Layer 2 (python/compile/model.py)** — a JAX energy/throughput
//!   prediction model, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/)** — the Pallas candidate-grid
//!   scoring kernel called by Layer 2.
//!
//! The compiled predictor is executed from Rust through
//! [`runtime`] (PJRT CPU client); Python never runs on the decision path.

// Documentation is a first-class surface: every public item must carry a
// doc comment, and CI runs `cargo doc --no-deps` with warnings denied so
// drift fails the build.
#![warn(missing_docs)]

pub mod units;
pub mod rng;
pub mod testutil;
pub mod dataset;
pub mod netsim;
pub mod cpusim;
pub mod power;
pub mod transfer;
pub mod sim;
pub mod rebalance;
pub mod resilience;
pub mod history;
pub mod obs;
pub mod coordinator;
pub mod baselines;
pub mod predictor;
pub mod runtime;
pub mod config;
pub mod cli;
pub mod metrics;
pub mod experiments;
pub mod benchkit;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
