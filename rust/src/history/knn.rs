//! A deterministic k-nearest-neighbour index over run records.
//!
//! No external crates, no randomness: neighbours are found by a linear
//! scan over the indexed records, ordered by (distance, insertion order),
//! so the same store always produces the same answers. Two questions are
//! answered (the two the coordinator and dispatcher ask):
//!
//! * [`KnnIndex::warm_start`] — "what is the best known operating point
//!   for a workload like this?" A distance-weighted *vote* over the
//!   discretized `(cores, P-state, channels)` triples of the k nearest
//!   runs (à la the decision-tree history work, arXiv:2204.07601);
//! * [`KnnIndex::observed_j_per_byte`] — "what did moving a byte of a
//!   workload like this actually cost on host *h*?" A distance-weighted
//!   mean over that host's k nearest runs, which
//!   [`PlacementKind::Learned`](crate::coordinator::fleet::PlacementKind)
//!   blends with the model-based marginal-energy score.
//!
//! Both answers come with a confidence in `[0, 1]` (mean similarity of
//! the neighbours found, `0` for an empty index); callers fall back to
//! the model-only path below [`CONFIDENCE_FLOOR`].
//!
//! Runs that ended without completing (schema v3
//! [`RunOutcome`](super::RunOutcome)) are indexed *down-weighted*, not
//! censored: their distance is inflated by [`INCOMPLETE_PENALTY`], so a
//! completed twin always out-votes them but a host whose only history
//! is failure still answers — and answers with the cost its failures
//! actually ran up. Dropping them (the pre-v3 behaviour) was
//! survivorship bias: a flaky host's disasters vanished from the log
//! and only its lucky runs trained the learner.
//!
//! The index is a snapshot: it is built once from a store's records and
//! is *not* invalidated by later appends — rebuild (cheap, linear) to see
//! new history. See ARCHITECTURE.md §History.

use super::features::{self, FeatureVec, Query};
use super::record::RunRecord;

/// Minimum confidence at which history overrides the cold-start path.
pub const CONFIDENCE_FLOOR: f64 = 0.25;

/// Default neighbour count.
pub const DEFAULT_K: usize = 5;

/// Distance penalty added per mismatched categorical field (testbed,
/// algorithm) — large enough that a same-testbed record always beats a
/// cross-testbed one at comparable workload distance, small enough that a
/// sparse store still answers.
const CATEGORY_PENALTY: f64 = 1.0;

/// Distance inflation applied to runs that ended without completing —
/// twice the categorical penalty, so an incomplete run is out-voted by
/// any completed record at comparable distance (even one from the
/// wrong testbed) yet still answers when it is all the history a host
/// has.
pub const INCOMPLETE_PENALTY: f64 = 2.0;

/// A warm-start recommendation: the operating point a
/// [`HistoryTuned`](crate::coordinator::history_tuned::HistoryTuned)
/// session starts from instead of the paper's cold slow-start probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct WarmStart {
    /// Client cores to start with.
    pub cores: u32,
    /// Client P-state index to start at (into the testbed's ladder).
    pub pstate: u32,
    /// Channel count to open immediately (no slow-start correction).
    pub channels: u32,
}

#[derive(Debug, Clone)]
struct Entry {
    features: FeatureVec,
    testbed: String,
    algorithm: String,
    host: String,
    op: WarmStart,
    j_per_byte: f64,
    /// The marginal J/B the dispatcher estimated at this run's admission
    /// (v2 records; `None` on v1 records and single-host runs).
    marginal_j_per_byte: Option<f64>,
    /// Whether the residency completed; incomplete entries pay
    /// [`INCOMPLETE_PENALTY`] in every distance computation.
    completed: bool,
}

/// The index itself (see the module docs). Cloneable so a
/// [`DispatcherConfig`](crate::sim::dispatcher::DispatcherConfig) can
/// carry one.
#[derive(Debug, Clone)]
pub struct KnnIndex {
    k: usize,
    entries: Vec<Entry>,
}

impl KnnIndex {
    /// Index `records` with the default neighbour count. Runs that moved
    /// no bytes are skipped — they carry no usable operating point or
    /// cost; incomplete runs are kept but pay [`INCOMPLETE_PENALTY`].
    pub fn build(records: &[RunRecord]) -> KnnIndex {
        KnnIndex::with_k(records, DEFAULT_K)
    }

    /// Index `records` with an explicit neighbour count.
    pub fn with_k(records: &[RunRecord], k: usize) -> KnnIndex {
        let entries = records
            .iter()
            .filter(|r| r.moved_bytes > 0.0)
            .map(|r| Entry {
                features: features::features(
                    &r.workload,
                    r.rtt_s,
                    r.bandwidth_bps,
                    r.contention,
                ),
                testbed: r.testbed.clone(),
                algorithm: r.algorithm.clone(),
                host: r.host.clone(),
                op: WarmStart {
                    cores: r.cores,
                    pstate: r.pstate,
                    channels: r.channels,
                },
                j_per_byte: r.j_per_byte,
                marginal_j_per_byte: r.admission_marginal_jpb.filter(|m| m.is_finite()),
                completed: r.outcome.is_completed(),
            })
            .collect();
        KnnIndex { k: k.max(1), entries }
    }

    /// Indexed run count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing was indexed.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// True when at least one indexed run carries the v2 admission
    /// marginal. Callers that blend observations across hosts pick one
    /// scale per decision with this: marginal-only when available,
    /// full-cost otherwise — never a mix (a v1-era host would otherwise
    /// be compared on its full attributed bill against a v2 host's
    /// marginal, inflating it by the fixed costs).
    pub fn has_marginal_observations(&self) -> bool {
        self.entries.iter().any(|e| e.marginal_j_per_byte.is_some())
    }

    /// Distinct host names in the index, sorted.
    pub fn hosts(&self) -> Vec<String> {
        let mut hosts: Vec<String> = self.entries.iter().map(|e| e.host.clone()).collect();
        hosts.sort();
        hosts.dedup();
        hosts
    }

    fn dist(entry: &Entry, q: &Query, qf: &FeatureVec) -> f64 {
        let mut d = features::distance(&entry.features, qf);
        if let Some(tb) = &q.testbed {
            if tb != &entry.testbed {
                d += CATEGORY_PENALTY;
            }
        }
        if let Some(algo) = &q.algorithm {
            if algo != &entry.algorithm {
                d += CATEGORY_PENALTY;
            }
        }
        if !entry.completed {
            d += INCOMPLETE_PENALTY;
        }
        d
    }

    /// The k nearest entries (optionally restricted to one host), as
    /// `(distance, entry)` in deterministic (distance, insertion) order.
    /// The scan is O(n) + an O(k log k) sort of the survivors — the
    /// (distance, index) comparator is a strict total order, so the
    /// select-then-sort is as deterministic as a full sort.
    fn neighbors<'a>(&'a self, q: &Query, host: Option<&str>) -> Vec<(f64, &'a Entry)> {
        let qf = features::features(&q.workload, q.rtt_s, q.bandwidth_bps, q.contention);
        let cmp = |a: &(f64, usize), b: &(f64, usize)| {
            a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1))
        };
        let mut scored: Vec<(f64, usize)> = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, e)| host.is_none_or(|h| e.host == h))
            .map(|(i, e)| (Self::dist(e, q, &qf), i))
            .collect();
        if scored.len() > self.k {
            scored.select_nth_unstable_by(self.k - 1, cmp);
            scored.truncate(self.k);
        }
        scored.sort_by(cmp);
        scored
            .into_iter()
            .map(|(d, i)| (d, &self.entries[i]))
            .collect()
    }

    /// Mean similarity (`1/(1+d)`) of a neighbour set — the confidence
    /// attached to every answer.
    fn confidence(neighbors: &[(f64, &Entry)]) -> f64 {
        if neighbors.is_empty() {
            return 0.0;
        }
        neighbors.iter().map(|(d, _)| 1.0 / (1.0 + d)).sum::<f64>() / neighbors.len() as f64
    }

    /// Distance-weighted mean (weight `1/(ε + d)`) of one per-entry value
    /// over a neighbour set — the single weighting kernel behind both
    /// cost observations, so the marginal and full-cost answers can
    /// never drift apart in how they average. Callers guarantee a
    /// non-empty set.
    fn weighted_mean(neighbors: &[(f64, &Entry)], value: impl Fn(&Entry) -> f64) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for (d, e) in neighbors {
            let w = 1.0 / (1e-6 + d);
            num += w * value(e);
            den += w;
        }
        num / den
    }

    /// Best known operating point for a workload like `q`, with its
    /// confidence. `None` only when the index is empty.
    ///
    /// Distance-weighted vote over discrete `(cores, pstate, channels)`
    /// triples: each neighbour votes with weight `1/(ε + d)`, so an exact
    /// workload match dominates; ties break toward the smallest triple.
    pub fn warm_start(&self, q: &Query) -> Option<(WarmStart, f64)> {
        let neighbors = self.neighbors(q, None);
        if neighbors.is_empty() {
            return None;
        }
        let mut votes: std::collections::BTreeMap<WarmStart, f64> =
            std::collections::BTreeMap::new();
        for (d, e) in &neighbors {
            *votes.entry(e.op).or_insert(0.0) += 1.0 / (1e-6 + d);
        }
        // BTreeMap iterates ascending, so `>` keeps the smallest triple on
        // exact weight ties.
        let mut best: Option<(WarmStart, f64)> = None;
        for (op, w) in votes {
            if best.as_ref().is_none_or(|(_, bw)| w > *bw) {
                best = Some((op, w));
            }
        }
        best.map(|(op, _)| (op, Self::confidence(&neighbors)))
    }

    /// [`Self::warm_start`] gated at [`CONFIDENCE_FLOOR`]: `None` means
    /// "stay on the cold slow-start path".
    pub fn confident_warm_start(&self, q: &Query) -> Option<WarmStart> {
        match self.warm_start(q) {
            Some((op, conf)) if conf >= CONFIDENCE_FLOOR => Some(op),
            _ => None,
        }
    }

    /// Observed energy cost (J/B) of serving a workload like `q` on
    /// `host`, with its confidence. `None` when the index holds no run
    /// from that host.
    pub fn observed_j_per_byte(&self, host: &str, q: &Query) -> Option<(f64, f64)> {
        let neighbors = self.neighbors(q, Some(host));
        if neighbors.is_empty() {
            return None;
        }
        let mean = Self::weighted_mean(&neighbors, |e| e.j_per_byte);
        Some((mean, Self::confidence(&neighbors)))
    }

    /// Like [`Self::observed_j_per_byte`] but over the *marginal* J/B
    /// recorded at admission (schema v2) — the scale the dispatcher's
    /// model score lives on, so `Learned` placement can blend like with
    /// like. Only neighbours that carry the field participate (v1
    /// records do not); `None` when no neighbour from `host` does, in
    /// which case callers fall back to the full-cost observation.
    pub fn observed_marginal_j_per_byte(&self, host: &str, q: &Query) -> Option<(f64, f64)> {
        let neighbors: Vec<(f64, &Entry)> = self
            .neighbors(q, Some(host))
            .into_iter()
            .filter(|(_, e)| e.marginal_j_per_byte.is_some())
            .collect();
        if neighbors.is_empty() {
            return None;
        }
        let mean =
            Self::weighted_mean(&neighbors, |e| e.marginal_j_per_byte.expect("filtered above"));
        Some((mean, Self::confidence(&neighbors)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::history::features::WorkloadFingerprint;
    use crate::history::RunOutcome;

    fn record(
        host: &str,
        testbed: &str,
        total_gb: f64,
        op: (u32, u32, u32),
        jpb: f64,
    ) -> RunRecord {
        let n = 100;
        RunRecord {
            session: format!("s-{host}-{total_gb}"),
            algorithm: "history".to_string(),
            host: host.to_string(),
            testbed: testbed.to_string(),
            rtt_s: 0.044,
            bandwidth_bps: 1e9,
            workload: WorkloadFingerprint {
                total_bytes: total_gb * 1e9,
                num_files: n,
                avg_file_bytes: total_gb * 1e9 / n as f64,
                frac_small: 0.0,
                frac_medium: 1.0,
                frac_large: 0.0,
            },
            contention: 0,
            cores: op.0,
            pstate: op.1,
            channels: op.2,
            peak_channels: op.2,
            goodput_bps: 1e8,
            joules: jpb * total_gb * 1e9,
            j_per_byte: jpb,
            moved_bytes: total_gb * 1e9,
            duration_s: 100.0,
            completed: true,
            outcome: RunOutcome::Completed,
            admission_marginal_jpb: None,
            traj: Vec::new(),
        }
    }

    fn failed(mut r: RunRecord) -> RunRecord {
        r.completed = false;
        r.outcome = RunOutcome::Failed;
        r
    }

    fn query(total_gb: f64) -> Query {
        let n = 100;
        Query {
            workload: WorkloadFingerprint {
                total_bytes: total_gb * 1e9,
                num_files: n,
                avg_file_bytes: total_gb * 1e9 / n as f64,
                frac_small: 0.0,
                frac_medium: 1.0,
                frac_large: 0.0,
            },
            testbed: Some("DIDCLab".to_string()),
            rtt_s: 0.044,
            bandwidth_bps: 1e9,
            contention: 0,
            algorithm: None,
        }
    }

    #[test]
    fn empty_index_answers_nothing() {
        let idx = KnnIndex::build(&[]);
        assert!(idx.is_empty());
        assert!(idx.warm_start(&query(10.0)).is_none());
        assert!(idx.confident_warm_start(&query(10.0)).is_none());
        assert!(idx.observed_j_per_byte("h", &query(10.0)).is_none());
    }

    #[test]
    fn exact_match_wins_with_high_confidence() {
        let recs = vec![
            record("h0", "DIDCLab", 10.0, (2, 1, 9), 4e-8),
            record("h0", "DIDCLab", 0.1, (1, 0, 3), 9e-8),
        ];
        let idx = KnnIndex::build(&recs);
        let (op, conf) = idx.warm_start(&query(10.0)).unwrap();
        assert_eq!(op, WarmStart { cores: 2, pstate: 1, channels: 9 });
        assert!(conf >= CONFIDENCE_FLOOR, "confidence {conf}");
        assert_eq!(idx.confident_warm_start(&query(10.0)), Some(op));
    }

    #[test]
    fn incomplete_runs_are_indexed_but_down_weighted() {
        // Alone, a failed run still answers — with the cost its failure
        // actually ran up, dented by the built-in distance penalty.
        let lone = failed(record("h0", "DIDCLab", 10.0, (2, 1, 9), 4e-8));
        let idx = KnnIndex::build(&[lone]);
        assert_eq!(idx.len(), 1, "failures are no longer censored");
        let (jpb, conf) = idx.observed_j_per_byte("h0", &query(10.0)).unwrap();
        assert!((jpb - 4e-8).abs() < 1e-12);
        // An exact-match completed run would score 1.0; the penalty
        // dents this one to 1/(1 + INCOMPLETE_PENALTY).
        assert!((conf - 1.0 / (1.0 + INCOMPLETE_PENALTY)).abs() < 1e-9, "conf {conf}");
        // Next to a completed twin, the twin dominates both the vote and
        // the cost mean.
        let good = record("h0", "DIDCLab", 10.0, (2, 1, 9), 2e-8);
        let bad = failed(record("h0", "DIDCLab", 10.0, (8, 5, 30), 9e-8));
        let idx = KnnIndex::build(&[bad, good]);
        let (op, _) = idx.warm_start(&query(10.0)).unwrap();
        assert_eq!(op.channels, 9, "completed twin out-votes the failure");
        let (jpb, _) = idx.observed_j_per_byte("h0", &query(10.0)).unwrap();
        assert!((jpb - 2e-8).abs() < 1e-9, "cost mean stays near the survivor: {jpb}");
        // Zero-byte residencies stay out — nothing to learn from.
        let mut empty = failed(record("h0", "DIDCLab", 10.0, (2, 1, 9), 4e-8));
        empty.moved_bytes = 0.0;
        assert!(KnnIndex::build(&[empty]).is_empty());
    }

    #[test]
    fn vote_is_distance_weighted() {
        // Two far records agree on one op point, one exact match says
        // another: the exact match's 1/ε weight must dominate the vote.
        let recs = vec![
            record("h0", "DIDCLab", 0.1, (8, 5, 30), 9e-8),
            record("h0", "DIDCLab", 0.1, (8, 5, 30), 9e-8),
            record("h0", "DIDCLab", 10.0, (2, 1, 9), 4e-8),
        ];
        let idx = KnnIndex::build(&recs);
        let (op, _) = idx.warm_start(&query(10.0)).unwrap();
        assert_eq!(op.channels, 9, "exact match must out-vote the far pair");
    }

    #[test]
    fn testbed_mismatch_is_penalized_not_filtered() {
        let recs = vec![
            record("h0", "Chameleon", 10.0, (8, 5, 14), 2e-8),
            record("h1", "DIDCLab", 10.0, (2, 1, 9), 4e-8),
        ];
        let idx = KnnIndex::build(&recs);
        // Query prefers DIDCLab: the same-testbed record wins the vote.
        let (op, _) = idx.warm_start(&query(10.0)).unwrap();
        assert_eq!(op.cores, 2);
        // But a query indifferent to testbed still sees both.
        let mut q = query(10.0);
        q.testbed = None;
        let (_, conf) = idx.warm_start(&q).unwrap();
        assert!(conf > 0.5);
    }

    #[test]
    fn per_host_cost_estimates_are_host_filtered() {
        let recs = vec![
            record("efficient", "CloudLab", 10.0, (2, 1, 9), 2e-8),
            record("legacy", "DIDCLab", 10.0, (2, 1, 9), 8e-8),
        ];
        let idx = KnnIndex::build(&recs);
        assert_eq!(idx.hosts(), vec!["efficient".to_string(), "legacy".to_string()]);
        let (eff, _) = idx.observed_j_per_byte("efficient", &query(10.0)).unwrap();
        let (leg, _) = idx.observed_j_per_byte("legacy", &query(10.0)).unwrap();
        assert!((eff - 2e-8).abs() < 1e-12);
        assert!((leg - 8e-8).abs() < 1e-12);
        assert!(idx.observed_j_per_byte("nope", &query(10.0)).is_none());
    }

    #[test]
    fn marginal_observations_require_the_v2_field() {
        // v1-style records (no admission marginal) answer only the
        // full-cost question; mixed stores answer the marginal question
        // from the records that carry it.
        let mut a = record("h0", "DIDCLab", 10.0, (2, 1, 9), 4e-8);
        let b = record("h0", "DIDCLab", 10.0, (2, 1, 9), 6e-8);
        let idx = KnnIndex::build(&[a.clone(), b.clone()]);
        assert!(!idx.has_marginal_observations(), "pure v1-era store");
        assert!(idx.observed_j_per_byte("h0", &query(10.0)).is_some());
        assert!(
            idx.observed_marginal_j_per_byte("h0", &query(10.0)).is_none(),
            "no record carries the admission marginal"
        );
        a.admission_marginal_jpb = Some(1.5e-8);
        let idx = KnnIndex::build(&[a, b]);
        assert!(idx.has_marginal_observations());
        let (m, conf) = idx
            .observed_marginal_j_per_byte("h0", &query(10.0))
            .expect("one record carries it");
        assert!((m - 1.5e-8).abs() < 1e-14, "only the carrying record votes: {m}");
        assert!(conf > 0.0);
        // Full-cost observation is unchanged by the marginal field.
        let (jpb, _) = idx.observed_j_per_byte("h0", &query(10.0)).unwrap();
        assert!(jpb > 4e-8 && jpb < 6e-8);
    }

    #[test]
    fn answers_are_deterministic_across_rebuilds() {
        let recs: Vec<RunRecord> = (0..20u32)
            .map(|i| {
                record(
                    if i % 2 == 0 { "h0" } else { "h1" },
                    "DIDCLab",
                    1.0 + i as f64,
                    (1 + i % 4, i % 3, 4 + i % 11),
                    (2 + i % 7) as f64 * 1e-8,
                )
            })
            .collect();
        let a = KnnIndex::build(&recs);
        let b = KnnIndex::build(&recs);
        for gb in [1.0, 5.5, 19.0] {
            assert_eq!(a.warm_start(&query(gb)), b.warm_start(&query(gb)));
            assert_eq!(
                a.observed_j_per_byte("h0", &query(gb)),
                b.observed_j_per_byte("h0", &query(gb))
            );
        }
    }
}
