//! Experiment harnesses that regenerate every table and figure of the
//! paper's evaluation (§V). Each harness returns [`crate::metrics::Table`]s
//! (and writes CSVs under `results/` when asked) so the CLI, the bench
//! targets and the integration tests share one implementation.
//!
//! | Paper artifact | Harness |
//! |---|---|
//! | Table I / II   | [`validate`] |
//! | Figure 2 (a–f) | [`fig2`] |
//! | Figure 3 (a–d) | [`fig3`] |
//! | Figure 4 (a–c) | [`fig4`] |

mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod sweep;
pub mod validate;

pub use common::{run_cell, run_cells, Cell};
