//! Fleet hot-path bench: per-tick cost of a multi-tenant world, plus an
//! allocation audit proving the step path stays allocation-free.
//!
//!     cargo bench --bench bench_fleet
//!
//! A counting global allocator wraps the system allocator; after a warmup
//! that sizes every scratch buffer, N steps must perform zero heap
//! allocations — the invariant the scratch-buffer design exists for.

use greendt::benchkit::bench;
use greendt::config::testbeds;
use greendt::cpusim::CpuState;
use greendt::dataset::{partition_files_capped, standard};
use greendt::sim::Simulation;
use greendt::transfer::TransferEngine;
use greendt::units::SimDuration;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to the system allocator unchanged;
// the counter is a relaxed atomic with no other side effects.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// A world with `tenants` active large-dataset sessions (large files so no
/// partition completes mid-audit, which would legitimately reopen
/// channels).
fn fleet_sim(tenants: usize, channels_each: u32) -> Simulation {
    let tb = testbeds::cloudlab();
    let mut sim = Simulation::empty(
        &tb,
        CpuState::performance(tb.client_cpu.clone()),
        SimDuration::from_millis(100.0),
        9,
        Vec::new(),
    );
    for i in 0..tenants {
        let ds = standard::large_dataset(20 + i as u64);
        let parts = partition_files_capped(&ds, tb.bdp(), 5);
        let mut engine =
            TransferEngine::with_knee(&parts, tb.link.avg_win, tb.link.knee_streams());
        engine.set_num_channels(channels_each);
        let slot = sim.add_slot(engine);
        sim.activate_slot(slot);
    }
    sim
}

fn main() {
    println!("== bench_fleet: multi-tenant step hot path ==\n");

    // Timing across fleet sizes.
    for tenants in [1usize, 4, 16] {
        let mut sim = fleet_sim(tenants, 4);
        bench(&format!("fleet step/{tenants} tenants"), 200, 5000, || sim.step());
    }
    println!();

    // Allocation audit: warm up (scratch buffers grow to steady-state
    // capacity, TCP windows leave slow start), then count.
    let mut sim = fleet_sim(4, 4);
    for _ in 0..500 {
        sim.step();
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    let steps = 2000u64;
    for _ in 0..steps {
        sim.step();
    }
    let allocs = ALLOCS.load(Ordering::Relaxed) - before;
    println!("allocation audit: {allocs} allocations across {steps} steps (4 tenants)");
    assert_eq!(
        allocs, 0,
        "the fleet step path must stay allocation-free per tick"
    );
    println!("allocation audit passed: step is allocation-free\n");
}
