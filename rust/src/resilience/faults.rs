//! Scripted fault events: host failures and link degradations.
//!
//! A fault schedule is data, not behavior: it names *what* goes wrong
//! and *when*, and the dispatcher decides what that means for the
//! sessions involved (preemption, retry, dead-lettering). Host
//! failures model the transfer service dying — the host stops serving
//! sessions and admits nothing until its optional revival — while link
//! degradations model a path collapse beyond the everyday
//! [`BandwidthEvent`](crate::netsim::BandwidthEvent) variation: the
//! dispatcher maps them onto the host's background-traffic process and
//! lets the health monitor notice the goodput crater.
//!
//! The schedule expands into a [`FaultTimeline`]: one sorted stream of
//! [`FaultAction`]s the dispatcher pops at segment boundaries with the
//! same `at <= now + 1e-9` comparison scripted
//! [`PowerCapEvent`](crate::sim::dispatcher::PowerCapEvent)s use, so
//! fault ordering is deterministic and shard-invariant by construction.

use crate::units::SimTime;

/// A host dying at a scheduled instant, optionally reviving later.
///
/// Failure means the transfer *service* crashes: every running session
/// is lost (its delivered bytes stay delivered; the remainder must be
/// re-sent elsewhere) and the host admits nothing while down. The
/// host's meters keep running — a crashed daemon does not power off
/// the machine, and the fleet keeps paying its idle draw.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostFailureEvent {
    /// Index of the host (into the dispatcher's host list).
    pub host: usize,
    /// When the host dies.
    pub at: SimTime,
    /// When the host comes back (`None` = never during this run).
    pub revive_at: Option<SimTime>,
}

/// A scripted link collapse on one host: from `at` until `until` the
/// background-traffic mean jumps to `mean_fraction` (the fraction of
/// the bottleneck *lost* to cross traffic — `0.95` leaves sessions 5%
/// of the link). Restoration returns the mean to the testbed's own
/// level. The process ceiling still applies, so extreme fractions
/// clamp at the link's `max_fraction`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkDegradeEvent {
    /// Index of the host whose link degrades.
    pub host: usize,
    /// When the collapse starts.
    pub at: SimTime,
    /// When the link recovers.
    pub until: SimTime,
    /// Background fraction in force while degraded, in `[0, 1)`.
    pub mean_fraction: f64,
}

/// The full fault script of one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSchedule {
    /// Host deaths (and revivals), in any order.
    pub host_failures: Vec<HostFailureEvent>,
    /// Link collapses, in any order.
    pub link_degrades: Vec<LinkDegradeEvent>,
}

impl FaultSchedule {
    /// True when the script contains no events at all.
    pub fn is_empty(&self) -> bool {
        self.host_failures.is_empty() && self.link_degrades.is_empty()
    }

    /// Append a host failure.
    pub fn with_host_failure(
        mut self,
        host: usize,
        at: SimTime,
        revive_at: Option<SimTime>,
    ) -> Self {
        self.host_failures.push(HostFailureEvent { host, at, revive_at });
        self
    }

    /// Append a link degradation.
    pub fn with_link_degrade(
        mut self,
        host: usize,
        at: SimTime,
        until: SimTime,
        mean_fraction: f64,
    ) -> Self {
        self.link_degrades.push(LinkDegradeEvent { host, at, until, mean_fraction });
        self
    }

    /// Validate the script against a fleet of `hosts` hosts: every host
    /// index must be in range, revivals must follow deaths, and
    /// degradation windows must be non-empty with a fraction in
    /// `[0, 1)`.
    pub fn validate(&self, hosts: usize) -> Result<(), String> {
        for f in &self.host_failures {
            if f.host >= hosts {
                return Err(format!("fault references host {} of a {hosts}-host fleet", f.host));
            }
            if let Some(r) = f.revive_at {
                if r.as_secs() <= f.at.as_secs() {
                    return Err(format!(
                        "host {} revives at {}s, not after its death at {}s",
                        f.host,
                        r.as_secs(),
                        f.at.as_secs()
                    ));
                }
            }
        }
        for d in &self.link_degrades {
            if d.host >= hosts {
                return Err(format!("fault references host {} of a {hosts}-host fleet", d.host));
            }
            if d.until.as_secs() <= d.at.as_secs() {
                return Err(format!(
                    "host {} link degrade window [{}s, {}s] is empty",
                    d.host,
                    d.at.as_secs(),
                    d.until.as_secs()
                ));
            }
            if !(0.0..1.0).contains(&d.mean_fraction) {
                return Err(format!(
                    "degrade fraction {} must be in [0, 1)",
                    d.mean_fraction
                ));
            }
        }
        Ok(())
    }

    /// Parse the CLI fault grammar: semicolon-separated clauses of
    /// `down:host=H,at=T[,revive=T2]` and
    /// `degrade:host=H,at=T,until=T2,frac=F` (times in simulated
    /// seconds). Whitespace around clauses is ignored.
    ///
    /// # Examples
    ///
    /// ```
    /// use greendt::resilience::FaultSchedule;
    ///
    /// let s = FaultSchedule::parse("down:host=1,at=300,revive=900; degrade:host=0,at=60,until=240,frac=0.9")
    ///     .expect("valid spec");
    /// assert_eq!(s.host_failures.len(), 1);
    /// assert_eq!(s.link_degrades.len(), 1);
    /// ```
    pub fn parse(spec: &str) -> Result<FaultSchedule, String> {
        let mut schedule = FaultSchedule::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (kind, rest) = clause
                .split_once(':')
                .ok_or_else(|| format!("fault clause '{clause}' needs a 'kind:' prefix"))?;
            let mut host: Option<usize> = None;
            let mut at: Option<f64> = None;
            let mut until: Option<f64> = None;
            let mut revive: Option<f64> = None;
            let mut frac: Option<f64> = None;
            for pair in rest.split(',') {
                let (key, value) = pair
                    .trim()
                    .split_once('=')
                    .ok_or_else(|| format!("fault field '{pair}' needs key=value"))?;
                let parse_f = || {
                    value
                        .parse::<f64>()
                        .map_err(|_| format!("fault field '{key}' has non-numeric value '{value}'"))
                };
                match key {
                    "host" => {
                        host = Some(value.parse::<usize>().map_err(|_| {
                            format!("fault field 'host' has non-integer value '{value}'")
                        })?)
                    }
                    "at" => at = Some(parse_f()?),
                    "until" => until = Some(parse_f()?),
                    "revive" => revive = Some(parse_f()?),
                    "frac" => frac = Some(parse_f()?),
                    other => return Err(format!("unknown fault field '{other}'")),
                }
            }
            let host = host.ok_or_else(|| format!("fault clause '{clause}' needs host="))?;
            let at = at.ok_or_else(|| format!("fault clause '{clause}' needs at="))?;
            match kind.trim() {
                "down" => schedule.host_failures.push(HostFailureEvent {
                    host,
                    at: SimTime::from_secs(at),
                    revive_at: revive.map(SimTime::from_secs),
                }),
                "degrade" => schedule.link_degrades.push(LinkDegradeEvent {
                    host,
                    at: SimTime::from_secs(at),
                    until: SimTime::from_secs(
                        until.ok_or_else(|| format!("fault clause '{clause}' needs until="))?,
                    ),
                    mean_fraction: frac
                        .ok_or_else(|| format!("fault clause '{clause}' needs frac="))?,
                }),
                other => return Err(format!("unknown fault kind '{other}'")),
            }
        }
        Ok(schedule)
    }

    /// Expand the script into its sorted action stream.
    pub fn timeline(&self) -> FaultTimeline {
        let mut actions = Vec::new();
        for f in &self.host_failures {
            actions.push(FaultAction {
                at: f.at,
                host: f.host,
                kind: FaultKind::HostDown,
                mean_fraction: 0.0,
            });
            if let Some(r) = f.revive_at {
                actions.push(FaultAction {
                    at: r,
                    host: f.host,
                    kind: FaultKind::HostUp,
                    mean_fraction: 0.0,
                });
            }
        }
        for d in &self.link_degrades {
            actions.push(FaultAction {
                at: d.at,
                host: d.host,
                kind: FaultKind::LinkDegrade,
                mean_fraction: d.mean_fraction,
            });
            actions.push(FaultAction {
                at: d.until,
                host: d.host,
                kind: FaultKind::LinkRestore,
                mean_fraction: 0.0,
            });
        }
        // Total order: time, then host, then kind rank — simultaneous
        // actions fire in one deterministic sequence on every run and
        // every shard count.
        actions.sort_by(|a, b| {
            a.at.as_secs()
                .total_cmp(&b.at.as_secs())
                .then_with(|| a.host.cmp(&b.host))
                .then_with(|| a.kind.rank().cmp(&b.kind.rank()))
        });
        FaultTimeline { actions, next: 0 }
    }
}

/// What kind of fault action fired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A host died.
    HostDown,
    /// A dead host came back (empty, admitting again).
    HostUp,
    /// A link collapsed to its scripted degraded fraction.
    LinkDegrade,
    /// A degraded link recovered to the testbed mean.
    LinkRestore,
}

impl FaultKind {
    /// Stable identifier (telemetry tables and JSON lines).
    pub fn id(&self) -> &'static str {
        match self {
            FaultKind::HostDown => "host-down",
            FaultKind::HostUp => "host-up",
            FaultKind::LinkDegrade => "link-degrade",
            FaultKind::LinkRestore => "link-restore",
        }
    }

    /// Sort rank for simultaneous actions (deaths before revivals
    /// before link changes at the same instant).
    fn rank(&self) -> u8 {
        match self {
            FaultKind::HostDown => 0,
            FaultKind::HostUp => 1,
            FaultKind::LinkDegrade => 2,
            FaultKind::LinkRestore => 3,
        }
    }
}

/// One expanded, timestamped fault action.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultAction {
    /// When the action fires.
    pub at: SimTime,
    /// The host it targets.
    pub host: usize,
    /// What happens.
    pub kind: FaultKind,
    /// Degraded background fraction (meaningful for
    /// [`FaultKind::LinkDegrade`] only; `0.0` otherwise).
    pub mean_fraction: f64,
}

/// The sorted action stream of one run, consumed front to back as the
/// dispatcher's segment clock passes each action's instant.
#[derive(Debug, Clone)]
pub struct FaultTimeline {
    actions: Vec<FaultAction>,
    next: usize,
}

impl FaultTimeline {
    /// Pop the next action due at or before `now_secs` (the dispatcher
    /// calls this in a loop at each segment boundary, with the same
    /// `1e-9` epsilon every scripted event in the driver uses).
    pub fn pop_due(&mut self, now_secs: f64) -> Option<FaultAction> {
        let a = self.actions.get(self.next)?;
        if a.at.as_secs() <= now_secs + 1e-9 {
            self.next += 1;
            Some(*a)
        } else {
            None
        }
    }

    /// When the next unfired action fires (`None` once exhausted) —
    /// folded into the dispatcher's segment horizon so a fault can
    /// never fire late.
    pub fn next_at(&self) -> Option<SimTime> {
        self.actions.get(self.next).map(|a| a.at)
    }

    /// True once every action has fired.
    pub fn is_exhausted(&self) -> bool {
        self.next >= self.actions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_both_clause_kinds() {
        let s = FaultSchedule::parse(
            "down:host=1,at=300,revive=900 ; degrade:host=0,at=60,until=240,frac=0.9",
        )
        .expect("valid");
        assert_eq!(s.host_failures.len(), 1);
        assert_eq!(s.host_failures[0].host, 1);
        assert_eq!(s.host_failures[0].at, SimTime::from_secs(300.0));
        assert_eq!(s.host_failures[0].revive_at, Some(SimTime::from_secs(900.0)));
        assert_eq!(s.link_degrades.len(), 1);
        assert_eq!(s.link_degrades[0].mean_fraction, 0.9);
        assert!(s.validate(2).is_ok());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultSchedule::parse("boom:host=0,at=1").is_err());
        assert!(FaultSchedule::parse("down:at=1").is_err(), "missing host");
        assert!(FaultSchedule::parse("down:host=0").is_err(), "missing at");
        assert!(FaultSchedule::parse("degrade:host=0,at=1,frac=0.5").is_err(), "missing until");
        assert!(FaultSchedule::parse("down:host=0,at=x").is_err(), "non-numeric");
        assert!(FaultSchedule::parse("down:host=0,at=1,bogus=2").is_err(), "unknown field");
        // The empty spec is the empty schedule, not an error.
        assert!(FaultSchedule::parse("").expect("ok").is_empty());
    }

    #[test]
    fn validate_catches_out_of_range_and_inverted_windows() {
        let s = FaultSchedule::default().with_host_failure(3, SimTime::from_secs(10.0), None);
        assert!(s.validate(2).is_err());
        let s = FaultSchedule::default().with_host_failure(
            0,
            SimTime::from_secs(10.0),
            Some(SimTime::from_secs(5.0)),
        );
        assert!(s.validate(2).is_err(), "revive before death");
        let s = FaultSchedule::default().with_link_degrade(
            0,
            SimTime::from_secs(10.0),
            SimTime::from_secs(5.0),
            0.9,
        );
        assert!(s.validate(2).is_err(), "empty window");
        let s = FaultSchedule::default().with_link_degrade(
            0,
            SimTime::from_secs(5.0),
            SimTime::from_secs(10.0),
            1.5,
        );
        assert!(s.validate(2).is_err(), "fraction out of range");
    }

    #[test]
    fn timeline_fires_in_time_order_with_the_event_epsilon() {
        let s = FaultSchedule::default()
            .with_link_degrade(0, SimTime::from_secs(60.0), SimTime::from_secs(240.0), 0.9)
            .with_host_failure(1, SimTime::from_secs(30.0), Some(SimTime::from_secs(90.0)));
        let mut t = s.timeline();
        assert_eq!(t.next_at(), Some(SimTime::from_secs(30.0)));
        assert!(t.pop_due(29.0).is_none(), "not due yet");
        let a = t.pop_due(30.0).expect("due");
        assert_eq!((a.host, a.kind), (1, FaultKind::HostDown));
        // The epsilon admits an action the clock lands exactly on.
        let a = t.pop_due(60.0 - 5e-10).expect("within epsilon");
        assert_eq!(a.kind, FaultKind::LinkDegrade);
        assert_eq!(a.mean_fraction, 0.9);
        assert!(!t.is_exhausted());
        assert!(t.pop_due(1000.0).is_some()); // host-up @ 90
        assert!(t.pop_due(1000.0).is_some()); // link-restore @ 240
        assert!(t.pop_due(1000.0).is_none());
        assert!(t.is_exhausted());
        assert_eq!(t.next_at(), None);
    }

    #[test]
    fn simultaneous_actions_order_deterministically() {
        let s = FaultSchedule::default()
            .with_host_failure(1, SimTime::from_secs(10.0), None)
            .with_host_failure(0, SimTime::from_secs(10.0), None)
            .with_link_degrade(0, SimTime::from_secs(10.0), SimTime::from_secs(20.0), 0.5);
        let mut t = s.timeline();
        let a = t.pop_due(10.0).expect("first");
        let b = t.pop_due(10.0).expect("second");
        let c = t.pop_due(10.0).expect("third");
        assert_eq!((a.host, a.kind), (0, FaultKind::HostDown));
        assert_eq!((b.host, b.kind), (0, FaultKind::LinkDegrade));
        assert_eq!((c.host, c.kind), (1, FaultKind::HostDown));
    }
}
