//! Wide-area network substrate.
//!
//! The paper's testbeds (Table I) are WAN paths with a single bottleneck
//! link. The tuning algorithms never see packets — they observe *throughput
//! over time* as a function of how many TCP streams they open and how they
//! pipeline requests. This module reproduces exactly that observable
//! surface:
//!
//! * [`Link`] — bottleneck capacity, RTT, and a mean-reverting background
//!   cross-traffic process (plus scripted bandwidth events for failure
//!   injection, and optional seeded [`CrossTraffic`] generators — a
//!   steady UDP floor plus bursty TCP flows — for contended-path
//!   scenarios);
//! * [`StreamState`] — per-TCP-connection congestion window with slow
//!   start, giving new channels the ramp-up that Algorithm 2 (Slow Start)
//!   corrects for;
//! * [`share_goodput`] — fair-share allocation with an overload penalty
//!   past the stream-count knee, producing the concave
//!   throughput-vs-channels curve that the FSM algorithms search.

mod background;
mod crosstraffic;
mod link;
mod stream;

pub use background::{BackgroundTraffic, BandwidthEvent};
pub use crosstraffic::{CrossTraffic, CrossTrafficConfig, MAX_CROSS_FRACTION};
pub use link::{share_goodput, share_goodput_into, AllocCache, Link, LinkParams};
pub use stream::StreamState;
