//! Multi-tenant host: four concurrent transfer sessions sharing one
//! client CPU package and one bottleneck link, under two fleet policies.
//!
//!     cargo run --release --example fleet_tenants
//!
//! `fair-share` is the static reference (performance governor, equal
//! channel budget); `min-energy-fleet` generalizes the paper's
//! Algorithm 3 from one session's CPU load to the host's *aggregate*
//! load. The figure of merit is the host energy bill per served tenant.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, FleetPolicyKind};
use greendt::sim::fleet::{run_fleet, FleetConfig, FleetOutcome, TenantSpec};
use greendt::units::SimTime;

fn run_policy(policy: FleetPolicyKind) -> FleetOutcome {
    let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(policy)).with_seed(42);
    for i in 0..4u64 {
        cfg.tenants.push(
            TenantSpec::new(
                format!("tenant-{i}"),
                greendt::dataset::standard::medium_dataset(42 + i),
                AlgorithmKind::MaxThroughput,
            )
            // Staggered arrivals: the host sees between 1 and 4 sessions.
            .arriving_at(SimTime::from_secs(25.0 * i as f64)),
        );
    }
    run_fleet(&cfg)
}

fn report(out: &FleetOutcome) {
    println!("policy: {}", out.policy);
    for t in &out.tenants {
        println!(
            "  {:<9} arrive {:>5.0}s  finish {:>6.0}s  {:>9}  {:>11}  energy share {}",
            t.name,
            t.arrived_at.as_secs(),
            t.finished_at.map(|x| x.as_secs()).unwrap_or(f64::NAN),
            format!("{}", t.moved),
            format!("{}", t.avg_throughput),
            t.attributed_energy,
        );
    }
    println!(
        "  makespan {}  host energy {}  => energy/tenant {}\n",
        out.duration,
        out.client_energy,
        out.energy_per_tenant()
    );
}

fn main() {
    println!("== fleet_tenants: 4 sessions on one CloudLab client ==\n");

    let fair = run_policy(FleetPolicyKind::FairShare);
    report(&fair);

    let eco = run_policy(FleetPolicyKind::MinEnergyFleet);
    report(&eco);

    let saved = 100.0
        * (1.0 - eco.client_energy.as_joules() / fair.client_energy.as_joules());
    println!(
        "aggregate-load scaling saves {saved:.1}% host energy vs the static governor \
         ({} -> {} per tenant)",
        fair.energy_per_tenant(),
        eco.energy_per_tenant()
    );
}
