//! Acceptance tests for the fleet rebalancer (ISSUE 5).
//!
//! Pins the three headline properties:
//!
//! * **byte conservation** — a migrated session delivers exactly its
//!   dataset's bytes, split across the partial run on the source host
//!   and the resumed run on the target, with a visible slow-start dip
//!   after the move (the migration cost is simulated, not waived);
//! * **cap pressure pays** — on a hot-spot arrival script with a
//!   mid-run power-cap squeeze, `--rebalance cap-pressure` finishes
//!   with strictly fewer joules at equal-or-better total goodput than
//!   `--rebalance off`;
//! * **`Off` is inert** — a dispatcher with the rebalance policy off is
//!   bit-for-bit today's dispatcher, and an active policy whose cost
//!   gate never passes executes zero moves and matches it too.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::dataset::{generate, standard, DatasetSpec};
use greendt::rebalance::{MigrationCost, RebalanceConfig, RebalancePolicyKind};
use greendt::sim::dispatcher::{run_dispatcher, DispatcherConfig, HostSpec, SessionSpec};
use greendt::units::{Bytes, Power, SimDuration, SimTime};

/// The hot-spot scenario both migration tests build on: a single-slot
/// efficient host (CloudLab/Broadwell) next to a legacy one
/// (DIDCLab/Bloomfield, wall-metered). A short session takes the
/// efficient slot first, so the long one that arrives moments later is
/// stranded on the legacy host — exactly the placement the dispatcher
/// would never choose for it on an empty fleet.
fn hotspot_cfg(big: greendt::dataset::Dataset, legacy_slots: u32) -> DispatcherConfig {
    let hosts = vec![
        HostSpec::new("efficient", testbeds::cloudlab()).with_max_sessions(1),
        HostSpec::new("legacy", testbeds::didclab()).with_max_sessions(legacy_slots),
    ];
    let sessions = vec![
        SessionSpec::new("s0", standard::medium_dataset(301), AlgorithmKind::MaxThroughput),
        SessionSpec::new("s1", big, AlgorithmKind::MaxThroughput)
            .arriving_at(SimTime::from_secs(5.0)),
    ];
    DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(61)
}

#[test]
fn migration_conserves_bytes_and_pays_a_slow_start_dip() {
    let big = standard::large_dataset(302);
    let total = big.total_size().as_f64();
    let mut cfg = hotspot_cfg(big, 4);
    cfg.rebalance = RebalanceConfig::new(RebalancePolicyKind::MarginalEnergyDelta);
    cfg.record_timeline = true;
    let out = run_dispatcher(&cfg);
    assert!(out.fleet.completed, "every session must finish");
    assert!(out.unplaced.is_empty());

    // Exactly one move: the stranded session leaves the legacy host for
    // the efficient one once the short session departs and the marginal
    // gap pays for the migration.
    assert_eq!(out.migrations.len(), 1, "got {:?}", out.migrations);
    let m = &out.migrations[0];
    assert_eq!(m.session, "s1");
    assert_eq!((m.from.as_str(), m.to.as_str()), ("legacy", "efficient"));
    assert_eq!((m.from_host, m.to_host), (1, 0));
    assert_eq!(m.policy, "marginal-delta");
    assert!(m.moved_bytes > 0.0 && m.remaining_bytes > 0.0);
    assert!(
        (m.moved_bytes + m.remaining_bytes - total).abs() < 16.0,
        "the record itself conserves bytes: {} + {} vs {total}",
        m.moved_bytes,
        m.remaining_bytes
    );
    assert!(
        (m.resume_at_secs - m.t_secs - m.drain_secs).abs() < 1e-9,
        "resume = preemption + drain"
    );
    assert!(m.est_benefit_j > m.est_cost_j, "the move must have paid on paper");

    // Partial-run accounting: two outcomes under one name — the
    // preempted residency on the legacy host, the completed one on the
    // efficient host — and their moved bytes sum to the dataset.
    let s1: Vec<_> = out.fleet.tenants.iter().filter(|t| t.name == "s1").collect();
    assert_eq!(s1.len(), 2, "partial + resumed outcome");
    let (partial, resumed) = (s1[0], s1[1]);
    assert_eq!(partial.host, "legacy");
    assert!(partial.preempted && !partial.completed);
    assert_eq!(resumed.host, "efficient");
    assert!(resumed.completed && !resumed.preempted);
    let delivered = partial.moved.as_f64() + resumed.moved.as_f64();
    assert!(
        (delivered - total).abs() < 16.0,
        "byte conservation across the migration: {delivered} vs {total}"
    );
    assert!(
        (partial.moved.as_f64() - m.moved_bytes).abs() < 1.0
            && (resumed.moved.as_f64() - m.remaining_bytes).abs() < 16.0,
        "outcomes agree with the migration record"
    );
    // The handoff really took the drain delay: the resumed residency
    // starts one drain after the preemption instant.
    assert!(
        (resumed.arrived_at.as_secs() - (m.t_secs + m.drain_secs)).abs() < 1e-6,
        "re-admission at the resume instant, got {} vs {}",
        resumed.arrived_at.as_secs(),
        m.t_secs + m.drain_secs
    );

    // Visible slow-start dip: the resumed run re-enters TCP slow start
    // (cold congestion windows ramp over several RTTs) and the
    // coordinator's slow-start FSM, so its first tuning interval moves
    // bytes measurably below the later steady state. The ramp costs a
    // few percent of the first 3-second interval at minimum; require a
    // 2% dip so the assertion is insensitive to background noise.
    let first = resumed.timeline.first().expect("timeline recorded").throughput;
    let peak = resumed
        .timeline
        .iter()
        .map(|p| p.throughput.as_bytes_per_sec())
        .fold(0.0f64, f64::max);
    assert!(
        first.as_bytes_per_sec() < 0.98 * peak,
        "slow-start dip after the move: first interval {} vs peak {}",
        first.as_bytes_per_sec(),
        peak
    );

    // The re-admission shows up in the decision log as its own
    // placement (s0, s1, s1-resume).
    assert_eq!(out.decisions.len(), 3);
    assert_eq!(out.decisions[2].session, "s1");
    assert_eq!(out.decisions[2].admitted_host, Some(0));
}

#[test]
fn cap_pressure_squeeze_saves_joules_at_no_goodput_loss() {
    // ~114 GB: long enough that most of the transfer happens after the
    // short session departs, so where it runs dominates the fleet bill.
    let big = || {
        let spec =
            DatasetSpec::new("big", 512, Bytes::from_mb(222.78), Bytes::from_mb(15.19));
        generate(&spec, 303)
    };

    // Probe the fleet's projections from an uncapped run's first
    // decision (both hosts idle there, so the scores give P(0)/P(1) for
    // each host), then pick a cap between the pre-move and post-move
    // steady-state projections of the stranded phase.
    let probe = run_dispatcher(&hotspot_cfg(big(), 1));
    assert!(probe.fleet.completed);
    let first = &probe.decisions[0];
    let eff = first.scores.iter().find(|s| s.host == "efficient").unwrap();
    let leg = first.scores.iter().find(|s| s.host == "legacy").unwrap();
    let pre_move = eff.current_power_w + leg.projected_power_w; // s1 stuck on legacy
    let post_move = eff.projected_power_w + leg.current_power_w; // s1 moved
    assert!(
        post_move + 0.5 < pre_move,
        "the legacy host must project the bigger marginal draw: {post_move} vs {pre_move}"
    );
    let cap = Power::from_watts(0.5 * (pre_move + post_move));

    // Same script, cap squeezed mid-run, rebalancer off vs cap-pressure.
    let squeezed = |policy: RebalancePolicyKind| {
        let mut cfg = hotspot_cfg(big(), 1)
            .with_cap_event(SimTime::from_secs(50.0), Some(cap));
        cfg.rebalance = RebalanceConfig::new(policy);
        run_dispatcher(&cfg)
    };
    let off = squeezed(RebalancePolicyKind::Off);
    let cap_run = squeezed(RebalancePolicyKind::CapPressure);
    assert!(off.fleet.completed && cap_run.fleet.completed);
    assert!(off.migrations.is_empty(), "off must never move anything");
    assert_eq!(cap_run.migrations.len(), 1, "the squeeze must force one move");
    let m = &cap_run.migrations[0];
    assert_eq!((m.from.as_str(), m.to.as_str()), ("legacy", "efficient"));
    assert_eq!(m.policy, "cap-pressure");
    // The move only fires after the efficient slot frees up — while the
    // fleet was saturated there was nowhere to shed watts to.
    assert!(m.t_secs > 50.0, "no feasible move before the slot frees");

    // Headline: strictly fewer joules …
    let off_j = off.fleet.client_energy.as_joules();
    let cap_j = cap_run.fleet.client_energy.as_joules();
    assert!(
        cap_j < off_j,
        "cap-pressure rebalancing must save energy: {cap_j:.0} vs {off_j:.0} J"
    );

    // … at equal-or-better total goodput: the same bytes move, and the
    // makespan shrinks because the efficient host also carries them
    // faster than the legacy one.
    assert!(
        (off.fleet.moved.as_f64() - cap_run.fleet.moved.as_f64()).abs() < 32.0,
        "both runs deliver the same workload"
    );
    let goodput = |f: &greendt::sim::fleet::FleetOutcome| {
        f.moved.as_f64() / f.duration.as_secs()
    };
    assert!(
        goodput(&cap_run.fleet) >= goodput(&off.fleet),
        "rebalancing may not lose aggregate goodput: {} vs {}",
        goodput(&cap_run.fleet),
        goodput(&off.fleet)
    );
}

#[test]
fn off_policy_is_bit_for_bit_todays_dispatcher() {
    // One overlapping two-host scenario, run three ways: the default
    // config (no rebalance field touched), an explicit `Off`, and a
    // marginal-delta rebalancer whose hysteresis gate can never pass.
    // All three must agree to the bit — the rebalancer's presence alone
    // may not perturb a single tick.
    let mk = || {
        let hosts = vec![
            HostSpec::new("efficient", testbeds::cloudlab()),
            HostSpec::new("legacy", testbeds::didclab()),
        ];
        let sessions = vec![
            SessionSpec::new(
                "a",
                standard::medium_dataset(401),
                AlgorithmKind::MaxThroughput,
            ),
            SessionSpec::new(
                "b",
                standard::medium_dataset(402),
                AlgorithmKind::MaxThroughput,
            )
            .arriving_at(SimTime::from_secs(20.0)),
        ];
        DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
            .with_sessions(sessions)
            .with_seed(91)
    };
    let baseline = run_dispatcher(&mk());

    let mut explicit_off = mk();
    explicit_off.rebalance = RebalanceConfig::new(RebalancePolicyKind::Off);
    let explicit_off = run_dispatcher(&explicit_off);

    let mut gated = mk();
    gated.rebalance = RebalanceConfig::new(RebalancePolicyKind::MarginalEnergyDelta)
        .with_cost(MigrationCost {
            drain: SimDuration::from_secs(5.0),
            min_gain: 1e12, // benefit can never clear the gate
        });
    let gated = run_dispatcher(&gated);

    for (label, other) in [("explicit off", &explicit_off), ("gated delta", &gated)] {
        assert!(other.migrations.is_empty(), "{label}: no moves may execute");
        assert_eq!(
            baseline.fleet.client_energy.as_joules().to_bits(),
            other.fleet.client_energy.as_joules().to_bits(),
            "{label}: fleet energy must be bit-identical"
        );
        assert_eq!(
            baseline.fleet.duration.as_secs().to_bits(),
            other.fleet.duration.as_secs().to_bits(),
            "{label}: makespan must be bit-identical"
        );
        assert_eq!(baseline.decisions.len(), other.decisions.len());
        for (x, y) in baseline.decisions.iter().zip(&other.decisions) {
            assert_eq!(x.session, y.session);
            assert_eq!(x.admitted_host, y.admitted_host);
            assert_eq!(
                x.projected_fleet_power_w.to_bits(),
                y.projected_fleet_power_w.to_bits()
            );
        }
        for (x, y) in baseline.fleet.tenants.iter().zip(&other.fleet.tenants) {
            assert_eq!(x.host, y.host, "{label}: same placements");
            assert_eq!(
                x.attributed_energy.as_joules().to_bits(),
                y.attributed_energy.as_joules().to_bits(),
                "{label}: per-tenant energy must be bit-identical"
            );
            assert!(!x.preempted && !y.preempted);
        }
    }
}
