//! Session driver: one complete transfer under one tuning algorithm.

use crate::config::experiment::TunerParams;
use crate::config::Testbed;
use crate::coordinator::AlgorithmKind;
use crate::dataset::Dataset;
use crate::sim::Simulation;
use crate::transfer::TransferEngine;
use crate::units::{Bytes, Energy, Freq, Rate, SimDuration};

/// Everything needed to run one session.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub testbed: Testbed,
    pub dataset: Dataset,
    pub algorithm: AlgorithmKind,
    pub params: TunerParams,
    pub seed: u64,
    pub tick: SimDuration,
    /// Abort the session after this much simulated time.
    pub max_sim_time: SimDuration,
    /// Record a per-timeout timeline (costs memory; reports/examples).
    pub record_timeline: bool,
    /// Scripted background-traffic events (failure injection / the
    /// `adaptive_bandwidth` example).
    pub bandwidth_events: Vec<crate::netsim::BandwidthEvent>,
    /// GreenDT extension: Algorithm-3 scaling on the *server* too (the
    /// paper's testbeds scale only the client).
    pub server_scaling: bool,
}

impl SessionConfig {
    pub fn new(testbed: Testbed, dataset: Dataset, algorithm: AlgorithmKind) -> Self {
        SessionConfig {
            testbed,
            dataset,
            algorithm,
            params: TunerParams::default(),
            seed: 42,
            tick: SimDuration::from_millis(100.0),
            max_sim_time: SimDuration::from_secs(14_400.0),
            record_timeline: false,
            bandwidth_events: Vec::new(),
            server_scaling: false,
        }
    }

    /// Enable the server-side scaling extension.
    pub fn with_server_scaling(mut self) -> Self {
        self.server_scaling = true;
        self
    }

    /// Inject scripted bandwidth events into the session's path.
    pub fn with_bandwidth_events(mut self, events: Vec<crate::netsim::BandwidthEvent>) -> Self {
        self.bandwidth_events = events;
        self
    }

    pub fn with_params(mut self, params: TunerParams) -> Self {
        self.params = params;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn recording(mut self) -> Self {
        self.record_timeline = true;
        self
    }
}

/// One point of the per-timeout timeline.
#[derive(Debug, Clone, Copy)]
pub struct TimelinePoint {
    pub t_secs: f64,
    /// FSM state the algorithm was in when this interval was observed.
    pub fsm: &'static str,
    pub throughput: Rate,
    pub channels: u32,
    pub active_cores: u32,
    pub freq: Freq,
    pub cpu_load: f64,
    pub power_w: f64,
}

/// What one session produced — the quantities the paper's figures plot.
#[derive(Debug, Clone)]
pub struct SessionOutcome {
    pub algorithm: String,
    pub testbed: String,
    pub dataset: String,
    pub completed: bool,
    pub duration: SimDuration,
    pub moved: Bytes,
    /// Whole-session average application throughput.
    pub avg_throughput: Rate,
    /// Client energy per the testbed's instrument (RAPL or wall meter).
    pub client_energy: Energy,
    /// Client package (RAPL) energy, regardless of instrument.
    pub client_package_energy: Energy,
    pub server_energy: Energy,
    pub final_active_cores: u32,
    pub final_freq: Freq,
    pub peak_channels: u32,
    pub timeline: Vec<TimelinePoint>,
}

impl SessionOutcome {
    /// Client + server package energy: the "end systems" total.
    pub fn total_energy(&self) -> Energy {
        self.client_package_energy + self.server_energy
    }
}

/// Run a session to completion (or the time cap).
pub fn run_session(cfg: &SessionConfig) -> SessionOutcome {
    let mut algo = cfg.algorithm.build(cfg.params);
    let plan = algo.init(&cfg.testbed, &cfg.dataset);

    let mut engine = TransferEngine::with_knee(
        &plan.partitions,
        cfg.testbed.link.avg_win,
        cfg.testbed.link.knee_streams(),
    );
    if plan.handshake_rtts > 0.0 {
        for i in 0..plan.partitions.len() {
            engine.set_handshake_rtts(i, plan.handshake_rtts);
        }
    }
    engine.update_weights();
    engine.set_num_channels(plan.num_channels);

    let mut sim = Simulation::with_bandwidth_events(
        &cfg.testbed,
        engine,
        plan.client_cpu,
        cfg.tick,
        cfg.seed,
        cfg.bandwidth_events.clone(),
    );
    sim.server_autoscale = cfg.server_scaling;

    let total = sim.engine.total();
    let timeout = algo.timeout();
    let mut next_timeout = timeout;
    let mut peak_channels = sim.engine.num_channels();
    let mut timeline = Vec::new();

    while !sim.is_done() && sim.now.as_secs() < cfg.max_sim_time.as_secs() {
        sim.step();
        peak_channels = peak_channels.max(sim.engine.num_channels());
        if sim.now.as_secs() + 1e-9 >= next_timeout.as_secs() {
            let tel = sim.drain_telemetry();
            if cfg.record_timeline {
                timeline.push(TimelinePoint {
                    t_secs: tel.now.as_secs(),
                    fsm: algo.fsm_label(),
                    throughput: tel.avg_throughput,
                    channels: tel.num_channels,
                    active_cores: sim.client.active_cores(),
                    freq: sim.client.freq(),
                    cpu_load: tel.cpu_load,
                    power_w: tel.avg_power.as_watts(),
                });
            }
            algo.on_timeout(&tel, &mut sim);
            next_timeout = next_timeout + timeout;
        }
    }

    let completed = sim.is_done();
    let duration = sim.now.since(crate::units::SimTime::ZERO);
    let moved = total.saturating_sub(sim.engine.remaining());

    SessionOutcome {
        algorithm: algo.name().to_string(),
        testbed: cfg.testbed.name.to_string(),
        dataset: cfg.dataset.name.clone(),
        completed,
        duration,
        moved,
        avg_throughput: Rate::average(moved, duration),
        client_energy: sim.client_energy(),
        client_package_energy: sim.client_rapl.total(),
        server_energy: sim.server_energy(),
        final_active_cores: sim.client.active_cores(),
        final_freq: sim.client.freq(),
        peak_channels,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::standard;

    #[test]
    fn eemt_session_on_cloudlab_medium() {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::medium_dataset(1),
            AlgorithmKind::MaxThroughput,
        );
        let out = run_session(&cfg);
        assert!(out.completed, "must finish within the cap");
        // 11.7 GB over 1 Gbps is at least ~94 s.
        assert!(out.duration.as_secs() > 90.0);
        assert!(out.avg_throughput.as_mbps() > 500.0, "tput {}", out.avg_throughput);
        assert!(out.client_energy.as_joules() > 0.0);
        assert!((out.moved.as_gb() - 11.7).abs() < 0.5);
    }

    #[test]
    fn timeline_recorded_when_asked() {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::large_dataset(1),
            AlgorithmKind::MaxThroughput,
        )
        .recording();
        let out = run_session(&cfg);
        assert!(!out.timeline.is_empty());
        // Time increases monotonically.
        for w in out.timeline.windows(2) {
            assert!(w[1].t_secs > w[0].t_secs);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            SessionConfig::new(
                testbeds::didclab(),
                standard::large_dataset(9),
                AlgorithmKind::MinEnergy,
            )
            .with_seed(123)
        };
        let a = run_session(&mk());
        let b = run_session(&mk());
        assert_eq!(a.duration.as_secs(), b.duration.as_secs());
        assert_eq!(a.client_energy.as_joules(), b.client_energy.as_joules());
    }

    #[test]
    fn seed_changes_outcome_slightly() {
        let base = SessionConfig::new(
            testbeds::didclab(),
            standard::large_dataset(9),
            AlgorithmKind::MinEnergy,
        );
        let a = run_session(&base.clone().with_seed(1));
        let b = run_session(&base.with_seed(2));
        assert_ne!(
            a.client_energy.as_joules(),
            b.client_energy.as_joules(),
            "background noise must differ across seeds"
        );
    }

    #[test]
    fn total_energy_combines_nodes() {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::large_dataset(1),
            AlgorithmKind::MaxThroughput,
        );
        let out = run_session(&cfg);
        assert!(out.total_energy() > out.client_package_energy);
        assert!(out.total_energy() > out.server_energy);
    }
}
