"""Pure-jnp oracle for the predictor kernel.

Implements the analytic throughput / power / energy model (DESIGN.md §5)
with plain vectorized jax.numpy — no Pallas. This is the correctness
reference the Pallas kernel is tested against, and it mirrors formula-for-
formula the Rust-side oracle (`rust/src/predictor/reference.rs`).
"""

import jax.numpy as jnp

from . import layout as L

EPS = 1e-9
# Energy reported for infeasible candidates (zero cores / zero throughput):
# large enough to lose every argmin, small enough to stay finite in f32.
INFEASIBLE_ENERGY = 1e30


def predict_ref(cand, state):
    """Evaluate all candidates against the transfer state.

    Args:
      cand: float32[N, 3] — (channels, active_cores, freq_ghz) rows.
      state: float32[STATE_WIDTH] — see `layout`.

    Returns:
      float32[N, 3] — (throughput_Bps, power_W, energy_J) rows.
    """
    cand = jnp.asarray(cand, jnp.float32)
    state = jnp.asarray(state, jnp.float32)

    channels = cand[:, L.CAND_CHANNELS]
    cores = cand[:, L.CAND_CORES]
    freq = cand[:, L.CAND_FREQ_GHZ]

    capacity = state[L.S_CAPACITY_BPS]
    rtt = state[L.S_RTT_S]
    avg_win = state[L.S_AVG_WIN_BYTES]
    knee = state[L.S_KNEE_STREAMS]
    gamma = state[L.S_OVERLOAD_GAMMA]
    floor = state[L.S_OVERLOAD_FLOOR]
    par = state[L.S_PARALLELISM]
    remaining = state[L.S_REMAINING_BYTES]
    avg_file = state[L.S_AVG_FILE_BYTES]
    pp = state[L.S_PP_LEVEL]
    cpb = state[L.S_CYCLES_PER_BYTE]
    cpr = state[L.S_CYCLES_PER_REQ]
    cps = state[L.S_CYCLES_PER_STREAM]
    max_util = state[L.S_MAX_APP_UTIL]

    # --- Network side (mirrors netsim::share_goodput + pipelining) -------
    streams = channels * par
    win_rate = avg_win / jnp.maximum(rtt, EPS)  # bytes/s per stream
    over = jnp.maximum(streams - knee, 0.0) / jnp.maximum(knee, EPS)
    penalty = jnp.maximum(1.0 / (1.0 + gamma * over), floor)
    net = jnp.minimum(streams * win_rate, capacity * penalty)

    # Pipelining efficiency: time/file = max(S/r, RTT/pp) per channel.
    r_chan = net / jnp.maximum(channels, EPS)
    xfer = avg_file / jnp.maximum(r_chan, EPS)
    paced = jnp.maximum(xfer, rtt / jnp.maximum(pp, 1.0))
    eff = xfer / jnp.maximum(paced, EPS)
    net_eff = net * eff

    # --- CPU side (mirrors cpusim) ----------------------------------------
    cap_cycles = cores * freq * 1e9 * max_util
    req_rate_net = net_eff / jnp.maximum(avg_file, EPS)
    overhead = req_rate_net * cpr + streams * cps
    cpu_bytes = jnp.maximum(cap_cycles - overhead, 0.0) / jnp.maximum(cpb, EPS)
    tput = jnp.minimum(net_eff, cpu_bytes)

    # Load implied by the achieved throughput.
    req_rate = tput / jnp.maximum(avg_file, EPS)
    demand = tput * cpb + req_rate * cpr + streams * cps
    cap_full = cores * freq * 1e9
    load = demand / jnp.maximum(cap_full, EPS)
    util = jnp.clip(load, 0.0, 1.0)

    # --- Power (mirrors power::PowerModel) ---------------------------------
    v_min = state[L.S_V_MIN]
    v_max = state[L.S_V_MAX]
    f_min = state[L.S_F_MIN_GHZ]
    f_max = state[L.S_F_MAX_GHZ]
    t = jnp.clip((freq - f_min) / jnp.maximum(f_max - f_min, EPS), 0.0, 1.0)
    v = v_min + (v_max - v_min) * t
    per_core_idle = (
        state[L.S_CORE_IDLE_BASE_W] + state[L.S_CORE_IDLE_PER_GHZ_W] * freq
    )
    per_core_dyn = util * state[L.S_DYN_KAPPA] * v * v * freq
    dram = state[L.S_DRAM_W_PER_GBS] * tput / 1e9
    power = state[L.S_PKG_STATIC_W] + cores * (per_core_idle + per_core_dyn) + dram

    # --- Energy projection ---------------------------------------------------
    feasible = tput > EPS
    energy = jnp.where(
        feasible,
        power * remaining / jnp.maximum(tput, EPS),
        INFEASIBLE_ENERGY,
    )
    tput = jnp.where(feasible, tput, 0.0)

    return jnp.stack([tput, power, energy], axis=1)
