//! The paper's testbeds (Table I).
//!
//! | Testbed   | Bandwidth | RTT   | BDP    | CPUs |
//! |-----------|-----------|-------|--------|------|
//! | Chameleon | 10 Gbps   | 32 ms | 40 MB  | Haswell server + Haswell client |
//! | CloudLab  | 1 Gbps    | 36 ms | 4.5 MB | Haswell server + Broadwell client |
//! | DIDCLab   | 1 Gbps    | 44 ms | 5.5 MB | Haswell server + Bloomfield client |

use crate::cpusim::{standard as cpus, CpuSpec};
use crate::netsim::{BackgroundTraffic, Link, LinkParams};
use crate::units::{Bytes, Power, Rate, SimDuration};

/// A complete evaluation environment: WAN path + the two end systems.
#[derive(Debug, Clone)]
pub struct Testbed {
    /// Testbed name as the paper spells it.
    pub name: &'static str,
    /// Bottleneck WAN path parameters.
    pub link: LinkParams,
    /// Mean background cross-traffic fraction on the bottleneck.
    pub bg_mean: f64,
    /// Client (tunable) CPU model.
    pub client_cpu: CpuSpec,
    /// Server CPU model.
    pub server_cpu: CpuSpec,
    /// Platform base power (wall meter minus package) on the client.
    pub client_base_power: Power,
    /// True if the client energy is read from a wall meter (DIDCLab's
    /// Yokogawa WT210) rather than RAPL.
    pub wall_meter: bool,
}

impl Testbed {
    /// Build the live link for a session (background process + events are
    /// per-session state).
    pub fn make_link(&self) -> Link {
        Link::new(self.link.clone(), BackgroundTraffic::quiet(self.bg_mean))
    }

    /// Link with a fully deterministic background (tests).
    pub fn make_link_constant_bg(&self) -> Link {
        Link::new(self.link.clone(), BackgroundTraffic::constant(self.bg_mean))
    }

    /// Live link with scripted background events (failure injection).
    pub fn make_link_with_events(
        &self,
        events: Vec<crate::netsim::BandwidthEvent>,
    ) -> Link {
        Link::new(
            self.link.clone(),
            BackgroundTraffic::quiet(self.bg_mean).with_events(events),
        )
    }

    /// Deterministic *constant* background at the testbed's mean, plus
    /// scripted step events. Between events such a background is frozen
    /// ([`BackgroundTraffic::is_frozen`]), which is the link-side
    /// precondition for warm-epoch tick batching — large-scale fleet
    /// runs and the `bench_scale` sweep use this link so warm epochs
    /// batch instead of paying a (no-op) OU step per tick.
    pub fn make_link_constant_bg_with_events(
        &self,
        events: Vec<crate::netsim::BandwidthEvent>,
    ) -> Link {
        Link::new(
            self.link.clone(),
            BackgroundTraffic::constant(self.bg_mean).with_events(events),
        )
    }

    /// Like [`Self::make_link_with_events`] with seeded cross-traffic
    /// generators (steady UDP floor + bursty TCP flows) composed on top
    /// of the OU background — the contended-path scenarios. The
    /// generators derive their RNG stream from `seed`, so the load
    /// trajectory is a pure function of `(cross, seed)`; such a link is
    /// never frozen ([`Link::bg_frozen`]), so warm-epoch tick batching
    /// stays off.
    pub fn make_link_with_cross_traffic(
        &self,
        events: Vec<crate::netsim::BandwidthEvent>,
        cross: crate::netsim::CrossTrafficConfig,
        seed: u64,
    ) -> Link {
        self.make_link_with_events(events)
            .with_cross_traffic(crate::netsim::CrossTraffic::new(cross, seed))
    }

    /// Bandwidth-delay product of the path.
    pub fn bdp(&self) -> Bytes {
        self.link.bdp()
    }
}

/// Chameleon Cloud: UChicago → TACC, 10 Gbps, 32 ms.
pub fn chameleon() -> Testbed {
    Testbed {
        name: "Chameleon",
        link: LinkParams {
            capacity: Rate::from_gbps(10.0),
            rtt: SimDuration::from_millis(32.0),
            // A single stream reaches ~750 Mbps on this path (3 MB average
            // window over 32 ms) — large-BDP WANs are loss-limited well
            // below the pipe, which is why concurrency tuning matters.
            avg_win: Bytes::from_mb(3.0),
            overload_gamma: 0.015,
            overload_floor: 0.55,
        },
        bg_mean: 0.12,
        client_cpu: cpus::haswell_client(),
        server_cpu: cpus::haswell_server(),
        client_base_power: Power::from_watts(45.0),
        wall_meter: false,
    }
}

/// CloudLab: Wisconsin → Utah, 1 Gbps, 36 ms.
pub fn cloudlab() -> Testbed {
    Testbed {
        name: "CloudLab",
        link: LinkParams {
            capacity: Rate::from_gbps(1.0),
            rtt: SimDuration::from_millis(36.0),
            avg_win: Bytes::from_mb(1.0),
            overload_gamma: 0.02,
            overload_floor: 0.55,
        },
        bg_mean: 0.08,
        client_cpu: cpus::broadwell_client(),
        server_cpu: cpus::haswell_server(),
        client_base_power: Power::from_watts(40.0),
        wall_meter: false,
    }
}

/// DIDCLab: UChicago → Buffalo, 1 Gbps, 44 ms, older client hardware,
/// busier path (campus network).
pub fn didclab() -> Testbed {
    Testbed {
        name: "DIDCLab",
        link: LinkParams {
            capacity: Rate::from_gbps(1.0),
            rtt: SimDuration::from_millis(44.0),
            avg_win: Bytes::from_mb(1.0),
            overload_gamma: 0.03,
            overload_floor: 0.5,
        },
        bg_mean: 0.15,
        client_cpu: cpus::bloomfield_client(),
        server_cpu: cpus::haswell_server(),
        client_base_power: Power::from_watts(55.0),
        wall_meter: true,
    }
}

/// All three testbeds in paper order.
pub fn all() -> Vec<Testbed> {
    vec![chameleon(), cloudlab(), didclab()]
}

/// Look a testbed up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Testbed> {
    match name.to_ascii_lowercase().as_str() {
        "chameleon" => Some(chameleon()),
        "cloudlab" => Some(cloudlab()),
        "didclab" => Some(didclab()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bdps_match_table1() {
        assert!((chameleon().bdp().as_mb() - 40.0).abs() < 0.5);
        assert!((cloudlab().bdp().as_mb() - 4.5).abs() < 0.1);
        assert!((didclab().bdp().as_mb() - 5.5).abs() < 0.1);
    }

    #[test]
    fn client_cpus_match_table1() {
        assert!(chameleon().client_cpu.name.starts_with("Haswell"));
        assert!(cloudlab().client_cpu.name.starts_with("Broadwell"));
        assert!(didclab().client_cpu.name.starts_with("Bloomfield"));
        for tb in all() {
            assert!(tb.server_cpu.name.starts_with("Haswell"), "{}", tb.name);
        }
    }

    #[test]
    fn only_didclab_uses_wall_meter() {
        assert!(didclab().wall_meter);
        assert!(!chameleon().wall_meter);
        assert!(!cloudlab().wall_meter);
    }

    #[test]
    fn by_name_is_case_insensitive() {
        assert!(by_name("Chameleon").is_some());
        assert!(by_name("CHAMELEON").is_some());
        assert!(by_name("didclab").is_some());
        assert!(by_name("unknown").is_none());
    }

    #[test]
    fn knee_stream_counts_are_plausible() {
        // Enough streams should be needed that concurrency tuning matters.
        for tb in all() {
            let knee = tb.link.knee_streams();
            assert!((2.0..20.0).contains(&knee), "{}: knee {knee}", tb.name);
        }
    }
}
