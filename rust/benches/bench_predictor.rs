//! Predictor benchmarks: PJRT decision latency + the governor ablation.
//!
//!     make artifacts && cargo bench --bench bench_predictor
//!
//! (a) Latency of one candidate-grid evaluation through the compiled
//!     JAX/Pallas artifact vs the pure-Rust oracle — the cost ME pays per
//!     tuning timeout when running the predictive governor.
//! (b) Ablation: identical ME sessions under threshold (Alg. 3),
//!     predictive (PJRT), and OS-only governors.

use greendt::benchkit::{bench, time_once};
use greendt::config::experiment::TunerParams;
use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::cpusim::standard::broadwell_client;
use greendt::dataset::standard;
use greendt::predictor::{cpu_grid, demo_state_for_tests, Predictor};
use greendt::sim::session::{run_session, SessionConfig};

fn main() {
    println!("== bench_predictor ==\n");

    let grid = cpu_grid(&broadwell_client(), 8);
    let state = demo_state_for_tests();

    let oracle = Predictor::oracle();
    bench("oracle grid eval (110 candidates)", 50, 1000, || {
        oracle.predict(&grid, &state).unwrap()
    });

    match Predictor::from_artifact(&greendt::runtime::default_predictor_path()) {
        Ok(pjrt) => {
            bench("PJRT grid eval (110 candidates)", 50, 1000, || {
                pjrt.predict(&grid, &state).unwrap()
            });
        }
        Err(e) => println!("PJRT artifact unavailable ({e:#}); run `make artifacts`"),
    }
    println!();

    // Governor ablation on an identical workload.
    let mk = |params: TunerParams, label: &'static str| {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::mixed_dataset(42),
            AlgorithmKind::MinEnergy,
        )
        .with_params(params);
        let (out, _) = time_once(label, || run_session(&cfg));
        out
    };
    let threshold = mk(TunerParams::default(), "ME session, threshold governor");
    let predictive = mk(TunerParams::default().predictive(), "ME session, predictive governor");
    let os_only = mk(TunerParams::default().without_scaling(), "ME session, OS governor only");

    println!("\n  governor    throughput      client energy");
    for (name, o) in
        [("threshold", &threshold), ("predictive", &predictive), ("os-only", &os_only)]
    {
        println!(
            "  {:<10}  {:>12}  {:>16}",
            name,
            format!("{}", o.avg_throughput),
            format!("{}", o.client_energy)
        );
    }
}
