//! The rebalancer itself: scan host snapshots, propose at most one move.
//!
//! Pure over [`HostView`] snapshots (no simulation access), exactly like
//! [`Dispatcher::place`](crate::sim::dispatcher::Dispatcher::place) one
//! layer down — decisions are deterministic, unit-testable and replayable
//! from telemetry. The dispatcher builds the views at segment boundaries
//! from the same occupancy-keyed projections placement scoring uses
//! (`HostWorld::projected_power_w` / `projected_session_bps`), executes a
//! returned [`MoveProposal`] (preempt → drain → re-admit), and records a
//! [`MigrationRecord`](crate::sim::MigrationRecord).

use std::collections::BTreeMap;

use super::cost::MigrationCost;
use super::policy::{RebalanceConfig, RebalancePolicyKind};

/// Minimum projected fleet-power reduction (W) a cap-pressure move must
/// deliver. Guards against moves whose only effect is churn when two
/// hosts' marginal draws are within measurement noise of each other.
const MIN_POWER_DROP_W: f64 = 0.5;

/// One running session as the rebalancer sees it.
#[derive(Debug, Clone)]
pub struct SessionView {
    /// Index of the tenant inside its host's world (what the executor
    /// hands back so the dispatcher can preempt the right slot).
    pub tenant: usize,
    /// Session name (move budgets are kept per name, which survives the
    /// migration).
    pub name: String,
    /// Bytes the session still has to move — what a move would re-admit.
    pub remaining_bytes: f64,
}

/// One host's snapshot at a segment boundary: the occupancy-keyed power
/// and goodput projections around its current session count. All powers
/// are whole-host *instrument* projections (wall-metered hosts include
/// their platform base), the same convention admission control caps.
#[derive(Debug, Clone)]
pub struct HostView {
    /// Index of the host in the dispatcher's host list.
    pub host: usize,
    /// Sessions currently resident (registered and unfinished).
    pub active: u32,
    /// Session slots still free (0 = cannot be a migration target).
    pub free_slots: u32,
    /// Projected draw with no sessions at all, W — the idle floor.
    pub idle_power_w: f64,
    /// Projected draw at the current session count, W.
    pub power_now_w: f64,
    /// Projected draw with one session fewer, W (equals the idle floor
    /// when one session is resident).
    pub power_minus_one_w: f64,
    /// Projected draw with one session more, W.
    pub power_plus_one_w: f64,
    /// Expected per-session goodput at the current count, bytes/s.
    pub session_bps_now: f64,
    /// Expected per-session goodput with one session more, bytes/s.
    pub session_bps_plus_one: f64,
    /// Expected goodput of a session running *alone* here, bytes/s —
    /// the baseline the contention price is measured against.
    pub session_bps_alone: f64,
    /// Path round-trip time, seconds (prices the slow-start re-ramp).
    pub rtt_s: f64,
    /// The sessions running here, in tenant order.
    pub sessions: Vec<SessionView>,
}

impl HostView {
    /// Marginal watts released if one resident session departs.
    fn marginal_out_w(&self) -> f64 {
        (self.power_now_w - self.power_minus_one_w).max(0.0)
    }

    /// Marginal watts added if one more session is admitted.
    fn marginal_in_w(&self) -> f64 {
        (self.power_plus_one_w - self.power_now_w).max(0.0)
    }

    /// Contention price at `bps_shared` (see
    /// [`contention_price_j_per_byte`](super::contention_price_j_per_byte)
    /// — the same formula admission scoring uses). This is what keeps
    /// the rebalancer from "consolidating" sessions onto a
    /// link-saturated host: there the *marginal watts* of one more
    /// session are near zero (the link caps aggregate demand), but
    /// everyone's residency stretches.
    fn contention_price(&self, bps_shared: f64) -> f64 {
        super::contention_price_j_per_byte(self.idle_power_w, bps_shared, self.session_bps_alone)
    }

    /// Effective J/B a resident session pays by *staying* here: marginal
    /// watts over its goodput, plus the contention price it is already
    /// suffering. Infinite when the host moves nothing.
    fn jpb_stay(&self) -> f64 {
        if self.session_bps_now <= 0.0 {
            f64::INFINITY
        } else {
            self.marginal_out_w() / self.session_bps_now
                + self.contention_price(self.session_bps_now)
        }
    }

    /// Effective J/B an incoming session would pay here: marginal watts
    /// over its post-move goodput, plus the contention it would create.
    fn jpb_in(&self) -> f64 {
        if self.session_bps_plus_one <= 0.0 {
            f64::INFINITY
        } else {
            self.marginal_in_w() / self.session_bps_plus_one
                + self.contention_price(self.session_bps_plus_one)
        }
    }

    /// Watts of this host's idle draw effectively stranded by the
    /// contention an incoming session would create:
    /// `idle_W × (1 − bps_shared/bps_alone)` — the contention price
    /// expressed in watts (price × post-move goodput), so cap-pressure
    /// can net it against a projected watt drop. Zero on an
    /// uncontended target; approaches the full idle draw as the
    /// target's link saturates.
    fn contention_toll_w(&self) -> f64 {
        if self.session_bps_alone <= 0.0 {
            return 0.0;
        }
        let ratio = (self.session_bps_plus_one / self.session_bps_alone).clamp(0.0, 1.0);
        (self.idle_power_w * (1.0 - ratio)).max(0.0)
    }
}

/// One move the rebalancer wants executed: preempt `session` on `from`,
/// re-admit its remaining bytes on `to` after the drain delay.
#[derive(Debug, Clone, PartialEq)]
pub struct MoveProposal {
    /// Session name.
    pub session: String,
    /// Tenant index inside the source host's world.
    pub tenant: usize,
    /// Source host index.
    pub from: usize,
    /// Target host index.
    pub to: usize,
    /// Estimated joules saved by serving the remaining bytes on the
    /// target instead (may be negative for cap-pressure moves — the cap
    /// is a constraint, not an optimization).
    pub est_benefit_j: f64,
    /// Estimated joules the move itself burns (drain + slow-start
    /// re-ramp; see [`MigrationCost::estimate_joules`]).
    pub est_cost_j: f64,
    /// Projected fleet-power reduction of the move, W.
    pub est_power_drop_w: f64,
}

/// The cost-model verdict on one candidate move — the audit trail the
/// tracer turns into `rebalance_proposal` events, *including rejected
/// candidates* (ISSUE 9). Produced by [`Rebalancer::propose_audited`];
/// [`Rebalancer::propose`] evaluates the same candidates without
/// recording them.
#[derive(Debug, Clone, PartialEq)]
pub struct MoveVerdict {
    /// Session name of the candidate.
    pub session: String,
    /// Source host index.
    pub from: usize,
    /// Target host index.
    pub to: usize,
    /// Estimated joules saved on the remaining bytes.
    pub est_benefit_j: f64,
    /// Estimated joules the move would burn (drain + re-ramp).
    pub est_cost_j: f64,
    /// Projected fleet-power change of the move, W (cap-pressure nets
    /// the contention toll off this figure before ranking).
    pub est_power_drop_w: f64,
    /// True for the one candidate the policy picked this boundary.
    pub accepted: bool,
    /// Why the candidate was (not) picked: `picked`, `outscored`,
    /// `cost-hysteresis`, `cap-worsened`, or `below-min-drop`.
    pub reason: &'static str,
}

impl MoveVerdict {
    /// Predicted net joules saved by the move: estimated benefit minus
    /// the drain/re-ramp toll. Positive means the cost model expects
    /// the move to pay for itself; the calibration ledger compares this
    /// against the realized benefit at residency close.
    pub fn net_j(&self) -> f64 {
        self.est_benefit_j - self.est_cost_j
    }
}

/// The rebalancer: policy + cost model + per-session move budgets.
#[derive(Debug, Clone)]
pub struct Rebalancer {
    cfg: RebalanceConfig,
    /// Moves already executed, by session name.
    moves: BTreeMap<String, u32>,
}

impl Rebalancer {
    /// A rebalancer for `cfg`. An `Off` config never proposes anything.
    pub fn new(cfg: RebalanceConfig) -> Rebalancer {
        Rebalancer { cfg, moves: BTreeMap::new() }
    }

    /// True when the policy can ever propose a move — the dispatcher
    /// skips snapshot building entirely otherwise, so `Off` adds zero
    /// work to the segment loop.
    pub fn active(&self) -> bool {
        self.cfg.policy != RebalancePolicyKind::Off
    }

    /// The configured drain/handoff delay (the dispatcher holds a
    /// preempted session out of every host for exactly this long).
    pub fn drain(&self) -> crate::units::SimDuration {
        self.cfg.migration_cost.drain
    }

    /// The policy in charge.
    pub fn policy(&self) -> RebalancePolicyKind {
        self.cfg.policy
    }

    /// True when advisory-driven evacuation may propose moves. Separate
    /// from [`Self::active`] on purpose: evacuation works even with the
    /// trigger policy `Off` (damage control needs no optimization policy
    /// to be on), so the dispatcher consults this flag — alongside its
    /// own recovery switch — before building snapshots for it.
    pub fn evacuates(&self) -> bool {
        self.cfg.evacuate_on_advisory
    }

    /// Record that `session` was moved (spends one unit of its budget).
    pub fn note_move(&mut self, session: &str) {
        *self.moves.entry(session.to_string()).or_insert(0) += 1;
    }

    /// True while `session` still has move budget left.
    fn eligible(&self, session: &str) -> bool {
        self.moves.get(session).copied().unwrap_or(0) < self.cfg.max_moves_per_session
    }

    /// Scan the fleet and propose at most one move (the dispatcher calls
    /// this once per segment boundary; multi-move rebalances happen one
    /// boundary at a time, which keeps every step priced against fresh
    /// projections). `cap_w` is the *effective* admission power cap at
    /// this instant, if any.
    pub fn propose(&self, hosts: &[HostView], cap_w: Option<f64>) -> Option<MoveProposal> {
        self.propose_inner(hosts, cap_w, None)
    }

    /// Like [`Self::propose`], additionally recording a [`MoveVerdict`]
    /// for *every* candidate evaluated — picked, outscored, or gated by
    /// the cost model — into `audit`. The returned proposal is
    /// bit-identical to [`Self::propose`] on the same inputs; auditing
    /// only observes the scan, it never changes it. The tracer is the
    /// intended caller.
    pub fn propose_audited(
        &self,
        hosts: &[HostView],
        cap_w: Option<f64>,
        audit: &mut Vec<MoveVerdict>,
    ) -> Option<MoveProposal> {
        self.propose_inner(hosts, cap_w, Some(audit))
    }

    fn propose_inner(
        &self,
        hosts: &[HostView],
        cap_w: Option<f64>,
        audit: Option<&mut Vec<MoveVerdict>>,
    ) -> Option<MoveProposal> {
        match self.cfg.policy {
            RebalancePolicyKind::Off => None,
            RebalancePolicyKind::CapPressure => {
                self.propose_cap_pressure(hosts, cap_w?, audit)
            }
            RebalancePolicyKind::MarginalEnergyDelta => {
                self.propose_delta(hosts, cap_w, audit)
            }
        }
    }

    /// Record one candidate's verdict (no-op without an audit sink).
    #[allow(clippy::too_many_arguments)]
    fn audit_push(
        audit: &mut Option<&mut Vec<MoveVerdict>>,
        s: &SessionView,
        from: usize,
        to: usize,
        benefit: f64,
        cost: f64,
        drop_w: f64,
        reason: &'static str,
    ) {
        if let Some(a) = audit.as_deref_mut() {
            a.push(MoveVerdict {
                session: s.name.clone(),
                from,
                to,
                est_benefit_j: benefit,
                est_cost_j: cost,
                est_power_drop_w: drop_w,
                accepted: false,
                reason,
            });
        }
    }

    /// Promote the winning candidate's verdict to `accepted`/`picked`.
    fn audit_pick(audit: &mut Option<&mut Vec<MoveVerdict>>, mv: &MoveProposal) {
        if let Some(a) = audit.as_deref_mut() {
            if let Some(v) = a
                .iter_mut()
                .find(|v| v.session == mv.session && v.from == mv.from && v.to == mv.to)
            {
                v.accepted = true;
                v.reason = "picked";
            }
        }
    }

    /// Evacuate one session off a health-degraded host (see
    /// [`HealthMonitor`](crate::resilience::HealthMonitor)): the
    /// advisory already established the host is delivering a fraction
    /// of what it should, so — unlike [`Self::propose`] — the move is
    /// *not* benefit-gated; getting bytes off a dying host is damage
    /// control. `degraded[h]` marks host `h` as advised-against (both
    /// as a source to drain and as a target to avoid).
    ///
    /// Deterministic choice: the lowest-indexed degraded host with an
    /// eligible session; its session with the most remaining bytes
    /// (most future exposure; ties to the first in tenant order); the
    /// non-degraded target with a free slot and the lowest incoming
    /// J/B (ties to the lowest host index). One proposal per call —
    /// multi-session evacuations drain one segment boundary at a time,
    /// exactly like policy moves.
    pub fn propose_evacuation(
        &self,
        hosts: &[HostView],
        degraded: &[bool],
    ) -> Option<MoveProposal> {
        if !self.cfg.evacuate_on_advisory {
            return None;
        }
        for src in hosts.iter().filter(|h| degraded.get(h.host).copied().unwrap_or(false)) {
            let victim = src
                .sessions
                .iter()
                .filter(|s| s.remaining_bytes > 0.0 && self.eligible(&s.name))
                .max_by(|a, b| {
                    a.remaining_bytes
                        .total_cmp(&b.remaining_bytes)
                        // max_by keeps the *last* max on ties; invert the
                        // tenant order so the first tenant wins instead.
                        .then_with(|| b.tenant.cmp(&a.tenant))
                });
            let Some(victim) = victim else { continue };
            let target = hosts
                .iter()
                .filter(|dst| {
                    dst.host != src.host
                        && dst.free_slots > 0
                        && !degraded.get(dst.host).copied().unwrap_or(false)
                })
                .min_by(|a, b| {
                    a.jpb_in().total_cmp(&b.jpb_in()).then_with(|| a.host.cmp(&b.host))
                });
            let Some(target) = target else { continue };
            let drop_w = src.marginal_out_w() - target.marginal_in_w();
            return Some(self.proposal_for(hosts, victim, src.host, target.host, drop_w));
        }
        None
    }

    /// Projected fleet power after moving one session `from → to`.
    fn power_after(hosts: &[HostView], fleet_now_w: f64, from: usize, to: usize) -> f64 {
        fleet_now_w - hosts[from].marginal_out_w() + hosts[to].marginal_in_w()
    }

    /// The move candidates shared by both policies: every eligible
    /// session on every host, paired with every *other* host that has a
    /// free slot. Yields `(session, from, to)` in deterministic
    /// (host, tenant, target) order.
    fn candidates<'a>(
        &'a self,
        hosts: &'a [HostView],
    ) -> impl Iterator<Item = (&'a SessionView, usize, usize)> + 'a {
        hosts.iter().flat_map(move |src| {
            src.sessions
                .iter()
                .filter(move |s| s.remaining_bytes > 0.0 && self.eligible(&s.name))
                .flat_map(move |s| {
                    hosts
                        .iter()
                        .filter(move |dst| dst.host != src.host && dst.free_slots > 0)
                        .map(move |dst| (s, src.host, dst.host))
                })
        })
    }

    /// Cap pressure: only while the projected fleet power exceeds the
    /// cap. Picks the move shedding the most projected watts *net of the
    /// idle-watts the created contention strands* (a link-saturated sink
    /// drops projected watts for free but stretches every resident's
    /// residency — see [`HostView::contention_toll_w`]); ties break to
    /// the session with the most remaining bytes (longest future
    /// benefit), then to the first candidate in scan order.
    fn propose_cap_pressure(
        &self,
        hosts: &[HostView],
        cap_w: f64,
        mut audit: Option<&mut Vec<MoveVerdict>>,
    ) -> Option<MoveProposal> {
        let fleet_now: f64 = hosts.iter().map(|h| h.power_now_w).sum();
        if fleet_now <= cap_w + 1e-6 {
            return None;
        }
        // Scan with scalars only; the winning proposal (name clone, cost
        // estimate) is assembled once at the end.
        let mut best: Option<(f64, f64, (&SessionView, usize, usize, f64))> = None;
        for (s, from, to) in self.candidates(hosts) {
            let drop = fleet_now - Self::power_after(hosts, fleet_now, from, to);
            let net = drop - hosts[to].contention_toll_w();
            if net < MIN_POWER_DROP_W {
                Self::audit_push(&mut audit, s, from, to, 0.0, 0.0, net, "below-min-drop");
                continue;
            }
            Self::audit_push(&mut audit, s, from, to, 0.0, 0.0, net, "outscored");
            let better = match &best {
                Some((bn, br, _)) => {
                    net > *bn + 1e-12 || (net > *bn - 1e-12 && s.remaining_bytes > *br)
                }
                None => true,
            };
            if better {
                best = Some((net, s.remaining_bytes, (s, from, to, drop)));
            }
        }
        let mv =
            best.map(|(_, _, (s, from, to, drop))| self.proposal_for(hosts, s, from, to, drop));
        if let Some(mv) = &mv {
            Self::audit_pick(&mut audit, mv);
        }
        mv
    }

    /// Marginal-energy delta: move whenever the estimated saving on the
    /// remaining bytes clears the migration cost plus hysteresis. With a
    /// cap in force a move may never push the projection further above
    /// it. Picks the largest net (benefit − cost) saving.
    fn propose_delta(
        &self,
        hosts: &[HostView],
        cap_w: Option<f64>,
        mut audit: Option<&mut Vec<MoveVerdict>>,
    ) -> Option<MoveProposal> {
        let fleet_now: f64 = hosts.iter().map(|h| h.power_now_w).sum();
        let cost_model: &MigrationCost = &self.cfg.migration_cost;
        // Scan with scalars only (see `propose_cap_pressure`); benefit
        // and cost are pure functions of the views, so the winner's
        // proposal recomputes them identically.
        let mut best: Option<(f64, (&SessionView, usize, usize, f64))> = None;
        for (s, from, to) in self.candidates(hosts) {
            let after = Self::power_after(hosts, fleet_now, from, to);
            let benefit = s.remaining_bytes * (hosts[from].jpb_stay() - hosts[to].jpb_in());
            let cost = cost_model.estimate_joules(
                hosts[to].idle_power_w,
                hosts[to].marginal_in_w(),
                hosts[to].rtt_s,
            );
            let drop = fleet_now - after;
            if let Some(cap) = cap_w {
                // Never worsen a cap violation (reducing one is fine).
                if after > cap + 1e-9 && after > fleet_now - 1e-9 {
                    Self::audit_push(&mut audit, s, from, to, benefit, cost, drop, "cap-worsened");
                    continue;
                }
            }
            if !cost_model.worth_it(benefit, cost) {
                Self::audit_push(&mut audit, s, from, to, benefit, cost, drop, "cost-hysteresis");
                continue;
            }
            Self::audit_push(&mut audit, s, from, to, benefit, cost, drop, "outscored");
            let net = benefit - cost;
            let better = match &best {
                Some((bn, _)) => net > *bn + 1e-12,
                None => true,
            };
            if better {
                best = Some((net, (s, from, to, drop)));
            }
        }
        let mv = best.map(|(_, (s, from, to, drop))| self.proposal_for(hosts, s, from, to, drop));
        if let Some(mv) = &mv {
            Self::audit_pick(&mut audit, mv);
        }
        mv
    }

    /// Assemble the proposal record for one candidate move.
    fn proposal_for(
        &self,
        hosts: &[HostView],
        s: &SessionView,
        from: usize,
        to: usize,
        drop_w: f64,
    ) -> MoveProposal {
        let benefit = s.remaining_bytes * (hosts[from].jpb_stay() - hosts[to].jpb_in());
        let cost = self.cfg.migration_cost.estimate_joules(
            hosts[to].idle_power_w,
            hosts[to].marginal_in_w(),
            hosts[to].rtt_s,
        );
        MoveProposal {
            session: s.name.clone(),
            tenant: s.tenant,
            from,
            to,
            est_benefit_j: benefit,
            est_cost_j: cost,
            est_power_drop_w: drop_w,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A host serving `active` sessions with linear per-session power.
    fn host(idx: usize, active: u32, free: u32, idle_w: f64, per_session_w: f64) -> HostView {
        let sessions = (0..active)
            .map(|i| SessionView {
                tenant: i as usize,
                name: format!("h{idx}-s{i}"),
                remaining_bytes: 10e9,
            })
            .collect();
        HostView {
            host: idx,
            active,
            free_slots: free,
            idle_power_w: idle_w,
            power_now_w: idle_w + per_session_w * active as f64,
            power_minus_one_w: idle_w + per_session_w * active.saturating_sub(1) as f64,
            power_plus_one_w: idle_w + per_session_w * (active + 1) as f64,
            session_bps_now: 100e6,
            session_bps_plus_one: 100e6,
            session_bps_alone: 100e6,
            rtt_s: 0.04,
            sessions,
        }
    }

    fn delta_rebalancer() -> Rebalancer {
        Rebalancer::new(RebalanceConfig::new(RebalancePolicyKind::MarginalEnergyDelta))
    }

    #[test]
    fn off_policy_proposes_nothing_and_is_inactive() {
        let r = Rebalancer::new(RebalanceConfig::default());
        assert!(!r.active());
        let hosts = vec![host(0, 1, 3, 20.0, 40.0), host(1, 0, 4, 10.0, 5.0)];
        assert_eq!(r.propose(&hosts, Some(30.0)), None);
    }

    #[test]
    fn delta_moves_to_the_cheaper_host_when_the_gap_pays() {
        // Staying costs 40 W / 100 MB/s = 4e-7 J/B; moving costs 5 W /
        // 100 MB/s = 5e-8 J/B. Benefit on 10 GB ≈ 3500 J; cost ≈ 5 s ×
        // 10 W + ramp ≈ 53 J — clears the gate easily.
        let r = delta_rebalancer();
        let hosts = vec![host(0, 1, 3, 20.0, 40.0), host(1, 0, 4, 10.0, 5.0)];
        let mv = r.propose(&hosts, None).expect("the gap must pay for a move");
        assert_eq!((mv.from, mv.to), (0, 1));
        assert_eq!(mv.session, "h0-s0");
        assert!(mv.est_benefit_j > 3000.0, "benefit {:.0}", mv.est_benefit_j);
        assert!(mv.est_cost_j > 0.0 && mv.est_cost_j < mv.est_benefit_j);
        assert!(mv.est_power_drop_w > 30.0);
    }

    #[test]
    fn delta_respects_cost_hysteresis() {
        // Near-identical hosts: the saving cannot clear the migration
        // cost, so nothing moves even though host 1 is a hair cheaper.
        let r = delta_rebalancer();
        let hosts = vec![host(0, 1, 3, 20.0, 10.0), host(1, 0, 4, 20.0, 9.9)];
        assert_eq!(r.propose(&hosts, None), None);
    }

    #[test]
    fn delta_needs_a_free_slot_on_the_target() {
        let r = delta_rebalancer();
        let hosts = vec![host(0, 1, 3, 20.0, 40.0), host(1, 4, 0, 10.0, 5.0)];
        assert_eq!(r.propose(&hosts, None), None, "full targets are not targets");
    }

    #[test]
    fn move_budget_pins_a_session_after_its_last_move() {
        let mut r = Rebalancer::new(RebalanceConfig {
            max_moves_per_session: 1,
            ..RebalanceConfig::new(RebalancePolicyKind::MarginalEnergyDelta)
        });
        let hosts = vec![host(0, 1, 3, 20.0, 40.0), host(1, 0, 4, 10.0, 5.0)];
        let mv = r.propose(&hosts, None).expect("first move allowed");
        r.note_move(&mv.session);
        assert_eq!(r.propose(&hosts, None), None, "budget spent: session is pinned");
    }

    #[test]
    fn delta_never_consolidates_onto_a_saturated_host() {
        // Host 1 is link-saturated: taking one more session adds almost
        // no marginal watts (the raw marginal score calls it nearly
        // free), but it would halve every session's goodput. The
        // contention price must kill the move.
        let r = delta_rebalancer();
        let src = host(0, 1, 3, 20.0, 25.0); // 25 W / 100 MB/s staying
        let mut saturated = host(1, 1, 3, 30.0, 25.0);
        saturated.power_plus_one_w = saturated.power_now_w + 0.2; // ~free marginal
        saturated.session_bps_plus_one = 50e6; // …but everyone crawls
        assert_eq!(
            r.propose(&[src, saturated], None),
            None,
            "contention-priced target must not attract the session"
        );
    }

    #[test]
    fn cap_pressure_only_acts_above_the_cap() {
        let r = Rebalancer::new(RebalanceConfig::new(RebalancePolicyKind::CapPressure));
        let hosts = vec![host(0, 1, 3, 20.0, 40.0), host(1, 0, 4, 10.0, 5.0)];
        // Fleet projection = 60 + 10 = 70 W.
        assert_eq!(r.propose(&hosts, Some(80.0)), None, "under the cap: inert");
        assert_eq!(r.propose(&hosts, None), None, "no cap at all: inert");
        let mv = r.propose(&hosts, Some(50.0)).expect("above the cap: act");
        assert_eq!((mv.from, mv.to), (0, 1));
        // The move sheds 40 W and adds 5 W.
        assert!((mv.est_power_drop_w - 35.0).abs() < 1e-9);
    }

    #[test]
    fn cap_pressure_avoids_saturated_sinks() {
        // The saturated host sheds the most *projected* watts (its
        // marginal intake is nearly free because the link caps demand),
        // but its contention toll strands most of its idle draw — the
        // net ranking must prefer the genuinely idle host.
        let r = Rebalancer::new(RebalanceConfig::new(RebalancePolicyKind::CapPressure));
        let src = host(0, 1, 3, 20.0, 40.0);
        let mut saturated = host(1, 1, 3, 30.0, 25.0);
        saturated.power_plus_one_w = saturated.power_now_w + 0.2; // ~free intake
        saturated.session_bps_plus_one = 50e6; // …but everyone crawls
        let idle = host(2, 0, 4, 10.0, 15.0);
        let mv = r.propose(&[src, saturated, idle], Some(40.0)).expect("over the cap");
        // Raw drops: via saturated 39.8 W, via idle 25 W — but the
        // saturated toll (30 W × ½ = 15 W) nets it to 24.8 W, under the
        // idle host's 25 W.
        assert_eq!(mv.to, 2, "net-of-toll ranking must pick the idle sink");
        assert_eq!(mv.from, 0);
    }

    #[test]
    fn cap_pressure_picks_the_biggest_power_drop() {
        let r = Rebalancer::new(RebalanceConfig::new(RebalancePolicyKind::CapPressure));
        // Host 0 sheds 40 W/session, host 2 sheds 15 W/session; host 1 is
        // the cheap sink.
        let hosts = vec![
            host(0, 1, 3, 20.0, 40.0),
            host(1, 0, 4, 10.0, 5.0),
            host(2, 1, 3, 20.0, 15.0),
        ];
        let mv = r.propose(&hosts, Some(40.0)).expect("well above the cap");
        assert_eq!(mv.from, 0, "the hungriest host gives up its session");
        assert_eq!(mv.to, 1);
    }

    #[test]
    fn evacuation_drains_the_degraded_host_without_a_benefit_gate() {
        // Near-identical hosts: the delta policy refuses this move (the
        // saving cannot clear the migration cost — see
        // `delta_respects_cost_hysteresis`), but an advisory against
        // host 0 forces it anyway.
        let r = delta_rebalancer();
        let hosts = vec![host(0, 1, 3, 20.0, 10.0), host(1, 0, 4, 20.0, 9.9)];
        assert_eq!(r.propose(&hosts, None), None, "no policy move");
        let mv = r
            .propose_evacuation(&hosts, &[true, false])
            .expect("advisory must force the drain");
        assert_eq!((mv.from, mv.to), (0, 1));
        assert_eq!(mv.session, "h0-s0");
        // No advisory, no move; advisory against an empty host, no move;
        // evacuation disabled, no move.
        assert_eq!(r.propose_evacuation(&hosts, &[false, false]), None);
        assert_eq!(r.propose_evacuation(&hosts, &[false, true]), None);
        let off = Rebalancer::new(
            RebalanceConfig::new(RebalancePolicyKind::MarginalEnergyDelta)
                .with_evacuation(false),
        );
        assert_eq!(off.propose_evacuation(&hosts, &[true, false]), None);
    }

    #[test]
    fn evacuation_avoids_degraded_targets_and_respects_budgets() {
        // Both non-source hosts have slots, but host 1 is itself
        // degraded: the session must land on host 2 even though host 1
        // is cheaper.
        let mut r = Rebalancer::new(RebalanceConfig::new(RebalancePolicyKind::Off));
        assert!(!r.active(), "evacuation needs no trigger policy");
        let hosts = vec![
            host(0, 2, 2, 20.0, 40.0),
            host(1, 0, 4, 10.0, 5.0),
            host(2, 0, 4, 10.0, 15.0),
        ];
        let mv = r.propose_evacuation(&hosts, &[true, true, false]).unwrap();
        assert_eq!(mv.to, 2, "degraded hosts are not evacuation targets");
        // Equal remaining bytes: ties break to the first tenant.
        assert_eq!(mv.session, "h0-s0");
        // Spend both sessions' budgets: the degraded host still holds
        // them, but nothing is left to propose.
        r.note_move("h0-s0");
        r.note_move("h0-s0");
        r.note_move("h0-s1");
        r.note_move("h0-s1");
        assert_eq!(
            r.propose_evacuation(&hosts, &[true, true, false]),
            None,
            "move budgets still bind advisory moves"
        );
        // Everything degraded: nowhere to go.
        assert_eq!(r.propose_evacuation(&hosts, &[true, true, true]), None);
    }

    #[test]
    fn audited_propose_matches_plain_and_records_rejections() {
        let r = delta_rebalancer();
        // Three hosts: one winning target, one cost-gated near-twin of
        // the source — so the audit must carry both a pick and a
        // rejection.
        let hosts = vec![
            host(0, 1, 3, 20.0, 40.0),
            host(1, 0, 4, 10.0, 5.0),
            host(2, 0, 4, 20.0, 39.9),
        ];
        let plain = r.propose(&hosts, None);
        let mut audit = Vec::new();
        let audited = r.propose_audited(&hosts, None, &mut audit);
        assert_eq!(plain, audited, "auditing must not change the decision");
        let mv = audited.expect("the cheap host attracts the session");
        let picked: Vec<&MoveVerdict> = audit.iter().filter(|v| v.accepted).collect();
        assert_eq!(picked.len(), 1, "exactly one accepted verdict");
        assert_eq!(picked[0].reason, "picked");
        assert_eq!((picked[0].from, picked[0].to), (mv.from, mv.to));
        assert!(
            audit.iter().any(|v| !v.accepted && v.reason == "cost-hysteresis"),
            "the near-twin target must be recorded as cost-gated: {audit:?}"
        );
        // Cap-pressure audit carries `below-min-drop` rejections too.
        let rcap = Rebalancer::new(RebalanceConfig::new(RebalancePolicyKind::CapPressure));
        let mut audit = Vec::new();
        let capped = rcap.propose_audited(&hosts, Some(40.0), &mut audit);
        assert_eq!(capped, rcap.propose(&hosts, Some(40.0)));
        assert!(audit.iter().any(|v| v.accepted));
    }

    #[test]
    fn proposals_are_deterministic() {
        let r = delta_rebalancer();
        let hosts = vec![
            host(0, 2, 2, 20.0, 40.0),
            host(1, 0, 4, 10.0, 5.0),
            host(2, 0, 4, 10.0, 5.0),
        ];
        let a = r.propose(&hosts, None);
        let b = r.propose(&hosts, None);
        assert_eq!(a, b);
        // Equal-score targets tie-break to the first in scan order.
        assert_eq!(a.unwrap().to, 1);
    }

    #[test]
    fn verdict_net_is_benefit_minus_cost() {
        let v = MoveVerdict {
            session: "s".to_string(),
            from: 0,
            to: 1,
            est_benefit_j: 12.5,
            est_cost_j: 4.5,
            est_power_drop_w: 1.0,
            accepted: true,
            reason: "picked",
        };
        assert_eq!(v.net_j(), 8.0);
    }
}
