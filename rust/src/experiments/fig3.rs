//! Figure 3 — target-throughput tracking and energy, EETT vs Ismail-TT.
//!
//! Targets are 80/60/40/20 % of the nominal bandwidth on Chameleon and
//! CloudLab (DIDCLab is excluded, as in the paper, for its low available
//! bandwidth), on the mixed dataset. Paper shapes:
//! * EETT tracks within 5–10 % everywhere except the 8 Gbps Chameleon
//!   point (bandwidth-limited);
//! * Ismail-TT only reaches low targets (slow 1-channel ramp) and
//!   overshoots the lowest one;
//! * EETT uses ~20–29 % less energy at comparable targets.

use super::common::{fmt_energy_kj, run_cells, Cell};
use crate::coordinator::AlgorithmKind;
use crate::metrics::Table;
use crate::sim::session::SessionOutcome;
use crate::units::Rate;
use std::path::Path;

/// (testbed, bandwidth Mbps) panels of Figure 3.
pub const PANELS: [(&str, f64); 2] = [("chameleon", 10_000.0), ("cloudlab", 1_000.0)];
/// Target fractions of the nominal bandwidth.
pub const FRACTIONS: [f64; 4] = [0.8, 0.6, 0.4, 0.2];

/// All outcomes of the Figure 3 target-throughput comparison.
pub struct Fig3Results {
    /// (testbed, target, tool, outcome)
    pub outcomes: Vec<(String, Rate, String, SessionOutcome)>,
    /// Rendered tables.
    pub tables: Vec<Table>,
}

/// Run the Figure 3 panels at `seed`.
pub fn run(seed: u64) -> Fig3Results {
    let mut cells = Vec::new();
    let mut keys = Vec::new();
    for (tb, bw_mbps) in PANELS {
        for frac in FRACTIONS {
            let target = Rate::from_mbps(bw_mbps * frac);
            for (name, kind) in [
                ("EETT", AlgorithmKind::TargetThroughput(target)),
                ("Ismail-TT", AlgorithmKind::IsmailTarget(target)),
            ] {
                cells.push(Cell::new(tb, "mixed", kind).with_seed(seed));
                keys.push((tb.to_string(), target, name.to_string()));
            }
        }
    }
    let outs = run_cells(&cells);

    let mut outcomes = Vec::new();
    for (k, o) in keys.into_iter().zip(outs) {
        outcomes.push((k.0, k.1, k.2, o));
    }

    let mut tables = Vec::new();
    for (tb, bw_mbps) in PANELS {
        let mut t = Table::new(
            format!("Figure 3 — target tracking on {tb} (mixed dataset)"),
            &["target", "EETT tput", "EETT energy", "Ismail-TT tput", "Ismail-TT energy",
              "EETT err %", "Ismail err %"],
        );
        for frac in FRACTIONS {
            let target = Rate::from_mbps(bw_mbps * frac);
            let eett = lookup(&outcomes, tb, target, "EETT");
            let ismail = lookup(&outcomes, tb, target, "Ismail-TT");
            let err = |o: &SessionOutcome| {
                (o.avg_throughput.as_mbps() - target.as_mbps()).abs() / target.as_mbps() * 100.0
            };
            t.push_row(vec![
                format!("{target}"),
                format!("{}", eett.avg_throughput),
                fmt_energy_kj(eett.client_energy.as_joules()),
                format!("{}", ismail.avg_throughput),
                fmt_energy_kj(ismail.client_energy.as_joules()),
                format!("{:.1}", err(eett)),
                format!("{:.1}", err(ismail)),
            ]);
        }
        tables.push(t);
    }
    Fig3Results { outcomes, tables }
}

fn lookup<'a>(
    outcomes: &'a [(String, Rate, String, SessionOutcome)],
    tb: &str,
    target: Rate,
    tool: &str,
) -> &'a SessionOutcome {
    &outcomes
        .iter()
        .find(|(t, r, n, _)| t == tb && *r == target && n == tool)
        .expect("cell present")
        .3
}

impl Fig3Results {
    /// Look one cell up by testbed, target and tool.
    pub fn outcome(&self, tb: &str, target: Rate, tool: &str) -> &SessionOutcome {
        lookup(&self.outcomes, tb, target, tool)
    }

    /// Write the per-panel CSV files into `dir`.
    pub fn save_csvs(&self, dir: impl AsRef<Path>) -> anyhow::Result<()> {
        let dir = dir.as_ref();
        for (t, (tb, _)) in self.tables.iter().zip(PANELS) {
            t.save_csv(dir.join(format!("fig3_{tb}.csv")))?;
        }
        Ok(())
    }
}
