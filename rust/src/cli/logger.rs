//! Minimal stderr logger for the `log` facade (no env_logger offline).

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    max: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:<5}] {}: {}", record.level(), record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; level from `GREENDT_LOG`
/// (`error|warn|info|debug|trace`, default `warn`).
pub fn init_logger() {
    let level = match std::env::var("GREENDT_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => Level::Error,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Warn,
    };
    let logger = Box::leak(Box::new(StderrLogger { max: level }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::max());
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init_logger();
        super::init_logger(); // second call must not panic
        log::warn!("logger smoke");
    }
}
