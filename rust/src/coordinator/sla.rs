//! Service-level-agreement policies.

use crate::units::Rate;

/// What the user asked for (§IV): minimum energy, maximum throughput, or a
/// specific throughput target.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlaPolicy {
    /// Finish the transfer with the least end-system energy.
    Energy,
    /// Finish as fast as possible, spending no more energy than needed.
    Throughput,
    /// Hold the transfer at a target rate (± the tuner's tolerance band).
    TargetThroughput(Rate),
}

impl SlaPolicy {
    /// True for the energy-minimizing SLA.
    pub fn is_energy(&self) -> bool {
        matches!(self, SlaPolicy::Energy)
    }

    /// The target rate, for the target-throughput SLA.
    pub fn target(&self) -> Option<Rate> {
        match self {
            SlaPolicy::TargetThroughput(r) => Some(*r),
            _ => None,
        }
    }

    /// SLA name for tables and traces.
    pub fn name(&self) -> &'static str {
        match self {
            SlaPolicy::Energy => "energy",
            SlaPolicy::Throughput => "throughput",
            SlaPolicy::TargetThroughput(_) => "target-throughput",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        assert!(SlaPolicy::Energy.is_energy());
        assert!(!SlaPolicy::Throughput.is_energy());
        assert_eq!(SlaPolicy::Throughput.target(), None);
        let t = SlaPolicy::TargetThroughput(Rate::from_gbps(2.0));
        assert_eq!(t.target(), Some(Rate::from_gbps(2.0)));
        assert_eq!(t.name(), "target-throughput");
    }
}
