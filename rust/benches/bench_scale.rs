//! Scale bench: dispatcher throughput as fleets grow, swept over shard
//! counts, written to `BENCH_scale.json`.
//!
//!     cargo bench --bench bench_scale              # full curve
//!     cargo bench --bench bench_scale -- --smoke   # trimmed CI grid
//!
//! Thin wrapper over [`greendt::benchkit::scale`]. Every grid point is
//! measured at 1, 2 and 8 shards on the identical synchronized-arrival,
//! constant-background workload; multi-shard runs are bit-compared to
//! the 1-shard outcome before being reported. The full grid tops out at
//! 1,000 hosts / 100,000 sessions.
//!
//! Set `GREENDT_BENCH_JSON=<path>` to redirect the report (default
//! `BENCH_scale.json` in the working directory).

use greendt::benchkit::scale;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "== bench_scale: sharded dispatcher scale curve{} ==\n",
        if smoke { " (smoke)" } else { "" }
    );
    let report = scale::run(smoke);
    let path = std::env::var("GREENDT_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_scale.json".to_string());
    report.write_json(&path).expect("writing BENCH_scale.json");
    println!("\nbench report written to {path}");
}
