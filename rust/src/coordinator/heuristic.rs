//! Algorithm 1 — heuristic-based parameter initialization.
//!
//! Runs once before the transfer starts:
//!
//! 1. partition the dataset and split over-BDP files into BDP chunks
//!    (lines 1–5, implemented in [`crate::dataset::partition_files`]);
//! 2. per-partition pipelining `⌈BDP / avgFileSize⌉` (line 6);
//! 3. estimate the single-channel throughput `avgWinSize / RTT` and the
//!    channel count `⌈bandwidth / tputChannel⌉` needed to fill the pipe
//!    (lines 8–9);
//! 4. distribute channels across partitions by data-fraction weight
//!    (lines 10–13);
//! 5. pick the initial CPU setting from the SLA policy (lines 14–20).

use super::sla::SlaPolicy;
use crate::config::Testbed;
use crate::cpusim::CpuState;
use crate::dataset::{partition_files_capped, Dataset, Partition};

/// Result of Algorithm 1.
#[derive(Debug, Clone)]
pub struct HeuristicInit {
    /// Partitioned dataset (lines 1–8).
    pub partitions: Vec<Partition>,
    /// Total channels to open (`numChannels`, line 9).
    pub num_channels: u32,
    /// Initial client CPU setting (lines 14–20).
    pub client_cpu: CpuState,
}

/// Hard cap on the initial channel estimate (keeps pathological RTT/window
/// combinations from opening hundreds of connections before slow start
/// has any feedback).
pub const MAX_INITIAL_CHANNELS: u32 = 32;

/// Execute Algorithm 1.
pub fn initialize(testbed: &Testbed, dataset: &Dataset, sla: SlaPolicy) -> HeuristicInit {
    // Lines 1–7: partition + chunk + pipelining. Parallelism per channel
    // is capped at the stream count that fills the pipe — except for
    // target-throughput SLAs, where one stream per channel keeps the
    // channel count a fine-grained rate knob (a channel's worth of
    // throughput is the control quantum EETT works in).
    let p_cap = match sla {
        SlaPolicy::TargetThroughput(_) => 1,
        _ => (testbed.link.knee_streams().ceil() as u32).max(1),
    };
    let partitions = partition_files_capped(dataset, testbed.bdp(), p_cap);

    // Line 8: theoretical throughput of one TCP channel.
    let tput_channel = testbed.link.channel_throughput();
    // Line 9: channels needed to fill the bandwidth — or, for a target
    // SLA, to reach the target.
    let goal_rate = match sla {
        SlaPolicy::TargetThroughput(r) => r.min(testbed.link.capacity),
        _ => testbed.link.capacity,
    };
    let num_channels = (goal_rate / tput_channel).ceil() as u32;
    let num_channels = num_channels.clamp(1, MAX_INITIAL_CHANNELS);

    // Lines 14–20: SLA-based CPU setting.
    let client_cpu = match sla {
        SlaPolicy::Energy => CpuState::min_energy_start(testbed.client_cpu.clone()),
        // Throughput and target-throughput SLAs start with all cores at
        // min frequency; Algorithm 3 raises frequency only under load.
        SlaPolicy::Throughput | SlaPolicy::TargetThroughput(_) => {
            CpuState::max_throughput_start(testbed.client_cpu.clone())
        }
    };

    HeuristicInit { partitions, num_channels, client_cpu }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::standard;

    #[test]
    fn channel_estimate_fills_the_pipe() {
        // Chameleon: 10 Gbps / (3 MB / 32 ms = 750 Mbps) = 14 channels.
        let init = initialize(
            &testbeds::chameleon(),
            &standard::medium_dataset(1),
            SlaPolicy::Throughput,
        );
        assert_eq!(init.num_channels, 14);

        // CloudLab: 1 Gbps / (1 MB / 36 ms ≈ 222 Mbps) = 5 channels.
        let init = initialize(
            &testbeds::cloudlab(),
            &standard::medium_dataset(1),
            SlaPolicy::Throughput,
        );
        assert_eq!(init.num_channels, 5);
    }

    #[test]
    fn energy_sla_starts_minimal() {
        let init =
            initialize(&testbeds::didclab(), &standard::small_dataset(1), SlaPolicy::Energy);
        assert_eq!(init.client_cpu.active_cores(), 1);
        assert!(init.client_cpu.at_min_freq());
    }

    #[test]
    fn throughput_sla_starts_all_cores_min_freq() {
        let tb = testbeds::chameleon();
        let init = initialize(&tb, &standard::large_dataset(1), SlaPolicy::Throughput);
        assert_eq!(init.client_cpu.active_cores(), tb.client_cpu.num_cores);
        assert!(init.client_cpu.at_min_freq());
    }

    #[test]
    fn partitions_cover_dataset() {
        let ds = standard::mixed_dataset(1);
        let init = initialize(&testbeds::cloudlab(), &ds, SlaPolicy::Throughput);
        let n: usize = init.partitions.iter().map(|p| p.files.len()).sum();
        assert_eq!(n, ds.num_files());
    }

    #[test]
    fn channel_estimate_is_capped() {
        // Degenerate testbed: tiny window + long RTT would ask for hundreds.
        let mut tb = testbeds::chameleon();
        tb.link.avg_win = crate::units::Bytes::from_kb(64.0);
        let init = initialize(&tb, &standard::medium_dataset(1), SlaPolicy::Throughput);
        assert_eq!(init.num_channels, MAX_INITIAL_CHANNELS);
    }
}
