//! The transfer engine: datasets bound to channels.
//!
//! Implements the application semantics of §II: a session moves a set of
//! file *partitions* over a set of *channels* (concurrency), each channel
//! carrying `parallelism` TCP streams and issuing up to `pipelining`
//! requests back-to-back. The engine tracks remaining data per partition,
//! converts network stream allocations into application goodput (charging
//! the per-file RTT overhead that pipelining amortizes), and exposes the
//! channel-redistribution primitive (`weight_i × numCh`, Alg. 2/4/5/6
//! line "updateChannels") that all tuning algorithms share.

mod engine;
mod channel;

pub use channel::Channel;
pub use engine::{PartitionProgress, TickOutput, TransferEngine};
