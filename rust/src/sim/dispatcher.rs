//! Multi-host fleet dispatcher: place each arriving session on the host
//! that serves it cheapest.
//!
//! The paper tunes *how* a transfer runs on one end system; GreenDataFlow
//! (arXiv:1810.05892) shows the larger fleet-level win comes from *where*
//! it runs: on a heterogeneous fleet, the host whose operating point
//! yields the lowest marginal energy should take the next session. This
//! module owns that layer:
//!
//! * [`HostSpec`] / [`run_dispatcher`] — several independent hosts (each
//!   with its own link, power model and session-slot pool) driven in
//!   lockstep behind one [`Dispatcher`];
//! * [`PlacementKind`] policies — `RoundRobin`, `LeastLoaded` and
//!   `MarginalEnergy`, the last scoring candidates by predicted
//!   joules-per-byte deltas priced through the same
//!   [`PowerModel::at`](crate::power::PowerModel::at) /
//!   [`OpPointPower`](crate::power::OpPointPower) coefficients the
//!   epoch-cached stepper runs on;
//! * open workloads — a seeded [`PoissonArrivals`] process generating
//!   [`SessionSpec`]s, instead of PR 1's scripted schedules;
//! * admission control — a fleet-wide cap on *projected* aggregate host
//!   power: arrivals that would push the projection past the cap wait in
//!   a FIFO queue and retry as sessions depart;
//! * decision telemetry — every placement emits a
//!   [`DispatchRecord`](crate::sim::DispatchRecord) with the per-host
//!   scores, so the dispatcher's behavior can be mined offline
//!   (historical-log-driven tuning, arXiv:2104.01192);
//! * rebalancing — a [`Rebalancer`](crate::rebalance::Rebalancer) may
//!   preempt running sessions at segment boundaries and re-admit their
//!   remaining bytes on a cheaper host, paying a simulated drain delay
//!   and slow-start re-ramp; scripted [`PowerCapEvent`]s tighten (or
//!   lift) the admission cap mid-run, which is the cap-pressure
//!   policy's trigger. Every move emits a
//!   [`MigrationRecord`](crate::sim::MigrationRecord);
//! * resilience — a scripted
//!   [`FaultSchedule`](crate::resilience::FaultSchedule) fires host
//!   deaths and link collapses at the same segment boundaries. A death
//!   preempts every running session on the host; with recovery on
//!   ([`ResilienceConfig::enabled`]) the lost bytes re-materialize as a
//!   [`PenaltyBox`](crate::resilience::PenaltyBox)-backed retry (full
//!   slow-start re-ramp, flaky hosts outbid by a decaying J/B
//!   surcharge) until the retry budget runs out and the session is
//!   quarantined in the bounded
//!   [`DeadLetterQueue`](crate::resilience::DeadLetterQueue); with
//!   recovery off the loss is terminal and quarantined immediately. A
//!   [`HealthMonitor`](crate::resilience::HealthMonitor) watches every
//!   host's delivered goodput against its own projection and its
//!   advisories trigger rebalancer evacuation *before* a degrading
//!   host dies. An inactive config takes none of these branches — the
//!   `--resilience off` bit-identity contract
//!   (`rust/tests/resilience_faults.rs` pins it).
//!
//! The driver extends the PR 2 event-horizon loop across hosts: each
//! segment computes the earliest driver-level event over *all* hosts
//! (arrivals, migration resumes, scripted cap changes, tuning timeouts,
//! arbitrations, the time cap) and then runs a tight lockstep inner
//! loop of bare `step()` calls, so ticks between cross-host deadlines
//! stay as cheap as in the single-host fleet.
//!
//! With [`DispatcherConfig::shards`] above one, that inner loop is
//! *sharded*: hosts are partitioned across worker threads which advance
//! their shard a completion-free, horizon-bounded run of ticks at a
//! time (`HostWorld::advance_ticks`), rejoining at every possible
//! break point. Hosts never interact between driver events — placement,
//! arbitration, rebalancing and cap changes all happen at segment
//! boundaries on the dispatcher thread — so the outcome is bit-for-bit
//! invariant to the shard count; `shards == 1` keeps the serial
//! reference loop verbatim. See `ARCHITECTURE.md` §Scale for the
//! determinism contract.

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};

use super::fleet::{FleetOutcome, HostWorld, TenantSpec};
use super::telemetry::{DispatchRecord, FaultRecord, MigrationRecord, PlacementScore, RetryRecord};
use crate::config::experiment::TunerParams;
use crate::config::Testbed;
use crate::coordinator::fleet::{FleetPolicyKind, PlacementKind};
use crate::coordinator::AlgorithmKind;
use crate::history::{KnnIndex, Query, RunOutcome, WorkloadFingerprint, CONFIDENCE_FLOOR};
use crate::netsim::{BandwidthEvent, CrossTrafficConfig};
use crate::obs::calibrate::{
    jain_index, CalibrationAnomaly, CalibrationConfig, CalibrationLedger, CalibrationRecord,
    MigrationCalibration,
};
use crate::obs::metrics::{FleetMetrics, SegmentSnapshot};
use crate::obs::trace::{AttrValue, TraceRecord, TraceSink};
use crate::rebalance::{HostView, MoveVerdict, RebalanceConfig, Rebalancer, SessionView};
use crate::resilience::{
    Advisory, DeadLetter, DeadLetterQueue, FailureReason, FaultKind, FaultSchedule, HealthMonitor,
    PenaltyBox, ResilienceConfig,
};
use crate::rng::{self, Distribution, Exponential};
use crate::units::{Bytes, Energy, Power, SimDuration, SimTime};

/// An open-workload session request. Exactly a [`TenantSpec`] — the
/// dispatcher decides *which host* becomes the session's tenant world,
/// then hands the spec to that host's fleet driver unchanged.
pub type SessionSpec = TenantSpec;

/// One host in the dispatcher's fleet: a named testbed (its own WAN
/// path, CPUs, power models and meters) plus a bound on how many
/// concurrent sessions its slot pool accepts.
#[derive(Debug, Clone)]
pub struct HostSpec {
    /// Display name, unique within the fleet (used in telemetry and
    /// outcomes).
    pub name: String,
    /// The end system + path this host models.
    pub testbed: Testbed,
    /// Hard cap on concurrently admitted sessions (the slot pool size).
    pub max_sessions: u32,
}

impl HostSpec {
    /// A host with the default 8-session slot pool.
    pub fn new(name: impl Into<String>, testbed: Testbed) -> Self {
        HostSpec { name: name.into(), testbed, max_sessions: 8 }
    }

    /// Override the slot-pool size.
    pub fn with_max_sessions(mut self, max_sessions: u32) -> Self {
        self.max_sessions = max_sessions.max(1);
        self
    }
}

/// A seeded Poisson arrival process: `count` sessions whose inter-arrival
/// times are exponential with rate `rate_per_sec`. Fully deterministic
/// under a fixed seed (the generator draws from its own
/// [`rng::stream`]), so open-workload experiments are reproducible.
#[derive(Debug, Clone, Copy)]
pub struct PoissonArrivals {
    /// Mean arrival rate, sessions per simulated second.
    pub rate_per_sec: f64,
    /// How many sessions to generate.
    pub count: u32,
    /// RNG seed for the inter-arrival draws (and derived dataset seeds).
    pub seed: u64,
}

impl PoissonArrivals {
    /// A process with `rate_per_sec` mean arrivals per second. Degenerate
    /// parameters (rate ≤ 0, zero count) are allowed and describe the
    /// empty process — [`Self::times`] yields no arrivals instead of
    /// panicking, so a scripted sweep can drive the rate to zero.
    pub fn new(rate_per_sec: f64, count: u32, seed: u64) -> Self {
        PoissonArrivals { rate_per_sec, count, seed }
    }

    /// The arrival instants: a strictly increasing sequence of `count`
    /// times starting after t = 0 — empty when the process is degenerate
    /// (rate ≤ 0 or `count` 0).
    pub fn times(&self) -> Vec<SimTime> {
        if self.rate_per_sec <= 0.0 || self.count == 0 {
            return Vec::new();
        }
        let mut rng = rng::stream(self.seed, "poisson-arrivals");
        let exp = Exponential::new(self.rate_per_sec);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(self.count as usize);
        for _ in 0..self.count {
            t += exp.sample(&mut rng);
            out.push(SimTime::from_secs(t));
        }
        out
    }

    /// Generate the session specs: one dataset per session drawn from the
    /// standard family `dataset_family` (`"small"`, `"medium"`, `"large"`,
    /// `"mixed"`) with per-session derived seeds, all tuned by
    /// `algorithm`. Returns `None` for an unknown family name.
    pub fn sessions(
        &self,
        dataset_family: &str,
        algorithm: AlgorithmKind,
    ) -> Option<Vec<SessionSpec>> {
        self.times()
            .into_iter()
            .enumerate()
            .map(|(i, at)| {
                let ds = crate::dataset::standard::by_name(
                    dataset_family,
                    self.seed.wrapping_add(1 + i as u64),
                )?;
                Some(TenantSpec::new(format!("session-{i}"), ds, algorithm).arriving_at(at))
            })
            .collect()
    }
}

/// A candidate host as [`Dispatcher::place`] sees it: a snapshot of the
/// host's occupancy plus the power projections the dispatcher computed
/// for it. `projected_*` quantities assume the new session is placed on
/// this host; `current_power_w` assumes it is not.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostCandidate {
    /// Index of the host in the dispatcher's host list.
    pub host: usize,
    /// Sessions currently admitted and unfinished on this host.
    pub active_sessions: u32,
    /// Session slots still free (0 = the host cannot take the session).
    pub free_slots: u32,
    /// Predicted whole-host instrument power at the current session
    /// count, W.
    pub current_power_w: f64,
    /// Predicted whole-host instrument power with the new session
    /// placed here, W.
    pub projected_power_w: f64,
    /// Expected goodput of the new session if placed here, bytes/s.
    pub projected_session_bps: f64,
    /// Projected aggregate fleet power if placed here (every other host
    /// at its current projection), W — what admission control compares
    /// against the power cap.
    pub projected_fleet_power_w: f64,
    /// Queueing-delay price of this placement, J/B (zero unless the run
    /// enables [`DispatcherConfig::price_queue_delay`]): the expected
    /// extra seconds-per-byte the session suffers from contention on
    /// this host relative to running alone, priced at the host's idle
    /// draw — `idle_W × (1/bps_shared − 1/bps_alone)`. This is what
    /// stops `MarginalEnergy` being goodput-blind on a saturated link,
    /// where piling on another session adds almost no marginal watts
    /// (the link caps aggregate demand) yet stretches every session's
    /// residency.
    pub queue_delay_j_per_byte: f64,
    /// History-observed J/B for a workload like this on this host
    /// (`None` when no [`KnnIndex`] is attached, it has no record from
    /// this host, or the observation's confidence sits below
    /// [`CONFIDENCE_FLOOR`](crate::history::CONFIDENCE_FLOOR)). With a
    /// v2 store this is the *marginal* observation recorded at past
    /// admissions (scale-consistent with [`Self::marginal_j_per_byte`]);
    /// stores holding only v1 records fall back to the session's *total
    /// attributed* cost — see [`Self::learned_score`].
    pub learned_j_per_byte: Option<f64>,
    /// Confidence of the observation in `[0, 1]` — the blend weight
    /// `Learned` placement gives it over the model score. Already gated
    /// at the confidence floor when set by the dispatcher.
    pub learned_weight: f64,
}

impl HostCandidate {
    /// The `MarginalEnergy` score: predicted extra watts divided by the
    /// new session's expected goodput — joules per byte moved. Infinite
    /// when the host could not move any bytes for the session.
    pub fn marginal_j_per_byte(&self) -> f64 {
        if self.projected_session_bps <= 0.0 {
            f64::INFINITY
        } else {
            (self.projected_power_w - self.current_power_w).max(0.0)
                / self.projected_session_bps
        }
    }

    /// The `Learned` score: the model-based marginal J/B blended with the
    /// history-observed J/B for similar workloads on this host, weighted
    /// by the observation's confidence. Without history (or when the
    /// model already scores the host unusable) this reduces exactly to
    /// [`Self::marginal_j_per_byte`], so an empty store ranks hosts
    /// identically to `MarginalEnergy`.
    ///
    /// The two terms deliberately price different things: the model term
    /// is *marginal* (extra watts the placement adds), the observed term
    /// is *full-cost* (the session's attributed share of everything the
    /// host drew, platform base included — the number the fleet actually
    /// billed). Blending them biases placement away from hosts whose
    /// realized per-byte bills ran high — contention, overload, a heavy
    /// idle floor — exactly the costs the marginal projection is blind
    /// to. The price is that a high-fixed-cost host can be passed over
    /// even when its marginal draw is competitive; recording a marginal
    /// estimate at admission for a scale-consistent blend is a noted
    /// ROADMAP follow-on.
    pub fn learned_score(&self) -> f64 {
        let model = self.marginal_j_per_byte();
        match self.learned_j_per_byte {
            Some(observed) if model.is_finite() && self.learned_weight > 0.0 => {
                let w = self.learned_weight.clamp(0.0, 1.0);
                (1.0 - w) * model + w * observed
            }
            _ => model,
        }
    }

    /// What `MarginalEnergy` placement actually ranks by: the marginal
    /// model score plus the queueing-delay price (zero unless the run
    /// prices queue delay — see [`Self::queue_delay_j_per_byte`]).
    pub fn score(&self) -> f64 {
        self.marginal_j_per_byte() + self.queue_delay_j_per_byte
    }
}

/// What [`Dispatcher::place`] decided for one arriving session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlaceDecision {
    /// Admit on this host (a [`HostCandidate::host`] index).
    Admit(usize),
    /// Some host has a free slot, but every placement would push the
    /// projected fleet power past the cap — the session must wait.
    QueuePowerCap,
    /// No host has a free session slot.
    QueueNoSlot,
}

/// The placement + admission state machine: ranks candidate hosts by the
/// configured [`PlacementKind`] and enforces the fleet power cap. Pure
/// over the candidate snapshots (no simulation access), so decisions are
/// easy to test, replay and mine offline.
#[derive(Debug, Clone)]
pub struct Dispatcher {
    placement: PlacementKind,
    power_cap: Option<Power>,
    /// Round-robin cursor (next host index to try first).
    rr_cursor: usize,
}

impl Dispatcher {
    /// A dispatcher using `placement`, admitting only while the projected
    /// aggregate fleet power stays within `power_cap` (if set).
    pub fn new(placement: PlacementKind, power_cap: Option<Power>) -> Self {
        Dispatcher { placement, power_cap, rr_cursor: 0 }
    }

    /// Which placement policy this dispatcher ranks hosts by.
    pub fn placement(&self) -> PlacementKind {
        self.placement
    }

    /// Retarget the admission power cap mid-run (a scripted
    /// [`PowerCapEvent`] firing). Affects every later decision; sessions
    /// already admitted are untouched — shedding them is the
    /// cap-pressure rebalancer's job, not admission control's.
    pub fn set_power_cap(&mut self, cap: Option<Power>) {
        self.power_cap = cap;
    }

    /// Choose a host for one arriving session.
    ///
    /// Candidates are ranked by the placement policy; the best-ranked
    /// host with a free slot whose projected fleet power fits the cap
    /// wins. With a cap set, a worse-ranked host that fits is preferred
    /// over queueing behind a better-ranked host that does not.
    ///
    /// # Examples
    ///
    /// ```
    /// use greendt::coordinator::fleet::PlacementKind;
    /// use greendt::sim::dispatcher::{Dispatcher, HostCandidate, PlaceDecision};
    ///
    /// let mut d = Dispatcher::new(PlacementKind::MarginalEnergy, None);
    /// let candidates = [
    ///     HostCandidate {
    ///         host: 0,
    ///         active_sessions: 1,
    ///         free_slots: 3,
    ///         current_power_w: 30.0,
    ///         projected_power_w: 55.0,   // +25 W …
    ///         projected_session_bps: 50e6, // … for 50 MB/s → 0.5 µJ/B
    ///         projected_fleet_power_w: 75.0,
    ///         queue_delay_j_per_byte: 0.0,
    ///         learned_j_per_byte: None,
    ///         learned_weight: 0.0,
    ///     },
    ///     HostCandidate {
    ///         host: 1,
    ///         active_sessions: 0,
    ///         free_slots: 4,
    ///         current_power_w: 20.0,
    ///         projected_power_w: 35.0,   // +15 W …
    ///         projected_session_bps: 100e6, // … for 100 MB/s → 0.15 µJ/B
    ///         projected_fleet_power_w: 65.0,
    ///         queue_delay_j_per_byte: 0.0,
    ///         learned_j_per_byte: None,
    ///         learned_weight: 0.0,
    ///     },
    /// ];
    /// // Host 1 moves the session's bytes for fewer joules each: admit it.
    /// assert_eq!(d.place(&candidates), PlaceDecision::Admit(1));
    /// ```
    pub fn place(&mut self, candidates: &[HostCandidate]) -> PlaceDecision {
        if candidates.is_empty() {
            return PlaceDecision::QueueNoSlot;
        }
        // Preference order over candidate positions.
        let mut order: Vec<usize> = (0..candidates.len()).collect();
        match self.placement {
            PlacementKind::RoundRobin => {
                order = (0..candidates.len())
                    .map(|k| (self.rr_cursor + k) % candidates.len())
                    .collect();
            }
            PlacementKind::LeastLoaded => {
                order.sort_by_key(|&i| (candidates[i].active_sessions, candidates[i].host));
            }
            PlacementKind::MarginalEnergy => {
                order.sort_by(|&a, &b| {
                    candidates[a]
                        .score()
                        .total_cmp(&candidates[b].score())
                        .then_with(|| candidates[a].host.cmp(&candidates[b].host))
                });
            }
            PlacementKind::Learned => {
                order.sort_by(|&a, &b| {
                    (candidates[a].learned_score() + candidates[a].queue_delay_j_per_byte)
                        .total_cmp(
                            &(candidates[b].learned_score()
                                + candidates[b].queue_delay_j_per_byte),
                        )
                        .then_with(|| candidates[a].host.cmp(&candidates[b].host))
                });
            }
        }
        let mut any_free = false;
        for idx in order {
            let c = &candidates[idx];
            if c.free_slots == 0 {
                continue;
            }
            any_free = true;
            if let Some(cap) = self.power_cap {
                if c.projected_fleet_power_w > cap.as_watts() + 1e-9 {
                    continue;
                }
            }
            if self.placement == PlacementKind::RoundRobin {
                self.rr_cursor = (idx + 1) % candidates.len();
            }
            return PlaceDecision::Admit(c.host);
        }
        if any_free {
            PlaceDecision::QueuePowerCap
        } else {
            PlaceDecision::QueueNoSlot
        }
    }
}

/// A scripted change of the fleet admission power cap mid-run — the
/// "cap tightens" scenario the cap-pressure rebalancer exists for.
/// Events fire at segment boundaries once the simulated clock passes
/// `at`; the latest fired event's cap is in force.
#[derive(Debug, Clone, Copy)]
pub struct PowerCapEvent {
    /// When the new cap takes effect.
    pub at: SimTime,
    /// The new cap (`None` removes the cap).
    pub cap: Option<Power>,
}

/// Everything needed to run a multi-host world.
#[derive(Debug, Clone)]
pub struct DispatcherConfig {
    /// The fleet's hosts, in placement-index order.
    pub hosts: Vec<HostSpec>,
    /// The workload: scripted [`SessionSpec`]s or a generated
    /// [`PoissonArrivals`] batch (see [`PoissonArrivals::sessions`]).
    pub sessions: Vec<SessionSpec>,
    /// How arriving sessions are placed on hosts.
    pub placement: PlacementKind,
    /// Per-host arbitration policy (always active in dispatcher mode —
    /// each host needs an owner for its CPU knobs).
    pub policy: FleetPolicyKind,
    /// Fleet-wide admission cap on *projected* aggregate host power.
    /// Admission control never admits a session whose projection exceeds
    /// it; `None` admits freely. This bounds the steady-state projection,
    /// not the instantaneous meters.
    pub power_cap: Option<Power>,
    /// Scripted mid-run cap changes, applied on top of [`Self::power_cap`]
    /// in event-time order.
    pub cap_events: Vec<PowerCapEvent>,
    /// The rebalancer: cap-aware preemption and live migration of running
    /// sessions between hosts at segment boundaries (see
    /// [`crate::rebalance`]). The default `Off` policy leaves the
    /// dispatcher bit-for-bit as it is without one.
    pub rebalance: RebalanceConfig,
    /// Price expected contention delay into `MarginalEnergy`/`Learned`
    /// placement scores (see [`HostCandidate::queue_delay_j_per_byte`]).
    /// Off by default: scores then match the pre-rebalancer dispatcher
    /// exactly.
    pub price_queue_delay: bool,
    /// Tuner knobs shared by every session's algorithm.
    pub params: TunerParams,
    /// Arbitration cadence of each host's fleet policy.
    pub fleet_interval: SimDuration,
    /// Base RNG seed; each host derives its own background-traffic seed.
    pub seed: u64,
    /// Simulation tick length (shared by every host).
    pub tick: SimDuration,
    /// Abort the run after this much simulated time.
    pub max_sim_time: SimDuration,
    /// Record per-timeout timelines for every session (costs memory).
    pub record_timeline: bool,
    /// Drive every host with the naive reference stepper instead of the
    /// epoch-cached fast path (tests and benchmarks).
    pub reference_stepper: bool,
    /// Worker threads the lockstep inner loop shards hosts across. `1`
    /// (the default) keeps the serial per-tick reference loop exactly as
    /// earlier releases ran it; `0` resolves to
    /// [`std::thread::available_parallelism`]; values above the host
    /// count clamp to it. Outcomes are bit-for-bit invariant to the
    /// shard count — sharding changes wall-clock time only (the
    /// `stepper_equivalence` suite pins this).
    pub shards: usize,
    /// Build every host's link with a *constant* background at the
    /// testbed mean (plus any scripted events) instead of the seeded OU
    /// process. A constant background is frozen between events, which is
    /// the link-side precondition for warm-epoch tick batching
    /// ([`crate::netsim::BackgroundTraffic::is_frozen`]) — large-scale
    /// runs and `bench_scale` set this so warm epochs batch.
    pub constant_bg: bool,
    /// Seeded cross-traffic generators (steady UDP floor + bursty TCP
    /// flows) on every host's link — the contended-network scenarios.
    /// Each host derives its generator stream from its own
    /// [`host_seed`], so trajectories differ per host but the whole
    /// fleet stays a pure function of [`Self::seed`]. Mutually
    /// exclusive with [`Self::constant_bg`]: a contended link is never
    /// frozen, so warm-epoch batching stays off.
    pub cross_traffic: Option<CrossTrafficConfig>,
    /// Run every session's per-channel FSM with AIMD competing-flow
    /// dynamics instead of slow-start-then-hold (see
    /// [`crate::transfer::TransferEngine::set_aimd`]).
    pub aimd: bool,
    /// Historical-log index consulted at every placement decision: each
    /// candidate host is annotated with the history-observed ΔJ/byte for
    /// workloads like the arriving one, which
    /// [`PlacementKind::Learned`] blends into its score (other placements
    /// carry it as telemetry only), and cold
    /// [`AlgorithmKind::HistoryTuned`] sessions are warm-started at
    /// admission time against the host that actually admitted them.
    /// `None` — and an index that knows nothing relevant — degrades to
    /// pure model-based scoring with cold slow starts.
    pub history: Option<KnnIndex>,
    /// The failure model and its response (see [`crate::resilience`]):
    /// a scripted fault schedule plus the recovery machinery — retries
    /// under the PenaltyBox, dead-letter quarantine, health-driven
    /// evacuation. The default ([`ResilienceConfig::new`]) is inactive,
    /// and the dispatcher then runs bit-for-bit as it did before the
    /// subsystem existed.
    pub resilience: ResilienceConfig,
    /// Collect the session-lifecycle trace (see [`crate::obs::trace`]):
    /// every residency, tune, migration, retry and decision becomes a
    /// span or instant event in [`DispatchOutcome::trace`]. Off by
    /// default, and an off run takes none of the collection branches —
    /// the `--trace` off bit-identity contract
    /// (`rust/tests/trace_determinism.rs` pins it). All emission happens
    /// at segment boundaries on the dispatcher thread, so the trace is
    /// byte-identical across shard counts.
    pub trace: bool,
    /// Collect the fleet metrics registry + per-segment timeline (see
    /// [`crate::obs::metrics`]) into [`DispatchOutcome::metrics`]. Off
    /// by default. Unlike the trace, the `stepper.*` series (and the
    /// snapshot warm/slow tick fields) are shard-*sensitive* by design —
    /// they measure the driver, not the simulated fleet.
    pub metrics: bool,
    /// Knobs for the decision calibration ledger and its watchdogs
    /// (see [`crate::obs::calibrate`]). The ledger itself runs whenever
    /// any observability is on (`trace` or `metrics`) — this only tunes
    /// the anomaly factor and the watchdog thresholds.
    pub calibration: CalibrationConfig,
}

impl DispatcherConfig {
    /// A dispatcher fleet with default knobs (min-energy host policy, no
    /// power cap) and no sessions yet.
    pub fn new(hosts: Vec<HostSpec>, placement: PlacementKind) -> Self {
        DispatcherConfig {
            hosts,
            sessions: Vec::new(),
            placement,
            policy: FleetPolicyKind::MinEnergyFleet,
            power_cap: None,
            cap_events: Vec::new(),
            rebalance: RebalanceConfig::default(),
            price_queue_delay: false,
            params: TunerParams::default(),
            fleet_interval: SimDuration::from_secs(3.0),
            seed: 42,
            tick: SimDuration::from_millis(100.0),
            max_sim_time: SimDuration::from_secs(14_400.0),
            record_timeline: false,
            reference_stepper: false,
            shards: 1,
            constant_bg: false,
            cross_traffic: None,
            aimd: false,
            history: None,
            resilience: ResilienceConfig::new(),
            trace: false,
            metrics: false,
            calibration: CalibrationConfig::default(),
        }
    }

    /// Replace the workload.
    pub fn with_sessions(mut self, sessions: Vec<SessionSpec>) -> Self {
        self.sessions = sessions;
        self
    }

    /// Set the fleet-wide power cap.
    pub fn with_power_cap(mut self, cap: Power) -> Self {
        self.power_cap = Some(cap);
        self
    }

    /// Append a scripted mid-run cap change.
    pub fn with_cap_event(mut self, at: SimTime, cap: Option<Power>) -> Self {
        self.cap_events.push(PowerCapEvent { at, cap });
        self
    }

    /// Enable a rebalance policy (see [`crate::rebalance`]).
    pub fn with_rebalance(mut self, rebalance: RebalanceConfig) -> Self {
        self.rebalance = rebalance;
        self
    }

    /// Price expected contention delay into placement scores.
    pub fn with_queue_delay_price(mut self) -> Self {
        self.price_queue_delay = true;
        self
    }

    /// Attach a historical-log index (see [`Self::history`]).
    pub fn with_history(mut self, index: KnnIndex) -> Self {
        self.history = Some(index);
        self
    }

    /// Set the RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Shard the lockstep inner loop across `shards` worker threads
    /// (see [`Self::shards`]; `0` = one per available core).
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Freeze every host's background traffic at the testbed mean so
    /// warm epochs batch (see [`Self::constant_bg`]).
    pub fn with_constant_bg(mut self) -> Self {
        self.constant_bg = true;
        self
    }

    /// Add seeded cross-traffic generators to every host's link (see
    /// [`Self::cross_traffic`]).
    pub fn with_cross_traffic(mut self, cross: CrossTrafficConfig) -> Self {
        self.cross_traffic = Some(cross);
        self
    }

    /// Run every session with AIMD competing-flow channel dynamics (see
    /// [`Self::aimd`]).
    pub fn with_aimd(mut self, on: bool) -> Self {
        self.aimd = on;
        self
    }

    /// Install the resilience config (fault schedule + recovery knobs).
    pub fn with_resilience(mut self, resilience: ResilienceConfig) -> Self {
        self.resilience = resilience;
        self
    }

    /// Collect the session-lifecycle trace (see [`Self::trace`]).
    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    /// Collect the metrics registry + timeline (see [`Self::metrics`]).
    pub fn with_metrics(mut self) -> Self {
        self.metrics = true;
        self
    }

    /// Tune the calibration ledger / watchdog knobs (see
    /// [`Self::calibration`]).
    pub fn with_calibration(mut self, calibration: CalibrationConfig) -> Self {
        self.calibration = calibration;
        self
    }
}

/// What a dispatcher run produced: the fleet outcome (tenants flattened
/// across hosts, per-host breakdowns in [`FleetOutcome::hosts`]) plus the
/// dispatcher's own telemetry.
#[derive(Debug, Clone)]
pub struct DispatchOutcome {
    /// Aggregate + per-tenant + per-host results.
    pub fleet: FleetOutcome,
    /// One record per placement decision, in decision order.
    pub decisions: Vec<DispatchRecord>,
    /// One record per rebalancer move, in execution order (empty with
    /// the rebalance policy off).
    pub migrations: Vec<MigrationRecord>,
    /// Sessions never admitted before the run ended (still queued, still
    /// pending arrival, mid-migration-drain, or waiting out a retry
    /// backoff at the time cap). Dead-lettered sessions are *not* here —
    /// they are itemized in [`FleetOutcome::dead_letters`].
    pub unplaced: Vec<String>,
    /// One record per fired fault action, in firing order (empty
    /// without a fault schedule).
    pub faults: Vec<FaultRecord>,
    /// One record per retry the PenaltyBox pipeline scheduled, in
    /// firing order (empty unless recovery is on and a host died under
    /// running sessions).
    pub retries: Vec<RetryRecord>,
    /// Health-monitor degradation advisories, in firing order (empty
    /// unless recovery is on).
    pub advisories: Vec<Advisory>,
    /// The merged session-lifecycle trace, sorted by `(t0, id)` (`None`
    /// unless [`DispatcherConfig::trace`] was set). Serialize with
    /// [`crate::obs::trace::trace_jsonl`] or
    /// [`crate::obs::trace::chrome_trace_json`].
    pub trace: Option<Vec<TraceRecord>>,
    /// The metrics registry + per-segment timeline (`None` unless
    /// [`DispatcherConfig::metrics`] was set).
    pub metrics: Option<FleetMetrics>,
    /// The decision calibration ledger: per-residency predicted-vs-
    /// realized J/B joins, per-migration benefit joins and flagged
    /// anomalies (`None` unless some observability — trace or metrics —
    /// was on). Its realized joules bit-match
    /// [`FleetOutcome`]'s per-tenant attribution.
    pub calibration: Option<CalibrationLedger>,
}

/// Derive one host's RNG seed from the fleet seed (distinct background
/// noise per host, reproducible from the pair).
fn host_seed(seed: u64, host: usize) -> u64 {
    seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(host as u64 + 1))
}

/// Resolve [`DispatcherConfig::shards`] to a concrete worker count:
/// `0` means one shard per available core, and no configuration ever
/// yields more shards than hosts (an empty shard is pure overhead).
fn effective_shards(requested: usize, hosts: usize) -> usize {
    let requested = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    requested.min(hosts.max(1)).max(1)
}

/// Upper bound on how many ticks the clock can advance from `now`
/// before hitting the segment horizon or the time cap, found by
/// replaying the exact accumulation the stepper performs (`now += dt`
/// per tick, then the `t + 1e-9 >= horizon || t >= max` break). Lockstep
/// keeps every host's clock bit-identical, so one world's replay
/// decides for all — and because the replay *is* the break condition,
/// a chunk can never carry the fleet onto or past a driver event.
fn horizon_bound_ticks(now: f64, dt: f64, cap: u64, horizon: f64, max: f64) -> u64 {
    let mut t = now;
    let mut safe = 0u64;
    while safe < cap {
        t += dt;
        if t + 1e-9 >= horizon || t >= max {
            break;
        }
        safe += 1;
    }
    safe
}

/// The sharded lockstep inner loop: advance every host to the segment
/// horizon, partitioned across `shards` worker threads.
///
/// Correctness rests on two bounds computed fresh each round:
///
/// * [`HostWorld::completion_bound_ticks`] — a tick count no session on
///   any host can finish within (link-capacity-limited byte budget), so
///   no chunk ever skips a completion the driver must react to;
/// * [`horizon_bound_ticks`] — the exact number of ticks before the
///   shared clock would trip a segment break, so no chunk ever crosses
///   a driver event (arrival, cap change, migration resume, timeout,
///   arbitration).
///
/// Ticks inside those bounds touch no cross-host state — hosts only
/// interact through the dispatcher at segment boundaries — so each
/// worker advances its shard independently and the merged fleet state
/// is bit-for-bit what the serial loop produces. When the bound hits
/// zero (a break could fire on the very next tick) that tick runs
/// serially with the reference loop's own break checks, which is where
/// completions and horizons actually fire. Worst case (a session one
/// tick from finishing every round) this degenerates to the serial
/// reference loop — never to a wrong answer.
fn step_segment_sharded(worlds: &mut [HostWorld], shards: usize, horizon: f64, max: f64) {
    // Cap per-round chunks so a long quiet segment still rejoins often
    // enough to keep completion bounds honest against rate changes.
    const CHUNK_CAP: u64 = 4096;
    loop {
        let mut chunk = CHUNK_CAP;
        for w in worlds.iter() {
            chunk = chunk.min(w.completion_bound_ticks());
        }
        let dt = worlds[0].sim.tick_len().as_secs();
        let chunk = horizon_bound_ticks(worlds[0].now_secs(), dt, chunk, horizon, max);
        if chunk == 0 {
            // Boundary tick: step once serially under the reference
            // break checks — completions and the horizon fire here.
            let mut completed = false;
            for w in worlds.iter_mut() {
                completed |= w.step_once().session_completed;
            }
            let t = worlds[0].now_secs();
            if completed || t + 1e-9 >= horizon || t >= max {
                return;
            }
            continue;
        }
        // `chunk` ticks are completion-free and horizon-free on every
        // host: fan the hosts out across workers, each advancing its
        // shard the same tick count (warm epochs batch inside).
        let per = worlds.len().div_ceil(shards);
        std::thread::scope(|scope| {
            for shard in worlds.chunks_mut(per) {
                scope.spawn(move || {
                    for w in shard {
                        w.advance_ticks(chunk);
                    }
                });
            }
        });
    }
}

/// True when a projected fleet power fits under `cap` (no cap at all
/// fits everything) — the admission comparison, shared with the
/// migration re-admission path.
fn cap_ok(cap: Option<Power>, projected_w: f64) -> bool {
    cap.is_none_or(|cap| projected_w <= cap.as_watts() + 1e-9)
}

/// Expand the fault schedule's link degradations targeting `host` into
/// the scripted [`BandwidthEvent`]s its background process replays: the
/// collapse jumps the background mean to the degraded fraction at `at`,
/// the restore returns it to the testbed's own mean at `until`. An
/// empty schedule yields the same empty vec every pre-resilience build
/// passed, so inactive runs build bit-identical worlds.
fn link_events(faults: &FaultSchedule, host: usize, bg_mean: f64) -> Vec<BandwidthEvent> {
    let mut evs = Vec::new();
    for d in faults.link_degrades.iter().filter(|d| d.host == host) {
        evs.push(BandwidthEvent { at: d.at, mean_fraction: d.mean_fraction });
        evs.push(BandwidthEvent { at: d.until, mean_fraction: bg_mean });
    }
    evs
}

/// Overlay the resilience view on freshly built placement candidates: a
/// down host is masked out entirely (no free slots — it admits nothing
/// until revival), and every other host pays the PenaltyBox's decaying
/// per-strike J/B surcharge on its queue-delay price, so flaky hosts
/// are outbid rather than blacklisted (a struck host still wins when
/// every alternative is worse). Only called while the resilience config
/// is active.
fn apply_resilience(
    candidates: &mut [HostCandidate],
    down: &[bool],
    penalty: &PenaltyBox,
    now: f64,
) {
    for c in candidates.iter_mut() {
        if down[c.host] {
            c.free_slots = 0;
        } else {
            c.queue_delay_j_per_byte += penalty.surcharge_j_per_byte(c.host, now);
        }
    }
}

/// Session slots already spoken for by migrations mid-drain, per host —
/// both admission control and the rebalancer fold these into occupancy.
fn reserved_slots(in_flight: &[InFlight], hosts: usize) -> Vec<u32> {
    let mut reserved = vec![0u32; hosts];
    for m in in_flight {
        reserved[m.target] += 1;
    }
    reserved
}

/// A session mid-migration: preempted on its source host, draining, due
/// to re-admit its remaining bytes once the handoff delay passes. While
/// in flight the session is resident nowhere (it consumes no slot, no
/// link, no CPU) and the rebalancer cannot touch it again — the
/// "no migration during drain" invariant.
struct InFlight {
    /// The remaining-bytes spec; `arrive_at` is the resume instant.
    spec: SessionSpec,
    /// The host the rebalancer picked.
    target: usize,
    /// Index of this move in the run's migration records, patched if the
    /// fallback placement has to land the session elsewhere.
    record: usize,
}

/// The history context of one arriving session, resolved once at arrival
/// time: the attached index plus the session's workload fingerprint —
/// fingerprinting walks the whole file list, so queued sessions that
/// retry placement every segment must not recompute it.
struct LearnedQuery<'a> {
    index: &'a KnnIndex,
    fingerprint: WorkloadFingerprint,
    algo_id: &'static str,
    /// True when the index carries any v2 admission marginals: every
    /// host's observation then comes from the marginal question (hosts
    /// without one get none), never mixed with full-cost answers —
    /// scales must be uniform *within* one placement decision.
    marginal_scale: bool,
    /// Memoized per-`(host index, occupancy)` observations: the k-NN
    /// answer is a pure function of those two, and a power-capped queue
    /// head re-asks for it every event segment — without the memo each
    /// retry would rescan the whole index per host.
    observations: RefCell<BTreeMap<(usize, u32), Option<(f64, f64)>>>,
}

impl<'a> LearnedQuery<'a> {
    /// Resolve the context for `spec` (`None` without an index).
    fn for_spec(history: Option<&'a KnnIndex>, spec: &SessionSpec) -> Option<LearnedQuery<'a>> {
        history.map(|index| LearnedQuery {
            index,
            fingerprint: WorkloadFingerprint::of(&spec.dataset),
            algo_id: spec.algorithm.id(),
            marginal_scale: index.has_marginal_observations(),
            observations: RefCell::new(BTreeMap::new()),
        })
    }

    /// Observed `(J/B, confidence)` for this session on host `host_idx`
    /// at its current occupancy (memoized; see [`Self::observations`]).
    /// Uses the scale-consistent *marginal* observation recorded at past
    /// admissions (schema v2) whenever the store carries any; pure
    /// v1-era stores fall back to the full-cost attributed J/B. The
    /// choice is per store, not per host (see [`Self::marginal_scale`]).
    fn observed(
        &self,
        host_idx: usize,
        host_name: &str,
        world: &HostWorld,
        active: u32,
    ) -> Option<(f64, f64)> {
        *self
            .observations
            .borrow_mut()
            .entry((host_idx, active))
            .or_insert_with(|| {
                let q = Query::on_testbed(world.testbed(), self.fingerprint, active)
                    .with_algorithm(self.algo_id);
                if self.marginal_scale {
                    self.index.observed_marginal_j_per_byte(host_name, &q)
                } else {
                    self.index.observed_j_per_byte(host_name, &q)
                }
            })
    }
}

/// Warm-start a cold `HistoryTuned` session against the host that just
/// admitted it: the k-NN query uses *that* host's path and its occupancy
/// at admission, so on heterogeneous fleets the warm operating point
/// matches the hardware the session will actually run on (a host-0 query
/// at arrival time would answer for the wrong testbed). Sessions of any
/// other algorithm — and unconfident answers — pass through untouched.
fn warm_start_on_host(spec: &mut SessionSpec, world: &HostWorld, learned: Option<&LearnedQuery>) {
    if spec.algorithm != AlgorithmKind::HistoryTuned(None) {
        return;
    }
    let Some(lq) = learned else { return };
    let q = Query::on_testbed(world.testbed(), lq.fingerprint, world.occupancy())
        .with_algorithm(lq.algo_id);
    if let Some(warm) = lq.index.confident_warm_start(&q) {
        spec.algorithm = AlgorithmKind::HistoryTuned(Some(warm));
    }
}

/// Snapshot every host into placement candidates (see [`HostCandidate`]).
/// With a history context resolved, each candidate is additionally scored
/// with the observed ΔJ/byte of workloads like the arriving one on that
/// host (the per-host testbed and current occupancy parameterize the
/// query).
fn build_candidates(
    worlds: &[HostWorld],
    hosts: &[HostSpec],
    learned: Option<&LearnedQuery<'_>>,
    price_queue_delay: bool,
    reserved: &[u32],
) -> Vec<HostCandidate> {
    let current: Vec<(u32, f64)> = worlds
        .iter()
        .enumerate()
        .map(|(i, w)| {
            // Occupancy, not activation: sessions registered this segment
            // activate on the next tick but already claim their slot and
            // their share of the projection, otherwise two simultaneous
            // arrivals would both see an empty host. Migrants mid-drain
            // (`reserved`) equally claim their planned target's slot and
            // draw, so an arrival cannot steal them during the handoff.
            let active = w.occupancy() + reserved[i];
            (active, w.projected_power_w(active))
        })
        .collect();
    let fleet_base: f64 = current.iter().map(|(_, w)| w).sum();
    worlds
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let (active, cur_w) = current[i];
            let proj_w = w.projected_power_w(active + 1);
            // Same gate warm starts honor: an observation below the
            // confidence floor is telemetry at best, never a score term.
            let observed = learned
                .and_then(|lq| lq.observed(i, &hosts[i].name, w, active))
                .filter(|&(_, conf)| conf >= CONFIDENCE_FLOOR);
            // Contention price: extra seconds-per-byte vs running alone,
            // at the host's idle draw (zero on an empty host, and zero
            // whenever queue-delay pricing is disabled). The formula is
            // shared with the rebalancer's move comparison so the two
            // layers can never price contention differently.
            let queue_delay_j_per_byte = if price_queue_delay && active > 0 {
                crate::rebalance::contention_price_j_per_byte(
                    w.projected_power_w(0),
                    w.projected_session_bps(active + 1),
                    w.projected_session_bps(1),
                )
            } else {
                0.0
            };
            HostCandidate {
                host: i,
                active_sessions: active,
                free_slots: hosts[i].max_sessions.saturating_sub(active),
                current_power_w: cur_w,
                projected_power_w: proj_w,
                projected_session_bps: w.projected_session_bps(active + 1),
                projected_fleet_power_w: fleet_base - cur_w + proj_w,
                queue_delay_j_per_byte,
                learned_j_per_byte: observed.map(|(jpb, _)| jpb),
                learned_weight: observed.map(|(_, conf)| conf).unwrap_or(0.0),
            }
        })
        .collect()
}

/// Turn one decision into its telemetry record.
fn make_record(
    now: f64,
    session: &str,
    requested_at: f64,
    admitted: Option<usize>,
    candidates: &[HostCandidate],
    hosts: &[HostSpec],
) -> DispatchRecord {
    let scores = candidates
        .iter()
        .map(|c| PlacementScore {
            host: hosts[c.host].name.clone(),
            active_sessions: c.active_sessions,
            current_power_w: c.current_power_w,
            projected_power_w: c.projected_power_w,
            projected_session_bps: c.projected_session_bps,
            marginal_j_per_byte: c.marginal_j_per_byte(),
            queue_delay_j_per_byte: c.queue_delay_j_per_byte,
            learned_j_per_byte: c.learned_j_per_byte,
        })
        .collect();
    let projected_fleet_power_w = match admitted {
        Some(h) => candidates
            .iter()
            .find(|c| c.host == h)
            .map(|c| c.projected_fleet_power_w)
            .unwrap_or(0.0),
        // Queued: report the best projection among hosts that had a free
        // slot — the one that still broke the cap (or the fleet's current
        // draw when no slot was free at all).
        None => {
            let best = candidates
                .iter()
                .filter(|c| c.free_slots > 0)
                .map(|c| c.projected_fleet_power_w)
                .fold(f64::INFINITY, f64::min);
            if best.is_finite() {
                best
            } else {
                candidates.iter().map(|c| c.current_power_w).sum()
            }
        }
    };
    DispatchRecord {
        t_secs: now,
        session: session.to_string(),
        requested_at_secs: requested_at,
        admitted_host: admitted,
        host: admitted.map(|h| hosts[h].name.clone()),
        projected_fleet_power_w,
        scores,
    }
}

/// The run's observability funnel: the trace sink (collector track 0)
/// and/or the metrics registry, both optional and both fed exclusively
/// from segment-boundary code on the dispatcher thread. An inactive
/// collector (`--trace` and `--metrics` both off) is a pair of `None`s
/// and every hook below is a cold branch — the off-path bit-identity
/// contract.
struct Collector {
    sink: Option<TraceSink>,
    metrics: Option<FleetMetrics>,
    /// The decision calibration ledger (on whenever any observability
    /// is — it feeds trace events, metrics histograms and the outcome's
    /// ledger alike).
    calib: Option<CalibrationLedger>,
    /// Anomaly/watchdog thresholds for the ledger.
    calib_cfg: CalibrationConfig,
    /// Admissions so far — the starvation watchdog's progress marker.
    admitted_total: u64,
    /// `(admissions at anchor, anchor time)`: the queue has been
    /// non-empty with no admission since the anchor. `None` while the
    /// queue is empty.
    starve_anchor: Option<(u64, f64)>,
    /// Edge trigger: the starvation alarm already fired for this stall.
    starving: bool,
    /// Edge trigger: the fairness alarm already fired for this dip.
    fairness_low: bool,
    /// Previous boundary's per-host delivered-byte odometers (fairness
    /// watchdog deltas).
    last_moved_by_host: Vec<f64>,
    /// Segment-delta bookkeeping for the timeline (previous boundary's
    /// clock, fleet byte/joule odometers and driver tick counters).
    last_t: f64,
    last_moved: f64,
    last_joules: f64,
    last_warm: u64,
    last_slow: u64,
    last_aimd: u64,
}

impl Collector {
    fn new(trace: bool, metrics: bool, calib_cfg: CalibrationConfig) -> Collector {
        Collector {
            sink: trace.then(TraceSink::new),
            metrics: metrics.then(FleetMetrics::default),
            calib: (trace || metrics).then(CalibrationLedger::default),
            calib_cfg,
            admitted_total: 0,
            starve_anchor: None,
            starving: false,
            fairness_low: false,
            last_moved_by_host: Vec::new(),
            last_t: 0.0,
            last_moved: 0.0,
            last_joules: 0.0,
            last_warm: 0,
            last_slow: 0,
            last_aimd: 0,
        }
    }

    fn active(&self) -> bool {
        self.sink.is_some() || self.metrics.is_some()
    }

    /// A scripted cap change fired.
    fn on_cap_event(&mut self, now: f64, cap: Option<Power>) {
        if let Some(sink) = &mut self.sink {
            let cap_attr = match cap {
                Some(p) => AttrValue::F64(p.as_watts()),
                None => "none".into(),
            };
            sink.event("cap_event", now, None, None, None, vec![("cap_w", cap_attr)]);
        }
        if let Some(m) = &mut self.metrics {
            m.registry.inc("cap.events", 1);
        }
    }

    /// A scripted fault action fired (recorded after its victims, so the
    /// event carries the final `sessions_hit` count).
    fn on_fault(&mut self, rec: &FaultRecord) {
        if let Some(sink) = &mut self.sink {
            sink.event(
                "fault",
                rec.t_secs,
                None,
                Some(&rec.host_name),
                None,
                vec![
                    ("kind", rec.kind.id().into()),
                    ("sessions_hit", AttrValue::U64(rec.sessions_hit as u64)),
                ],
            );
        }
        if let Some(m) = &mut self.metrics {
            m.registry.inc("faults.fired", 1);
        }
    }

    /// A session was quarantined in the dead-letter queue.
    fn on_dead_letter(&mut self, dl: &DeadLetter, host_name: &str) {
        if let Some(sink) = &mut self.sink {
            let root = sink.root(&dl.session, dl.at_secs);
            sink.event(
                "dead_letter",
                dl.at_secs,
                Some(&dl.session),
                Some(host_name),
                Some(root),
                vec![
                    ("reason", dl.reason.id().into()),
                    ("attempts", AttrValue::U64(dl.attempts as u64)),
                    ("moved_bytes", AttrValue::F64(dl.moved_bytes)),
                    ("remaining_bytes", AttrValue::F64(dl.remaining_bytes)),
                ],
            );
        }
        if let Some(m) = &mut self.metrics {
            m.registry.inc("sessions.dead_lettered", 1);
        }
    }

    /// The PenaltyBox scheduled a retry: an instant `retry` event plus a
    /// `penalty_box` span covering the backoff wait.
    fn on_retry(&mut self, rec: &RetryRecord) {
        if let Some(sink) = &mut self.sink {
            let root = sink.root(&rec.session, rec.t_secs);
            sink.event(
                "retry",
                rec.t_secs,
                Some(&rec.session),
                Some(&rec.from),
                Some(root),
                vec![
                    ("attempt", AttrValue::U64(rec.attempt as u64)),
                    ("backoff_s", AttrValue::F64(rec.backoff_secs)),
                    ("remaining_bytes", AttrValue::F64(rec.remaining_bytes)),
                ],
            );
            sink.span(
                "penalty_box",
                rec.t_secs,
                rec.resume_at_secs,
                Some(&rec.session),
                None,
                Some(root),
                vec![("attempt", AttrValue::U64(rec.attempt as u64))],
            );
        }
        if let Some(m) = &mut self.metrics {
            m.registry.inc("retries.scheduled", 1);
            m.registry.record("retry.backoff_s", rec.backoff_secs);
        }
    }

    /// A placement decision was made (admitted or queued): a `placement`
    /// event under the session root plus one `placement_score` child per
    /// candidate host, so rejected candidates are visible with the
    /// scores that outbid them.
    fn on_decision(&mut self, rec: &DispatchRecord) {
        if rec.admitted_host.is_some() {
            self.admitted_total += 1;
        }
        if let Some(m) = &mut self.metrics {
            match rec.admitted_host {
                Some(_) => {
                    m.registry.inc("placements.admitted", 1);
                    m.registry.record("queue.wait_s", rec.waited_secs());
                }
                None => m.registry.inc("placements.queued", 1),
            }
        }
        let Some(sink) = &mut self.sink else { return };
        let root = sink.root(&rec.session, rec.t_secs);
        let mut attrs = vec![
            ("queued", AttrValue::Bool(rec.queued())),
            ("waited_s", AttrValue::F64(rec.waited_secs())),
            ("projected_fleet_power_w", AttrValue::F64(rec.projected_fleet_power_w)),
        ];
        if let Some(h) = &rec.host {
            attrs.push(("host", h.as_str().into()));
        }
        let placement = sink.event(
            "placement",
            rec.t_secs,
            Some(&rec.session),
            rec.host.as_deref(),
            Some(root),
            attrs,
        );
        for s in &rec.scores {
            sink.event(
                "placement_score",
                rec.t_secs,
                Some(&rec.session),
                Some(&s.host),
                Some(placement),
                vec![
                    ("active_sessions", AttrValue::U64(s.active_sessions as u64)),
                    ("marginal_j_per_byte", AttrValue::F64(s.marginal_j_per_byte)),
                    ("queue_delay_j_per_byte", AttrValue::F64(s.queue_delay_j_per_byte)),
                    ("projected_session_bps", AttrValue::F64(s.projected_session_bps)),
                ],
            );
        }
    }

    /// A session is about to register on `world`: hand the host buffer
    /// the session's root id so residency spans parent correctly.
    fn on_admit(&mut self, world: &mut HostWorld, session: &str, now: f64) {
        if let Some(sink) = &mut self.sink {
            let root = sink.root(session, now);
            world.trace_root(session, root);
        }
    }

    /// The rebalancer executed a move: a `migrate` span covering the
    /// drain window, plus the est-cost histograms the realized-delay
    /// series is compared against.
    fn on_migration(&mut self, rec: &MigrationRecord) {
        if let Some(c) = &mut self.calib {
            c.migrations.push(MigrationCalibration {
                session: rec.session.clone(),
                from: rec.from.clone(),
                to: rec.to.clone(),
                t_secs: rec.t_secs,
                resume_at_secs: rec.resume_at_secs,
                est_benefit_j: rec.est_benefit_j,
                est_cost_j: rec.est_cost_j,
                realized_delay_s: None,
                realized_benefit_j: None,
            });
        }
        if let Some(sink) = &mut self.sink {
            let root = sink.root(&rec.session, rec.t_secs);
            sink.span(
                "migrate",
                rec.t_secs,
                rec.resume_at_secs,
                Some(&rec.session),
                Some(&rec.from),
                Some(root),
                vec![
                    ("from", rec.from.as_str().into()),
                    ("to", rec.to.as_str().into()),
                    ("moved_bytes", AttrValue::F64(rec.moved_bytes)),
                    ("remaining_bytes", AttrValue::F64(rec.remaining_bytes)),
                    ("drain_s", AttrValue::F64(rec.drain_secs)),
                    ("est_benefit_j", AttrValue::F64(rec.est_benefit_j)),
                    ("est_cost_j", AttrValue::F64(rec.est_cost_j)),
                    ("policy", rec.policy.into()),
                ],
            );
        }
        if let Some(m) = &mut self.metrics {
            m.registry.inc("migrations.executed", 1);
            m.registry.record("migration.est_benefit_j", rec.est_benefit_j);
            m.registry.record("migration.est_cost_j", rec.est_cost_j);
        }
    }

    /// A migrated session re-admitted: how late past the planned resume
    /// instant it actually landed (0 when the drain window ended exactly
    /// on plan, positive when the fleet kept it queued longer).
    fn on_migration_resumed(&mut self, now: f64, planned_resume: f64) {
        if let Some(m) = &mut self.metrics {
            m.registry.record("migration.realized_delay_s", (now - planned_resume).max(0.0));
        }
    }

    /// The rebalancer's audited scan: one `rebalance_proposal` event per
    /// candidate verdict, accepted and rejected alike, with the cost
    /// model's reasoning attached.
    fn on_rebalance_verdicts(&mut self, now: f64, verdicts: &[MoveVerdict], hosts: &[HostSpec]) {
        if let Some(m) = &mut self.metrics {
            let rejected = verdicts.iter().filter(|v| !v.accepted).count() as u64;
            m.registry.inc("rebalance.rejected", rejected);
            for v in verdicts.iter().filter(|v| v.accepted) {
                m.registry.record("rebalance.net_j", v.net_j());
            }
        }
        let Some(sink) = &mut self.sink else { return };
        for v in verdicts {
            let root = sink.root_of(&v.session);
            sink.event(
                "rebalance_proposal",
                now,
                Some(&v.session),
                Some(&hosts[v.from].name),
                root,
                vec![
                    ("to", hosts[v.to].name.as_str().into()),
                    ("est_benefit_j", AttrValue::F64(v.est_benefit_j)),
                    ("est_cost_j", AttrValue::F64(v.est_cost_j)),
                    ("est_power_drop_w", AttrValue::F64(v.est_power_drop_w)),
                    ("accepted", AttrValue::Bool(v.accepted)),
                    ("reason", v.reason.into()),
                ],
            );
        }
    }

    /// The health monitor flagged a degrading host.
    fn on_advisory(&mut self, a: &Advisory, host_name: &str) {
        if let Some(sink) = &mut self.sink {
            sink.event(
                "health_advisory",
                a.at_secs,
                None,
                Some(host_name),
                None,
                vec![
                    ("observed_bps", AttrValue::F64(a.observed_bps)),
                    ("expected_bps", AttrValue::F64(a.expected_bps)),
                    ("below_since_s", AttrValue::F64(a.below_since_secs)),
                ],
            );
        }
        if let Some(m) = &mut self.metrics {
            m.registry.inc("health.advisories", 1);
        }
    }

    /// Segment boundary: drain every host's trace buffer into the sink
    /// in host-index order (the merge discipline that keeps the log
    /// shard-invariant) and snapshot the fleet for the timeline.
    fn on_segment(&mut self, worlds: &mut [HostWorld], queued: usize) {
        if let Some(sink) = &mut self.sink {
            for w in worlds.iter_mut() {
                sink.absorb(w.take_trace());
            }
        }
        let t = worlds[0].now_secs();
        if self.calib.is_some() {
            for i in 0..worlds.len() {
                for rec in worlds[i].take_calibration() {
                    self.process_calibration(rec);
                }
            }
            self.watchdogs(worlds, queued, t);
        }
        let Some(m) = &mut self.metrics else { return };
        let mut moved = 0.0;
        let mut joules = 0.0;
        let mut warm = 0u64;
        let mut slow = 0u64;
        let mut aimd = 0u64;
        let mut active = 0u64;
        for w in worlds.iter() {
            moved += w.moved_bytes();
            joules += w.sim.client_energy().as_joules();
            let (tw, ts) = w.sim.tick_counts();
            warm += tw;
            slow += ts;
            aimd += w.sim.slots().iter().map(|s| s.engine.aimd_backoffs()).sum::<u64>();
            active += w.occupancy() as u64;
        }
        let dt = t - self.last_t;
        let (goodput_bps, watts) = if dt > 1e-9 {
            ((moved - self.last_moved) / dt, (joules - self.last_joules) / dt)
        } else {
            (0.0, 0.0)
        };
        if dt > 1e-9 {
            m.registry.record("goodput.segment_bps", goodput_bps);
            m.registry.record("watts.segment_w", watts);
        }
        m.registry.inc("stepper.warm_ticks", warm - self.last_warm);
        m.registry.inc("stepper.slow_ticks", slow - self.last_slow);
        m.registry.inc("aimd.backoffs", aimd.saturating_sub(self.last_aimd));
        m.timeline.snapshots.push(SegmentSnapshot {
            t_secs: t,
            active_sessions: active,
            queued: queued as u64,
            goodput_bps,
            watts,
            warm_ticks: warm - self.last_warm,
            slow_ticks: slow - self.last_slow,
        });
        self.last_t = t;
        self.last_moved = moved;
        self.last_joules = joules;
        self.last_warm = warm;
        self.last_slow = slow;
        self.last_aimd = aimd;
    }

    /// One closed residency reaches the ledger: error histogram,
    /// anomaly screen (trace event + counter when the realized J/B
    /// deviates beyond the configured factor), then the record itself.
    fn process_calibration(&mut self, rec: CalibrationRecord) {
        if let Some(m) = &mut self.metrics {
            if let Some(e) = rec.rel_error() {
                m.registry.record("placement.jpb_error", e);
            }
            m.registry.inc("calibration.records", 1);
        }
        if rec.is_anomalous(self.calib_cfg.anomaly_factor) {
            let anomaly = CalibrationAnomaly {
                session: rec.session.clone(),
                host: rec.host.clone(),
                t_secs: rec.t1_secs,
                predicted_jpb: rec.predicted_jpb.unwrap_or(0.0),
                realized_jpb: rec.realized_jpb().unwrap_or(0.0),
                ratio: rec.error_ratio().unwrap_or(0.0),
            };
            if let Some(m) = &mut self.metrics {
                m.registry.inc("calibration.anomalies", 1);
            }
            if let Some(sink) = &mut self.sink {
                let root = sink.root_of(&rec.session);
                sink.event(
                    "calibration_anomaly",
                    rec.t1_secs,
                    Some(&rec.session),
                    Some(&rec.host),
                    root,
                    vec![
                        ("predicted_jpb", AttrValue::F64(anomaly.predicted_jpb)),
                        ("realized_jpb", AttrValue::F64(anomaly.realized_jpb)),
                        ("ratio", AttrValue::F64(anomaly.ratio)),
                    ],
                );
            }
            if let Some(c) = &mut self.calib {
                c.anomalies.push(anomaly);
            }
        }
        if let Some(c) = &mut self.calib {
            c.placements.push(rec);
        }
    }

    /// Segment-boundary health screens: a starvation alarm when the
    /// queue stays non-empty with zero admissions past the configured
    /// window, and a Jain-fairness alarm when active hosts' segment
    /// byte deltas skew below the floor. Both are edge-triggered — one
    /// event per stall/dip, re-armed on recovery.
    fn watchdogs(&mut self, worlds: &[HostWorld], queued: usize, t: f64) {
        if queued == 0 {
            self.starve_anchor = None;
            self.starving = false;
        } else {
            match self.starve_anchor {
                Some((n, since)) if n == self.admitted_total => {
                    if !self.starving && t - since > self.calib_cfg.starve_secs {
                        self.starving = true;
                        if let Some(m) = &mut self.metrics {
                            m.registry.inc("watchdog.queue_starved", 1);
                        }
                        if let Some(sink) = &mut self.sink {
                            sink.event(
                                "queue_starved",
                                t,
                                None,
                                None,
                                None,
                                vec![
                                    ("queued", AttrValue::U64(queued as u64)),
                                    ("starved_s", AttrValue::F64(t - since)),
                                ],
                            );
                        }
                    }
                }
                _ => {
                    self.starve_anchor = Some((self.admitted_total, t));
                    self.starving = false;
                }
            }
        }
        self.last_moved_by_host.resize(worlds.len(), 0.0);
        let mut deltas = Vec::new();
        for (i, w) in worlds.iter().enumerate() {
            let moved = w.moved_bytes();
            let delta = moved - self.last_moved_by_host[i];
            self.last_moved_by_host[i] = moved;
            if w.occupancy() > 0 {
                deltas.push(delta);
            }
        }
        if deltas.len() >= 2 {
            if let Some(j) = jain_index(deltas.iter().copied()) {
                if let Some(m) = &mut self.metrics {
                    m.registry.record("fairness.jain_hosts", j);
                }
                if j < self.calib_cfg.fairness_floor {
                    if !self.fairness_low {
                        self.fairness_low = true;
                        if let Some(m) = &mut self.metrics {
                            m.registry.inc("watchdog.fairness_drop", 1);
                        }
                        if let Some(sink) = &mut self.sink {
                            sink.event(
                                "fairness_drop",
                                t,
                                None,
                                None,
                                None,
                                vec![
                                    ("jain", AttrValue::F64(j)),
                                    ("hosts_active", AttrValue::U64(deltas.len() as u64)),
                                ],
                            );
                        }
                    }
                } else {
                    self.fairness_low = false;
                }
            }
        } else {
            self.fairness_low = false;
        }
    }

    /// End of run (satellite: censored-wait accounting): admissions
    /// still queued when the run ends never reach [`Self::on_decision`],
    /// so their waits would silently vanish from `queue.wait_s` and the
    /// histogram would under-report exactly the saturated tail. Record
    /// each censored wait (request → run end) plus a `queue.censored`
    /// counter so readers can tell observed waits from censored ones.
    fn on_run_end(&mut self, end_secs: f64, queued_requested: &[f64]) {
        if let Some(m) = &mut self.metrics {
            m.registry.inc("queue.censored", queued_requested.len() as u64);
            for &req in queued_requested {
                m.registry.record("queue.wait_s", (end_secs - req).max(0.0));
            }
        }
    }

    /// End of run: close every host's still-open residency, drain the
    /// leftovers, join migrations against their resumed residencies and
    /// finalize the merged log.
    fn finish(mut self, worlds: &mut [HostWorld], end_secs: f64) -> FinishedCollector {
        if self.calib.is_some() {
            for i in 0..worlds.len() {
                worlds[i].finalize_calibration();
                for rec in worlds[i].take_calibration() {
                    self.process_calibration(rec);
                }
            }
            if let Some(c) = &mut self.calib {
                c.join_migrations();
                if let Some(m) = &mut self.metrics {
                    for mig in &c.migrations {
                        if let Some(e) = mig.benefit_error_j() {
                            m.registry.record("migration.benefit_error_j", e);
                        }
                    }
                }
            }
        }
        if let Some(sink) = &mut self.sink {
            for w in worlds.iter_mut() {
                w.finalize_trace();
                sink.absorb(w.take_trace());
            }
        }
        FinishedCollector {
            trace: self.sink.map(|s| s.finalize(end_secs)),
            metrics: self.metrics,
            calibration: self.calib,
        }
    }
}

/// What [`Collector::finish`] hands the outcome.
struct FinishedCollector {
    trace: Option<Vec<TraceRecord>>,
    metrics: Option<FleetMetrics>,
    calibration: Option<CalibrationLedger>,
}

/// Run a multi-host fleet to completion (or the time cap): sessions
/// arrive on their [`TenantSpec::arrive_at`] schedule, the
/// [`Dispatcher`] places each one, and every host runs the shared
/// [`super::fleet`] driver. See the module docs for the semantics of
/// placement, admission control and the cross-host event horizon.
pub fn run_dispatcher(cfg: &DispatcherConfig) -> DispatchOutcome {
    assert!(!cfg.hosts.is_empty(), "a dispatcher needs at least one host");
    cfg.resilience
        .faults
        .validate(cfg.hosts.len())
        .unwrap_or_else(|e| panic!("invalid fault schedule: {e}"));

    let mut worlds: Vec<HostWorld> = cfg
        .hosts
        .iter()
        .enumerate()
        .map(|(i, h)| {
            HostWorld::build(
                h.name.clone(),
                &h.testbed,
                &[],
                Some(cfg.policy),
                cfg.params,
                cfg.fleet_interval,
                cfg.tick,
                host_seed(cfg.seed, i),
                // Scripted link collapses ride the same bandwidth-event
                // machinery the single-host scenarios use (empty vec
                // without a fault schedule).
                link_events(&cfg.resilience.faults, i, h.testbed.bg_mean),
                false,
                cfg.record_timeline,
                cfg.reference_stepper,
                cfg.constant_bg,
                cfg.cross_traffic,
                cfg.aimd,
            )
        })
        .collect();

    // The observability funnel: trace sink and/or metrics registry,
    // inert (and bit-invisible to the run) unless enabled. Host worlds
    // get per-host trace buffers on tracks 1..=N; the collector itself
    // is track 0.
    let mut coll = Collector::new(cfg.trace, cfg.metrics, cfg.calibration);
    if cfg.trace {
        for (i, w) in worlds.iter_mut().enumerate() {
            w.enable_trace(i as u64 + 1);
        }
    }
    if coll.active() {
        for w in worlds.iter_mut() {
            w.enable_calibration();
        }
    }
    if let Some(m) = &mut coll.metrics {
        m.registry.set_gauge("fleet.hosts", cfg.hosts.len() as f64);
    }

    // Arrivals ordered by request time (stable for equal instants, so
    // spec order breaks ties deterministically).
    let mut pending: Vec<SessionSpec> = cfg.sessions.clone();
    pending.sort_by(|a, b| a.arrive_at.as_secs().total_cmp(&b.arrive_at.as_secs()));
    let mut pending: VecDeque<SessionSpec> = pending.into();
    // Sessions admission control is holding back, FIFO: the head blocks
    // the rest so a power-hungry host cannot starve early requesters.
    // Each entry carries its once-resolved history context so retries
    // never re-fingerprint the dataset.
    // Queue entries additionally carry the session's migration-record
    // index when it is a resuming migrant, so a re-admission that lands
    // off-plan can patch the record's target.
    let mut queue: VecDeque<(SessionSpec, f64, Option<LearnedQuery>, Option<usize>)> =
        VecDeque::new();
    let mut dispatcher = Dispatcher::new(cfg.placement, cfg.power_cap);
    let mut decisions: Vec<DispatchRecord> = Vec::new();

    // The rebalancer and its bookkeeping: scripted cap changes in event
    // order, executed moves, and sessions mid-drain.
    let mut effective_cap = cfg.power_cap;
    let mut cap_events: VecDeque<PowerCapEvent> = {
        let mut evs = cfg.cap_events.clone();
        evs.sort_by(|a, b| a.at.as_secs().total_cmp(&b.at.as_secs()));
        evs.into()
    };
    let mut rebalancer = Rebalancer::new(cfg.rebalance.clone());
    let mut migrations: Vec<MigrationRecord> = Vec::new();
    let mut in_flight: Vec<InFlight> = Vec::new();

    // The resilience pipeline (see `crate::resilience`): the expanded
    // fault timeline, the per-host down mask, per-session attempt
    // counts, the PenaltyBox, the dead-letter queue, the health monitor
    // and the retries waiting out their backoff. All of it stays empty
    // — and every gate below stays cold — while the config is inactive,
    // which is the `--resilience off` bit-identity contract.
    let res = &cfg.resilience;
    let res_active = res.active();
    let mut fault_timeline = res.faults.timeline();
    let mut down = vec![false; worlds.len()];
    let mut attempts: BTreeMap<String, u32> = BTreeMap::new();
    // Cumulative bytes each preempted session delivered across all its
    // residencies (retries *and* migrations), so a dead letter's ledger
    // closes on its own: `moved_bytes + remaining_bytes` equals the
    // session's original dataset size however many hops it survived.
    let mut delivered: BTreeMap<String, f64> = BTreeMap::new();
    let mut penalty_box = PenaltyBox::new(res.penalty);
    let mut dead_letters = DeadLetterQueue::new(res.dead_letter_capacity);
    let mut health = HealthMonitor::new(res.health, worlds.len());
    let mut retries: Vec<SessionSpec> = Vec::new();
    let mut faults_log: Vec<FaultRecord> = Vec::new();
    let mut retry_log: Vec<RetryRecord> = Vec::new();
    let mut advisories: Vec<Advisory> = Vec::new();
    let mut last_moved = vec![0.0f64; worlds.len()];
    let mut last_health_at = 0.0f64;

    let max = cfg.max_sim_time.as_secs();
    let shards = effective_shards(cfg.shards, cfg.hosts.len());
    loop {
        let now = worlds[0].now_secs();

        // Scripted cap changes due now retarget admission control (and
        // the cap-pressure trigger) before any decision this segment.
        while cap_events
            .front()
            .is_some_and(|e| e.at.as_secs() <= now + 1e-9)
        {
            effective_cap = cap_events.pop_front().expect("non-empty").cap;
            dispatcher.set_power_cap(effective_cap);
            if coll.active() {
                coll.on_cap_event(now, effective_cap);
            }
        }

        // Scripted faults due now fire next — before re-admissions and
        // arrivals, so nothing lands on a host in the instant it dies.
        // A host death preempts every running session there (tenant
        // order — deterministic); each victim's remaining bytes
        // re-materialize as a backed-off retry when the budget allows,
        // or a dead letter when it is exhausted (immediately, with
        // recovery off — the terminal-loss baseline).
        if res_active {
            while let Some(action) = fault_timeline.pop_due(now) {
                let mut sessions_hit = 0u32;
                match action.kind {
                    FaultKind::HostDown => {
                        down[action.host] = true;
                        for (tenant, name, _) in worlds[action.host].running_sessions() {
                            sessions_hit += 1;
                            let attempt = {
                                let n = attempts.entry(name.clone()).or_insert(0);
                                *n += 1;
                                *n
                            };
                            let pre = worlds[action.host].preempt(tenant);
                            let total_delivered = {
                                let d = delivered.entry(name).or_insert(0.0);
                                *d += pre.moved.as_f64();
                                *d
                            };
                            if attempt > res.effective_retry_budget() {
                                let reason = if res.enabled {
                                    FailureReason::RetryBudgetExhausted
                                } else {
                                    FailureReason::HostFailure
                                };
                                worlds[action.host]
                                    .mark_session_failed(tenant, RunOutcome::DeadLettered);
                                let letter = DeadLetter {
                                    session: pre.name,
                                    host: action.host,
                                    reason,
                                    attempts: attempt,
                                    moved_bytes: total_delivered,
                                    remaining_bytes: pre.remaining.as_f64(),
                                    at_secs: now,
                                };
                                if coll.active() {
                                    coll.on_dead_letter(&letter, &cfg.hosts[action.host].name);
                                }
                                dead_letters.push(letter);
                            } else {
                                worlds[action.host]
                                    .mark_session_failed(tenant, RunOutcome::Failed);
                                penalty_box.note_failure(action.host, now);
                                let backoff = penalty_box.backoff_secs(attempt);
                                retry_log.push(RetryRecord {
                                    t_secs: now,
                                    session: pre.name.clone(),
                                    from_host: action.host,
                                    from: cfg.hosts[action.host].name.clone(),
                                    attempt,
                                    backoff_secs: backoff,
                                    resume_at_secs: now + backoff,
                                    remaining_bytes: pre.remaining.as_f64(),
                                });
                                if coll.active() {
                                    coll.on_retry(retry_log.last().expect("just pushed"));
                                }
                                retries.push(
                                    TenantSpec::new(pre.name, pre.dataset, pre.algorithm)
                                        .arriving_at(SimTime::from_secs(now + backoff)),
                                );
                            }
                        }
                    }
                    FaultKind::HostUp => down[action.host] = false,
                    // Link faults act through the bandwidth events each
                    // world replays (scheduled at build time); firing
                    // here only records that they happened.
                    FaultKind::LinkDegrade | FaultKind::LinkRestore => {}
                }
                faults_log.push(FaultRecord {
                    t_secs: now,
                    host: action.host,
                    host_name: cfg.hosts[action.host].name.clone(),
                    kind: action.kind,
                    sessions_hit,
                });
                if coll.active() {
                    coll.on_fault(faults_log.last().expect("just pushed"));
                }
            }
        }

        // Migrations due re-admit before anything else: the session was
        // admitted once already, so the move must not cost it its place
        // behind the FIFO queue.
        let mut mi = 0;
        while mi < in_flight.len() {
            if in_flight[mi].spec.arrive_at.as_secs() > now + 1e-9 {
                mi += 1;
                continue;
            }
            let InFlight { mut spec, target, record } = in_flight.remove(mi);
            let resumed_at = spec.arrive_at.as_secs();
            let learned = LearnedQuery::for_spec(cfg.history.as_ref(), &spec);
            // Computed after the removal above, so the resuming session
            // does not block itself with its own reservation.
            let reserved = reserved_slots(&in_flight, worlds.len());
            let mut candidates = build_candidates(
                &worlds,
                &cfg.hosts,
                learned.as_ref(),
                cfg.price_queue_delay,
                &reserved,
            );
            if res_active {
                // A host that died during the drain is masked out, so
                // the direct-return check below falls through to a
                // fresh placement instead of resuming onto a corpse.
                apply_resilience(&mut candidates, &down, &penalty_box, now);
            }
            // The planned target takes the session back if it still can
            // (free slot, cap headroom); a fleet that changed during the
            // drain falls back to a fresh placement decision.
            let direct = candidates
                .iter()
                .find(|c| c.host == target && c.free_slots > 0)
                .filter(|c| cap_ok(effective_cap, c.projected_fleet_power_w))
                .map(|c| PlaceDecision::Admit(c.host));
            match direct.unwrap_or_else(|| dispatcher.place(&candidates)) {
                PlaceDecision::Admit(h) => {
                    decisions.push(make_record(
                        now,
                        &spec.name,
                        resumed_at,
                        Some(h),
                        &candidates,
                        &cfg.hosts,
                    ));
                    if coll.active() {
                        coll.on_decision(decisions.last().expect("just pushed"));
                        coll.on_migration_resumed(now, resumed_at);
                    }
                    if h != target {
                        migrations[record].to_host = h;
                        migrations[record].to = cfg.hosts[h].name.clone();
                    }
                    let marginal = candidates
                        .iter()
                        .find(|c| c.host == h)
                        .map(|c| c.marginal_j_per_byte());
                    warm_start_on_host(&mut spec, &worlds[h], learned.as_ref());
                    let fp = learned.map(|l| l.fingerprint);
                    coll.on_admit(&mut worlds[h], &spec.name, now);
                    worlds[h].register_arrival(spec, fp, marginal);
                }
                _ => {
                    // Nowhere to land right now: wait at the queue head
                    // (the resuming session is the oldest requester).
                    decisions.push(make_record(
                        now,
                        &spec.name,
                        resumed_at,
                        None,
                        &candidates,
                        &cfg.hosts,
                    ));
                    if coll.active() {
                        coll.on_decision(decisions.last().expect("just pushed"));
                    }
                    queue.push_front((spec, resumed_at, learned, Some(record)));
                }
            }
        }

        // Retries whose PenaltyBox backoff has elapsed re-enter
        // placement next: like a resuming migrant, a retried session
        // was admitted once already, so it goes ahead of the FIFO
        // queue rather than to its tail. The batch is ordered by
        // (resume instant, name) — deterministic — and once one retry
        // fails to land, the rest of the batch defers behind it in the
        // same order (each gets its queued decision record, exactly as
        // a blocked newcomer would).
        if !retries.is_empty() {
            let mut due: Vec<SessionSpec> = Vec::new();
            let mut ri = 0;
            while ri < retries.len() {
                if retries[ri].arrive_at.as_secs() <= now + 1e-9 {
                    due.push(retries.remove(ri));
                } else {
                    ri += 1;
                }
            }
            due.sort_by(|a, b| {
                a.arrive_at
                    .as_secs()
                    .total_cmp(&b.arrive_at.as_secs())
                    .then_with(|| a.name.cmp(&b.name))
            });
            let mut deferred = Vec::new();
            for mut spec in due {
                let resumed_at = spec.arrive_at.as_secs();
                let learned = LearnedQuery::for_spec(cfg.history.as_ref(), &spec);
                let reserved = reserved_slots(&in_flight, worlds.len());
                let mut candidates = build_candidates(
                    &worlds,
                    &cfg.hosts,
                    learned.as_ref(),
                    cfg.price_queue_delay,
                    &reserved,
                );
                apply_resilience(&mut candidates, &down, &penalty_box, now);
                let decision = if deferred.is_empty() {
                    dispatcher.place(&candidates)
                } else {
                    PlaceDecision::QueuePowerCap // FIFO within the batch
                };
                match decision {
                    PlaceDecision::Admit(h) => {
                        decisions.push(make_record(
                            now,
                            &spec.name,
                            resumed_at,
                            Some(h),
                            &candidates,
                            &cfg.hosts,
                        ));
                        if coll.active() {
                            coll.on_decision(decisions.last().expect("just pushed"));
                        }
                        let marginal = candidates
                            .iter()
                            .find(|c| c.host == h)
                            .map(|c| c.marginal_j_per_byte());
                        warm_start_on_host(&mut spec, &worlds[h], learned.as_ref());
                        let fp = learned.map(|l| l.fingerprint);
                        coll.on_admit(&mut worlds[h], &spec.name, now);
                        worlds[h].register_arrival(spec, fp, marginal);
                    }
                    _ => {
                        decisions.push(make_record(
                            now,
                            &spec.name,
                            resumed_at,
                            None,
                            &candidates,
                            &cfg.hosts,
                        ));
                        if coll.active() {
                            coll.on_decision(decisions.last().expect("just pushed"));
                        }
                        deferred.push((spec, resumed_at, learned, None));
                    }
                }
            }
            // Reverse push_front preserves the batch order at the head.
            for entry in deferred.into_iter().rev() {
                queue.push_front(entry);
            }
        }

        // Queued sessions retry first (FIFO: stop at the first that still
        // does not fit), then arrivals due now. A newcomer never jumps an
        // occupied queue. In-flight migrations keep their target slots
        // reserved against both.
        let reserved = reserved_slots(&in_flight, worlds.len());
        while !queue.is_empty() {
            let mut candidates = {
                let head = queue.front().expect("non-empty");
                build_candidates(
                    &worlds,
                    &cfg.hosts,
                    head.2.as_ref(),
                    cfg.price_queue_delay,
                    &reserved,
                )
            };
            if res_active {
                apply_resilience(&mut candidates, &down, &penalty_box, now);
            }
            match dispatcher.place(&candidates) {
                PlaceDecision::Admit(h) => {
                    let (mut spec, requested, lq, migrated) =
                        queue.pop_front().expect("non-empty");
                    decisions.push(make_record(
                        now,
                        &spec.name,
                        requested,
                        Some(h),
                        &candidates,
                        &cfg.hosts,
                    ));
                    if coll.active() {
                        coll.on_decision(decisions.last().expect("just pushed"));
                        if migrated.is_some() {
                            coll.on_migration_resumed(now, requested);
                        }
                    }
                    // A resuming migrant that lands off its planned
                    // target corrects its migration record.
                    if let Some(rec) = migrated {
                        if migrations[rec].to_host != h {
                            migrations[rec].to_host = h;
                            migrations[rec].to = cfg.hosts[h].name.clone();
                        }
                    }
                    let marginal = candidates
                        .iter()
                        .find(|c| c.host == h)
                        .map(|c| c.marginal_j_per_byte());
                    warm_start_on_host(&mut spec, &worlds[h], lq.as_ref());
                    coll.on_admit(&mut worlds[h], &spec.name, now);
                    worlds[h].register_arrival(spec, lq.map(|l| l.fingerprint), marginal);
                }
                _ => break,
            }
        }
        while pending
            .front()
            .is_some_and(|s| s.arrive_at.as_secs() <= now + 1e-9)
        {
            let mut spec = pending.pop_front().expect("non-empty");
            let requested = spec.arrive_at.as_secs();
            let learned = LearnedQuery::for_spec(cfg.history.as_ref(), &spec);
            let mut candidates = build_candidates(
                &worlds,
                &cfg.hosts,
                learned.as_ref(),
                cfg.price_queue_delay,
                &reserved,
            );
            if res_active {
                apply_resilience(&mut candidates, &down, &penalty_box, now);
            }
            let decision = if queue.is_empty() {
                dispatcher.place(&candidates)
            } else {
                PlaceDecision::QueuePowerCap // FIFO: wait behind the queue head
            };
            match decision {
                PlaceDecision::Admit(h) => {
                    decisions.push(make_record(
                        now,
                        &spec.name,
                        requested,
                        Some(h),
                        &candidates,
                        &cfg.hosts,
                    ));
                    if coll.active() {
                        coll.on_decision(decisions.last().expect("just pushed"));
                    }
                    let marginal = candidates
                        .iter()
                        .find(|c| c.host == h)
                        .map(|c| c.marginal_j_per_byte());
                    warm_start_on_host(&mut spec, &worlds[h], learned.as_ref());
                    let fp = learned.map(|l| l.fingerprint);
                    coll.on_admit(&mut worlds[h], &spec.name, now);
                    worlds[h].register_arrival(spec, fp, marginal);
                }
                _ => {
                    decisions.push(make_record(
                        now,
                        &spec.name,
                        requested,
                        None,
                        &candidates,
                        &cfg.hosts,
                    ));
                    if coll.active() {
                        coll.on_decision(decisions.last().expect("just pushed"));
                    }
                    queue.push_back((spec, requested, learned, None));
                }
            }
        }

        let all_done = worlds.iter().all(|w| w.all_done());
        if (pending.is_empty()
            && queue.is_empty()
            && in_flight.is_empty()
            && retries.is_empty()
            && all_done)
            || now >= max
        {
            break;
        }
        // Stuck queue: nothing is running, pending or mid-drain, yet the
        // head still does not fit. Occupancy — and therefore every
        // projection the cap is checked against — can never change again,
        // so simulating idle hosts until the time cap would be pure
        // waste: end the run now and report the queue as unplaced. (A
        // drain in flight *will* change occupancy, so it keeps the loop
        // alive — and so does a scripted cap change still ahead: a
        // future `PowerCapEvent` can loosen the very cap blocking the
        // head, so the run must idle forward to it, not give up. The
        // `stepper_equivalence` cap-squeeze test pins this. Unfired
        // fault actions and waiting retries equally keep the loop
        // alive: a scripted revival can unmask the very host the head
        // is blocked on, and a retry's re-admission changes occupancy.)
        if pending.is_empty()
            && in_flight.is_empty()
            && retries.is_empty()
            && all_done
            && !queue.is_empty()
            && cap_events.is_empty()
            && fault_timeline.is_exhausted()
        {
            break;
        }

        for w in worlds.iter_mut() {
            w.admissions_due();
            w.sample_peaks();
        }

        // Cross-host event horizon: the earliest driver-level event on
        // any host, the next arrival, the next migration resume, the
        // next scripted cap change, or the time cap. Between now and
        // then every tick on every host is pure stepping.
        let mut horizon = max;
        if let Some(s) = pending.front() {
            horizon = horizon.min(s.arrive_at.as_secs());
        }
        for m in &in_flight {
            horizon = horizon.min(m.spec.arrive_at.as_secs());
        }
        if let Some(e) = cap_events.front() {
            horizon = horizon.min(e.at.as_secs());
        }
        for s in &retries {
            horizon = horizon.min(s.arrive_at.as_secs());
        }
        if let Some(at) = fault_timeline.next_at() {
            horizon = horizon.min(at.as_secs());
        }
        for w in worlds.iter() {
            horizon = horizon.min(w.internal_horizon(max));
        }

        // Lockstep inner loop: one tick on every host per iteration. A
        // completion on any host ends the segment (its departure — and
        // any queued admission it unblocks — must be handled on exactly
        // that tick). With more than one shard the same segment runs
        // chunked across worker threads (see [`step_segment_sharded`]);
        // `shards == 1` is the bit-for-bit reference path.
        if shards <= 1 {
            loop {
                let mut completed = false;
                for w in worlds.iter_mut() {
                    completed |= w.step_once().session_completed;
                }
                let t = worlds[0].now_secs();
                if completed || t + 1e-9 >= horizon || t >= max {
                    break;
                }
            }
        } else {
            step_segment_sharded(&mut worlds, shards, horizon, max);
        }

        for w in worlds.iter_mut() {
            w.post_segment();
        }

        // Health observations: differentiate each host's delivered-byte
        // counter over the segment against its own steady-state
        // projection. A host below the degrade ratio for a full dwell
        // earns one advisory per episode; down hosts are not judged —
        // the failure path already owns them.
        if res.enabled {
            let now = worlds[0].now_secs();
            let dt = now - last_health_at;
            if dt > 1e-9 {
                for (i, w) in worlds.iter().enumerate() {
                    let moved_now = w.moved_bytes();
                    let observed_bps = (moved_now - last_moved[i]) / dt;
                    last_moved[i] = moved_now;
                    if down[i] {
                        continue;
                    }
                    let occ = w.occupancy();
                    let expected_bps = w.projected_session_bps(occ) * occ as f64;
                    if let Some(a) = health.observe(i, now, observed_bps, expected_bps) {
                        if coll.active() {
                            coll.on_advisory(&a, &cfg.hosts[i].name);
                        }
                        advisories.push(a);
                    }
                }
                last_health_at = now;
            }
        }

        // Rebalance step: with departures handled and the clock fresh,
        // the rebalancer sees exactly the occupancy the next admission
        // decision would. At most one move per segment boundary — each
        // subsequent move is priced against re-taken projections.
        // Advisory-driven evacuation rides the same machinery and takes
        // precedence over the optimization policy: damage control
        // first, savings second.
        let evac_wanted = res.enabled
            && rebalancer.evacuates()
            && (0..worlds.len()).any(|i| health.is_degraded(i) && !down[i]);
        if rebalancer.active() || evac_wanted {
            let now = worlds[0].now_secs();
            // Sessions mid-drain are resident nowhere, but their planned
            // target slot — and their imminent draw there — are spoken
            // for: fold them into the target's occupancy so a second
            // move cannot double-book the slot and the cap trigger sees
            // the fleet's post-resume projection, not the drain dip.
            let reserved = reserved_slots(&in_flight, worlds.len());
            let views: Vec<HostView> = worlds
                .iter()
                .enumerate()
                .map(|(i, w)| {
                    let active = w.occupancy() + reserved[i];
                    // A dead host takes no moved session: masked like
                    // it is for admission (it holds no sessions either
                    // — the failure path preempted them all).
                    let free_slots = if res_active && down[i] {
                        0
                    } else {
                        cfg.hosts[i].max_sessions.saturating_sub(active)
                    };
                    HostView {
                        host: i,
                        active,
                        free_slots,
                        idle_power_w: w.projected_power_w(0),
                        power_now_w: w.projected_power_w(active),
                        power_minus_one_w: w.projected_power_w(active.saturating_sub(1)),
                        power_plus_one_w: w.projected_power_w(active + 1),
                        session_bps_now: w.projected_session_bps(active),
                        session_bps_plus_one: w.projected_session_bps(active + 1),
                        session_bps_alone: w.projected_session_bps(1),
                        rtt_s: w.link_rtt_s(),
                        sessions: w
                            .running_sessions()
                            .into_iter()
                            .map(|(tenant, name, remaining_bytes)| SessionView {
                                tenant,
                                name,
                                remaining_bytes,
                            })
                            .collect(),
                    }
                })
                .collect();
            let evac = if evac_wanted {
                let degraded: Vec<bool> =
                    (0..worlds.len()).map(|i| health.is_degraded(i) && !down[i]).collect();
                rebalancer.propose_evacuation(&views, &degraded)
            } else {
                None
            };
            let (proposal, policy_id) = match evac {
                Some(mv) => (Some(mv), "evacuate"),
                None if rebalancer.active() => {
                    let cap_w = effective_cap.map(|p| p.as_watts());
                    // With the collector on, the audited scan records a
                    // verdict per candidate (identical decision — the
                    // executor test pins plain == audited); off, the
                    // plain path runs verbatim.
                    let proposal = if coll.active() {
                        let mut verdicts: Vec<MoveVerdict> = Vec::new();
                        let p = rebalancer.propose_audited(&views, cap_w, &mut verdicts);
                        coll.on_rebalance_verdicts(now, &verdicts, &cfg.hosts);
                        p
                    } else {
                        rebalancer.propose(&views, cap_w)
                    };
                    (proposal, rebalancer.policy().id())
                }
                None => (None, rebalancer.policy().id()),
            };
            if let Some(mv) = proposal {
                let pre = worlds[mv.from].preempt(mv.tenant);
                if res_active {
                    // The migrated residency's bytes join the session's
                    // delivered ledger: a later dead letter must account
                    // for them too.
                    *delivered.entry(pre.name.clone()).or_insert(0.0) += pre.moved.as_f64();
                }
                rebalancer.note_move(&pre.name);
                let drain = rebalancer.drain().as_secs();
                let spec = TenantSpec::new(pre.name.clone(), pre.dataset, pre.algorithm)
                    .arriving_at(SimTime::from_secs(now + drain));
                migrations.push(MigrationRecord {
                    t_secs: now,
                    session: pre.name,
                    from_host: mv.from,
                    from: cfg.hosts[mv.from].name.clone(),
                    to_host: mv.to,
                    to: cfg.hosts[mv.to].name.clone(),
                    moved_bytes: pre.moved.as_f64(),
                    remaining_bytes: pre.remaining.as_f64(),
                    drain_secs: drain,
                    resume_at_secs: now + drain,
                    est_benefit_j: mv.est_benefit_j,
                    est_cost_j: mv.est_cost_j,
                    policy: policy_id,
                });
                if coll.active() {
                    coll.on_migration(migrations.last().expect("just pushed"));
                }
                in_flight.push(InFlight {
                    spec,
                    target: mv.to,
                    record: migrations.len() - 1,
                });
            }
        }

        // Segment boundary complete: drain host trace buffers (in host
        // index order) and snapshot the fleet for the metrics timeline.
        if coll.active() {
            coll.on_segment(&mut worlds, queue.len());
        }
    }

    let completed = pending.is_empty()
        && queue.is_empty()
        && in_flight.is_empty()
        && retries.is_empty()
        && dead_letters.is_empty()
        && worlds.iter().all(|w| w.all_done());
    let duration = worlds[0].sim.now.since(SimTime::ZERO);
    // Close still-open residencies (time-capped sessions), drain the
    // last host buffers and finalize the merged log before `finish`
    // consumes the worlds.
    let end_secs = worlds[0].now_secs();
    if coll.active() {
        let censored: Vec<f64> = queue.iter().map(|(_, req, _, _)| *req).collect();
        coll.on_run_end(end_secs, &censored);
    }
    let observed = coll.finish(&mut worlds, end_secs);
    let unplaced: Vec<String> = queue
        .iter()
        .map(|(s, _, _, _)| s.name.clone())
        .chain(pending.iter().map(|s| s.name.clone()))
        .chain(in_flight.iter().map(|m| m.spec.name.clone()))
        .chain(retries.iter().map(|s| s.name.clone()))
        .collect();
    let (dead_letters, dead_letter_overflow) = dead_letters.into_parts();
    let policy = format!("{}+{}", cfg.placement.id(), worlds[0].policy_name());

    let mut tenants = Vec::new();
    let mut hosts = Vec::new();
    let mut run_records = Vec::new();
    let mut moved = Bytes::ZERO;
    let mut client_energy = Energy::ZERO;
    let mut client_package_energy = Energy::ZERO;
    let mut server_energy = Energy::ZERO;
    for w in worlds {
        let (t, b, r) = w.finish();
        tenants.extend(t);
        run_records.extend(r);
        moved += b.moved;
        client_energy = client_energy + b.client_energy;
        client_package_energy = client_package_energy + b.client_package_energy;
        server_energy = server_energy + b.server_energy;
        hosts.push(b);
    }
    tenants.sort_by(|a, b| {
        a.arrived_at
            .as_secs()
            .total_cmp(&b.arrived_at.as_secs())
            .then_with(|| a.name.cmp(&b.name))
    });

    DispatchOutcome {
        fleet: FleetOutcome {
            policy,
            tenants,
            completed,
            duration,
            moved,
            client_energy,
            client_package_energy,
            server_energy,
            final_active_cores: hosts[0].final_active_cores,
            final_freq: hosts[0].final_freq,
            hosts,
            run_records,
            dead_letters,
            dead_letter_overflow,
        },
        decisions,
        migrations,
        unplaced,
        faults: faults_log,
        retries: retry_log,
        advisories,
        trace: observed.trace,
        metrics: observed.metrics,
        calibration: observed.calibration,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;

    fn cand(
        host: usize,
        active: u32,
        free: u32,
        cur_w: f64,
        proj_w: f64,
        bps: f64,
        fleet_w: f64,
    ) -> HostCandidate {
        HostCandidate {
            host,
            active_sessions: active,
            free_slots: free,
            current_power_w: cur_w,
            projected_power_w: proj_w,
            projected_session_bps: bps,
            projected_fleet_power_w: fleet_w,
            queue_delay_j_per_byte: 0.0,
            learned_j_per_byte: None,
            learned_weight: 0.0,
        }
    }

    #[test]
    fn poisson_times_are_deterministic_and_hit_the_rate() {
        let a = PoissonArrivals::new(0.5, 4000, 7).times();
        let b = PoissonArrivals::new(0.5, 4000, 7).times();
        assert_eq!(a.len(), 4000);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.as_secs().to_bits(), y.as_secs().to_bits());
        }
        for w in a.windows(2) {
            assert!(w[1] > w[0], "arrival times must strictly increase");
        }
        // Empirical rate: mean inter-arrival ≈ 1/λ = 2 s within 5%. The
        // sample list is non-empty by the length assertion above, so the
        // guard only documents that `times()` may legally return nothing.
        let mean = a.last().map(|t| t.as_secs()).unwrap_or(0.0) / 4000.0;
        assert!((mean - 2.0).abs() < 0.1, "mean inter-arrival {mean}");
        // A different seed perturbs the process.
        let c = PoissonArrivals::new(0.5, 4000, 8).times();
        assert_ne!(a[0].as_secs(), c[0].as_secs());
    }

    #[test]
    fn degenerate_poisson_processes_yield_empty_schedules() {
        // Rate ≈ 0 or a zero session budget must not panic — the process
        // is simply empty (regression: `new` used to assert on the rate
        // and downstream code unwrapped the last sample).
        assert!(PoissonArrivals::new(0.0, 100, 7).times().is_empty());
        assert!(PoissonArrivals::new(-1.0, 100, 7).times().is_empty());
        assert!(PoissonArrivals::new(2.0, 0, 7).times().is_empty());
        let specs = PoissonArrivals::new(0.0, 4, 7)
            .sessions("medium", AlgorithmKind::MaxThroughput)
            .expect("known family");
        assert!(specs.is_empty(), "empty schedule, not a panic");
    }

    #[test]
    fn poisson_sessions_carry_arrival_times_and_distinct_datasets() {
        let specs = PoissonArrivals::new(0.1, 5, 3)
            .sessions("medium", AlgorithmKind::MaxThroughput)
            .expect("known family");
        assert_eq!(specs.len(), 5);
        for w in specs.windows(2) {
            assert!(w[1].arrive_at > w[0].arrive_at);
        }
        // Per-session seeds differ, so file layouts differ.
        assert_ne!(
            specs[0].dataset.files[0].size.as_f64(),
            specs[1].dataset.files[0].size.as_f64()
        );
        assert!(PoissonArrivals::new(0.1, 5, 3)
            .sessions("no-such-family", AlgorithmKind::MaxThroughput)
            .is_none());
    }

    #[test]
    fn round_robin_cycles_and_skips_full_hosts() {
        let mut d = Dispatcher::new(PlacementKind::RoundRobin, None);
        let free = |h| cand(h, 0, 2, 10.0, 12.0, 1e8, 40.0);
        let cands = vec![free(0), free(1), free(2)];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(0));
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
        assert_eq!(d.place(&cands), PlaceDecision::Admit(2));
        assert_eq!(d.place(&cands), PlaceDecision::Admit(0));
        // A full host is skipped without disturbing the rotation.
        let cands = vec![free(0), cand(1, 2, 0, 10.0, 12.0, 1e8, 40.0), free(2)];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(2));
    }

    #[test]
    fn least_loaded_prefers_the_emptier_host() {
        let mut d = Dispatcher::new(PlacementKind::LeastLoaded, None);
        let cands = vec![
            cand(0, 3, 1, 30.0, 32.0, 1e8, 60.0),
            cand(1, 1, 3, 30.0, 32.0, 1e8, 60.0),
            cand(2, 2, 2, 30.0, 32.0, 1e8, 60.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
    }

    #[test]
    fn marginal_energy_prefers_fewer_joules_per_byte() {
        let mut d = Dispatcher::new(PlacementKind::MarginalEnergy, None);
        // Host 0: +25 W for 50 MB/s = 0.5 µJ/B; host 1: +15 W for
        // 100 MB/s = 0.15 µJ/B.
        let cands = vec![
            cand(0, 1, 3, 30.0, 55.0, 50e6, 75.0),
            cand(1, 0, 4, 20.0, 35.0, 100e6, 65.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
        // A host that cannot move bytes scores infinitely bad.
        let cands = vec![
            cand(0, 1, 3, 30.0, 31.0, 0.0, 61.0),
            cand(1, 0, 4, 20.0, 50.0, 100e6, 80.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
    }

    #[test]
    fn learned_placement_blends_observed_costs() {
        let mut d = Dispatcher::new(PlacementKind::Learned, None);
        // Model says host 0 wins (0.15 vs 0.5 µJ/B)…
        let mut c0 = cand(0, 0, 4, 20.0, 35.0, 100e6, 65.0);
        let mut c1 = cand(1, 0, 4, 30.0, 55.0, 50e6, 75.0);
        // …but history has seen this workload cost 2 µJ/B there.
        c0.learned_j_per_byte = Some(2e-6);
        c0.learned_weight = 0.9;
        c1.learned_j_per_byte = Some(4e-7);
        c1.learned_weight = 0.9;
        assert_eq!(d.place(&[c0, c1]), PlaceDecision::Admit(1));
        // Without observations the blend reduces exactly to the model
        // score, i.e. `Learned` on an empty store == `MarginalEnergy`.
        let cands = vec![
            cand(0, 0, 4, 20.0, 35.0, 100e6, 65.0),
            cand(1, 0, 4, 30.0, 55.0, 50e6, 75.0),
        ];
        assert_eq!(cands[0].learned_score(), cands[0].marginal_j_per_byte());
        assert_eq!(d.place(&cands), PlaceDecision::Admit(0));
    }

    #[test]
    fn queue_delay_price_breaks_saturated_host_blindness() {
        // The goodput-blind case: host 0 is saturated (adding a session
        // costs ~0 marginal watts because the link caps aggregate
        // demand), so pure marginal energy scores it as nearly free even
        // though the new session would crawl. The queue-delay price makes
        // the idle empty host win instead.
        let mut d = Dispatcher::new(PlacementKind::MarginalEnergy, None);
        let mut saturated = cand(0, 4, 4, 60.0, 60.5, 25e6, 80.0); // 0.02 µJ/B marginal
        let fresh = cand(1, 0, 8, 20.0, 38.0, 100e6, 98.5); // 0.18 µJ/B marginal
        assert_eq!(
            d.place(&[saturated, fresh]),
            PlaceDecision::Admit(0),
            "without the price, the saturated host looks cheapest"
        );
        // Price the contention: 20 W idle × (1/25 MB/s − 1/125 MB/s).
        saturated.queue_delay_j_per_byte = 20.0 * (1.0 / 25e6 - 1.0 / 125e6);
        assert!(saturated.score() > fresh.score());
        assert_eq!(
            d.place(&[saturated, fresh]),
            PlaceDecision::Admit(1),
            "the priced saturated host loses to the idle one"
        );
        // An unpriced candidate's score reduces to the pure marginal.
        assert_eq!(fresh.score(), fresh.marginal_j_per_byte());
    }

    #[test]
    fn set_power_cap_retargets_admission_mid_run() {
        let mut d =
            Dispatcher::new(PlacementKind::MarginalEnergy, Some(Power::from_watts(100.0)));
        let cands = vec![cand(0, 0, 4, 20.0, 35.0, 100e6, 75.0)];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(0));
        // Tighten below the projection: the same candidate now queues.
        d.set_power_cap(Some(Power::from_watts(70.0)));
        assert_eq!(d.place(&cands), PlaceDecision::QueuePowerCap);
        // Removing the cap admits freely again.
        d.set_power_cap(None);
        assert_eq!(d.place(&cands), PlaceDecision::Admit(0));
    }

    #[test]
    fn power_cap_queues_or_reroutes() {
        let mut d =
            Dispatcher::new(PlacementKind::MarginalEnergy, Some(Power::from_watts(70.0)));
        // Best-scored host breaks the cap; the other fits → reroute.
        let cands = vec![
            cand(0, 0, 4, 20.0, 35.0, 100e6, 75.0), // 0.15 µJ/B but 75 W > cap
            cand(1, 0, 4, 30.0, 55.0, 50e6, 65.0),  // 0.5 µJ/B, fits
        ];
        assert_eq!(d.place(&cands), PlaceDecision::Admit(1));
        // Nobody fits → queue on the power cap.
        let cands = vec![
            cand(0, 0, 4, 20.0, 35.0, 100e6, 75.0),
            cand(1, 0, 4, 30.0, 55.0, 50e6, 72.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::QueuePowerCap);
        // No free slots anywhere → queue on capacity instead.
        let cands = vec![
            cand(0, 4, 0, 20.0, 35.0, 100e6, 60.0),
            cand(1, 4, 0, 30.0, 55.0, 50e6, 60.0),
        ];
        assert_eq!(d.place(&cands), PlaceDecision::QueueNoSlot);
        assert_eq!(d.place(&[]), PlaceDecision::QueueNoSlot);
    }

    #[test]
    fn warm_start_resolves_against_the_admitting_host() {
        use crate::config::experiment::TunerParams;
        use crate::history::{KnnIndex, RunOutcome, RunRecord, WorkloadFingerprint};

        let tb = testbeds::didclab();
        let world = HostWorld::build(
            "h",
            &tb,
            &[],
            Some(FleetPolicyKind::MinEnergyFleet),
            TunerParams::default(),
            SimDuration::from_secs(3.0),
            SimDuration::from_millis(100.0),
            1,
            Vec::new(),
            false,
            false,
            false,
            false,
            None,
            false,
        );
        let ds = crate::dataset::standard::medium_dataset(11);
        let record = RunRecord {
            session: "past".to_string(),
            algorithm: "history".to_string(),
            host: "h".to_string(),
            testbed: tb.name.to_string(),
            rtt_s: tb.link.rtt.as_secs(),
            bandwidth_bps: tb.link.capacity.as_bits_per_sec(),
            workload: WorkloadFingerprint::of(&ds),
            contention: 0,
            cores: 2,
            pstate: 1,
            channels: 9,
            peak_channels: 12,
            goodput_bps: 1e8,
            joules: 8000.0,
            j_per_byte: 8000.0 / 11.7e9,
            moved_bytes: 11.7e9,
            duration_s: 110.0,
            completed: true,
            outcome: RunOutcome::Completed,
            admission_marginal_jpb: None,
            traj: Vec::new(),
        };
        let index = KnnIndex::build(&[record]);

        // A cold `history` session is warmed against this host's path…
        let mut spec = TenantSpec::new("s", ds, AlgorithmKind::HistoryTuned(None));
        let lq = LearnedQuery::for_spec(Some(&index), &spec);
        warm_start_on_host(&mut spec, &world, lq.as_ref());
        assert!(
            matches!(
                spec.algorithm,
                AlgorithmKind::HistoryTuned(Some(w)) if w.channels == 9 && w.cores == 2
            ),
            "expected the recorded op point, got {:?}",
            spec.algorithm
        );
        // …while non-history sessions pass through untouched.
        let mut other = TenantSpec::new(
            "o",
            crate::dataset::standard::medium_dataset(12),
            AlgorithmKind::MaxThroughput,
        );
        let lq = LearnedQuery::for_spec(Some(&index), &other);
        warm_start_on_host(&mut other, &world, lq.as_ref());
        assert_eq!(other.algorithm, AlgorithmKind::MaxThroughput);
        // And without an index nothing changes.
        let mut cold = TenantSpec::new(
            "c",
            crate::dataset::standard::medium_dataset(13),
            AlgorithmKind::HistoryTuned(None),
        );
        warm_start_on_host(&mut cold, &world, None);
        assert_eq!(cold.algorithm, AlgorithmKind::HistoryTuned(None));
    }

    #[test]
    fn effective_shards_resolves_auto_and_clamps_to_hosts() {
        // Explicit counts clamp to the host count; zero hosts still
        // yields one (the driver asserts non-empty fleets anyway).
        assert_eq!(effective_shards(1, 8), 1);
        assert_eq!(effective_shards(4, 8), 4);
        assert_eq!(effective_shards(16, 8), 8);
        assert_eq!(effective_shards(3, 0), 1);
        // Auto resolves to at least one worker, never more than hosts.
        let auto = effective_shards(0, 4);
        assert!((1..=4).contains(&auto), "auto resolved to {auto}");
    }

    #[test]
    fn horizon_bound_replays_the_break_condition_exactly() {
        // 0.1 is not exact in binary: the bound must replay the same
        // accumulated sum the stepper produces, not divide analytically.
        let dt = 0.1;
        let bound = horizon_bound_ticks(0.0, dt, u64::MAX, 10.0, f64::MAX);
        let mut t = 0.0;
        for _ in 0..bound {
            t += dt;
        }
        assert!(t + 1e-9 < 10.0, "bound overshoots the horizon: t = {t}");
        assert!(t + dt + 1e-9 >= 10.0, "bound stops early: t = {t}");
        // The cap and the time limit both clip the bound.
        assert_eq!(horizon_bound_ticks(0.0, dt, 7, 10.0, f64::MAX), 7);
        assert_eq!(horizon_bound_ticks(0.0, dt, u64::MAX, 10.0, 0.35), 3);
        // Already at (or past) the horizon: nothing is safe.
        assert_eq!(horizon_bound_ticks(10.0, dt, u64::MAX, 10.0, f64::MAX), 0);
    }

    #[test]
    fn two_hosts_two_sessions_least_loaded_spreads() {
        let hosts = vec![
            HostSpec::new("a", testbeds::cloudlab()),
            HostSpec::new("b", testbeds::cloudlab()),
        ];
        let sessions = vec![
            TenantSpec::new(
                "s0",
                crate::dataset::standard::medium_dataset(1),
                AlgorithmKind::MaxThroughput,
            ),
            TenantSpec::new(
                "s1",
                crate::dataset::standard::medium_dataset(2),
                AlgorithmKind::MaxThroughput,
            ),
        ];
        let cfg = DispatcherConfig::new(hosts, PlacementKind::LeastLoaded)
            .with_sessions(sessions)
            .with_seed(5);
        let out = run_dispatcher(&cfg);
        assert!(out.fleet.completed, "both sessions must finish");
        assert!(out.unplaced.is_empty());
        assert_eq!(out.fleet.tenants.len(), 2);
        assert_eq!(out.fleet.hosts.len(), 2);
        // Least-loaded spreads simultaneous arrivals across hosts.
        assert_ne!(out.fleet.tenants[0].host, out.fleet.tenants[1].host);
        assert_eq!(out.decisions.len(), 2);
        assert!(out.decisions.iter().all(|d| !d.queued()));
        // Both hosts billed some energy (idle or serving).
        for h in &out.fleet.hosts {
            assert!(h.client_energy.as_joules() > 0.0, "{} unbilled", h.host);
        }
        // Observability is strictly opt-in.
        assert!(out.trace.is_none());
        assert!(out.metrics.is_none());
    }

    #[test]
    fn collector_produces_reconciled_trace_and_metrics() {
        let hosts = vec![
            HostSpec::new("a", testbeds::cloudlab()),
            HostSpec::new("b", testbeds::cloudlab()),
        ];
        let sessions = vec![
            TenantSpec::new(
                "s0",
                crate::dataset::standard::medium_dataset(1),
                AlgorithmKind::MaxThroughput,
            ),
            TenantSpec::new(
                "s1",
                crate::dataset::standard::medium_dataset(2),
                AlgorithmKind::MaxThroughput,
            ),
        ];
        let cfg = DispatcherConfig::new(hosts, PlacementKind::LeastLoaded)
            .with_sessions(sessions)
            .with_seed(5)
            .with_trace()
            .with_metrics();
        let out = run_dispatcher(&cfg);
        assert!(out.fleet.completed);
        let trace = out.trace.as_ref().expect("trace enabled");
        for s in ["s0", "s1"] {
            assert!(
                trace
                    .iter()
                    .any(|r| r.name == "session" && r.session.as_deref() == Some(s)),
                "{s} has a root span"
            );
            assert!(
                trace.iter().any(|r| r.name == "admit"
                    && r.session.as_deref() == Some(s)
                    && r.is_span()),
                "{s} has a residency span"
            );
            assert!(
                trace
                    .iter()
                    .any(|r| r.name == "complete" && r.session.as_deref() == Some(s)),
                "{s} has a completion event"
            );
        }
        // One placement event per decision, each with per-host scores.
        assert_eq!(
            trace.iter().filter(|r| r.name == "placement").count(),
            out.decisions.len()
        );
        assert_eq!(
            trace.iter().filter(|r| r.name == "placement_score").count(),
            out.decisions.iter().map(|d| d.scores.len()).sum::<usize>()
        );
        // The residency span's byte/joule attrs reconcile *exactly* with
        // the tenant outcome — same reads, same instant.
        for t in &out.fleet.tenants {
            let span = trace
                .iter()
                .find(|r| r.name == "admit" && r.session.as_deref() == Some(t.name.as_str()))
                .expect("residency span");
            assert_eq!(
                span.attr_f64("moved_bytes").unwrap().to_bits(),
                t.moved.as_f64().to_bits(),
                "{} moved bytes reconcile",
                t.name
            );
            assert_eq!(
                span.attr_f64("attributed_j").unwrap().to_bits(),
                t.attributed_energy.as_joules().to_bits(),
                "{} attributed joules reconcile",
                t.name
            );
        }
        // The log is sorted by (t0, id) and ids are unique.
        for w in trace.windows(2) {
            assert!(
                (w[0].t0_secs, w[0].id) <= (w[1].t0_secs, w[1].id),
                "log sorted by (t0, id)"
            );
        }
        let mut ids: Vec<u64> = trace.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "record ids unique");

        let m = out.metrics.as_ref().expect("metrics enabled");
        assert_eq!(m.registry.counter("placements.admitted"), 2);
        assert!(m.registry.histogram("queue.wait_s").is_some());
        assert!(m.registry.histogram("goodput.segment_bps").is_some());
        assert!(!m.timeline.snapshots.is_empty());
        assert_eq!(m.registry.gauge("fleet.hosts"), Some(2.0));
        assert!(m.warm_hit_rate().is_some(), "ticks were counted");
    }

    #[test]
    fn calibration_ledger_reconciles_with_fleet_outcome() {
        let hosts = vec![
            HostSpec::new("a", testbeds::cloudlab()),
            HostSpec::new("b", testbeds::cloudlab()),
        ];
        let sessions = vec![
            TenantSpec::new(
                "s0",
                crate::dataset::standard::medium_dataset(1),
                AlgorithmKind::MaxThroughput,
            ),
            TenantSpec::new(
                "s1",
                crate::dataset::standard::medium_dataset(2),
                AlgorithmKind::MaxThroughput,
            ),
        ];
        let cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
            .with_sessions(sessions)
            .with_seed(5)
            .with_metrics();
        let out = run_dispatcher(&cfg);
        assert!(out.fleet.completed);
        let cal = out.calibration.as_ref().expect("metrics turns the ledger on");
        // One close per residency, each bit-matching the tenant outcome
        // the same (session, host) pair reconciles to.
        assert_eq!(cal.placements.len(), out.fleet.tenants.len());
        for rec in &cal.placements {
            let t = out
                .fleet
                .tenants
                .iter()
                .find(|t| t.name == rec.session && t.host == rec.host)
                .expect("tenant outcome for calibration record");
            assert_eq!(
                rec.realized_bytes.to_bits(),
                t.moved.as_f64().to_bits(),
                "{} bytes reconcile",
                rec.session
            );
            assert_eq!(
                rec.realized_joules.to_bits(),
                t.attributed_energy.as_joules().to_bits(),
                "{} joules reconcile",
                rec.session
            );
            assert_eq!(rec.end, "complete");
            // Marginal-energy placement carries a J/B prediction, so
            // every record is a joined prediction-vs-realized pair.
            assert!(rec.predicted_jpb.is_some(), "{} has a prediction", rec.session);
            assert!(rec.realized_jpb().is_some());
        }
        let summed: f64 = cal.realized_joules();
        let fleet: f64 = out
            .fleet
            .tenants
            .iter()
            .map(|t| t.attributed_energy.as_joules())
            .sum();
        assert_eq!(summed.to_bits(), fleet.to_bits(), "summed joules bit-match");
        let m = out.metrics.as_ref().expect("metrics enabled");
        assert_eq!(
            m.registry.counter("calibration.records"),
            cal.placements.len() as u64
        );
        assert!(m.registry.histogram("placement.jpb_error").is_some());
        // The ledger round-trips through its JSON report.
        let doc = crate::history::json::parse(&cal.to_json()).expect("ledger json");
        assert_eq!(
            doc.get("placements").and_then(|p| p.as_arr()).map(|a| a.len()),
            Some(cal.placements.len())
        );
    }

    #[test]
    fn trace_off_metrics_off_leaves_calibration_none() {
        let hosts = vec![HostSpec::new("solo", testbeds::cloudlab())];
        let sessions = vec![TenantSpec::new(
            "s0",
            crate::dataset::standard::medium_dataset(1),
            AlgorithmKind::MaxThroughput,
        )];
        let cfg = DispatcherConfig::new(hosts, PlacementKind::LeastLoaded)
            .with_sessions(sessions)
            .with_seed(1);
        let out = run_dispatcher(&cfg);
        assert!(out.fleet.completed);
        assert!(out.calibration.is_none(), "ledger off without observability");
        assert!(out.trace.is_none());
        assert!(out.metrics.is_none());
    }
}
