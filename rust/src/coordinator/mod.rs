//! The paper's contribution: SLA-driven runtime parameter tuning.
//!
//! * [`sla`] — the three SLA policies (§I: "user can set performance or
//!   energy constraints based on SLAs").
//! * [`heuristic`] — Algorithm 1: heuristic parameter initialization.
//! * [`fsm`] — Figure 1: the shared finite state machine.
//! * [`slow_start`] — Algorithm 2: initial channel-count correction.
//! * [`load_control`] — Algorithm 3: threshold-based dynamic frequency and
//!   core scaling, plus the predictive (PJRT model-driven) governor
//!   extension.
//! * [`min_energy`] / [`max_throughput`] / [`target_throughput`] —
//!   Algorithms 4, 5, 6.
//! * [`no_tune`] — the static fixed-channel baseline (sweeps, fleet
//!   tenants).
//! * [`history_tuned`] — ME warm-started from the historical-log
//!   subsystem ([`crate::history`]): skips the slow-start probe when the
//!   k-NN index has seen a similar workload, falls back to the paper's
//!   cold path otherwise.
//! * [`algorithm`] — the common [`algorithm::Algorithm`] trait and the
//!   factory used by sessions, experiments and the CLI.
//! * [`fleet`] — cross-session arbitration of the shared host's
//!   cores/frequency/channel budget (multi-tenant extension), plus the
//!   [`PlacementKind`] policies the multi-host dispatcher ranks
//!   candidate hosts by (multi-host extension).

pub mod algorithm;
pub mod fleet;
pub mod fsm;
pub mod heuristic;
pub mod history_tuned;
pub mod load_control;
pub mod max_throughput;
pub mod min_energy;
pub mod no_tune;
pub mod sla;
pub mod slow_start;
pub mod target_throughput;

pub use algorithm::{Algorithm, AlgorithmKind, InitPlan};
pub use fleet::{FleetDirective, FleetPolicy, FleetPolicyKind, PlacementKind};
pub use fsm::{Feedback, FsmState};
pub use sla::SlaPolicy;
