//! Algorithm 5 — the Energy-Efficient Maximum Throughput (EEMT) algorithm.
//!
//! Maximizes throughput *while keeping the channel count as low as
//! possible*: channels are added only when doing so actually raised the
//! measured throughput past the reference by β; a reference throughput
//! (`refTput`, the best observed in state Increase) anchors the feedback.

use super::algorithm::{make_governor, Algorithm, InitPlan};
use super::fsm::{self, Action, FsmState};
use super::heuristic;
use super::load_control::Governor;
use super::sla::SlaPolicy;
use super::slow_start::SlowStart;
use crate::config::experiment::TunerParams;
use crate::config::Testbed;
use crate::dataset::Dataset;
use crate::sim::{Telemetry, TuneCtx};
use crate::transfer::TransferEngine;
use crate::units::SimDuration;

#[derive(Debug)]
/// Algorithm 5 — Energy-Efficient Maximum Throughput (EEMT).
pub struct MaxThroughput {
    params: TunerParams,
    governor: Box<dyn Governor>,
    state: FsmState,
    slow_start: Option<SlowStart>,
    /// Reference throughput in bits/s (`refTput`).
    ref_tput: f64,
    num_ch: u32,
}

impl MaxThroughput {
    /// Fresh EEMT instance with the given tuner knobs.
    pub fn new(params: TunerParams) -> Self {
        MaxThroughput {
            governor: make_governor(
                params.governor,
                &params,
                crate::predictor::PredictMode::MaxThroughput,
            ),
            params,
            state: FsmState::SlowStart,
            slow_start: None,
            ref_tput: 0.0,
            num_ch: 1,
        }
    }

    /// Current FSM state.
    pub fn fsm_state(&self) -> FsmState {
        self.state
    }

    /// Channel count the algorithm currently wants.
    pub fn num_channels(&self) -> u32 {
        self.num_ch
    }

    /// Reference throughput (`refTput`), bits/s.
    pub fn ref_tput_bps(&self) -> f64 {
        self.ref_tput
    }

    fn apply_channels(&mut self, engine: &mut TransferEngine) {
        engine.update_weights();
        engine.set_num_channels(self.num_ch);
    }
}

impl Algorithm for MaxThroughput {
    fn name(&self) -> &'static str {
        "EEMT"
    }

    fn timeout(&self) -> SimDuration {
        self.params.timeout
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        let init = heuristic::initialize(testbed, dataset, SlaPolicy::Throughput);
        self.num_ch = init.num_channels;
        self.slow_start = Some(SlowStart::new(
            testbed.link.capacity,
            self.params.max_ch,
            self.params.slow_start_rounds,
        ));
        self.state = FsmState::SlowStart;
        // Without the load-control module the OS owns the CPU: all cores
        // online, ondemand frequency (Figure 4's "w/o scaling" ablation).
        let client_cpu = if self.params.governor == crate::config::experiment::GovernorKind::Os {
            crate::cpusim::CpuState::performance(testbed.client_cpu.clone())
        } else {
            init.client_cpu
        };
        InitPlan::new(init.partitions, init.num_channels, client_cpu)
    }

    fn fsm_label(&self) -> &'static str {
        self.state.label()
    }

    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx) {
        // Algorithm 3 at every timeout.
        self.governor.control(telemetry, ctx.client);

        if let Some(ss) = &mut self.slow_start {
            let done = ss.on_timeout(telemetry, ctx.engine);
            self.num_ch = ctx.engine.num_channels().max(1);
            if done {
                self.slow_start = None;
                self.state = FsmState::Increase;
                // "updates the reference throughput to the average
                // throughput measured in the Slow Start phase" (§IV-B).
                self.ref_tput = telemetry.avg_throughput.as_bits_per_sec();
            }
            return;
        }

        let avg = telemetry.avg_throughput.as_bits_per_sec();
        let feedback = fsm::classify(avg, self.ref_tput, self.params.alpha, self.params.beta);
        let (next, action) = fsm::step(self.state, feedback);

        match (self.state, action) {
            (FsmState::Increase, Action::Grow) => {
                // Lines 5–7: grow and move the reference up.
                self.num_ch = (self.num_ch + self.params.delta_ch).min(self.params.max_ch);
                self.ref_tput = avg;
            }
            (_, Action::Shrink) => {
                // Lines 14–16.
                self.num_ch = self.num_ch.saturating_sub(self.params.delta_ch).max(1);
            }
            (_, Action::Restore) => {
                // Lines 21–24: the drop was a bandwidth change — restore the
                // channel count and accept the new reality as reference.
                self.num_ch = (self.num_ch + self.params.delta_ch).min(self.params.max_ch);
                self.ref_tput = avg;
            }
            _ => {}
        }
        self.state = next;
        self.apply_channels(ctx.engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::coordinator::AlgorithmKind;
    use crate::dataset::standard;
    use crate::sim::session::{run_session, SessionConfig};

    #[test]
    fn init_uses_throughput_sla() {
        let mut a = MaxThroughput::new(TunerParams::default());
        let tb = testbeds::chameleon();
        let plan = a.init(&tb, &standard::large_dataset(1));
        assert_eq!(plan.client_cpu.active_cores(), tb.client_cpu.num_cores);
        assert!(plan.client_cpu.at_min_freq());
    }

    #[test]
    fn session_reaches_high_utilization_on_cloudlab() {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::large_dataset(2),
            AlgorithmKind::MaxThroughput,
        );
        let out = run_session(&cfg);
        assert!(out.completed);
        assert!(
            out.avg_throughput.as_mbps() > 600.0,
            "EEMT should fill most of 1 Gbps, got {}",
            out.avg_throughput
        );
    }

    #[test]
    fn session_beats_single_channel_on_chameleon() {
        let cfg_eemt = SessionConfig::new(
            testbeds::chameleon(),
            standard::medium_dataset(2),
            AlgorithmKind::MaxThroughput,
        );
        let out_eemt = run_session(&cfg_eemt);
        let cfg_curl = SessionConfig::new(
            testbeds::chameleon(),
            standard::medium_dataset(2),
            AlgorithmKind::Curl,
        );
        let out_curl = run_session(&cfg_curl);
        assert!(out_eemt.completed && out_curl.completed);
        assert!(
            out_eemt.avg_throughput.as_gbps() > 2.0 * out_curl.avg_throughput.as_gbps(),
            "EEMT {} vs curl {}",
            out_eemt.avg_throughput,
            out_curl.avg_throughput
        );
    }

    #[test]
    fn reference_updates_on_growth() {
        let mut a = MaxThroughput::new(TunerParams {
            slow_start_rounds: 1,
            governor: crate::config::experiment::GovernorKind::Os,
            ..Default::default()
        });
        a.state = FsmState::Increase;
        a.ref_tput = 1e9;
        a.num_ch = 4;
        // Positive: avg well above reference.
        let f = fsm::classify(1.3e9, a.ref_tput, a.params.alpha, a.params.beta);
        let (s, act) = fsm::step(a.state, f);
        assert_eq!(s, FsmState::Increase);
        assert_eq!(act, Action::Grow);
    }
}
