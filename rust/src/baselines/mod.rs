//! Comparison systems from §V.
//!
//! * [`simple`] — the standard command-line tools: wget, curl, http/2.0.
//! * [`ismail`] — the state-of-the-art comparators of Figures 2–3
//!   (Ismail et al.): static heuristic tuning, no CPU scaling, no channel
//!   redistribution; the target variant ramps additively from 1 channel.
//! * [`alan`] — the Figure 4 comparators (Alan et al. [2,3]): heuristic
//!   power-aware parameter *search* done once before the transfer, static
//!   afterwards.
//!
//! All baselines run under the OS `performance` governor (all cores at max
//! frequency): the paper's testbeds scale frequency only in the proposed
//! algorithms.

pub mod alan;
pub mod ismail;
pub mod simple;
