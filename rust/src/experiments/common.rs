//! Shared experiment plumbing: one "cell" = one session of one algorithm
//! on one testbed × dataset.

use crate::config::experiment::TunerParams;
use crate::config::testbeds;
use crate::coordinator::AlgorithmKind;
use crate::dataset::standard;
use crate::sim::session::{run_session, SessionConfig, SessionOutcome};
use crate::units::SimDuration;

/// One experiment cell.
#[derive(Debug, Clone)]
pub struct Cell {
    /// Testbed name.
    pub testbed: String,
    /// Dataset family name.
    pub dataset: String,
    /// Algorithm to run.
    pub kind: AlgorithmKind,
    /// Tuner knobs.
    pub params: TunerParams,
    /// RNG seed.
    pub seed: u64,
    /// Session time cap (slow sweep points need more than the default).
    pub max_sim_time: SimDuration,
}

impl Cell {
    /// A cell with default knobs.
    pub fn new(
        testbed: impl Into<String>,
        dataset: impl Into<String>,
        kind: AlgorithmKind,
    ) -> Cell {
        Cell {
            testbed: testbed.into(),
            dataset: dataset.into(),
            kind,
            params: TunerParams::default(),
            seed: 42,
            max_sim_time: SimDuration::from_secs(14_400.0),
        }
    }

    /// Replace the tuner parameters.
    pub fn with_params(mut self, params: TunerParams) -> Cell {
        self.params = params;
        self
    }

    /// Set the RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Cell {
        self.seed = seed;
        self
    }

    /// Raise the session time cap.
    pub fn with_max_sim_time(mut self, cap: SimDuration) -> Cell {
        self.max_sim_time = cap;
        self
    }
}

/// Run one cell to completion.
pub fn run_cell(cell: &Cell) -> SessionOutcome {
    let testbed = testbeds::by_name(&cell.testbed).expect("unknown testbed");
    let dataset = standard::by_name(&cell.dataset, cell.seed).expect("unknown dataset");
    let mut cfg = SessionConfig::new(testbed, dataset, cell.kind)
        .with_params(cell.params)
        .with_seed(cell.seed);
    cfg.max_sim_time = cell.max_sim_time;
    run_session(&cfg)
}

/// Run cells across worker threads (cells are independent sessions).
/// Results come back in input order.
pub fn run_cells(cells: &[Cell]) -> Vec<SessionOutcome> {
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut results: Vec<Option<SessionOutcome>> = (0..cells.len()).map(|_| None).collect();
    let slots: Vec<std::sync::Mutex<&mut Option<SessionOutcome>>> =
        results.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers.min(cells.len().max(1)) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= cells.len() {
                    break;
                }
                let out = run_cell(&cells[i]);
                **slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    results.into_iter().map(|r| r.expect("cell completed")).collect()
}

/// Format helpers shared by the figure harnesses.
pub fn fmt_tput(out: &SessionOutcome) -> String {
    if out.avg_throughput.as_gbps() >= 1.0 {
        format!("{:.2} Gbps", out.avg_throughput.as_gbps())
    } else {
        format!("{:.0} Mbps", out.avg_throughput.as_mbps())
    }
}

/// Format joules as a kJ string for tables.
pub fn fmt_energy_kj(joules: f64) -> String {
    format!("{:.2} kJ", joules / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_cells_preserves_order_and_completes() {
        let cells = vec![
            Cell::new("cloudlab", "large", AlgorithmKind::MaxThroughput),
            Cell::new("didclab", "large", AlgorithmKind::MinEnergy),
        ];
        let outs = run_cells(&cells);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].testbed, "CloudLab");
        assert_eq!(outs[1].testbed, "DIDCLab");
        assert!(outs.iter().all(|o| o.completed));
    }
}
