//! Failure & resilience: scripted faults, retries, quarantine, and
//! health-driven evacuation.
//!
//! Every fleet scenario before this subsystem assumed no transfer ever
//! fails — yet GreenDataFlow (arXiv:1810.05892) motivates the work
//! with wide-area transfers whose end systems and paths degrade
//! mid-run, and the historical-log follow-up (arXiv:2104.01192) shows
//! tuning must survive (and learn from) runs that did not finish
//! cleanly. This module is the missing failure model, in four pure
//! pieces the dispatcher wires together at segment boundaries:
//!
//! * **faults** ([`faults`]) — scripted [`HostFailureEvent`]s and
//!   [`LinkDegradeEvent`]s, expanded into a deterministic
//!   [`FaultTimeline`] fired alongside scripted power-cap events;
//! * **penalty** ([`penalty`]) — the [`PenaltyBox`]: exponential
//!   session backoff between retries, plus a decaying per-strike J/B
//!   surcharge that deprioritizes flaky hosts in placement scoring;
//! * **deadletter** ([`deadletter`]) — the bounded [`DeadLetterQueue`]
//!   quarantining sessions that exhaust their retry budget, reported
//!   as first-class [`FleetOutcome`](crate::sim::FleetOutcome) fields;
//! * **health** ([`health`]) — the [`HealthMonitor`]: per-host
//!   stall/degradation dwell detection emitting [`Advisory`] records
//!   that trigger rebalancer evacuation *before* a host dies.
//!
//! Founding principle, borrowed from the `core-resilience` pattern
//! set: everything here is plain types and arithmetic with zero
//! knowledge of the simulation, the network model, or session
//! internals. The dispatcher owns all side effects (preemption,
//! re-materialized datasets, slow-start re-ramp); this module only
//! decides *when* and *what*. Invariants — byte conservation across a
//! crash, `--resilience off` bit-identity, shard invariance of the
//! whole fault pipeline — are pinned by
//! `rust/tests/resilience_faults.rs`.

pub mod deadletter;
pub mod faults;
pub mod health;
pub mod penalty;

pub use deadletter::{DeadLetter, DeadLetterQueue, FailureReason};
pub use faults::{
    FaultAction, FaultKind, FaultSchedule, FaultTimeline, HostFailureEvent, LinkDegradeEvent,
};
pub use health::{Advisory, HealthConfig, HealthMonitor};
pub use penalty::{PenaltyBox, PenaltyConfig};

/// Everything the dispatcher needs to run the resilience pipeline.
///
/// Two independent switches live here. The *fault schedule* injects
/// failures whenever it is non-empty — faults are part of the world,
/// not of the response to them. The *recovery machinery* (`enabled`)
/// is what `--resilience on|off` toggles: with it off, a session lost
/// to a fault is dead-lettered immediately (the terminal-loss
/// baseline the resilience benchmark compares against); with it on,
/// lost sessions retry under the [`PenaltyBox`] and degrading hosts
/// are evacuated on [`HealthMonitor`] advisories.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResilienceConfig {
    /// Turn the recovery machinery on (retries, penalty scoring,
    /// health-driven evacuation). Off by default: the dispatcher is
    /// then bit-for-bit the pre-resilience dispatcher unless a fault
    /// schedule is present, and terminal under faults when one is.
    pub enabled: bool,
    /// The run's scripted faults (empty = nothing ever fails).
    pub faults: FaultSchedule,
    /// Retries a session may consume before it is dead-lettered
    /// (ignored — effectively 0 — while recovery is off).
    pub retry_budget: u32,
    /// PenaltyBox knobs (backoff and strike decay).
    pub penalty: PenaltyConfig,
    /// Health-monitor knobs (degradation ratio and dwell).
    pub health: HealthConfig,
    /// Dead-letter queue bound.
    pub dead_letter_capacity: usize,
}

impl ResilienceConfig {
    /// The disabled default with the standard knob values filled in:
    /// recovery off, no faults, 3 retries, 64 quarantine slots.
    pub fn new() -> Self {
        ResilienceConfig {
            enabled: false,
            faults: FaultSchedule::default(),
            retry_budget: 3,
            penalty: PenaltyConfig::default(),
            health: HealthConfig::default(),
            dead_letter_capacity: 64,
        }
    }

    /// Enable the recovery machinery.
    pub fn with_recovery(mut self) -> Self {
        self.enabled = true;
        self
    }

    /// Install a fault schedule.
    pub fn with_faults(mut self, faults: FaultSchedule) -> Self {
        self.faults = faults;
        self
    }

    /// Set the retry budget.
    pub fn with_retry_budget(mut self, budget: u32) -> Self {
        self.retry_budget = budget;
        self
    }

    /// True when the dispatcher must run any part of the pipeline —
    /// recovery requested, or a fault schedule present. False is the
    /// bit-identity contract: the dispatcher then takes no resilience
    /// branch at all.
    pub fn active(&self) -> bool {
        self.enabled || !self.faults.is_empty()
    }

    /// The retry budget in force: the configured budget with recovery
    /// on, zero (immediate quarantine) with it off.
    pub fn effective_retry_budget(&self) -> u32 {
        if self.enabled {
            self.retry_budget
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::SimTime;

    #[test]
    fn default_config_is_inactive() {
        let cfg = ResilienceConfig::default();
        assert!(!cfg.active());
        assert_eq!(cfg.effective_retry_budget(), 0);
        let cfg = ResilienceConfig::new();
        assert!(!cfg.active());
        assert_eq!(cfg.retry_budget, 3);
    }

    #[test]
    fn faults_alone_activate_the_pipeline_but_not_recovery() {
        let cfg = ResilienceConfig::new().with_faults(
            FaultSchedule::default().with_host_failure(0, SimTime::from_secs(10.0), None),
        );
        assert!(cfg.active());
        assert_eq!(cfg.effective_retry_budget(), 0, "recovery off = terminal losses");
        let cfg = cfg.with_recovery().with_retry_budget(5);
        assert_eq!(cfg.effective_retry_budget(), 5);
    }
}
