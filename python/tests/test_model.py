"""Layer-2 model and AOT pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import layout as L


def test_example_args_match_layout():
    cand, state = model.example_args()
    assert cand.shape == (L.NUM_CANDIDATES, L.CAND_WIDTH)
    assert state.shape == (L.STATE_WIDTH,)
    assert cand.dtype == jnp.float32


def test_demo_grid_is_padded_and_valid():
    g = np.asarray(model.demo_grid())
    assert g.shape == (L.NUM_CANDIDATES, L.CAND_WIDTH)
    # Real rows first, zero padding after.
    real = g[:, L.CAND_CORES] > 0
    if real.any():
        last_real = np.nonzero(real)[0].max()
        assert not real[last_real + 1 :].any() if last_real + 1 < len(g) else True


def test_lowering_produces_hlo_text():
    text = aot.lower_predictor()
    assert text.startswith("HloModule")
    assert "f32[128,3]" in text
    # The lowered module is self-contained: no TPU custom-calls (interpret
    # mode flattens the Pallas kernel into plain HLO ops).
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_lowered_module_runs_and_matches_reference():
    """Execute the lowered HLO via jax's own CPU client — the same artifact
    the Rust runtime loads — and compare with the oracle."""
    lowered = jax.jit(model.predict).lower(*model.example_args())
    compiled = lowered.compile()
    cand, state = model.demo_grid(), model.demo_state()
    got = np.asarray(compiled(cand, state))
    want = np.asarray(model.predict_reference(cand, state))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)


def test_tile_divides_candidates():
    assert L.NUM_CANDIDATES % L.TILE == 0


def test_layout_indices_are_unique():
    idx = [
        L.S_CAPACITY_BPS, L.S_RTT_S, L.S_AVG_WIN_BYTES, L.S_KNEE_STREAMS,
        L.S_OVERLOAD_GAMMA, L.S_OVERLOAD_FLOOR, L.S_PARALLELISM,
        L.S_REMAINING_BYTES, L.S_AVG_FILE_BYTES, L.S_PP_LEVEL,
        L.S_CYCLES_PER_BYTE, L.S_CYCLES_PER_REQ, L.S_CYCLES_PER_STREAM,
        L.S_MAX_APP_UTIL, L.S_PKG_STATIC_W, L.S_CORE_IDLE_BASE_W,
        L.S_CORE_IDLE_PER_GHZ_W, L.S_DYN_KAPPA, L.S_V_MIN, L.S_V_MAX,
        L.S_F_MIN_GHZ, L.S_F_MAX_GHZ, L.S_DRAM_W_PER_GBS, L.S_RESERVED,
    ]
    assert len(set(idx)) == L.STATE_WIDTH
    assert max(idx) == L.STATE_WIDTH - 1
