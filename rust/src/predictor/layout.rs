//! Interchange layout with the AOT predictor artifact.
//!
//! **Mirror of `python/compile/kernels/layout.py`** — keep in sync. The
//! `predictor_parity` integration test executes the compiled artifact
//! against [`super::reference`] and fails on drift.

pub const NUM_CANDIDATES: usize = 128;
pub const TILE: usize = 32;

pub const CAND_WIDTH: usize = 3;
pub const CAND_CHANNELS: usize = 0;
pub const CAND_CORES: usize = 1;
pub const CAND_FREQ_GHZ: usize = 2;

pub const STATE_WIDTH: usize = 24;
pub const S_CAPACITY_BPS: usize = 0;
pub const S_RTT_S: usize = 1;
pub const S_AVG_WIN_BYTES: usize = 2;
pub const S_KNEE_STREAMS: usize = 3;
pub const S_OVERLOAD_GAMMA: usize = 4;
pub const S_OVERLOAD_FLOOR: usize = 5;
pub const S_PARALLELISM: usize = 6;
pub const S_REMAINING_BYTES: usize = 7;
pub const S_AVG_FILE_BYTES: usize = 8;
pub const S_PP_LEVEL: usize = 9;
pub const S_CYCLES_PER_BYTE: usize = 10;
pub const S_CYCLES_PER_REQ: usize = 11;
pub const S_CYCLES_PER_STREAM: usize = 12;
pub const S_MAX_APP_UTIL: usize = 13;
pub const S_PKG_STATIC_W: usize = 14;
pub const S_CORE_IDLE_BASE_W: usize = 15;
pub const S_CORE_IDLE_PER_GHZ_W: usize = 16;
pub const S_DYN_KAPPA: usize = 17;
pub const S_V_MIN: usize = 18;
pub const S_V_MAX: usize = 19;
pub const S_F_MIN_GHZ: usize = 20;
pub const S_F_MAX_GHZ: usize = 21;
pub const S_DRAM_W_PER_GBS: usize = 22;
pub const S_RESERVED: usize = 23;

pub const OUT_WIDTH: usize = 3;
pub const OUT_TPUT_BPS: usize = 0;
pub const OUT_POWER_W: usize = 1;
pub const OUT_ENERGY_J: usize = 2;

/// Energy assigned to infeasible candidates (mirrors the Python constant).
pub const INFEASIBLE_ENERGY: f32 = 1e30;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_tiles_evenly() {
        assert_eq!(NUM_CANDIDATES % TILE, 0);
    }

    #[test]
    fn state_indices_dense() {
        // The last index must be the final slot.
        assert_eq!(S_RESERVED, STATE_WIDTH - 1);
    }
}
