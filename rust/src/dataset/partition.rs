//! File partitioning and BDP chunking — the data-layout half of Algorithm 1.
//!
//! `partitionFiles()` clusters the dataset into partitions of similar file
//! size (relative to the network BDP), so that each partition can get its
//! own pipelining / parallelism setting:
//!
//! * files larger than the BDP are split into BDP-sized chunks, to be
//!   transferred on parallel streams ("parallelism", §II);
//! * each partition's pipelining level is `⌈BDP / avgFileSize⌉` (Alg. 1
//!   line 6) so that back-to-back requests keep a channel's BDP full even
//!   when individual files are small.

use super::{Dataset, FileSpec};
use crate::units::Bytes;

/// Upper bound on the per-partition pipelining level. Matches the cap used
/// by real transfer tools (GridFTP pipelining depth); prevents the
/// small-file partition from requesting thousands of outstanding requests.
pub const MAX_PIPELINING: u32 = 32;

/// Upper bound on per-file parallelism (streams per file).
pub const MAX_PARALLELISM: u32 = 16;

/// Size-band boundaries relative to BDP. A file of size `s` falls in band
/// `i` where `s < BDP * BAND_EDGES[i]` first holds (last band is open).
const BAND_EDGES: [f64; 3] = [0.1, 1.0, f64::INFINITY];
const BAND_NAMES: [&str; 3] = ["small", "medium", "large"];

/// Aggregate statistics of one partition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionStats {
    /// Files in the partition.
    pub num_files: usize,
    /// Sum of the partition's file sizes.
    pub total_size: Bytes,
    /// Mean file size in the partition.
    pub avg_file_size: Bytes,
}

/// A cluster of similar-sized files plus its tuned per-partition
/// parameters.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Band label (`small`/`medium`/`large` relative to BDP).
    pub name: &'static str,
    /// The files assigned to this partition (original, pre-chunking).
    pub files: Vec<FileSpec>,
    /// Pipelining level: requests in flight back-to-back per connection.
    pub pp_level: u32,
    /// Parallelism: chunks of a single file moved concurrently
    /// (1 unless files exceed the BDP and are chunked).
    pub parallelism: u32,
    /// Chunk size used when splitting (equals BDP for the large band).
    pub chunk_size: Bytes,
}

impl Partition {
    /// Aggregate statistics over the partition's current file list.
    pub fn stats(&self) -> PartitionStats {
        let total: Bytes = self.files.iter().map(|f| f.size).sum();
        let n = self.files.len();
        PartitionStats {
            num_files: n,
            total_size: total,
            avg_file_size: if n == 0 { Bytes::ZERO } else { total / n as f64 },
        }
    }

    /// Sum of the partition's file sizes.
    pub fn total_size(&self) -> Bytes {
        self.files.iter().map(|f| f.size).sum()
    }
}

/// Algorithm 1, lines 1–7 with the default parallelism cap.
pub fn partition_files(dataset: &Dataset, bdp: Bytes) -> Vec<Partition> {
    partition_files_capped(dataset, bdp, MAX_PARALLELISM)
}

/// Algorithm 1, lines 1–7: cluster files into size bands relative to the
/// BDP, split over-BDP files into BDP chunks (expressed as a per-partition
/// `parallelism` level), and derive the pipelining level.
///
/// `max_parallelism` caps the streams opened per channel — callers that
/// know the path (the heuristic initializer) pass the number of streams
/// that fills the pipe (`⌈BDP / avgWin⌉`); more than that per channel
/// only adds overhead.
///
/// Empty bands are dropped; the result is ordered small → large.
pub fn partition_files_capped(
    dataset: &Dataset,
    bdp: Bytes,
    max_parallelism: u32,
) -> Vec<Partition> {
    let bdp_f = bdp.as_f64().max(1.0);
    let mut bands: Vec<Vec<FileSpec>> = vec![Vec::new(); BAND_EDGES.len()];
    for f in &dataset.files {
        let ratio = f.size.as_f64() / bdp_f;
        let band = BAND_EDGES.iter().position(|&e| ratio < e).unwrap_or(BAND_EDGES.len() - 1);
        bands[band].push(*f);
    }

    let mut partitions = Vec::new();
    for (i, files) in bands.into_iter().enumerate() {
        if files.is_empty() {
            continue;
        }
        let total: Bytes = files.iter().map(|f| f.size).sum();
        let avg = total / files.len() as f64;

        // Alg. 1 line 3-5: if avg file size exceeds BDP, split into BDP
        // chunks; the number of concurrent chunks is the parallelism level.
        let parallelism = if avg.as_f64() > bdp_f {
            ((avg.as_f64() / bdp_f).ceil() as u32)
                .clamp(1, max_parallelism.clamp(1, MAX_PARALLELISM))
        } else {
            1
        };

        // Alg. 1 line 6: ppLevel = ceil(BDP / avgFileSize).
        let pp_level = ((bdp_f / avg.as_f64().max(1.0)).ceil() as u32).clamp(1, MAX_PIPELINING);

        partitions.push(Partition {
            name: BAND_NAMES[i],
            files,
            pp_level,
            parallelism,
            chunk_size: bdp.min(avg),
        });
    }
    partitions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::standard;
    use crate::units::{bdp, Rate, SimDuration};

    fn chameleon_bdp() -> Bytes {
        bdp(Rate::from_gbps(10.0), SimDuration::from_millis(32.0))
    }

    #[test]
    fn partitions_cover_all_files() {
        let d = standard::mixed_dataset(1);
        let parts = partition_files(&d, chameleon_bdp());
        let covered: usize = parts.iter().map(|p| p.files.len()).sum();
        assert_eq!(covered, d.num_files(), "every file lands in exactly one partition");
        let total: f64 = parts.iter().map(|p| p.total_size().as_f64()).sum();
        assert!((total - d.total_size().as_f64()).abs() < 1.0);
    }

    #[test]
    fn small_files_get_pipelining() {
        // 102 KB files vs a 40 MB BDP -> deep pipelining, capped.
        let d = standard::small_dataset(1);
        let parts = partition_files(&d, chameleon_bdp());
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].pp_level, MAX_PIPELINING);
        assert_eq!(parts[0].parallelism, 1);
    }

    #[test]
    fn large_files_get_parallelism_on_small_bdp() {
        // 222 MB files vs a 5.5 MB BDP (DIDCLab) -> chunked, parallelism > 1.
        let d = standard::large_dataset(1);
        let didclab_bdp = bdp(Rate::from_gbps(1.0), SimDuration::from_millis(44.0));
        let parts = partition_files(&d, didclab_bdp);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].name, "large");
        assert!(parts[0].parallelism > 1, "parallelism {}", parts[0].parallelism);
        assert_eq!(parts[0].pp_level, 1);
    }

    #[test]
    fn mixed_dataset_spans_bands() {
        let d = standard::mixed_dataset(1);
        let didclab_bdp = bdp(Rate::from_gbps(1.0), SimDuration::from_millis(44.0));
        let parts = partition_files(&d, didclab_bdp);
        assert!(parts.len() >= 2, "mixed should split into multiple bands, got {}", parts.len());
    }

    #[test]
    fn empty_dataset_yields_no_partitions() {
        let d = Dataset::new("e", vec![]);
        assert!(partition_files(&d, chameleon_bdp()).is_empty());
    }

    #[test]
    fn pp_level_bounds() {
        let d = standard::mixed_dataset(2);
        for tb_bdp in [chameleon_bdp(), Bytes::from_mb(4.5), Bytes::from_mb(5.5)] {
            for p in partition_files(&d, tb_bdp) {
                assert!(p.pp_level >= 1 && p.pp_level <= MAX_PIPELINING);
                assert!(p.parallelism >= 1 && p.parallelism <= MAX_PARALLELISM);
            }
        }
    }
}
