//! Ismail et al. — the state-of-the-art comparators of Figures 2 and 3.
//!
//! Re-implemented from the paper's description of their behaviour (§V-A,
//! §V-B), since the original system is not available:
//!
//! * **static parameter tuning**: pipelining/parallelism/concurrency are
//!   chosen once from historical heuristics and never adapted — "static
//!   parameter tuning, which at times leads to suboptimal parameters";
//! * **the parallelism flaw**: "as the buffer size grows to match the
//!   network BDP, the parallelism level drops to 1, causing poor
//!   performance" — their heuristic sets `p = ⌈BDP / bufferSize⌉` and a
//!   tuned system has `bufferSize ≈ BDP`, so `p = 1` always;
//! * **no channel redistribution**: "the algorithm does not distribute
//!   the channels across datasets based on the remaining size or current
//!   speed, resulting in slower datasets becoming bottlenecks";
//! * **no CPU scaling**: runs under the performance governor;
//! * the **target** variant "starts with one channel and slowly
//!   increments its channel count, taking a very long time to achieve
//!   the target".

use crate::config::Testbed;
use crate::coordinator::algorithm::{Algorithm, InitPlan};
use crate::coordinator::load_control::{Governor, OndemandGovernor};
use crate::cpusim::CpuState;
use crate::dataset::{partition_files, Dataset};
use crate::sim::{Telemetry, TuneCtx};
use crate::units::{Rate, SimDuration};

/// Static channel budget used by their max-throughput heuristic (chosen
/// from "historical data" — a fixed table, not the live path).
const ISMAIL_MT_CHANNELS: u32 = 6;
/// Their min-energy heuristic: fewest channels that keep the NIC busy.
const ISMAIL_ME_CHANNELS: u32 = 5;
/// Ramp cap for the target variant.
const ISMAIL_TT_MAX_CHANNELS: u32 = 32;

/// Ismail et al. ME / MT (static).
#[derive(Debug)]
pub struct Ismail {
    name: &'static str,
    channels: u32,
    governor: OndemandGovernor,
}

impl Ismail {
    /// Ismail et al. static minimum-energy tuning.
    pub fn min_energy() -> Self {
        Ismail { name: "Ismail-ME", channels: ISMAIL_ME_CHANNELS, governor: OndemandGovernor::default() }
    }

    /// Ismail et al. static maximum-throughput tuning.
    pub fn max_throughput() -> Self {
        Ismail { name: "Ismail-MT", channels: ISMAIL_MT_CHANNELS, governor: OndemandGovernor::default() }
    }
}

impl Algorithm for Ismail {
    fn name(&self) -> &'static str {
        self.name
    }

    fn timeout(&self) -> SimDuration {
        SimDuration::from_secs(5.0)
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        // They partition like everyone in this line of work (same lab
        // lineage), but apply the flawed parallelism rule and never adapt.
        let mut partitions = partition_files(dataset, testbed.bdp());
        for p in &mut partitions {
            // buffer == BDP  =>  parallelism = ceil(BDP / buffer) = 1.
            p.parallelism = 1;
        }
        InitPlan::new(
            partitions,
            self.channels,
            CpuState::performance(testbed.client_cpu.clone()),
        )
    }

    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx) {
        // Static: no runtime adaptation; only the OS governor acts.
        self.governor.control(telemetry, ctx.client);
    }
}

/// Ismail et al. Target Throughput: additive ramp from one channel.
#[derive(Debug)]
pub struct IsmailTarget {
    target: Rate,
    num_ch: u32,
    governor: OndemandGovernor,
}

impl IsmailTarget {
    /// Ismail et al. target-throughput ramp toward `target`.
    pub fn new(target: Rate) -> Self {
        IsmailTarget { target, num_ch: 1, governor: OndemandGovernor::default() }
    }

    /// The target rate.
    pub fn target(&self) -> Rate {
        self.target
    }
}

impl Algorithm for IsmailTarget {
    fn name(&self) -> &'static str {
        "Ismail-TT"
    }

    fn timeout(&self) -> SimDuration {
        SimDuration::from_secs(5.0)
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        let mut partitions = partition_files(dataset, testbed.bdp());
        for p in &mut partitions {
            p.parallelism = 1;
        }
        self.num_ch = 1; // "starts with one channel"
        InitPlan::new(partitions, 1, CpuState::performance(testbed.client_cpu.clone()))
    }

    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx) {
        // Additive ±1 step toward the target; no weight redistribution
        // (channels keep their initial partition assignment proportions —
        // we redistribute by the *static initial* weights, i.e. never call
        // update_weights()).
        self.governor.control(telemetry, ctx.client);
        let avg = telemetry.avg_throughput.as_bits_per_sec();
        let t = self.target.as_bits_per_sec();
        if avg < 0.95 * t {
            self.num_ch = (self.num_ch + 1).min(ISMAIL_TT_MAX_CHANNELS);
        } else if avg > 1.05 * t && self.num_ch > 1 {
            self.num_ch -= 1;
        }
        ctx.engine.set_num_channels(self.num_ch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::coordinator::AlgorithmKind;
    use crate::dataset::standard;
    use crate::sim::session::{run_session, SessionConfig};

    #[test]
    fn parallelism_is_always_one() {
        let mut mt = Ismail::max_throughput();
        // DIDCLab has a small BDP, so our heuristic would chunk large
        // files; Ismail must not.
        let plan = mt.init(&testbeds::didclab(), &standard::large_dataset(1));
        for p in &plan.partitions {
            assert_eq!(p.parallelism, 1);
        }
    }

    #[test]
    fn static_channel_budgets() {
        let mut me = Ismail::min_energy();
        let mut mt = Ismail::max_throughput();
        let tb = testbeds::cloudlab();
        let ds = standard::medium_dataset(1);
        assert_eq!(me.init(&tb, &ds).num_channels, 5);
        assert_eq!(mt.init(&tb, &ds).num_channels, 6);
    }

    #[test]
    fn no_scaling_performance_governor() {
        let mut mt = Ismail::max_throughput();
        let plan = mt.init(&testbeds::chameleon(), &standard::medium_dataset(1));
        assert!(plan.client_cpu.at_max_cores() && plan.client_cpu.at_max_freq());
    }

    #[test]
    fn target_ramps_slowly_from_one() {
        // 8 Gbps target on Chameleon: starting from one ~750 Mbps channel
        // and adding one per 5 s timeout takes a long time — the paper's
        // complaint about this algorithm.
        let target = Rate::from_gbps(8.0);
        let cfg = SessionConfig::new(
            testbeds::chameleon(),
            standard::mixed_dataset(2),
            AlgorithmKind::IsmailTarget(target),
        )
        .recording();
        let out = run_session(&cfg);
        assert!(out.completed);
        let early = &out.timeline[0];
        assert!(
            early.throughput.as_gbps() < 0.5 * 8.0,
            "early ramp should be far below target: {}",
            early.throughput
        );
    }

    #[test]
    fn our_eemt_beats_ismail_mt_on_chameleon_mixed() {
        let ds = standard::mixed_dataset(3);
        let ours = run_session(&SessionConfig::new(
            testbeds::chameleon(),
            ds.clone(),
            AlgorithmKind::MaxThroughput,
        ));
        let theirs = run_session(&SessionConfig::new(
            testbeds::chameleon(),
            ds,
            AlgorithmKind::IsmailMaxThroughput,
        ));
        assert!(ours.completed && theirs.completed);
        assert!(
            ours.avg_throughput.as_gbps() > 1.3 * theirs.avg_throughput.as_gbps(),
            "EEMT {} vs Ismail-MT {}",
            ours.avg_throughput,
            theirs.avg_throughput
        );
    }
}
