//! Acceptance tests for the observability layer (ISSUE 9).
//!
//! Tracing is only admissible if it is *free* in the determinism
//! currency the rest of the repo trades in:
//!
//! * **off-path identity** — a traced run's simulated outcome is
//!   bit-identical to an untraced run of the same `(config, seed)`;
//! * **replay identity** — two traced runs of one `(config, seed)`
//!   produce byte-identical JSONL (and Chrome) exports;
//! * **shard invariance** — the trace bytes are identical across
//!   `--shards` 1/2/8; metrics agree too, except the warm/slow stepper
//!   occupancy carve-out (an implementation detail of the driver, see
//!   `obs::metrics`'s module docs);
//! * **reconciliation** — the acceptance scenario (admit → migrate →
//!   host failure → retry → complete) yields one connected span tree
//!   per session whose byte/joule attributes equal the corresponding
//!   `FleetOutcome` entries to the bit.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::dataset::standard;
use greendt::obs::{chrome_trace_json, trace_jsonl, FleetMetrics, TraceLog};
use greendt::rebalance::{RebalanceConfig, RebalancePolicyKind};
use greendt::resilience::{FaultSchedule, ResilienceConfig};
use greendt::sim::dispatcher::{
    run_dispatcher, DispatchOutcome, DispatcherConfig, HostSpec, SessionSpec,
};
use greendt::units::SimTime;

fn assert_f64_bits(a: f64, b: f64, what: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a} vs {b}");
}

/// The outcome fields tracing must never perturb, compared exactly.
fn assert_outcomes_identical(a: &DispatchOutcome, b: &DispatchOutcome, label: &str) {
    assert_eq!(a.fleet.completed, b.fleet.completed, "{label}: completed");
    assert_f64_bits(
        a.fleet.duration.as_secs(),
        b.fleet.duration.as_secs(),
        &format!("{label}: duration"),
    );
    assert_f64_bits(
        a.fleet.moved.as_f64(),
        b.fleet.moved.as_f64(),
        &format!("{label}: moved"),
    );
    assert_f64_bits(
        a.fleet.client_energy.as_joules(),
        b.fleet.client_energy.as_joules(),
        &format!("{label}: client energy"),
    );
    assert_eq!(a.fleet.tenants.len(), b.fleet.tenants.len(), "{label}: tenant count");
    for (x, y) in a.fleet.tenants.iter().zip(&b.fleet.tenants) {
        let t = format!("{label}/{}", x.name);
        assert_eq!(x.name, y.name, "{t}: order");
        assert_f64_bits(x.moved.as_f64(), y.moved.as_f64(), &format!("{t}: moved"));
        assert_f64_bits(
            x.attributed_energy.as_joules(),
            y.attributed_energy.as_joules(),
            &format!("{t}: attributed energy"),
        );
    }
    assert_eq!(a.decisions.len(), b.decisions.len(), "{label}: decisions");
    assert_eq!(a.migrations.len(), b.migrations.len(), "{label}: migrations");
    assert_eq!(a.retries.len(), b.retries.len(), "{label}: retries");
    assert_eq!(a.unplaced, b.unplaced, "{label}: unplaced");
}

/// A five-host heterogeneous fleet with staggered arrivals — the same
/// shape `stepper_equivalence` pins, busy enough that admissions,
/// completions and tuning land across many segment boundaries.
fn busy_cfg(shards: usize) -> DispatcherConfig {
    let testbeds = testbeds::all();
    let hosts: Vec<HostSpec> = (0..5)
        .map(|i| {
            let tb = testbeds[i % testbeds.len()].clone();
            HostSpec::new(format!("host{i}-{}", tb.name), tb).with_max_sessions(2)
        })
        .collect();
    let sessions: Vec<SessionSpec> = (0..8u64)
        .map(|i| {
            SessionSpec::new(
                format!("session-{i}"),
                standard::medium_dataset(100 + i),
                if i % 2 == 0 { AlgorithmKind::MaxThroughput } else { AlgorithmKind::MinEnergy },
            )
            .arriving_at(SimTime::from_secs(10.0 * i as f64))
        })
        .collect();
    DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(7)
        .with_shards(shards)
}

#[test]
fn tracing_off_path_is_bit_identical() {
    // The observability hooks are pure reads: switching them on may not
    // move a single bit of the simulated outcome, and switching them
    // off must leave no residue in the output struct.
    let plain = run_dispatcher(&busy_cfg(1));
    assert!(plain.trace.is_none() && plain.metrics.is_none());
    let observed = run_dispatcher(&busy_cfg(1).with_trace().with_metrics());
    assert!(observed.trace.is_some() && observed.metrics.is_some());
    assert!(plain.fleet.completed, "the base workload must finish");
    assert_outcomes_identical(&plain, &observed, "trace on vs off");
}

/// Everything shard-invariant in a metrics snapshot — all fields except
/// the warm/slow tick split (the documented stepper-occupancy
/// carve-out).
fn assert_metrics_shard_invariant(a: &FleetMetrics, b: &FleetMetrics, label: &str) {
    assert_eq!(
        a.registry.histograms_json(),
        b.registry.histograms_json(),
        "{label}: histogram series must be shard-invariant"
    );
    for name in [
        "placements.admitted",
        "placements.queued",
        "cap.events",
        "faults.fired",
        "retries.scheduled",
        "sessions.dead_lettered",
        "migrations.executed",
        "rebalance.rejected",
        "health.advisories",
        "aimd.backoffs",
    ] {
        assert_eq!(
            a.registry.counter(name),
            b.registry.counter(name),
            "{label}: counter {name}"
        );
    }
    let (sa, sb) = (&a.timeline.snapshots, &b.timeline.snapshots);
    assert_eq!(sa.len(), sb.len(), "{label}: snapshot count");
    for (x, y) in sa.iter().zip(sb) {
        let t = format!("{label}/segment t={}", x.t_secs);
        assert_f64_bits(x.t_secs, y.t_secs, &format!("{t}: boundary time"));
        assert_eq!(x.active_sessions, y.active_sessions, "{t}: active");
        assert_eq!(x.queued, y.queued, "{t}: queued");
        assert_f64_bits(x.goodput_bps, y.goodput_bps, &format!("{t}: goodput"));
        assert_f64_bits(x.watts, y.watts, &format!("{t}: watts"));
        // warm_ticks / slow_ticks deliberately NOT compared.
    }
}

#[test]
fn trace_bytes_identical_across_repeats_and_shard_counts() {
    let run = |shards: usize| run_dispatcher(&busy_cfg(shards).with_trace().with_metrics());
    let reference = run(1);
    let ref_jsonl = trace_jsonl(reference.trace.as_ref().unwrap());
    let ref_chrome = chrome_trace_json(reference.trace.as_ref().unwrap());
    assert!(!ref_jsonl.is_empty(), "the busy fleet must trace something");

    // Replay identity: the same (config, seed) twice.
    let again = run(1);
    assert_eq!(ref_jsonl, trace_jsonl(again.trace.as_ref().unwrap()), "repeat run drifted");

    // Shard invariance: the merged log is a pure function of the
    // simulated run, not of the worker-thread partition.
    for shards in [2usize, 8] {
        let sharded = run(shards);
        let label = format!("{shards}-shard");
        assert_eq!(
            ref_jsonl,
            trace_jsonl(sharded.trace.as_ref().unwrap()),
            "{label}: trace bytes diverged from the serial loop"
        );
        assert_eq!(
            ref_chrome,
            chrome_trace_json(sharded.trace.as_ref().unwrap()),
            "{label}: chrome export diverged"
        );
        assert_metrics_shard_invariant(
            reference.metrics.as_ref().unwrap(),
            sharded.metrics.as_ref().unwrap(),
            &label,
        );
    }

    // The JSONL round-trips: parsing the bytes back loses nothing.
    let log = TraceLog::parse(&ref_jsonl);
    assert_eq!(log.skipped, 0, "every line must parse");
    assert_eq!(log.records.len(), reference.trace.as_ref().unwrap().len());
}

/// The hot-spot scenario from `rebalance_migration`: an efficient
/// single-slot host and a roomy legacy host, so the second session
/// lands on legacy and the marginal-delta rebalancer moves it over once
/// the efficient slot frees up.
fn hotspot_cfg(faults: Option<FaultSchedule>) -> DispatcherConfig {
    let hosts = vec![
        HostSpec::new("efficient", testbeds::cloudlab()).with_max_sessions(1),
        HostSpec::new("legacy", testbeds::didclab()).with_max_sessions(4),
    ];
    let sessions = vec![
        SessionSpec::new("s0", standard::medium_dataset(301), AlgorithmKind::MaxThroughput),
        SessionSpec::new("s1", standard::large_dataset(302), AlgorithmKind::MaxThroughput)
            .arriving_at(SimTime::from_secs(5.0)),
    ];
    let mut cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(61)
        .with_trace()
        .with_metrics();
    cfg.rebalance = RebalanceConfig::new(RebalancePolicyKind::MarginalEnergyDelta);
    if let Some(f) = faults {
        cfg.resilience = ResilienceConfig::new().with_faults(f).with_recovery();
    }
    cfg
}

#[test]
fn migrated_retried_session_reconciles_as_one_connected_tree() {
    // Probe run (no faults): learn when s1's post-migration residency
    // runs, so the scripted death can land squarely inside it.
    let probe = run_dispatcher(&hotspot_cfg(None));
    assert!(probe.fleet.completed);
    let mig = probe
        .migrations
        .iter()
        .find(|m| m.session == "s1")
        .expect("the hot-spot scenario must migrate s1");
    let resume = mig.t_secs + mig.drain_secs;
    let finish = probe
        .fleet
        .tenants
        .iter()
        .filter(|t| t.name == "s1")
        .filter_map(|t| t.finished_at)
        .map(|t| t.as_secs())
        .fold(0.0_f64, f64::max);
    assert!(finish > resume, "s1 must finish after its migration resumes");

    // Faulted run: kill the migration target mid-residency, revive it
    // later; recovery retries s1 through the penalty box.
    let down = (resume + finish) / 2.0;
    let faults = FaultSchedule::default().with_host_failure(
        0,
        SimTime::from_secs(down),
        Some(SimTime::from_secs(finish + 200.0)),
    );
    let out = run_dispatcher(&hotspot_cfg(Some(faults)));
    assert!(out.fleet.completed, "s1 must be redelivered after the crash");
    assert!(out.migrations.iter().any(|m| m.session == "s1"), "still migrates");
    assert!(out.retries.iter().any(|r| r.session == "s1"), "the death must retry s1");

    let log = TraceLog::parse(&trace_jsonl(out.trace.as_ref().unwrap()));
    assert_eq!(log.skipped, 0);

    // One connected tree per session; s1's carries the whole story.
    for session in ["s0", "s1"] {
        let tree = log.tree(session);
        assert!(tree.root.is_some(), "{session}: synthesized session root");
        assert!(tree.connected(), "{session}: span tree must be connected:\n{}", tree.waterfall());
    }
    let s1: Vec<_> = log.session_records("s1");
    let names: Vec<&str> = s1.iter().map(|r| r.name.as_str()).collect();
    for expected in ["admit", "migrate", "retry", "penalty_box", "complete"] {
        assert!(names.contains(&expected), "s1 trace lacks '{expected}': {names:?}");
    }
    // Three residencies: legacy, the migration target, the redelivery.
    let admits = s1.iter().filter(|r| r.name == "admit").count();
    assert!(admits >= 3, "expected >= 3 residencies for s1, got {admits}");
    assert!(
        s1.iter().any(|r| r.name == "admit" && r.attr_str("end") == Some("preempt")),
        "the killed residency must close as a preemption"
    );

    // Byte/joule reconciliation: each residency span's closing
    // attributes equal the matching FleetOutcome tenant entry bits.
    for session in ["s0", "s1"] {
        let mut outcomes: Vec<_> =
            out.fleet.tenants.iter().filter(|t| t.name == session).collect();
        outcomes.sort_by(|a, b| a.arrived_at.as_secs().total_cmp(&b.arrived_at.as_secs()));
        let mut spans: Vec<_> = log
            .session_records(session)
            .into_iter()
            .filter(|r| r.name == "admit")
            .collect();
        spans.sort_by(|a, b| a.t0_secs.total_cmp(&b.t0_secs));
        assert_eq!(spans.len(), outcomes.len(), "{session}: residency count");
        for (span, tenant) in spans.iter().zip(&outcomes) {
            let what = format!("{session} residency @ {}", span.t0_secs);
            assert_f64_bits(
                span.attr_f64("moved_bytes").unwrap(),
                tenant.moved.as_f64(),
                &format!("{what}: moved"),
            );
            assert_f64_bits(
                span.attr_f64("attributed_j").unwrap(),
                tenant.attributed_energy.as_joules(),
                &format!("{what}: attributed joules"),
            );
        }
    }

    // The decision log and the trace agree on counts.
    let m = out.metrics.as_ref().unwrap();
    assert_eq!(m.registry.counter("retries.scheduled"), out.retries.len() as u64);
    assert_eq!(m.registry.counter("migrations.executed"), out.migrations.len() as u64);
    assert_eq!(m.registry.counter("faults.fired"), out.faults.len() as u64);
    let placements = log.records.iter().filter(|r| r.name == "placement").count();
    assert_eq!(placements, out.decisions.len(), "one placement event per decision");
}
