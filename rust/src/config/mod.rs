//! Configuration: testbed definitions, experiment configs, and a
//! TOML-subset parser for user-supplied config files.
//!
//! [`testbeds`] carries the paper's three evaluation environments
//! (Table I) as ready-made [`Testbed`] values; [`toml`] implements the
//! parser (the offline crate set has no serde/toml, so GreenDT ships its
//! own); [`experiment`] maps parsed files to typed experiment configs.

pub mod experiment;
pub mod loader;
pub mod testbeds;
pub mod toml;

pub use experiment::{ExperimentConfig, TunerParams};
pub use loader::{load_file, load_str, LoadedConfig};
pub use testbeds::Testbed;
