//! Historical-log learning, end to end: run a fleet cold, record it,
//! then replay the same seeded arrival script warm and print the
//! joules/goodput delta.
//!
//!     cargo run --release --example learned_fleet
//!
//! Cold, every `HistoryTuned` tenant is bit-for-bit the paper's Minimum
//! Energy algorithm: Algorithm 1's heuristic guess, then the Slow Start
//! correction phase probing for the right concurrency. The completed
//! runs are appended to a JSONL [`HistoryStore`]; a deterministic k-NN
//! index over them answers "best known operating point for a workload
//! like this", and the warm replay starts every tenant there — no
//! probing, channels open at the converged count. Same arrivals, same
//! background-noise seed, strictly fewer joules at equal-or-better
//! aggregate goodput (pinned by `rust/tests/history_learning.rs`).

use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::coordinator::FleetPolicyKind;
use greendt::dataset::standard;
use greendt::history::{HistoryStore, Query, WorkloadFingerprint};
use greendt::metrics::Table;
use greendt::sim::fleet::{run_fleet, FleetConfig, FleetOutcome, TenantSpec};
use greendt::units::{Rate, SimTime};

/// Tenants per run, arrival spacing, and the shared RNG seed — one
/// "arrival script", reused cold and warm.
const TENANTS: u64 = 3;
const SPACING_S: f64 = 40.0;
const SEED: u64 = 11;

/// The shared arrival script with per-tenant algorithm kinds.
fn fleet_cfg(kinds: &[AlgorithmKind]) -> FleetConfig {
    let mut cfg = FleetConfig::new(testbeds::didclab(), Some(FleetPolicyKind::MinEnergyFleet))
        .with_seed(SEED);
    for (i, kind) in kinds.iter().enumerate() {
        cfg.tenants.push(
            TenantSpec::new(
                format!("tenant-{i}"),
                standard::medium_dataset(SEED + i as u64),
                *kind,
            )
            .arriving_at(SimTime::from_secs(SPACING_S * i as f64)),
        );
    }
    cfg
}

fn goodput(out: &FleetOutcome) -> Rate {
    Rate::average(out.moved, out.duration)
}

fn main() {
    println!("== learned_fleet: {TENANTS} tenants on DIDCLab, cold then warm ==\n");

    // 1. Cold: HistoryTuned with no store is exactly ME's slow start.
    let cold_kinds = vec![AlgorithmKind::HistoryTuned(None); TENANTS as usize];
    let cold = run_fleet(&fleet_cfg(&cold_kinds));
    assert!(cold.completed, "cold run must finish");

    // 2. Record: append the completed runs to a store (a real file, so
    // the demo exercises the same persistence path as --record-history).
    let path = std::env::temp_dir().join("greendt_learned_fleet.jsonl");
    let _ = std::fs::remove_file(&path);
    let mut store = HistoryStore::open(&path).expect("open store");
    store.append_runs(&cold.run_records).expect("record cold runs");
    println!(
        "recorded {} runs to {} — settled operating points:",
        cold.run_records.len(),
        path.display()
    );
    for r in &cold.run_records {
        println!(
            "  {:<9} {} cores / P-state {} / {:>2} channels   {:>7.0} J  ({:.0} s)",
            r.session, r.cores, r.pstate, r.channels, r.joules, r.duration_s
        );
    }

    // 3. Learn + replay warm: each tenant asks the k-NN index for the
    // best known operating point of its own workload.
    let index = store.index();
    let tb = testbeds::didclab();
    let warm_kinds: Vec<AlgorithmKind> = (0..TENANTS)
        .map(|i| {
            let fp = WorkloadFingerprint::of(&standard::medium_dataset(SEED + i));
            let q = Query::on_testbed(&tb, fp, (i as u32).min(8))
                .with_algorithm("history");
            match index.confident_warm_start(&q) {
                Some(warm) => AlgorithmKind::HistoryTuned(Some(warm)),
                None => AlgorithmKind::HistoryTuned(None),
            }
        })
        .collect();
    let warmed = warm_kinds
        .iter()
        .filter(|k| matches!(k, AlgorithmKind::HistoryTuned(Some(_))))
        .count();
    println!("\nwarm replay: {warmed}/{TENANTS} tenants warm-started\n");
    let warm = run_fleet(&fleet_cfg(&warm_kinds));
    assert!(warm.completed, "warm run must finish");

    // 4. The headline delta.
    let mut t = Table::new(
        "cold vs warm on the same arrival script",
        &["run", "host energy", "makespan", "agg goodput", "energy/tenant"],
    );
    for (label, out) in [("cold", &cold), ("warm", &warm)] {
        t.push_row(vec![
            label.to_string(),
            format!("{}", out.client_energy),
            format!("{}", out.duration),
            format!("{}", goodput(out)),
            format!("{}", out.energy_per_tenant()),
        ]);
    }
    println!("{}", t.to_markdown());

    let dj = cold.client_energy.as_joules() - warm.client_energy.as_joules();
    let dj_pct = 100.0 * dj / cold.client_energy.as_joules();
    println!(
        "warm start saved {dj:.0} J ({dj_pct:.1}%) and moved the same bytes at \
         {} vs {}",
        goodput(&warm),
        goodput(&cold)
    );
    assert!(
        warm.client_energy < cold.client_energy,
        "warm must consume strictly fewer joules"
    );
    assert!(
        goodput(&warm).as_bytes_per_sec() >= goodput(&cold).as_bytes_per_sec(),
        "warm must not lose aggregate goodput"
    );
    println!(
        "\nlearning converged: the probing energy the paper's slow start pays on\n\
         every transfer is paid once, recorded, and skipped on every replay."
    );
    let _ = std::fs::remove_file(&path);
}
