//! Per-TCP-connection congestion-window model.
//!
//! Each open connection (a "stream"; a channel with parallelism `p` holds
//! `p` streams) carries a congestion window that ramps via slow start from
//! `INIT_WINDOW` toward the path's average window size (Table I's
//! `avgWinSize`, what `iperf` would report). The window bounds the stream's
//! rate at `win / RTT`; the bottleneck's fair share caps it further (see
//! [`super::share_goodput`]).

use crate::units::{Bytes, Rate, Rtt, SimDuration};

/// TCP maximum segment size modeled by the window dynamics (bytes).
pub const MSS: f64 = 1460.0;

/// Initial congestion window: 10 MSS of 1460 B (RFC 6928).
pub const INIT_WINDOW: f64 = 10.0 * MSS;

/// Congestion state of one TCP connection.
#[derive(Debug, Clone, Copy)]
pub struct StreamState {
    /// Current congestion window.
    window: Bytes,
    /// Path average window (slow start target).
    avg_win: Bytes,
    /// True while still in the exponential ramp.
    slow_start: bool,
}

impl StreamState {
    /// A fresh connection entering slow start.
    pub fn new(avg_win: Bytes) -> Self {
        StreamState {
            window: Bytes::new(INIT_WINDOW.min(avg_win.as_f64())),
            avg_win,
            slow_start: true,
        }
    }

    /// A connection already at steady state (for tests and warm restarts).
    pub fn warm(avg_win: Bytes) -> Self {
        StreamState { window: avg_win, avg_win, slow_start: false }
    }

    /// Current congestion window.
    pub fn window(&self) -> Bytes {
        self.window
    }

    /// True while the window is still ramping.
    pub fn in_slow_start(&self) -> bool {
        self.slow_start
    }

    /// Maximum rate this stream's window allows.
    pub fn window_rate(&self, rtt: Rtt) -> Rate {
        if rtt.is_zero() {
            return Rate::ZERO;
        }
        Rate::from_bytes_per_sec(self.window.as_f64() / rtt.as_secs())
    }

    /// The per-tick slow-start multiplier `2^(min(dt/rtt, 32))` — a pure
    /// function of the (tick, RTT) pair, so epoch-cached steppers compute
    /// it once per tick instead of calling `powf` per stream. `None` when
    /// `rtt` is zero (windows hold still, exactly as [`Self::tick`] does).
    ///
    /// The `min(32)` clamp sits *inside* the cached exponent: a cached
    /// factor therefore reproduces [`Self::tick`] bit-for-bit, including
    /// the tick on which a stream lands on `avg_win` and leaves slow start.
    pub fn growth_factor(dt: SimDuration, rtt: Rtt) -> Option<f64> {
        if rtt.is_zero() {
            return None;
        }
        let growth = (dt.as_secs() / rtt.as_secs()).min(32.0); // avoid inf pow
        Some(2f64.powf(growth))
    }

    /// Advance the window by `dt`: during slow start the window doubles
    /// once per RTT (continuous-time equivalent: `w *= 2^(dt/rtt)`), capped
    /// at `avg_win`, after which the stream holds steady (the paper's
    /// testbeds are loss-managed by the overload penalty at the link level,
    /// not per-stream AIMD).
    pub fn tick(&mut self, dt: SimDuration, rtt: Rtt) {
        if let Some(factor) = Self::growth_factor(dt, rtt) {
            self.tick_cached(factor);
        }
    }

    /// [`Self::tick`] with the growth factor precomputed by
    /// [`Self::growth_factor`]. Exiting slow start lands exactly on
    /// `avg_win` and flips `slow_start` on the same tick as the uncached
    /// path: both compare the identical product `window * factor` against
    /// `avg_win`.
    pub fn tick_cached(&mut self, growth_factor: f64) {
        if !self.slow_start {
            return;
        }
        let w = self.window.as_f64() * growth_factor;
        if w >= self.avg_win.as_f64() {
            self.window = self.avg_win;
            self.slow_start = false;
        } else {
            self.window = Bytes::new(w);
        }
    }

    /// Back off after an overload signal: halve the window (multiplicative
    /// decrease) but never below the initial window.
    pub fn backoff(&mut self) {
        self.window = Bytes::new((self.window.as_f64() * 0.5).max(INIT_WINDOW));
        self.slow_start = false;
    }

    /// AIMD additive increase: grow the window by one [`MSS`] per RTT
    /// (continuous-time: `w += MSS * dt/rtt`), capped at `avg_win` — the
    /// path ceiling the allocator models. Only meaningful once the stream
    /// has left slow start; slow-start streams keep their exponential
    /// ramp ([`Self::tick`]) until the first congestion signal. A zero
    /// RTT holds the window still, exactly as [`Self::tick`] does.
    pub fn additive_increase(&mut self, dt: SimDuration, rtt: Rtt) {
        if self.slow_start || rtt.is_zero() {
            return;
        }
        let w = self.window.as_f64() + MSS * (dt.as_secs() / rtt.as_secs());
        self.window = Bytes::new(w.min(self.avg_win.as_f64()));
    }

    /// BBR-like congestion response (feature `bbr`): instead of halving,
    /// drain to the delivered-rate BDP estimate `delivered_bps * rtt`
    /// (floored at [`INIT_WINDOW`]) — model of BBR's ProbeBW leaving the
    /// queue it built rather than multiplicatively backing off.
    #[cfg(feature = "bbr")]
    pub fn drain_to_delivered(&mut self, delivered_bps: f64, rtt: Rtt) {
        let bdp = (delivered_bps * rtt.as_secs()).max(INIT_WINDOW);
        self.window = Bytes::new(bdp.min(self.avg_win.as_f64()));
        self.slow_start = false;
    }

    /// BBR-like probe (feature `bbr`): multiplicative 25%-per-RTT window
    /// probe toward the path ceiling, the ProbeBW up-phase analogue of
    /// [`Self::additive_increase`].
    #[cfg(feature = "bbr")]
    pub fn probe_gain(&mut self, dt: SimDuration, rtt: Rtt) {
        if self.slow_start || rtt.is_zero() {
            return;
        }
        let w = self.window.as_f64() * (1.0 + 0.25 * dt.as_secs() / rtt.as_secs());
        self.window = Bytes::new(w.min(self.avg_win.as_f64()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rtt() -> Rtt {
        SimDuration::from_millis(32.0)
    }

    #[test]
    fn starts_in_slow_start() {
        let s = StreamState::new(Bytes::from_mb(4.0));
        assert!(s.in_slow_start());
        assert_eq!(s.window().as_f64(), INIT_WINDOW);
    }

    #[test]
    fn window_doubles_per_rtt() {
        let mut s = StreamState::new(Bytes::from_mb(4.0));
        let w0 = s.window().as_f64();
        s.tick(rtt(), rtt());
        assert!((s.window().as_f64() / w0 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn converges_to_avg_win_and_exits_slow_start() {
        let mut s = StreamState::new(Bytes::from_mb(4.0));
        for _ in 0..1000 {
            s.tick(SimDuration::from_millis(100.0), rtt());
        }
        assert!(!s.in_slow_start());
        assert_eq!(s.window(), Bytes::from_mb(4.0));
    }

    #[test]
    fn ramp_time_is_log2_of_ratio() {
        // From 14.6 KB to 4 MB is log2(274) ≈ 8.1 RTTs ≈ 0.26 s at 32 ms.
        let mut s = StreamState::new(Bytes::from_mb(4.0));
        let mut t = 0.0;
        let dt = SimDuration::from_millis(10.0);
        while s.in_slow_start() && t < 10.0 {
            s.tick(dt, rtt());
            t += dt.as_secs();
        }
        assert!(t > 0.2 && t < 0.4, "ramp took {t}s");
    }

    #[test]
    fn window_rate() {
        let s = StreamState::warm(Bytes::from_mb(4.0));
        let r = s.window_rate(SimDuration::from_millis(32.0));
        // 4 MB / 32 ms = 125 MB/s = 1 Gbps.
        assert!((r.as_gbps() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn backoff_halves_but_floors() {
        let mut s = StreamState::warm(Bytes::from_mb(4.0));
        s.backoff();
        assert_eq!(s.window(), Bytes::from_mb(2.0));
        for _ in 0..64 {
            s.backoff();
        }
        assert_eq!(s.window().as_f64(), INIT_WINDOW);
    }

    #[test]
    fn warm_stream_does_not_grow() {
        let mut s = StreamState::warm(Bytes::from_mb(4.0));
        s.tick(SimDuration::from_secs(1.0), rtt());
        assert_eq!(s.window(), Bytes::from_mb(4.0));
    }

    #[test]
    fn cached_growth_matches_tick_bit_for_bit() {
        // Every (dt, rtt) pair — including dt/rtt > 32 where the clamp
        // engages — must evolve identically through the cached factor,
        // window bits and slow-start flag alike, on every tick.
        for dt_ms in [1.0, 10.0, 100.0, 1000.0, 5000.0] {
            for rtt_ms in [1.0, 32.0, 44.0, 100.0] {
                let dt = SimDuration::from_millis(dt_ms);
                let rtt = SimDuration::from_millis(rtt_ms);
                let mut naive = StreamState::new(Bytes::from_mb(4.0));
                let mut cached = StreamState::new(Bytes::from_mb(4.0));
                let factor = StreamState::growth_factor(dt, rtt).unwrap();
                // The ramp is ~8.2 RTTs (log2 of 4 MB / INIT_WINDOW); run
                // comfortably past it for the slowest (dt ≪ rtt) pairs.
                let ticks = (10.0 * rtt_ms / dt_ms).ceil() as usize + 4;
                for step in 0..ticks {
                    naive.tick(dt, rtt);
                    cached.tick_cached(factor);
                    assert_eq!(
                        naive.window().as_f64().to_bits(),
                        cached.window().as_f64().to_bits(),
                        "window diverged at step {step} (dt {dt_ms} ms, rtt {rtt_ms} ms)"
                    );
                    assert_eq!(
                        naive.in_slow_start(),
                        cached.in_slow_start(),
                        "slow-start flag diverged at step {step} (dt {dt_ms} ms, rtt {rtt_ms} ms)"
                    );
                }
                assert!(!naive.in_slow_start(), "{ticks} ticks must finish the ramp");
            }
        }
    }

    #[test]
    fn cached_growth_lands_exactly_on_avg_win() {
        // avg_win = 8 × INIT_WINDOW and dt = rtt (factor exactly 2.0):
        // after three doublings the product equals avg_win exactly, so the
        // `>=` branch fires and both paths exit slow start that tick.
        let avg = Bytes::new(8.0 * INIT_WINDOW);
        let mut s = StreamState::new(avg);
        let factor = StreamState::growth_factor(rtt(), rtt()).unwrap();
        assert_eq!(factor, 2.0);
        s.tick_cached(factor);
        s.tick_cached(factor);
        assert!(s.in_slow_start());
        s.tick_cached(factor);
        assert!(!s.in_slow_start(), "must exit on the exact-landing tick");
        assert_eq!(s.window(), avg);
    }

    #[test]
    fn additive_increase_is_one_mss_per_rtt_capped_at_avg_win() {
        let mut s = StreamState::warm(Bytes::from_mb(4.0));
        s.backoff(); // 2 MB, out of slow start
        let w0 = s.window().as_f64();
        s.additive_increase(rtt(), rtt());
        assert!((s.window().as_f64() - (w0 + MSS)).abs() < 1e-9);
        // Fractional RTTs scale linearly.
        s.additive_increase(SimDuration::from_millis(16.0), rtt());
        assert!((s.window().as_f64() - (w0 + 1.5 * MSS)).abs() < 1e-9);
        // Growth is capped at the path average window.
        for _ in 0..100_000 {
            s.additive_increase(rtt(), rtt());
        }
        assert_eq!(s.window(), Bytes::from_mb(4.0));
    }

    #[test]
    fn additive_increase_ignores_slow_start_and_zero_rtt() {
        let mut ramping = StreamState::new(Bytes::from_mb(4.0));
        let w0 = ramping.window();
        ramping.additive_increase(rtt(), rtt());
        assert_eq!(ramping.window(), w0, "slow-start streams keep the exponential ramp");
        let mut warm = StreamState::warm(Bytes::from_mb(4.0));
        warm.backoff();
        let w1 = warm.window();
        warm.additive_increase(rtt(), SimDuration::ZERO);
        assert_eq!(warm.window(), w1, "zero RTT holds the window still");
    }

    #[cfg(feature = "bbr")]
    #[test]
    fn bbr_drain_and_probe_track_the_delivered_bdp() {
        let mut s = StreamState::warm(Bytes::from_mb(4.0));
        // Delivered 31.25 MB/s over a 32 ms path: BDP = 1 MB.
        s.drain_to_delivered(31.25e6, rtt());
        assert!(!s.in_slow_start());
        assert!((s.window().as_f64() - 1e6).abs() < 1.0, "window {}", s.window());
        // Probe grows 25% per RTT, capped at avg_win.
        let w0 = s.window().as_f64();
        s.probe_gain(rtt(), rtt());
        assert!((s.window().as_f64() - 1.25 * w0).abs() < 1.0);
        for _ in 0..1000 {
            s.probe_gain(rtt(), rtt());
        }
        assert_eq!(s.window(), Bytes::from_mb(4.0));
        // Drain floors at the initial window.
        s.drain_to_delivered(0.0, rtt());
        assert_eq!(s.window().as_f64(), INIT_WINDOW);
    }

    #[test]
    fn zero_rtt_has_no_growth_factor() {
        assert!(StreamState::growth_factor(rtt(), SimDuration::ZERO).is_none());
        let mut s = StreamState::new(Bytes::from_mb(4.0));
        let w0 = s.window();
        s.tick(rtt(), SimDuration::ZERO);
        assert_eq!(s.window(), w0);
        assert!(s.in_slow_start());
    }
}
