//! Load experiment configuration from TOML files.
//!
//! `greendt run --config transfer.toml` reads everything a session needs —
//! testbed (by name, or fully custom link/CPU parameters), dataset (by
//! name, or a custom spec), algorithm, SLA target, tuner knobs — letting a
//! downstream user script experiments without recompiling.
//!
//! ```toml
//! # transfer.toml
//! [session]
//! testbed  = "cloudlab"        # or define [testbed] below
//! dataset  = "mixed"           # or define [dataset] below
//! algorithm = "eett"
//! target_mbps = 400
//! seed = 7
//!
//! [tuner]
//! alpha = 0.1
//! beta = 0.05
//! delta_ch = 2
//! max_ch = 48
//! timeout_s = 3.0
//! governor = "predictive"
//!
//! [testbed]                    # optional full override
//! name = "custom"
//! bandwidth_gbps = 2.5
//! rtt_ms = 20
//! avg_win_mb = 2.0
//! bg_mean = 0.1
//! client_cpu = "broadwell"     # haswell|broadwell|bloomfield
//!
//! [dataset]                    # optional synthetic spec
//! num_files = 500
//! avg_size_mb = 8.0
//! std_size_mb = 2.0
//! ```

use super::experiment::{GovernorKind, TunerParams};
use super::testbeds::{self, Testbed};
use super::toml::Document;
use crate::coordinator::AlgorithmKind;
use crate::cpusim::standard as cpus;
use crate::dataset::{generate, Dataset, DatasetSpec};
use crate::units::{Bytes, Power, Rate, SimDuration};
use anyhow::{bail, Context, Result};

/// Everything parsed from a config file.
#[derive(Debug, Clone)]
pub struct LoadedConfig {
    /// The resolved testbed.
    pub testbed: Testbed,
    /// The resolved (generated or manifest-loaded) dataset.
    pub dataset: Dataset,
    /// The tuning algorithm to run.
    pub algorithm: AlgorithmKind,
    /// Tuner knobs.
    pub tuner: TunerParams,
    /// RNG seed.
    pub seed: u64,
}

/// Parse a config file's contents.
pub fn load_str(input: &str) -> Result<LoadedConfig> {
    let doc = Document::parse(input).map_err(|e| anyhow::anyhow!("config parse error: {e}"))?;

    let seed = doc.get_int("session.seed").unwrap_or(42) as u64;

    // --- testbed --------------------------------------------------------
    let testbed = if doc.get("testbed.bandwidth_gbps").is_some() {
        custom_testbed(&doc)?
    } else {
        let name = doc.get_str("session.testbed").unwrap_or("cloudlab");
        testbeds::by_name(name).with_context(|| format!("unknown testbed '{name}'"))?
    };

    // --- dataset --------------------------------------------------------
    let dataset = if doc.get("dataset.num_files").is_some() {
        let spec = DatasetSpec::new(
            "custom",
            doc.get_int("dataset.num_files").unwrap_or(100) as usize,
            Bytes::from_mb(doc.get_float("dataset.avg_size_mb").unwrap_or(1.0)),
            Bytes::from_mb(doc.get_float("dataset.std_size_mb").unwrap_or(0.1)),
        );
        generate(&spec, seed)
    } else {
        let name = doc.get_str("session.dataset").unwrap_or("mixed");
        crate::dataset::standard::by_name(name, seed)
            .with_context(|| format!("unknown dataset '{name}'"))?
    };

    // --- algorithm ------------------------------------------------------
    let algo_id = doc.get_str("session.algorithm").unwrap_or("eemt");
    let target = doc.get_float("session.target_mbps").map(Rate::from_mbps);
    let algorithm = AlgorithmKind::parse(algo_id, target).with_context(|| {
        format!("unknown algorithm '{algo_id}' (target algorithms need session.target_mbps)")
    })?;

    // --- tuner ----------------------------------------------------------
    let mut tuner = TunerParams::default();
    if let Some(v) = doc.get_float("tuner.alpha") {
        tuner.alpha = v;
    }
    if let Some(v) = doc.get_float("tuner.beta") {
        tuner.beta = v;
    }
    if let Some(v) = doc.get_int("tuner.delta_ch") {
        tuner.delta_ch = v.max(1) as u32;
    }
    if let Some(v) = doc.get_int("tuner.max_ch") {
        tuner.max_ch = v.max(1) as u32;
    }
    if let Some(v) = doc.get_float("tuner.timeout_s") {
        tuner.timeout = SimDuration::from_secs(v);
    }
    if let Some(v) = doc.get_float("tuner.target_timeout_s") {
        tuner.target_timeout = SimDuration::from_secs(v);
    }
    if let Some(v) = doc.get_int("tuner.slow_start_rounds") {
        tuner.slow_start_rounds = v.max(1) as u32;
    }
    if let Some(v) = doc.get_float("tuner.max_load") {
        tuner.thresholds.max_load = v;
    }
    if let Some(v) = doc.get_float("tuner.min_load") {
        tuner.thresholds.min_load = v;
    }
    if let Some(g) = doc.get_str("tuner.governor") {
        tuner.governor = match g {
            "threshold" => GovernorKind::Threshold,
            "predictive" => GovernorKind::Predictive,
            "os" => GovernorKind::Os,
            "none" => GovernorKind::None,
            other => bail!("unknown governor '{other}'"),
        };
    }
    validate_tuner(&tuner)?;

    Ok(LoadedConfig { testbed, dataset, algorithm, tuner, seed })
}

/// Load from a file path.
pub fn load_file(path: &str) -> Result<LoadedConfig> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
    load_str(&text).with_context(|| format!("in config {path}"))
}

fn custom_testbed(doc: &Document) -> Result<Testbed> {
    let cpu = |key: &str, default: &str| -> Result<crate::cpusim::CpuSpec> {
        Ok(match doc.get_str(key).unwrap_or(default) {
            "haswell" => cpus::haswell_client(),
            "haswell-server" => cpus::haswell_server(),
            "broadwell" => cpus::broadwell_client(),
            "bloomfield" => cpus::bloomfield_client(),
            other => bail!("unknown CPU '{other}'"),
        })
    };
    let bw = doc.get_float("testbed.bandwidth_gbps").unwrap_or(1.0);
    let rtt = doc.get_float("testbed.rtt_ms").unwrap_or(30.0);
    anyhow::ensure!(bw > 0.0, "testbed.bandwidth_gbps must be positive");
    anyhow::ensure!(rtt > 0.0, "testbed.rtt_ms must be positive");
    Ok(Testbed {
        name: "custom",
        link: crate::netsim::LinkParams {
            capacity: Rate::from_gbps(bw),
            rtt: SimDuration::from_millis(rtt),
            avg_win: Bytes::from_mb(doc.get_float("testbed.avg_win_mb").unwrap_or(1.0)),
            overload_gamma: doc.get_float("testbed.overload_gamma").unwrap_or(0.02),
            overload_floor: doc.get_float("testbed.overload_floor").unwrap_or(0.55),
        },
        bg_mean: doc.get_float("testbed.bg_mean").unwrap_or(0.1),
        client_cpu: cpu("testbed.client_cpu", "haswell")?,
        server_cpu: cpu("testbed.server_cpu", "haswell-server")?,
        client_base_power: Power::from_watts(
            doc.get_float("testbed.client_base_power_w").unwrap_or(45.0),
        ),
        wall_meter: doc.get_bool("testbed.wall_meter").unwrap_or(false),
    })
}

fn validate_tuner(t: &TunerParams) -> Result<()> {
    anyhow::ensure!(t.alpha > 0.0 && t.alpha < 1.0, "tuner.alpha must be in (0,1)");
    anyhow::ensure!(t.beta > 0.0 && t.beta < 1.0, "tuner.beta must be in (0,1)");
    anyhow::ensure!(t.delta_ch <= t.max_ch, "tuner.delta_ch must not exceed tuner.max_ch");
    anyhow::ensure!(
        t.thresholds.min_load < t.thresholds.max_load,
        "tuner.min_load must be below tuner.max_load"
    );
    anyhow::ensure!(!t.timeout.is_zero(), "tuner.timeout_s must be positive");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_config_uses_defaults() {
        let c = load_str("").unwrap();
        assert_eq!(c.testbed.name, "CloudLab");
        assert_eq!(c.dataset.name, "mixed");
        assert_eq!(c.algorithm.id(), "eemt");
        assert_eq!(c.seed, 42);
    }

    #[test]
    fn full_session_config() {
        let c = load_str(
            "[session]\ntestbed = \"chameleon\"\ndataset = \"large\"\n\
             algorithm = \"eett\"\ntarget_mbps = 2000\nseed = 7\n\
             [tuner]\nalpha = 0.2\ngovernor = \"predictive\"\n",
        )
        .unwrap();
        assert_eq!(c.testbed.name, "Chameleon");
        assert_eq!(c.dataset.name, "large");
        assert_eq!(c.algorithm.id(), "eett");
        assert_eq!(c.seed, 7);
        assert_eq!(c.tuner.alpha, 0.2);
        assert_eq!(c.tuner.governor, GovernorKind::Predictive);
    }

    #[test]
    fn custom_testbed_and_dataset() {
        let c = load_str(
            "[testbed]\nbandwidth_gbps = 2.5\nrtt_ms = 20\navg_win_mb = 2.0\n\
             client_cpu = \"bloomfield\"\nwall_meter = true\n\
             [dataset]\nnum_files = 50\navg_size_mb = 8.0\nstd_size_mb = 1.0\n",
        )
        .unwrap();
        assert_eq!(c.testbed.name, "custom");
        assert!((c.testbed.link.capacity.as_gbps() - 2.5).abs() < 1e-9);
        assert!(c.testbed.wall_meter);
        assert!(c.testbed.client_cpu.name.starts_with("Bloomfield"));
        assert_eq!(c.dataset.num_files(), 50);
        assert!((c.dataset.avg_file_size().as_mb() - 8.0).abs() < 1e-6);
    }

    #[test]
    fn bad_values_are_rejected() {
        assert!(load_str("[session]\nalgorithm = \"warp\"\n").is_err());
        assert!(load_str("[session]\nalgorithm = \"eett\"\n").is_err(), "missing target");
        assert!(load_str("[tuner]\nalpha = 1.5\n").is_err());
        assert!(load_str("[tuner]\ngovernor = \"chaos\"\n").is_err());
        assert!(load_str("[testbed]\nbandwidth_gbps = -1\n").is_err());
        assert!(load_str("[tuner]\nmin_load = 0.9\nmax_load = 0.5\n").is_err());
    }

    #[test]
    fn loaded_config_runs_a_session() {
        let c = load_str(
            "[session]\ntestbed = \"cloudlab\"\ndataset = \"large\"\nalgorithm = \"me\"\n",
        )
        .unwrap();
        let cfg = crate::sim::session::SessionConfig::new(c.testbed, c.dataset, c.algorithm)
            .with_params(c.tuner)
            .with_seed(c.seed);
        let out = crate::sim::session::run_session(&cfg);
        assert!(out.completed);
    }
}
