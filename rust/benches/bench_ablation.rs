//! Design-choice ablations (DESIGN.md §6): the concurrency landscape the
//! tuners search, feedback-band / timeout sensitivity, and the Slow Start
//! and server-scaling ablations.
//!
//!     cargo bench --bench bench_ablation

use greendt::benchkit::time_once;
use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::dataset::standard;
use greendt::experiments::sweep;
use greendt::sim::session::{run_session, SessionConfig};

fn main() {
    println!("== bench_ablation: design-choice ablations ==\n");

    let ((), secs) = time_once("all ablation grids", || {
        for tb in ["chameleon", "cloudlab", "didclab"] {
            let pts = sweep::concurrency_sweep(tb, "large", 42);
            println!("{}", sweep::sweep_table(tb, "large", &pts).to_markdown());
            // The landscape the FSMs search: report knee and overload tail.
            let peak = pts.iter().map(|p| p.throughput_gbps).fold(0.0, f64::max);
            let tail = pts.last().unwrap().throughput_gbps;
            println!(
                "  peak {peak:.2} Gbps, 48-channel tail {tail:.2} Gbps ({:.0}% of peak)\n",
                tail / peak * 100.0
            );
        }
        println!("{}", sweep::band_sensitivity(42).to_markdown());
        println!("{}", sweep::timeout_sensitivity(42).to_markdown());
        println!("{}", sweep::slow_start_ablation(42).to_markdown());
    });

    // Server-scaling extension ablation.
    let base = SessionConfig::new(
        testbeds::cloudlab(),
        standard::mixed_dataset(42),
        AlgorithmKind::MaxThroughput,
    );
    let plain = run_session(&base.clone());
    let scaled = run_session(&base.with_server_scaling());
    println!("server-scaling extension (EEMT, CloudLab/mixed):");
    println!(
        "  server energy {} -> {} ({:+.0}%), throughput {} -> {}",
        plain.server_energy,
        scaled.server_energy,
        (scaled.server_energy.as_joules() / plain.server_energy.as_joules() - 1.0) * 100.0,
        plain.avg_throughput,
        scaled.avg_throughput
    );
    println!("\nwall time: {secs:.2}s");
}
