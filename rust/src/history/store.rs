//! The persistent transfer log: a JSONL [`HistoryStore`].
//!
//! One store is one append-only file (or a purely in-memory buffer for
//! tests and examples): `greendt … --record-history <path>` appends one
//! [`RunRecord`] line per completed session plus one line per placement
//! decision and per rebalancer migration, and `--history <path>` loads
//! the same file back —
//! across process runs — to warm-start tuning and placement. Loading is
//! forgiving: lines with an unknown version, unknown kind, or any parse
//! error are counted in [`HistoryStore::skipped`] and kept verbatim (so
//! maintenance never destroys them), never fatal (see [`super::record`]
//! for the schema contract).

use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::{self, Json};
use super::knn::KnnIndex;
use super::record::{self, RunRecord, FORMAT_VERSION, MIN_SUPPORTED_VERSION};
use crate::sim::{DispatchRecord, MigrationRecord};

/// Summary counters of one store (printed by `greendt history stats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Parsed run records.
    pub runs: usize,
    /// Preserved dispatch-decision lines.
    pub dispatches: usize,
    /// Preserved rebalancer-migration lines.
    pub migrations: usize,
    /// Lines skipped on load (unknown version/kind, parse errors).
    pub skipped: usize,
}

/// Which buffer one store line lives in (see [`HistoryStore::order`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LineKind {
    Run,
    Dispatch,
    Migration,
    Foreign,
}

/// The persistent transfer log (see the module docs).
#[derive(Debug, Clone)]
pub struct HistoryStore {
    path: Option<PathBuf>,
    /// Parsed run records (loaded + appended; [`Self::append_only`]
    /// stores hold only what this process appended).
    runs: Vec<RunRecord>,
    /// The original text of every run record, parallel to `runs`: a
    /// rewrite ([`Self::prune`]) must reproduce the line verbatim, not
    /// re-serialize the parsed struct — a same-version line may carry
    /// extra keys this build parses past but does not own.
    run_lines: Vec<String>,
    /// Dispatch lines are preserved verbatim (they are write-mostly
    /// telemetry; nothing in-process parses them back).
    dispatch_lines: Vec<String>,
    /// Migration lines, preserved verbatim like dispatch lines.
    migration_lines: Vec<String>,
    /// Lines this build could not interpret (unknown version/kind, parse
    /// errors), preserved verbatim so maintenance operations like
    /// [`Self::prune`] never destroy what a newer build wrote.
    foreign_lines: Vec<String>,
    /// Append-order journal across the four buffers: `(kind, index into
    /// that kind's buffer)` per line, so a rewrite reproduces the
    /// original interleaving (offline miners correlate timestamp-less
    /// run lines with decisions by position).
    order: Vec<(LineKind, usize)>,
    /// False for [`Self::append_only`] handles, which never read the
    /// backing file and therefore must not rewrite it.
    loaded: bool,
}

impl HistoryStore {
    /// An unbacked store (tests, examples): appends stay in memory.
    pub fn in_memory() -> HistoryStore {
        HistoryStore {
            path: None,
            runs: Vec::new(),
            run_lines: Vec::new(),
            dispatch_lines: Vec::new(),
            migration_lines: Vec::new(),
            foreign_lines: Vec::new(),
            order: Vec::new(),
            loaded: true,
        }
    }

    /// Open (and load) the store at `path`; a missing file is an empty
    /// store, created on first append.
    pub fn open(path: impl AsRef<Path>) -> Result<HistoryStore> {
        let path = path.as_ref();
        let mut store = HistoryStore::append_only(path);
        if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading history store {}", path.display()))?;
            store.ingest(&text);
        }
        store.loaded = true;
        Ok(store)
    }

    /// A file-backed store that does *not* read existing contents —
    /// the recording path (`--record-history`) only ever appends, so
    /// re-parsing a large accumulated log would be pure waste. Queries
    /// against such a store see only what this process appended, and
    /// [`Self::prune`] refuses it (a rewrite from a partial view would
    /// destroy the unread lines — use [`Self::open`] to prune).
    pub fn append_only(path: impl AsRef<Path>) -> HistoryStore {
        let mut store = HistoryStore::in_memory();
        store.path = Some(path.as_ref().to_path_buf());
        store.loaded = false;
        store
    }

    /// Parse store text into this store's buffers (counting skips).
    fn ingest(&mut self, text: &str) {
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Some(v) = json::parse(line) else {
                self.push_foreign(line);
                continue;
            };
            let version = v.get("v").and_then(Json::as_u32);
            if !version.is_some_and(|n| (MIN_SUPPORTED_VERSION..=FORMAT_VERSION).contains(&n)) {
                self.push_foreign(line);
                continue;
            }
            match v.get("kind").and_then(Json::as_str) {
                Some("run") => match RunRecord::from_json(&v) {
                    Some(r) => {
                        self.order.push((LineKind::Run, self.runs.len()));
                        self.runs.push(r);
                        self.run_lines.push(line.to_string());
                    }
                    None => self.push_foreign(line),
                },
                Some("dispatch") => {
                    self.order.push((LineKind::Dispatch, self.dispatch_lines.len()));
                    self.dispatch_lines.push(line.to_string());
                }
                Some("migration") => {
                    self.order.push((LineKind::Migration, self.migration_lines.len()));
                    self.migration_lines.push(line.to_string());
                }
                _ => self.push_foreign(line),
            }
        }
    }

    fn push_foreign(&mut self, line: &str) {
        self.order.push((LineKind::Foreign, self.foreign_lines.len()));
        self.foreign_lines.push(line.to_string());
    }

    /// Append `lines` to the backing file in one open/write (no-op for
    /// in-memory stores).
    fn write_lines(&self, lines: &[String]) -> Result<()> {
        if lines.is_empty() {
            return Ok(());
        }
        let Some(path) = &self.path else { return Ok(()) };
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening history store {}", path.display()))?;
        let mut buf = String::with_capacity(lines.iter().map(|l| l.len() + 1).sum());
        for line in lines {
            buf.push_str(line);
            buf.push('\n');
        }
        f.write_all(buf.as_bytes())
            .with_context(|| format!("appending to {}", path.display()))
    }

    /// Append run records (write-through when file-backed, one file open
    /// per batch). Returns how many were appended.
    pub fn append_runs(&mut self, records: &[RunRecord]) -> Result<usize> {
        let lines: Vec<String> = records.iter().map(RunRecord::to_json_line).collect();
        self.write_lines(&lines)?;
        for (r, line) in records.iter().zip(lines) {
            self.order.push((LineKind::Run, self.runs.len()));
            self.runs.push(r.clone());
            self.run_lines.push(line);
        }
        Ok(records.len())
    }

    /// Append dispatcher decisions (write-through when file-backed, one
    /// file open per batch). Returns how many were appended.
    pub fn append_dispatches(&mut self, decisions: &[DispatchRecord]) -> Result<usize> {
        let lines: Vec<String> =
            decisions.iter().map(record::dispatch_to_json_line).collect();
        self.write_lines(&lines)?;
        for line in lines {
            self.order.push((LineKind::Dispatch, self.dispatch_lines.len()));
            self.dispatch_lines.push(line);
        }
        Ok(decisions.len())
    }

    /// Append rebalancer migrations (write-through when file-backed, one
    /// file open per batch). Returns how many were appended.
    pub fn append_migrations(&mut self, migrations: &[MigrationRecord]) -> Result<usize> {
        let lines: Vec<String> =
            migrations.iter().map(record::migration_to_json_line).collect();
        self.write_lines(&lines)?;
        for line in lines {
            self.order.push((LineKind::Migration, self.migration_lines.len()));
            self.migration_lines.push(line);
        }
        Ok(migrations.len())
    }

    /// The loaded + appended run records, oldest first.
    pub fn runs(&self) -> &[RunRecord] {
        &self.runs
    }

    /// Number of dispatch-decision lines held.
    pub fn dispatch_count(&self) -> usize {
        self.dispatch_lines.len()
    }

    /// Number of rebalancer-migration lines held.
    pub fn migration_count(&self) -> usize {
        self.migration_lines.len()
    }

    /// Lines skipped while loading (unknown version/kind or malformed).
    /// They are preserved verbatim, not discarded — see [`Self::prune`].
    pub fn skipped(&self) -> usize {
        self.foreign_lines.len()
    }

    /// Summary counters.
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            runs: self.runs.len(),
            dispatches: self.dispatch_lines.len(),
            migrations: self.migration_lines.len(),
            skipped: self.foreign_lines.len(),
        }
    }

    /// Build a k-NN index over the current run records (a snapshot —
    /// later appends do not update it; rebuild to refresh).
    pub fn index(&self) -> KnnIndex {
        KnnIndex::build(&self.runs)
    }

    /// Keep only the newest `keep` run records, `keep` dispatch lines and
    /// `keep` migration lines, rewriting the backing file with the
    /// surviving lines in their original order. Lines this build could not interpret (e.g.
    /// records written by a newer version) are rewritten verbatim, never
    /// dropped — pruning must not destroy what it cannot read; for the
    /// same reason an [`Self::append_only`] handle (which never read the
    /// file) cannot prune. Returns the number of lines dropped.
    pub fn prune(&mut self, keep: usize) -> Result<usize> {
        if !self.loaded {
            bail!(
                "pruning needs a fully loaded store (HistoryStore::open), \
                 not an append-only handle"
            );
        }
        let drop_runs = self.runs.len().saturating_sub(keep);
        let drop_disp = self.dispatch_lines.len().saturating_sub(keep);
        let drop_migr = self.migration_lines.len().saturating_sub(keep);
        // Rebuild the buffers through the order journal so the surviving
        // lines keep their original interleaving.
        let mut runs = Vec::with_capacity(self.runs.len() - drop_runs);
        let mut run_lines = Vec::with_capacity(self.runs.len() - drop_runs);
        let mut dispatches = Vec::with_capacity(self.dispatch_lines.len() - drop_disp);
        let mut migrations = Vec::with_capacity(self.migration_lines.len() - drop_migr);
        let mut foreign = Vec::with_capacity(self.foreign_lines.len());
        let mut order = Vec::with_capacity(self.order.len());
        for &(kind, idx) in &self.order {
            match kind {
                LineKind::Run => {
                    if idx >= drop_runs {
                        order.push((LineKind::Run, runs.len()));
                        runs.push(self.runs[idx].clone());
                        run_lines.push(self.run_lines[idx].clone());
                    }
                }
                LineKind::Dispatch => {
                    if idx >= drop_disp {
                        order.push((LineKind::Dispatch, dispatches.len()));
                        dispatches.push(self.dispatch_lines[idx].clone());
                    }
                }
                LineKind::Migration => {
                    if idx >= drop_migr {
                        order.push((LineKind::Migration, migrations.len()));
                        migrations.push(self.migration_lines[idx].clone());
                    }
                }
                LineKind::Foreign => {
                    order.push((LineKind::Foreign, foreign.len()));
                    foreign.push(self.foreign_lines[idx].clone());
                }
            }
        }
        self.runs = runs;
        self.run_lines = run_lines;
        self.dispatch_lines = dispatches;
        self.migration_lines = migrations;
        self.foreign_lines = foreign;
        self.order = order;
        if let Some(path) = &self.path {
            let mut out = String::new();
            // Run lines are rewritten from their original text, not
            // re-serialized: a same-version line may carry keys this
            // build does not know about.
            for &(kind, idx) in &self.order {
                match kind {
                    LineKind::Run => out.push_str(&self.run_lines[idx]),
                    LineKind::Dispatch => out.push_str(&self.dispatch_lines[idx]),
                    LineKind::Migration => out.push_str(&self.migration_lines[idx]),
                    LineKind::Foreign => out.push_str(&self.foreign_lines[idx]),
                }
                out.push('\n');
            }
            // Atomic replace: write a sibling temp file, then rename over
            // the original, so a crash mid-rewrite cannot truncate the
            // store (the lines prune promises to preserve included).
            let mut tmp_name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
            tmp_name.push(".tmp");
            let tmp = path.with_file_name(tmp_name);
            std::fs::write(&tmp, out)
                .with_context(|| format!("writing pruned store to {}", tmp.display()))?;
            std::fs::rename(&tmp, path)
                .with_context(|| format!("replacing history store {}", path.display()))?;
        }
        Ok(drop_runs + drop_disp + drop_migr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::PlacementScore;

    fn sample_run(name: &str) -> RunRecord {
        let mut r = crate::history::record::sample_record();
        r.session = name.to_string();
        r
    }

    fn sample_dispatch() -> DispatchRecord {
        DispatchRecord {
            t_secs: 1.0,
            session: "s".to_string(),
            requested_at_secs: 1.0,
            admitted_host: Some(0),
            host: Some("h".to_string()),
            projected_fleet_power_w: 50.0,
            scores: vec![PlacementScore {
                host: "h".to_string(),
                active_sessions: 0,
                current_power_w: 10.0,
                projected_power_w: 20.0,
                projected_session_bps: 1e8,
                marginal_j_per_byte: 1e-7,
                queue_delay_j_per_byte: 0.0,
                learned_j_per_byte: Some(2e-7),
            }],
        }
    }

    fn temp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("greendt_history_{name}_{}.jsonl", std::process::id()))
    }

    #[test]
    fn file_round_trip_preserves_records() {
        let path = temp_path("roundtrip");
        let _ = std::fs::remove_file(&path);
        let mut store = HistoryStore::open(&path).unwrap();
        store.append_runs(&[sample_run("a"), sample_run("b")]).unwrap();
        store.append_dispatches(&[sample_dispatch()]).unwrap();

        let back = HistoryStore::open(&path).unwrap();
        assert_eq!(back.stats(), StoreStats { runs: 2, dispatches: 1, migrations: 0, skipped: 0 });
        assert_eq!(back.runs(), store.runs());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unknown_versions_and_garbage_are_skipped_with_a_count() {
        let path = temp_path("skip");
        let good = sample_run("good").to_json_line();
        let future = good.replace("\"v\":3,", "\"v\":999,");
        let text = format!("{good}\nnot json at all\n{future}\n{{\"v\":1,\"kind\":\"??\"}}\n");
        std::fs::write(&path, text).unwrap();
        let store = HistoryStore::open(&path).unwrap();
        assert_eq!(store.stats(), StoreStats { runs: 1, dispatches: 0, migrations: 0, skipped: 3 });
        assert_eq!(store.runs()[0].session, "good");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn prune_keeps_the_newest_and_rewrites() {
        let path = temp_path("prune");
        let _ = std::fs::remove_file(&path);
        let mut store = HistoryStore::open(&path).unwrap();
        let runs: Vec<RunRecord> =
            (0..5).map(|i| sample_run(&format!("run-{i}"))).collect();
        store.append_runs(&runs).unwrap();
        let dropped = store.prune(2).unwrap();
        assert_eq!(dropped, 3);
        assert_eq!(store.runs().len(), 2);
        assert_eq!(store.runs()[0].session, "run-3");
        let back = HistoryStore::open(&path).unwrap();
        assert_eq!(back.stats(), StoreStats { runs: 2, dispatches: 0, migrations: 0, skipped: 0 });
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn prune_preserves_lines_it_cannot_read() {
        // A newer build's records must survive this build's maintenance.
        let path = temp_path("prune_foreign");
        let good = sample_run("mine").to_json_line();
        let future = good.replace("\"v\":3,", "\"v\":9,");
        std::fs::write(&path, format!("{good}\n{future}\n")).unwrap();
        let mut store = HistoryStore::open(&path).unwrap();
        assert_eq!(store.stats(), StoreStats { runs: 1, dispatches: 0, migrations: 0, skipped: 1 });
        store.prune(0).unwrap();
        let back = HistoryStore::open(&path).unwrap();
        assert_eq!(
            back.stats(),
            StoreStats { runs: 0, dispatches: 0, migrations: 0, skipped: 1 },
            "the v9 line must still be in the file after prune"
        );
        assert!(std::fs::read_to_string(&path).unwrap().contains("\"v\":9,"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn prune_preserves_the_original_interleaving() {
        // run/dispatch/run/dispatch must come back in that order, not
        // grouped by kind — offline miners correlate by position.
        let path = temp_path("prune_order");
        let _ = std::fs::remove_file(&path);
        let mut store = HistoryStore::open(&path).unwrap();
        store.append_runs(&[sample_run("r0")]).unwrap();
        store.append_dispatches(&[sample_dispatch()]).unwrap();
        store.append_runs(&[sample_run("r1")]).unwrap();
        store.append_dispatches(&[sample_dispatch()]).unwrap();
        // Nothing dropped: the rewrite must be order-identical.
        assert_eq!(store.prune(10).unwrap(), 0);
        let text = std::fs::read_to_string(&path).unwrap();
        let kinds: Vec<&str> = text
            .lines()
            .map(|l| if l.contains("\"kind\":\"run\"") { "run" } else { "dispatch" })
            .collect();
        assert_eq!(kinds, ["run", "dispatch", "run", "dispatch"]);
        // Dropping the oldest run keeps everyone else in place.
        assert_eq!(store.prune(1).unwrap(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("\"session\":\"r0\""));
        assert!(text.contains("\"session\":\"r1\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn prune_keeps_extra_keys_on_same_version_lines() {
        // A v1 line with a key this build does not know still parses
        // (from_json ignores extras) — and a rewrite must not strip it.
        let path = temp_path("prune_extra_keys");
        let annotated = sample_run("keep")
            .to_json_line()
            .replace("\"kind\":\"run\",", "\"kind\":\"run\",\"note\":\"baseline\",");
        std::fs::write(&path, format!("{annotated}\n")).unwrap();
        let mut store = HistoryStore::open(&path).unwrap();
        assert_eq!(store.runs().len(), 1, "the annotated line must parse");
        store.prune(10).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            text.contains("\"note\":\"baseline\""),
            "prune must rewrite run lines verbatim, not re-serialize them"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_only_handles_cannot_prune() {
        let path = temp_path("prune_append_only");
        let _ = std::fs::remove_file(&path);
        let mut store = HistoryStore::append_only(&path);
        store.append_runs(&[sample_run("x")]).unwrap();
        assert!(
            store.prune(0).is_err(),
            "a partial view must not rewrite the backing file"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_only_store_writes_without_loading() {
        let path = temp_path("append_only");
        let _ = std::fs::remove_file(&path);
        // Pre-existing contents are not read…
        std::fs::write(&path, format!("{}\n", sample_run("old").to_json_line())).unwrap();
        let mut store = HistoryStore::append_only(&path);
        assert_eq!(store.stats(), StoreStats::default());
        // …but appends land after them.
        store.append_runs(&[sample_run("new")]).unwrap();
        let back = HistoryStore::open(&path).unwrap();
        assert_eq!(back.runs().len(), 2);
        assert_eq!(back.runs()[0].session, "old");
        assert_eq!(back.runs()[1].session, "new");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn migration_lines_round_trip_and_survive_prune() {
        use crate::sim::MigrationRecord;
        let path = temp_path("migrations");
        let _ = std::fs::remove_file(&path);
        let m = MigrationRecord {
            t_secs: 99.0,
            session: "s".to_string(),
            from_host: 1,
            from: "legacy".to_string(),
            to_host: 0,
            to: "efficient".to_string(),
            moved_bytes: 1e9,
            remaining_bytes: 2e9,
            drain_secs: 5.0,
            resume_at_secs: 104.0,
            est_benefit_j: 1000.0,
            est_cost_j: 100.0,
            policy: "cap-pressure",
        };
        let mut store = HistoryStore::open(&path).unwrap();
        store.append_runs(&[sample_run("r")]).unwrap();
        store.append_migrations(&[m.clone(), m]).unwrap();
        assert_eq!(store.migration_count(), 2);

        let back = HistoryStore::open(&path).unwrap();
        assert_eq!(
            back.stats(),
            StoreStats { runs: 1, dispatches: 0, migrations: 2, skipped: 0 },
            "migration lines load as their own kind, not as foreign"
        );
        // Prune treats them like dispatch lines: keep the newest N.
        let mut back = back;
        assert_eq!(back.prune(1).unwrap(), 1, "one migration line dropped");
        assert_eq!(back.stats().migrations, 1);
        assert_eq!(back.stats().runs, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn in_memory_store_never_touches_disk() {
        let mut store = HistoryStore::in_memory();
        store.append_runs(&[sample_run("x")]).unwrap();
        store.append_dispatches(&[sample_dispatch()]).unwrap();
        assert_eq!(store.stats(), StoreStats { runs: 1, dispatches: 1, migrations: 0, skipped: 0 });
        assert_eq!(store.index().len(), 1);
        assert_eq!(store.prune(0).unwrap(), 2);
        assert_eq!(store.stats(), StoreStats::default());
    }
}
