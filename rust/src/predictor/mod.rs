//! The energy/throughput predictor: Layer 2/1 consumed from Layer 3.
//!
//! The predictor evaluates a grid of candidate operating points
//! (channels, active cores, CPU frequency) against the analytic transfer
//! model and returns `(throughput, power, energy)` per candidate. Two
//! interchangeable backends:
//!
//! * [`Backend::Pjrt`] — the JAX/Pallas model AOT-compiled to
//!   `artifacts/predictor.hlo.txt`, executed through [`crate::runtime`]
//!   (the production path; Python never runs at transfer time);
//! * [`Backend::Oracle`] — a bit-compatible pure-Rust implementation
//!   ([`reference`]), used as fallback when the artifact is absent and as
//!   the parity check in tests.
//!
//! [`PredictiveGovernor`] is the GreenDT extension of the paper's
//! Algorithm 3: instead of threshold steps it picks the best whole
//! operating point for the SLA each timeout.

pub mod layout;
pub mod reference;
mod grid;
mod governor;

pub use governor::{PredictMode, PredictiveGovernor};
pub use grid::{build_state, cpu_grid, Candidate, Prediction};

/// The shared demo state (mirrors Python's `model.demo_state()`), exposed
/// for integration tests and benches.
pub fn demo_state_for_tests() -> Vec<f32> {
    grid::demo_state()
}

use crate::runtime::{ArrayF32, Executable};
use anyhow::Result;

/// Prediction backend.
#[derive(Debug)]
pub enum Backend {
    /// AOT-compiled JAX/Pallas model via PJRT.
    Pjrt(Executable),
    /// Pure-Rust oracle (identical math).
    Oracle,
}

/// A loaded predictor.
#[derive(Debug)]
pub struct Predictor {
    backend: Backend,
}

impl Predictor {
    /// Load the PJRT artifact, falling back to the oracle when missing.
    pub fn load_or_oracle() -> Predictor {
        let path = crate::runtime::default_predictor_path();
        match Executable::load_hlo_text(&path) {
            Ok(exe) => Predictor { backend: Backend::Pjrt(exe) },
            Err(e) => {
                log::warn!("predictor artifact unavailable ({e:#}); using Rust oracle");
                Predictor { backend: Backend::Oracle }
            }
        }
    }

    /// A predictor pinned to the pure-Rust oracle backend.
    pub fn oracle() -> Predictor {
        Predictor { backend: Backend::Oracle }
    }

    /// Load a compiled HLO artifact from `path` (requires the `pjrt` feature).
    pub fn from_artifact(path: &str) -> Result<Predictor> {
        Ok(Predictor { backend: Backend::Pjrt(Executable::load_hlo_text(path)?) })
    }

    /// True when the compiled PJRT backend is live.
    pub fn is_pjrt(&self) -> bool {
        matches!(self.backend, Backend::Pjrt(_))
    }

    /// Evaluate candidates (padded internally to the artifact's grid size).
    pub fn predict(&self, cands: &[Candidate], state: &[f32]) -> Result<Vec<Prediction>> {
        anyhow::ensure!(
            state.len() == layout::STATE_WIDTH,
            "state width {} != {}",
            state.len(),
            layout::STATE_WIDTH
        );
        anyhow::ensure!(
            cands.len() <= layout::NUM_CANDIDATES,
            "too many candidates: {} > {}",
            cands.len(),
            layout::NUM_CANDIDATES
        );
        match &self.backend {
            Backend::Oracle => Ok(cands
                .iter()
                .map(|c| reference::predict_one(c, state))
                .collect()),
            Backend::Pjrt(exe) => {
                let mut flat = vec![0f32; layout::NUM_CANDIDATES * layout::CAND_WIDTH];
                for (i, c) in cands.iter().enumerate() {
                    flat[i * layout::CAND_WIDTH] = c.channels;
                    flat[i * layout::CAND_WIDTH + 1] = c.cores;
                    flat[i * layout::CAND_WIDTH + 2] = c.freq_ghz;
                }
                let cand_arr =
                    ArrayF32::new(vec![layout::NUM_CANDIDATES, layout::CAND_WIDTH], flat)?;
                let state_arr = ArrayF32::vector(state.to_vec());
                let outs = exe.run_f32(&[cand_arr, state_arr])?;
                let out = &outs[0];
                Ok((0..cands.len())
                    .map(|i| Prediction {
                        tput_bps: out[i * layout::OUT_WIDTH] as f64,
                        power_w: out[i * layout::OUT_WIDTH + 1] as f64,
                        energy_j: out[i * layout::OUT_WIDTH + 2] as f64,
                    })
                    .collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_backend_predicts() {
        let p = Predictor::oracle();
        let cands = vec![Candidate { channels: 4.0, cores: 2.0, freq_ghz: 2.0 }];
        let state = grid::demo_state();
        let out = p.predict(&cands, &state).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].tput_bps > 0.0);
        assert!(out[0].power_w > 0.0);
        assert!(out[0].energy_j > 0.0);
    }

    #[test]
    fn state_width_checked() {
        let p = Predictor::oracle();
        let cands = vec![Candidate { channels: 1.0, cores: 1.0, freq_ghz: 1.0 }];
        assert!(p.predict(&cands, &[0.0; 3]).is_err());
    }
}
