//! Integration test: the AOT-compiled JAX/Pallas predictor executed
//! through PJRT must agree with the pure-Rust oracle.
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees it).

use greendt::cpusim::standard::{bloomfield_client, broadwell_client, haswell_server};
use greendt::predictor::{cpu_grid, demo_state_for_tests, Candidate, Predictor};

fn artifact_available() -> Option<Predictor> {
    match Predictor::from_artifact(&greendt::runtime::default_predictor_path()) {
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("SKIP: predictor artifact not built ({e:#}) — run `make artifacts`");
            None
        }
    }
}

fn assert_parity(cands: &[Candidate], state: &[f32], pjrt: &Predictor) {
    let oracle = Predictor::oracle();
    let a = pjrt.predict(cands, state).expect("pjrt predict");
    let b = oracle.predict(cands, state).expect("oracle predict");
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        let close = |u: f64, v: f64, what: &str| {
            let denom = u.abs().max(v.abs()).max(1.0);
            assert!(
                (u - v).abs() / denom < 2e-4,
                "candidate {i} {what}: pjrt {u} vs oracle {v} (cand {:?})",
                cands[i]
            );
        };
        close(x.tput_bps, y.tput_bps, "tput");
        close(x.power_w, y.power_w, "power");
        close(x.energy_j, y.energy_j, "energy");
    }
}

#[test]
fn pjrt_matches_oracle_on_demo_state() {
    let Some(pjrt) = artifact_available() else { return };
    assert!(pjrt.is_pjrt());
    let cands = cpu_grid(&broadwell_client(), 6);
    assert_parity(&cands, &demo_state_for_tests(), &pjrt);
}

#[test]
fn pjrt_matches_oracle_across_cpus_and_channels() {
    let Some(pjrt) = artifact_available() else { return };
    for spec in [haswell_server(), bloomfield_client()] {
        for channels in [1u32, 4, 16, 48] {
            let cands = cpu_grid(&spec, channels);
            assert_parity(&cands, &demo_state_for_tests(), &pjrt);
        }
    }
}

#[test]
fn pjrt_matches_oracle_on_perturbed_states() {
    use greendt::predictor::layout as l;
    let Some(pjrt) = artifact_available() else { return };
    let cands = cpu_grid(&broadwell_client(), 8);
    // Sweep a few axes of the state space deterministically.
    for (slot, values) in [
        (l::S_CAPACITY_BPS, vec![12.5e6f32, 125e6, 1.25e9]),
        (l::S_RTT_S, vec![0.004, 0.044, 0.2]),
        (l::S_AVG_FILE_BYTES, vec![1e5, 2.4e6, 2.2e8]),
        (l::S_PP_LEVEL, vec![1.0, 8.0, 32.0]),
        (l::S_PARALLELISM, vec![1.0, 4.0]),
    ] {
        for v in values {
            let mut state = demo_state_for_tests();
            state[slot] = v;
            assert_parity(&cands, &state, &pjrt);
        }
    }
}

#[test]
fn infeasible_padding_agrees() {
    let Some(pjrt) = artifact_available() else { return };
    let cands =
        vec![Candidate { channels: 0.0, cores: 0.0, freq_ghz: 0.0 }];
    let a = pjrt.predict(&cands, &demo_state_for_tests()).unwrap();
    assert_eq!(a[0].tput_bps, 0.0);
    assert!(a[0].energy_j > 1e29);
}
