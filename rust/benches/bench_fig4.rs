//! Figure 4 macro-benchmark: the frequency/core-scaling ablation
//! (3 testbeds × 6 variants, mixed dataset, client energy).
//!
//!     cargo bench --bench bench_fig4

use greendt::benchkit::time_once;
use greendt::experiments::fig4;

fn main() {
    println!("== bench_fig4: load-control ablation ==");
    let (results, secs) = time_once("fig4 grid (18 sessions)", || fig4::run(42));
    for t in &results.tables {
        println!("{}", t.to_markdown());
    }
    results.print_headlines();
    println!("wall time: {secs:.2}s");
}
