//! L3 hot-path benchmarks: the per-tick simulation loop.
//!
//!     cargo bench --bench bench_hotpath
//!
//! Thin wrapper over [`greendt::benchkit::hotpath`] (shared with the
//! `greendt bench` subcommand): goodput allocation (`share_goodput`),
//! whole-world tick cost at realistic stream counts for both the naive
//! reference stepper and the epoch-cached fast path, channel
//! redistribution, and the headline end-to-end rate — simulated seconds
//! per wall second — for both steppers.
//!
//! Set `GREENDT_BENCH_JSON=<path>` to also write the machine-readable
//! report (the same file `greendt bench --json <path>` produces).

use greendt::benchkit::hotpath;

fn main() {
    println!("== bench_hotpath: simulation hot loop ==\n");
    let report = hotpath::run(false);
    if let Ok(path) = std::env::var("GREENDT_BENCH_JSON") {
        report.write_json(&path).expect("writing bench JSON");
        println!("\nbench report written to {path}");
    }
}
