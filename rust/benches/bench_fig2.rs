//! Figure 2 macro-benchmark: regenerates the full tool × dataset ×
//! testbed grid (84 sessions) and reports wall time plus the tables.
//!
//!     cargo bench --bench bench_fig2

use greendt::benchkit::time_once;
use greendt::experiments::fig2;

fn main() {
    println!("== bench_fig2: full Figure 2 grid ==");
    let (results, secs) = time_once("fig2 grid (84 sessions)", || fig2::run(42));
    for t in &results.tables {
        println!("{}", t.to_markdown());
    }
    results.headlines().print();
    let total_sim: f64 =
        results.outcomes.iter().map(|(_, _, _, o)| o.duration.as_secs()).sum();
    println!(
        "\n{} sessions, {:.0} simulated seconds in {:.2} wall seconds ({:.0}x real time)",
        results.outcomes.len(),
        total_sim,
        secs,
        total_sim / secs.max(1e-9)
    );
}
