//! The perf/energy regression sentinel: compare a freshly regenerated
//! `BENCH_*.json` against the committed baseline, metric by metric,
//! with per-metric tolerances and direction-aware verdicts.
//!
//! The sentinel walks both documents with the observability flattener
//! ([`crate::obs::diff::flatten`]) so nested sections (`reference.*`,
//! `micro[...]`, grid rows) compare on stable dotted paths. Each
//! numeric leaf gets a verdict:
//!
//! - **Pass** — within tolerance (default ±25% relative; `micro`
//!   paths get ±50%, timer noise on sub-microsecond samples being what
//!   it is).
//! - **Warn** — a metric *improved* beyond tolerance (verify the gain
//!   is real before celebrating), appeared, disappeared, or moved in a
//!   direction the sentinel cannot rank (unknown metric names are
//!   two-sided).
//! - **Fail** — a metric the sentinel can rank (throughput-like up,
//!   latency-like down) worsened beyond tolerance.
//!
//! One global switch defangs the whole run: while the committed
//! baseline says `"measured": false` (the schema-only seed this repo
//! starts from — no toolchain in the authoring container), every Fail
//! downgrades to Warn, so CI reports drift without gating on numbers
//! nobody has measured yet.

use crate::history::json::{self, Json};
use crate::metrics::Table;
use crate::obs::diff::flatten;
use std::collections::BTreeMap;

/// Which way "better" points for a metric, inferred from its path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Direction {
    /// Bigger is better (throughput, speedups, hit rates).
    HigherBetter,
    /// Smaller is better (wall seconds, latencies).
    LowerBetter,
    /// Unknown name: any large move is a Warn, never a Fail.
    Unknown,
}

/// Infer the ranking direction from a dotted metric path. Checked in
/// order: rate-like markers first so `sim_seconds_per_wall_second`
/// (which also contains `seconds`) ranks as a throughput.
fn direction(path: &str) -> Direction {
    let p = path.to_ascii_lowercase();
    if p.contains("per_wall_second")
        || p.contains("speedup")
        || p.contains("hit_rate")
        || p.ends_with("_bps")
        || p.contains("per_second")
    {
        Direction::HigherBetter
    } else if p.ends_with("_s")
        || p.contains("seconds")
        || p.contains("wall")
        || p.contains("latency")
    {
        Direction::LowerBetter
    } else {
        Direction::Unknown
    }
}

/// Relative tolerance for a path: `micro` benches time sub-microsecond
/// bodies where ±50% is honest noise; everything else gets the default.
fn tolerance_for(path: &str, default_tol: f64) -> f64 {
    if path.contains("micro") {
        default_tol.max(0.5)
    } else {
        default_tol
    }
}

/// Verdict on one metric, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verdict {
    /// Within tolerance (or an exact non-numeric match).
    Pass,
    /// Worth a look, not a gate: large improvement, appeared/vanished,
    /// unrankable drift, or a Fail defanged by an unmeasured baseline.
    Warn,
    /// A rankable metric worsened beyond tolerance on a measured
    /// baseline.
    Fail,
}

impl Verdict {
    /// Stable lowercase label (reports, JSON).
    pub fn label(self) -> &'static str {
        match self {
            Verdict::Pass => "pass",
            Verdict::Warn => "warn",
            Verdict::Fail => "fail",
        }
    }
}

/// One compared metric.
#[derive(Debug, Clone)]
pub struct SentinelRow {
    /// Dotted path into the BENCH document.
    pub path: String,
    /// Baseline value rendered as text (`"null"` when absent).
    pub baseline: String,
    /// Fresh value rendered as text (`"null"` when absent).
    pub fresh: String,
    /// Relative change `(fresh − baseline) / |baseline|` when both
    /// sides are finite numbers and the baseline is non-zero.
    pub rel_change: Option<f64>,
    /// The verdict after tolerance, direction and the measured switch.
    pub verdict: Verdict,
    /// One-phrase reason backing the verdict.
    pub reason: &'static str,
}

/// The sentinel's full comparison of one baseline/fresh pair.
#[derive(Debug, Clone)]
pub struct SentinelReport {
    /// Whether the baseline was a measured record (`"measured": true`);
    /// when false every Fail is downgraded to Warn.
    pub measured: bool,
    /// Metrics that passed (count only — passing rows carry no news).
    pub passed: usize,
    /// Every non-Pass row, sorted by severity then path.
    pub rows: Vec<SentinelRow>,
}

fn leaf_text(v: &Json) -> String {
    match v {
        Json::Null => "null".to_string(),
        Json::Bool(b) => b.to_string(),
        Json::Num(x) => json::num(*x),
        Json::Str(s) => s.clone(),
        _ => String::new(),
    }
}

/// Paths the sentinel never compares: the format stamp, prose, and the
/// measured switch itself (which legitimately flips when CI
/// regenerates a seed).
fn skipped(path: &str) -> bool {
    path == "v" || path == "measured" || path == "note" || path.ends_with(".note")
}

impl SentinelReport {
    /// Compare `fresh` against `baseline`. `default_tol` is the
    /// relative tolerance applied outside `micro` paths (the CLI
    /// default is 0.25).
    pub fn compare(baseline: &Json, fresh: &Json, default_tol: f64) -> SentinelReport {
        let measured =
            baseline.get("measured").and_then(Json::as_bool).unwrap_or(false);
        let mut merged: BTreeMap<String, (Option<Json>, Option<Json>)> = BTreeMap::new();
        for (path, v) in flatten(baseline) {
            merged.entry(path).or_insert((None, None)).0 = Some(v);
        }
        for (path, v) in flatten(fresh) {
            merged.entry(path).or_insert((None, None)).1 = Some(v);
        }
        let mut passed = 0usize;
        let mut rows = Vec::new();
        for (path, (a, b)) in merged {
            if skipped(&path) {
                continue;
            }
            let (verdict, reason, rel) = judge(&path, a.as_ref(), b.as_ref(), default_tol);
            let verdict = match verdict {
                Verdict::Fail if !measured => Verdict::Warn,
                v => v,
            };
            if verdict == Verdict::Pass {
                passed += 1;
                continue;
            }
            rows.push(SentinelRow {
                path,
                baseline: a.as_ref().map(leaf_text).unwrap_or_else(|| "null".to_string()),
                fresh: b.as_ref().map(leaf_text).unwrap_or_else(|| "null".to_string()),
                rel_change: rel,
                verdict,
                reason,
            });
        }
        rows.sort_by(|x, y| y.verdict.cmp(&x.verdict).then_with(|| x.path.cmp(&y.path)));
        SentinelReport { measured, passed, rows }
    }

    /// The most severe verdict in the report (Pass when every metric
    /// passed).
    pub fn worst(&self) -> Verdict {
        self.rows.iter().map(|r| r.verdict).max().unwrap_or(Verdict::Pass)
    }

    /// True when the run should gate (some metric failed).
    pub fn failed(&self) -> bool {
        self.worst() == Verdict::Fail
    }

    /// Markdown report: a verdict summary line plus one table row per
    /// non-Pass metric.
    pub fn to_markdown(&self, label_a: &str, label_b: &str) -> String {
        let mut out = format!(
            "# Sentinel: {} vs {}\n\nVerdict: **{}** — {} passed, {} flagged{}\n",
            label_a,
            label_b,
            self.worst().label(),
            self.passed,
            self.rows.len(),
            if self.measured { "" } else { " (baseline unmeasured: warn-only)" },
        );
        if !self.rows.is_empty() {
            let mut table = Table::new(
                "Flagged metrics",
                &["verdict", "metric", "baseline", "fresh", "rel", "reason"],
            );
            for r in &self.rows {
                table.push_row(vec![
                    r.verdict.label().to_string(),
                    r.path.clone(),
                    r.baseline.clone(),
                    r.fresh.clone(),
                    r.rel_change.map(|x| format!("{:+.1}%", x * 100.0)).unwrap_or_default(),
                    r.reason.to_string(),
                ]);
            }
            out.push('\n');
            out.push_str(&table.to_markdown());
        }
        out
    }

    /// Machine-readable report (kind `greendt-sentinel`).
    pub fn to_json(&self, label_a: &str, label_b: &str) -> String {
        let rows: Vec<String> = self
            .rows
            .iter()
            .map(|r| {
                format!(
                    "{{\"verdict\":\"{}\",\"path\":\"{}\",\"baseline\":\"{}\",\
                     \"fresh\":\"{}\",\"rel_change\":{},\"reason\":\"{}\"}}",
                    r.verdict.label(),
                    json::escape(&r.path),
                    json::escape(&r.baseline),
                    json::escape(&r.fresh),
                    r.rel_change.map(json::num).unwrap_or_else(|| "null".to_string()),
                    json::escape(r.reason),
                )
            })
            .collect();
        format!(
            "{{\"kind\":\"greendt-sentinel\",\"baseline\":\"{}\",\"fresh\":\"{}\",\
             \"verdict\":\"{}\",\"measured\":{},\"passed\":{},\"rows\":[{}]}}",
            json::escape(label_a),
            json::escape(label_b),
            self.worst().label(),
            self.measured,
            self.passed,
            rows.join(","),
        )
    }
}

/// Verdict for one path. Returns `(verdict, reason, rel_change)`
/// *before* the unmeasured-baseline downgrade.
fn judge(
    path: &str,
    a: Option<&Json>,
    b: Option<&Json>,
    default_tol: f64,
) -> (Verdict, &'static str, Option<f64>) {
    match (a, b) {
        (None, None) => (Verdict::Pass, "absent", None),
        (Some(Json::Null), Some(Json::Null)) => (Verdict::Pass, "unmeasured", None),
        (None, Some(_)) | (Some(Json::Null), Some(_)) => {
            (Verdict::Warn, "new metric", None)
        }
        (Some(_), None) | (Some(_), Some(Json::Null)) => {
            (Verdict::Warn, "metric vanished", None)
        }
        (Some(Json::Num(x)), Some(Json::Num(y))) => {
            if !x.is_finite() || !y.is_finite() {
                return (Verdict::Warn, "non-finite value", None);
            }
            if x == y {
                return (Verdict::Pass, "unchanged", Some(0.0));
            }
            if *x == 0.0 {
                return (Verdict::Warn, "baseline zero", None);
            }
            let rel = (y - x) / x.abs();
            let tol = tolerance_for(path, default_tol);
            if rel.abs() <= tol {
                return (Verdict::Pass, "within tolerance", Some(rel));
            }
            match direction(path) {
                Direction::HigherBetter if rel < 0.0 => {
                    (Verdict::Fail, "regressed (lower)", Some(rel))
                }
                Direction::LowerBetter if rel > 0.0 => {
                    (Verdict::Fail, "regressed (higher)", Some(rel))
                }
                Direction::Unknown => (Verdict::Warn, "drifted", Some(rel)),
                _ => (Verdict::Warn, "improved: verify", Some(rel)),
            }
        }
        (Some(x), Some(y)) if x == y => (Verdict::Pass, "unchanged", None),
        _ => (Verdict::Warn, "value changed", None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::history::json::parse;

    fn doc(s: &str) -> Json {
        parse(s).expect("test doc parses")
    }

    #[test]
    fn identical_measured_docs_all_pass() {
        let a = doc(r#"{"bench":"x","measured":true,"speedup":4.0,"wall_seconds":2.0}"#);
        let r = SentinelReport::compare(&a, &a, 0.25);
        assert!(r.measured);
        assert_eq!(r.worst(), Verdict::Pass);
        assert!(r.rows.is_empty());
        assert_eq!(r.passed, 3);
    }

    #[test]
    fn direction_aware_fail_and_improvement_warn() {
        let a = doc(r#"{"measured":true,"speedup":4.0,"wall_seconds":2.0}"#);
        // Speedup halved (regression), wall time halved (improvement —
        // beyond tolerance, so verify-warn rather than silent pass).
        let b = doc(r#"{"measured":true,"speedup":2.0,"wall_seconds":1.0}"#);
        let r = SentinelReport::compare(&a, &b, 0.25);
        assert!(r.failed());
        let speedup = r.rows.iter().find(|x| x.path == "speedup").unwrap();
        assert_eq!(speedup.verdict, Verdict::Fail);
        let wall = r.rows.iter().find(|x| x.path == "wall_seconds").unwrap();
        assert_eq!(wall.verdict, Verdict::Warn);
        assert_eq!(wall.reason, "improved: verify");
    }

    #[test]
    fn unmeasured_baseline_downgrades_fail_to_warn() {
        let a = doc(r#"{"measured":false,"speedup":4.0}"#);
        let b = doc(r#"{"measured":false,"speedup":1.0}"#);
        let r = SentinelReport::compare(&a, &b, 0.25);
        assert!(!r.measured);
        assert!(!r.failed());
        assert_eq!(r.worst(), Verdict::Warn);
    }

    #[test]
    fn null_seed_vs_fresh_numbers_warns_not_fails() {
        // The committed schema-only seed: every metric null. Fresh CI
        // numbers must read as "new metric", never a gate.
        let a = doc(r#"{"measured":false,"speedup":null,"epoch":{"wall_seconds":null}}"#);
        let b = doc(r#"{"measured":true,"speedup":5.1,"epoch":{"wall_seconds":0.8}}"#);
        let r = SentinelReport::compare(&a, &b, 0.25);
        assert_eq!(r.worst(), Verdict::Warn);
        assert!(r.rows.iter().all(|x| x.reason == "new metric"));
    }

    #[test]
    fn micro_paths_get_looser_tolerance() {
        let a = doc(r#"{"measured":true,"micro":[{"name":"tick","mean_s":1.0e-7}]}"#);
        // +40% on a micro timing: inside the ±50% micro band.
        let b = doc(r#"{"measured":true,"micro":[{"name":"tick","mean_s":1.4e-7}]}"#);
        let r = SentinelReport::compare(&a, &b, 0.25);
        assert_eq!(r.worst(), Verdict::Pass, "{:?}", r.rows);
        // The same drift outside micro on a latency-like name fails.
        let a2 = doc(r#"{"measured":true,"wall_seconds":1.0}"#);
        let b2 = doc(r#"{"measured":true,"wall_seconds":1.4}"#);
        assert!(SentinelReport::compare(&a2, &b2, 0.25).failed());
    }

    #[test]
    fn reports_render_and_json_parses() {
        let a = doc(r#"{"measured":true,"speedup":4.0}"#);
        let b = doc(r#"{"measured":true,"speedup":2.0}"#);
        let r = SentinelReport::compare(&a, &b, 0.25);
        let md = r.to_markdown("BENCH_scale.json", "fresh.json");
        assert!(md.contains("**fail**"));
        assert!(md.contains("speedup"));
        let j = parse(&r.to_json("BENCH_scale.json", "fresh.json")).expect("sentinel json");
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("greendt-sentinel"));
        assert_eq!(j.get("verdict").and_then(Json::as_str), Some("fail"));
        assert_eq!(
            j.get("rows").and_then(|r| r.as_arr()).map(|r| r.len()),
            Some(1)
        );
    }

    #[test]
    fn direction_inference_orders_rate_before_seconds() {
        assert_eq!(direction("epoch.sim_seconds_per_wall_second"), Direction::HigherBetter);
        assert_eq!(direction("reference.wall_seconds"), Direction::LowerBetter);
        assert_eq!(direction("grid[h8s64x1].n"), Direction::Unknown);
    }
}
