"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps the state space (link speeds, RTTs, CPU parameters) and
candidate grids; the kernel must agree with `ref.predict_ref` everywhere.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import layout as L
from compile.kernels.energy_model import predict_pallas
from compile.kernels.ref import predict_ref
from compile import model


def run_both(cand, state):
    got = np.asarray(predict_pallas(cand, state, interpret=True))
    want = np.asarray(predict_ref(cand, state))
    return got, want


def assert_match(cand, state):
    got, want = run_both(cand, state)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)


def test_demo_grid_matches():
    assert_match(model.demo_grid(), model.demo_state())


def test_padding_rows_are_infeasible():
    out = np.asarray(model.predict(model.demo_grid(), model.demo_state()))
    # demo_grid pads the tail with zero candidates.
    assert out[-1, L.OUT_TPUT_BPS] == 0.0
    assert out[-1, L.OUT_ENERGY_J] >= 1e29


def test_output_shape_and_dtype():
    out = model.predict(model.demo_grid(), model.demo_state())
    assert out.shape == (L.NUM_CANDIDATES, L.OUT_WIDTH)
    assert out.dtype == jnp.float32


def test_more_cores_never_reduce_throughput():
    state = model.demo_state()
    rows = [(6.0, float(c), 2.0) for c in range(1, 11)]
    rows += [(0.0, 0.0, 0.0)] * (L.NUM_CANDIDATES - len(rows))
    out = np.asarray(model.predict(jnp.asarray(rows, jnp.float32), state))
    tputs = out[:10, L.OUT_TPUT_BPS]
    assert (np.diff(tputs) >= -1e-3).all(), tputs


def test_power_monotone_in_frequency():
    state = model.demo_state()
    freqs = [1.2 + 0.2 * i for i in range(12)]
    rows = [(6.0, 4.0, f) for f in freqs]
    rows += [(0.0, 0.0, 0.0)] * (L.NUM_CANDIDATES - len(rows))
    out = np.asarray(model.predict(jnp.asarray(rows, jnp.float32), state))
    powers = out[: len(freqs), L.OUT_POWER_W]
    assert (np.diff(powers) > 0).all(), powers


def test_energy_has_interior_optimum_under_network_bound():
    # On a 1 Gbps path the CPU is over-provisioned: energy should be
    # minimized at a low-frequency setting, not the highest.
    state = model.demo_state()
    freqs = [1.2 + 0.2 * i for i in range(12)]
    rows = [(6.0, 2.0, f) for f in freqs]
    rows += [(0.0, 0.0, 0.0)] * (L.NUM_CANDIDATES - len(rows))
    out = np.asarray(model.predict(jnp.asarray(rows, jnp.float32), state))
    energies = out[: len(freqs), L.OUT_ENERGY_J]
    assert np.argmin(energies) <= 2, energies


state_strategy = st.fixed_dictionaries(
    {
        "capacity_gbps": st.floats(0.1, 40.0),
        "rtt_ms": st.floats(1.0, 200.0),
        "avg_win_mb": st.floats(0.05, 16.0),
        "gamma": st.floats(0.0, 0.5),
        "floor": st.floats(0.1, 0.9),
        "par": st.integers(1, 16),
        "remaining_gb": st.floats(0.01, 100.0),
        "avg_file_mb": st.floats(0.01, 500.0),
        "pp": st.integers(1, 64),
        "cpb": st.floats(0.5, 8.0),
    }
)


def build_state(p):
    s = np.asarray(model.demo_state()).copy()
    s[L.S_CAPACITY_BPS] = p["capacity_gbps"] * 0.125e9
    s[L.S_RTT_S] = p["rtt_ms"] / 1e3
    s[L.S_AVG_WIN_BYTES] = p["avg_win_mb"] * 1e6
    s[L.S_KNEE_STREAMS] = max(
        s[L.S_CAPACITY_BPS] / max(s[L.S_AVG_WIN_BYTES] / s[L.S_RTT_S], 1.0), 1.0
    )
    s[L.S_OVERLOAD_GAMMA] = p["gamma"]
    s[L.S_OVERLOAD_FLOOR] = p["floor"]
    s[L.S_PARALLELISM] = float(p["par"])
    s[L.S_REMAINING_BYTES] = p["remaining_gb"] * 1e9
    s[L.S_AVG_FILE_BYTES] = p["avg_file_mb"] * 1e6
    s[L.S_PP_LEVEL] = float(p["pp"])
    s[L.S_CYCLES_PER_BYTE] = p["cpb"]
    return jnp.asarray(s, jnp.float32)


@settings(max_examples=40, deadline=None)
@given(p=state_strategy, seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_ref_across_state_space(p, seed):
    rng = np.random.default_rng(seed)
    cand = np.zeros((L.NUM_CANDIDATES, L.CAND_WIDTH), np.float32)
    n = rng.integers(1, L.NUM_CANDIDATES + 1)
    cand[:n, L.CAND_CHANNELS] = rng.integers(1, 49, n)
    cand[:n, L.CAND_CORES] = rng.integers(1, 17, n)
    cand[:n, L.CAND_FREQ_GHZ] = rng.uniform(0.8, 4.0, n)
    assert_match(jnp.asarray(cand), build_state(p))


@settings(max_examples=10, deadline=None)
@given(tiles=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_kernel_handles_any_tile_multiple(tiles, seed):
    # The kernel is shape-polymorphic in TILE multiples even though the AOT
    # artifact pins NUM_CANDIDATES.
    rng = np.random.default_rng(seed)
    n = tiles * L.TILE
    cand = np.zeros((n, L.CAND_WIDTH), np.float32)
    cand[:, L.CAND_CHANNELS] = rng.integers(1, 33, n)
    cand[:, L.CAND_CORES] = rng.integers(1, 9, n)
    cand[:, L.CAND_FREQ_GHZ] = rng.uniform(1.0, 3.6, n)
    got, want = run_both(jnp.asarray(cand), model.demo_state())
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)
    assert got.shape == (n, L.OUT_WIDTH)


def test_non_tile_multiple_rejected():
    cand = jnp.zeros((L.TILE + 1, L.CAND_WIDTH), jnp.float32)
    with pytest.raises(AssertionError):
        predict_pallas(cand, model.demo_state(), interpret=True)
