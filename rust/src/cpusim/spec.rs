//! CPU models and the utilization ↔ throughput coupling.

use crate::units::Freq;

/// Transfer activity that consumes CPU cycles during one interval.
#[derive(Debug, Clone, Copy, Default)]
pub struct CpuDemand {
    /// Application goodput being moved, bytes/s.
    pub bytes_per_sec: f64,
    /// File/chunk requests issued per second (protocol processing).
    pub requests_per_sec: f64,
    /// Open TCP streams (each costs polling/interrupt overhead).
    pub open_streams: f64,
}

/// A CPU model: topology, P-state ladder, and cycle costs.
///
/// Cycle costs are calibrated so that moving 10 Gbps (1.25 GB/s) of TCP
/// traffic costs roughly one fully-loaded modern core at ~3 GHz — the
/// commonly reported "1 GHz per 1 Gbps processed, amortized" rule adjusted
/// for large-segment offload.
#[derive(Debug, Clone)]
pub struct CpuSpec {
    /// Marketing / micro-architecture name, e.g. `"Haswell (client)"`.
    pub name: String,
    /// Physical cores available for hotplugging.
    pub num_cores: u32,
    /// P-state ladder, ascending. Algorithm 3 moves one step at a time.
    pub freq_levels: Vec<Freq>,
    /// Cycles consumed per byte moved (syscall + memcpy + TCP stack).
    pub cycles_per_byte: f64,
    /// Cycles per file/chunk request (metadata, protocol round-trip work).
    pub cycles_per_request: f64,
    /// Cycles per open stream per second (epoll/interrupt housekeeping).
    pub cycles_per_stream_sec: f64,
}

impl CpuSpec {
    /// Bottom of the P-state ladder.
    pub fn min_freq(&self) -> Freq {
        *self.freq_levels.first().expect("non-empty ladder")
    }

    /// Top of the P-state ladder.
    pub fn max_freq(&self) -> Freq {
        *self.freq_levels.last().expect("non-empty ladder")
    }

    /// Total cycle demand per second for the given activity.
    pub fn cycles_demanded(&self, demand: &CpuDemand) -> f64 {
        demand.bytes_per_sec * self.cycles_per_byte
            + demand.requests_per_sec * self.cycles_per_request
            + demand.open_streams * self.cycles_per_stream_sec
    }

    /// Cycle capacity per second at a setting.
    pub fn cycles_capacity(&self, active_cores: u32, freq: Freq) -> f64 {
        active_cores as f64 * freq.as_hz()
    }

    /// CPU load (utilization) in [0, ∞): demand / capacity. Values > 1 mean
    /// the CPU cannot keep up and throughput is being back-pressured.
    pub fn load(&self, demand: &CpuDemand, active_cores: u32, freq: Freq) -> f64 {
        let cap = self.cycles_capacity(active_cores, freq);
        if cap <= 0.0 {
            return f64::INFINITY;
        }
        self.cycles_demanded(demand) / cap
    }

    /// The highest goodput (bytes/s) the CPU can sustain at a setting given
    /// fixed request/stream overheads — the inversion of [`Self::load`] at
    /// load = `max_utilization`.
    ///
    /// `max_utilization` < 1.0 reflects that transfer threads never get
    /// 100% of the machine (kernel, interrupts, the tuning process itself).
    pub fn achievable_bytes_per_sec(
        &self,
        active_cores: u32,
        freq: Freq,
        requests_per_sec: f64,
        open_streams: f64,
        max_utilization: f64,
    ) -> f64 {
        let cap = self.cycles_capacity(active_cores, freq) * max_utilization;
        let overhead = requests_per_sec * self.cycles_per_request
            + open_streams * self.cycles_per_stream_sec;
        ((cap - overhead) / self.cycles_per_byte).max(0.0)
    }
}

/// The paper's CPU models (Table I column "CPU architecture").
pub mod standard {
    use super::CpuSpec;
    use crate::units::Freq;

    fn ladder(min_ghz: f64, max_ghz: f64, step_ghz: f64) -> Vec<Freq> {
        let mut v = Vec::new();
        let mut f = min_ghz;
        while f <= max_ghz + 1e-9 {
            v.push(Freq::from_ghz((f * 10.0).round() / 10.0));
            f += step_ghz;
        }
        v
    }

    /// Haswell-EP server (Chameleon + CloudLab servers, DIDCLab server):
    /// 8 cores, 1.2–3.5 GHz.
    pub fn haswell_server() -> CpuSpec {
        CpuSpec {
            name: "Haswell (server)".into(),
            num_cores: 8,
            freq_levels: ladder(1.2, 3.5, 0.2),
            cycles_per_byte: 2.4,
            cycles_per_request: 12_000.0,
            cycles_per_stream_sec: 1.5e6,
        }
    }

    /// Haswell client (Chameleon client): 8 cores, 1.2–3.5 GHz.
    pub fn haswell_client() -> CpuSpec {
        CpuSpec { name: "Haswell (client)".into(), ..haswell_server() }
    }

    /// Broadwell client (CloudLab client): 10 cores, 1.2–3.4 GHz, slightly
    /// better per-byte efficiency than Haswell.
    pub fn broadwell_client() -> CpuSpec {
        CpuSpec {
            name: "Broadwell (client)".into(),
            num_cores: 10,
            freq_levels: ladder(1.2, 3.4, 0.2),
            cycles_per_byte: 2.2,
            cycles_per_request: 11_000.0,
            cycles_per_stream_sec: 1.4e6,
        }
    }

    /// Bloomfield client (DIDCLab client): 4 cores, 1.6–3.2 GHz, an older
    /// Nehalem-era part with a higher per-byte cost.
    pub fn bloomfield_client() -> CpuSpec {
        CpuSpec {
            name: "Bloomfield (client)".into(),
            num_cores: 4,
            freq_levels: ladder(1.6, 3.2, 0.2),
            cycles_per_byte: 3.2,
            cycles_per_request: 16_000.0,
            cycles_per_stream_sec: 2.0e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::standard::*;
    use super::*;

    #[test]
    fn ladders_are_ascending_and_bounded() {
        for spec in [haswell_server(), broadwell_client(), bloomfield_client()] {
            assert!(spec.freq_levels.len() >= 5, "{}", spec.name);
            for w in spec.freq_levels.windows(2) {
                assert!(w[0] < w[1], "{} ladder must ascend", spec.name);
            }
            assert_eq!(spec.min_freq(), spec.freq_levels[0]);
            assert_eq!(spec.max_freq(), *spec.freq_levels.last().unwrap());
        }
    }

    #[test]
    fn ten_gbps_needs_about_one_fast_core() {
        let spec = haswell_server();
        let demand = CpuDemand { bytes_per_sec: 1.25e9, requests_per_sec: 10.0, open_streams: 8.0 };
        let load = spec.load(&demand, 1, Freq::from_ghz(3.5));
        assert!(load > 0.8 && load < 1.1, "load {load}");
    }

    #[test]
    fn one_gbps_fits_min_freq_single_core() {
        let spec = haswell_server();
        let demand = CpuDemand { bytes_per_sec: 0.125e9, requests_per_sec: 20.0, open_streams: 4.0 };
        let load = spec.load(&demand, 1, spec.min_freq());
        assert!(load < 0.5, "load {load} — 1 Gbps should be cheap at min freq");
    }

    #[test]
    fn load_scales_inversely_with_cores_and_freq() {
        let spec = haswell_server();
        let demand = CpuDemand { bytes_per_sec: 1e9, requests_per_sec: 0.0, open_streams: 0.0 };
        let l1 = spec.load(&demand, 1, Freq::from_ghz(2.0));
        let l2 = spec.load(&demand, 2, Freq::from_ghz(2.0));
        let l4 = spec.load(&demand, 1, Freq::from_ghz(4.0));
        assert!((l1 / l2 - 2.0).abs() < 1e-9);
        assert!((l1 / l4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn achievable_inverts_load() {
        let spec = haswell_server();
        let bps = spec.achievable_bytes_per_sec(2, Freq::from_ghz(2.0), 50.0, 16.0, 0.9);
        let demand = CpuDemand { bytes_per_sec: bps, requests_per_sec: 50.0, open_streams: 16.0 };
        let load = spec.load(&demand, 2, Freq::from_ghz(2.0));
        assert!((load - 0.9).abs() < 1e-9, "load {load}");
    }

    #[test]
    fn achievable_never_negative() {
        let spec = bloomfield_client();
        let bps = spec.achievable_bytes_per_sec(1, spec.min_freq(), 1e9, 1e6, 0.9);
        assert_eq!(bps, 0.0);
    }

    #[test]
    fn zero_capacity_is_infinite_load() {
        let spec = haswell_server();
        let demand = CpuDemand { bytes_per_sec: 1.0, ..Default::default() };
        assert!(spec.load(&demand, 0, Freq::ZERO).is_infinite());
    }
}
