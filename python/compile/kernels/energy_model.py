"""Layer-1 Pallas kernel: candidate-grid energy/throughput scoring.

The Rust coordinator's predictive governor evaluates, at every tuning
timeout, a grid of (channels, cores, frequency) operating points against
the analytic transfer model. This kernel is that evaluation, tiled along
the candidate axis so each block fits comfortably in VMEM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid dimension iterates
TILE-row blocks of the candidate matrix; the `state` vector is small and
replicated to every block (`index_map` pins it to block 0). All math is
elementwise f32 — VPU work, no MXU — so the natural layout is (TILE, 3)
blocks streamed HBM→VMEM. `interpret=True` is mandatory on this CPU-only
image; on a real TPU the same kernel lowers through Mosaic unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import layout as L

EPS = 1e-9
INFEASIBLE_ENERGY = 1e30


def _predict_kernel(cand_ref, state_ref, out_ref):
    """One TILE-row block of the candidate grid."""
    cand = cand_ref[...]
    state = state_ref[...]

    channels = cand[:, L.CAND_CHANNELS]
    cores = cand[:, L.CAND_CORES]
    freq = cand[:, L.CAND_FREQ_GHZ]

    capacity = state[L.S_CAPACITY_BPS]
    rtt = state[L.S_RTT_S]
    avg_win = state[L.S_AVG_WIN_BYTES]
    knee = state[L.S_KNEE_STREAMS]
    gamma = state[L.S_OVERLOAD_GAMMA]
    floor = state[L.S_OVERLOAD_FLOOR]
    par = state[L.S_PARALLELISM]
    remaining = state[L.S_REMAINING_BYTES]
    avg_file = state[L.S_AVG_FILE_BYTES]
    pp = state[L.S_PP_LEVEL]
    cpb = state[L.S_CYCLES_PER_BYTE]
    cpr = state[L.S_CYCLES_PER_REQ]
    cps = state[L.S_CYCLES_PER_STREAM]
    max_util = state[L.S_MAX_APP_UTIL]

    # Network: window-limited aggregate with overload penalty.
    streams = channels * par
    win_rate = avg_win / jnp.maximum(rtt, EPS)
    over = jnp.maximum(streams - knee, 0.0) / jnp.maximum(knee, EPS)
    penalty = jnp.maximum(1.0 / (1.0 + gamma * over), floor)
    net = jnp.minimum(streams * win_rate, capacity * penalty)

    # Pipelining pacing.
    r_chan = net / jnp.maximum(channels, EPS)
    xfer = avg_file / jnp.maximum(r_chan, EPS)
    paced = jnp.maximum(xfer, rtt / jnp.maximum(pp, 1.0))
    eff = xfer / jnp.maximum(paced, EPS)
    net_eff = net * eff

    # CPU ceiling.
    cap_cycles = cores * freq * 1e9 * max_util
    req_rate_net = net_eff / jnp.maximum(avg_file, EPS)
    overhead = req_rate_net * cpr + streams * cps
    cpu_bytes = jnp.maximum(cap_cycles - overhead, 0.0) / jnp.maximum(cpb, EPS)
    tput = jnp.minimum(net_eff, cpu_bytes)

    # Utilization at the achieved rate.
    req_rate = tput / jnp.maximum(avg_file, EPS)
    demand = tput * cpb + req_rate * cpr + streams * cps
    cap_full = cores * freq * 1e9
    load = demand / jnp.maximum(cap_full, EPS)
    util = jnp.clip(load, 0.0, 1.0)

    # Package power.
    v_min = state[L.S_V_MIN]
    v_max = state[L.S_V_MAX]
    f_min = state[L.S_F_MIN_GHZ]
    f_max = state[L.S_F_MAX_GHZ]
    t = jnp.clip((freq - f_min) / jnp.maximum(f_max - f_min, EPS), 0.0, 1.0)
    v = v_min + (v_max - v_min) * t
    per_core_idle = (
        state[L.S_CORE_IDLE_BASE_W] + state[L.S_CORE_IDLE_PER_GHZ_W] * freq
    )
    per_core_dyn = util * state[L.S_DYN_KAPPA] * v * v * freq
    dram = state[L.S_DRAM_W_PER_GBS] * tput / 1e9
    power = state[L.S_PKG_STATIC_W] + cores * (per_core_idle + per_core_dyn) + dram

    feasible = tput > EPS
    energy = jnp.where(
        feasible, power * remaining / jnp.maximum(tput, EPS), INFEASIBLE_ENERGY
    )
    tput = jnp.where(feasible, tput, 0.0)

    out_ref[...] = jnp.stack([tput, power, energy], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def predict_pallas(cand, state, *, interpret=True):
    """Pallas-tiled candidate evaluation.

    `cand` must have a row count divisible by `layout.TILE` (the AOT entry
    point fixes it at `layout.NUM_CANDIDATES`); `state` is broadcast to
    every tile.
    """
    cand = jnp.asarray(cand, jnp.float32)
    state = jnp.asarray(state, jnp.float32)
    n = cand.shape[0]
    assert n % L.TILE == 0, f"candidate rows {n} not a multiple of {L.TILE}"
    grid = (n // L.TILE,)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L.TILE, L.CAND_WIDTH), lambda i: (i, 0)),
            # The state vector is replicated: every tile reads block 0.
            pl.BlockSpec((L.STATE_WIDTH,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((L.TILE, L.OUT_WIDTH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, L.OUT_WIDTH), jnp.float32),
        interpret=interpret,
    )(cand, state)
