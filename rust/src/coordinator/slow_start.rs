//! Algorithm 2 — the Slow Start correction phase.
//!
//! Algorithm 1's channel estimate is built from `avgWinSize / RTT`, which
//! can be off when the path is shared or the window estimate is stale.
//! After the first timeout(s), Slow Start measures the real throughput and
//! rescales the channel count by `bandwidth / lastThroughput`, then
//! redistributes channels over datasets by weight.

use crate::sim::Telemetry;
use crate::transfer::TransferEngine;
use crate::units::Rate;

/// Slow-start controller state.
#[derive(Debug, Clone)]
pub struct SlowStart {
    /// Nominal path bandwidth (the rescaling target).
    bandwidth: Rate,
    /// Cap on the channel count after rescaling.
    max_channels: u32,
    /// Correction rounds left before handing over to the main FSM.
    rounds_left: u32,
}

impl SlowStart {
    /// `rounds` correction timeouts (the paper uses a short phase; 2 keeps
    /// one re-measurement after the first correction).
    pub fn new(bandwidth: Rate, max_channels: u32, rounds: u32) -> Self {
        SlowStart { bandwidth, max_channels, rounds_left: rounds.max(1) }
    }

    /// True once every correction round has run.
    pub fn done(&self) -> bool {
        self.rounds_left == 0
    }

    /// One Slow Start timeout (Alg. 2 body). Returns `true` if the phase
    /// is finished after this call.
    pub fn on_timeout(&mut self, telemetry: &Telemetry, engine: &mut TransferEngine) -> bool {
        if self.rounds_left == 0 {
            return true;
        }
        self.rounds_left -= 1;

        let measured = telemetry.avg_throughput;
        if !measured.is_zero() {
            // numCh *= bandwidth / lastThroughput  (line 3)
            let factor = self.bandwidth / measured;
            // Keep the correction sane: the first interval still contains
            // TCP slow-start ramp, which understates steady throughput.
            let factor = factor.clamp(0.25, 8.0);
            let current = engine.num_channels().max(1);
            let target =
                ((current as f64 * factor).round() as u32).clamp(1, self.max_channels);
            // updateWeights + redistribute (lines 4–8).
            engine.update_weights();
            engine.set_num_channels(target);
        }
        // Early exit: measured throughput already close to the bandwidth.
        if measured.as_bits_per_sec() >= 0.85 * self.bandwidth.as_bits_per_sec() {
            self.rounds_left = 0;
        }
        self.rounds_left == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::cpusim::CpuState;
    use crate::dataset::{partition_files, standard};
    use crate::sim::Simulation;
    use crate::transfer::TransferEngine;
    use crate::units::SimDuration;

    fn sim_with_channels(n: u32) -> Simulation {
        let tb = testbeds::cloudlab();
        let ds = standard::medium_dataset(1);
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(n);
        Simulation::new(
            &tb,
            engine,
            CpuState::performance(tb.client_cpu.clone()),
            SimDuration::from_millis(100.0),
            1,
        )
    }

    #[test]
    fn underestimation_is_corrected_upward() {
        let mut sim = sim_with_channels(1);
        // Warm up for one interval with a single channel (~220 Mbps).
        for _ in 0..30 {
            sim.step();
        }
        let tel = sim.drain_telemetry();
        let mut ss = SlowStart::new(Rate::from_gbps(1.0), 32, 2);
        ss.on_timeout(&tel, sim.engine_mut());
        assert!(
            sim.engine().num_channels() >= 3,
            "should scale up from 1, got {}",
            sim.engine().num_channels()
        );
    }

    #[test]
    fn saturated_measurement_ends_phase_early() {
        let mut sim = sim_with_channels(6);
        for _ in 0..60 {
            sim.step();
        }
        let tel = sim.drain_telemetry();
        let mut ss = SlowStart::new(Rate::from_gbps(1.0), 32, 3);
        let done = ss.on_timeout(&tel, sim.engine_mut());
        assert!(done, "already ≥85% of bandwidth → phase over");
    }

    #[test]
    fn rounds_are_bounded() {
        let mut sim = sim_with_channels(2);
        let mut ss = SlowStart::new(Rate::from_gbps(1.0), 32, 2);
        let mut finished = false;
        for _ in 0..5 {
            for _ in 0..30 {
                sim.step();
            }
            let tel = sim.drain_telemetry();
            if ss.on_timeout(&tel, sim.engine_mut()) {
                finished = true;
                break;
            }
        }
        assert!(finished, "slow start must terminate");
    }

    #[test]
    fn zero_throughput_does_not_panic_or_change() {
        let mut sim = sim_with_channels(4);
        let tel = sim.drain_telemetry(); // empty interval, zero throughput
        let before = sim.engine().num_channels();
        let mut ss = SlowStart::new(Rate::from_gbps(1.0), 32, 1);
        ss.on_timeout(&tel, sim.engine_mut());
        assert_eq!(sim.engine().num_channels(), before);
    }
}
