//! L3 hot-path micro-benchmarks: the per-tick simulation loop.
//!
//!     cargo bench --bench bench_hotpath
//!
//! These are the quantities the §Perf pass optimizes: goodput allocation
//! (`share_goodput`), whole-world tick cost at realistic stream counts,
//! channel redistribution, and end-to-end session rate (simulated
//! seconds per wall second).

use greendt::benchkit::{bench, time_once};
use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::cpusim::CpuState;
use greendt::dataset::{partition_files_capped, standard};
use greendt::netsim::{share_goodput, StreamState};
use greendt::sim::session::{run_session, SessionConfig};
use greendt::sim::Simulation;
use greendt::transfer::TransferEngine;
use greendt::units::SimDuration;

fn main() {
    println!("== bench_hotpath: simulation hot loop ==\n");

    // share_goodput at various stream counts.
    let tb = testbeds::cloudlab();
    for n in [4usize, 16, 64, 256] {
        let link = tb.make_link_constant_bg();
        let streams: Vec<StreamState> =
            (0..n).map(|_| StreamState::warm(tb.link.avg_win)).collect();
        bench(&format!("share_goodput/{n} streams"), 100, 2000, || {
            share_goodput(&link, &streams)
        });
    }
    println!();

    // Whole-world step at mixed-dataset scale.
    for channels in [4u32, 16, 48] {
        let ds = standard::mixed_dataset(7);
        let parts = partition_files_capped(&ds, tb.bdp(), 5);
        let mut engine = TransferEngine::with_knee(&parts, tb.link.avg_win, tb.link.knee_streams());
        engine.set_num_channels(channels);
        let mut sim = Simulation::new(
            &tb,
            engine,
            CpuState::performance(tb.client_cpu.clone()),
            SimDuration::from_millis(100.0),
            9,
        );
        bench(&format!("simulation step/{channels} channels"), 200, 5000, || sim.step());
    }
    println!();

    // Channel redistribution.
    let ds = standard::mixed_dataset(7);
    let parts = partition_files_capped(&ds, tb.bdp(), 5);
    let mut engine = TransferEngine::with_knee(&parts, tb.link.avg_win, tb.link.knee_streams());
    let mut n = 4u32;
    bench("set_num_channels (4<->24)", 100, 2000, || {
        n = if n == 4 { 24 } else { 4 };
        engine.update_weights();
        engine.set_num_channels(n);
    });
    println!();

    // End-to-end session rate.
    let cfg = SessionConfig::new(
        testbeds::chameleon(),
        standard::mixed_dataset(42),
        AlgorithmKind::MaxThroughput,
    );
    let (out, secs) = time_once("EEMT session chameleon/mixed", || run_session(&cfg));
    println!(
        "  simulated {:.0}s in {:.3}s wall => {:.0}x real time",
        out.duration.as_secs(),
        secs,
        out.duration.as_secs() / secs.max(1e-9)
    );
}
