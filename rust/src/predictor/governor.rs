//! The predictive governor — GreenDT's model-driven alternative to the
//! paper's threshold-based Algorithm 3.
//!
//! Every timeout it evaluates the full (cores × P-state) grid at the
//! current channel count through the compiled JAX/Pallas predictor and
//! jumps straight to the best operating point for the SLA, instead of
//! stepping one level at a time. The ablation bench
//! (`cargo bench --bench bench_predictor`) compares the two policies.

use super::{cpu_grid, Predictor};
use crate::coordinator::load_control::Governor;
use crate::cpusim::CpuState;
use crate::power::standard_power;
use crate::sim::Telemetry;
use crate::units::Freq;

/// What "best" means for the SLA being served.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PredictMode {
    /// Minimize projected energy to completion.
    MinEnergy,
    /// Maximize throughput; break ties on power.
    MaxThroughput,
    /// Cheapest point that sustains the target (bytes/s); if none can,
    /// fall back to the fastest.
    Target(f64),
}

#[derive(Debug)]
/// Governor that jumps to the predictor's best operating point.
pub struct PredictiveGovernor {
    predictor: Predictor,
    mode: PredictMode,
}

impl PredictiveGovernor {
    /// A governor over an explicit predictor backend.
    pub fn new(predictor: Predictor, mode: PredictMode) -> Self {
        PredictiveGovernor { predictor, mode }
    }

    /// Production constructor: artifact from `GREENDT_PREDICTOR` (default
    /// `artifacts/predictor.hlo.txt`), oracle fallback.
    pub fn from_env(mode: PredictMode) -> Self {
        PredictiveGovernor { predictor: Predictor::load_or_oracle(), mode }
    }

    /// True when the compiled PJRT backend is live.
    pub fn is_pjrt(&self) -> bool {
        self.predictor.is_pjrt()
    }

    /// The SLA objective being served.
    pub fn mode(&self) -> PredictMode {
        self.mode
    }
}

impl Governor for PredictiveGovernor {
    fn control(&mut self, telemetry: &Telemetry, cpu: &mut CpuState) {
        // Nothing to decide before any data has moved.
        if telemetry.net.avg_file_bytes <= 0.0 || telemetry.remaining.is_zero() {
            return;
        }
        let power = standard_power(cpu.spec());
        let state = super::build_state(telemetry, &power);
        let cands = cpu_grid(cpu.spec(), telemetry.num_channels.max(1));
        let preds = match self.predictor.predict(&cands, &state) {
            Ok(p) => p,
            Err(e) => {
                log::warn!("predictive governor evaluation failed: {e:#}");
                return;
            }
        };

        let mut best: Option<(usize, f64)> = None;
        for (i, p) in preds.iter().enumerate() {
            let score = match self.mode {
                PredictMode::MinEnergy => -p.energy_j,
                PredictMode::MaxThroughput => p.tput_bps * 1e3 - p.power_w,
                PredictMode::Target(target) => {
                    if p.tput_bps + 1e-6 >= target {
                        1e18 - p.energy_j // feasible: cheapest wins
                    } else {
                        p.tput_bps // infeasible: fastest wins
                    }
                }
            };
            if best.map(|(_, s)| score > s).unwrap_or(true) {
                best = Some((i, score));
            }
        }
        if let Some((i, _)) = best {
            let c = cands[i];
            cpu.apply(c.cores as u32, Freq::from_ghz(c.freq_ghz as f64));
        }
    }

    fn name(&self) -> &'static str {
        "predictive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpusim::standard::broadwell_client;
    use crate::sim::NetView;
    use crate::units::{Bytes, Energy, Power, Rate, SimDuration, SimTime};

    fn cloudlab_tel(channels: u32) -> Telemetry {
        Telemetry {
            now: SimTime::from_secs(10.0),
            avg_throughput: Rate::from_mbps(900.0),
            interval_energy: Energy::from_joules(50.0),
            avg_power: Power::from_watts(25.0),
            cpu_load: 0.2,
            remaining: Bytes::from_gb(10.0),
            total: Bytes::from_gb(12.0),
            elapsed: SimDuration::from_secs(10.0),
            num_channels: channels,
            open_streams: channels as usize,
            net: NetView {
                available_bps: 115e6,
                rtt_s: 0.036,
                avg_win_bytes: 1e6,
                knee_streams: 4.5,
                overload_gamma: 0.02,
                overload_floor: 0.55,
                parallelism: 1.0,
                avg_file_bytes: 2.4e6,
                pp_level: 2.0,
            },
        }
    }

    #[test]
    fn min_energy_mode_downscales_on_slow_network() {
        // 1 Gbps path, 10-core Broadwell: the grid's energy optimum is a
        // small low-frequency configuration, not the performance governor.
        let mut g = PredictiveGovernor::new(Predictor::oracle(), PredictMode::MinEnergy);
        let mut cpu = CpuState::performance(broadwell_client());
        g.control(&cloudlab_tel(6), &mut cpu);
        assert!(cpu.active_cores() <= 3, "cores {}", cpu.active_cores());
        assert!(cpu.freq().as_ghz() <= 2.0, "freq {}", cpu.freq());
    }

    #[test]
    fn max_throughput_mode_keeps_enough_capacity() {
        let mut g = PredictiveGovernor::new(Predictor::oracle(), PredictMode::MaxThroughput);
        let mut cpu = CpuState::min_energy_start(broadwell_client());
        g.control(&cloudlab_tel(6), &mut cpu);
        // 1 Gbps needs well under one fast core; whatever is chosen must
        // sustain the network-bound throughput.
        let spec = cpu.spec().clone();
        let cap = spec.achievable_bytes_per_sec(
            cpu.active_cores(),
            cpu.freq(),
            60.0,
            6.0,
            crate::sim::MAX_APP_UTILIZATION,
        );
        assert!(cap >= 110e6, "cap {cap}");
    }

    #[test]
    fn target_mode_prefers_cheapest_feasible() {
        let mut g =
            PredictiveGovernor::new(Predictor::oracle(), PredictMode::Target(50e6));
        let mut cpu = CpuState::performance(broadwell_client());
        g.control(&cloudlab_tel(2), &mut cpu);
        assert!(
            cpu.active_cores() <= 2 && cpu.freq().as_ghz() <= 2.0,
            "target mode should pick a small point: {} cores @ {}",
            cpu.active_cores(),
            cpu.freq()
        );
    }

    #[test]
    fn empty_interval_is_a_noop() {
        let mut g = PredictiveGovernor::new(Predictor::oracle(), PredictMode::MinEnergy);
        let mut cpu = CpuState::performance(broadwell_client());
        let mut tel = cloudlab_tel(4);
        tel.net.avg_file_bytes = 0.0;
        let before = (cpu.active_cores(), cpu.freq());
        g.control(&tel, &mut cpu);
        assert_eq!(before, (cpu.active_cores(), cpu.freq()));
    }
}
