//! Sampling distributions over [`Xoshiro256`].

use super::Xoshiro256;

/// A samplable distribution over `f64`.
pub trait Distribution {
    /// Draw one sample.
    fn sample(&self, rng: &mut Xoshiro256) -> f64;
}

/// Uniform over [lo, hi).
#[derive(Debug, Clone, Copy)]
pub struct Uniform {
    /// Inclusive lower bound.
    pub lo: f64,
    /// Exclusive upper bound.
    pub hi: f64,
}

impl Uniform {
    /// Uniform over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo, "Uniform requires hi >= lo");
        Uniform { lo, hi }
    }
}

impl Distribution for Uniform {
    fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
}

/// Normal(mean, std) via Marsaglia's polar method.
#[derive(Debug, Clone, Copy)]
pub struct Normal {
    /// Mean of the distribution.
    pub mean: f64,
    /// Standard deviation.
    pub std: f64,
}

impl Normal {
    /// Normal with the given mean and standard deviation.
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0, "Normal requires std >= 0");
        Normal { mean, std }
    }
}

impl Distribution for Normal {
    fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        // Polar Box-Muller; draw pairs until inside the unit circle.
        loop {
            let u = 2.0 * rng.next_f64() - 1.0;
            let v = 2.0 * rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let z = u * (-2.0 * s.ln() / s).sqrt();
                return self.mean + self.std * z;
            }
        }
    }
}

/// LogNormal parameterized by the **target** mean and std of the samples
/// (not of the underlying normal), matching how Table II reports datasets
/// (avg file size + std dev).
#[derive(Debug, Clone, Copy)]
pub struct LogNormal {
    mu: f64,
    sigma: f64,
}

impl LogNormal {
    /// Build from desired sample mean `m` and standard deviation `s`.
    pub fn from_mean_std(m: f64, s: f64) -> Self {
        assert!(m > 0.0, "LogNormal mean must be positive");
        let v = s * s;
        let sigma2 = (1.0 + v / (m * m)).ln();
        let mu = m.ln() - sigma2 / 2.0;
        LogNormal { mu, sigma: sigma2.sqrt() }
    }

    /// µ of the underlying normal.
    pub fn mu(&self) -> f64 {
        self.mu
    }

    /// σ of the underlying normal.
    pub fn sigma(&self) -> f64 {
        self.sigma
    }
}

impl Distribution for LogNormal {
    fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        let n = Normal::new(self.mu, self.sigma).sample(rng);
        n.exp()
    }
}

/// Exponential with rate `lambda` (mean 1/lambda). Used for event
/// inter-arrival times in the background-traffic process.
#[derive(Debug, Clone, Copy)]
pub struct Exponential {
    /// Rate parameter (mean `1/lambda`).
    pub lambda: f64,
}

impl Exponential {
    /// Exponential with rate `lambda`.
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0, "Exponential requires lambda > 0");
        Exponential { lambda }
    }
}

impl Distribution for Exponential {
    fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        // Inverse CDF; guard against ln(0).
        let u = (1.0 - rng.next_f64()).max(f64::MIN_POSITIVE);
        -u.ln() / self.lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(samples: &[f64]) -> (f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var.sqrt())
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Xoshiro256::seeded(1);
        let d = Uniform::new(2.0, 6.0);
        let xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| (2.0..6.0).contains(&x)));
        let (mean, _) = stats(&xs);
        assert!((mean - 4.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn normal_mean_std() {
        let mut rng = Xoshiro256::seeded(2);
        let d = Normal::new(10.0, 3.0);
        let xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        let (mean, std) = stats(&xs);
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
        assert!((std - 3.0).abs() < 0.05, "std {std}");
    }

    #[test]
    fn lognormal_matches_table2_small_files() {
        // Table II small files: avg 101.92 KB, std 29.06 KB.
        let mut rng = Xoshiro256::seeded(3);
        let d = LogNormal::from_mean_std(101.92e3, 29.06e3);
        let xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        let (mean, std) = stats(&xs);
        assert!((mean / 101.92e3 - 1.0).abs() < 0.02, "mean {mean}");
        assert!((std / 29.06e3 - 1.0).abs() < 0.05, "std {std}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Xoshiro256::seeded(4);
        let d = Exponential::new(0.5); // mean 2.0
        let xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        let (mean, _) = stats(&xs);
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }
}
