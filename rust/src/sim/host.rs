//! The shared client end system: CPU settings, power models and meters.
//!
//! A [`Host`] is the machine every transfer session on a node contends
//! for. It owns both end-system CPU settings (the tunable client and the
//! performance-pinned server), the power models that map operating points
//! to watts, and the energy instruments (RAPL package meters plus the
//! wall-socket node meter). A single-session world holds one `Host` and
//! one slot; a fleet world holds one `Host` and N slots that split its
//! capacity — see [`super::Simulation`].

use crate::config::Testbed;
use crate::coordinator::load_control::LoadThresholds;
use crate::cpusim::{CpuDemand, CpuState};
use crate::power::{standard_power, NodeMeter, OpPointPower, PowerModel, RaplMeter};
use crate::units::{Bytes, Energy, Power, Rate, SimDuration, SimTime};

/// Fraction of CPU capacity the transfer application can actually use
/// (kernel, interrupts and the tuner itself take the rest). Re-exported
/// as `crate::sim::MAX_APP_UTILIZATION`.
pub const MAX_APP_UTILIZATION: f64 = 0.92;

/// Everything one tick of host accounting produced.
#[derive(Debug, Clone, Copy, Default)]
pub struct HostTick {
    /// Client CPU load implied by the aggregate demand (0..∞).
    pub client_load: f64,
    /// Server CPU load implied by the aggregate demand.
    pub server_load: f64,
    /// Client package power this tick.
    pub client_power: Power,
    /// Server package power this tick.
    pub server_power: Power,
    /// Energy this tick on the testbed's client instrument (wall meter on
    /// DIDCLab, RAPL elsewhere), in joules.
    pub instrument_energy_j: f64,
    /// Client package (RAPL) energy this tick, in joules.
    pub package_energy_j: f64,
}

/// Aggregate host-level observations over one fleet arbitration interval —
/// what a [`crate::coordinator::fleet::FleetPolicy`] reads.
#[derive(Debug, Clone, Copy)]
pub struct FleetView {
    /// When the interval ended.
    pub now: SimTime,
    /// Sessions currently admitted and unfinished.
    pub active_sessions: u32,
    /// Mean client CPU load over the interval.
    pub avg_load: f64,
    /// Mean server CPU load over the interval.
    pub avg_server_load: f64,
    /// Aggregate application throughput over the interval.
    pub avg_throughput: Rate,
    /// Mean client power (instrument) over the interval.
    pub avg_power: Power,
}

/// Per-tick quantities that depend only on a CPU's (cores, frequency)
/// operating point. Settings move at tuning/arbitration timeouts —
/// thousands of ticks apart — while these subexpressions were being
/// re-derived every tick; the cache is keyed by (cores, P-state index)
/// and rebuilt lazily when the setting moves. All cached values are the
/// identical subexpressions the uncached formulas compute, so results
/// are bit-for-bit unchanged (pinned by `op_point_cache_matches_fresh_
/// computation` below).
#[derive(Debug, Clone)]
struct OpPointCache {
    key: (u32, usize),
    /// `CpuSpec::cycles_capacity(cores, freq)` — `load`'s denominator.
    cap_cycles: f64,
    /// `cycles_capacity × MAX_APP_UTILIZATION` — the budget inside
    /// `CpuSpec::achievable_bytes_per_sec`.
    cap_cycles_util: f64,
    power: OpPointPower,
}

impl OpPointCache {
    fn build(state: &CpuState, model: &PowerModel) -> OpPointCache {
        let cores = state.active_cores();
        let f = state.freq();
        let cap = state.spec().cycles_capacity(cores, f);
        OpPointCache {
            key: (cores, state.freq_index()),
            cap_cycles: cap,
            cap_cycles_util: cap * MAX_APP_UTILIZATION,
            power: model.at(cores, f),
        }
    }

    /// `CpuSpec::load` with the capacity denominator cached.
    fn load(&self, state: &CpuState, demand: &CpuDemand) -> f64 {
        if self.cap_cycles <= 0.0 {
            return f64::INFINITY;
        }
        state.spec().cycles_demanded(demand) / self.cap_cycles
    }

    /// `CpuSpec::achievable_bytes_per_sec` at `MAX_APP_UTILIZATION` with
    /// the derated cycle budget cached.
    fn achievable(&self, state: &CpuState, requests_per_sec: f64, open_streams: f64) -> f64 {
        let spec = state.spec();
        let overhead = requests_per_sec * spec.cycles_per_request
            + open_streams * spec.cycles_per_stream_sec;
        ((self.cap_cycles_util - overhead) / spec.cycles_per_byte).max(0.0)
    }
}

/// A client CPU operating point chosen by [`Host::min_client_power_for`]:
/// the cheapest (cores, frequency) able to carry a projected demand, and
/// the package power it would draw there.
#[derive(Debug, Clone, Copy)]
pub struct ProjectedPoint {
    /// Client package power at this point under the projected demand.
    pub power: Power,
    /// Active cores at the chosen point.
    pub cores: u32,
    /// Core frequency at the chosen point.
    pub freq: crate::units::Freq,
}

/// The shared client machine (plus its peer server) that all sessions of
/// one simulated world run on.
#[derive(Debug, Clone)]
pub struct Host {
    /// Client CPU setting — the knob tuning algorithms / fleet policies
    /// actuate.
    pub client: CpuState,
    /// Server CPU setting — pinned to the performance governor (the paper:
    /// "there is no frequency scaling on the server") unless
    /// [`Self::server_autoscale`] is enabled.
    pub server: CpuState,
    client_power: PowerModel,
    server_power: PowerModel,
    /// RAPL package meter on the client.
    pub client_rapl: RaplMeter,
    /// Wall meter on the client (package + platform base).
    pub client_node: NodeMeter,
    /// RAPL package meter on the server.
    pub server_rapl: RaplMeter,
    /// Whether this testbed reports client energy from the wall meter.
    wall_meter: bool,
    /// GreenDT extension (the paper leaves the server unscaled): when
    /// enabled, an Algorithm-3 threshold policy also drives the server's
    /// cores/frequency at every telemetry drain.
    pub server_autoscale: bool,
    /// When the server policy last stepped — on a multi-tenant host the
    /// per-slot drains would otherwise step it N× per interval.
    last_server_autoscale: SimTime,
    // Lazily refreshed (cores, P-state) operating-point caches; `None`
    // until first use, rebuilt whenever the public `client`/`server`
    // settings move (checked by key every tick — two integer compares).
    client_op: Option<OpPointCache>,
    server_op: Option<OpPointCache>,
    // Fleet-interval accumulators (reset by `drain_fleet_interval`; unused
    // and unbounded-but-cheap in single-session worlds).
    fleet_moved: Bytes,
    fleet_time: SimDuration,
    fleet_load: f64,
    fleet_server_load: f64,
    fleet_ticks: u32,
    fleet_energy_start: Energy,
}

impl Host {
    /// Assemble the host for a testbed. `client` is the initial client CPU
    /// setting (Alg. 1 lines 14–20, or a fleet policy's choice).
    pub fn new(testbed: &Testbed, client: CpuState) -> Self {
        Host {
            client,
            server: CpuState::performance(testbed.server_cpu.clone()),
            client_power: standard_power(&testbed.client_cpu),
            server_power: standard_power(&testbed.server_cpu),
            client_rapl: RaplMeter::new(),
            client_node: NodeMeter::new(testbed.client_base_power),
            server_rapl: RaplMeter::new(),
            wall_meter: testbed.wall_meter,
            server_autoscale: false,
            last_server_autoscale: SimTime::ZERO,
            client_op: None,
            server_op: None,
            fleet_moved: Bytes::ZERO,
            fleet_time: SimDuration::ZERO,
            fleet_load: 0.0,
            fleet_server_load: 0.0,
            fleet_ticks: 0,
            fleet_energy_start: Energy::ZERO,
        }
    }

    /// Client energy according to the testbed's instrument (RAPL package
    /// or wall meter).
    pub fn client_energy(&self) -> Energy {
        if self.wall_meter {
            self.client_node.total()
        } else {
            self.client_rapl.total()
        }
    }

    /// Server package energy so far.
    pub fn server_energy(&self) -> Energy {
        self.server_rapl.total()
    }

    /// True when the client instrument is the wall meter.
    pub fn wall_meter(&self) -> bool {
        self.wall_meter
    }

    /// Average power of the client at an arbitrary hypothetical setting —
    /// exposed for the predictive governor's candidate evaluation.
    pub fn client_power_model(&self) -> &PowerModel {
        &self.client_power
    }

    /// Rebuild the operating-point caches if either CPU setting moved
    /// since the last tick (tuning algorithms and fleet policies mutate
    /// the public `client`/`server` fields directly, so the caches key on
    /// the setting rather than relying on invalidation hooks).
    fn refresh_op_caches(&mut self) {
        let ckey = (self.client.active_cores(), self.client.freq_index());
        if self.client_op.as_ref().map(|c| c.key) != Some(ckey) {
            self.client_op = Some(OpPointCache::build(&self.client, &self.client_power));
        }
        let skey = (self.server.active_cores(), self.server.freq_index());
        if self.server_op.as_ref().map(|c| c.key) != Some(skey) {
            self.server_op = Some(OpPointCache::build(&self.server, &self.server_power));
        }
    }

    /// End-system throughput ceiling (bytes/s) at the current CPU
    /// settings, given the aggregate request rate and open-stream count of
    /// every session on the host.
    ///
    /// Warm-batch contract: apart from the lazy op-point cache refresh
    /// (keyed on `(cores, P-state)`, pure memoization) this is a pure
    /// function of the CPU settings and the demand — same input bits,
    /// same output bits. The warm-epoch batched stepper relies on that:
    /// with knobs and demand frozen it reads the capacity once per
    /// batch instead of once per tick.
    pub fn capacity_bytes_per_sec(&mut self, requests_per_sec: f64, open_streams: f64) -> f64 {
        self.refresh_op_caches();
        let client = self.client_op.as_ref().unwrap().achievable(
            &self.client,
            requests_per_sec,
            open_streams,
        );
        let server = self.server_op.as_ref().unwrap().achievable(
            &self.server,
            requests_per_sec,
            open_streams,
        );
        client.min(server)
    }

    /// One tick of load/power/meter accounting for the aggregate demand of
    /// every session on the host.
    ///
    /// This is the *only* per-tick host mutation: the meters integrate
    /// (RAPL sampling included), so it must run once per simulated tick
    /// even inside a warm-batched epoch — the batch hoists everything
    /// else but replays this call tick-for-tick, which is what keeps
    /// the energy books bit-identical to the naive stepper.
    pub fn record_tick(
        &mut self,
        now: SimTime,
        demand: &CpuDemand,
        moved: Bytes,
        dt: SimDuration,
    ) -> HostTick {
        self.refresh_op_caches();
        let client_op = self.client_op.as_ref().unwrap();
        let server_op = self.server_op.as_ref().unwrap();
        let client_load = client_op.load(&self.client, demand);
        let server_load = server_op.load(&self.server, demand);

        let client_power = client_op.power.power(client_load, demand.bytes_per_sec);
        let server_power = server_op.power.power(server_load, demand.bytes_per_sec);
        self.client_rapl.record(now, client_power, dt);
        self.client_node.record(now, client_power, dt);
        self.server_rapl.record(now, server_power, dt);

        let package_energy_j = client_power.over(dt).as_joules();
        let instrument_energy_j = if self.wall_meter {
            (client_power + self.client_node.base()).over(dt).as_joules()
        } else {
            package_energy_j
        };

        self.fleet_moved += moved;
        self.fleet_time += dt;
        self.fleet_load += client_load.min(4.0);
        self.fleet_server_load += server_load.min(4.0);
        self.fleet_ticks += 1;

        HostTick {
            client_load,
            server_load,
            client_power,
            server_power,
            instrument_energy_j,
            package_energy_j,
        }
    }

    /// Rate-limited server scaling: steps at most once per `interval`, so
    /// N tenants draining telemetry independently still walk the server
    /// at the single-session cadence.
    pub fn maybe_autoscale_server(
        &mut self,
        now: SimTime,
        interval: SimDuration,
        avg_load: f64,
    ) {
        if now.since(self.last_server_autoscale).as_secs() + 1e-9 >= interval.as_secs() {
            self.autoscale_server(avg_load);
            self.last_server_autoscale = now;
        }
    }

    /// One Algorithm-3 threshold step on the *server* CPU, driven by the
    /// interval-average server load (the `server_autoscale` extension).
    pub fn autoscale_server(&mut self, avg_load: f64) {
        let th = LoadThresholds::default();
        if avg_load > th.max_load {
            if !self.server.increase_cores() {
                self.server.increase_freq();
            }
        } else if avg_load < th.min_load {
            if !self.server.decrease_freq() {
                self.server.decrease_cores();
            }
        }
    }

    /// The cheapest client operating point able to carry `demand`, and
    /// the client package power it would draw there — `None` when the
    /// demand exceeds what even the maximum point can serve.
    ///
    /// Scans the full (active cores, P-state) grid, pricing each point
    /// with the same frozen [`crate::power::OpPointPower`] coefficients
    /// ([`PowerModel::at`]) the epoch-cached stepper uses, so projections
    /// are consistent with what the meters will record once a
    /// load-tracking policy settles there. This is the primitive behind
    /// the multi-host dispatcher's marginal-energy placement
    /// (GreenDataFlow, arXiv:1810.05892): a candidate host is scored by
    /// the delta between this projection at its post-placement demand and
    /// at its current demand. Callers that need a number even for
    /// infeasible demand combine it with [`Self::saturated_client_point`].
    pub fn min_client_power_for(&self, demand: &CpuDemand) -> Option<ProjectedPoint> {
        let spec = self.client.spec();
        let mut best: Option<ProjectedPoint> = None;
        for cores in 1..=spec.num_cores {
            for &f in &spec.freq_levels {
                let cap = spec.achievable_bytes_per_sec(
                    cores,
                    f,
                    demand.requests_per_sec,
                    demand.open_streams,
                    MAX_APP_UTILIZATION,
                );
                if cap + 1e-9 < demand.bytes_per_sec {
                    continue;
                }
                let load = spec.load(demand, cores, f);
                let power =
                    self.client_power.at(cores, f).power(load, demand.bytes_per_sec);
                let better = match &best {
                    Some(b) => power < b.power,
                    None => true,
                };
                if better {
                    best = Some(ProjectedPoint { power, cores, freq: f });
                }
            }
        }
        best
    }

    /// The maximum client operating point under `demand`, with its
    /// (clamped-load) power — what the host would actually run at if
    /// asked to serve more than it can: it saturates there.
    pub fn saturated_client_point(&self, demand: &CpuDemand) -> ProjectedPoint {
        let spec = self.client.spec();
        let cores = spec.num_cores;
        let f = spec.max_freq();
        let load = spec.load(demand, cores, f);
        ProjectedPoint {
            power: self.client_power.at(cores, f).power(load, demand.bytes_per_sec),
            cores,
            freq: f,
        }
    }

    /// [`Self::min_client_power_for`] expressed on the testbed's
    /// *instrument*: wall-metered hosts (DIDCLab) add the always-on
    /// platform base to the projected package draw, RAPL hosts report the
    /// package alone — the same convention [`Self::record_tick`] bills
    /// under. Infeasible demand is priced at the saturated maximum point.
    /// The dispatcher's fleet power cap compares aggregates of this
    /// quantity.
    pub fn projected_instrument_power(&self, demand: &CpuDemand) -> Power {
        let pkg = self
            .min_client_power_for(demand)
            .unwrap_or_else(|| self.saturated_client_point(demand))
            .power;
        if self.wall_meter {
            pkg + self.client_node.base()
        } else {
            pkg
        }
    }

    /// Read and reset the fleet-interval accumulators — called by the
    /// fleet driver at each arbitration timeout.
    pub fn drain_fleet_interval(&mut self, now: SimTime, active_sessions: u32) -> FleetView {
        let interval_energy = self.client_energy().saturating_sub(self.fleet_energy_start);
        let view = FleetView {
            now,
            active_sessions,
            avg_load: if self.fleet_ticks == 0 {
                0.0
            } else {
                self.fleet_load / self.fleet_ticks as f64
            },
            avg_server_load: if self.fleet_ticks == 0 {
                0.0
            } else {
                self.fleet_server_load / self.fleet_ticks as f64
            },
            avg_throughput: Rate::average(self.fleet_moved, self.fleet_time),
            avg_power: interval_energy.average_power(self.fleet_time),
        };
        self.fleet_moved = Bytes::ZERO;
        self.fleet_time = SimDuration::ZERO;
        self.fleet_load = 0.0;
        self.fleet_server_load = 0.0;
        self.fleet_ticks = 0;
        self.fleet_energy_start = self.client_energy();
        view
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::units::Freq;

    fn host(testbed: &str) -> Host {
        let tb = testbeds::by_name(testbed).unwrap();
        let client = CpuState::performance(tb.client_cpu.clone());
        Host::new(&tb, client)
    }

    #[test]
    fn wall_meter_host_reports_node_energy() {
        let mut h = host("didclab");
        let demand =
            CpuDemand { bytes_per_sec: 50e6, requests_per_sec: 10.0, open_streams: 4.0 };
        let dt = SimDuration::from_millis(100.0);
        let mut t = SimTime::ZERO;
        for _ in 0..50 {
            h.record_tick(t, &demand, Bytes::from_mb(5.0), dt);
            t += dt;
        }
        assert!(h.client_energy() > h.client_rapl.total(), "wall > package");
        // The per-tick instrument energy matches the meter's integral.
        let ht = h.record_tick(t, &demand, Bytes::from_mb(5.0), dt);
        assert!(ht.instrument_energy_j > ht.package_energy_j);
    }

    #[test]
    fn rapl_host_instrument_is_package() {
        let mut h = host("cloudlab");
        let demand =
            CpuDemand { bytes_per_sec: 50e6, requests_per_sec: 10.0, open_streams: 4.0 };
        let ht = h.record_tick(
            SimTime::ZERO,
            &demand,
            Bytes::from_mb(5.0),
            SimDuration::from_millis(100.0),
        );
        assert_eq!(ht.instrument_energy_j, ht.package_energy_j);
        assert_eq!(h.client_energy(), h.client_rapl.total());
    }

    #[test]
    fn capacity_is_min_of_both_ends() {
        let mut h = host("cloudlab");
        let cap = h.capacity_bytes_per_sec(10.0, 8.0);
        let client = h.client.spec().achievable_bytes_per_sec(
            h.client.active_cores(),
            h.client.freq(),
            10.0,
            8.0,
            MAX_APP_UTILIZATION,
        );
        let server = h.server.spec().achievable_bytes_per_sec(
            h.server.active_cores(),
            h.server.freq(),
            10.0,
            8.0,
            MAX_APP_UTILIZATION,
        );
        assert_eq!(cap, client.min(server));
        assert!(cap > 0.0);
    }

    #[test]
    fn op_point_cache_matches_fresh_computation() {
        // The lazily cached loads/powers/capacities must equal the direct
        // spec/model computations bit-for-bit, across setting changes
        // (which exercise the rebuild-on-key-change path).
        let mut h = host("didclab");
        let demand =
            CpuDemand { bytes_per_sec: 80e6, requests_per_sec: 15.0, open_streams: 6.0 };
        let dt = SimDuration::from_millis(100.0);
        let mut t = SimTime::ZERO;
        for step in 0..6 {
            let expect_client_load =
                h.client.spec().load(&demand, h.client.active_cores(), h.client.freq());
            let expect_client_power = h.client_power_model().package_power(
                h.client.active_cores(),
                h.client.freq(),
                expect_client_load,
                demand.bytes_per_sec,
            );
            let expect_cap = {
                let client = h.client.spec().achievable_bytes_per_sec(
                    h.client.active_cores(),
                    h.client.freq(),
                    demand.requests_per_sec,
                    demand.open_streams,
                    MAX_APP_UTILIZATION,
                );
                let server = h.server.spec().achievable_bytes_per_sec(
                    h.server.active_cores(),
                    h.server.freq(),
                    demand.requests_per_sec,
                    demand.open_streams,
                    MAX_APP_UTILIZATION,
                );
                client.min(server)
            };
            let cap = h.capacity_bytes_per_sec(demand.requests_per_sec, demand.open_streams);
            assert_eq!(cap.to_bits(), expect_cap.to_bits(), "capacity at step {step}");
            let ht = h.record_tick(t, &demand, Bytes::from_mb(8.0), dt);
            assert_eq!(ht.client_load.to_bits(), expect_client_load.to_bits());
            assert_eq!(
                ht.client_power.as_watts().to_bits(),
                expect_client_power.as_watts().to_bits()
            );
            t += dt;
            // Walk the settings so the cache must rebuild mid-test.
            if step % 2 == 0 {
                h.client.decrease_freq();
            } else {
                h.client.decrease_cores();
                h.server.decrease_freq();
            }
        }
    }

    #[test]
    fn autoscale_server_walks_thresholds() {
        let tb = testbeds::cloudlab();
        let mut h = Host::new(&tb, CpuState::performance(tb.client_cpu.clone()));
        // Server starts at the performance setting: max cores, max freq.
        assert!(h.server.at_max_cores() && h.server.at_max_freq());
        // Low aggregate load sheds frequency first, then cores.
        h.autoscale_server(0.1);
        assert!(!h.server.at_max_freq(), "frequency drops first");
        let cores0 = h.server.active_cores();
        while !h.server.at_min_freq() {
            h.autoscale_server(0.1);
        }
        assert_eq!(h.server.active_cores(), cores0, "cores held while freq can drop");
        h.autoscale_server(0.1);
        assert_eq!(h.server.active_cores(), cores0 - 1, "cores drop at min freq");
        // High load grows cores first, then frequency.
        while !h.server.at_max_cores() {
            h.autoscale_server(0.95);
        }
        assert!(h.server.at_min_freq(), "freq untouched while cores remain");
        h.autoscale_server(0.95);
        assert!(h.server.freq() > h.server.spec().min_freq());
        // Mid-band load holds steady.
        let setting = (h.server.active_cores(), h.server.freq());
        h.autoscale_server(0.6);
        assert_eq!((h.server.active_cores(), h.server.freq()), setting);
    }

    #[test]
    fn maybe_autoscale_is_rate_limited_per_interval() {
        let tb = testbeds::cloudlab();
        let mut h = Host::new(&tb, CpuState::performance(tb.client_cpu.clone()));
        let interval = SimDuration::from_secs(3.0);
        let f0 = h.server.freq();
        // First drain of the interval steps the server…
        h.maybe_autoscale_server(SimTime::from_secs(3.0), interval, 0.1);
        let f1 = h.server.freq();
        assert!(f1 < f0);
        // …but other tenants draining inside the same window do not.
        h.maybe_autoscale_server(SimTime::from_secs(4.0), interval, 0.1);
        h.maybe_autoscale_server(SimTime::from_secs(5.0), interval, 0.1);
        assert_eq!(h.server.freq(), f1, "at most one step per interval");
        // The next window steps again.
        h.maybe_autoscale_server(SimTime::from_secs(6.0), interval, 0.1);
        assert!(h.server.freq() < f1);
    }

    #[test]
    fn fleet_interval_drains_and_resets() {
        let mut h = host("cloudlab");
        let demand =
            CpuDemand { bytes_per_sec: 100e6, requests_per_sec: 20.0, open_streams: 8.0 };
        let dt = SimDuration::from_millis(100.0);
        let mut t = SimTime::ZERO;
        for _ in 0..30 {
            h.record_tick(t, &demand, Bytes::from_mb(10.0), dt);
            t += dt;
        }
        let view = h.drain_fleet_interval(t, 3);
        assert_eq!(view.active_sessions, 3);
        assert!(view.avg_load > 0.0);
        assert!(view.avg_power.as_watts() > 0.0);
        assert!((view.avg_throughput.as_bytes_per_sec() - 100e6).abs() / 100e6 < 1e-9);
        // Second drain covers an empty interval.
        let empty = h.drain_fleet_interval(t, 3);
        assert_eq!(empty.avg_load, 0.0);
        assert_eq!(empty.avg_throughput, Rate::ZERO);
    }

    #[test]
    fn min_power_projection_picks_cheapest_feasible_point() {
        let h = host("cloudlab");
        // Idle demand: the floor of the grid wins.
        let idle = h.min_client_power_for(&CpuDemand::default()).unwrap();
        assert_eq!(idle.cores, 1);
        assert_eq!(idle.freq, h.client.spec().min_freq());
        // ~1 Gbps of goodput still fits low operating points on Broadwell
        // and must cost more than idle.
        let demand =
            CpuDemand { bytes_per_sec: 115e6, requests_per_sec: 0.0, open_streams: 5.0 };
        let p = h.min_client_power_for(&demand).unwrap();
        assert!(p.power > idle.power);
        let spec = h.client.spec().clone();
        // The chosen point can actually carry the demand…
        let cap = spec.achievable_bytes_per_sec(p.cores, p.freq, 0.0, 5.0, MAX_APP_UTILIZATION);
        assert!(cap + 1e-9 >= demand.bytes_per_sec);
        // …and no feasible grid point is cheaper.
        for cores in 1..=spec.num_cores {
            for &f in &spec.freq_levels {
                let cap =
                    spec.achievable_bytes_per_sec(cores, f, 0.0, 5.0, MAX_APP_UTILIZATION);
                if cap + 1e-9 < demand.bytes_per_sec {
                    continue;
                }
                let load = spec.load(&demand, cores, f);
                let w = h.client_power_model().at(cores, f).power(load, demand.bytes_per_sec);
                assert!(w >= p.power, "{cores} cores @ {f}: {w:?} beats {:?}", p.power);
            }
        }
    }

    #[test]
    fn min_power_projection_is_monotone_in_demanded_goodput() {
        // More demanded bytes/s can never get cheaper: the feasible set
        // only shrinks, so the chosen minimum power is non-decreasing.
        let h = host("cloudlab");
        let mut last = Power::ZERO;
        let mut bps = 1e6;
        while let Some(p) = h.min_client_power_for(&CpuDemand {
            bytes_per_sec: bps,
            requests_per_sec: 10.0,
            open_streams: 6.0,
        }) {
            assert!(
                p.power >= last,
                "power must not drop as demand grows: {:?} after {last:?} at {bps} B/s",
                p.power
            );
            last = p.power;
            bps *= 1.5;
            assert!(bps < 1e13, "demand must eventually become infeasible");
        }
    }

    #[test]
    fn min_power_projection_agrees_with_the_power_model_at_its_point() {
        // The returned power must be exactly PowerModel::at(...).power at
        // the chosen op point — the same coefficients the meters bill.
        let h = host("didclab");
        for bps in [0.0, 20e6, 60e6, 110e6] {
            let demand =
                CpuDemand { bytes_per_sec: bps, requests_per_sec: 5.0, open_streams: 4.0 };
            let p = h.min_client_power_for(&demand).unwrap();
            let spec = h.client.spec();
            let load = spec.load(&demand, p.cores, p.freq);
            let direct = h.client_power_model().at(p.cores, p.freq).power(load, bps);
            assert_eq!(
                p.power.as_watts().to_bits(),
                direct.as_watts().to_bits(),
                "projection must match PowerModel::at at {bps} B/s"
            );
        }
    }

    #[test]
    fn infeasible_demand_returns_none_and_saturates_the_instrument() {
        let h = host("didclab");
        let demand = CpuDemand { bytes_per_sec: 1e12, ..CpuDemand::default() };
        assert!(
            h.min_client_power_for(&demand).is_none(),
            "demand beyond host capacity has no feasible point"
        );
        // The saturated fallback prices the maximum point; the instrument
        // projection uses it (plus the wall base on DIDCLab).
        let sat = h.saturated_client_point(&demand);
        assert_eq!(sat.cores, h.client.spec().num_cores);
        assert_eq!(sat.freq, h.client.spec().max_freq());
        assert_eq!(
            h.projected_instrument_power(&demand),
            sat.power + h.client_node.base()
        );
    }

    #[test]
    fn wall_meter_projection_includes_platform_base() {
        let didclab = host("didclab");
        let d = CpuDemand::default();
        assert!(
            didclab.projected_instrument_power(&d)
                > didclab.min_client_power_for(&d).unwrap().power,
            "wall instrument adds the platform base"
        );
        let cloudlab = host("cloudlab");
        assert_eq!(
            cloudlab.projected_instrument_power(&d),
            cloudlab.min_client_power_for(&d).unwrap().power
        );
    }

    #[test]
    fn eco_setting_draws_less_power_than_performance() {
        let tb = testbeds::cloudlab();
        let mut perf = Host::new(&tb, CpuState::performance(tb.client_cpu.clone()));
        let mut eco = Host::new(&tb, CpuState::new(tb.client_cpu.clone(), 1, Freq::from_ghz(1.2)));
        let demand =
            CpuDemand { bytes_per_sec: 10e6, requests_per_sec: 5.0, open_streams: 2.0 };
        let dt = SimDuration::from_millis(100.0);
        let a = perf.record_tick(SimTime::ZERO, &demand, Bytes::from_mb(1.0), dt);
        let b = eco.record_tick(SimTime::ZERO, &demand, Bytes::from_mb(1.0), dt);
        assert!(a.client_power.as_watts() > 1.5 * b.client_power.as_watts());
    }
}
