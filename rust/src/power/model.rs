//! CMOS package power model.

use crate::cpusim::CpuSpec;
use crate::units::{Freq, Power};

/// Parameters of the package power model (per CPU micro-architecture).
#[derive(Debug, Clone)]
pub struct PowerParams {
    /// Uncore + LLC + memory controller static draw, W.
    pub pkg_static_w: f64,
    /// Per-active-core idle draw at min frequency, W.
    pub core_idle_base_w: f64,
    /// Additional per-core idle draw per GHz (clock tree, leakage w/ f), W.
    pub core_idle_per_ghz_w: f64,
    /// Dynamic coefficient κ in `P_dyn = util · κ · V(f)² · f_GHz`, W.
    pub dyn_kappa: f64,
    /// Core voltage at the bottom / top of the P-state ladder, V.
    pub v_min: f64,
    /// Core voltage at the top of the P-state ladder, V.
    pub v_max: f64,
    /// DRAM power per GB/s of moved data, W (RAPL DRAM domain).
    pub dram_w_per_gbs: f64,
}

/// A CPU spec paired with its power parameters: everything needed to map a
/// (cores, freq, utilization, traffic) operating point to watts.
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// CPU topology / P-state ladder the model covers.
    pub spec: CpuSpec,
    /// The model's power parameters.
    pub params: PowerParams,
}

impl PowerModel {
    /// Pair a CPU spec with its power parameters.
    pub fn new(spec: CpuSpec, params: PowerParams) -> Self {
        PowerModel { spec, params }
    }

    /// Core voltage at frequency `f`: affine across the ladder.
    pub fn voltage(&self, f: Freq) -> f64 {
        let fmin = self.spec.min_freq().as_ghz();
        let fmax = self.spec.max_freq().as_ghz();
        if fmax <= fmin {
            return self.params.v_max;
        }
        let t = ((f.as_ghz() - fmin) / (fmax - fmin)).clamp(0.0, 1.0);
        self.params.v_min + (self.params.v_max - self.params.v_min) * t
    }

    /// Package power at an operating point.
    ///
    /// `utilization` is the average load of the *active* cores in [0, 1];
    /// `bytes_per_sec` feeds the DRAM domain.
    pub fn package_power(
        &self,
        active_cores: u32,
        f: Freq,
        utilization: f64,
        bytes_per_sec: f64,
    ) -> Power {
        let util = utilization.clamp(0.0, 1.0);
        let v = self.voltage(f);
        let per_core_idle =
            self.params.core_idle_base_w + self.params.core_idle_per_ghz_w * f.as_ghz();
        let per_core_dyn = util * self.params.dyn_kappa * v * v * f.as_ghz();
        let dram = self.params.dram_w_per_gbs * (bytes_per_sec / 1e9);
        Power::from_watts(
            self.params.pkg_static_w + active_cores as f64 * (per_core_idle + per_core_dyn) + dram,
        )
    }

    /// Freeze the power computation at one (cores, frequency) operating
    /// point — the per-tick inputs reduce to `utilization` and traffic.
    /// Settings move only at tuning/arbitration timeouts (thousands of
    /// ticks apart), so the epoch-cached stepper rebuilds this once per
    /// setting instead of re-deriving voltage and idle draw every tick.
    /// The multi-host dispatcher prices candidate operating points
    /// through the same coefficients, so placement projections agree
    /// with what the meters will record.
    ///
    /// # Examples
    ///
    /// ```
    /// use greendt::cpusim::standard::haswell_server;
    /// use greendt::power::standard_power;
    /// use greendt::units::Freq;
    ///
    /// let model = standard_power(&haswell_server());
    /// let op = model.at(4, Freq::from_ghz(2.0));
    /// // The frozen coefficients reproduce the full model bit-for-bit.
    /// assert_eq!(
    ///     op.power(0.5, 1e9),
    ///     model.package_power(4, Freq::from_ghz(2.0), 0.5, 1e9),
    /// );
    /// // More utilization at the same point always costs more watts.
    /// assert!(op.power(0.9, 1e9) > op.power(0.1, 1e9));
    /// ```
    pub fn at(&self, active_cores: u32, f: Freq) -> OpPointPower {
        OpPointPower {
            cores: active_cores as f64,
            f_ghz: f.as_ghz(),
            v: self.voltage(f),
            per_core_idle: self.params.core_idle_base_w
                + self.params.core_idle_per_ghz_w * f.as_ghz(),
            kappa: self.params.dyn_kappa,
            static_w: self.params.pkg_static_w,
            dram_w_per_gbs: self.params.dram_w_per_gbs,
        }
    }

    /// Power with every core active at max frequency and full load —
    /// the worst case (and roughly the TDP this model implies).
    pub fn max_power(&self) -> Power {
        self.package_power(self.spec.num_cores, self.spec.max_freq(), 1.0, 0.0)
    }

    /// Idle package power at the lowest setting.
    pub fn floor_power(&self) -> Power {
        self.package_power(1, self.spec.min_freq(), 0.0, 0.0)
    }
}

/// Package-power coefficients frozen at one (active cores, frequency)
/// operating point; see [`PowerModel::at`].
///
/// [`Self::power`] replays [`PowerModel::package_power`]'s arithmetic in
/// the identical order with the per-op-point subexpressions (voltage,
/// per-core idle draw) cached, so results are **bit-identical** — pinned
/// by `cached_op_point_matches_package_power` below. Keep the two bodies
/// in lockstep when editing either.
#[derive(Debug, Clone, Copy)]
pub struct OpPointPower {
    cores: f64,
    f_ghz: f64,
    v: f64,
    per_core_idle: f64,
    kappa: f64,
    static_w: f64,
    dram_w_per_gbs: f64,
}

impl OpPointPower {
    /// Package power at the frozen operating point for this tick's
    /// utilization and traffic.
    pub fn power(&self, utilization: f64, bytes_per_sec: f64) -> Power {
        let util = utilization.clamp(0.0, 1.0);
        let per_core_dyn = util * self.kappa * self.v * self.v * self.f_ghz;
        let dram = self.dram_w_per_gbs * (bytes_per_sec / 1e9);
        Power::from_watts(
            self.static_w + self.cores * (self.per_core_idle + per_core_dyn) + dram,
        )
    }
}

/// Standard power parameters for the paper's CPU models. Calibrated so
/// that: Haswell-EP 8-core full load ≈ 85 W package, idle ≈ 15 W;
/// Bloomfield (45 nm, 2008) is markedly less efficient; Broadwell (14 nm)
/// slightly better than Haswell.
pub fn standard_power(spec: &CpuSpec) -> PowerModel {
    let params = if spec.name.starts_with("Bloomfield") {
        PowerParams {
            pkg_static_w: 17.0,
            core_idle_base_w: 3.6,
            core_idle_per_ghz_w: 1.0,
            dyn_kappa: 3.4,
            v_min: 0.95,
            v_max: 1.30,
            dram_w_per_gbs: 3.0,
        }
    } else if spec.name.starts_with("Broadwell") {
        PowerParams {
            pkg_static_w: 10.0,
            core_idle_base_w: 0.5,
            core_idle_per_ghz_w: 0.28,
            dyn_kappa: 1.7,
            v_min: 0.65,
            v_max: 1.05,
            dram_w_per_gbs: 2.0,
        }
    } else {
        // Haswell default.
        PowerParams {
            pkg_static_w: 12.0,
            core_idle_base_w: 0.6,
            core_idle_per_ghz_w: 0.30,
            dyn_kappa: 1.9,
            v_min: 0.70,
            v_max: 1.10,
            dram_w_per_gbs: 2.2,
        }
    };
    PowerModel::new(spec.clone(), params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpusim::standard::*;

    #[test]
    fn haswell_envelope_is_realistic() {
        let m = standard_power(&haswell_server());
        let max = m.max_power().as_watts();
        let idle = m.floor_power().as_watts();
        assert!(max > 70.0 && max < 110.0, "max {max} W");
        assert!(idle > 10.0 && idle < 20.0, "idle {idle} W");
    }

    #[test]
    fn power_monotone_in_frequency() {
        let m = standard_power(&haswell_server());
        let mut prev = 0.0;
        for &f in &m.spec.freq_levels.clone() {
            let p = m.package_power(4, f, 0.7, 1e9).as_watts();
            assert!(p > prev, "power must rise with f: {p} after {prev}");
            prev = p;
        }
    }

    #[test]
    fn power_monotone_in_cores_and_util() {
        let m = standard_power(&broadwell_client());
        let f = Freq::from_ghz(2.0);
        assert!(m.package_power(4, f, 0.5, 0.0) > m.package_power(2, f, 0.5, 0.0));
        assert!(m.package_power(4, f, 0.9, 0.0) > m.package_power(4, f, 0.2, 0.0));
    }

    #[test]
    fn frequency_scaling_is_superlinear() {
        // Doubling f should more than double the *dynamic* term (V rises too).
        let m = standard_power(&haswell_server());
        let lo = Freq::from_ghz(1.6);
        let hi = Freq::from_ghz(3.2);
        let p_lo = m.package_power(1, lo, 1.0, 0.0).as_watts() - m.package_power(1, lo, 0.0, 0.0).as_watts();
        let p_hi = m.package_power(1, hi, 1.0, 0.0).as_watts() - m.package_power(1, hi, 0.0, 0.0).as_watts();
        assert!(p_hi > 2.2 * p_lo, "dynamic power superlinear: {p_hi} vs {p_lo}");
    }

    #[test]
    fn bloomfield_less_efficient_than_haswell() {
        let hw = standard_power(&haswell_client());
        let bf = standard_power(&bloomfield_client());
        // Same work (1 core, ~2.4 GHz-ish, full util): Bloomfield burns more.
        let p_hw = hw.package_power(1, Freq::from_ghz(2.4), 1.0, 0.5e9).as_watts();
        let p_bf = bf.package_power(1, Freq::from_ghz(2.4), 1.0, 0.5e9).as_watts();
        assert!(p_bf > 1.4 * p_hw, "bloomfield {p_bf} vs haswell {p_hw}");
    }

    #[test]
    fn voltage_clamps_at_ladder_ends() {
        let m = standard_power(&haswell_server());
        assert_eq!(m.voltage(Freq::from_ghz(0.1)), m.params.v_min);
        assert_eq!(m.voltage(Freq::from_ghz(9.9)), m.params.v_max);
    }

    #[test]
    fn cached_op_point_matches_package_power() {
        // The epoch-cached coefficients must reproduce `package_power`
        // bit-for-bit across the whole operating envelope.
        for spec in [haswell_server(), broadwell_client(), bloomfield_client()] {
            let m = standard_power(&spec);
            for cores in 1..=spec.num_cores {
                for &f in &spec.freq_levels.clone() {
                    let op = m.at(cores, f);
                    for util in [0.0, 0.13, 0.5, 0.97, 1.0, 3.7] {
                        for bps in [0.0, 12.5e6, 1.1e9] {
                            let fresh = m.package_power(cores, f, util, bps);
                            let cached = op.power(util, bps);
                            assert_eq!(
                                fresh.as_watts().to_bits(),
                                cached.as_watts().to_bits(),
                                "{} {cores} cores @ {f} util {util} bps {bps}",
                                spec.name
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn utilization_clamped() {
        let m = standard_power(&haswell_server());
        let a = m.package_power(2, Freq::from_ghz(2.0), 5.0, 0.0);
        let b = m.package_power(2, Freq::from_ghz(2.0), 1.0, 0.0);
        assert_eq!(a, b);
    }
}
